"""Benchmark: probabilistic-convolution throughput (paper §Results).

Two comparisons:
  1. the ANALOG machine's rated throughput (26.7e9 prob-conv/s, 37.5 ps
     latency, 1.28 Tbit/s interface) — constants of the physical design;
  2. the DIGITAL cost of the same operation on this host: per-conv wall
     time of (a) the PRNG-bound naive path (sample weights + conv) and
     (b) the fused Pallas/jnp kernel path with an external entropy
     stream — demonstrating the sampling bottleneck the paper removes.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.photonic import conv_throughput_estimate
from repro.kernels import ops, ref


def _time(f, *args, iters=20):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else \
        jax.block_until_ready(f(*args))
    t0 = time.time()
    for _ in range(iters):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters


def run(quick: bool = False) -> dict:
    B, T, C = (256, 128, 9) if quick else (1024, 256, 9)
    key = jax.random.key(0)
    x = jax.random.uniform(key, (B, T), minval=-1, maxval=1)
    mu = jnp.linspace(-0.5, 0.5, C)
    sigma = jnp.abs(mu) * 0.2
    To = T - C + 1

    # (a) naive: PRNG inside the step (the digital bottleneck)
    @jax.jit
    def naive(x, key):
        eps = jax.random.normal(key, (B, To, C))      # PRNG in the path
        return ref.photonic_conv(x, mu, sigma, eps)

    # (b) fused path: entropy is a pre-drawn external stream
    eps = jax.random.normal(jax.random.key(1), (B, To, C))

    @jax.jit
    def fused(x, eps):
        return ref.photonic_conv(x, mu, sigma, eps)

    # (c) seeded in-kernel path: on TPU the eps tensor never exists
    # (kernels/photonic_conv draws per-symbol variates in-register);
    # here the seeded oracle stands in.
    @jax.jit
    def seeded(x, seed):
        return ops.photonic_conv_sampled(x, mu, sigma, seed, impl="auto")

    seed = jnp.asarray(7, jnp.int32)
    t_naive = _time(lambda a, b: naive(a, b), x, key)
    t_fused = _time(lambda a, b: fused(a, b), x, eps)
    t_seeded = _time(lambda a, b: seeded(a, b), x, seed)
    n_convs = B * To
    analog = conv_throughput_estimate()
    in_kernel = jax.default_backend() == "tpu"
    return {
        "analog_conv_per_s": analog["conv_per_s"],
        "analog_latency_ps": analog["latency_ps"],
        "interface_tbit_s": analog["interface_tbit_s"],
        "digital_naive_conv_per_s": n_convs / t_naive,
        "digital_fused_conv_per_s": n_convs / t_fused,
        "digital_seeded_conv_per_s": n_convs / t_seeded,
        "prng_overhead_x": t_naive / t_fused,
        "entropy_bytes_operand": ops.entropy_bytes(
            "conv", num_samples=1, b=B, t_out=To, c=C),
        "entropy_bytes_in_kernel": ops.entropy_bytes(
            "conv", num_samples=1, b=B, t_out=To, c=C,
            in_kernel=in_kernel),
    }


def main(quick: bool = False):
    r = run(quick)
    print("probabilistic convolution throughput (paper §Results)")
    print(f"  analog machine:    {r['analog_conv_per_s'] / 1e9:8.1f} G conv/s"
          f"   ({r['analog_latency_ps']} ps/conv, "
          f"{r['interface_tbit_s']:.2f} Tbit/s interface)")
    print(f"  digital naive:     "
          f"{r['digital_naive_conv_per_s'] / 1e6:8.1f} M conv/s (PRNG in path)")
    print(f"  digital fused:     "
          f"{r['digital_fused_conv_per_s'] / 1e6:8.1f} M conv/s "
          f"(external entropy)")
    print(f"  digital seeded:    "
          f"{r['digital_seeded_conv_per_s'] / 1e6:8.1f} M conv/s "
          f"(in-kernel on TPU)")
    print(f"  PRNG overhead removed by the machine: "
          f"{r['prng_overhead_x']:.2f}x")
    print(f"  entropy over HBM per batch: "
          f"{r['entropy_bytes_operand'] / 1e6:.1f} MB operand -> "
          f"{r['entropy_bytes_in_kernel'] / 1e6:.1f} MB in-kernel")
    return r


if __name__ == "__main__":
    main()
