"""Benchmark: machine computation error (paper Fig. 2c/d) + calibration.

Reproduces the paper's accuracy characterization: program 25 random
probabilistic kernels, measure output-distribution moments over repeated
shots, report normalized mean/std errors against the analytic target.
Paper: 0.158 (mean), 0.266 (std).
"""

from __future__ import annotations

import time

import jax

from repro.core import photonic as PH


def run(quick: bool = False) -> dict:
    key = jax.random.key(42)
    t0 = time.time()
    r = PH.computation_error(
        key, n_kernels=8 if quick else 25,
        n_shots=256 if quick else 512,
        seq_len=48 if quick else 64)
    dt = time.time() - t0

    _, hist = PH.calibrate(
        jax.random.key(1),
        target_mu=jax.numpy.linspace(-0.7, 0.7, 9),
        target_sigma=jax.numpy.abs(jax.numpy.linspace(-0.7, 0.7, 9)) * 0.2,
        iters=6 if quick else 12, n_shots=128 if quick else 256)

    return {
        "mean_error": r["mean_error"],
        "std_error": r["std_error"],
        "paper_mean_error": r["paper_mean_error"],
        "paper_std_error": r["paper_std_error"],
        "calib_mu_err_first": hist["mu_err"][0],
        "calib_mu_err_last": hist["mu_err"][-1],
        "wall_s": dt,
    }


def main(quick: bool = False):
    r = run(quick)
    print("photonic machine computation error (paper Fig. 2c/d)")
    print(f"  mean error: {r['mean_error']:.3f}   "
          f"(paper: {r['paper_mean_error']})")
    print(f"  std  error: {r['std_error']:.3f}   "
          f"(paper: {r['paper_std_error']})")
    print(f"  calibration |mu err|: {r['calib_mu_err_first']:.4f} -> "
          f"{r['calib_mu_err_last']:.4f}")
    return r


if __name__ == "__main__":
    main()
