"""Benchmark: continuous-batching scan-decode engine vs per-token loop.

The serving analog of the paper's headline numbers (37.5 ps/convolution,
1.28 Tbit/s interface): how fast can the stack emit uncertainty-gated
tokens?  Both paths run the identical model + MC head; they differ only
in drive: the baseline dispatches one jitted step and syncs the host per
token (the pre-engine ``serve`` driver, kept as
``launch.serve.decode_loop_reference``), the engine decodes ``chunk``
tokens per device call inside ``jax.lax.scan`` and syncs once per chunk,
with requests continuously admitted/evicted over a slot-indexed KV
cache.  Compilation is excluded on both sides (steady-state dispatch is
what serving pays per token).

A second, MIXED-LENGTH workload drives the paged KV allocator
(``--kv-layout paged``): requests with heterogeneous prompt and
generation lengths run through the dense-strip reference layout and the
paged layout, and the row reports peak KV bytes actually resident
(mapped blocks) against the dense ``slots * max_len`` strips at the
measured decode throughput of each.

Two PREFIX-CACHE workloads drive the radix tree over the paged pool
(``--prefix-cache on``): ``prefix_shared_prompt`` (every request opens
with the same system-prompt tokens, diverging mid-block so hits take
the copy-on-write path) and ``sample_fanout`` (S identical prompts —
the Monte-Carlo fanout the paper's photonic sampling makes cheap; the
digital side amortizes the prefill).  Each row reports prefill tokens
saved, hit rate, CoW copies, and decode tok/s warm vs cold.

A LONG-PROMPT workload (``long_prompt`` row) staggers one outlier
request with a prompt ~10x the steady traffic into a stream of short
decoders: under ``--prefill batch`` its monolithic prefill stalls every
running stream for the whole prompt; under ``--prefill chunked`` the
prefill interleaves with decode in ``--prefill-chunk`` slices, bounding
the worst decode-token inter-arrival gap near one chunk's compute.  The
outlier's prompt + gen also exceeds the admission-time table span, so
finishing it exercises on-demand block-table growth.

Writes ``BENCH_serve.json`` (next to ``BENCH_kernels.json``, the CI
perf-trajectory artifacts).  The file is stamped ONCE, at the top
level, with the ``git_sha`` and a ``config_hash`` over the arch config
plus every workload's knobs (the knobs themselves live in each row, so
rows stay distinguishable without per-row re-stamping).  Fields:

  shapes                 {slots, chunk, prompt_len, gen_len, num_requests}
  backend                jax backend the numbers were taken on
  timings_indicative     True off-TPU (CPU dispatch dominates)
  baseline_tok_per_s     per-token-loop decode throughput (1 sync/token)
  engine_tok_per_s       scan-decode engine decode throughput
  speedup_scan_x         engine_tok_per_s / baseline_tok_per_s (>= 2x
                         is the acceptance bar on the reduced CPU config)
  engine_e2e_tok_per_s   engine end to end: prefills + scheduling + decode
  latency_p50_s, latency_p99_s, latency_max_s
                         per-request submit->finish latency; the p99 is
                         nearest-rank (a latency some request actually
                         experienced — no interpolated tail at small N)
  prefill_compile_s      first jitted prefill call (includes tracing+XLA)
  prefill_steady_s       mean steady-state per-request prefill
  flags_per_1k_tokens    {epistemic, aleatoric} gating rates of the run
  entropy_mode           head-draw stream ('operand': the CPU parity path)
  mixed                  mixed-length dense-vs-paged row:
    kv_block, max_len, prompt_lens/gen_lens of the workload,
    dense_tok_per_s / paged_tok_per_s (+ paged_vs_dense_x),
    kv_bytes_dense_strips   what the dense layout keeps resident,
    kv_bytes_paged_peak     peak mapped paged blocks in bytes,
    kv_bytes_saved_frac     1 - paged_peak / dense_strips,
    blocks_peak / blocks_total   pool utilization high-water mark
  decode_attn            block-sparse decode-attention row (paged):
    gather_kv_bytes_per_step    KV bytes/step of the full-span gather,
    kernel_kv_bytes_per_step    KV bytes/step the block-sparse kernel
                                reads (scales with tokens cached),
    kv_bytes_saved_frac, kernel_vs_gather_x (tok/s at parity streams)
  prefix_shared_prompt   shared-system-prompt row (prefix cache on):
    shared_len / unique_len / num_requests of the workload,
    hit_rate, prefill_tokens_saved_frac (acceptance: >= 0.5),
    cow_copies, warm_tok_per_s / cold_tok_per_s
  sample_fanout          S-identical-prompt row: same fields, plus
    samples (the MC fanout width)
  mesh_scaling           sharded-runner row (subprocess: the forced
                         4-device CPU mesh must be pinned before jax
                         initializes):
    mesh, devices          the --mesh shape and forced device count,
    bitwise_equal          sharded stream == unsharded stream (operand
                           mode; the serve-TP acceptance gate),
    tok_per_s_1dev / tok_per_s_mesh / mesh_speedup
                           steady-state decode rate unsharded vs
                           sharded (indicative on CPU: forced host
                           devices share the same cores, so the ratio
                           measures collective overhead, not scaling)
  spec_decode            uncertainty-gated speculative decoding row
                         (shared-prefix first wave, the identical queue
                         driven spec-off and spec-on; GATED on bitwise
                         stream equality — a speculative stream that
                         drifts from plain decode publishes nothing):
    slots / shared_len / unique_len / gen_len / spec_k / draft_samples,
    bitwise_equal          always True in an emitted row (the gate),
    acceptance_rate, tokens_per_round, rounds, rollbacks,
    full_model_calls_off / full_model_calls_spec
                           full-S-sample dispatches each drive paid
                           (a scan chunk costs ``chunk`` calls, a
                           batched verify costs ONE),
    full_model_calls_saved_frac   1 - spec/off (acceptance: >= 0.25),
    off_tok_per_s / spec_tok_per_s / spec_vs_off_x   decode rate
                           (indicative on CPU; the call count is the
                           hardware-independent claim)
  priority_burst         risk-aware scheduling row (2 slots, heavy-tail
                         class-2 traffic in bursts, short class-0
                         requests arriving mid-burst; GATED on the fifo
                         engine replaying the per-token oracle bitwise):
    bitwise_equal          always True in an emitted row (the gate),
    hi_p99_fifo_s / hi_p99_priority_s / hi_p99_improvement_x
                           class-0 tail latency under each policy
                           (acceptance: >= 2x better under priority),
    per_class_fifo / per_class_priority   per-class p50/p99 latency +
                           queue/service decomposition + counters,
    preemptions, escalations, escalated_tokens, verify_samples
                           the priority drive arms --escalate-mi at the
                           carried-MI band the reduced config crosses
  long_prompt            chunked-vs-batch prefill interleaving row:
    long_len / short_len / gen_len / prefill_chunk of the workload,
    batch_interarrival_p99_s / chunked_interarrival_p99_s   worst gap
        between decode-serving scans under each prefill mode,
    interarrival_improvement_x   batch p99 / chunked p99 (acceptance:
        >= 2x at parity decode tok/s),
    batch_tok_per_s / chunked_tok_per_s, table_growths, prefill_chunks
  git_sha, config_hash   top level ONLY — stamped once per file
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config, reduced
from repro.launch import steps as S
from repro.launch.serve import (Request, ServeEngine, decode_loop_reference)
from repro.models import registry as M


def git_sha() -> str:
    """Short SHA of HEAD (or 'unknown' outside a git checkout)."""
    try:
        return subprocess.check_output(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=Path(__file__).resolve().parent,
            stderr=subprocess.DEVNULL).decode().strip()
    except Exception:
        return "unknown"


def config_hash(cfg, **extra) -> str:
    """Stable 12-hex digest of the arch config + workload knobs, so two
    BENCH_serve.json rows taken under different configs can never be
    confused when diffing the bench trajectory."""
    payload = {"cfg": dataclasses.asdict(cfg), **extra}
    blob = json.dumps(payload, sort_keys=True, default=str)
    return hashlib.sha256(blob.encode()).hexdigest()[:12]


def mesh_scaling_row() -> dict:
    """Sharded-runner decode rate + bit-exactness, via a SUBPROCESS.

    ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` must be set
    before jax initializes and this process already holds a 1-device
    jax, so the row is produced by ``launch.engine.mesh_check --bench``
    in a fresh interpreter.  A parity failure fails the bench run: a
    mesh that drifts from the unsharded stream must never publish a
    throughput number.
    """
    repo = Path(__file__).resolve().parent.parent
    env = dict(os.environ)
    env["PYTHONPATH"] = str(repo / "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.engine.mesh_check",
         "--families", "dense", "--bench", "--json"],
        capture_output=True, text=True, env=env, timeout=540, cwd=repo)
    assert out.returncode == 0, \
        f"mesh parity/bench failed:\n{out.stdout}{out.stderr}"
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    dense = rec["families"]["dense"]
    return {
        "mesh": rec["mesh"],
        "devices": rec["mesh_devices"],
        "arch": dense["arch"],
        "bitwise_equal": dense["bitwise_equal"],
        "gen_tokens": dense["gen_tokens"],
        "tok_per_s_1dev": rec["tok_per_s_1dev"],
        "tok_per_s_mesh": rec["tok_per_s_mesh"],
        "mesh_speedup": rec["mesh_speedup"],
    }


def run(quick: bool = False) -> dict:
    slots, chunk, prompt_len = 4, 8, 16
    gen_len, num_requests = (16, 8) if quick else (32, 12)
    arch = "qwen2_1_5b"
    cfg = reduced(get_config(arch))
    import dataclasses
    cfg = dataclasses.replace(cfg, head_entropy="operand")
    key = jax.random.key(0)
    params = M.init_params(key, cfg)
    prompts = np.asarray(
        jax.random.randint(key, (num_requests, prompt_len), 0,
                           cfg.vocab_size), np.int32)

    def make_requests():
        return [Request(rid=i, prompt=prompts[i], max_new_tokens=gen_len)
                for i in range(num_requests)]

    # --- baseline: per-token loop over static batches of `slots` rows ---
    decode_fn = jax.jit(S.build_decode_step(cfg), donate_argnums=(2,))
    decode_loop_reference(params, cfg, prompts[:slots], 2,
                          decode_fn=decode_fn)       # warm up compile
    base_s, base_tokens = 0.0, 0
    for lo in range(0, num_requests, slots):
        batch = prompts[lo:lo + slots]
        r = decode_loop_reference(params, cfg, batch, gen_len,
                                  decode_fn=decode_fn)
        base_s += r["decode_s"]
        base_tokens += gen_len * batch.shape[0]
    baseline_tok_s = base_tokens / max(base_s, 1e-9)

    # --- engine: continuous batching + chunked scan decode ---
    engine = ServeEngine(params, cfg, num_slots=slots,
                         max_len=prompt_len + gen_len + chunk, chunk=chunk)
    warm = engine.run(make_requests()[:slots])       # warm up compile
    res = engine.run(make_requests())

    # --- mixed-length traffic: dense strips vs paged blocks ---
    kv_block = 8
    mixed_max_len = 48                               # kv_block multiple
    n_mixed = num_requests
    prompt_lens = [16 if i % 2 == 0 else 8 for i in range(n_mixed)]
    gen_lens = [(4, 24, 8, 16)[i % 4] for i in range(n_mixed)]
    mixed_prompts = np.asarray(
        jax.random.randint(jax.random.key(1), (n_mixed, 16), 0,
                           cfg.vocab_size), np.int32)

    def mixed_requests():
        return [Request(rid=i, prompt=mixed_prompts[i, :prompt_lens[i]],
                        max_new_tokens=gen_lens[i])
                for i in range(n_mixed)]

    # three drives of the same workload: dense strips, paged + gather
    # decode attention, paged + the block-sparse kernel
    variants = {"dense": ("dense", "gather"), "paged": ("paged", "gather"),
                "kernel": ("paged", "kernel")}
    engines = {}
    for name, (layout, attn) in variants.items():
        engines[name] = ServeEngine(params, cfg, num_slots=slots,
                                    max_len=mixed_max_len, chunk=chunk,
                                    kv_layout=layout, kv_block=kv_block,
                                    decode_attn=attn)
        engines[name].run(mixed_requests()[:slots])    # warm up compile
    # interleaved best-of-3: CPU dispatch jitter on this tiny config is
    # ~10%, larger than the layouts' real difference, so alternate the
    # layouts run-to-run (drift hits both) and keep each one's best
    runs = {name: [] for name in engines}
    for _ in range(3):
        for name, eng in engines.items():
            runs[name].append(eng.run(mixed_requests()))
    mixed = {name: max(rs, key=lambda r: r["decode_tok_per_s"])
             for name, rs in runs.items()}
    kv_d, kv_p = mixed["dense"]["kv"], mixed["paged"]["kv"]
    da_g, da_k = mixed["paged"]["decode_attn"], mixed["kernel"]["decode_attn"]

    # --- prefix cache: shared-system-prompt + S-sample-fanout rows ---
    shared_len, unique_len, pc_gen = 20, 6, 8     # divergence mid-block
    # 2 slots stagger the traffic: only the first two admissions run
    # before an eviction has seeded the radix tree, so 6 of 8 requests
    # hit (the cache fills at eviction, not at admission)
    n_pc, pc_slots = 8, 2
    pc_block = 8
    pc_max_len = 40                               # kv_block multiple
    sys_prompt = np.asarray(
        jax.random.randint(jax.random.key(2), (shared_len,), 0,
                           cfg.vocab_size), np.int32)
    uniq = np.asarray(
        jax.random.randint(jax.random.key(3), (n_pc, unique_len), 0,
                           cfg.vocab_size), np.int32)

    def prefix_row(make_reqs, **meta):
        engines = {}
        for on in (False, True):
            engines[on] = ServeEngine(
                params, cfg, num_slots=pc_slots, max_len=pc_max_len,
                chunk=chunk, kv_layout="paged", kv_block=pc_block,
                kv_blocks=(pc_slots + 2) * (pc_max_len // pc_block),
                prefix_cache=on)
            engines[on].run(make_reqs()[:pc_slots])  # warm up compile
        cold = engines[False].run(make_reqs())
        warm = engines[True].run(make_reqs())
        pc = warm["prefix_cache"]
        return {
            **meta,
            "num_requests": len(make_reqs()),
            "slots": pc_slots,
            "kv_block": pc_block,
            "hit_rate": pc["hit_rate"],
            "prefill_tokens": pc["prompt_tokens"],
            "prefill_tokens_saved": pc["prompt_tokens_saved"],
            "prefill_tokens_saved_frac": pc["saved_frac"],
            "cow_copies": pc["cow_copies"],
            "cache_evictions": pc["cache_evictions"],
            "cold_tok_per_s": cold["decode_tok_per_s"],
            "warm_tok_per_s": warm["decode_tok_per_s"],
            "warm_vs_cold_x": warm["decode_tok_per_s"]
            / max(cold["decode_tok_per_s"], 1e-9),
        }

    def shared_prompt_requests():
        return [Request(rid=i,
                        prompt=np.concatenate([sys_prompt, uniq[i]]),
                        max_new_tokens=pc_gen) for i in range(n_pc)]

    def fanout_requests():
        prompt = np.concatenate([sys_prompt, uniq[0]])
        return [Request(rid=i, prompt=prompt.copy(),
                        max_new_tokens=pc_gen) for i in range(n_pc)]

    prefix_shared = prefix_row(shared_prompt_requests,
                               workload="prefix_shared_prompt",
                               shared_len=shared_len,
                               unique_len=unique_len)
    fanout = prefix_row(fanout_requests, workload="sample_fanout",
                        samples=n_pc,
                        prompt_len=shared_len + unique_len)

    # --- long-prompt outlier: chunked vs batch prefill interleaving ---
    # gen is sized so decode traffic outlives the outlier's chunk walk
    # (gen/chunk scans > prompt/prefill_chunk chunks): once the last
    # short finishes, chunk-only iterations emit no tokens and the
    # whole tail would land in one giant inter-arrival gap
    lp_short, lp_long, lp_gen, lp_block = 16, 384, 96, 8
    lp_max_len = lp_short + lp_gen + chunk            # sized for SHORTS
    lp_width = -(-lp_max_len // lp_block)             # admission span
    lp_blocks = slots * lp_width + -(-(lp_long + lp_gen + chunk)
                                     // lp_block)
    lp_prompts = np.asarray(
        jax.random.randint(jax.random.key(4), (8, lp_long), 0,
                           cfg.vocab_size), np.int32)

    def long_prompt_requests():
        # the outlier arrives LAST: it admits while other slots are
        # mid-decode, which is exactly when a monolithic prefill stalls
        reqs = [Request(rid=i, prompt=lp_prompts[i, :lp_short],
                        max_new_tokens=lp_gen) for i in range(7)]
        reqs.append(Request(rid=7, prompt=lp_prompts[7],
                            max_new_tokens=lp_gen))
        return reqs

    lp = {}
    for mode in ("batch", "chunked"):
        eng = ServeEngine(params, cfg, num_slots=slots,
                          max_len=lp_max_len, chunk=chunk,
                          kv_layout="paged", kv_block=lp_block,
                          kv_blocks=lp_blocks, prefill_mode=mode,
                          prefill_chunk=32)
        eng.run(long_prompt_requests())       # warm: compiles + growths
        lp[mode] = eng.run(long_prompt_requests())
        assert lp[mode]["table_growths"] > 0  # the outlier outgrew the
        #                                       admission-time span
    long_prompt = {
        "short_len": lp_short, "long_len": lp_long, "gen_len": lp_gen,
        "kv_block": lp_block, "max_len": lp_max_len,
        "num_requests": 8, "slots": slots, "prefill_chunk": 32,
        "batch_interarrival_p99_s": lp["batch"][
            "decode_interarrival_p99_s"],
        "chunked_interarrival_p99_s": lp["chunked"][
            "decode_interarrival_p99_s"],
        "interarrival_improvement_x":
            lp["batch"]["decode_interarrival_p99_s"]
            / max(lp["chunked"]["decode_interarrival_p99_s"], 1e-9),
        "batch_tok_per_s": lp["batch"]["decode_tok_per_s"],
        "chunked_tok_per_s": lp["chunked"]["decode_tok_per_s"],
        "table_growths": lp["chunked"]["table_growths"],
        "prefill_chunks": lp["chunked"]["prefill_chunks"],
        "prefill_compiles": lp["chunked"]["prefill_compiles"],
    }

    # --- uncertainty-gated speculative decoding: verify-amortized row ---
    # one first wave (num_requests == slots, equal gens): admission is
    # FIFO-into-slot-order in both drives, so the slot-keyed operand
    # noise streams line up token for token and bitwise equality is
    # well-defined.  The prompts share a system prefix — the regime
    # spec decode targets (seen text, low MI, drafts likely to survive
    # the verify).  Savings are counted in full-S-sample dispatches,
    # the quantity a verify round amortizes: a scan chunk costs
    # ``chunk`` full-model calls, a batched verify costs ONE.
    sp_slots, sp_shared, sp_unique = 4, 16, 8
    sp_gen, sp_k = 32, 4
    sp_max_len = 64                               # kv_block multiple
    sp_sys = np.asarray(
        jax.random.randint(jax.random.key(5), (sp_shared,), 0,
                           cfg.vocab_size), np.int32)
    sp_uniq = np.asarray(
        jax.random.randint(jax.random.key(6), (sp_slots, sp_unique), 0,
                           cfg.vocab_size), np.int32)

    def spec_requests():
        return [Request(rid=i,
                        prompt=np.concatenate([sp_sys, sp_uniq[i]]),
                        max_new_tokens=sp_gen) for i in range(sp_slots)]

    sp = {}
    for on in (False, True):
        eng = ServeEngine(params, cfg, num_slots=sp_slots,
                          max_len=sp_max_len, chunk=chunk,
                          kv_layout="paged", kv_block=kv_block,
                          spec_decode=on, spec_k=sp_k,
                          spec_mi_threshold=float("inf"))
        eng.run(spec_requests())                  # warm up compile
        sp[on] = eng.run(spec_requests())
    # THE GATE: no speculative number is published unless the spec-on
    # stream (tokens AND the full uncertainty triplet) is bitwise
    # identical to plain decode on every request
    for a, b in zip(sp[False]["requests"], sp[True]["requests"]):
        assert a.slot == b.slot and a.finish_reason == b.finish_reason
        np.testing.assert_array_equal(a.tokens, b.tokens)
        for name in ("H", "SE", "MI", "p_max"):
            np.testing.assert_array_equal(
                np.asarray(getattr(a, name), np.float32),
                np.asarray(getattr(b, name), np.float32))
    sd = sp[True]["spec_decode"]
    calls_off = sp[False]["spec_decode"]["full_model_calls"]
    calls_on = sd["full_model_calls"]
    calls_saved = 1.0 - calls_on / max(calls_off, 1)
    assert calls_saved >= 0.25, \
        f"spec decode saved only {calls_saved:.0%} full-model calls " \
        f"({calls_on} vs {calls_off}): below the 25% acceptance bar"
    spec_row = {
        "slots": sp_slots,
        "shared_len": sp_shared,
        "unique_len": sp_unique,
        "gen_len": sp_gen,
        "spec_k": sp_k,
        "draft_samples": sd["draft_samples"],
        "mi_threshold": sd["mi_threshold"],
        "bitwise_equal": True,
        "acceptance_rate": sd["acceptance_rate"],
        "tokens_per_round": sd["tokens_per_round"],
        "rounds": sd["rounds"],
        "rollbacks": sd["rollbacks"],
        "gated_slot_rounds": sd["gated_slot_rounds"],
        "full_model_calls_off": calls_off,
        "full_model_calls_spec": calls_on,
        "full_model_calls_saved_frac": calls_saved,
        "off_tok_per_s": sp[False]["decode_tok_per_s"],
        "spec_tok_per_s": sp[True]["decode_tok_per_s"],
        "spec_vs_off_x": sp[True]["decode_tok_per_s"]
        / max(sp[False]["decode_tok_per_s"], 1e-9),
    }

    # --- risk-aware scheduling: priority burst + escalation row ---
    # THE GATE first: the policy-layered engine only publishes priority
    # numbers if --policy fifo still replays the pre-engine per-token
    # oracle bit for bit (dense reference layout, one static wave)
    gate_gen = 8
    gate_eng = ServeEngine(params, cfg, num_slots=2,
                           max_len=prompt_len + gate_gen, chunk=chunk,
                           policy="fifo")
    gate_res = gate_eng.run([Request(rid=i, prompt=prompts[i],
                                     max_new_tokens=gate_gen)
                             for i in range(2)])
    gate_ref = decode_loop_reference(params, cfg, prompts[:2], gate_gen,
                                     max_len=prompt_len + gate_gen)
    for j, req in enumerate(gate_res["requests"]):
        np.testing.assert_array_equal(req.tokens, gate_ref["token"][:, j])
        for name in ("H", "SE", "MI", "p_max"):
            np.testing.assert_array_equal(
                np.asarray(getattr(req, name), np.float32),
                gate_ref[name][:, j])

    # bursty heavy-tail trace over 2 slots: class-2 requests with
    # heavy-tail generation lengths arrive in two bursts; short class-0
    # requests (tight SLO) land MID-burst, when every slot is pinned by
    # a long low-priority decode.  fifo makes them wait out the tail;
    # the priority policy preempts a strictly-worse decoding slot.  The
    # priority drive also arms MI escalation at the threshold band the
    # reduced operand-mode config actually crosses (carried MI sits
    # around 4.5e-3..5.4e-3 — see docs/uncertainty.md), so the row
    # exercises the full risk-aware path: preempt AND escalate.
    pb_slots, pb_max_len = 2, 80                  # kv_block multiple
    pb_lo_gens = (32, 48, 16, 40, 24, 16)         # the heavy tail
    pb_lo_arr = (0, 0, 0, 0, 16, 16)              # two bursts
    pb_hi_arr = (4, 12, 24)                       # mid-burst arrivals
    pb_hi_gen, pb_esc_mi = 8, 0.005
    pb_prompts = np.asarray(
        jax.random.randint(jax.random.key(7), (9, 16), 0,
                           cfg.vocab_size), np.int32)

    def burst_requests():
        reqs = [Request(rid=i, prompt=pb_prompts[i],
                        max_new_tokens=pb_lo_gens[i], priority=2,
                        arrival_step=pb_lo_arr[i]) for i in range(6)]
        reqs += [Request(rid=6 + j, prompt=pb_prompts[6 + j, :8],
                         max_new_tokens=pb_hi_gen, priority=0,
                         slo_s=0.5, arrival_step=pb_hi_arr[j])
                 for j in range(3)]
        return reqs

    pb = {}
    for pol in ("fifo", "priority"):
        pb_kw = dict(num_slots=pb_slots, max_len=pb_max_len, chunk=chunk,
                     kv_layout="paged", kv_block=kv_block, policy=pol)
        if pol == "priority":
            pb_kw.update(escalate_mi=pb_esc_mi)
        eng = ServeEngine(params, cfg, **pb_kw)
        eng.run(burst_requests())                 # warm up compile
        pb[pol] = eng.run(burst_requests())
    hi_fifo = pb["fifo"]["per_class"][0]
    hi_prio = pb["priority"]["per_class"][0]
    hi_x = hi_fifo["latency_p99_s"] / max(hi_prio["latency_p99_s"], 1e-9)
    assert hi_x >= 2.0, \
        f"priority policy improved high-priority p99 only {hi_x:.2f}x " \
        f"({hi_fifo['latency_p99_s']:.3f}s -> " \
        f"{hi_prio['latency_p99_s']:.3f}s): below the 2x acceptance bar"
    assert pb["priority"]["preemptions"] > 0
    esc = pb["priority"]["escalation"]
    assert esc["escalations"] > 0, \
        f"escalation armed at MI {pb_esc_mi} never fired: threshold " \
        f"outside the config's carried-MI band"
    priority_burst = {
        "slots": pb_slots,
        "max_len": pb_max_len,
        "lo_gen_lens": list(pb_lo_gens),
        "lo_arrival_steps": list(pb_lo_arr),
        "hi_gen_len": pb_hi_gen,
        "hi_arrival_steps": list(pb_hi_arr),
        "hi_slo_s": 0.5,
        "escalate_mi": pb_esc_mi,
        "bitwise_equal": True,                    # the fifo oracle gate
        "hi_p99_fifo_s": hi_fifo["latency_p99_s"],
        "hi_p99_priority_s": hi_prio["latency_p99_s"],
        "hi_p99_improvement_x": hi_x,
        "per_class_fifo": pb["fifo"]["per_class"],
        "per_class_priority": pb["priority"]["per_class"],
        "queue_p99_fifo_s": pb["fifo"]["queue_time_p99_s"],
        "queue_p99_priority_s": pb["priority"]["queue_time_p99_s"],
        "preemptions": pb["priority"]["preemptions"],
        "escalations": esc["escalations"],
        "escalated_tokens": esc["tokens"],
        "verify_samples": esc["verify_samples"],
    }

    return {
        "git_sha": git_sha(),
        # ONE stamp for the whole file: the hash covers the arch config
        # plus every workload's knobs (each row carries its own knobs)
        "config_hash": config_hash(
            cfg, slots=slots, chunk=chunk, prompt_len=prompt_len,
            gen_len=gen_len, num_requests=num_requests,
            kv_block=kv_block, max_len=mixed_max_len,
            prompt_lens=prompt_lens, gen_lens=gen_lens,
            pc=dict(slots=pc_slots, kv_block=pc_block,
                    max_len=pc_max_len, shared_len=shared_len,
                    unique_len=unique_len, fanout=n_pc),
            long_prompt=dict(short_len=lp_short, long_len=lp_long,
                             gen_len=lp_gen, kv_block=lp_block,
                             max_len=lp_max_len, prefill_chunk=32),
            spec=dict(slots=sp_slots, shared_len=sp_shared,
                      unique_len=sp_unique, gen_len=sp_gen,
                      spec_k=sp_k, max_len=sp_max_len),
            burst=dict(slots=pb_slots, max_len=pb_max_len,
                       lo_gens=pb_lo_gens, lo_arr=pb_lo_arr,
                       hi_gen=pb_hi_gen, hi_arr=pb_hi_arr,
                       escalate_mi=pb_esc_mi)),
        "mesh_scaling": mesh_scaling_row(),
        "priority_burst": priority_burst,
        "spec_decode": spec_row,
        "long_prompt": long_prompt,
        "prefix_shared_prompt": prefix_shared,
        "sample_fanout": fanout,
        # block-sparse decode attention: HBM KV bytes one decode step
        # reads — the gather path always pulls the full logical span,
        # the kernel only the blocks holding cached tokens
        "decode_attn": {
            "kv_block": kv_block,
            "max_len": mixed_max_len,
            "gather_kv_bytes_per_step": da_g["kv_bytes_read_per_step"],
            "kernel_kv_bytes_per_step": da_k["kv_bytes_read_per_step"],
            "logical_span_kv_bytes_per_step":
                da_k["kv_bytes_span_per_step"],
            "kv_bytes_saved_frac": 1.0 - da_k["kv_bytes_read_per_step"]
            / max(da_g["kv_bytes_read_per_step"], 1e-9),
            "gather_tok_per_s": mixed["paged"]["decode_tok_per_s"],
            "kernel_tok_per_s": mixed["kernel"]["decode_tok_per_s"],
            "kernel_vs_gather_x": mixed["kernel"]["decode_tok_per_s"]
            / max(mixed["paged"]["decode_tok_per_s"], 1e-9),
        },
        "mixed": {
            "kv_block": kv_block,
            "max_len": mixed_max_len,
            "prompt_lens": prompt_lens,
            "gen_lens": gen_lens,
            "dense_tok_per_s": mixed["dense"]["decode_tok_per_s"],
            "paged_tok_per_s": mixed["paged"]["decode_tok_per_s"],
            "paged_vs_dense_x": mixed["paged"]["decode_tok_per_s"]
            / max(mixed["dense"]["decode_tok_per_s"], 1e-9),
            "kv_bytes_dense_strips": kv_d["bytes_in_use_peak"],
            "kv_bytes_paged_peak": kv_p["bytes_in_use_peak"],
            "kv_bytes_saved_frac": 1.0 - kv_p["bytes_in_use_peak"]
            / max(kv_d["bytes_in_use_peak"], 1),
            "blocks_peak": kv_p["blocks_peak"],
            "blocks_total": kv_p["blocks_total"],
        },
        "shapes": {"slots": slots, "chunk": chunk,
                   "prompt_len": prompt_len, "gen_len": gen_len,
                   "num_requests": num_requests, "arch": arch},
        "backend": jax.default_backend(),
        "timings_indicative": jax.default_backend() != "tpu",
        "baseline_tok_per_s": baseline_tok_s,
        "engine_tok_per_s": res["decode_tok_per_s"],
        "speedup_scan_x": res["decode_tok_per_s"] / baseline_tok_s,
        "engine_e2e_tok_per_s": res["e2e_tok_per_s"],
        "latency_p50_s": res["latency_p50_s"],
        "latency_p99_s": res["latency_p99_s"],
        "latency_max_s": res["latency_max_s"],
        "prefill_compile_s": warm["prefill_compile_s"],
        "prefill_steady_s": res["prefill_steady_s"],
        "flags_per_1k_tokens": res["flags_per_1k_tokens"],
        "entropy_mode": "operand",
    }


def main(quick: bool = False, json_path: str = "BENCH_serve.json"):
    r = run(quick)
    s = r["shapes"]
    print(f"serving bench ({s['arch']} reduced, {s['num_requests']} reqs, "
          f"{s['slots']} slots, chunk {s['chunk']})")
    print(f"  per-token loop:   {r['baseline_tok_per_s']:8.1f} tok/s "
          f"(1 host sync per token)")
    print(f"  scan-decode:      {r['engine_tok_per_s']:8.1f} tok/s "
          f"({r['speedup_scan_x']:.2f}x, 1 sync per {s['chunk']} tokens)")
    print(f"  engine e2e:       {r['engine_e2e_tok_per_s']:8.1f} tok/s "
          f"(incl. prefill + scheduling)")
    print(f"  latency p50/p99:  {r['latency_p50_s']:.3f}s / "
          f"{r['latency_p99_s']:.3f}s per request "
          f"(max {r['latency_max_s']:.3f}s; p99 is nearest-rank — at "
          f"{s['num_requests']} requests it IS the max)")
    print(f"  prefill:          compile {r['prefill_compile_s']:.2f}s, "
          f"steady {r['prefill_steady_s'] * 1e3:.1f}ms")
    f = r["flags_per_1k_tokens"]
    print(f"  flags/1k tokens:  {f['epistemic']:.1f} epistemic, "
          f"{f['aleatoric']:.1f} aleatoric")
    m = r["mixed"]
    print(f"  mixed-length traffic (prompts {sorted(set(m['prompt_lens']))},"
          f" gens {sorted(set(m['gen_lens']))}, kv_block {m['kv_block']}):")
    print(f"    dense strips:   {m['dense_tok_per_s']:8.1f} tok/s, "
          f"{m['kv_bytes_dense_strips'] / 1e3:.1f} KB KV resident")
    print(f"    paged blocks:   {m['paged_tok_per_s']:8.1f} tok/s "
          f"({m['paged_vs_dense_x']:.2f}x), "
          f"{m['kv_bytes_paged_peak'] / 1e3:.1f} KB peak "
          f"({m['blocks_peak']}/{m['blocks_total']} blocks, "
          f"{m['kv_bytes_saved_frac']:.0%} saved)")
    d = r["decode_attn"]
    print(f"  decode attention (paged, kv_block {d['kv_block']}):")
    print(f"    gather reads:   {d['gather_kv_bytes_per_step'] / 1e3:8.1f} "
          f"KB KV/step (the full logical span)")
    print(f"    kernel reads:   {d['kernel_kv_bytes_per_step'] / 1e3:8.1f} "
          f"KB KV/step ({d['kv_bytes_saved_frac']:.0%} saved, "
          f"{d['kernel_vs_gather_x']:.2f}x tok/s)")
    for name, label in (("prefix_shared_prompt", "shared system prompt"),
                        ("sample_fanout", "S-sample fanout")):
        p = r[name]
        print(f"  prefix cache — {label} ({p['num_requests']} reqs):")
        print(f"    {p['prefill_tokens_saved']}/{p['prefill_tokens']} "
              f"prefill tokens saved "
              f"({p['prefill_tokens_saved_frac']:.0%}), "
              f"hit rate {p['hit_rate']:.0%}, "
              f"{p['cow_copies']} CoW copies")
        print(f"    warm {p['warm_tok_per_s']:.1f} tok/s vs "
              f"cold {p['cold_tok_per_s']:.1f} "
              f"({p['warm_vs_cold_x']:.2f}x decode)")
    lp = r["long_prompt"]
    print(f"  long-prompt outlier ({lp['long_len']} tokens into "
          f"{lp['short_len']}-token traffic, prefill chunk "
          f"{lp['prefill_chunk']}):")
    print(f"    decode inter-arrival p99: batch "
          f"{lp['batch_interarrival_p99_s'] * 1e3:.1f}ms vs chunked "
          f"{lp['chunked_interarrival_p99_s'] * 1e3:.1f}ms "
          f"({lp['interarrival_improvement_x']:.1f}x better)")
    print(f"    decode tok/s: batch {lp['batch_tok_per_s']:.1f} vs "
          f"chunked {lp['chunked_tok_per_s']:.1f}; "
          f"{lp['table_growths']} table growths, "
          f"{lp['prefill_chunks']} prefill chunks")
    sd = r["spec_decode"]
    print(f"  spec decode (shared prefix {sd['shared_len']}, gen "
          f"{sd['gen_len']}, k={sd['spec_k']}, "
          f"{sd['draft_samples']}-sample draft):")
    print(f"    bitwise vs plain decode: "
          f"{'OK' if sd['bitwise_equal'] else 'MISMATCH'}; "
          f"acceptance {sd['acceptance_rate']:.0%}, "
          f"{sd['tokens_per_round']:.2f} tokens/round, "
          f"{sd['rollbacks']} rollbacks")
    print(f"    full-model calls: {sd['full_model_calls_spec']} vs "
          f"{sd['full_model_calls_off']} plain "
          f"({sd['full_model_calls_saved_frac']:.0%} saved; "
          f"{sd['spec_vs_off_x']:.2f}x decode tok/s)")
    pb = r["priority_burst"]
    print(f"  priority burst ({pb['slots']} slots, heavy-tail gens "
          f"{sorted(set(pb['lo_gen_lens']))}, {len(pb['hi_arrival_steps'])}"
          f" class-0 arrivals mid-burst; fifo oracle gate "
          f"{'OK' if pb['bitwise_equal'] else 'MISMATCH'}):")
    print(f"    class-0 p99: fifo {pb['hi_p99_fifo_s']:.3f}s vs priority "
          f"{pb['hi_p99_priority_s']:.3f}s "
          f"({pb['hi_p99_improvement_x']:.1f}x better; "
          f"{pb['preemptions']} preemptions)")
    print(f"    escalation @ MI {pb['escalate_mi']}: "
          f"{pb['escalations']} requests, {pb['escalated_tokens']} tokens "
          f"at S={pb['verify_samples']}")
    for pol in ("fifo", "priority"):
        cls = pb[f"per_class_{pol}"]
        split = ", ".join(
            f"class {c}: p50 {v['latency_p50_s']:.3f}s / "
            f"p99 {v['latency_p99_s']:.3f}s" for c, v in cls.items())
        print(f"    {pol:8s} {split}")
    ms = r["mesh_scaling"]
    print(f"  mesh scaling ({ms['mesh']} forced-host mesh, "
          f"{ms['devices']} devices, {ms['arch']} reduced):")
    print(f"    bitwise vs unsharded: "
          f"{'OK' if ms['bitwise_equal'] else 'MISMATCH'} "
          f"over {ms['gen_tokens']} tokens")
    print(f"    decode tok/s: 1 dev {ms['tok_per_s_1dev']:.1f} vs mesh "
          f"{ms['tok_per_s_mesh']:.1f} ({ms['mesh_speedup']:.2f}x; "
          f"forced host devices share cores — collective overhead, "
          f"not scaling)")
    print(f"  file stamped git {r['git_sha']}, "
          f"config {r['config_hash']}")
    if r["timings_indicative"]:
        print(f"  [timings on {r['backend']} are indicative; the ratio is "
              f"the dispatch-overhead win, which only grows on TPU]")
    if json_path:
        with open(json_path, "w") as fo:
            json.dump(r, fo, indent=1, default=float)
        print(f"  -> {json_path}")
    return r


if __name__ == "__main__":
    main()
