"""Benchmark: fused-sampling kernel micro-bench (beyond-paper, TPU analog
of the machine's 'randomness never transits the digital datapath').

Compares on this host (jnp reference path; the Pallas kernels compile for
TPU and validate in interpret mode):
  * naive MC head: materialize S sampled weight tensors, S GEMMs
  * LRT fused head: 1 mean GEMM + 1 var GEMM + output-space noise
and reports the entropy-traffic reduction (bytes of randomness per MC
sample) that motivates kernels/bayes_matmul + kernels/uncertainty_head.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ref


def _timeit(f, iters=10):
    jax.block_until_ready(f())
    t0 = time.time()
    for _ in range(iters):
        out = f()
    jax.block_until_ready(out)
    return (time.time() - t0) / iters


def run(quick: bool = False) -> dict:
    M, K, V, S = (64, 256, 1024, 10) if quick else (128, 1024, 4096, 10)
    key = jax.random.key(0)
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (M, K))
    mu = jax.random.normal(ks[1], (K, V)) * 0.02
    sigma = jnp.abs(jax.random.normal(ks[2], (K, V))) * 0.01

    @jax.jit
    def naive(x, key):
        def one(k):
            eps = jax.random.normal(k, (K, V))     # S weight-space draws
            return ref.bayes_matmul(x, mu, sigma, eps)
        return jax.vmap(one)(jax.random.split(key, S))

    @jax.jit
    def fused(x, key):
        xi = jax.random.normal(key, (S, M, V))     # output-space noise
        return jax.vmap(lambda z: ref.lrt_matmul(x, mu, sigma, z))(xi)

    t_naive = _timeit(lambda: naive(x, ks[3]))
    t_fused = _timeit(lambda: fused(x, ks[3]))
    return {
        "naive_ms": t_naive * 1e3,
        "fused_lrt_ms": t_fused * 1e3,
        "speedup_x": t_naive / t_fused,
        "entropy_bytes_naive": S * K * V * 4,
        "entropy_bytes_fused": S * M * V * 4,
        "entropy_reduction_x": (K / M),
    }


def main(quick: bool = False):
    r = run(quick)
    print("fused Bayesian head micro-bench (beyond-paper TPU adaptation)")
    print(f"  naive S-sample weight-space head: {r['naive_ms']:9.2f} ms")
    print(f"  fused LRT head:                   {r['fused_lrt_ms']:9.2f} ms"
          f"   ({r['speedup_x']:.2f}x)")
    print(f"  entropy traffic: {r['entropy_bytes_naive'] / 1e6:.1f} MB -> "
          f"{r['entropy_bytes_fused'] / 1e6:.1f} MB per prediction "
          f"({r['entropy_reduction_x']:.0f}x less)")
    return r


if __name__ == "__main__":
    main()
