"""Benchmark: fused-sampling kernel micro-bench (beyond-paper, TPU analog
of the machine's 'randomness never transits the digital datapath').

Two measurements, reported to stdout and to ``BENCH_kernels.json`` so the
perf trajectory accumulates in CI:

  1. **S-sample fused GEMM** — the vmap-of-single-sample baseline (S
     weight-space draws, S GEMMs, PRNG in the path: exactly what
     ``mc_forward`` does today) vs the fused seeded path
     (``ops.lrt_matmul_sampled``: ONE mean GEMM + ONE variance GEMM
     shared by all S samples, same marginals by the local
     reparameterization theorem).  On this CPU host the timings are
     indicative; the structural win (2 matmuls vs 2*S, one weight load
     per prediction) is backend-independent.

  2. **Entropy HBM traffic per prediction** — bytes of randomness
     crossing HBM on each path: S*K*V*4 for the naive weight-space
     operand, S*M*V*4 for the LRT operand, and 0 for the in-kernel PRNG
     path (the variates are born and die in registers;
     ``pltpu.prng_random_bits`` + Box-Muller, kernels/rng.py).  Measured
     via ``ops.entropy_bytes`` — the same accounting the kernels' block
     specs imply — not asserted.
"""

from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def _timeit(f, iters=10):
    jax.block_until_ready(f())
    t0 = time.time()
    for _ in range(iters):
        out = f()
    jax.block_until_ready(out)
    return (time.time() - t0) / iters


def run(quick: bool = False) -> dict:
    M, K, V, S = (64, 256, 1024, 10) if quick else (128, 1024, 4096, 10)
    key = jax.random.key(0)
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (M, K))
    mu = jax.random.normal(ks[1], (K, V)) * 0.02
    sigma = jnp.abs(jax.random.normal(ks[2], (K, V))) * 0.01

    # baseline: vmap of single-sample weight-space draws (PRNG in path,
    # one sampled (K, V) weight tensor and one GEMM per MC sample) —
    # the repo's pre-fusion MC serving path.
    @jax.jit
    def vmap_single(x, key):
        def one(k):
            eps = jax.random.normal(k, (K, V))
            return ref.bayes_matmul(x, mu, sigma, eps)
        return jax.vmap(one)(jax.random.split(key, S))

    # fused: all S samples from one seeded call, mean/var GEMMs shared.
    @jax.jit
    def fused_sampled(x, seed):
        return ops.lrt_matmul_sampled(x, mu, sigma, seed, num_samples=S,
                                      impl="auto")

    seed = jnp.asarray(42, jnp.int32)
    t_vmap = _timeit(lambda: vmap_single(x, ks[3]))
    t_fused = _timeit(lambda: fused_sampled(x, seed))

    on_tpu = jax.default_backend() == "tpu"
    traffic = {
        "weight_space_operand": ops.entropy_bytes(
            "weight_space", num_samples=S, k=K, n=V),
        "lrt_operand": ops.entropy_bytes("lrt", num_samples=S, m=M, n=V),
        "head_operand": ops.entropy_bytes("head", num_samples=S, m=M, n=V),
        "in_kernel": ops.entropy_bytes("lrt", num_samples=S, m=M, n=V,
                                       in_kernel=True),
    }
    return {
        "shapes": {"M": M, "K": K, "V": V, "S": S},
        "backend": jax.default_backend(),
        "timings_indicative": not on_tpu,
        "vmap_single_sample_ms": t_vmap * 1e3,
        "fused_sampled_ms": t_fused * 1e3,
        "speedup_fused_x": t_vmap / t_fused,
        "entropy_bytes_per_prediction": traffic,
        "entropy_reduction_operand_x": (K / M),
    }


def main(quick: bool = False, json_path: str = "BENCH_kernels.json"):
    r = run(quick)
    s = r["shapes"]
    print("fused Bayesian head micro-bench (beyond-paper TPU adaptation)")
    print(f"  vmap-of-single-sample (S={s['S']} weight draws): "
          f"{r['vmap_single_sample_ms']:9.2f} ms")
    print(f"  fused S-sample seeded GEMM:                      "
          f"{r['fused_sampled_ms']:9.2f} ms   "
          f"({r['speedup_fused_x']:.2f}x)")
    tb = r["entropy_bytes_per_prediction"]
    print("  entropy over HBM per prediction:")
    print(f"    weight-space operand: {tb['weight_space_operand'] / 1e6:8.1f} MB"
          f"   (S*K*V*4)")
    print(f"    LRT operand:          {tb['lrt_operand'] / 1e6:8.1f} MB"
          f"   (S*M*V*4, {r['entropy_reduction_operand_x']:.0f}x less)")
    print(f"    in-kernel PRNG:       {tb['in_kernel'] / 1e6:8.1f} MB"
          f"   (born in registers)")
    if r["timings_indicative"]:
        print(f"  [timings on {r['backend']} are indicative; the kernel "
              f"path compiles on TPU]")
    if json_path:
        with open(json_path, "w") as f:
            json.dump(r, f, indent=1, default=float)
        print(f"  -> {json_path}")
    return r


if __name__ == "__main__":
    main()
