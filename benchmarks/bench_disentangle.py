"""Benchmark: uncertainty disentanglement (paper Fig. 5, DDU benchmark).

Trains on clean glyphs (MNIST stand-in) ONLY -- the paper's protocol --
then predicts on ID / ambiguous / fashion-OOD sets and reports:
  * ID accuracy without / with OOD rejection (paper: 96.01% -> 99.7%)
  * aleatoric detector AUROC on ambiguous    (paper: 88.03%)
  * epistemic detector AUROC on fashion      (paper: 84.42%)
  * the (SE, MI) cluster centroids           (paper Fig. 5e)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.bench_bloodcell import train_bnn
from repro.core.uncertainty import (auroc, best_rejection_threshold,
                                    disentangle_clusters,
                                    predictive_moments, rejection_accuracy)
from repro.data import synthetic as D
from repro.models import bnn_cnn as B


def run(quick: bool = False) -> dict:
    rng = np.random.default_rng(1)
    cfg = B.BNNConfig(num_classes=10, in_channels=1,
                      width=16,
                      mc_samples=10)
    n_train = 2500 if quick else 4000
    steps = 250 if quick else 400
    xtr, ytr = D.glyphs(rng, n_train)
    params = train_bnn(cfg, xtr, ytr, steps, seed=1)

    n = 250 if quick else 800
    key = jax.random.key(7)
    x_id, y_id = D.glyphs(rng, n)
    x_amb, _ = D.ambiguous_glyphs(rng, n)
    x_ood, _ = D.fashion_ood(rng, n)

    def predict(x):
        probs = B.mc_predict(params, cfg, jnp.asarray(x), key, "machine")
        return predictive_moments(probs)

    m_id, m_amb, m_ood = predict(x_id), predict(x_amb), predict(x_ood)

    a_alea = float(auroc(m_amb["SE"], m_id["SE"]))
    a_epi = float(auroc(m_ood["MI"], m_id["MI"]))
    t, _ = best_rejection_threshold(m_id["MI"], m_id["p_mean"],
                                    jnp.asarray(y_id))
    r = rejection_accuracy(m_id["p_mean"], m_id["MI"],
                           jnp.asarray(y_id), t)
    clusters = disentangle_clusters(
        jnp.concatenate([m_id["MI"], m_amb["MI"], m_ood["MI"]]),
        jnp.concatenate([m_id["SE"], m_amb["SE"], m_ood["SE"]]),
        jnp.concatenate([jnp.full((n,), d) for d in range(3)]))
    return {
        "id_accuracy": float(r["accuracy_all"]),
        "id_accuracy_rejected": float(r["accuracy_accepted"]),
        "mi_threshold": t,
        "aleatoric_auroc": a_alea,
        "epistemic_auroc": a_epi,
        "cluster_centroids_se_mi": np.asarray(
            clusters["centroids"]).tolist(),
        "cluster_min_pairwise": float(clusters["min_pairwise"]),
        "paper": {"id_accuracy": 0.9601, "id_accuracy_rejected": 0.997,
                  "aleatoric_auroc": 0.8803, "epistemic_auroc": 0.8442,
                  "mi_threshold": 0.00308},
    }


def main(quick: bool = False):
    r = run(quick)
    p = r["paper"]
    print("uncertainty disentanglement (paper Fig. 5, trained on ID only)")
    print(f"  ID accuracy:           {r['id_accuracy']:.4f}  "
          f"(paper {p['id_accuracy']})")
    print(f"  ID acc w/ rejection:   {r['id_accuracy_rejected']:.4f}  "
          f"(paper {p['id_accuracy_rejected']})")
    print(f"  aleatoric AUROC:       {r['aleatoric_auroc']:.4f}  "
          f"(paper {p['aleatoric_auroc']})")
    print(f"  epistemic AUROC:       {r['epistemic_auroc']:.4f}  "
          f"(paper {p['epistemic_auroc']})")
    print(f"  (SE, MI) centroids [ID, ambiguous, OOD]: "
          f"{r['cluster_centroids_se_mi']}")
    return r


if __name__ == "__main__":
    main()
