"""Benchmark harness entry point: one benchmark per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]

  bench_photonic     paper Fig. 2c/d  machine computation error
  bench_throughput   paper §Results   26.7 G conv/s vs digital PRNG path
  bench_bloodcell    paper Fig. 4     ID/OOD classification + rejection
  bench_disentangle  paper Fig. 5     MNIST/Ambiguous/Fashion clusters
  bench_kernels      beyond-paper     fused-sampling kernel micro-bench
                                      (emits BENCH_kernels.json: entropy
                                      HBM traffic + fused-GEMM speedup,
                                      the CI perf-trajectory artifact)
  bench_serve        beyond-paper     continuous-batching scan-decode
                                      engine vs per-token loop (emits
                                      BENCH_serve.json: tok/s,
                                      p50/p99/max request latency,
                                      flags/1k tokens, stamped once
                                      with git SHA + config hash), plus
                                      one row per serving subsystem:
    mixed                 mixed-length dense-vs-paged-KV workload
                          (tok/s + peak resident KV bytes per layout)
    decode_attn           block-sparse decode kernel vs gather
                          (KV bytes read per decode step)
    prefix_shared_prompt  shared-system-prompt radix-cache workload
    sample_fanout         S-identical-prompt MC fanout workload
                          (prefill tokens saved, hit rate, CoW copies)
    long_prompt           chunked vs batch prefill interleaving
                          (decode-token inter-arrival p99 with a
                          prompt outlier + on-demand table growth)
    mesh_scaling          --mesh sharded runner on a forced-host
                          4-device CPU mesh (bitwise parity vs the
                          unsharded engine + decode tok/s both ways)
  roofline           deliverable (g)  three-term roofline per dry-run cell
"""

from __future__ import annotations

import argparse
import json
import time


def main() -> None:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--quick", action="store_true",
                    help="reduced sizes (CI-friendly)")
    ap.add_argument("--only", default=None)
    ap.add_argument("--json", default=None, help="dump results to file")
    args = ap.parse_args()

    from benchmarks import (bench_bloodcell, bench_disentangle,
                            bench_kernels, bench_photonic, bench_serve,
                            bench_throughput, roofline)

    benches = {
        "photonic": lambda: bench_photonic.main(args.quick),
        "throughput": lambda: bench_throughput.main(args.quick),
        "kernels": lambda: bench_kernels.main(args.quick),
        "serve": lambda: bench_serve.main(args.quick),
        "bloodcell": lambda: bench_bloodcell.main(args.quick),
        "disentangle": lambda: bench_disentangle.main(args.quick),
    }
    results = {}
    for name, fn in benches.items():
        if args.only and args.only != name:
            continue
        print(f"\n=== {name} " + "=" * (60 - len(name)))
        t0 = time.time()
        results[name] = fn()
        print(f"[{name}: {time.time() - t0:.1f}s]")

    if not args.only or args.only == "roofline":
        print("\n=== roofline " + "=" * 52)
        roofline.main()

    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=1, default=float)
        print(f"\nresults -> {args.json}")


if __name__ == "__main__":
    main()
