"""Benchmark: blood-cell classification + OOD rejection (paper Fig. 4).

Trains the paper's hybrid BNN (surrogate mode) on synthetic blood-cell
images, predicts on the photonic machine twin, and reports:
  * ID accuracy without / with MI-threshold rejection  (paper: 90.26% ->
    94.62% at threshold 0.0185)
  * OOD (erythroblast) AUROC                            (paper: 91.16%)
Numbers are dataset-bound (synthetic stand-ins); qualitative agreement is
asserted by tests/test_paper_experiments.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import svi
from repro.core.uncertainty import (auroc, best_rejection_threshold,
                                    predictive_moments, rejection_accuracy)
from repro.data import synthetic as D
from repro.models import bnn_cnn as B
from repro.optim import adamw


def train_bnn(cfg, images, labels, steps, lr=3e-3, batch=64, seed=0):
    key = jax.random.key(seed)
    params = B.init_params(key, cfg)
    opt_cfg = adamw.AdamWConfig(lr=lr, warmup_steps=10, total_steps=steps,
                                weight_decay=1e-4)
    state = adamw.init_state(params, opt_cfg)
    svi_cfg = svi.SVIConfig(num_train_examples=images.shape[0],
                            kl_warmup_steps=steps // 3)
    nll = B.nll_fn(cfg)

    @jax.jit
    def step(params, state, batch, key, i):
        (loss, aux), g = jax.value_and_grad(
            lambda p: svi.elbo_loss(nll, p, batch, key, i, svi_cfg),
            has_aux=True)(params)
        params, state, _ = adamw.apply_updates(params, g, state, opt_cfg)
        return params, state, loss, aux

    n = images.shape[0]
    for i in range(steps):
        key, k1, k2 = jax.random.split(key, 3)
        idx = jax.random.randint(k1, (batch,), 0, n)
        b = {"images": jnp.asarray(images[idx]),
             "labels": jnp.asarray(labels[idx])}
        params, state, loss, aux = step(params, state, b, k2,
                                        jnp.asarray(i))
    return params


def run(quick: bool = False) -> dict:
    rng = np.random.default_rng(0)
    cfg = B.BNNConfig(num_classes=7, in_channels=3,
                      width=16,
                      mc_samples=10)
    n_train = 2500 if quick else 4000
    steps = 250 if quick else 400
    xtr, ytr = D.blood_cells(rng, n_train)
    params = train_bnn(cfg, xtr, ytr, steps)

    n_test = 250 if quick else 800
    xte, yte = D.blood_cells(rng, n_test)
    xood, _ = D.blood_cells_ood(rng, n_test)
    key = jax.random.key(100)
    p_id = B.mc_predict(params, cfg, jnp.asarray(xte), key, "machine")
    p_ood = B.mc_predict(params, cfg, jnp.asarray(xood), key, "machine")
    m_id = predictive_moments(p_id)
    m_ood = predictive_moments(p_ood)

    a = float(auroc(m_ood["MI"], m_id["MI"]))
    t, _ = best_rejection_threshold(m_id["MI"], m_id["p_mean"],
                                    jnp.asarray(yte))
    r = rejection_accuracy(m_id["p_mean"], m_id["MI"], jnp.asarray(yte), t)
    return {
        "id_accuracy": float(r["accuracy_all"]),
        "id_accuracy_rejected": float(r["accuracy_accepted"]),
        "rejection_rate": float(r["rejection_rate"]),
        "mi_threshold": t,
        "ood_auroc": a,
        "paper": {"id_accuracy": 0.9026, "id_accuracy_rejected": 0.9462,
                  "ood_auroc": 0.9116, "mi_threshold": 0.0185},
    }


def main(quick: bool = False):
    r = run(quick)
    p = r["paper"]
    print("blood-cell classification + OOD rejection (paper Fig. 4)")
    print(f"  ID accuracy:            {r['id_accuracy']:.4f}  "
          f"(paper {p['id_accuracy']})")
    print(f"  ID accuracy w/ reject:  {r['id_accuracy_rejected']:.4f}  "
          f"(paper {p['id_accuracy_rejected']})")
    print(f"  OOD AUROC:              {r['ood_auroc']:.4f}  "
          f"(paper {p['ood_auroc']})")
    print(f"  MI threshold:           {r['mi_threshold']:.4f}  "
          f"(paper {p['mi_threshold']}; dataset-bound)")
    return r


if __name__ == "__main__":
    main()
