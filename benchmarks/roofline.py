"""Roofline analysis from dry-run artifacts (deliverable g).

Reads ``artifacts/dryrun/<arch>__<shape>__<mesh>.json`` produced by
``repro.launch.dryrun`` and derives, per (arch x shape x mesh):

  compute term    = HLO_FLOPs_per_device / peak_FLOP/s        [s]
  memory term     = HLO_bytes_per_device / HBM_bw             [s]
  collective term = collective_link_bytes_per_device / ICI_bw [s]

(the post-SPMD HLO is the per-device program, so cost_analysis numbers
are already per-chip -- dividing totals by chip count is equivalent).

Also reports MODEL_FLOPS / HLO_FLOPS ("useful compute" fraction; catches
remat/redundancy waste) and the dominant bottleneck.  Hardware constants:
TPU v5e -- 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def model_flops(rec: dict) -> float:
    """6*N_active*D for train (fwd+bwd), 2*N_active*D for inference."""
    n = rec["active_param_count"]
    d = rec["tokens"]
    mult = 6.0 if rec.get("kind") == "train" else 2.0
    return mult * n * d


def analyze_record(rec: dict) -> dict | None:
    if "skipped" in rec:
        return None
    # prefer the trip-count-aware HLO accounting (launch.hlo_cost); XLA's
    # cost_analysis counts while bodies once and under-reports scanned
    # programs by the trip count.
    hc = rec.get("hlo_cost")
    if hc:
        flops_dev = hc["flops"]
        bytes_dev = hc["bytes"]
        coll_dev = hc["collectives"]["total_link_bytes"]
    else:
        ca = rec.get("cost_analysis", {})
        flops_dev = ca.get("flops", 0.0)
        bytes_dev = ca.get("bytes accessed", 0.0)
        coll_dev = rec.get("collectives", {}).get("total_link_bytes", 0)
    ndev = rec.get("num_devices", 1)

    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)
    t_bound = max(terms.values())
    mf = model_flops(rec)
    hlo_total = flops_dev * ndev
    useful = mf / hlo_total if hlo_total else 0.0
    # roofline fraction: useful-compute time over the bound term
    t_useful = (mf / ndev) / PEAK_FLOPS
    frac = t_useful / t_bound if t_bound else 0.0
    out = {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "kind": rec.get("kind"),
        "compute_s": t_compute, "memory_s": t_memory,
        "collective_s": t_coll, "dominant": dominant,
        "model_flops": mf, "hlo_flops_total": hlo_total,
        "useful_fraction": useful, "roofline_fraction": frac,
        "collectives": {k: v for k, v in
                        (hc["collectives"] if hc
                         else rec["collectives"]).items()
                        if isinstance(v, dict)},
    }
    # fused-attention (Pallas kernel) variant: same HLO, the
    # 'fused_attention' scope's score-tile traffic stays in VMEM.
    fa = rec.get("hlo_cost_fused_attn")
    if fa:
        t_mem_f = fa["bytes"] / HBM_BW
        terms_f = {"compute": t_compute, "memory": t_mem_f,
                   "collective": t_coll}
        bound_f = max(terms_f.values())
        out["memory_fused_s"] = t_mem_f
        out["dominant_fused"] = max(terms_f, key=terms_f.get)
        out["roofline_fraction_fused"] = \
            t_useful / bound_f if bound_f else 0.0
    return out


def load_all(art_dir: str = ART, mesh: str | None = "16x16",
             tag: str | None = None) -> list[dict]:
    out = []
    for p in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        base = os.path.basename(p)
        parts = base[:-5].split("__")
        if tag is None and len(parts) > 3:
            continue           # perf-iteration artifacts have a 4th tag
        if tag is not None and (len(parts) < 4 or parts[3] != tag):
            continue
        with open(p) as f:
            rec = json.load(f)
        a = analyze_record(rec)
        if a is None:
            continue
        if mesh is None or a["mesh"] == mesh:
            out.append(a)
    return out


def fmt_time(s: float) -> str:
    if s >= 1.0:
        return f"{s:7.2f}s "
    if s >= 1e-3:
        return f"{s * 1e3:7.2f}ms"
    return f"{s * 1e6:7.2f}us"


def table(rows: list[dict]) -> str:
    hdr = (f"{'arch':22s} {'shape':12s} {'mesh':8s} {'compute':9s} "
           f"{'memory':9s} {'collect':9s} {'bound':10s} "
           f"{'useful':7s} {'roofline':8s} {'mem(fa)':9s} {'roof(fa)':8s}")
    lines = [hdr, "-" * len(hdr)]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        fa = ""
        if "memory_fused_s" in r:
            fa = (f" {fmt_time(r['memory_fused_s'])} "
                  f"{r['roofline_fraction_fused']:7.1%}")
        lines.append(
            f"{r['arch']:22s} {r['shape']:12s} {r['mesh']:8s} "
            f"{fmt_time(r['compute_s'])} {fmt_time(r['memory_s'])} "
            f"{fmt_time(r['collective_s'])} {r['dominant']:10s} "
            f"{r['useful_fraction']:6.1%} {r['roofline_fraction']:7.1%}"
            + fa)
    return "\n".join(lines)


def main():
    rows = load_all(mesh=None)
    if not rows:
        print("no dry-run artifacts found -- run repro.launch.dryrun first")
        return
    print(table(rows))
    print()
    for mesh in ("16x16", "2x16x16"):
        sub = [r for r in rows if r["mesh"] == mesh]
        if not sub:
            continue
        by_dom = {}
        for r in sub:
            by_dom.setdefault(r["dominant"], []).append(r)
        print(f"[{mesh}] {len(sub)} cells; bottleneck breakdown: "
              + ", ".join(f"{k}={len(v)}" for k, v in by_dom.items()))


if __name__ == "__main__":
    main()
