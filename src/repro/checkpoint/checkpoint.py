"""Fault-tolerant checkpointing: atomic, async, elastic.

Layout:  <dir>/step_<N>/
             arrays.npz      -- flattened param/optimizer/entropy leaves
             meta.msgpack    -- treedef paths, shapes/dtypes, step,
                                data-loader cursor, mesh shape at save

Guarantees:
  * ATOMIC:   writes go to ``step_<N>.tmp`` then ``os.rename`` — a crash
    mid-write can never corrupt the restore point (rename is atomic on
    POSIX), and ``latest_step`` only ever sees complete directories.
  * ASYNC:    ``save_async`` snapshots to host memory synchronously (cheap)
    and writes on a daemon thread, overlapping I/O with the next train
    steps; ``wait()`` joins before the next save or at exit.
  * ELASTIC:  arrays are saved as full (addressable-gathered) host numpy;
    ``restore`` re-places them under ANY mesh/sharding via
    ``jax.device_put`` — scaling from (16,16) to (2,16,16) or to a
    degraded pod is a restore, not a migration tool.  (At 1000+ nodes the
    same format holds per-host shards; the gather step is the only part
    that is container-scale.)
  * GC:       ``keep`` most recent steps are retained.

Wrapped for the train loop by ``CheckpointManager``.
"""

from __future__ import annotations

import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

from repro.core.bayesian import GaussianVariational


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for kp, leaf in flat:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp)
        out[path] = np.asarray(leaf)
    return out


def _unflatten_into(tree: Any, arrays: dict[str, np.ndarray]) -> Any:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    leaves = []
    for kp, leaf in flat:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp)
        if path not in arrays:
            raise KeyError(f"checkpoint missing leaf {path}")
        a = arrays[path]
        if tuple(a.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch at {path}: ckpt {a.shape} vs {leaf.shape}")
        leaves.append(a.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(tree), leaves)


def save(path: str, step: int, tree: Any, extra: Optional[dict] = None):
    """Synchronous atomic save of ``tree`` (+ json-able ``extra``)."""
    final = os.path.join(path, f"step_{step:09d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    arrays = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    meta = {"step": step, "extra": extra or {},
            "leaves": {k: [list(v.shape), str(v.dtype)]
                       for k, v in arrays.items()}}
    with open(os.path.join(tmp, "meta.msgpack"), "wb") as f:
        f.write(msgpack.packb(meta))
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def list_steps(path: str) -> list[int]:
    if not os.path.isdir(path):
        return []
    out = []
    for d in os.listdir(path):
        m = re.fullmatch(r"step_(\d+)", d)
        if m and os.path.exists(os.path.join(path, d, "meta.msgpack")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(path: str) -> Optional[int]:
    steps = list_steps(path)
    return steps[-1] if steps else None


def restore(path: str, step: int, template: Any,
            shardings: Optional[Any] = None) -> tuple[Any, dict]:
    """Load step into the structure of ``template``.

    ``shardings``: optional pytree of NamedSharding — the ELASTIC path:
    arrays are placed directly onto the (possibly different) target mesh.
    """
    d = os.path.join(path, f"step_{step:09d}")
    with open(os.path.join(d, "meta.msgpack"), "rb") as f:
        meta = msgpack.unpackb(f.read())
    arrays = dict(np.load(os.path.join(d, "arrays.npz")))
    tree = _unflatten_into(template, arrays)
    if shardings is not None:
        tree = jax.tree.map(
            lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree, meta["extra"]


class CheckpointManager:
    """Async save + GC + resume discovery for the train loop."""

    def __init__(self, path: str, keep: int = 3):
        self.path = path
        self.keep = keep
        os.makedirs(path, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save_async(self, step: int, tree: Any,
                   extra: Optional[dict] = None):
        self.wait()
        # snapshot to host synchronously — device buffers may be donated
        # or mutated by the next step; numpy copies are crash-consistent
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)

        def work():
            save(self.path, step, host_tree, extra)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def _gc(self):
        steps = list_steps(self.path)
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.path, f"step_{s:09d}"),
                          ignore_errors=True)

    def restore_latest(self, template: Any, shardings=None):
        step = latest_step(self.path)
        if step is None:
            return None, None, None
        tree, extra = restore(self.path, step, template, shardings)
        return step, tree, extra
