"""Pallas TPU kernel: fused sampled-weight GEMM.

The photonic machine's defining property is that the stochastic weights are
*fused with the MAC*: randomness never transits the digital datapath.  The
TPU translation: mu / sigma tiles are loaded HBM->VMEM once and perturbed
in-register, so the HBM weight traffic per MC sample is the same as a
deterministic GEMM of the *mean* weights (plus the entropy operand, which
on hardware is generated in-kernel via pltpu.prng_random_bits; in this
repo it is an explicit operand so the kernel validates in interpret mode
and stays faithful to the paper's external entropy source).

Two variants:

  * ``bayes_matmul_kernel``  -- weight-space noise, eps: (K, N).  Used for
    the CNN's probabilistic conv (9-channel weights are tiny).
  * ``lrt_matmul_kernel``    -- local-reparameterization, xi: (M, N).
    Noise in output space: exact same marginals, S-sample entropy cost
    scales with activations, not weights.  This is the LM-head workhorse.

Tiling: classic (M/bm, N/bn, K/bk) grid, K innermost/sequential, f32
accumulation in the output ref.  Block shapes default to MXU-aligned
(128, 128) tiles with bk=512 to amortize loop overhead while three f32
operand tiles + accumulator stay well under VMEM (~1.3 MB at defaults).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bayes_mm_kernel(x_ref, mu_ref, sg_ref, eps_ref, o_ref, *, nk: int):
    """One (bm, bn) output tile; accumulate over the K grid dimension."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = (mu_ref[...] + sg_ref[...] * eps_ref[...]).astype(jnp.float32)
    o_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32), w,
                          preferred_element_type=jnp.float32)


def bayes_matmul_kernel(x: jax.Array, mu: jax.Array, sigma: jax.Array,
                        eps: jax.Array, *, bm: int = 128, bn: int = 128,
                        bk: int = 512, interpret: bool = False) -> jax.Array:
    """y = x @ (mu + sigma*eps); x (M,K), mu/sigma/eps (K,N) -> (M,N) f32."""
    m, k = x.shape
    k2, n = mu.shape
    assert k == k2 and mu.shape == sigma.shape == eps.shape
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_bayes_mm_kernel, nk=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, mu, sigma, eps)


def _lrt_mm_kernel(x_ref, mu_ref, sg_ref, xi_ref, o_ref, *, nk: int):
    """LRT tile: accumulate mean part and variance part over K, then
    combine with the output-space noise on the last K step."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)
    mu = mu_ref[...].astype(jnp.float32)
    sg = sg_ref[...].astype(jnp.float32)
    mean_part = jnp.dot(x, mu, preferred_element_type=jnp.float32)
    var_part = jnp.dot(x * x, sg * sg, preferred_element_type=jnp.float32)
    # pack (mean, var) accumulation: o carries mean + i*var? No complex --
    # accumulate var scaled into the imaginary trick is fragile; instead
    # o_ref is (2, bm, bn): channel 0 mean, channel 1 variance.
    o_ref[0] += mean_part
    o_ref[1] += var_part

    @pl.when(k == nk - 1)
    def _finish():
        mean = o_ref[0]
        var = jnp.maximum(o_ref[1], 0.0)
        o_ref[0] = mean + jnp.sqrt(var) * xi_ref[0].astype(jnp.float32)


def lrt_matmul_kernel(x: jax.Array, mu: jax.Array, sigma: jax.Array,
                      xi: jax.Array, *, bm: int = 128, bn: int = 128,
                      bk: int = 512, interpret: bool = False) -> jax.Array:
    """Local-reparameterization GEMM.

    x (M,K); mu/sigma (K,N); xi (M,N) output-space standard variates.
    Returns (M,N) f32:  x@mu + sqrt((x*x)@(sigma^2)) * xi.
    """
    m, k = x.shape
    _, n = mu.shape
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    grid = (m // bm, n // bn, k // bk)
    xi3 = xi[None]  # leading unit axis so the block carries a channel dim
    out = pl.pallas_call(
        functools.partial(_lrt_mm_kernel, nk=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bm, bn), lambda i, j, kk: (0, i, j)),
        ],
        out_specs=pl.BlockSpec((2, bm, bn), lambda i, j, kk: (0, i, j)),
        out_shape=jax.ShapeDtypeStruct((2, m, n), jnp.float32),
        interpret=interpret,
    )(x, mu, sigma, xi3)
    return out[0]
