"""Pallas TPU kernels: fused sampled-weight GEMM.

The photonic machine's defining property is that the stochastic weights are
*fused with the MAC*: randomness never transits the digital datapath.  The
TPU translation: mu / sigma tiles are loaded HBM->VMEM once and perturbed
in-register, so the HBM weight traffic per MC sample is the same as a
deterministic GEMM of the *mean* weights.

Every variant exists on two entropy paths:

  * **in-kernel PRNG fast path** (``*_fused_kernel`` with
    ``in_kernel_rng=True``): the kernel seeds the per-core PRNG from
    ``(seed, grid coordinates)`` and draws its standard variates
    in-register via ``pltpu.prng_random_bits`` + Box-Muller
    (``kernels.rng``).  No entropy operand exists — 0 bytes of randomness
    cross HBM per prediction.  This is the production path on TPU.
  * **explicit-operand validation path** (``in_kernel_rng=False``, and the
    original single-sample kernels below): the standard variates arrive as
    a plain input tensor.  This is what interpret mode executes on CPU
    (the generic interpreter has no rule for the TPU PRNG primitives),
    what the parity tests drive bit-exactly against ``ref.py``, and the
    faithful model of the paper's *external* entropy source
    (``core.entropy.EntropyStream``).

Single-sample kernels (one MC draw per call, entropy operand only):

  * ``bayes_matmul_kernel``  -- weight-space noise, eps: (K, N).  Used for
    the CNN's probabilistic conv (9-channel weights are tiny).
  * ``lrt_matmul_kernel``    -- local-reparameterization, xi: (M, N).
    Noise in output space: exact same marginals, S-sample entropy cost
    scales with activations, not weights.  This is the LM-head workhorse.

Fused S-sample kernels (the TPU twin of the machine's 37.5 ps/conv
amortization — one weight load per *prediction*, not per sample):

  * ``bayes_matmul_fused_kernel`` -- grid (M/bm, N/bn, K/bk); each
    mu/sigma tile is read once and all S sampled partial products are
    accumulated into an (S, bm, bn) VMEM-resident output block.
  * ``lrt_matmul_fused_kernel``   -- mean and variance GEMMs are computed
    ONCE (they are sample-independent), accumulated in VMEM scratch, and
    the S output samples are formed on the last K step with output-space
    noise: 2 matmuls total instead of 2*S.

Tiling: classic (M/bm, N/bn, K/bk) grid, K innermost/sequential, f32
accumulation in the output ref.  Block shapes default to MXU-aligned
(128, 128) tiles with bk=512 to amortize loop overhead while the operand
tiles + accumulators stay well under VMEM (~1.3 MB at single-sample
defaults; the fused S=10 output block adds ~0.65 MB).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import rng


# ---------------------------------------------------------------------------
# single-sample, explicit-operand kernels (validation / external entropy)
# ---------------------------------------------------------------------------

def _bayes_mm_kernel(x_ref, mu_ref, sg_ref, eps_ref, o_ref):
    """One (bm, bn) output tile; accumulate over the K grid dimension."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = (mu_ref[...] + sg_ref[...] * eps_ref[...]).astype(jnp.float32)
    o_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32), w,
                          preferred_element_type=jnp.float32)


def bayes_matmul_kernel(x: jax.Array, mu: jax.Array, sigma: jax.Array,
                        eps: jax.Array, *, bm: int = 128, bn: int = 128,
                        bk: int = 512, interpret: bool = False) -> jax.Array:
    """y = x @ (mu + sigma*eps); x (M,K), mu/sigma/eps (K,N) -> (M,N) f32."""
    m, k = x.shape
    k2, n = mu.shape
    assert k == k2 and mu.shape == sigma.shape == eps.shape
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _bayes_mm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, mu, sigma, eps)


def _lrt_mm_kernel(x_ref, mu_ref, sg_ref, xi_ref, o_ref, mean_ref, var_ref,
                   *, nk: int):
    """LRT tile: accumulate mean and variance parts over K in VMEM
    scratch, then combine with the output-space noise on the last K step."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        mean_ref[...] = jnp.zeros_like(mean_ref)
        var_ref[...] = jnp.zeros_like(var_ref)

    x = x_ref[...].astype(jnp.float32)
    mu = mu_ref[...].astype(jnp.float32)
    sg = sg_ref[...].astype(jnp.float32)
    mean_ref[...] += jnp.dot(x, mu, preferred_element_type=jnp.float32)
    var_ref[...] += jnp.dot(x * x, sg * sg,
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _finish():
        var = jnp.maximum(var_ref[...], 0.0)
        o_ref[...] = (mean_ref[...] +
                      jnp.sqrt(var) * xi_ref[...].astype(jnp.float32))


def lrt_matmul_kernel(x: jax.Array, mu: jax.Array, sigma: jax.Array,
                      xi: jax.Array, *, bm: int = 128, bn: int = 128,
                      bk: int = 512, interpret: bool = False) -> jax.Array:
    """Local-reparameterization GEMM.

    x (M,K); mu/sigma (K,N); xi (M,N) output-space standard variates.
    Returns (M,N) f32:  x@mu + sqrt((x*x)@(sigma^2)) * xi.
    """
    m, k = x.shape
    _, n = mu.shape
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_lrt_mm_kernel, nk=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((bm, bn), jnp.float32),
        ],
        interpret=interpret,
    )(x, mu, sigma, xi)


# ---------------------------------------------------------------------------
# fused S-sample kernels (weights VMEM-resident across all MC samples)
# ---------------------------------------------------------------------------

def _bayes_mm_fused_kernel(*refs, num_samples: int, in_kernel_rng: bool):
    """All S sampled partial products of one mu/sigma tile read.

    The weight tile is loaded once and perturbed S times in-register —
    one HBM weight read per prediction instead of per sample.
    """
    if in_kernel_rng:
        seed_ref, x_ref, mu_ref, sg_ref, o_ref = refs
    else:
        seed_ref, x_ref, mu_ref, sg_ref, eps_ref, o_ref = refs
    j, k = pl.program_id(1), pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)
    mu = mu_ref[...].astype(jnp.float32)
    sg = sg_ref[...].astype(jnp.float32)
    if in_kernel_rng:
        # seed on the WEIGHT tile coordinates only: one MC sample must
        # apply one sampled W to every row block, so the i-th row tile
        # must replay the same eps for weight tile (j, k).
        pltpu.prng_seed(seed_ref[0, 0], j, k)
    for s in range(num_samples):
        if in_kernel_rng:
            eps = rng.normal_draw(mu.shape)
        else:
            eps = eps_ref[s].astype(jnp.float32)
        o_ref[s] += jnp.dot(x, mu + sg * eps,
                            preferred_element_type=jnp.float32)


def bayes_matmul_fused_kernel(x: jax.Array, mu: jax.Array, sigma: jax.Array,
                              seed, *, num_samples: int,
                              eps: jax.Array | None = None,
                              bm: int = 128, bn: int = 128, bk: int = 512,
                              interpret: bool = False) -> jax.Array:
    """S weight-space MC samples in one pass: (S, M, N) f32.

    eps=None selects the in-kernel PRNG fast path (TPU only); an explicit
    eps (S, K, N) selects the validation path (runs in interpret mode).
    """
    m, k = x.shape
    _, n = mu.shape
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    grid = (m // bm, n // bn, k // bk)
    in_kernel_rng = eps is None
    seed_arr = jnp.asarray(seed, jnp.int32).reshape(1, 1)
    in_specs = [
        pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0)),
        pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
    ]
    operands = [seed_arr, x, mu, sigma]
    if not in_kernel_rng:
        assert eps.shape == (num_samples, k, n), (eps.shape, (k, n))
        in_specs.append(
            pl.BlockSpec((num_samples, bk, bn), lambda i, j, kk: (0, kk, j)))
        operands.append(eps)
    return pl.pallas_call(
        functools.partial(_bayes_mm_fused_kernel, num_samples=num_samples,
                          in_kernel_rng=in_kernel_rng),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((num_samples, bm, bn),
                               lambda i, j, kk: (0, i, j)),
        out_shape=jax.ShapeDtypeStruct((num_samples, m, n), jnp.float32),
        interpret=interpret,
    )(*operands)


def _lrt_mm_fused_kernel(*refs, num_samples: int, nk: int,
                         in_kernel_rng: bool):
    """S LRT samples sharing ONE mean GEMM and ONE variance GEMM.

    The two matmuls are sample-independent, so they accumulate once in
    VMEM scratch; the S samples differ only by the output-space noise
    applied on the last K step.  2 matmuls per prediction vs 2*S for
    vmap-of-single-sample.
    """
    if in_kernel_rng:
        seed_ref, x_ref, mu_ref, sg_ref, o_ref, mean_ref, var_ref = refs
    else:
        (seed_ref, x_ref, mu_ref, sg_ref, xi_ref, o_ref,
         mean_ref, var_ref) = refs
    i, j, k = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        mean_ref[...] = jnp.zeros_like(mean_ref)
        var_ref[...] = jnp.zeros_like(var_ref)
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)
    mu = mu_ref[...].astype(jnp.float32)
    sg = sg_ref[...].astype(jnp.float32)
    mean_ref[...] += jnp.dot(x, mu, preferred_element_type=jnp.float32)
    var_ref[...] += jnp.dot(x * x, sg * sg,
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _finish():
        mean = mean_ref[...]
        std = jnp.sqrt(jnp.maximum(var_ref[...], 0.0))
        if in_kernel_rng:
            pltpu.prng_seed(seed_ref[0, 0], i, j)
        for s in range(num_samples):
            if in_kernel_rng:
                xi = rng.normal_draw(mean.shape)
            else:
                xi = xi_ref[s].astype(jnp.float32)
            o_ref[s] = mean + std * xi


def lrt_matmul_fused_kernel(x: jax.Array, mu: jax.Array, sigma: jax.Array,
                            seed, *, num_samples: int,
                            xi: jax.Array | None = None,
                            bm: int = 128, bn: int = 128, bk: int = 512,
                            interpret: bool = False) -> jax.Array:
    """S LRT MC samples in one pass: (S, M, N) f32.

    xi=None selects the in-kernel PRNG fast path (TPU only); an explicit
    xi (S, M, N) selects the validation path (runs in interpret mode).
    """
    m, k = x.shape
    _, n = mu.shape
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    grid = (m // bm, n // bn, k // bk)
    in_kernel_rng = xi is None
    seed_arr = jnp.asarray(seed, jnp.int32).reshape(1, 1)
    in_specs = [
        pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0)),
        pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
    ]
    operands = [seed_arr, x, mu, sigma]
    if not in_kernel_rng:
        assert xi.shape == (num_samples, m, n), (xi.shape, (m, n))
        in_specs.append(
            pl.BlockSpec((num_samples, bm, bn), lambda i, j, kk: (0, i, j)))
        operands.append(xi)
    return pl.pallas_call(
        functools.partial(_lrt_mm_fused_kernel, num_samples=num_samples,
                          nk=grid[2], in_kernel_rng=in_kernel_rng),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((num_samples, bm, bn),
                               lambda i, j, kk: (0, i, j)),
        out_shape=jax.ShapeDtypeStruct((num_samples, m, n), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((bm, bn), jnp.float32),
        ],
        interpret=interpret,
    )(*operands)
