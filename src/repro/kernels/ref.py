"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are validated against (interpret
mode on CPU, shape/dtype sweeps in tests/test_kernels.py).  They are also
the fallback path on backends without Pallas support.

The ``*_sampled`` variants are the seeded oracles for the in-kernel
entropy path: they derive their standard variates deterministically from
an int32 seed (``sampled_normal``) and return all S Monte-Carlo samples.
Parity with the kernels' in-kernel PRNG is statistical — output *moments*
(mean/std over S) within tolerance — since the TPU PRNG and threefry
produce different bit streams from the same seed.  Determinism (same
seed -> same output) holds exactly on each path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize(x: jax.Array, bits: int, x_max: float) -> jax.Array:
    """Forward-only uniform quantizer (kernels run inference; no STE)."""
    levels = 2 ** (bits - 1) - 1
    scale = x_max / levels
    return jnp.clip(jnp.round(x / scale), -levels, levels) * scale


def bayes_matmul(x: jax.Array, mu: jax.Array, sigma: jax.Array,
                 eps: jax.Array) -> jax.Array:
    """Weight-space sampled GEMM:  y = x @ (mu + sigma * eps).

    x: (M, K), mu/sigma/eps: (K, N)  ->  (M, N), f32 accumulation.
    """
    w = (mu + sigma * eps).astype(jnp.float32)
    return x.astype(jnp.float32) @ w


def lrt_matmul(x: jax.Array, mu: jax.Array, sigma: jax.Array,
               xi: jax.Array) -> jax.Array:
    """Local-reparameterization GEMM (Kingma et al. 2015):

        y = x @ mu + sqrt((x*x) @ (sigma*sigma)) * xi

    Exact same marginals as weight-space sampling but entropy lives in the
    *output* space (xi: (..., M, N)) -- the TPU analog of the photonic
    machine's output-side randomness, and far less entropy traffic than
    (K, N) weight noise per MC sample.
    """
    x32 = x.astype(jnp.float32)
    m = x32 @ mu.astype(jnp.float32)
    v = (x32 * x32) @ (sigma.astype(jnp.float32) ** 2)
    return m + jnp.sqrt(jnp.maximum(v, 0.0)) * xi.astype(jnp.float32)


def photonic_conv(x: jax.Array, mu: jax.Array, sigma: jax.Array,
                  eps: jax.Array, dac_bits: int = 8, adc_bits: int = 8,
                  in_range: float = 1.0, out_range: float = 4.0) -> jax.Array:
    """The machine's primitive: 9-tap probabilistic convolution.

    x: (B, T); mu/sigma: (C,); eps: (B, To, C) with To = T - C + 1.
    y[b, t] = sum_k x_q[b, t+k] * w[b, t, C-1-k],  w = mu + sigma*eps,
    then ADC quantization.  Matches core.photonic.convolve with the
    Gaussian surrogate and impairments disabled.
    """
    C = mu.shape[-1]
    To = x.shape[-1] - C + 1
    xq = quantize(x, dac_bits, in_range)
    idx = jnp.arange(To)[:, None] + jnp.arange(C)[None, :]
    taps = xq[..., idx]                       # (B, To, C)
    w = mu + sigma * eps                      # (B, To, C)
    y = jnp.sum(taps * w[..., ::-1], axis=-1)
    return quantize(y, adc_bits, out_range)


def uncertainty_head(x: jax.Array, mu: jax.Array, sigma: jax.Array,
                     xi: jax.Array) -> dict[str, jax.Array]:
    """Fused Bayesian head + uncertainty readout (paper Eqs. 1-2).

    x: (M, K) final hidden states; mu/sigma: (K, V) variational head;
    xi: (S, M, V) output-space entropy (LRT).  Returns per-row:
      H (total), SE (aleatoric), MI (epistemic), pred (argmax of mean
      predictive), p_max (confidence).
    """
    logits = lrt_matmul(x, mu, sigma, xi)     # (S, M, V) f32
    logp = jax.nn.log_softmax(logits, axis=-1)
    probs = jnp.exp(logp)
    p_mean = probs.mean(axis=0)               # (M, V)
    h = -jnp.sum(p_mean * jnp.log(p_mean + 1e-12), axis=-1)
    se = (-jnp.sum(probs * logp, axis=-1)).mean(axis=0)
    mi = jnp.maximum(h - se, 0.0)
    return {"H": h, "SE": se, "MI": mi,
            "pred": p_mean.argmax(axis=-1).astype(jnp.int32),
            "p_max": p_mean.max(axis=-1)}


# ---------------------------------------------------------------------------
# seeded oracles for the in-kernel entropy path
# ---------------------------------------------------------------------------

def sampled_normal(seed, shape: tuple[int, ...],
                   dtype=jnp.float32) -> jax.Array:
    """Deterministic standard variates from an int32 seed (threefry)."""
    key = jax.random.key(jnp.asarray(seed, jnp.uint32))
    return jax.random.normal(key, shape, dtype)


def bayes_matmul_sampled(x: jax.Array, mu: jax.Array, sigma: jax.Array,
                         seed, num_samples: int) -> jax.Array:
    """S seeded weight-space MC samples: (S, M, N)."""
    eps = sampled_normal(seed, (num_samples, *mu.shape))
    return jax.vmap(lambda e: bayes_matmul(x, mu, sigma, e))(eps)


def lrt_matmul_sampled(x: jax.Array, mu: jax.Array, sigma: jax.Array,
                       seed, num_samples: int) -> jax.Array:
    """S seeded LRT MC samples sharing one mean/variance GEMM: (S, M, N).

    This IS the fused-kernel computation shape: the two matmuls are
    sample-independent, only the output-space noise varies with s.
    """
    x32 = x.astype(jnp.float32)
    mean = x32 @ mu.astype(jnp.float32)
    var = (x32 * x32) @ (sigma.astype(jnp.float32) ** 2)
    std = jnp.sqrt(jnp.maximum(var, 0.0))
    xi = sampled_normal(seed, (num_samples, *mean.shape))
    return mean[None] + std[None] * xi


def photonic_conv_sampled(x: jax.Array, mu: jax.Array, sigma: jax.Array,
                          seed, dac_bits: int = 8, adc_bits: int = 8,
                          in_range: float = 1.0,
                          out_range: float = 4.0) -> jax.Array:
    """Seeded 9-tap probabilistic conv: fresh per-symbol draws from seed."""
    C = mu.shape[-1]
    To = x.shape[-1] - C + 1
    eps = sampled_normal(seed, (*x.shape[:-1], To, C))
    return photonic_conv(x, mu, sigma, eps, dac_bits=dac_bits,
                         adc_bits=adc_bits, in_range=in_range,
                         out_range=out_range)


def uncertainty_head_sampled(x: jax.Array, mu: jax.Array, sigma: jax.Array,
                             seed, num_samples: int) -> dict[str, jax.Array]:
    """Seeded fused Bayesian head + uncertainty readout."""
    xi = sampled_normal(seed, (num_samples, x.shape[0], mu.shape[-1]))
    return uncertainty_head(x, mu, sigma, xi)
