"""Public jit'd wrappers around the Pallas kernels.

Selection logic: on TPU backends the Pallas path runs compiled; elsewhere
(this CPU container) `interpret=True` executes the kernel body in Python
for correctness, and callers who need speed on CPU (tests over big sweeps,
examples) can force the pure-jnp oracle with ``impl='ref'``.

The ``*_sampled`` wrappers are the seed-driven fast path: on TPU the
kernels generate their entropy in-register (``in_kernel_rng=True``, zero
HBM entropy bytes); in interpret mode the same fused kernels run with an
explicit operand derived host-side from the same seed (the validation
path); ``impl='ref'`` routes to the seeded jnp oracle.  ``entropy_bytes``
reports the HBM randomness traffic of each configuration so benchmarks
measure the win instead of asserting it.

All wrappers handle padding to kernel tile multiples and strip it off, so
arbitrary problem shapes are accepted.
"""

from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.bayes_matmul import (
    bayes_matmul_fused_kernel, bayes_matmul_kernel, lrt_matmul_fused_kernel,
    lrt_matmul_kernel)
from repro.kernels.flash_attention import flash_attention_kernel
from repro.kernels.paged_attention import (paged_decode_attention_kernel,
                                           paged_prefill_attention_kernel)
from repro.kernels.photonic_conv import (
    photonic_conv_fused_kernel, photonic_conv_kernel)
from repro.kernels.uncertainty_head import (
    uncertainty_head_fused_kernel, uncertainty_head_kernel)

Impl = Literal["auto", "pallas", "ref"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(impl: Impl) -> tuple[bool, bool]:
    """-> (use_pallas, interpret)"""
    if impl == "ref":
        return False, False
    if impl == "pallas":
        return True, not _on_tpu()
    return (True, False) if _on_tpu() else (False, False)


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("impl", "bm", "bn", "bk"))
def bayes_matmul(x, mu, sigma, eps, impl: Impl = "auto",
                 bm: int = 128, bn: int = 128, bk: int = 512):
    """Sampled-weight GEMM y = x @ (mu + sigma*eps); any (M, K, N)."""
    use_pallas, interp = _resolve(impl)
    if not use_pallas:
        return ref.bayes_matmul(x, mu, sigma, eps)
    m, k = x.shape
    _, n = mu.shape
    xp = _pad_to(_pad_to(x, 0, bm), 1, bk)
    mup = _pad_to(_pad_to(mu, 0, bk), 1, bn)
    sgp = _pad_to(_pad_to(sigma, 0, bk), 1, bn)
    epp = _pad_to(_pad_to(eps, 0, bk), 1, bn)
    y = bayes_matmul_kernel(xp, mup, sgp, epp, bm=bm, bn=bn, bk=bk,
                            interpret=interp)
    return y[:m, :n]


@functools.partial(jax.jit, static_argnames=("impl", "bm", "bn", "bk"))
def lrt_matmul(x, mu, sigma, xi, impl: Impl = "auto",
               bm: int = 128, bn: int = 128, bk: int = 512):
    """Local-reparameterization GEMM; xi is output-space (M, N) noise."""
    use_pallas, interp = _resolve(impl)
    if not use_pallas:
        return ref.lrt_matmul(x, mu, sigma, xi)
    m, k = x.shape
    _, n = mu.shape
    xp = _pad_to(_pad_to(x, 0, bm), 1, bk)
    mup = _pad_to(_pad_to(mu, 0, bk), 1, bn)
    sgp = _pad_to(_pad_to(sigma, 0, bk), 1, bn)
    xip = _pad_to(_pad_to(xi, 0, bm), 1, bn)
    y = lrt_matmul_kernel(xp, mup, sgp, xip, bm=bm, bn=bn, bk=bk,
                          interpret=interp)
    return y[:m, :n]


@functools.partial(jax.jit, static_argnames=("impl", "bb", "dac_bits",
                                             "adc_bits"))
def photonic_conv(x, mu, sigma, eps, impl: Impl = "auto", bb: int = 8,
                  dac_bits: int = 8, adc_bits: int = 8):
    """Machine primitive: (B, T) x 9-channel probabilistic kernel."""
    use_pallas, interp = _resolve(impl)
    if not use_pallas:
        return ref.photonic_conv(x, mu, sigma, eps, dac_bits=dac_bits,
                                 adc_bits=adc_bits)
    b = x.shape[0]
    xp = _pad_to(x, 0, bb)
    epp = _pad_to(eps, 0, bb)
    y = photonic_conv_kernel(xp, mu, sigma, epp, bb=bb, dac_bits=dac_bits,
                             adc_bits=adc_bits, interpret=interp)
    return y[:b]


@functools.partial(jax.jit, static_argnames=("impl", "bm", "bv"))
def uncertainty_head(x, mu, sigma, xi, impl: Impl = "auto",
                     bm: int = 128, bv: int = 512):
    """Fused Bayesian head + (H, SE, MI, pred, p_max) per row."""
    use_pallas, interp = _resolve(impl)
    if not use_pallas:
        return ref.uncertainty_head(x, mu, sigma, xi)
    m = x.shape[0]
    xp = _pad_to(x, 0, bm)
    xip = _pad_to(xi, 1, bm)
    out = uncertainty_head_kernel(xp, mu, sigma, xip, bm=bm, bv=bv,
                                  interpret=interp)
    return {k: v[:m] for k, v in out.items()}


@functools.partial(jax.jit, static_argnames=("impl", "causal", "q_offset",
                                              "bq", "bk"))
def flash_attention(q, k, v, impl: Impl = "auto", causal: bool = True,
                    q_offset: int = 0, bq: int = 128, bk: int = 256):
    """Fused flash attention; q (B,S,H,D), k/v (B,S,Hkv,D) -> (B,S,H,D).

    On TPU this is the production path of the models' attention scope
    ('fused_attention'); elsewhere the jnp online-softmax reference.
    """
    use_pallas, interp = _resolve(impl)
    if not use_pallas:
        from repro.models.layers import flash_attention as ref_attn
        return ref_attn(q, k, v, causal=causal, q_offset=q_offset,
                        q_chunk=bq, kv_chunk=bk)
    out = flash_attention_kernel(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=causal, q_offset=q_offset,
        bq=bq, bk=bk, interpret=interp)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


@functools.partial(jax.jit, static_argnames=("impl",))
def paged_decode_attention(q, k_pool, v_pool, block_table, cache_len,
                           impl: Impl = "auto"):
    """Block-sparse decode attention over the paged KV pool.

    q (B, 1, H, D); k/v pools (NB, BS, Hkv, D); block_table (B, MB);
    cache_len () or (B,).  Unlike the other wrappers, ``impl='auto'``
    still runs the KERNEL off-TPU (interpret mode — the CI validation
    path): the jnp reference of this op is the gather path
    (``layers.paged_gather`` + ``layers.decode_attention``), and the
    serving engine selects between the two one level up
    (``--decode-attn``), so falling back here would silently benchmark
    the wrong HBM traffic.  ``impl='ref'`` routes to that gather
    composition for tests.
    """
    if impl == "ref":
        from repro.models.layers import (decode_attention, mapped_span,
                                         paged_gather)
        eff = mapped_span(block_table, k_pool.shape[1], cache_len)
        return decode_attention(q, paged_gather(k_pool, block_table),
                                paged_gather(v_pool, block_table), eff)
    return paged_decode_attention_kernel(q, k_pool, v_pool, block_table,
                                         cache_len,
                                         interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("span", "kv_chunk", "impl"))
def paged_prefill_attention(q, k_pool, v_pool, block_row, offset,
                            span: int, kv_chunk: int = 1024,
                            impl: Impl = "auto"):
    """Multi-query block-sparse attention for one slot's prompt chunk.

    q (1, S, H, D) at absolute positions ``offset + [0, S)``; k/v pools
    (NB, BS, Hkv, D); ``block_row`` (1, NBLK) the slot's leading mapped
    table entries covering ``span`` tokens.  Same ``impl`` policy as
    :func:`paged_decode_attention` — 'auto' still runs the kernel
    off-TPU (interpret), 'ref' routes to the gather composition
    (``layers.paged_gather`` + causal ``layers.flash_attention`` with
    ``q_offset``), which the kernel matches bitwise.
    """
    if impl == "ref":
        from repro.models.layers import flash_attention, paged_gather
        ks = paged_gather(k_pool, block_row)[:, :span]
        vs = paged_gather(v_pool, block_row)[:, :span]
        return flash_attention(q, ks, vs, causal=True, kv_chunk=kv_chunk,
                               q_offset=offset)
    return paged_prefill_attention_kernel(q, k_pool, v_pool, block_row,
                                          offset, span=span,
                                          kv_chunk=kv_chunk,
                                          interpret=not _on_tpu())


# ---------------------------------------------------------------------------
# seed-driven fast path: entropy generated in-kernel on TPU
# ---------------------------------------------------------------------------

def entropy_bytes(kind: str, *, num_samples: int, m: int = 0, k: int = 0,
                  n: int = 0, b: int = 0, t_out: int = 0, c: int = 9,
                  in_kernel: bool = False) -> int:
    """Bytes of randomness crossing HBM per prediction.

    kind: 'weight_space' (S*K*N operand), 'lrt' (S*M*N), 'head' (S*M*V ==
    lrt at the vocab), 'conv' (S*B*To*C — one fresh per-symbol draw per
    MC shot).  The in-kernel path is 0 by construction: the variates are
    born and die in registers.
    """
    if in_kernel:
        return 0
    counts = {
        "weight_space": num_samples * k * n,
        "lrt": num_samples * m * n,
        "head": num_samples * m * n,
        "conv": num_samples * b * t_out * c,
    }
    return counts[kind] * 4


@functools.partial(jax.jit, static_argnames=("num_samples", "impl", "bm",
                                             "bn", "bk"))
def bayes_matmul_sampled(x, mu, sigma, seed, num_samples: int = 10,
                         impl: Impl = "auto", bm: int = 128, bn: int = 128,
                         bk: int = 512):
    """S seeded weight-space MC samples of y = x @ (mu + sigma*eps).

    Returns (S, M, N).  On TPU the eps tensor never exists: the kernel
    draws it in-register from (seed, grid coords), and each mu/sigma tile
    is read once for all S samples.
    """
    use_pallas, interp = _resolve(impl)
    if not use_pallas:
        return ref.bayes_matmul_sampled(x, mu, sigma, seed, num_samples)
    m, k = x.shape
    _, n = mu.shape
    xp = _pad_to(_pad_to(x, 0, bm), 1, bk)
    mup = _pad_to(_pad_to(mu, 0, bk), 1, bn)
    sgp = _pad_to(_pad_to(sigma, 0, bk), 1, bn)
    eps = None
    if interp:  # validation path: host-derived operand, same seed
        eps = ref.sampled_normal(seed, (num_samples, *mup.shape))
    y = bayes_matmul_fused_kernel(xp, mup, sgp, seed,
                                  num_samples=num_samples, eps=eps,
                                  bm=bm, bn=bn, bk=bk, interpret=interp)
    return y[:, :m, :n]


@functools.partial(jax.jit, static_argnames=("num_samples", "impl", "bm",
                                             "bn", "bk"))
def lrt_matmul_sampled(x, mu, sigma, seed, num_samples: int = 10,
                       impl: Impl = "auto", bm: int = 128, bn: int = 128,
                       bk: int = 512):
    """S seeded LRT MC samples: (S, M, N), one mean/var GEMM for all S."""
    use_pallas, interp = _resolve(impl)
    if not use_pallas:
        return ref.lrt_matmul_sampled(x, mu, sigma, seed, num_samples)
    m, k = x.shape
    _, n = mu.shape
    xp = _pad_to(_pad_to(x, 0, bm), 1, bk)
    mup = _pad_to(_pad_to(mu, 0, bk), 1, bn)
    sgp = _pad_to(_pad_to(sigma, 0, bk), 1, bn)
    xi = None
    if interp:
        xi = ref.sampled_normal(
            seed, (num_samples, xp.shape[0], mup.shape[1]))
    y = lrt_matmul_fused_kernel(xp, mup, sgp, seed,
                                num_samples=num_samples, xi=xi,
                                bm=bm, bn=bn, bk=bk, interpret=interp)
    return y[:, :m, :n]


@functools.partial(jax.jit, static_argnames=("impl", "bb", "dac_bits",
                                             "adc_bits"))
def photonic_conv_sampled(x, mu, sigma, seed, impl: Impl = "auto",
                          bb: int = 8, dac_bits: int = 8, adc_bits: int = 8):
    """Seeded machine primitive: per-symbol draws born in-kernel on TPU."""
    use_pallas, interp = _resolve(impl)
    if not use_pallas:
        return ref.photonic_conv_sampled(x, mu, sigma, seed,
                                         dac_bits=dac_bits,
                                         adc_bits=adc_bits)
    b, t = x.shape
    c = mu.shape[-1]
    xp = _pad_to(x, 0, bb)
    eps = None
    if interp:
        eps = ref.sampled_normal(seed, (xp.shape[0], t - c + 1, c))
    y = photonic_conv_fused_kernel(xp, mu, sigma, seed, eps=eps, bb=bb,
                                   dac_bits=dac_bits, adc_bits=adc_bits,
                                   interpret=interp)
    return y[:b]


@functools.partial(jax.jit, static_argnames=("num_samples", "impl", "bm",
                                             "bv"))
def uncertainty_head_sampled(x, mu, sigma, seed, num_samples: int = 10,
                             impl: Impl = "auto", bm: int = 128,
                             bv: int = 512):
    """Seeded fused Bayesian head: no xi operand, no logits scratch.

    Pass 2 regenerates the sample logits from the replayed in-kernel
    stream instead of re-reading an (S, M, V) HBM buffer.
    """
    use_pallas, interp = _resolve(impl)
    if not use_pallas:
        return ref.uncertainty_head_sampled(x, mu, sigma, seed, num_samples)
    m = x.shape[0]
    xp = _pad_to(x, 0, bm)
    xi = None
    if interp:
        xi = ref.sampled_normal(
            seed, (num_samples, xp.shape[0], mu.shape[-1]))
    out = uncertainty_head_fused_kernel(xp, mu, sigma, seed,
                                        num_samples=num_samples, xi=xi,
                                        bm=bm, bv=bv, interpret=interp)
    return {k: v[:m] for k, v in out.items()}


def bayes_conv2d_im2col_sampled(x, mu, sigma, seed, num_samples: int = 10,
                                impl: Impl = "auto"):
    """S seeded MC samples of the 3x3 probabilistic conv (im2col GEMM).

    x: (B, C_in, H, W); mu/sigma: (C_out, C_in, 3, 3)
    -> (S, B, C_out, H, W).  The im2col GEMM routes through the fused
    S-sample kernel: one weight load per prediction.
    """
    b, cin, h, w = x.shape
    cout = mu.shape[0]
    xp = jnp.pad(x, ((0, 0), (0, 0), (1, 1), (1, 1)))
    patches = jax.lax.conv_general_dilated_patches(
        xp, (3, 3), (1, 1), "VALID",
        dimension_numbers=("NCHW", "OIHW", "NHWC"))
    pk = patches.reshape(b * h * w, cin * 9)
    mu2 = mu.reshape(cout, cin * 9).T
    sg2 = sigma.reshape(cout, cin * 9).T
    y = bayes_matmul_sampled(pk, mu2, sg2, seed, num_samples=num_samples,
                             impl=impl)
    return y.reshape(num_samples, b, h, w, cout).transpose(0, 1, 4, 2, 3)


def bayes_conv2d_im2col(x, mu, sigma, eps, impl: Impl = "auto"):
    """3x3 probabilistic conv as a sampled GEMM (im2col).

    The TPU-native form of the machine's convolution: the 9 spectral
    channels become the 9 im2col columns feeding the MXU.
    x: (B, C_in, H, W); mu/sigma/eps: (C_out, C_in, 3, 3) -> (B, C_out, H, W).
    """
    b, cin, h, w = x.shape
    cout = mu.shape[0]
    xp = jnp.pad(x, ((0, 0), (0, 0), (1, 1), (1, 1)))
    # patches: (B, H, W, C_in*9)
    patches = jax.lax.conv_general_dilated_patches(
        xp, (3, 3), (1, 1), "VALID",
        dimension_numbers=("NCHW", "OIHW", "NHWC"))
    pk = patches.reshape(b * h * w, cin * 9)
    mu2 = mu.reshape(cout, cin * 9).T
    sg2 = sigma.reshape(cout, cin * 9).T
    ep2 = eps.reshape(cout, cin * 9).T
    y = bayes_matmul(pk, mu2, sg2, ep2, impl=impl)
    return y.reshape(b, h, w, cout).transpose(0, 3, 1, 2)
