"""Pallas TPU kernels for the probabilistic compute hot spots.

bayes_matmul     -- fused sampled-weight GEMM (weight-space noise)
lrt_matmul       -- local-reparameterization GEMM (output-space noise)
photonic_conv    -- the machine's 9-tap frequency-time interleaved conv
uncertainty_head -- fused S-sample Bayesian head + online H/SE/MI reduce
flash_attention  -- fused online-softmax attention (score tiles in VMEM)

Each has a pure-jnp oracle in ref.py (flash: models.layers) and a jit'd
public wrapper in ops.py.
"""

from repro.kernels import ops, ref  # noqa: F401
from repro.kernels.ops import (  # noqa: F401
    bayes_conv2d_im2col, bayes_matmul, flash_attention, lrt_matmul,
    photonic_conv, uncertainty_head)
