"""Pallas TPU kernels for the probabilistic compute hot spots.

Every Bayesian kernel family exists on two entropy paths:

  * **in-kernel PRNG fast path** — the production TPU path.  Kernels seed
    the per-core PRNG from (seed, grid coordinates) and draw standard
    normals in-register (``pltpu.prng_random_bits`` + Box-Muller, see
    ``rng.py``).  No entropy operand exists: 0 bytes of randomness cross
    HBM per prediction — the TPU twin of the photonic machine's
    "randomness never transits the digital datapath".  Selected by the
    ``*_sampled`` ops wrappers when running compiled on TPU.
  * **explicit-operand validation path** — the standard variates arrive
    as a plain tensor operand (``eps``/``xi``).  Used by interpret mode
    on CPU (the generic interpreter has no TPU PRNG rule), by the parity
    tests against the ``ref.py`` oracles, and to model the paper's
    *external* entropy source (``core.entropy.EntropyStream``).

Kernels:

bayes_matmul      -- fused sampled-weight GEMM (weight-space noise)
lrt_matmul        -- local-reparameterization GEMM (output-space noise)
*_sampled         -- fused S-sample variants: mu/sigma tiles stay
                     VMEM-resident across all S MC samples (one weight
                     load per prediction, not per sample), LRT shares
                     one mean+variance GEMM across samples
photonic_conv     -- the machine's 9-tap frequency-time interleaved conv
uncertainty_head  -- fused S-sample Bayesian head + online H/SE/MI reduce;
                     the sampled variant regenerates logits in pass 2
                     from the replayed PRNG stream instead of re-reading
                     an (S, M, V) HBM scratch
flash_attention   -- fused online-softmax attention (score tiles in VMEM)

Each has a pure-jnp oracle in ref.py (flash: models.layers) — including
seeded ``*_sampled`` oracles — and a jit'd public wrapper in ops.py.
"""

from repro.kernels import ops, ref, rng  # noqa: F401
from repro.kernels.ops import (  # noqa: F401
    bayes_conv2d_im2col, bayes_conv2d_im2col_sampled, bayes_matmul,
    bayes_matmul_sampled, entropy_bytes, flash_attention, lrt_matmul,
    lrt_matmul_sampled, photonic_conv, photonic_conv_sampled,
    uncertainty_head, uncertainty_head_sampled)
