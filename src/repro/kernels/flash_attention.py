"""Pallas TPU kernel: fused flash attention (forward).

Backs the ``jax.named_scope('fused_attention')`` region of
``models.layers.flash_attention``: on TPU the score tile
(q_block x kv_block) lives in VMEM and never touches HBM — HBM traffic is
Q + K + V reads and O writes only, which is exactly what the roofline
accounting (launch.hlo_cost skip_byte_scopes) models for that scope.

Layout: q (B, H, Sq, D); k/v (B, Hkv, Sk, D); GQA via h // rep in the
k/v BlockSpec index map.  Grid (B*H, nq, nk) with nk innermost and
SEQUENTIAL: the (m, l, acc) online-softmax state persists in the output
refs across the nk steps (same accumulation pattern as bayes_matmul).

MXU alignment: D and the kv block are multiples of 128 at production
sizes; q block 128-512 rows.  f32 accumulation throughout.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, *,
                  nk: int, kc: int, qc: int, sq: int, sk: int,
                  causal: bool, q_offset: int, scale: float):
    kj = pl.program_id(2)
    qi = pl.program_id(1)

    @pl.when(kj == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)                       # (qc, D)
    k = k_ref[0].astype(jnp.float32)                       # (kc, D)
    v = v_ref[0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    qpos = q_offset + qi * qc + jax.lax.broadcasted_iota(
        jnp.int32, (qc, kc), 0)
    kpos = kj * kc + jax.lax.broadcasted_iota(jnp.int32, (qc, kc), 1)
    mask = kpos < sk
    if causal:
        mask = mask & (kpos <= qpos)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[0]                                      # (qc,)
    l_prev = l_ref[0]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=-1)
    o_new = o_ref[0] * corr[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[0] = m_new
    l_ref[0] = l_new
    o_ref[0] = o_new

    @pl.when(kj == nk - 1)
    def _finish():
        o_ref[0] = o_ref[0] / jnp.maximum(l_ref[0], 1e-20)[:, None]


def flash_attention_kernel(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, q_offset: int = 0,
                           bq: int = 128, bk: int = 256,
                           interpret: bool = False) -> jax.Array:
    """q: (B, H, Sq, D); k/v: (B, Hkv, Sk, D) -> (B, H, Sq, D) f32.

    Sq/Sk need not be multiples of bq/bk (padded; masked by Sk).
    """
    B, H, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    rep = H // Hkv
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    pq = (-Sq) % bq
    pk = (-Sk) % bk
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    nq = qp.shape[2] // bq
    nk = kp.shape[2] // bk
    grid = (B * H, nq, nk)
    scale = 1.0 / float(D) ** 0.5

    out, _, _ = pl.pallas_call(
        functools.partial(_flash_kernel, nk=nk, kc=bk, qc=bq, sq=Sq,
                          sk=Sk, causal=causal, q_offset=q_offset,
                          scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D),
                         lambda bh, qi, kj: (bh, qi, 0)),
            pl.BlockSpec((1, bk, D),
                         lambda bh, qi, kj, rep=rep, Hh=H:
                         ((bh // Hh) * (Hh // rep) + (bh % Hh) // rep,
                          kj, 0)),
            pl.BlockSpec((1, bk, D),
                         lambda bh, qi, kj, rep=rep, Hh=H:
                         ((bh // Hh) * (Hh // rep) + (bh % Hh) // rep,
                          kj, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, D), lambda bh, qi, kj: (bh, qi, 0)),
            pl.BlockSpec((1, bq), lambda bh, qi, kj: (bh, qi)),
            pl.BlockSpec((1, bq), lambda bh, qi, kj: (bh, qi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, nq * bq, D), jnp.float32),
            jax.ShapeDtypeStruct((B * H, nq * bq), jnp.float32),
            jax.ShapeDtypeStruct((B * H, nq * bq), jnp.float32),
        ],
        interpret=interpret,
    )(qp.reshape(B * H, nq * bq, D),
      kp.reshape(B * Hkv, nk * bk, D),
      vp.reshape(B * Hkv, nk * bk, D))
    return out.reshape(B, H, nq * bq, D)[:, :, :Sq]
