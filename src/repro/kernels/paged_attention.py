"""Pallas TPU kernels: block-sparse paged attention (decode + prefill).

Two kernels share the paged-pool layout: the single-token DECODE kernel
below, and the multi-query PREFILL kernel (``_paged_prefill_kernel``)
that chunked prefill (PR 6) uses to attend a whole prompt chunk against
one slot's mapped blocks — same query-span tiling idea, but its
finalize replays ``layers.flash_attention``'s online per-kv-chunk
recurrence instead of the decode reference's deferred softmax, because
each kernel must be bit-exact against ITS OWN gather reference and the
two references associate differently.

Single-token decode attention that reads K/V **directly from the global
block pool** through the per-slot block table — the bandwidth half of
the paged-KV story.  The gather path (``layers.paged_gather`` followed
by ``layers.decode_attention``) first materializes each slot's full
logical strip — ``MB * BS`` tokens per slot per layer, mapped or not —
so its per-step HBM traffic is identical to the dense strips the paged
layout was built to retire.  This kernel touches exactly the blocks
that hold cached tokens: per-step HBM reads scale with ``cache_len``,
not with the logical span.

Grid: ``(B, Hkv, MB)`` — (slot, kv-head, logical-block), the
logical-block axis innermost and SEQUENTIAL.  Each program loads ONE
physical K block and one V block of ``BS`` tokens through a
scalar-prefetched ``(B, MB)`` block table (``pltpu.
PrefetchScalarGridSpec``): the BlockSpec index map reads the table and
returns the mapped physical block id for step ``j``.

Skip rule — two kinds of logical block never cost HBM:

* blocks entirely past ``cache_len[b]``: the index map clamps ``j`` to
  the last block the slot's depth spans, so every skipped step returns
  the SAME physical index as its predecessor and the Pallas pipeline
  elides the copy (consecutive equal index-map results fetch nothing);
* ``-1`` (unmapped) table entries: clamped to physical block 0 in the
  index map (fetched once, then elided) and masked to ``-inf`` in the
  body, so an evicted slot's junk steps — or a table whose mapped
  prefix is shorter than its depth — contribute nothing to the softmax.
  The same masking guards the gather path (see
  ``layers.mapped_span``): physical block 0 may be OWNED by the prefix
  cache (PR 4), and a masked position must never leak cached bytes
  into another request's reduction.

Reduction: flash-style with DEFERRED normalization.  Per-block score
tiles ``q @ k_j^T / sqrt(D)`` and the V blocks stream into VMEM scratch
(``(rep, MB*BS)`` + ``(MB*BS, D)`` f32); the last grid step runs ONE
softmax over exactly the masked span and one ``p @ V`` contraction over
the full span.  This is deliberate: the gather reference computes
softmax and the value contraction at full span, and BIT-EXACTNESS
requires matching its reduction extents and association — a
running-rescale online softmax multiplies ``exp(s - m_j)`` by
correction factors ``exp(m_j - m_final)`` and drifts in the last ulp
(the same lesson as PR 4's equal-reduction-extent suffix prefill).
HBM traffic is identical either way; what the deferral costs is VMEM
(one f32 score row and one f32 V strip per (slot, kv-head) program,
fine at serving block counts; tiling the span for 32k+ contexts is
future work).  Bit-exactness vs the gather path:
tests/test_paged_attention.py.

MXU alignment at production sizes wants BS and D multiples of 128 and
``rep`` padded to the sublane; the reduced CPU configs run the kernel
in interpret mode, which is also the CI validation path (no TPU in the
container — compiled-path numbers land with first TPU access, like the
in-kernel entropy path of PR 1).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _paged_decode_kernel(bt_ref, cl_ref, q_ref, k_ref, v_ref, o_ref,
                         s_scr, v_scr, *, MB: int, BS: int, D: int,
                         rep: int):
    """One (slot b, kv-head h, logical block j) program.

    ``bt_ref`` (B, MB) and ``cl_ref`` (B,) are the scalar-prefetch refs
    the index maps already consumed; the body re-reads them for the
    validity mask.  ``s_scr``/``v_scr`` persist across the sequential
    ``j`` axis; every step writes its slice (skipped blocks write
    ``-inf`` scores and the clamped fetch's V bytes, which the zero
    probabilities annihilate), so the output is a deterministic
    function of the inputs alone.
    """
    b = pl.program_id(0)
    j = pl.program_id(2)

    phys = bt_ref[b, j]                    # raw entry: -1 = unmapped
    clen = cl_ref[b]
    kpos = j * BS + jax.lax.broadcasted_iota(jnp.int32, (rep, BS), 1)
    # a position is readable only if it is below the slot's depth AND
    # its logical block is actually mapped: -inf BEFORE the reduction,
    # exactly like the gather path's mapped_span clamp
    valid = (kpos < clen) & (phys >= 0)

    q = q_ref[0, 0].astype(jnp.float32)                    # (rep, D)
    k = k_ref[0, :, 0].astype(jnp.float32)                 # (BS, D)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) / jnp.sqrt(
        jnp.float32(D))
    s_scr[:, pl.ds(j * BS, BS)] = jnp.where(valid, s, -jnp.inf)
    v_scr[pl.ds(j * BS, BS), :] = v_ref[0, :, 0].astype(jnp.float32)

    @pl.when(j == MB - 1)
    def _finalize():
        # one softmax + one value contraction over the FULL span: the
        # reduction extents and association match decode_attention's
        # bit for bit (masked columns hold -inf -> exact zeros)
        sf = s_scr[...]
        m = jnp.max(sf, axis=-1, keepdims=True)
        p = jnp.exp(sf - jax.lax.stop_gradient(m))
        p = p / jnp.sum(p, axis=-1, keepdims=True)
        o_ref[0, 0] = jnp.dot(p, v_scr[...],
                              preferred_element_type=jnp.float32)


def paged_decode_attention_kernel(q: jax.Array, k_pool: jax.Array,
                                  v_pool: jax.Array,
                                  block_table: jax.Array,
                                  cache_len: jax.Array, *,
                                  interpret: bool = False) -> jax.Array:
    """q (B, 1, H, D); k/v pools (NB, BS, Hkv, D); table (B, MB) int32;
    cache_len (B,) int32 -> (B, 1, H, D) in q.dtype.

    Matches ``layers.decode_attention(q, paged_gather(k), paged_gather
    (v), mapped-span-clamped len)`` bit for bit (operand/interpret
    mode) while reading only mapped, in-depth blocks from HBM.  A slot
    whose span is fully masked (``cache_len == 0`` or an all ``-1``
    table row) returns NaN, exactly like the reference's fully-masked
    softmax — never another block's bytes.
    """
    NB, BS, Hkv, D = k_pool.shape
    B, _, H, _ = q.shape
    MB = block_table.shape[1]
    rep = H // Hkv
    # head h of the flat H axis is (group g = h // rep, replica h % rep)
    qg = q.reshape(B, Hkv, rep, D)
    if rep == 1:
        # MHA: pad the replica axis to two rows, mirroring
        # layers.decode_attention — a 1-row tile would take XLA's
        # matrix-vector emitter, whose f32 association differs from the
        # gemm the reference's padded form uses; the zero row is
        # discarded below
        qg = jnp.concatenate([qg, jnp.zeros_like(qg)], axis=2)
    krep = qg.shape[2]
    table = block_table.astype(jnp.int32)
    lens = jnp.broadcast_to(jnp.reshape(cache_len, (-1,)),
                            (B,)).astype(jnp.int32)

    def kv_map(b, h, j, bt, cl):
        # clamp j to the last block the slot's depth spans: every step
        # past it returns the SAME physical index, so the pipeline
        # skips the fetch; -1 entries clamp to block 0 (fetched once,
        # masked in the body)
        nb = jnp.maximum(jnp.minimum(pl.cdiv(cl[b], BS), MB), 1)
        je = jnp.minimum(j, nb - 1)
        return (jnp.maximum(bt[b, je], 0), 0, h, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, MB),
        in_specs=[
            pl.BlockSpec((1, 1, krep, D),
                         lambda b, h, j, bt, cl: (b, h, 0, 0)),
            pl.BlockSpec((1, BS, 1, D), kv_map),
            pl.BlockSpec((1, BS, 1, D), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, krep, D),
                               lambda b, h, j, bt, cl: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((krep, MB * BS), jnp.float32),
            pltpu.VMEM((MB * BS, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_paged_decode_kernel, MB=MB, BS=BS, D=D,
                          rep=krep),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, krep, D), jnp.float32),
        interpret=interpret,
    )(table, lens, qg, k_pool, v_pool)
    return out[:, :, :rep].reshape(B, 1, H, D).astype(q.dtype)


def _paged_prefill_kernel(bt_ref, off_ref, q_ref, k_ref, v_ref, o_ref,
                          s_scr, v_scr, *, NBLK: int, BS: int, D: int,
                          S: int, kc: int, NK: int, span: int):
    """One (kv-head h, logical block j) program of the multi-query
    (chunked-prefill) kernel.

    Scores for the whole q tile against block j stream into scratch;
    the last step replays ``layers.flash_attention``'s per-``kc``-group
    ONLINE softmax recurrence over the buffered span — group extents,
    masking, correction factors and the final ``acc / max(l, 1e-20)``
    all mirror the jnp reference op for op, which is what makes the
    kernel bit-exact against the gather+flash composition (the decode
    kernel's reference instead normalizes before the value contraction;
    the two associate differently, hence two kernels).
    """
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        s_scr[...] = jnp.full_like(s_scr[...], -jnp.inf)
        v_scr[...] = jnp.zeros_like(v_scr[...])

    off = off_ref[0]
    QR = s_scr.shape[0]
    q = q_ref[0].astype(jnp.float32)                       # (QR, D)
    k = k_ref[0, :, 0].astype(jnp.float32)                 # (BS, D)
    scale = 1.0 / jnp.sqrt(jnp.float32(D))
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    kpos = j * BS + jax.lax.broadcasted_iota(jnp.int32, (QR, BS), 1)
    qpos = off + jax.lax.broadcasted_iota(jnp.int32, (QR, BS), 0) % S
    mask = (kpos < span) & (kpos <= qpos)
    s_scr[:, pl.ds(j * BS, BS)] = jnp.where(mask, s, -jnp.inf)
    v_scr[pl.ds(j * BS, BS), :] = v_ref[0, :, 0].astype(jnp.float32)

    @pl.when(j == NBLK - 1)
    def _finalize():
        m = jnp.full((QR,), -jnp.inf, jnp.float32)
        l = jnp.zeros((QR,), jnp.float32)
        acc = jnp.zeros((QR, D), jnp.float32)
        for g in range(NK):                     # flash's kv-chunk scan
            sl = s_scr[:, g * kc:(g + 1) * kc]
            m2 = jnp.maximum(m, sl.max(axis=-1))
            m2s = jnp.where(jnp.isinf(m2), 0.0, m2)
            p = jnp.exp(sl - m2s[..., None])
            p = jnp.where(jnp.isinf(sl), 0.0, p)
            corr = jnp.where(jnp.isinf(m), 0.0, jnp.exp(m - m2s))
            l = l * corr + p.sum(axis=-1)
            acc = acc * corr[..., None] + jnp.dot(
                p, v_scr[g * kc:(g + 1) * kc, :],
                preferred_element_type=jnp.float32)
            m = m2
        o_ref[0] = acc / jnp.maximum(l, 1e-20)[..., None]


def paged_prefill_attention_kernel(q: jax.Array, k_pool: jax.Array,
                                   v_pool: jax.Array,
                                   block_row: jax.Array,
                                   offset: jax.Array, *, span: int,
                                   kv_chunk: int = 1024,
                                   interpret: bool = False) -> jax.Array:
    """Multi-query block-sparse attention for ONE slot's prompt chunk.

    q (1, S, H, D) chunk queries at absolute positions
    ``offset + [0, S)``; k/v pools (NB, BS, Hkv, D); ``block_row``
    (1, NBLK) the leading mapped entries of the slot's table row
    (exactly the blocks spanning ``span`` tokens — query-span tiling:
    HBM reads scale with the prompt span, not the table width);
    ``offset`` traced int32; ``span`` STATIC reduction extent.

    Bit-exact vs ``paged_gather`` + ``layers.flash_attention(causal,
    kv_chunk, q_offset=offset)`` over the same span
    (tests/test_chunked_prefill.py), unmapped-entry block-0 fallback
    included.  Grid (Hkv, NBLK), block axis sequential.
    """
    NB, BS, Hkv, D = k_pool.shape
    _, S, H, _ = q.shape
    NBLK = block_row.shape[1]
    rep = H // Hkv
    kc = min(kv_chunk, span)
    NK = -(-span // kc)
    SW = max(NBLK * BS, NK * kc)            # scratch span (cols >= both)
    # rows flatten (replica, query) -> r * S + q; pad to >= 2 rows so the
    # score contraction stays on the gemm path (see the decode kernel)
    qg = q.reshape(S, Hkv, rep, D).transpose(1, 2, 0, 3)
    qg = qg.reshape(Hkv, rep * S, D)
    QR = max(rep * S, 2)
    if qg.shape[1] < QR:
        qg = jnp.pad(qg, ((0, 0), (0, QR - qg.shape[1]), (0, 0)))
    table = jnp.maximum(block_row.astype(jnp.int32), 0)[0]     # (NBLK,)
    off = jnp.reshape(jnp.asarray(offset, jnp.int32), (1,))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(Hkv, NBLK),
        in_specs=[
            pl.BlockSpec((1, QR, D), lambda h, j, bt, off: (h, 0, 0)),
            pl.BlockSpec((1, BS, 1, D),
                         lambda h, j, bt, off: (bt[j], 0, h, 0)),
            pl.BlockSpec((1, BS, 1, D),
                         lambda h, j, bt, off: (bt[j], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, QR, D),
                               lambda h, j, bt, off: (h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((QR, SW), jnp.float32),
            pltpu.VMEM((SW, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_paged_prefill_kernel, NBLK=NBLK, BS=BS, D=D,
                          S=S, kc=kc, NK=NK, span=span),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Hkv, QR, D), jnp.float32),
        interpret=interpret,
    )(table, off, qg, k_pool, v_pool)
    out = out[:, :rep * S].reshape(Hkv, rep, S, D)
    return out.transpose(2, 0, 1, 3).reshape(1, S, H, D).astype(q.dtype)


def kv_blocks_read(cache_len, mapped_blocks, block_size: int,
                   table_width: int) -> int:
    """Physical KV blocks one decode step reads for one slot.

    The kernel's skip rule in host arithmetic: blocks spanned by the
    slot's depth, clamped to what the table actually maps (the
    ``-1``-clamped fetches of a junk slot collapse to one block-0
    fetch, counted as 0 here since the pipeline elides all but the
    first; the bench treats it as noise).  The gather path reads the
    full ``table_width`` span regardless.
    """
    spanned = min(-(-int(cache_len) // block_size), table_width)
    return max(min(spanned, int(mapped_blocks)), 0)
