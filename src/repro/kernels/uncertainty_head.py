"""Pallas TPU kernels: fused Bayesian LM head + uncertainty readout.

The serving hot spot of a Bayesian LM: for every token, draw S Monte-Carlo
samples of the output head, softmax each over the vocabulary, and reduce to
the paper's uncertainty triplet (H total / SE aleatoric / MI epistemic,
Eqs. 1-2).  Done naively this is S full-vocab softmaxes plus S sampled
(K, V) weight tensors in HBM.

Fusion strategy (two passes over the vocab tiles):

  pass 1 ``_head_stats_kernel``:
    logits_s = x @ mu + sqrt((x*x) @ sigma^2) * xi_s       (LRT sampling,
    mu/sigma read ONCE for all S samples — the photonic 'weights stay in
    the analog domain' property), written to a scratch logits buffer, with
    ONLINE (max, sumexp, sum l*exp) accumulators per (sample, row) carried
    across vocab tiles — the flash-softmax trick extended with the
    first-moment accumulator A = sum(e^{l-mx} * l), which closes SE:
        SE_s = mx + log Z - A / Z.

  pass 2 ``_head_entropy_kernel``:
    re-reads the logits tiles with the pass-1 normalizers to accumulate the
    mean predictive p_bar tile by tile:  H = -sum p_bar log p_bar, plus the
    argmax/confidence of p_bar.  No matmul in this pass — it is purely
    bandwidth-bound over the (S, M, V) logits scratch.

``uncertainty_head_fused_kernel`` is the in-kernel-entropy successor: the
(S, M, V) logits scratch — at V=4096, S=10 *larger than the weight
traffic the kernel was built to avoid* — disappears entirely.  Pass 1
emits only the (3, S, M) online stats; pass 2 *regenerates* each logits
tile (re-doing the two small matmuls and re-seeding the per-core PRNG
with the same (seed, i, j), which replays the same variates) instead of
re-reading it from HBM.  Compute is traded for the dominant HBM term.
With an explicit xi operand the same structure runs in interpret mode as
the validation path (both passes read the same xi tile).

Vocab padding is handled by masking inside the kernel (static closure over
the true V), so any vocabulary size works with 128-aligned tiles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import rng

_NEG = -1e30


def _head_stats_kernel(x_ref, mu_ref, sg_ref, xi_ref, logits_ref, stats_ref,
                       *, v_actual: int, bv: int):
    j = pl.program_id(1)
    x = x_ref[...].astype(jnp.float32)                       # (bm, K)
    mu = mu_ref[...].astype(jnp.float32)                     # (K, bv)
    sg = sg_ref[...].astype(jnp.float32)
    mean = jnp.dot(x, mu, preferred_element_type=jnp.float32)
    var = jnp.dot(x * x, sg * sg, preferred_element_type=jnp.float32)
    std = jnp.sqrt(jnp.maximum(var, 0.0))
    logits = mean[None] + std[None] * xi_ref[...].astype(jnp.float32)
    # mask padded vocab columns
    col = j * bv + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
    logits = jnp.where(col < v_actual, logits, _NEG)
    logits_ref[...] = logits

    tmax = logits.max(axis=-1)                               # (S, bm)
    ex = jnp.exp(logits - tmax[..., None])
    tz = ex.sum(axis=-1)
    ta = (ex * logits).sum(axis=-1)

    @pl.when(j == 0)
    def _init():
        stats_ref[0] = tmax
        stats_ref[1] = tz
        stats_ref[2] = ta

    @pl.when(j > 0)
    def _merge():
        mx, z, a = stats_ref[0], stats_ref[1], stats_ref[2]
        mx2 = jnp.maximum(mx, tmax)
        c1 = jnp.exp(mx - mx2)
        c2 = jnp.exp(tmax - mx2)
        stats_ref[0] = mx2
        stats_ref[1] = z * c1 + tz * c2
        stats_ref[2] = a * c1 + ta * c2


def _head_entropy_kernel(logits_ref, stats_ref, h_ref, best_ref, *,
                         v_actual: int, bv: int, num_samples: int):
    j = pl.program_id(1)
    logits = logits_ref[...]                                 # (S, bm, bv)
    mx = stats_ref[0][..., None]                             # (S, bm, 1)
    z = stats_ref[1][..., None]
    pbar = (jnp.exp(logits - mx) / z).mean(axis=0)           # (bm, bv)
    contrib = pbar * jnp.log(pbar + 1e-12)
    col = j * bv + jax.lax.broadcasted_iota(jnp.int32, pbar.shape, 1)
    contrib = jnp.where(col < v_actual, contrib, 0.0)
    tile_h = contrib.sum(axis=-1)                            # (bm,)
    pbar_m = jnp.where(col < v_actual, pbar, -1.0)
    tile_best = pbar_m.max(axis=-1)
    tile_idx = (j * bv + jnp.argmax(pbar_m, axis=-1)).astype(jnp.float32)

    @pl.when(j == 0)
    def _init():
        h_ref[0] = -tile_h
        best_ref[0] = tile_best
        best_ref[1] = tile_idx

    @pl.when(j > 0)
    def _merge():
        h_ref[0] = h_ref[0] - tile_h
        better = tile_best > best_ref[0]
        best_ref[0] = jnp.where(better, tile_best, best_ref[0])
        best_ref[1] = jnp.where(better, tile_idx, best_ref[1])


def uncertainty_head_kernel(x: jax.Array, mu: jax.Array, sigma: jax.Array,
                            xi: jax.Array, *, bm: int = 128, bv: int = 512,
                            interpret: bool = False) -> dict[str, jax.Array]:
    """x: (M, K); mu/sigma: (K, V); xi: (S, M, V) -> uncertainty dict.

    Shapes must satisfy M % bm == 0; V is padded internally to bv-multiple
    (mask-correct).  K is unblocked (the head's K is d_model, fits VMEM).
    """
    m, k = x.shape
    _, v = mu.shape
    s = xi.shape[0]
    bm = min(bm, m)
    assert m % bm == 0, (m, bm)
    v_pad = (-v) % bv
    if v_pad:
        mu = jnp.pad(mu, ((0, 0), (0, v_pad)))
        sigma = jnp.pad(sigma, ((0, 0), (0, v_pad)))
        xi = jnp.pad(xi, ((0, 0), (0, 0), (0, v_pad)))
    vp = v + v_pad
    grid = (m // bm, vp // bv)

    logits, stats = pl.pallas_call(
        functools.partial(_head_stats_kernel, v_actual=v, bv=bv),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bv), lambda i, j: (0, j)),
            pl.BlockSpec((k, bv), lambda i, j: (0, j)),
            pl.BlockSpec((s, bm, bv), lambda i, j: (0, i, j)),
        ],
        out_specs=[
            pl.BlockSpec((s, bm, bv), lambda i, j: (0, i, j)),
            pl.BlockSpec((3, s, bm), lambda i, j: (0, 0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((s, m, vp), jnp.float32),
            jax.ShapeDtypeStruct((3, s, m), jnp.float32),
        ],
        interpret=interpret,
    )(x, mu, sigma, xi)

    h, best = pl.pallas_call(
        functools.partial(_head_entropy_kernel, v_actual=v, bv=bv,
                          num_samples=s),
        grid=grid,
        in_specs=[
            pl.BlockSpec((s, bm, bv), lambda i, j: (0, i, j)),
            pl.BlockSpec((3, s, bm), lambda i, j: (0, 0, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, bm), lambda i, j: (0, i)),
            pl.BlockSpec((2, bm), lambda i, j: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, m), jnp.float32),
            jax.ShapeDtypeStruct((2, m), jnp.float32),
        ],
        interpret=interpret,
    )(logits, stats)

    mx, z, a = stats[0], stats[1], stats[2]
    se = (mx + jnp.log(z) - a / z).mean(axis=0)              # (M,)
    h = h[0]
    return {"H": h, "SE": se, "MI": jnp.maximum(h - se, 0.0),
            "pred": best[1].astype(jnp.int32), "p_max": best[0]}


# ---------------------------------------------------------------------------
# fused in-kernel-entropy variant: no (S, M, V) logits scratch in HBM
# ---------------------------------------------------------------------------

def _sampled_logits_tile(x_ref, mu_ref, sg_ref, xi, j, *, v_actual: int,
                         bv: int):
    """(S, bm, bv) LRT logits of one vocab tile, padded columns masked."""
    x = x_ref[...].astype(jnp.float32)                       # (bm, K)
    mu = mu_ref[...].astype(jnp.float32)                     # (K, bv)
    sg = sg_ref[...].astype(jnp.float32)
    mean = jnp.dot(x, mu, preferred_element_type=jnp.float32)
    var = jnp.dot(x * x, sg * sg, preferred_element_type=jnp.float32)
    std = jnp.sqrt(jnp.maximum(var, 0.0))
    logits = mean[None] + std[None] * xi
    col = j * bv + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
    return jnp.where(col < v_actual, logits, _NEG)


def _tile_xi(seed_ref, refs_xi, shape, in_kernel_rng: bool):
    """The (S, bm, bv) standard variates of the current tile.

    In-kernel path: re-seeding with the same (seed, i, j) replays the
    same bits in pass 1 and pass 2 — the property that makes the logits
    scratch avoidable.  Operand path: both passes read the same tile.
    """
    if in_kernel_rng:
        pltpu.prng_seed(seed_ref[0, 0], pl.program_id(0), pl.program_id(1))
        return rng.normal_draw(shape)
    return refs_xi[...].astype(jnp.float32)


def _head_stats_fused_kernel(*refs, v_actual: int, bv: int,
                             num_samples: int, in_kernel_rng: bool):
    if in_kernel_rng:
        seed_ref, x_ref, mu_ref, sg_ref, stats_ref = refs
        xi_ref = None
    else:
        seed_ref, x_ref, mu_ref, sg_ref, xi_ref, stats_ref = refs
    j = pl.program_id(1)
    bm = x_ref.shape[0]
    xi = _tile_xi(seed_ref, xi_ref, (num_samples, bm, bv), in_kernel_rng)
    logits = _sampled_logits_tile(x_ref, mu_ref, sg_ref, xi, j,
                                  v_actual=v_actual, bv=bv)

    tmax = logits.max(axis=-1)                               # (S, bm)
    ex = jnp.exp(logits - tmax[..., None])
    tz = ex.sum(axis=-1)
    ta = (ex * logits).sum(axis=-1)

    @pl.when(j == 0)
    def _init():
        stats_ref[0] = tmax
        stats_ref[1] = tz
        stats_ref[2] = ta

    @pl.when(j > 0)
    def _merge():
        mx, z, a = stats_ref[0], stats_ref[1], stats_ref[2]
        mx2 = jnp.maximum(mx, tmax)
        c1 = jnp.exp(mx - mx2)
        c2 = jnp.exp(tmax - mx2)
        stats_ref[0] = mx2
        stats_ref[1] = z * c1 + tz * c2
        stats_ref[2] = a * c1 + ta * c2


def _head_entropy_fused_kernel(*refs, v_actual: int, bv: int,
                               num_samples: int, in_kernel_rng: bool):
    if in_kernel_rng:
        seed_ref, x_ref, mu_ref, sg_ref, stats_ref, h_ref, best_ref = refs
        xi_ref = None
    else:
        (seed_ref, x_ref, mu_ref, sg_ref, xi_ref, stats_ref, h_ref,
         best_ref) = refs
    j = pl.program_id(1)
    bm = x_ref.shape[0]
    xi = _tile_xi(seed_ref, xi_ref, (num_samples, bm, bv), in_kernel_rng)
    logits = _sampled_logits_tile(x_ref, mu_ref, sg_ref, xi, j,
                                  v_actual=v_actual, bv=bv)
    mx = stats_ref[0][..., None]                             # (S, bm, 1)
    z = stats_ref[1][..., None]
    pbar = (jnp.exp(logits - mx) / z).mean(axis=0)           # (bm, bv)
    contrib = pbar * jnp.log(pbar + 1e-12)
    col = j * bv + jax.lax.broadcasted_iota(jnp.int32, pbar.shape, 1)
    contrib = jnp.where(col < v_actual, contrib, 0.0)
    tile_h = contrib.sum(axis=-1)                            # (bm,)
    pbar_m = jnp.where(col < v_actual, pbar, -1.0)
    tile_best = pbar_m.max(axis=-1)
    tile_idx = (j * bv + jnp.argmax(pbar_m, axis=-1)).astype(jnp.float32)

    @pl.when(j == 0)
    def _init():
        h_ref[0] = -tile_h
        best_ref[0] = tile_best
        best_ref[1] = tile_idx

    @pl.when(j > 0)
    def _merge():
        h_ref[0] = h_ref[0] - tile_h
        better = tile_best > best_ref[0]
        best_ref[0] = jnp.where(better, tile_best, best_ref[0])
        best_ref[1] = jnp.where(better, tile_idx, best_ref[1])


def uncertainty_head_fused_kernel(x: jax.Array, mu: jax.Array,
                                  sigma: jax.Array, seed, *,
                                  num_samples: int,
                                  xi: jax.Array | None = None,
                                  bm: int = 128, bv: int = 512,
                                  interpret: bool = False
                                  ) -> dict[str, jax.Array]:
    """x: (M, K); mu/sigma: (K, V) -> uncertainty dict, no logits scratch.

    xi=None selects the in-kernel PRNG fast path (TPU only); an explicit
    xi (S, M, V) selects the validation path (runs in interpret mode).
    Pass 2 regenerates the logits tiles (two small matmuls + the replayed
    variates) instead of re-reading an (S, M, V) HBM buffer.
    """
    m, k = x.shape
    _, v = mu.shape
    s = num_samples
    bm = min(bm, m)
    assert m % bm == 0, (m, bm)
    v_pad = (-v) % bv
    if v_pad:
        mu = jnp.pad(mu, ((0, 0), (0, v_pad)))
        sigma = jnp.pad(sigma, ((0, 0), (0, v_pad)))
    vp = v + v_pad
    grid = (m // bm, vp // bv)
    in_kernel_rng = xi is None
    seed_arr = jnp.asarray(seed, jnp.int32).reshape(1, 1)

    in_specs = [
        pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
        pl.BlockSpec((k, bv), lambda i, j: (0, j)),
        pl.BlockSpec((k, bv), lambda i, j: (0, j)),
    ]
    operands = [seed_arr, x, mu, sigma]
    if not in_kernel_rng:
        assert xi.shape == (s, m, v), (xi.shape, (s, m, v))
        if v_pad:
            xi = jnp.pad(xi, ((0, 0), (0, 0), (0, v_pad)))
        in_specs.append(pl.BlockSpec((s, bm, bv), lambda i, j: (0, i, j)))
        operands.append(xi)

    stats = pl.pallas_call(
        functools.partial(_head_stats_fused_kernel, v_actual=v, bv=bv,
                          num_samples=s, in_kernel_rng=in_kernel_rng),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((3, s, bm), lambda i, j: (0, 0, i)),
        out_shape=jax.ShapeDtypeStruct((3, s, m), jnp.float32),
        interpret=interpret,
    )(*operands)

    h, best = pl.pallas_call(
        functools.partial(_head_entropy_fused_kernel, v_actual=v, bv=bv,
                          num_samples=s, in_kernel_rng=in_kernel_rng),
        grid=grid,
        in_specs=in_specs + [
            pl.BlockSpec((3, s, bm), lambda i, j: (0, 0, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, bm), lambda i, j: (0, i)),
            pl.BlockSpec((2, bm), lambda i, j: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, m), jnp.float32),
            jax.ShapeDtypeStruct((2, m), jnp.float32),
        ],
        interpret=interpret,
    )(*operands, stats)

    mx, z, a = stats[0], stats[1], stats[2]
    se = (mx + jnp.log(z) - a / z).mean(axis=0)              # (M,)
    h = h[0]
    return {"H": h, "SE": se, "MI": jnp.maximum(h - se, 0.0),
            "pred": best[1].astype(jnp.int32), "p_max": best[0]}
