"""Pallas TPU kernel: the machine's primitive — 9-tap probabilistic conv.

Direct TPU mapping of the frequency-time interleaved analog dot product
(paper Fig. 2a): the chirped grating's one-symbol-per-channel delay becomes
a static shifted-window accumulation inside a VMEM tile; the per-symbol
fresh weight draws become the eps operand (B, To, C) — the digital twin of
the chaotic carrier.  DAC/ADC 8-bit quantization is fused, matching the
machine's interface, so one kernel call is one batch of analog shots.

Grid: batch tiles only — the full time axis of a tile lives in VMEM
(To <= a few thousand symbols per shot, exactly the machine's operating
regime; bb*T*4B + bb*To*C*4B ~ 2.5 MB at bb=8, T=4096).

Two entropy paths (see kernels/bayes_matmul.py for the full story):
``photonic_conv_kernel`` takes an explicit eps operand (validation /
external-entropy path); ``photonic_conv_fused_kernel`` with eps=None
seeds the per-core PRNG and draws the per-symbol variates in-register —
the (B, To, C) entropy operand never exists in HBM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import entropy as E
from repro.kernels import rng


def _quant(x, bits, x_max):
    levels = 2 ** (bits - 1) - 1
    scale = x_max / levels
    return jnp.clip(jnp.round(x / scale), -levels, levels) * scale


def _photonic_conv_kernel(x_ref, mu_ref, sg_ref, eps_ref, o_ref, *,
                          num_channels: int, dac_bits: int, adc_bits: int,
                          in_range: float, out_range: float):
    C = num_channels
    To = o_ref.shape[-1]
    xq = _quant(x_ref[...].astype(jnp.float32), dac_bits, in_range)
    acc = jnp.zeros(o_ref.shape, jnp.float32)
    # chirped-grating delay: channel k arrives k symbols late -> tap x[t+k]
    # multiplies weight channel C-1-k (see core.photonic.convolve).
    for k in range(C):
        w = (mu_ref[0, C - 1 - k] +
             sg_ref[0, C - 1 - k] * eps_ref[..., C - 1 - k].astype(jnp.float32))
        acc += xq[:, k:k + To] * w
    o_ref[...] = _quant(acc, adc_bits, out_range)


def photonic_conv_kernel(x: jax.Array, mu: jax.Array, sigma: jax.Array,
                         eps: jax.Array, *, dac_bits: int = E.DAC_BITS,
                         adc_bits: int = E.ADC_BITS, in_range: float = 1.0,
                         out_range: float = 4.0, bb: int = 8,
                         interpret: bool = False) -> jax.Array:
    """x: (B, T); mu/sigma: (C,); eps: (B, To, C) -> y: (B, To)."""
    B, T = x.shape
    C = mu.shape[-1]
    To = T - C + 1
    assert eps.shape == (B, To, C)
    bb = min(bb, B)
    assert B % bb == 0
    grid = (B // bb,)
    return pl.pallas_call(
        functools.partial(_photonic_conv_kernel, num_channels=C,
                          dac_bits=dac_bits, adc_bits=adc_bits,
                          in_range=in_range, out_range=out_range),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, T), lambda i: (i, 0)),
            pl.BlockSpec((1, C), lambda i: (0, 0)),
            pl.BlockSpec((1, C), lambda i: (0, 0)),
            pl.BlockSpec((bb, To, C), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bb, To), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, To), jnp.float32),
        interpret=interpret,
    )(x, mu[None], sigma[None], eps)


def _photonic_conv_fused_kernel(*refs, num_channels: int, dac_bits: int,
                                adc_bits: int, in_range: float,
                                out_range: float, in_kernel_rng: bool):
    if in_kernel_rng:
        seed_ref, x_ref, mu_ref, sg_ref, o_ref = refs
    else:
        seed_ref, x_ref, mu_ref, sg_ref, eps_ref, o_ref = refs
    C = num_channels
    To = o_ref.shape[-1]
    xq = _quant(x_ref[...].astype(jnp.float32), dac_bits, in_range)
    acc = jnp.zeros(o_ref.shape, jnp.float32)
    if in_kernel_rng:
        pltpu.prng_seed(seed_ref[0, 0], pl.program_id(0))
    for k in range(C):
        if in_kernel_rng:
            eps_k = rng.normal_draw((xq.shape[0], To))
        else:
            eps_k = eps_ref[..., C - 1 - k].astype(jnp.float32)
        w = mu_ref[0, C - 1 - k] + sg_ref[0, C - 1 - k] * eps_k
        acc += xq[:, k:k + To] * w
    o_ref[...] = _quant(acc, adc_bits, out_range)


def photonic_conv_fused_kernel(x: jax.Array, mu: jax.Array, sigma: jax.Array,
                               seed, *, eps: jax.Array | None = None,
                               dac_bits: int = E.DAC_BITS,
                               adc_bits: int = E.ADC_BITS,
                               in_range: float = 1.0, out_range: float = 4.0,
                               bb: int = 8,
                               interpret: bool = False) -> jax.Array:
    """x: (B, T); mu/sigma: (C,) -> y: (B, To) with in-kernel entropy.

    eps=None selects the in-kernel PRNG fast path (TPU only); an explicit
    eps (B, To, C) selects the validation path (runs in interpret mode).
    """
    B, T = x.shape
    C = mu.shape[-1]
    To = T - C + 1
    bb = min(bb, B)
    assert B % bb == 0
    grid = (B // bb,)
    in_kernel_rng = eps is None
    seed_arr = jnp.asarray(seed, jnp.int32).reshape(1, 1)
    in_specs = [
        pl.BlockSpec((1, 1), lambda i: (0, 0)),
        pl.BlockSpec((bb, T), lambda i: (i, 0)),
        pl.BlockSpec((1, C), lambda i: (0, 0)),
        pl.BlockSpec((1, C), lambda i: (0, 0)),
    ]
    operands = [seed_arr, x, mu[None], sigma[None]]
    if not in_kernel_rng:
        assert eps.shape == (B, To, C)
        in_specs.append(pl.BlockSpec((bb, To, C), lambda i: (i, 0, 0)))
        operands.append(eps)
    return pl.pallas_call(
        functools.partial(_photonic_conv_fused_kernel, num_channels=C,
                          dac_bits=dac_bits, adc_bits=adc_bits,
                          in_range=in_range, out_range=out_range,
                          in_kernel_rng=in_kernel_rng),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bb, To), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, To), jnp.float32),
        interpret=interpret,
    )(*operands)
