"""In-kernel entropy for the Bayesian Pallas kernels.

The photonic machine's architectural rule — randomness is generated *at*
the MAC and never transits the datapath — maps to TPU as the on-core PRNG:
``pltpu.prng_seed`` + ``pltpu.prng_random_bits`` produce the standard
variates in registers, so the entropy operand disappears from HBM
entirely (0 bytes of randomness crossing the memory system per
prediction, vs S*K*N*4 for weight-space operands or S*M*V*4 for the
LRT head operand).

Two helpers:

  * ``uniform_from_bits``  -- uint32 -> U[0, 1) using the top 24 bits
    (full f32 mantissa precision, no modulo bias).
  * ``normal_draw``        -- Box-Muller over two independent bit draws;
    the per-core PRNG state advances between ``prng_random_bits`` calls,
    so repeated draws inside one kernel invocation are independent.

Seeding convention (shared by every kernel family): the kernel mixes the
user seed with its grid coordinates, ``pltpu.prng_seed(seed, i, j, ...)``,
so each tile owns a distinct stream and re-seeding with the same
coordinates replays the same bits — which is what lets the uncertainty
head's pass 2 *regenerate* the sample logits instead of re-reading an
(S, M, V) scratch from HBM.

These primitives only lower on real TPUs (Mosaic); this container's
generic interpret mode has no rule for them.  The ops.py wrappers
therefore derive the variates host-side from the same seed
(``ref.sampled_normal``) and feed them to the kernels as an explicit
operand — the validation path.  Parity between the two paths is
statistical (moments over S samples), not bitwise; determinism (same
seed -> same output) holds on each path separately.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.experimental.pallas import tpu as pltpu

_TWO_PI = 2.0 * math.pi
_INV_2_24 = 1.0 / float(1 << 24)


def uniform_from_bits(bits: jax.Array) -> jax.Array:
    """uint32 random bits -> U[0, 1) f32 (top 24 bits, unbiased)."""
    u32 = pltpu.bitcast(bits, jnp.uint32)
    return (u32 >> jnp.uint32(8)).astype(jnp.float32) * _INV_2_24


def normal_draw(shape: tuple[int, ...]) -> jax.Array:
    """One standard-normal tensor from the seeded per-core PRNG.

    Box-Muller: r*cos(theta) with r = sqrt(-2 log(1-u1)), theta = 2 pi u2.
    u1 in [0, 1) keeps 1-u1 in (0, 1], so the log never sees 0.
    Call pltpu.prng_seed(...) before the first draw of a kernel body.
    """
    u1 = uniform_from_bits(pltpu.prng_random_bits(shape))
    u2 = uniform_from_bits(pltpu.prng_random_bits(shape))
    r = jnp.sqrt(-2.0 * jnp.log(1.0 - u1))
    return r * jnp.cos(_TWO_PI * u2)


def seed_from_key(key: jax.Array) -> jax.Array:
    """int32 kernel seed from a typed or raw uint32 PRNG key — the bridge
    from key-threaded call sites to the seed-driven kernel entropy path."""
    if jnp.issubdtype(key.dtype, jax.dtypes.prng_key):
        key = jax.random.key_data(key)
    return key.ravel()[-1].astype(jnp.int32)
