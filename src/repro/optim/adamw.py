"""AdamW in pure JAX, with the large-scale knobs the launcher needs.

 * dtype-policied moments (``ArchConfig.moment_dtype``: grok-1 keeps bf16
   moments so the 314B training state fits HBM — DESIGN.md §5),
 * global-norm clipping,
 * cosine / linear-warmup schedules,
 * gradient ACCUMULATION (microbatching) as a lax.scan in the train step,
 * optional top-k GRADIENT COMPRESSION applied before the DP all-reduce
   (error feedback carried in the optimizer state) — the classic
   distributed-optimization trick for collective-bound steps.

Works over arbitrary param pytrees including GaussianVariational leaves
(registered pytree nodes, so mu and rho are ordinary leaves here).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"
    warmup_steps: int = 200
    total_steps: int = 10_000
    schedule: str = "cosine"         # cosine | linear | constant
    min_lr_ratio: float = 0.1
    # gradient compression (0 disables): keep top-k fraction of entries
    compress_topk: float = 0.0


def schedule_lr(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps) /
                 jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    if cfg.schedule == "cosine":
        decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * \
            (1 + jnp.cos(jnp.pi * t))
    elif cfg.schedule == "linear":
        decay = 1.0 - (1 - cfg.min_lr_ratio) * t
    else:
        decay = jnp.ones_like(t)
    return cfg.lr * warm * decay


def init_state(params: Any, cfg: AdamWConfig) -> dict:
    mdt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    state = {"mu": jax.tree.map(zeros, params),
             "nu": jax.tree.map(zeros, params),
             "step": jnp.zeros((), jnp.int32)}
    if cfg.compress_topk > 0:
        state["error"] = jax.tree.map(zeros, params)
    return state


def global_norm(tree: Any) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def clip_by_global_norm(grads: Any, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def compress_topk(grads: Any, error: Any, frac: float):
    """Error-feedback top-k sparsification (per-leaf threshold).

    Dense-representation top-k: entries below the per-leaf magnitude
    threshold are zeroed and fed back into the error accumulator.  The
    all-reduce then moves (structurally) sparse tensors; on hardware this
    pairs with a sparsity-aware collective, here it models the bandwidth
    reduction for the §Perf collective-term analysis.
    """

    def one(g, e):
        g = g.astype(jnp.float32) + e.astype(jnp.float32)
        k = jnp.quantile(jnp.abs(g.reshape(-1)), 1.0 - frac)
        keep = jnp.abs(g) >= k
        sent = jnp.where(keep, g, 0.0)
        return sent.astype(g.dtype), (g - sent)

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    sent = tdef.unflatten([o[0] for o in outs])
    new_err = tdef.unflatten([o[1] for o in outs])
    return sent, new_err


def apply_updates(params: Any, grads: Any, state: dict,
                  cfg: AdamWConfig) -> tuple[Any, dict, dict]:
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    metrics = {}
    if cfg.compress_topk > 0:
        grads, new_error = compress_topk(grads, state["error"],
                                         cfg.compress_topk)
        metrics["compressed"] = jnp.array(1.0)
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    metrics["grad_norm"] = gnorm
    step = state["step"] + 1
    lr = schedule_lr(cfg, step)
    metrics["lr"] = lr
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m2 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v2 = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
        mhat = m2 / bc1
        vhat = v2 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        # decoupled weight decay (skip 1-d norm/bias-like leaves)
        if p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * delta
        return p2.astype(p.dtype), m2.astype(mdt), v2.astype(mdt)

    out = jax.tree.map(upd, params, grads, state["mu"], state["nu"])
    # unzip the 3-tuples
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_state = {"mu": new_mu, "nu": new_nu, "step": step}
    if cfg.compress_topk > 0:
        new_state["error"] = new_error
    return new_params, new_state, metrics
