"""zamba2-7b [arXiv:2411.15242]: 81 Mamba2 blocks (d3584, ssm_state=64) +
one SHARED attention block (32H, ff 14336) applied every 6 layers.
Sub-quadratic: runs the long_500k cell."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b", family="hybrid",
    num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32,
    head_dim=112, d_ff=14336, vocab_size=32000,
    ssm_state=64, ssm_head_dim=64, attn_every=6,
    subquadratic=True,
)
