"""mamba2-370m [arXiv:2405.21060]: 48L SSD blocks, d1024, attn-free,
d_inner 2048, 32 heads of 64, ssm_state 128, vocab 50280.
Sub-quadratic: runs the long_500k cell with O(1) decode state."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-370m", family="ssm",
    num_layers=48, d_model=1024, num_heads=0, num_kv_heads=0, head_dim=1,
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2,
    subquadratic=True, fsdp_params=False,
)
