"""Architecture config schema + shape-cell definitions.

One ``ArchConfig`` per assigned architecture (``repro/configs/<id>.py``),
selectable with ``--arch <id>`` through ``repro.configs.registry``.

The four assigned input-shape cells (LM family):
    train_4k     seq 4096,   global batch 256   (train_step)
    prefill_32k  seq 32768,  global batch 32    (serve: prefill)
    decode_32k   seq 32768,  global batch 128   (serve: 1 new token w/ KV)
    long_500k    seq 524288, global batch 1     (serve: long-context decode)
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads

    # --- MoE ---
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                # per-expert ff (deepseek fine-grained)
    capacity_factor: float = 1.25
    expert_sharding: str = "ep"      # "ep" (experts on model axis) | "tp"

    # --- SSM / hybrid ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 256
    attn_every: int = 0              # hybrid: shared attn block period

    # --- enc-dec ---
    encoder_layers: int = 0          # 0 -> decoder-only
    decoder_layers: int = 0

    # --- modality frontend stubs (vlm / audio) ---
    num_prefix_embeds: int = 0       # patch/frame embeddings prepended

    # --- flavor ---
    mlp_activation: str = "silu"     # silu | gelu | relu2 (nemotron)
    qkv_bias: bool = False           # qwen-style
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    logits_softcap: float = 0.0      # grok-style tanh soft-capping

    # --- paper technique ---
    bayesian_head: bool = True       # Gaussian variational output head
    mc_samples: int = 10             # paper: N=10 MC draws per prediction
    head_init_sigma: float = 0.01
    head_entropy: str = "kernel"     # "kernel": seeded fused head (drawn
                                     # in-kernel on TPU); "operand":
                                     # key-threaded explicit xi tensor
    decode_attn: str = "gather"      # paged decode attention: "kernel"
                                     # reads mapped blocks straight from
                                     # the pool (block-sparse Pallas
                                     # kernel); "gather" materializes the
                                     # full logical span (the bit-exact
                                     # reference path)

    # --- numerics / memory ---
    param_dtype: str = "bfloat16"
    moment_dtype: str = "float32"    # adam moments (grok: bfloat16)
    remat: bool = True
    remat_group: int = 0             # >0: two-level scan; checkpoint every
                                     # `remat_group` layers (saved-activation
                                     # stack shrinks L -> L/group; §Perf)
    seq_parallel: bool = False       # Korthikanti sequence-parallel residual
                                     # stream: 16x less activation memory,
                                     # +AG/RS transitions (§Perf it.7 —
                                     # wins for capacity-bound and
                                     # chunk-sharded-attention archs)
    scan_layers: bool = True
    attn_q_chunk: int = 512          # flash-style query block
    attn_kv_chunk: int = 1024        # flash-style kv block
    fsdp_params: bool = True         # shard weights over data axis too

    # --- long context applicability ---
    subquadratic: bool = False       # True only for ssm / hybrid

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim",
                               self.d_model // self.num_heads)

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks + head)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.head_dim
        emb = v * d * (1 if self.tie_embeddings else 2)
        attn = d * (self.num_heads * hd) + 2 * d * (self.num_kv_heads * hd) \
            + (self.num_heads * hd) * d
        dense_mlp = 3 * d * ff if self.mlp_activation in ("silu", "gelu") \
            else 2 * d * ff
        if self.is_moe:
            eff = self.moe_d_ff or ff
            moe = self.num_experts * 3 * d * eff \
                + self.num_shared_experts * 3 * d * eff + d * self.num_experts
            block = attn + moe
        elif self.family in ("ssm",):
            din = self.ssm_expand * d
            h = din // self.ssm_head_dim
            block = d * (2 * din + 2 * self.ssm_state + h) \
                + din * d + din * self.ssm_conv_width
        elif self.family == "hybrid":
            din = self.ssm_expand * d
            h = din // self.ssm_head_dim
            # mamba-only blocks; the shared attn+mlp block is counted once
            block = d * (2 * din + 2 * self.ssm_state + h) + din * d \
                + din * self.ssm_conv_width
        else:
            block = attn + dense_mlp
        n_blocks = self.num_layers if not self.encoder_layers else \
            self.encoder_layers + self.decoder_layers
        total = emb + n_blocks * block
        if self.family == "hybrid" and self.attn_every:
            total += attn + 3 * d * ff  # one shared attention+mlp block
        if self.encoder_layers:  # cross attention in decoder
            total += self.decoder_layers * attn
        return total

    @property
    def active_param_count(self) -> int:
        """Per-token active params (MoE: top_k + shared experts only)."""
        if not self.is_moe:
            return self.param_count
        eff = self.moe_d_ff or self.d_ff
        inactive = (self.num_experts - self.top_k) * 3 * self.d_model * eff
        return self.param_count - self.num_layers * inactive


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # "train" | "prefill" | "decode"


SHAPE_CELLS = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}


def cell_applicable(cfg: ArchConfig, cell: ShapeCell) -> tuple[bool, str]:
    """Assignment rule: long_500k only for sub-quadratic archs."""
    if cell.name == "long_500k" and not cfg.subquadratic:
        return False, ("skipped: pure full-attention arch; 500k dense KV "
                       "cache exceeds per-pod memory (see DESIGN.md)")
    return True, ""
