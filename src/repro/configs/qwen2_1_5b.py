"""qwen2-1.5b [arXiv:2407.10671]: 28L, d1536, 12H GQA(kv=2), ff 8960,
vocab 151936, QKV bias."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-1.5b", family="dense",
    num_layers=28, d_model=1536, num_heads=12, num_kv_heads=2,
    head_dim=128, d_ff=8960, vocab_size=151936, qkv_bias=True,
    rope_theta=1_000_000.0, fsdp_params=False,
    seq_parallel=True,  # heads don't divide the 16-way model axis:
                        # chunk-sharded attention + seq-parallel stream
)
