"""seamless-m4t-medium [arXiv:2308.11596]: enc-dec, 12+12L, d1024, 16H MHA,
ff 4096, vocab 256206.  Audio frontend is a STUB: the encoder consumes
precomputed frame embeddings (assignment rule)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium", family="encdec",
    num_layers=12, encoder_layers=12, decoder_layers=12,
    d_model=1024, num_heads=16, num_kv_heads=16, head_dim=64,
    d_ff=4096, vocab_size=256206, mlp_activation="gelu",
    fsdp_params=False,
)
