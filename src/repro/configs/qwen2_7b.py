"""qwen2-7b [arXiv:2407.10671]: 28L, d3584, 28H GQA(kv=4), ff 18944,
vocab 152064, QKV bias."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-7b", family="dense",
    num_layers=28, d_model=3584, num_heads=28, num_kv_heads=4,
    head_dim=128, d_ff=18944, vocab_size=152064, qkv_bias=True,
    rope_theta=1_000_000.0,
    seq_parallel=True,  # heads don't divide the 16-way model axis:
                        # chunk-sharded attention + seq-parallel stream
)
