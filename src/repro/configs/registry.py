"""--arch registry: every assigned architecture + the paper's own BNN.

``get_config(name)`` returns the full published config; ``reduced(cfg)``
scales any config down to a CPU-smoke-testable size while preserving the
family's structural features (GQA ratio, MoE routing, SSD, hybrid period,
enc-dec split, QKV bias, activation flavor, Bayesian head).
"""

from __future__ import annotations

import dataclasses
import importlib

from repro.configs.base import ArchConfig

ARCH_IDS = [
    "grok_1_314b",
    "deepseek_moe_16b",
    "qwen2_1_5b",
    "codeqwen1_5_7b",
    "nemotron_4_15b",
    "qwen2_7b",
    "seamless_m4t_medium",
    "zamba2_7b",
    "phi_3_vision_4_2b",
    "mamba2_370m",
]


def normalize(name: str) -> str:
    return name.replace("-", "_").replace(".", "_").lower()


def get_config(name: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{normalize(name)}")
    return mod.CONFIG


def get_bnn_config(preset: str = "bloodcell"):
    """The paper's own CNN (configs/paper_bnn.py): not an LM ArchConfig."""
    from repro.configs import paper_bnn
    return {"bloodcell": paper_bnn.BLOODCELL,
            "mnist": paper_bnn.MNIST_LIKE}[preset]


def all_configs() -> dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


def reduced(cfg: ArchConfig) -> ArchConfig:
    """Small-but-structurally-identical config for CPU smoke tests."""
    kv_ratio = max(cfg.num_heads // max(cfg.num_kv_heads, 1), 1) \
        if cfg.num_heads else 1
    heads = min(cfg.num_heads, 4) if cfg.num_heads else 0
    kv = max(heads // kv_ratio, 1) if heads else 0
    changes = dict(
        num_layers=min(cfg.num_layers, 4 if cfg.family in ("ssm", "hybrid")
                       else 2),
        d_model=128,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=32 if heads else cfg.head_dim,
        d_ff=256 if cfg.d_ff else 0,
        vocab_size=512,
        attn_q_chunk=64,
        attn_kv_chunk=64,
        remat=False,
        param_dtype="float32",
        mc_samples=4,
    )
    if cfg.is_moe:
        changes.update(num_experts=min(cfg.num_experts, 8),
                       top_k=min(cfg.top_k, 2),
                       num_shared_experts=min(cfg.num_shared_experts, 1),
                       moe_d_ff=64 if cfg.moe_d_ff else 0)
    if cfg.family in ("ssm", "hybrid"):
        changes.update(ssm_state=16, ssm_head_dim=32, ssm_chunk=16)
    if cfg.family == "hybrid":
        changes.update(attn_every=2)
    if cfg.encoder_layers:
        changes.update(encoder_layers=2, decoder_layers=2)
    if cfg.num_prefix_embeds:
        changes.update(num_prefix_embeds=8)
    return dataclasses.replace(cfg, **changes)
