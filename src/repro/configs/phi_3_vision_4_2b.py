"""phi-3-vision-4.2b [hf:microsoft/Phi-3-vision-128k-instruct]: phi3-mini
backbone — 32L, d3072, 32H MHA, ff 8192, vocab 32064 — with a CLIP patch
frontend STUB: input_specs provides 576 precomputed patch embeddings
prepended to the token sequence (assignment rule)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="phi-3-vision-4.2b", family="vlm",
    num_layers=32, d_model=3072, num_heads=32, num_kv_heads=32,
    head_dim=96, d_ff=8192, vocab_size=32064,
    num_prefix_embeds=576, rope_theta=500_000.0,
)
