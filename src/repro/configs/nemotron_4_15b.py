"""nemotron-4-15b [arXiv:2402.16819]: 32L, d6144, 48H GQA(kv=8), ff 24576,
vocab 256000, squared-ReLU MLP (non-gated)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="nemotron-4-15b", family="dense",
    num_layers=32, d_model=6144, num_heads=48, num_kv_heads=8,
    head_dim=128, d_ff=24576, vocab_size=256000,
    mlp_activation="relu2",
)
