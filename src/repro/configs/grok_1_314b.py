"""grok-1-314b [hf:xai-org/grok-1]: 64L, d6144, 48H GQA(kv=8), ff 32768,
vocab 131072, MoE 8 experts top-2, tanh logits soft-capping.

8 experts < the 16-way model axis, so expert_sharding='tp' (experts
replicated over the axis, per-expert ff tensor-parallel); optimizer
moments in bf16 to keep the 314B-param training state inside HBM
(DESIGN.md §5).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="grok-1-314b", family="moe",
    num_layers=64, d_model=6144, num_heads=48, num_kv_heads=8,
    head_dim=128, d_ff=32768, vocab_size=131072,
    num_experts=8, top_k=2, moe_d_ff=32768, expert_sharding="tp",
    logits_softcap=30.0, mlp_activation="gelu",
    moment_dtype="bfloat16",
    seq_parallel=True,   # capacity: 64L saved residuals (§Perf it.7)
)
