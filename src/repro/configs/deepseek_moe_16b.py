"""deepseek-moe-16b [arXiv:2401.06066]: 28L, d2048, 16H MHA, vocab 102400,
fine-grained MoE: 64 routed experts top-6 + 2 shared, expert ff 1408.
64 experts shard cleanly over the 16-way model axis (EP, 4 per group)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b", family="moe",
    num_layers=28, d_model=2048, num_heads=16, num_kv_heads=16,
    head_dim=128, d_ff=1408, vocab_size=102400,
    num_experts=64, top_k=6, num_shared_experts=2, moe_d_ff=1408,
    expert_sharding="ep",
)
