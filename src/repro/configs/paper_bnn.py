"""The paper's own architecture (Fig. 3): hybrid Bayesian CNN.

Not an LM ArchConfig — this is the BNNConfig consumed by
``models/bnn_cnn.py`` (DenseNet concat skips + MobileNetV1 DWS convs,
six conv layers + linear head, ONE probabilistic depthwise block mapped
onto the photonic Bayesian machine).  Selectable through
``repro.configs.registry.get_bnn_config()`` and used by the examples /
benchmarks; the LM registry (``--arch``) covers the 10 assigned
architectures.

Two presets matching the paper's experiments:
  * ``BLOODCELL``  — 7 classes, RGB 28x28 (Fig. 4, BloodMNIST-like)
  * ``MNIST_LIKE`` — 10 classes, grayscale 28x28 (Fig. 5, DDU benchmark)
"""

from repro.models.bnn_cnn import BNNConfig

BLOODCELL = BNNConfig(
    num_classes=7, in_channels=3, width=16, image_size=28,
    mc_samples=10,              # paper: N = 10 MC samples per prediction
    prob_block=3,               # the probabilistic DWS block (Fig. 3)
    init_sigma=0.08,
)

MNIST_LIKE = BNNConfig(
    num_classes=10, in_channels=1, width=16, image_size=28,
    mc_samples=10, prob_block=3, init_sigma=0.08,
)

CONFIG = BLOODCELL
