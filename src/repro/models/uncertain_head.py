"""The family-shared uncertain decode head (the body/head split).

Every family's ``decode_step`` is a KV-writing BODY (``decode_hidden``:
embed -> blocks -> final norm, advancing the cache by one position)
followed by this HEAD: ``cfg.mc_samples`` LRT draws from the Bayesian
output projection over the body's hidden state, reduced to the paper's
(H, SE, MI) uncertainty triplet plus the greedy next token.

The split is what speculative decoding builds on (launch/steps.py):

  * the DRAFT pass reuses the full body — its KV writes are bitwise the
    writes plain decode would do for the same fed tokens — and proposes
    with a cheap ``num_samples`` override of this head (1 draw, or 0
    for the deterministic mean head);
  * the VERIFY step re-runs ONLY this head, ``jax.vmap``-ped over the k
    stacked draft hiddens at their per-position depths.

In operand-entropy mode the head noise is a pure function of
(key, slot, depth) (``layers.decode_head_noise`` folds slot and depth,
never the global step), and the vmapped head is bitwise identical to k
sequential per-step heads at equal (slot, depth) sites — which is the
whole losslessness argument tests/test_spec_decode.py enforces.

Per-family head differences are preserved exactly: only the dense/vlm
transformer has the fused seeded-kernel path and the logits sharding
constraint; every other family keeps the plain operand tail.
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.uncertainty import uncertainty_from_logits
from repro.models import layers as L
from repro.sharding.partition import constrain


def head_outputs(params, cfg: ArchConfig, hidden, cache_len, key,
                 num_samples: Optional[int] = None) -> dict:
    """Uncertain head over a decode hidden state.

    hidden: (B, d) pre-head hidden; ``cache_len``: () or (B,) PRE-step
    depths (the noise site — the body has already advanced its own
    ``len`` by the time the head runs).  ``num_samples`` overrides
    ``cfg.mc_samples`` for the cheap draft head (0 = mean head, greedy
    argmax of the softmax-mean, no draws at all).  Returns
    {next_token, H, SE, MI, p_max} per slot.
    """
    head = params["head"]
    S = cfg.mc_samples if num_samples is None else num_samples
    transformer = cfg.family in ("dense", "vlm")
    if transformer and num_samples is None and "q" in head \
            and not cfg.logits_softcap and cfg.head_entropy == "kernel":
        # seed-driven fused head: on TPU the xi tensor never exists (the
        # uncertainty-head kernel draws it in-register and regenerates
        # the sample logits in its second pass); off-TPU the seeded
        # oracle runs.  Softcapped heads keep the explicit-logits path.
        from repro.kernels import ops, rng
        q = head["q"]
        unc = ops.uncertainty_head_sampled(
            hidden, q.mu, q.sigma, rng.seed_from_key(key), num_samples=S)
        return {
            "next_token": unc["pred"],
            "H": unc["H"], "SE": unc["SE"], "MI": unc["MI"],
            "p_max": unc["p_max"],
        }
    if "q" in head and S > 0:
        xi = L.decode_head_noise(key, cache_len, S, cfg.vocab_size)
        logits = L.head_logits_sampled(head, hidden[None], cfg, xi)
    else:
        logits = L.head_logits_mean(head, hidden, cfg)[None]
    if transformer:
        logits = constrain(logits, None, "batch", "model")
    unc = uncertainty_from_logits(logits)
    return {
        "next_token": unc["p_mean"].argmax(-1).astype(jnp.int32),
        "H": unc["H"], "SE": unc["SE"], "MI": unc["MI"],
        "p_max": unc["p_mean"].max(-1),
    }
