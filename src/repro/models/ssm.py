"""Mamba2 — state-space duality (SSD) blocks (arXiv:2405.21060).

Block: in_proj -> (z gate, x, B, C, dt) -> causal depthwise conv on
(x, B, C) -> SSD mixing -> gated RMSNorm -> out_proj.

SSD with scalar-per-head decay A:
    h_t = exp(A * dt_t) h_{t-1} + dt_t * B_t  (outer) x_t
    y_t = C_t . h_t + D * x_t

Training uses the chunked dual form (quadratic intra-chunk 'attention' with
a decay mask + a chunk-level recurrence), which is the MXU-friendly
formulation and the reason this arch owns the ``long_500k`` cell: state is
O(H*P*N) regardless of context.  Decode is the O(1) recurrence.

The chunk recurrence is validated against the naive recurrence in
tests/test_ssm.py.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import uncertain_head as U
from repro.sharding.partition import constrain


def dims(cfg: ArchConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    H = d_in // cfg.ssm_head_dim
    return d_in, H, cfg.ssm_head_dim, cfg.ssm_state


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_block(key, cfg: ArchConfig):
    d = cfg.d_model
    d_in, H, P, N = dims(cfg)
    dt = L.dtype_of(cfg)
    ks = jax.random.split(key, 4)
    proj_out = 2 * d_in + 2 * N + H          # z, x, B, C, dt (G=1 group)
    conv_ch = d_in + 2 * N
    return {
        "ln": jnp.ones((d,), dt),
        "in_proj": L.he_init(ks[0], (d, proj_out), d, dt),
        "conv_w": L.he_init(ks[1], (cfg.ssm_conv_width, conv_ch),
                            cfg.ssm_conv_width, dt),
        "conv_b": jnp.zeros((conv_ch,), dt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.full((H,), -2.0, jnp.float32),  # softplus^-1(~0.12)
        "gate_ln": jnp.ones((d_in,), dt),
        "out_proj": L.he_init(ks[2], (d_in, d), d_in, dt),
    }


def init_params(key, cfg: ArchConfig):
    ke, kb, kh = jax.random.split(key, 3)
    blocks = jax.vmap(lambda k: init_block(k, cfg))(
        jax.random.split(kb, cfg.num_layers))
    return {"embed": L.init_embed(ke, cfg), "blocks": blocks,
            "final_norm": jnp.ones((cfg.d_model,), L.dtype_of(cfg)),
            "head": L.init_head(kh, cfg)}


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------

def ssd_chunked(x, dt, A, Bm, Cm, D, chunk: int,
                h0: Optional[jax.Array] = None):
    """Chunked SSD scan.

    x: (B, S, H, P); dt: (B, S, H); A: (H,) negative; Bm/Cm: (B, S, N);
    D: (H,). Returns (y (B,S,H,P), h_final (B,H,P,N)).
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    # Q is ALWAYS the configured chunk (not min(chunk, S)): a sequence
    # shorter than one chunk pads up exactly like the tail block of a
    # longer sequence, so any S decomposes into the same per-block
    # reductions — what lets chunked prefill (pc % ssm_chunk == 0)
    # thread h0 through and reproduce batch prefill bit for bit.
    # Padded positions carry dt == 0 and contribute exact zeros.
    Q = chunk
    pad = (-S) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    nc = x.shape[1] // Q
    xc = x.reshape(Bsz, nc, Q, H, P)
    dtc = dt.reshape(Bsz, nc, Q, H).astype(jnp.float32)
    Bc = Bm.reshape(Bsz, nc, Q, N).astype(jnp.float32)
    Cc = Cm.reshape(Bsz, nc, Q, N).astype(jnp.float32)

    loga = dtc * A[None, None, None, :]              # (B,nc,Q,H) negative
    cum = jnp.cumsum(loga, axis=2)                   # within-chunk cumsum
    total = cum[:, :, -1:]                           # (B,nc,1,H)

    # intra-chunk: y[i] = sum_{j<=i} (C_i.B_j) exp(cum_i - cum_j) dt_j x_j
    scores = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)   # (B,nc,Q,Q)
    dec = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,Q,Q,H)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    dec = jnp.where(mask[None, None, :, :, None], dec, -jnp.inf)
    w = scores[..., None] * jnp.exp(dec)             # (B,nc,Q,Q,H)
    xdt = xc.astype(jnp.float32) * dtc[..., None]    # (B,nc,Q,H,P)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", w, xdt)

    # chunk states: S_c = sum_j exp(total - cum_j) dt_j B_j (x) x_j
    sdec = jnp.exp(total - cum)                       # (B,nc,Q,H)
    states = jnp.einsum("bcjh,bcjn,bcjhp->bchpn",
                        sdec * dtc, Bc, xc.astype(jnp.float32))

    # inter-chunk recurrence over c: Hc = exp(total_c) H_{c-1} + S_c
    decay_c = jnp.exp(total[:, :, 0])                 # (B,nc,H)

    def step(h, inp):
        d_c, s_c = inp                                # (B,H), (B,H,P,N)
        h_new = h * d_c[:, :, None, None] + s_c
        return h_new, h                               # emit PREVIOUS state

    h_init = h0 if h0 is not None else jnp.zeros((Bsz, H, P, N), jnp.float32)
    h_last, h_prev = jax.lax.scan(
        step, h_init,
        (decay_c.transpose(1, 0, 2), states.transpose(1, 0, 2, 3, 4)))
    h_prev = h_prev.transpose(1, 0, 2, 3, 4)          # (B,nc,H,P,N)

    # inter contribution: y[i] += C_i . (exp(cum_i) * H_{c-1})
    y_inter = jnp.einsum("bcin,bcihp... ->bcihp" if False else
                         "bcin,bchpn,bcih->bcihp",
                         Cc, h_prev, jnp.exp(cum))
    y = y_intra + y_inter + D[None, None, None, :, None] * \
        xc.astype(jnp.float32)
    y = y.reshape(Bsz, nc * Q, H, P)[:, :S]
    return y.astype(x.dtype), h_last


def ssd_step(h, x, dt, A, Bm, Cm, D):
    """One-token recurrence. h: (B,H,P,N); x: (B,H,P); dt: (B,H);
    Bm/Cm: (B,N)."""
    a = jnp.exp(dt * A[None, :])                      # (B,H)
    upd = jnp.einsum("bh,bn,bhp->bhpn", dt, Bm, x.astype(jnp.float32))
    h = h * a[:, :, None, None] + upd
    y = jnp.einsum("bn,bhpn->bhp", Cm, h) + D[None, :, None] * x
    return h, y.astype(x.dtype)


# ---------------------------------------------------------------------------
# block
# ---------------------------------------------------------------------------

def _split_proj(cfg, proj):
    d_in, H, P, N = dims(cfg)
    z, xr, B_, C_, dt = jnp.split(
        proj, [d_in, 2 * d_in, 2 * d_in + N, 2 * d_in + 2 * N], axis=-1)
    return z, xr, B_, C_, dt


def _causal_conv(u, w, b):
    """u: (B, S, C); w: (W, C) depthwise causal; left-pad W-1."""
    W = w.shape[0]
    up = jnp.pad(u, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(up[:, i:i + u.shape[1]] * w[i] for i in range(W))
    return jax.nn.silu(out + b)


def apply_block(bp, cfg: ArchConfig, x: jax.Array,
                ssm_state=None, conv_state=None,
                force_chunked: bool = False):
    """x: (B, S, d). If states given, runs recurrent single/few-step mode.

    ``force_chunked`` keeps S == 1 inputs on the ``ssd_chunked`` path
    instead of the one-token recurrence: the two associate their f32
    reductions differently, so chunked prefill (whose tail chunk can be
    a single token) forces the chunked form to stay bit-exact against
    the batch prefill's block decomposition.  Decode proper keeps the
    O(1) ``ssd_step``."""
    d_in, H, P, N = dims(cfg)
    u = L.rms_norm(x, bp["ln"], cfg.norm_eps)
    proj = L._mm(u, bp["in_proj"])
    z, xr, B_, C_, dtp = _split_proj(cfg, proj)
    conv_in = jnp.concatenate([xr, B_, C_], axis=-1)

    if conv_state is None:
        conv = _causal_conv(conv_in, bp["conv_w"], bp["conv_b"])
        new_conv_state = conv_in[:, -(cfg.ssm_conv_width - 1):]
    else:
        # decode: prepend cached inputs
        full = jnp.concatenate([conv_state, conv_in], axis=1)
        conv = _causal_conv(full, bp["conv_w"], bp["conv_b"])
        conv = conv[:, conv_state.shape[1]:]
        new_conv_state = full[:, -(cfg.ssm_conv_width - 1):]

    xr, B_, C_ = jnp.split(conv, [d_in, d_in + N], axis=-1)
    Bsz, S = x.shape[0], x.shape[1]
    xh = xr.reshape(Bsz, S, H, P)
    dt = jax.nn.softplus(dtp.astype(jnp.float32) + bp["dt_bias"])
    A = -jnp.exp(bp["A_log"])

    if ssm_state is None:
        y, h_last = ssd_chunked(xh, dt, A, B_, C_, bp["D"], cfg.ssm_chunk)
    elif S == 1 and not force_chunked:
        h_last, y1 = ssd_step(ssm_state, xh[:, 0], dt[:, 0], A,
                              B_[:, 0].astype(jnp.float32),
                              C_[:, 0].astype(jnp.float32), bp["D"])
        y = y1[:, None]
    else:
        y, h_last = ssd_chunked(xh, dt, A, B_, C_, bp["D"], cfg.ssm_chunk,
                                h0=ssm_state)
    y = y.reshape(Bsz, S, d_in)
    y = L.rms_norm(y * jax.nn.silu(z), bp["gate_ln"], cfg.norm_eps)
    out = L._mm(y, bp["out_proj"])
    return x + out, h_last, new_conv_state


# ---------------------------------------------------------------------------
# model
# ---------------------------------------------------------------------------

def forward(params, cfg: ArchConfig, tokens: jax.Array):
    x = L.apply_embed(params["embed"], tokens)
    x = constrain(x, "batch", None, None)

    def scan_step(x, bp):
        if cfg.remat:
            y, _, _ = jax.checkpoint(
                lambda b, xx: apply_block(b, cfg, xx),
                prevent_cse=False)(bp, x)
        else:
            y, _, _ = apply_block(bp, cfg, x)
        return y, None

    x, _ = jax.lax.scan(scan_step, x, params["blocks"])
    return L.rms_norm(x, params["final_norm"], cfg.norm_eps)


def nll_loss(params, cfg: ArchConfig, batch: dict, key: jax.Array):
    hidden = forward(params, cfg, batch["tokens"])
    head = params["head"]
    if "q" in head:
        eps = jax.random.normal(key, head["q"].mu.shape, jnp.float32)
        w = head["q"].sample_with_eps(eps)
        logits = jnp.dot(hidden, w.astype(hidden.dtype),
                         preferred_element_type=jnp.float32)
    else:
        logits = L.head_logits_mean(head, hidden, cfg)
    logits = constrain(logits, "batch", None, "model")
    labels = batch["labels"]
    valid = labels >= 0
    lab = jnp.where(valid, labels, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    tok = -jnp.take_along_axis(logp, lab[..., None], axis=-1)[..., 0]
    nll = jnp.where(valid, tok, 0.0).sum() / jnp.maximum(valid.sum(), 1)
    acc = ((logits.argmax(-1) == labels) & valid).sum() / \
        jnp.maximum(valid.sum(), 1)
    return nll, {"accuracy": acc}


def make_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=None):
    """Recurrent cache: per-layer SSM state + conv tail (O(1) in context!).

    No KV strips, so the paged layout has nothing to page — the serving
    engine keeps ``--kv-layout dense`` semantics for this family
    (``registry.supports_paged`` returns False)."""
    d_in, H, P, N = dims(cfg)
    dt = dtype or L.dtype_of(cfg)
    Lh = cfg.num_layers
    return {
        "ssm": jnp.zeros((Lh, batch, H, P, N), jnp.float32),
        "conv": jnp.zeros((Lh, batch, cfg.ssm_conv_width - 1, d_in + 2 * N),
                          dt),
        "len": jnp.zeros((batch,), jnp.int32),
    }


def prefill(params, cfg: ArchConfig, tokens: jax.Array, max_len: int):
    x = L.apply_embed(params["embed"], tokens)

    def scan_step(x, bp):
        y, h, cstate = apply_block(bp, cfg, x)
        return y, (h, cstate)

    x, (hs, cs) = jax.lax.scan(scan_step, x, params["blocks"])
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    cache = {"ssm": hs, "conv": cs,
             "len": jnp.full((tokens.shape[0],), tokens.shape[1],
                             jnp.int32)}
    return x[:, -1], cache


def decode_hidden(params, cfg: ArchConfig, token: jax.Array, cache: dict):
    """The state-advancing decode body (see transformer.decode_hidden):
    pure recurrence, no KV strips."""
    x = L.apply_embed(params["embed"], token[:, None])
    x = constrain(x, "batch", None, None)

    def scan_step(x, bpstate):
        bp, h, cstate = bpstate
        y, h_new, c_new = apply_block(bp, cfg, x, ssm_state=h,
                                      conv_state=cstate)
        return y, (h_new, c_new)

    x, (hs, cs) = jax.lax.scan(
        scan_step, x, (params["blocks"], cache["ssm"], cache["conv"]))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x[:, 0], {"ssm": hs, "conv": cs, "len": cache["len"] + 1}


def decode_step(params, cfg: ArchConfig, token: jax.Array, cache: dict,
                key: jax.Array):
    hidden, new_cache = decode_hidden(params, cfg, token, cache)
    return U.head_outputs(params, cfg, hidden, cache["len"], key), \
        new_cache
