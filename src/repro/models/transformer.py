"""Dense decoder-only transformer (qwen2*, codeqwen, nemotron, phi-3-vision).

Covers the ``dense`` and ``vlm`` families.  VLM/audio frontends are stubs
per the assignment: ``prefix_embeds`` (precomputed patch/frame embeddings)
overwrite the leading positions of the token embedding sequence.

Layers are stacked on a leading L axis and consumed with ``jax.lax.scan``
(+ optional per-layer remat) so the HLO stays O(1) in depth — essential
for the 64-layer dry-runs to compile quickly and for XLA's scheduler to
pipeline the FSDP all-gathers (weights of layer i+1 prefetch during i).

The output head follows the paper: a Gaussian-variational projection
(``bayesian_head=True``) trained with SVI (one weight-space draw per step)
and sampled N times at serving to produce the (H, SE, MI) uncertainty
triplet per generated token.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import uncertain_head as U
from repro.sharding.partition import constrain, constrain_seq


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_block(key, cfg: ArchConfig):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": jnp.ones((cfg.d_model,), L.dtype_of(cfg)),
        "attn": L.init_attention(k1, cfg),
        "ln2": jnp.ones((cfg.d_model,), L.dtype_of(cfg)),
        "mlp": L.init_mlp(k2, cfg),
    }


def init_params(key, cfg: ArchConfig):
    ke, kb, kh = jax.random.split(key, 3)
    block_keys = jax.random.split(kb, cfg.num_layers)
    blocks = jax.vmap(lambda k: init_block(k, cfg))(block_keys)
    return {
        "embed": L.init_embed(ke, cfg),
        "blocks": blocks,                      # stacked (L, ...)
        "final_norm": jnp.ones((cfg.d_model,), L.dtype_of(cfg)),
        "head": L.init_head(kh, cfg),
    }


# ---------------------------------------------------------------------------
# forward (training / prefill)
# ---------------------------------------------------------------------------

def _block_fwd(bp, cfg: ArchConfig, x, positions):
    # sequence-parallel residual stream: x lives S-sharded over 'model';
    # rms_norm is position-local so it runs sharded; the attention/MLP
    # inputs gather S implicitly (GSPMD AG) and their row-parallel
    # outputs reduce-scatter back into the sharded stream.
    h, kv = L.apply_attention(bp["attn"], cfg, L.rms_norm(x, bp["ln1"]),
                              positions=positions, causal=True)
    x = x + constrain_seq(h, cfg.seq_parallel)
    x = constrain_seq(x, cfg.seq_parallel)
    x = x + constrain_seq(L.apply_mlp(bp["mlp"], cfg,
                                      L.rms_norm(x, bp["ln2"])),
                          cfg.seq_parallel)
    x = constrain_seq(x, cfg.seq_parallel)
    return x, kv


def forward(params, cfg: ArchConfig, tokens: jax.Array,
            prefix_embeds: Optional[jax.Array] = None,
            return_kv: bool = False):
    """tokens: (B, S) -> hidden (B, S, d); optionally per-layer (k, v)."""
    x = L.apply_embed(params["embed"], tokens)
    if prefix_embeds is not None:
        P = prefix_embeds.shape[1]
        x = jnp.concatenate(
            [prefix_embeds.astype(x.dtype), x[:, P:]], axis=1)
    x = constrain(x, "batch", None, None)
    x = constrain_seq(x, cfg.seq_parallel)
    positions = jnp.arange(tokens.shape[1])[None, :]

    def scan_step(x, bp):
        if cfg.remat:
            y, kv = jax.checkpoint(
                lambda b, xx: _block_fwd(b, cfg, xx, positions),
                prevent_cse=False)(bp, x)
        else:
            y, kv = _block_fwd(bp, cfg, x, positions)
        return y, (kv if return_kv else None)

    g = cfg.remat_group
    if (cfg.scan_layers and cfg.remat and g and not return_kv
            and cfg.num_layers % g == 0):
        # hierarchical remat: checkpoint every g layers — the saved
        # residual stack shrinks L -> L/g slabs (grok: 64 -> 8), trading
        # one extra inner recompute during bwd (EXPERIMENTS.md §Perf).
        grouped = jax.tree.map(
            lambda a: a.reshape(cfg.num_layers // g, g, *a.shape[1:]),
            params["blocks"])

        def outer_step(x, bps):
            def inner(xx, bp):
                y, _ = _block_fwd(bp, cfg, xx, positions)
                return y, None

            y, _ = jax.checkpoint(
                lambda b, xx: jax.lax.scan(inner, xx, b),
                prevent_cse=False)(bps, x)
            return y, None

        x, kvs = jax.lax.scan(outer_step, x, grouped)
    elif cfg.scan_layers:
        x, kvs = jax.lax.scan(scan_step, x, params["blocks"])
    else:
        kvs = []
        blocks = [jax.tree.map(lambda a, i=i: a[i], params["blocks"])
                  for i in range(cfg.num_layers)]
        for bp in blocks:
            x, kv = scan_step(x, bp)
            kvs.append(kv)
        if return_kv:
            kvs = jax.tree.map(lambda *xs: jnp.stack(xs), *kvs)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return (x, kvs) if return_kv else (x, None)


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def nll_loss(params, cfg: ArchConfig, batch: dict, key: jax.Array):
    """Mean next-token NLL with one weight-space draw of the Bayesian head.

    batch: {tokens (B,S), labels (B,S)} (labels already shifted; -100 pad).
    """
    hidden, _ = forward(params, cfg, batch["tokens"],
                        prefix_embeds=batch.get("prefix_embeds"))
    head = params["head"]
    if "q" in head:
        eps = jax.random.normal(key, head["q"].mu.shape, jnp.float32)
        w = head["q"].sample_with_eps(eps)
        logits = jnp.dot(hidden, w.astype(hidden.dtype),
                         preferred_element_type=jnp.float32)
    else:
        logits = L.head_logits_mean(head, hidden, cfg)
    if cfg.logits_softcap:
        logits = cfg.logits_softcap * jnp.tanh(logits / cfg.logits_softcap)
    logits = constrain(logits, "batch", None, "model")
    labels = batch["labels"]
    valid = labels >= 0
    lab = jnp.where(valid, labels, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    tok_nll = -jnp.take_along_axis(logp, lab[..., None], axis=-1)[..., 0]
    tok_nll = jnp.where(valid, tok_nll, 0.0)
    nll = tok_nll.sum() / jnp.maximum(valid.sum(), 1)
    acc = ((logits.argmax(-1) == labels) & valid).sum() / \
        jnp.maximum(valid.sum(), 1)
    return nll, {"accuracy": acc}


# ---------------------------------------------------------------------------
# serving: prefill + MC-sampled uncertain decode
# ---------------------------------------------------------------------------

def make_cache(cfg: ArchConfig, batch: int, max_len: int,
               dtype=None, layout: str = "dense", kv_block: int = 16,
               num_blocks: int = 0):
    """Slot-indexed KV cache: ``len`` is per-slot (batch,) so decode slots
    admitted at different times sit at independent depths.

    ``layout='dense'`` (the reference layout) gives each slot one
    contiguous ``max_len`` strip.  ``layout='paged'`` replaces the strips
    with a global pool of ``num_blocks`` blocks of ``kv_block`` tokens
    each plus a (batch, MB) ``block_table`` mapping logical block j of a
    slot to its physical block (-1 = unmapped); the host-side
    ``launch.serve.BlockAllocator`` owns the pool."""
    dt = dtype or L.dtype_of(cfg)
    if layout == "paged":
        nb = num_blocks or batch * L.paged_table_width(max_len, kv_block)
        shape = (cfg.num_layers, nb, kv_block, cfg.num_kv_heads,
                 cfg.head_dim)
        return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt),
                "len": jnp.zeros((batch,), jnp.int32),
                "block_table": L.init_block_table(batch, max_len,
                                                  kv_block)}
    shape = (cfg.num_layers, batch, max_len, cfg.num_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt),
            "len": jnp.zeros((batch,), jnp.int32)}


def prefill(params, cfg: ArchConfig, tokens: jax.Array, max_len: int,
            prefix_embeds: Optional[jax.Array] = None):
    """Run the full prompt, build the KV cache, return (hidden_last, cache)."""
    hidden, kvs = forward(params, cfg, tokens, prefix_embeds=prefix_embeds,
                          return_kv=True)
    S = tokens.shape[1]
    k, v = kvs  # (L, B, S, Hkv, hd) each (scan stacks the per-layer kv)
    pad = max_len - S
    k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    cache = {"k": k, "v": v,
             "len": jnp.full((tokens.shape[0],), S, jnp.int32)}
    return hidden[:, -1], cache


def prefill_suffix(params, cfg: ArchConfig, tokens: jax.Array,
                   prefix_kv: dict, prefix_len: int):
    """Prefill ONLY the uncached suffix of a prefix-cache hit.

    tokens: (B, S) the suffix token IDs (absolute positions
    ``prefix_len + [0, S)``); ``prefix_kv``: {"k", "v"} logical strips
    (L, B, W, Hkv, hd) gathered from the block pool with
    ``W >= prefix_len``; ``prefix_len``: STATIC Python int (one compile
    per (hit, suffix) length pair — equal attention reduction extents
    are what make this path bit-exact, see
    ``layers.apply_attention_suffix``).

    Returns (hidden_last, sub) where sub holds the SUFFIX-ONLY K/V
    strips (L, B, S, Hkv, hd) — the caller scatters them at logical
    offset ``prefix_len`` (``write_slot(..., offset=prefix_len)``) —
    and the slot's full depth ``len = prefix_len + S``.  Suffix rows
    are bit-exact vs a cold prefill of the whole prompt (same
    flash-attention path; tested in tests/test_prefix_cache.py).
    """
    prefix_len = int(prefix_len)
    x = L.apply_embed(params["embed"], tokens)
    x = constrain(x, "batch", None, None)
    x = constrain_seq(x, cfg.seq_parallel)
    S = tokens.shape[1]
    positions = prefix_len + jnp.arange(S)[None, :]
    strips = {n: prefix_kv[n][:, :, :prefix_len] for n in ("k", "v")}

    def scan_step(x, bpkv):
        bp, pkv = bpkv
        h, kv = L.apply_attention_suffix(
            bp["attn"], cfg, L.rms_norm(x, bp["ln1"]),
            prefix_kv=(pkv["k"], pkv["v"]), prefix_len=prefix_len,
            positions=positions)
        x = x + constrain_seq(h, cfg.seq_parallel)
        x = constrain_seq(x, cfg.seq_parallel)
        x = x + constrain_seq(L.apply_mlp(bp["mlp"], cfg,
                                          L.rms_norm(x, bp["ln2"])),
                              cfg.seq_parallel)
        x = constrain_seq(x, cfg.seq_parallel)
        return x, kv

    x, kvs = jax.lax.scan(scan_step, x, (params["blocks"], strips))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    k, v = kvs
    lens = jnp.full((tokens.shape[0],), prefix_len + S, jnp.int32)
    return x[:, -1], {"k": k, "v": v, "len": lens}


def prefill_chunk(params, cfg: ArchConfig, tokens: jax.Array, cache: dict,
                  slot: jax.Array, offset: jax.Array, new_len: jax.Array,
                  span: int):
    """One chunk of an incremental (Sarathi-style) prompt prefill.

    tokens: (1, S) chunk token IDs for absolute positions
    ``offset + [0, S)`` of the slot's prompt (final chunks of
    padding-safe families carry junk pads past the true prompt end —
    causally masked, then overwritten by decode writes).  ``span``:
    STATIC attention extent = the prompt's bucketed width W.  Writes the
    chunk's per-layer K/V into the slot's pool blocks and pins the
    slot's ``len`` to ``new_len`` (the true prefilled depth — this also
    heals the +1/step drift that interleaved decode scans inflict on a
    mid-prefill slot's len).  Hidden outputs are discarded: the engine
    re-feeds the prompt's last token at activation, same as batch
    prefill.  Bit-exact vs ``prefill`` on the same bucketed prompt
    (tests/test_chunked_prefill.py)."""
    row = jax.lax.dynamic_slice_in_dim(cache["block_table"], slot, 1, 0)
    x = L.apply_embed(params["embed"], tokens)

    def scan_step(x, bpkv):
        bp, kp, vp = bpkv
        h, (kp, vp) = L.apply_attention_chunk(
            bp["attn"], cfg, L.rms_norm(x, bp["ln1"]),
            kv_pools=(kp, vp), block_row=row, offset=offset, span=span)
        x = x + h
        x = x + L.apply_mlp(bp["mlp"], cfg, L.rms_norm(x, bp["ln2"]))
        return x, (kp, vp)

    _, (kps, vps) = jax.lax.scan(
        scan_step, x, (params["blocks"], cache["k"], cache["v"]))
    return dict(cache, k=kps, v=vps,
                len=cache["len"].at[slot].set(new_len))


def _decode_block(bp, cfg, x, kv, cache_len, block_table=None):
    """One layer of single-token decode; kv: dict k/v (B, S, Hkv, hd)
    strips, or (NB, BS, Hkv, hd) block pools when ``block_table`` is set
    (read via gather or the block-sparse kernel per ``cfg.decode_attn``).

    cache_len () or (B,): per-slot depths give per-slot RoPE positions.
    """
    pos = jnp.reshape(cache_len, (-1, 1))
    h, new_kv = L.apply_attention(
        bp["attn"], cfg, L.rms_norm(x, bp["ln1"]), positions=pos,
        kv_cache=(kv["k"], kv["v"]), cache_len=cache_len,
        block_table=block_table)
    x = x + h
    x = x + L.apply_mlp(bp["mlp"], cfg, L.rms_norm(x, bp["ln2"]))
    return x, {"k": new_kv[0], "v": new_kv[1]}


def decode_hidden(params, cfg: ArchConfig, token: jax.Array, cache: dict):
    """The KV-writing decode body: embed -> blocks -> final norm.

    token: (B,) last sampled token.  Writes the step's K/V at each
    slot's PRE-step depth and returns ``(hidden, new_cache)`` with
    ``len`` advanced by one; the uncertain head over ``hidden`` is the
    shared ``uncertain_head.head_outputs`` (fed the pre-step depths).
    """
    x = L.apply_embed(params["embed"], token[:, None])
    x = constrain(x, "batch", None, None)
    cache_len = cache["len"]
    block_table = cache.get("block_table")     # paged layout marker

    def scan_step(x, bpkv):
        bp, kv = bpkv
        x, new_kv = _decode_block(bp, cfg, x, kv, cache_len, block_table)
        return x, new_kv

    x, new_kvs = jax.lax.scan(
        scan_step, x, (params["blocks"], {"k": cache["k"], "v": cache["v"]}))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x[:, 0], {"k": new_kvs["k"], "v": new_kvs["v"],
                     "len": cache_len + 1}


def decode_step(params, cfg: ArchConfig, token: jax.Array, cache: dict,
                key: jax.Array):
    """One uncertain decode step.

    token: (B,) last sampled token.  Returns (outputs, new_cache) where
    outputs = {next_token, H, SE, MI, p_max} per sequence — the paper's
    uncertainty triplet computed from cfg.mc_samples LRT head draws
    (fused in kernels/uncertainty_head on TPU; jnp math in
    ``uncertain_head`` lowers everywhere and is what the dry-run
    compiles).
    """
    hidden, new_cache = decode_hidden(params, cfg, token, cache)
    return U.head_outputs(params, cfg, hidden, cache["len"], key), \
        new_cache
