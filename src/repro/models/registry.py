"""Model dispatch: ArchConfig.family -> implementation module.

Uniform API across families:
    init_params(key, cfg) -> params
    nll_loss(params, cfg, batch, key) -> (nll, aux)
    make_cache(cfg, batch, max_len, layout=..., ...) -> cache
    prefill(params, cfg, tokens, max_len, **modality) -> (hidden, cache)
    decode_step(params, cfg, token, cache, key) -> (outputs, cache)
    write_slot(cfg, cache, slot, sub, block_row=None) -> cache

Caches are slot-indexed: every leaf carries the slot (batch) axis and
``cache["len"]`` is a per-slot (batch,) depth vector, so a continuous-
batching engine can admit/evict requests into individual slots while the
others keep decoding.  Under ``layout='paged'`` the self-attention KV
leaves (``PAGED_KV_LEAVES``) instead live in a global pool of fixed-size
blocks addressed through a per-slot ``block_table`` (-1 = unmapped);
``layout='dense'`` remains the bit-exact reference layout.

Paged decode reads are selected by ``cfg.decode_attn``, which every
family threads to ``layers.apply_attention`` untouched: ``'gather'``
(reference) materializes the logical span, ``'kernel'`` runs the
block-sparse Pallas kernel over the pool (kernels/paged_attention.py)
— no per-family code, the dispatch lives in the shared attention.

``batch_spec``/``cache_spec``/modality stubs are centralized here so the
launcher's ``input_specs`` stays arch-agnostic.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import encdec, hybrid, moe, ssm, transformer
from repro.models.layers import (copy_block as _copy_block_1l,
                                 mapped_span,  # noqa: F401 (re-export)
                                 paged_gather,  # noqa: F401 (re-export)
                                 paged_scatter,
                                 paged_table_width)


def module_for(cfg: ArchConfig):
    return {
        "dense": transformer,
        "vlm": transformer,
        "audio": encdec,
        "encdec": encdec,
        "moe": moe,
        "ssm": ssm,
        "hybrid": hybrid,
    }[cfg.family]


def init_params(key, cfg: ArchConfig):
    return module_for(cfg).init_params(key, cfg)


def init_params_shape(cfg: ArchConfig):
    """Shape-only params (no allocation) for dry-run lowering."""
    return jax.eval_shape(
        lambda: module_for(cfg).init_params(jax.random.key(0), cfg))


def make_batch_specs(cfg: ArchConfig, batch: int, seq: int) -> dict:
    """ShapeDtypeStruct training batch for this family."""
    tok = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    specs = {"tokens": tok, "labels": tok}
    if cfg.family == "encdec":
        from repro.models.encdec import ENC_LEN
        specs["frames"] = jax.ShapeDtypeStruct(
            (batch, ENC_LEN, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        specs["prefix_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.num_prefix_embeds, cfg.d_model), jnp.float32)
    return specs


def make_batch(key, cfg: ArchConfig, batch: int, seq: int) -> dict:
    """Concrete random batch matching make_batch_specs."""
    specs = make_batch_specs(cfg, batch, seq)
    out = {}
    for name, s in specs.items():
        key, k = jax.random.split(key)
        if jnp.issubdtype(s.dtype, jnp.integer):
            out[name] = jax.random.randint(k, s.shape, 0, cfg.vocab_size,
                                           s.dtype)
        else:
            out[name] = jax.random.normal(k, s.shape, s.dtype) * 0.02
    return out


def nll_loss(params, cfg: ArchConfig, batch: dict, key):
    return module_for(cfg).nll_loss(params, cfg, batch, key)


def supports_paged(cfg: ArchConfig) -> bool:
    """Whether this family has KV strips that benefit from paging.

    Pure-SSM caches are O(1) in context (recurrent state + conv tail),
    so the paged layout is a no-op there and the engine keeps the dense
    layout; every attention-bearing family (dense, vlm, moe, hybrid,
    encdec, audio) pages its self-attention KV.
    """
    return cfg.family != "ssm"


def supports_prompt_padding(cfg: ArchConfig) -> bool:
    """Whether a prompt may be right-padded with junk tokens at prefill.

    Attention-only prompt state is positional: junk rows past the true
    prompt end are causally invisible to real rows, masked below
    ``len`` at decode, and overwritten by the first decode writes — so
    the engine can bucket prompt lengths to ``kv_block`` multiples and
    bound prefill recompiles.  Recurrent families (``ssm``, ``hybrid``)
    would fold the junk tokens into their SSM/conv state, so they keep
    exact-length prefill.
    """
    return cfg.family in ("dense", "vlm", "moe", "encdec", "audio")


def supports_chunked_prefill(cfg: ArchConfig) -> bool:
    """Whether the family implements ``prefill_chunk`` (incremental
    prompt processing against the paged pool).  Requires the paged
    layout — chunks scatter straight into pool blocks.  ``vlm``/
    ``audio`` prompts splice modality embeddings into mid-prompt
    positions, which the chunk walker does not slice yet; they fall
    back to batch prefill.
    """
    return supports_paged(cfg) and cfg.family in ("dense", "moe",
                                                  "hybrid", "encdec")


def prefill_chunk(params, cfg: ArchConfig, tokens, cache, slot, offset,
                  new_len, span: int, **kw):
    """One incremental prefill chunk for ``slot`` (paged layout only).

    tokens: (1, S) chunk at absolute positions ``offset + [0, S)``;
    ``span``: static attention-reduction extent of the whole prompt.
    Family-specific keywords: ``expert_offsets`` (moe, returns
    ``(cache, new_offsets)``), ``state``/``finalize`` (hybrid, returns
    ``(cache, new_state)``), ``frames`` (encdec first chunk).  See
    ``supports_chunked_prefill`` for the dispatch gate."""
    if not supports_chunked_prefill(cfg):
        raise ValueError(f"family {cfg.family!r} has no chunked prefill")
    return module_for(cfg).prefill_chunk(params, cfg, tokens, cache,
                                         slot, offset, new_len, span,
                                         **kw)


def supports_prefix_cache(cfg: ArchConfig) -> bool:
    """Whether prompt KV can be shared across requests by token prefix.

    Sound only when per-position prompt state is a pure function of the
    token prefix: ``vlm``/``encdec``/``audio`` mix non-token modality
    inputs (prefix embeds, encoder frames) into the cache, ``ssm``/
    ``hybrid`` carry recurrent state that a KV-block prefix cannot
    reconstruct, and ``moe`` couples tokens through the expert-capacity
    cumsum (a suffix-only prefill sees a different contention set, so
    capacity drops — and therefore bits — can differ).  That leaves the
    dense token-only family.
    """
    return cfg.family == "dense"


def prefill_suffix(params, cfg: ArchConfig, tokens, prefix_kv: dict,
                   prefix_len):
    """Prefill only the uncached suffix of a prefix-cache hit; see the
    family implementations (``supports_prefix_cache`` gates dispatch)."""
    if not supports_prefix_cache(cfg):
        raise ValueError(f"family {cfg.family!r} cannot prefix-share "
                         "prompt KV")
    return module_for(cfg).prefill_suffix(params, cfg, tokens, prefix_kv,
                                          prefix_len)


def make_cache(cfg: ArchConfig, batch: int, max_len: int,
               layout: str = "dense", kv_block: int = 16,
               num_blocks: int = 0):
    """``layout='dense'``: one contiguous max_len strip per slot (the
    reference layout).  ``layout='paged'``: self-attention KV lives in a
    global pool of ``num_blocks`` x ``kv_block``-token blocks behind a
    per-slot ``block_table`` (see ``launch.serve.BlockAllocator``)."""
    if layout == "paged" and supports_paged(cfg):
        return module_for(cfg).make_cache(cfg, batch, max_len,
                                          layout="paged",
                                          kv_block=kv_block,
                                          num_blocks=num_blocks)
    return module_for(cfg).make_cache(cfg, batch, max_len)


def prefill(params, cfg: ArchConfig, tokens, max_len: int,
            modality: Any = None):
    mod = module_for(cfg)
    if cfg.family == "encdec":
        return mod.prefill(params, cfg, tokens, max_len, frames=modality)
    if cfg.family == "vlm":
        return mod.prefill(params, cfg, tokens, max_len,
                           prefix_embeds=modality)
    return mod.prefill(params, cfg, tokens, max_len)


def decode_step(params, cfg: ArchConfig, token, cache, key):
    out, new_cache = module_for(cfg).decode_step(params, cfg, token,
                                                 cache, key)
    # reattach the paged block table centrally so the scan carry keeps
    # its structure without every family hand-copying it
    if isinstance(cache, dict) and "block_table" in cache:
        new_cache.setdefault("block_table", cache["block_table"])
    return out, new_cache


def decode_hidden(params, cfg: ArchConfig, token, cache):
    """The KV-writing decode BODY alone: embed -> blocks -> final norm,
    returning ``(hidden, new_cache)`` with the step's cache writes done
    and ``len`` advanced, but NO head.  ``decode_step`` is exactly this
    followed by ``head_outputs`` at the pre-step depths — the split the
    speculative-decoding draft/verify passes build on (the draft shares
    the body, so its KV writes are bitwise plain decode's; the verify
    re-runs only the head over the stacked draft hiddens)."""
    hidden, new_cache = module_for(cfg).decode_hidden(params, cfg, token,
                                                      cache)
    if isinstance(cache, dict) and "block_table" in cache:
        new_cache.setdefault("block_table", cache["block_table"])
    return hidden, new_cache


def head_outputs(params, cfg: ArchConfig, hidden, cache_len, key,
                 num_samples=None):
    """The family-shared uncertain head (see models.uncertain_head):
    {next_token, H, SE, MI, p_max} from ``num_samples`` (default
    ``cfg.mc_samples``) LRT draws over ``hidden`` at depth
    ``cache_len``."""
    from repro.models.uncertain_head import head_outputs as _head
    return _head(params, cfg, hidden, cache_len, key,
                 num_samples=num_samples)


def supports_spec_decode(cfg: ArchConfig) -> bool:
    """Whether uncertainty-gated speculative decoding serves this family.

    Every family exposes the ``decode_hidden``/``head_outputs`` split,
    so all of them speculate.  Losslessness rests on per-slot decode
    state being independent across slots given the fed tokens; the one
    cross-slot coupling in the zoo is MoE's capacity cumsum, which only
    bites when an expert overflows during single-token decode dispatch
    — never hit on the served configs (the same assumption the PR 2
    scan-vs-reference parity already makes), and the bitwise parity
    harness (tests/test_spec_decode.py) would catch it if it were.
    """
    return True


# cache leaves that live in the global block pool under the paged layout
PAGED_KV_LEAVES = ("k", "v", "attn_k", "attn_v")

# per-slot recurrent state leaves (hybrid/ssm) that speculative-decode
# rollback must restore to the accepted step (KV pool junk above the
# rolled-back ``len`` is masked instead; see steps.build_spec_commit)
RECURRENT_LEAVES = ("ssm", "conv")


def kv_bytes(cache) -> int:
    """Total allocated bytes of the self-attention KV leaves of a cache
    (dense: the per-slot strips; paged: the whole block pool).  The
    serving engine divides by the pool's block count to price one block.
    """
    return sum(cache[n].size * cache[n].dtype.itemsize
               for n in PAGED_KV_LEAVES if n in cache)


def copy_block(cfg: ArchConfig, cache, src, dst):
    """Copy-on-write: duplicate physical block ``src`` into ``dst``
    across every paged KV leaf (vmapped over the layer axis).  The
    caller swaps the slot's table entry to ``dst`` host-side before the
    divergent write; non-pool leaves pass through untouched."""
    out = dict(cache)
    for name in PAGED_KV_LEAVES:
        if name in cache:
            out[name] = jax.vmap(
                lambda pool: _copy_block_1l(pool, src, dst))(cache[name])
    return out


def write_slot(cfg: ArchConfig, cache, slot, sub, block_row=None,
               offset=None):
    """Write a batch-1 request cache ``sub`` into decode slot ``slot``.

    Family-agnostic by layout convention: every cache leaf carries the
    slot (batch) axis at position 1 -- (L, B, ...) KV stacks, SSM/conv
    states, cross-attention KV -- except the per-slot ``len`` vector,
    which carries it at position 0.  ``slot`` may be traced (one compile
    serves every slot).

    Paged layout (``cache`` has a ``block_table``): ``block_row`` is the
    slot's (MB,) physical-block row from the host allocator; the
    ``PAGED_KV_LEAVES`` of ``sub`` (dense batch-1 strips from prefill)
    are scattered from logical position ``offset`` (default 0; a
    prefix-cache hit passes the matched prefix length so the suffix
    strip lands after the shared blocks) through the shared
    ``layers.paged_scatter`` indirection (vmapped over the layer axis),
    the remaining leaves take the dense slot write, and the
    slot's table row is installed.  Strip tokens past the mapped blocks
    drop (mode='drop'), so a strip padded beyond the prompt is safe.
    """
    if not isinstance(cache, dict) or "block_table" not in cache:
        def w(c, s):
            s = s.astype(c.dtype)
            if c.ndim == 1:                  # the (B,) len vector
                return jax.lax.dynamic_update_slice(c, s, (slot,))
            start = (0, slot) + (0,) * (c.ndim - 2)
            return jax.lax.dynamic_update_slice(c, s, start)

        return jax.tree.map(w, cache, sub)

    if block_row is None:
        raise ValueError("paged cache write needs the slot's block_row")
    lens0 = jnp.zeros((1,), jnp.int32) if offset is None else \
        jnp.reshape(jnp.asarray(offset, jnp.int32), (1,))
    out = {}
    for name, c in cache.items():
        if name == "block_table":
            out[name] = jax.lax.dynamic_update_slice(
                c, block_row[None].astype(c.dtype), (slot, jnp.int32(0)))
        elif name in PAGED_KV_LEAVES:
            strip = sub[name].astype(c.dtype)      # (A, 1, S, Hkv, hd)
            table = block_row[None].astype(jnp.int32)
            out[name] = jax.vmap(
                lambda pool, new: paged_scatter(pool, table, lens0, new)
            )(c, strip)
        elif c.ndim == 1:                          # the (B,) len vector
            out[name] = jax.lax.dynamic_update_slice(
                c, sub[name].astype(c.dtype), (slot,))
        else:
            s = sub[name].astype(c.dtype)
            start = (0, slot) + (0,) * (c.ndim - 2)
            out[name] = jax.lax.dynamic_update_slice(c, s, start)
    return out
