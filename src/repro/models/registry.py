"""Model dispatch: ArchConfig.family -> implementation module.

Uniform API across families:
    init_params(key, cfg) -> params
    nll_loss(params, cfg, batch, key) -> (nll, aux)
    make_cache(cfg, batch, max_len) -> cache
    prefill(params, cfg, tokens, max_len, **modality) -> (hidden, cache)
    decode_step(params, cfg, token, cache, key) -> (outputs, cache)
    write_slot(cfg, cache, slot, sub) -> cache   (slot-indexed serving)

Caches are slot-indexed: every leaf carries the slot (batch) axis and
``cache["len"]`` is a per-slot (batch,) depth vector, so a continuous-
batching engine can admit/evict requests into individual slots while the
others keep decoding.

``batch_spec``/``cache_spec``/modality stubs are centralized here so the
launcher's ``input_specs`` stays arch-agnostic.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import encdec, hybrid, moe, ssm, transformer


def module_for(cfg: ArchConfig):
    return {
        "dense": transformer,
        "vlm": transformer,
        "audio": encdec,
        "encdec": encdec,
        "moe": moe,
        "ssm": ssm,
        "hybrid": hybrid,
    }[cfg.family]


def init_params(key, cfg: ArchConfig):
    return module_for(cfg).init_params(key, cfg)


def init_params_shape(cfg: ArchConfig):
    """Shape-only params (no allocation) for dry-run lowering."""
    return jax.eval_shape(
        lambda: module_for(cfg).init_params(jax.random.key(0), cfg))


def make_batch_specs(cfg: ArchConfig, batch: int, seq: int) -> dict:
    """ShapeDtypeStruct training batch for this family."""
    tok = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    specs = {"tokens": tok, "labels": tok}
    if cfg.family == "encdec":
        from repro.models.encdec import ENC_LEN
        specs["frames"] = jax.ShapeDtypeStruct(
            (batch, ENC_LEN, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        specs["prefix_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.num_prefix_embeds, cfg.d_model), jnp.float32)
    return specs


def make_batch(key, cfg: ArchConfig, batch: int, seq: int) -> dict:
    """Concrete random batch matching make_batch_specs."""
    specs = make_batch_specs(cfg, batch, seq)
    out = {}
    for name, s in specs.items():
        key, k = jax.random.split(key)
        if jnp.issubdtype(s.dtype, jnp.integer):
            out[name] = jax.random.randint(k, s.shape, 0, cfg.vocab_size,
                                           s.dtype)
        else:
            out[name] = jax.random.normal(k, s.shape, s.dtype) * 0.02
    return out


def nll_loss(params, cfg: ArchConfig, batch: dict, key):
    return module_for(cfg).nll_loss(params, cfg, batch, key)


def make_cache(cfg: ArchConfig, batch: int, max_len: int):
    return module_for(cfg).make_cache(cfg, batch, max_len)


def prefill(params, cfg: ArchConfig, tokens, max_len: int,
            modality: Any = None):
    mod = module_for(cfg)
    if cfg.family == "encdec":
        return mod.prefill(params, cfg, tokens, max_len, frames=modality)
    if cfg.family == "vlm":
        return mod.prefill(params, cfg, tokens, max_len,
                           prefix_embeds=modality)
    return mod.prefill(params, cfg, tokens, max_len)


def decode_step(params, cfg: ArchConfig, token, cache, key):
    return module_for(cfg).decode_step(params, cfg, token, cache, key)


def write_slot(cfg: ArchConfig, cache, slot, sub):
    """Write a batch-1 request cache ``sub`` into decode slot ``slot``.

    Family-agnostic by layout convention: every cache leaf carries the
    slot (batch) axis at position 1 -- (L, B, ...) KV stacks, SSM/conv
    states, cross-attention KV -- except the per-slot ``len`` vector,
    which carries it at position 0.  ``slot`` may be traced (one compile
    serves every slot).
    """

    def w(c, s):
        s = s.astype(c.dtype)
        if c.ndim == 1:                      # the (B,) len vector
            return jax.lax.dynamic_update_slice(c, s, (slot,))
        start = (0, slot) + (0,) * (c.ndim - 2)
        return jax.lax.dynamic_update_slice(c, s, start)

    return jax.tree.map(w, cache, sub)
