"""Zamba2-style hybrid: Mamba2 backbone + one SHARED attention block
applied every ``attn_every`` layers (arXiv:2411.15242).

The shared block (attention + MLP, one set of weights reused at every
application depth) is the Zamba trick: global-context mixing at a fraction
of the parameter cost.  Implementation: scan over the stacked Mamba blocks
with a ``lax.cond`` that fires the shared block whenever
``layer_idx % attn_every == 0`` — the HLO stays O(1) in depth and only one
branch executes at runtime.

Decode keeps: per-layer Mamba (ssm, conv) states + per-APPLICATION KV
caches for the shared attention (same weights, distinct activations =>
distinct cache per application depth).  Mamba carries the long context;
attention applications see the full cache — decode attention is O(S) per
token, which is why this arch runs the ``long_500k`` cell.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import uncertain_head as U
from repro.models import ssm as S
from repro.sharding.partition import constrain


def n_attn_apps(cfg: ArchConfig) -> int:
    return (cfg.num_layers + cfg.attn_every - 1) // cfg.attn_every


def init_params(key, cfg: ArchConfig):
    ke, kb, ka, km, kh = jax.random.split(key, 5)
    blocks = jax.vmap(lambda k: S.init_block(k, cfg))(
        jax.random.split(kb, cfg.num_layers))
    shared = {
        "ln1": jnp.ones((cfg.d_model,), L.dtype_of(cfg)),
        "attn": L.init_attention(ka, cfg),
        "ln2": jnp.ones((cfg.d_model,), L.dtype_of(cfg)),
        "mlp": L.init_mlp(km, cfg),
    }
    return {"embed": L.init_embed(ke, cfg), "blocks": blocks,
            "shared": shared,
            "final_norm": jnp.ones((cfg.d_model,), L.dtype_of(cfg)),
            "head": L.init_head(kh, cfg)}


def _shared_fwd(sp, cfg, x, positions):
    h, kv = L.apply_attention(sp["attn"], cfg, L.rms_norm(x, sp["ln1"]),
                              positions=positions, causal=True)
    x = x + h
    x = x + L.apply_mlp(sp["mlp"], cfg, L.rms_norm(x, sp["ln2"]))
    return x, kv


def forward(params, cfg: ArchConfig, tokens: jax.Array):
    x = L.apply_embed(params["embed"], tokens)
    x = constrain(x, "batch", None, None)
    positions = jnp.arange(tokens.shape[1])[None, :]
    sp = params["shared"]

    def scan_step(x, idx_bp):
        idx, bp = idx_bp

        def body(xx):
            y = jax.lax.cond(
                idx % cfg.attn_every == 0,
                lambda v: _shared_fwd(sp, cfg, v, positions)[0],
                lambda v: v, xx)
            y, _, _ = S.apply_block(bp, cfg, y)
            return y

        y = jax.checkpoint(body, prevent_cse=False)(x) if cfg.remat \
            else body(x)
        return y, None

    idxs = jnp.arange(cfg.num_layers)
    x, _ = jax.lax.scan(scan_step, x, (idxs, params["blocks"]))
    return L.rms_norm(x, params["final_norm"], cfg.norm_eps)


def nll_loss(params, cfg: ArchConfig, batch: dict, key: jax.Array):
    hidden = forward(params, cfg, batch["tokens"])
    head = params["head"]
    if "q" in head:
        eps = jax.random.normal(key, head["q"].mu.shape, jnp.float32)
        w = head["q"].sample_with_eps(eps)
        logits = jnp.dot(hidden, w.astype(hidden.dtype),
                         preferred_element_type=jnp.float32)
    else:
        logits = L.head_logits_mean(head, hidden, cfg)
    logits = constrain(logits, "batch", None, "model")
    labels = batch["labels"]
    valid = labels >= 0
    lab = jnp.where(valid, labels, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    tok = -jnp.take_along_axis(logp, lab[..., None], axis=-1)[..., 0]
    nll = jnp.where(valid, tok, 0.0).sum() / jnp.maximum(valid.sum(), 1)
    acc = ((logits.argmax(-1) == labels) & valid).sum() / \
        jnp.maximum(valid.sum(), 1)
    return nll, {"accuracy": acc}


def make_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=None,
               layout: str = "dense", kv_block: int = 16,
               num_blocks: int = 0):
    """Mamba states stay per-slot (O(1) in context); only the shared
    attention's KV strips participate in the paged layout — one pool
    plane per application depth, all indexed by the same block table."""
    d_in, H, P, N = S.dims(cfg)
    dt = dtype or L.dtype_of(cfg)
    A = n_attn_apps(cfg)
    cache = {
        "ssm": jnp.zeros((cfg.num_layers, batch, H, P, N), jnp.float32),
        "conv": jnp.zeros((cfg.num_layers, batch, cfg.ssm_conv_width - 1,
                           d_in + 2 * N), dt),
        "len": jnp.zeros((batch,), jnp.int32),
    }
    if layout == "paged":
        nb = num_blocks or batch * L.paged_table_width(max_len, kv_block)
        kv = (A, nb, kv_block, cfg.num_kv_heads, cfg.head_dim)
        cache["attn_k"] = jnp.zeros(kv, dt)
        cache["attn_v"] = jnp.zeros(kv, dt)
        cache["block_table"] = L.init_block_table(batch, max_len,
                                                  kv_block)
    else:
        kv = (A, batch, max_len, cfg.num_kv_heads, cfg.head_dim)
        cache["attn_k"] = jnp.zeros(kv, dt)
        cache["attn_v"] = jnp.zeros(kv, dt)
    return cache


def prefill(params, cfg: ArchConfig, tokens: jax.Array, max_len: int):
    """Prefill with python-level loop over attention applications (static
    count) + scanned mamba groups — keeps caches per application."""
    x = L.apply_embed(params["embed"], tokens)
    positions = jnp.arange(tokens.shape[1])[None, :]
    sp = params["shared"]
    A = n_attn_apps(cfg)
    Sq = tokens.shape[1]
    ks, vs, hs, cs = [], [], [], []
    for a in range(A):
        lo = a * cfg.attn_every
        hi = min(lo + cfg.attn_every, cfg.num_layers)
        x, kv = _shared_fwd(sp, cfg, x, positions)
        pad = max_len - Sq
        ks.append(jnp.pad(kv[0], ((0, 0), (0, pad), (0, 0), (0, 0))))
        vs.append(jnp.pad(kv[1], ((0, 0), (0, pad), (0, 0), (0, 0))))
        grp = jax.tree.map(lambda p: p[lo:hi], params["blocks"])

        def scan_step(x, bp):
            y, h, c = S.apply_block(bp, cfg, x)
            return y, (h, c)

        x, (h, c) = jax.lax.scan(scan_step, x, grp)
        hs.append(h)
        cs.append(c)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    cache = {"ssm": jnp.concatenate(hs, 0), "conv": jnp.concatenate(cs, 0),
             "attn_k": jnp.stack(ks), "attn_v": jnp.stack(vs),
             "len": jnp.full((tokens.shape[0],), Sq, jnp.int32)}
    return x[:, -1], cache


def prefill_chunk(params, cfg: ArchConfig, tokens: jax.Array, cache: dict,
                  slot: jax.Array, offset: jax.Array, new_len: jax.Array,
                  span: int, state: dict, finalize: bool):
    """Chunked hybrid prefill step (see transformer.prefill_chunk).

    Mamba state is NOT positional, so the per-layer (ssm, conv) states
    of the in-flight prompt ride ENGINE-side in ``state`` (batch-1
    leaves, zeros before the first chunk — a zero conv tail reproduces
    the fresh path's left zero-pad exactly) and are written into the
    cache only on the ``finalize`` chunk.  Chunks must be multiples of
    ``cfg.ssm_chunk`` (exact tail allowed): the SSD block decomposition
    then matches batch prefill block for block, and ``force_chunked``
    keeps even a 1-token tail on the chunked form.  ``span`` is the
    EXACT prompt length — hybrid prompts are never padded (junk tokens
    would pollute the recurrent state)."""
    A = n_attn_apps(cfg)
    sp = params["shared"]
    row = jax.lax.dynamic_slice_in_dim(cache["block_table"], slot, 1, 0)
    x = L.apply_embed(params["embed"], tokens)
    new_k, new_v, new_h, new_c = [], [], [], []
    for a in range(A):
        lo = a * cfg.attn_every
        hi = min(lo + cfg.attn_every, cfg.num_layers)
        h_att, (kp, vp) = L.apply_attention_chunk(
            sp["attn"], cfg, L.rms_norm(x, sp["ln1"]),
            kv_pools=(cache["attn_k"][a], cache["attn_v"][a]),
            block_row=row, offset=offset, span=span)
        x = x + h_att
        x = x + L.apply_mlp(sp["mlp"], cfg, L.rms_norm(x, sp["ln2"]))
        new_k.append(kp)
        new_v.append(vp)
        grp = jax.tree.map(lambda p: p[lo:hi], params["blocks"])
        hgrp = state["ssm"][lo:hi]
        cgrp = state["conv"][lo:hi]

        def scan_step(x, bpstate):
            bp, h, c = bpstate
            y, h2, c2 = S.apply_block(bp, cfg, x, ssm_state=h,
                                      conv_state=c, force_chunked=True)
            return y, (h2, c2)

        x, (h2, c2) = jax.lax.scan(scan_step, x, (grp, hgrp, cgrp))
        new_h.append(h2)
        new_c.append(c2)
    state = {"ssm": jnp.concatenate(new_h, 0),
             "conv": jnp.concatenate(new_c, 0)}
    cache = dict(cache, attn_k=jnp.stack(new_k),
                 attn_v=jnp.stack(new_v),
                 len=cache["len"].at[slot].set(new_len))
    if finalize:
        cache["ssm"] = jax.lax.dynamic_update_slice(
            cache["ssm"], state["ssm"].astype(cache["ssm"].dtype),
            (0, slot, 0, 0, 0))
        cache["conv"] = jax.lax.dynamic_update_slice(
            cache["conv"], state["conv"].astype(cache["conv"].dtype),
            (0, slot, 0, 0))
    return cache, state


def decode_hidden(params, cfg: ArchConfig, token: jax.Array, cache: dict):
    """The KV/state-writing decode body (see transformer.decode_hidden);
    also advances the per-layer SSM/conv recurrent state."""
    x = L.apply_embed(params["embed"], token[:, None])
    x = constrain(x, "batch", None, None)
    sp = params["shared"]
    cache_len = cache["len"]
    block_table = cache.get("block_table")     # paged layout marker
    # (read path per cfg.decode_attn: gather or block-sparse kernel)
    A = n_attn_apps(cfg)
    new_k, new_v, new_h, new_c = [], [], [], []
    for a in range(A):
        lo = a * cfg.attn_every
        hi = min(lo + cfg.attn_every, cfg.num_layers)
        pos = jnp.reshape(cache_len, (-1, 1))
        h_att, kv = L.apply_attention(
            sp["attn"], cfg, L.rms_norm(x, sp["ln1"]), positions=pos,
            kv_cache=(cache["attn_k"][a], cache["attn_v"][a]),
            cache_len=cache_len, block_table=block_table)
        x = x + h_att
        x = x + L.apply_mlp(sp["mlp"], cfg, L.rms_norm(x, sp["ln2"]))
        new_k.append(kv[0])
        new_v.append(kv[1])
        grp = jax.tree.map(lambda p: p[lo:hi], params["blocks"])
        hgrp = cache["ssm"][lo:hi]
        cgrp = cache["conv"][lo:hi]

        def scan_step(x, bpstate):
            bp, h, c = bpstate
            y, h2, c2 = S.apply_block(bp, cfg, x, ssm_state=h, conv_state=c)
            return y, (h2, c2)

        x, (h2, c2) = jax.lax.scan(scan_step, x, (grp, hgrp, cgrp))
        new_h.append(h2)
        new_c.append(c2)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    new_cache = {"ssm": jnp.concatenate(new_h, 0),
                 "conv": jnp.concatenate(new_c, 0),
                 "attn_k": jnp.stack(new_k), "attn_v": jnp.stack(new_v),
                 "len": cache_len + 1}
    return x[:, 0], new_cache


def decode_step(params, cfg: ArchConfig, token: jax.Array, cache: dict,
                key: jax.Array):
    hidden, new_cache = decode_hidden(params, cfg, token, cache)
    return U.head_outputs(params, cfg, hidden, cache["len"], key), \
        new_cache
