"""Encoder-decoder transformer (seamless-m4t-medium backbone).

Per the assignment, the modality frontend is a STUB: the encoder consumes
precomputed speech-frame embeddings (B, S_enc, d) from ``input_specs``.
The encoder is bidirectional self-attention; the decoder interleaves causal
self-attention (KV-cached at decode), cross-attention over the encoder
memory (cross-KV computed once at prefill), and the MLP.  The Bayesian
variational head sits on the decoder output (paper technique, §DESIGN 4).

Encoder length is fixed at ``ENC_LEN`` (speech encoders emit a
near-constant frame count); the shape-cell seq_len applies to the decoder.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import uncertain_head as U
from repro.sharding.partition import constrain

ENC_LEN = 1024


def init_enc_block(key, cfg: ArchConfig):
    k1, k2 = jax.random.split(key)
    return {"ln1": jnp.ones((cfg.d_model,), L.dtype_of(cfg)),
            "attn": L.init_attention(k1, cfg),
            "ln2": jnp.ones((cfg.d_model,), L.dtype_of(cfg)),
            "mlp": L.init_mlp(k2, cfg)}


def init_dec_block(key, cfg: ArchConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": jnp.ones((cfg.d_model,), L.dtype_of(cfg)),
            "self_attn": L.init_attention(k1, cfg),
            "ln_x": jnp.ones((cfg.d_model,), L.dtype_of(cfg)),
            "cross_attn": L.init_attention(k2, cfg),
            "ln2": jnp.ones((cfg.d_model,), L.dtype_of(cfg)),
            "mlp": L.init_mlp(k3, cfg)}


def init_params(key, cfg: ArchConfig):
    ke, kenc, kdec, kh = jax.random.split(key, 4)
    n_enc = cfg.encoder_layers or cfg.num_layers
    n_dec = cfg.decoder_layers or cfg.num_layers
    enc = jax.vmap(lambda k: init_enc_block(k, cfg))(
        jax.random.split(kenc, n_enc))
    dec = jax.vmap(lambda k: init_dec_block(k, cfg))(
        jax.random.split(kdec, n_dec))
    return {"embed": L.init_embed(ke, cfg),
            "encoder": enc, "decoder": dec,
            "enc_norm": jnp.ones((cfg.d_model,), L.dtype_of(cfg)),
            "final_norm": jnp.ones((cfg.d_model,), L.dtype_of(cfg)),
            "head": L.init_head(kh, cfg)}


def encode(params, cfg: ArchConfig, frames: jax.Array) -> jax.Array:
    """frames: (B, S_enc, d) stub frontend embeddings -> encoder memory."""
    x = frames.astype(L.dtype_of(cfg))
    x = constrain(x, "batch", None, None)
    positions = jnp.arange(x.shape[1])[None, :]

    def scan_step(x, bp):
        def body(xx):
            h, _ = L.apply_attention(bp["attn"], cfg,
                                     L.rms_norm(xx, bp["ln1"]),
                                     positions=positions, causal=False)
            xx = xx + h
            return xx + L.apply_mlp(bp["mlp"], cfg,
                                    L.rms_norm(xx, bp["ln2"]))
        y = jax.checkpoint(body, prevent_cse=False)(x) if cfg.remat \
            else body(x)
        return y, None

    x, _ = jax.lax.scan(scan_step, x, params["encoder"])
    return L.rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _dec_block(bp, cfg, x, positions, enc_out=None, cross_kv=None,
               kv_cache=None, cache_len=None, block_table=None):
    h, kv = L.apply_attention(bp["self_attn"], cfg,
                              L.rms_norm(x, bp["ln1"]),
                              positions=positions, causal=True,
                              kv_cache=kv_cache, cache_len=cache_len,
                              block_table=block_table)
    x = x + h
    if cross_kv is None:
        cross_kv = L.make_cross_kv(bp["cross_attn"], cfg, enc_out)
    hc, _ = L.apply_attention(bp["cross_attn"], cfg,
                              L.rms_norm(x, bp["ln_x"]),
                              positions=positions, cross_kv=cross_kv)
    x = x + hc
    x = x + L.apply_mlp(bp["mlp"], cfg, L.rms_norm(x, bp["ln2"]))
    return x, kv, cross_kv


def decode_train(params, cfg: ArchConfig, tokens: jax.Array,
                 enc_out: jax.Array) -> jax.Array:
    x = L.apply_embed(params["embed"], tokens)
    x = constrain(x, "batch", None, None)
    positions = jnp.arange(tokens.shape[1])[None, :]

    def scan_step(x, bp):
        def body(xx):
            y, _, _ = _dec_block(bp, cfg, xx, positions, enc_out=enc_out)
            return y
        y = jax.checkpoint(body, prevent_cse=False)(x) if cfg.remat \
            else body(x)
        return y, None

    x, _ = jax.lax.scan(scan_step, x, params["decoder"])
    return L.rms_norm(x, params["final_norm"], cfg.norm_eps)


def nll_loss(params, cfg: ArchConfig, batch: dict, key: jax.Array):
    """batch: {frames (B,S_enc,d), tokens (B,S), labels (B,S)}."""
    enc_out = encode(params, cfg, batch["frames"])
    hidden = decode_train(params, cfg, batch["tokens"], enc_out)
    head = params["head"]
    if "q" in head:
        eps = jax.random.normal(key, head["q"].mu.shape, jnp.float32)
        w = head["q"].sample_with_eps(eps)
        logits = jnp.dot(hidden, w.astype(hidden.dtype),
                         preferred_element_type=jnp.float32)
    else:
        logits = L.head_logits_mean(head, hidden, cfg)
    logits = constrain(logits, "batch", None, "model")
    labels = batch["labels"]
    valid = labels >= 0
    lab = jnp.where(valid, labels, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    tok = -jnp.take_along_axis(logp, lab[..., None], axis=-1)[..., 0]
    nll = jnp.where(valid, tok, 0.0).sum() / jnp.maximum(valid.sum(), 1)
    acc = ((logits.argmax(-1) == labels) & valid).sum() / \
        jnp.maximum(valid.sum(), 1)
    return nll, {"accuracy": acc}


def make_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=None,
               layout: str = "dense", kv_block: int = 16,
               num_blocks: int = 0):
    """Self-attention KV pages; the cross-attention memory stays a dense
    per-slot strip (always exactly ENC_LEN deep — paging it would save
    nothing)."""
    dt = dtype or L.dtype_of(cfg)
    n_dec = cfg.decoder_layers or cfg.num_layers
    cross = (n_dec, batch, ENC_LEN, cfg.num_kv_heads, cfg.head_dim)
    cache = {"ck": jnp.zeros(cross, dt), "cv": jnp.zeros(cross, dt),
             "len": jnp.zeros((batch,), jnp.int32)}
    if layout == "paged":
        nb = num_blocks or batch * L.paged_table_width(max_len, kv_block)
        kv = (n_dec, nb, kv_block, cfg.num_kv_heads, cfg.head_dim)
        cache["block_table"] = L.init_block_table(batch, max_len,
                                                  kv_block)
    else:
        kv = (n_dec, batch, max_len, cfg.num_kv_heads, cfg.head_dim)
    cache["k"] = jnp.zeros(kv, dt)
    cache["v"] = jnp.zeros(kv, dt)
    return cache


def prefill(params, cfg: ArchConfig, tokens: jax.Array, max_len: int,
            frames: jax.Array):
    """Encode frames, precompute cross-KV, run decoder prompt."""
    enc_out = encode(params, cfg, frames)
    x = L.apply_embed(params["embed"], tokens)
    positions = jnp.arange(tokens.shape[1])[None, :]

    def scan_step(x, bp):
        y, kv, ckv = _dec_block(bp, cfg, x, positions, enc_out=enc_out)
        return y, (kv, ckv)

    x, (kvs, ckvs) = jax.lax.scan(scan_step, x, params["decoder"])
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    Sq = tokens.shape[1]
    pad = max_len - Sq
    k = jnp.pad(kvs[0], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    v = jnp.pad(kvs[1], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    cache = {"k": k, "v": v, "ck": ckvs[0], "cv": ckvs[1],
             "len": jnp.full((tokens.shape[0],), Sq, jnp.int32)}
    return x[:, -1], cache


def prefill_chunk(params, cfg: ArchConfig, tokens: jax.Array, cache: dict,
                  slot: jax.Array, offset: jax.Array, new_len: jax.Array,
                  span: int, frames: Optional[jax.Array] = None):
    """Chunked encoder-decoder prefill step (see
    transformer.prefill_chunk).

    The FIRST chunk passes ``frames``: it runs the encoder and writes
    the per-layer cross-attention K/V into the slot's dense ``ck``/
    ``cv`` strips (a separate jit variant).  Later chunks read those
    strips back — cross attention is non-causal over a fixed ENC_LEN
    extent and row-independent, so per-chunk decoder rows reproduce the
    batch path bit for bit.  Decoder self-attention pages through the
    block pool like the dense family."""
    row = jax.lax.dynamic_slice_in_dim(cache["block_table"], slot, 1, 0)
    x = L.apply_embed(params["embed"], tokens)
    pos = offset + jnp.arange(tokens.shape[1])[None, :]
    first = frames is not None
    if first:
        enc_out = encode(params, cfg, frames)
        xs_extra = ()
    else:
        ck_s = jax.lax.dynamic_slice_in_dim(cache["ck"], slot, 1, 1)
        cv_s = jax.lax.dynamic_slice_in_dim(cache["cv"], slot, 1, 1)
        xs_extra = (ck_s, cv_s)

    def scan_step(x, bpkv):
        bp, kp, vp = bpkv[:3]
        h, (kp, vp) = L.apply_attention_chunk(
            bp["self_attn"], cfg, L.rms_norm(x, bp["ln1"]),
            kv_pools=(kp, vp), block_row=row, offset=offset, span=span)
        x = x + h
        ckv = L.make_cross_kv(bp["cross_attn"], cfg, enc_out) if first \
            else (bpkv[3], bpkv[4])
        hc, _ = L.apply_attention(bp["cross_attn"], cfg,
                                  L.rms_norm(x, bp["ln_x"]),
                                  positions=pos, cross_kv=ckv)
        x = x + hc
        x = x + L.apply_mlp(bp["mlp"], cfg, L.rms_norm(x, bp["ln2"]))
        ys = (kp, vp, ckv[0], ckv[1]) if first else (kp, vp)
        return x, ys

    _, ys = jax.lax.scan(
        scan_step, x,
        (params["decoder"], cache["k"], cache["v"]) + xs_extra)
    cache = dict(cache, k=ys[0], v=ys[1],
                 len=cache["len"].at[slot].set(new_len))
    if first:
        cache["ck"] = jax.lax.dynamic_update_slice(
            cache["ck"], ys[2].astype(cache["ck"].dtype),
            (0, slot, 0, 0, 0))
        cache["cv"] = jax.lax.dynamic_update_slice(
            cache["cv"], ys[3].astype(cache["cv"].dtype),
            (0, slot, 0, 0, 0))
    return cache


def decode_hidden(params, cfg: ArchConfig, token: jax.Array, cache: dict):
    """The KV-writing decode body (see transformer.decode_hidden); the
    cross-attention KV strips pass through untouched."""
    x = L.apply_embed(params["embed"], token[:, None])
    x = constrain(x, "batch", None, None)
    cache_len = cache["len"]
    block_table = cache.get("block_table")     # paged layout marker
    # (read path per cfg.decode_attn: gather or block-sparse kernel)
    pos = jnp.reshape(cache_len, (-1, 1))

    def scan_step(x, bpkv):
        bp, k, v, ck, cv = bpkv
        y, kv, _ = _dec_block(bp, cfg, x, pos, cross_kv=(ck, cv),
                              kv_cache=(k, v), cache_len=cache_len,
                              block_table=block_table)
        return y, kv

    x, kvs = jax.lax.scan(
        scan_step, x,
        (params["decoder"], cache["k"], cache["v"], cache["ck"],
         cache["cv"]))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x[:, 0], {"k": kvs[0], "v": kvs[1], "ck": cache["ck"],
                     "cv": cache["cv"], "len": cache_len + 1}


def decode_step(params, cfg: ArchConfig, token: jax.Array, cache: dict,
                key: jax.Array):
    hidden, new_cache = decode_hidden(params, cfg, token, cache)
    return U.head_outputs(params, cfg, hidden, cache["len"], key), \
        new_cache
