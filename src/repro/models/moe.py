"""Mixture-of-Experts transformer (grok-1-314b, deepseek-moe-16b).

Routing: softmax top-k with capacity-based dispatch.  Token positions per
expert come from a cumsum over the routing one-hot (no sort), tokens are
scattered into an (E, C, d) buffer, experts run as one batched einsum, and
the combine weights scatter results back.  Capacity overflow drops tokens
(standard GShard semantics) — the capacity factor and the auxiliary
load-balancing loss keep drops rare.

Expert parallelism: the (E, ...) expert weights shard over the ``model``
mesh axis when ``expert_sharding == 'ep'`` (deepseek: 64 experts / 16-way
model axis = 4 experts per group; the dispatch buffer's E axis is
sharding-constrained so GSPMD inserts the dispatch/return all-to-alls).
grok-1's 8 experts < 16-way axis, so it uses ``'tp'``: experts replicated
over the axis with their ff dim tensor-parallel — no all-to-all, instead
the usual TP reduce.

DeepSeekMoE specifics (arXiv:2401.06066): fine-grained experts
(moe_d_ff=1408 vs dense d_ff) + 2 shared experts always active.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import uncertain_head as U
from repro.models import transformer as T
from repro.sharding.partition import constrain, constrain_seq


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_experts(key, cfg: ArchConfig, num: int, d_ff: int):
    dt = L.dtype_of(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 3)

    def stack(k, shape, fan):
        return (jax.random.normal(k, (num, *shape), jnp.float32)
                / jnp.sqrt(float(fan))).astype(dt)

    return {"w1": stack(ks[0], (d, d_ff), d),
            "w3": stack(ks[1], (d, d_ff), d),
            "w2": stack(ks[2], (d_ff, d), d_ff)}


def init_block(key, cfg: ArchConfig):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    eff = cfg.moe_d_ff or cfg.d_ff
    ename = "experts_ep" if cfg.expert_sharding == "ep" else "experts_tp"
    p = {
        "ln1": jnp.ones((cfg.d_model,), L.dtype_of(cfg)),
        "attn": L.init_attention(k1, cfg),
        "ln2": jnp.ones((cfg.d_model,), L.dtype_of(cfg)),
        "router": {"w": (jax.random.normal(
            k2, (cfg.d_model, cfg.num_experts), jnp.float32) * 0.02)},
        ename: _init_experts(k3, cfg, cfg.num_experts, eff),
    }
    if cfg.num_shared_experts:
        p["shared"] = _init_experts(
            k4, cfg, cfg.num_shared_experts, eff)
    return p


def init_params(key, cfg: ArchConfig):
    ke, kb, kh = jax.random.split(key, 3)
    block_keys = jax.random.split(kb, cfg.num_layers)
    blocks = jax.vmap(lambda k: init_block(k, cfg))(block_keys)
    return {
        "embed": L.init_embed(ke, cfg),
        "blocks": blocks,
        "final_norm": jnp.ones((cfg.d_model,), L.dtype_of(cfg)),
        "head": L.init_head(kh, cfg),
    }


# ---------------------------------------------------------------------------
# MoE layer
# ---------------------------------------------------------------------------

def _expert_ffn(ep, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    """x: (..., E, C, d) -> (..., E, C, d), batched gated MLP over
    experts (leading group axis broadcasts over the expert weights)."""
    # partial-sum outputs in the activation dtype: the row-parallel (w2)
    # all-reduce moves bf16 not f32 (§Perf/grok iteration 5)
    g = jnp.einsum("...ecd,edf->...ecf", x, ep["w1"],
                   preferred_element_type=x.dtype)
    u = jnp.einsum("...ecd,edf->...ecf", x, ep["w3"],
                   preferred_element_type=x.dtype)
    h = jax.nn.silu(g) * u
    return jnp.einsum("...ecf,efd->...ecd", h, ep["w2"],
                      preferred_element_type=x.dtype)


def _dispatch_groups(cfg: ArchConfig, total_tokens: int) -> int:
    """Number of dispatch groups = DP shards (GShard-style local
    dispatch).  Tokens never leave their data shard for the capacity
    buffer; only the expert einsum communicates (EP all-to-all or TP
    reduce).  Without a mesh context (unit tests) this is 1 group --
    identical semantics, global capacity.
    """
    from repro.sharding.partition import get_mesh
    mesh = get_mesh()
    if mesh is None:
        return 1
    g = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            g *= mesh.shape[a]
    while total_tokens % g and g > 1:       # safety for odd test shapes
        g //= 2
    return max(g, 1)


def moe_ffn(bp, cfg: ArchConfig, x: jax.Array,
            expert_offsets: Optional[jax.Array] = None,
            capacity: Optional[int] = None):
    """x: (B, S, d) -> (y, aux_loss). Top-k capacity dispatch.

    Grouped dispatch (perf iteration 1, EXPERIMENTS.md §Perf/grok):
    tokens are dispatched into a (G, E, C, d) buffer whose group axis G
    aligns with the DP sharding of the batch, so the scatter/gather is
    LOCAL to each data shard (the naive global (E, C, d) buffer forced
    GSPMD to all-reduce a replicated 32 GB scatter per layer).

    ``expert_offsets`` (E,) f32 + ``capacity`` enable CHUNKED prefill:
    the caller threads each expert's running assignment count across
    chunks and fixes C to the value the full prompt would compute, so a
    token's keep/drop decision is made against its GLOBAL queue position
    — identical to the one batch dispatch over the whole prompt (counts
    are small integers, exact in f32).  When set, the return gains a
    third element: the updated offsets (counts include dropped
    assignments, matching the batch cumsum).  G must be 1 in this mode.
    """
    B, S, d = x.shape
    Tn = B * S
    E, K = cfg.num_experts, cfg.top_k
    G = _dispatch_groups(cfg, Tn) if expert_offsets is None else 1
    Tg = Tn // G
    C = capacity if capacity is not None else \
        max(int(Tg * K / E * cfg.capacity_factor), 8)
    xg = x.reshape(G, Tg, d)                                   # B-major
    xg = constrain(xg, "batch", None, None)

    gate_logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32),
                             bp["router"]["w"])
    gates = jax.nn.softmax(gate_logits, axis=-1)               # (G, T, E)
    topv, topi = jax.lax.top_k(gates, K)                       # (G, T, K)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch): E * sum_e f_e * p_e
    onehot = jax.nn.one_hot(topi, E, dtype=jnp.float32)        # (G,T,K,E)
    f = onehot.sum(2).mean(1)                                  # (G, E)
    p = gates.mean(1)
    aux = E * jnp.sum(f * p, axis=-1).mean()

    # position of each (token, k) within its expert queue, per group
    oh_flat = onehot.reshape(G, Tg * K, E)
    pos = jnp.sum((jnp.cumsum(oh_flat, axis=1) - 1.0) * oh_flat,
                  axis=-1).reshape(G, Tg, K)
    if expert_offsets is None:
        keep = pos < C                                         # capacity
    else:
        # global queue position = carried count + local position; the
        # local position still indexes the scatter buffer (it is < C
        # whenever keep, since offsets >= 0)
        keep = (pos + expert_offsets[topi]) < C
    eid = topi.reshape(G, Tg * K)
    cid = jnp.where(keep, pos, C).reshape(G, Tg * K).astype(jnp.int32)

    # per-group local scatter into (E, C+1, d); slot C = overflow bin
    def scatter_group(xt, e, c):
        tok_rep = jnp.repeat(xt, K, axis=0)                    # (T*K, d)
        return jnp.zeros((E, C + 1, d), x.dtype).at[e, c].add(tok_rep)

    buf = jax.vmap(scatter_group)(xg, eid, cid)                # (G,E,C+1,d)
    buf = constrain(buf, "batch",
                    "model" if cfg.expert_sharding == "ep" else None,
                    None, None)
    ep = bp["experts_ep"] if "experts_ep" in bp else bp["experts_tp"]
    out_buf = _expert_ffn(ep, cfg, buf[:, :, :C])
    out_buf = constrain(out_buf, "batch",
                        "model" if cfg.expert_sharding == "ep" else None,
                        None, None)
    out_buf = jnp.pad(out_buf, ((0, 0), (0, 0), (0, 1), (0, 0)))

    # gather back with combine weights, per group
    def gather_group(ob, e, c, w):
        y = ob[e, c]                                           # (T*K, d)
        return (y * w[:, None]).reshape(Tg, K, d).sum(1)

    w = (topv.reshape(G, Tg * K)
         * keep.reshape(G, Tg * K)).astype(x.dtype)
    y = jax.vmap(gather_group)(out_buf, eid, cid, w)           # (G, Tg, d)

    if cfg.num_shared_experts:
        sh = _expert_ffn(bp["shared"], cfg,
                         jnp.broadcast_to(
                             xg.reshape(1, Tn, d),
                             (cfg.num_shared_experts, Tn, d)))
        y = y + sh.sum(0).reshape(G, Tg, d)
    if expert_offsets is not None:
        return (y.reshape(B, S, d), aux,
                expert_offsets + oh_flat[0].sum(axis=0))
    return y.reshape(B, S, d), aux


def _block_fwd(bp, cfg: ArchConfig, x, positions):
    # sequence-parallel residual stream (see models/transformer.py)
    h, _ = L.apply_attention(bp["attn"], cfg, L.rms_norm(x, bp["ln1"]),
                             positions=positions, causal=True)
    x = x + constrain_seq(h, cfg.seq_parallel)
    x = constrain_seq(x, cfg.seq_parallel)
    y, aux = moe_ffn(bp, cfg, L.rms_norm(x, bp["ln2"]))
    x = x + constrain_seq(y, cfg.seq_parallel)
    x = constrain_seq(x, cfg.seq_parallel)
    return x, aux


def forward(params, cfg: ArchConfig, tokens: jax.Array,
            prefix_embeds: Optional[jax.Array] = None):
    x = L.apply_embed(params["embed"], tokens)
    x = constrain(x, "batch", None, None)
    x = constrain_seq(x, cfg.seq_parallel)
    positions = jnp.arange(tokens.shape[1])[None, :]

    def scan_step(carry, bp):
        x = carry
        if cfg.remat:
            y, aux = jax.checkpoint(
                lambda b, xx: _block_fwd(b, cfg, xx, positions),
                prevent_cse=False)(bp, x)
        else:
            y, aux = _block_fwd(bp, cfg, x, positions)
        return y, aux

    g = cfg.remat_group
    if cfg.scan_layers and cfg.remat and g and cfg.num_layers % g == 0:
        # hierarchical remat (see models/transformer.py)
        grouped = jax.tree.map(
            lambda a: a.reshape(cfg.num_layers // g, g, *a.shape[1:]),
            params["blocks"])

        def outer_step(x, bps):
            def inner(xx, bp):
                y, aux = _block_fwd(bp, cfg, xx, positions)
                return y, aux

            y, auxes = jax.checkpoint(
                lambda b, xx: jax.lax.scan(inner, xx, b),
                prevent_cse=False)(bps, x)
            return y, auxes.mean()

        x, auxes = jax.lax.scan(outer_step, x, grouped)
    else:
        x, auxes = jax.lax.scan(scan_step, x, params["blocks"])
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, auxes.mean()


def nll_loss(params, cfg: ArchConfig, batch: dict, key: jax.Array,
             aux_weight: float = 0.01):
    hidden, aux = forward(params, cfg, batch["tokens"])
    head = params["head"]
    if "q" in head:
        eps = jax.random.normal(key, head["q"].mu.shape, jnp.float32)
        w = head["q"].sample_with_eps(eps)
        logits = jnp.dot(hidden, w.astype(hidden.dtype),
                         preferred_element_type=jnp.float32)
    else:
        logits = L.head_logits_mean(head, hidden, cfg)
    if cfg.logits_softcap:
        logits = cfg.logits_softcap * jnp.tanh(logits / cfg.logits_softcap)
    logits = constrain(logits, "batch", None, "model")
    labels = batch["labels"]
    valid = labels >= 0
    lab = jnp.where(valid, labels, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    tok_nll = -jnp.take_along_axis(logp, lab[..., None], axis=-1)[..., 0]
    nll = jnp.where(valid, tok_nll, 0.0).sum() / jnp.maximum(valid.sum(), 1)
    acc = ((logits.argmax(-1) == labels) & valid).sum() / \
        jnp.maximum(valid.sum(), 1)
    return nll + aux_weight * aux, {"accuracy": acc, "aux_loss": aux}


# ---------------------------------------------------------------------------
# serving (decode with per-token MoE routing)
# ---------------------------------------------------------------------------

make_cache = T.make_cache  # same KV cache layout


def prefill(params, cfg: ArchConfig, tokens: jax.Array, max_len: int):
    """MoE prefill: rerun forward collecting kv (same trick as dense)."""
    x = L.apply_embed(params["embed"], tokens)
    positions = jnp.arange(tokens.shape[1])[None, :]

    def scan_step(x, bp):
        h, kv = L.apply_attention(bp["attn"], cfg,
                                  L.rms_norm(x, bp["ln1"]),
                                  positions=positions, causal=True)
        x = x + h
        y, _ = moe_ffn(bp, cfg, L.rms_norm(x, bp["ln2"]))
        return x + y, kv

    x, kvs = jax.lax.scan(scan_step, x, params["blocks"])
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    S = tokens.shape[1]
    k, v = kvs
    pad = max_len - S
    k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    return x[:, -1], {"k": k, "v": v,
                      "len": jnp.full((tokens.shape[0],), S, jnp.int32)}


def prefill_chunk(params, cfg: ArchConfig, tokens: jax.Array, cache: dict,
                  slot: jax.Array, offset: jax.Array, new_len: jax.Array,
                  span: int, expert_offsets: jax.Array):
    """Chunked MoE prefill step (see transformer.prefill_chunk).

    ``expert_offsets``: (L, E) f32 per-layer running expert assignment
    counts, threaded by the engine across chunks so capacity drops match
    the single batch dispatch bit for bit; the capacity itself is pinned
    to what the full ``span``-token prompt computes.  Returns
    (cache, new_expert_offsets)."""
    E, K = cfg.num_experts, cfg.top_k
    C = max(int(span * K / E * cfg.capacity_factor), 8)
    row = jax.lax.dynamic_slice_in_dim(cache["block_table"], slot, 1, 0)
    x = L.apply_embed(params["embed"], tokens)

    def scan_step(x, bpkv):
        bp, kp, vp, off = bpkv
        h, (kp, vp) = L.apply_attention_chunk(
            bp["attn"], cfg, L.rms_norm(x, bp["ln1"]),
            kv_pools=(kp, vp), block_row=row, offset=offset, span=span)
        x = x + h
        y, _, off2 = moe_ffn(bp, cfg, L.rms_norm(x, bp["ln2"]),
                             expert_offsets=off, capacity=C)
        return x + y, (kp, vp, off2)

    _, (kps, vps, offs) = jax.lax.scan(
        scan_step, x,
        (params["blocks"], cache["k"], cache["v"], expert_offsets))
    cache = dict(cache, k=kps, v=vps,
                 len=cache["len"].at[slot].set(new_len))
    return cache, offs


def decode_hidden(params, cfg: ArchConfig, token: jax.Array, cache: dict):
    """The KV-writing decode body (see transformer.decode_hidden)."""
    x = L.apply_embed(params["embed"], token[:, None])
    cache_len = cache["len"]
    block_table = cache.get("block_table")     # paged layout marker
    # (read path per cfg.decode_attn: gather or block-sparse kernel)

    def scan_step(x, bpkv):
        bp, kv = bpkv
        pos = jnp.reshape(cache_len, (-1, 1))
        h, new_kv = L.apply_attention(
            bp["attn"], cfg, L.rms_norm(x, bp["ln1"]), positions=pos,
            kv_cache=(kv["k"], kv["v"]), cache_len=cache_len,
            block_table=block_table)
        x = x + h
        y, _ = moe_ffn(bp, cfg, L.rms_norm(x, bp["ln2"]))
        return x + y, {"k": new_kv[0], "v": new_kv[1]}

    x, new_kvs = jax.lax.scan(
        scan_step, x, (params["blocks"], {"k": cache["k"], "v": cache["v"]}))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x[:, 0], {"k": new_kvs["k"], "v": new_kvs["v"],
                     "len": cache_len + 1}


def decode_step(params, cfg: ArchConfig, token: jax.Array, cache: dict,
                key: jax.Array):
    hidden, new_cache = decode_hidden(params, cfg, token, cache)
    return U.head_outputs(params, cfg, hidden, cache["len"], key), \
        new_cache
