"""Shared model layers: norms, RoPE, flash-style attention, MLPs, heads.

Pure-functional: ``init_*`` builds param pytrees (plain dicts), ``apply``
functions are jit/scan/remat friendly.  All matmuls keep a bf16 storage /
f32 accumulation policy via ``preferred_element_type``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.bayesian import GaussianVariational
# serving-TP seam: no-op unless launch.engine.runner set a serve mesh —
# each call site sits DIRECTLY on a sharded producer (column-parallel
# matmul outputs; the kv-head-sharded attention read before wo), so the
# forced all-gather (pure data movement) replicates the operand before
# any elementwise tail or contraction can absorb the shard; that
# producer-adjacent placement is what keeps sharded decode bitwise
# equal to the unsharded reference (see partition.gather_rep)
from repro.sharding.partition import gather_rep


def dtype_of(cfg: ArchConfig):
    return jnp.dtype(cfg.param_dtype)


# --------------------------------------------------------------------------
# primitives
# --------------------------------------------------------------------------

def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: (..., S) int32."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def _mm(x, w):
    # output dtype == activation dtype: the MXU still accumulates f32
    # internally, but the PARTIAL-SUM output of sharded contractions is
    # bf16, so GSPMD's row-parallel all-reduces move bf16 not f32
    # (2x collective bytes; Megatron's 'bf16 reduce' — §Perf/grok it.5).
    return jnp.dot(x, w, preferred_element_type=x.dtype)


def he_init(key, shape, fan_in, dtype):
    return (jax.random.normal(key, shape, jnp.float32)
            / jnp.sqrt(float(max(fan_in, 1)))).astype(dtype)


# --------------------------------------------------------------------------
# flash-style chunked attention (pure jnp online softmax)
# --------------------------------------------------------------------------

def _attn_chunk_spec(nq: int, B: int, H: int):
    """Sharding for the (nq, B, qc, H, D) q-chunk stack.

    Heads shard over 'model' when they divide; otherwise the q-CHUNK axis
    takes the model axis (sequence-parallel attention).  GQA archs whose
    head counts don't divide the 16-way model axis (qwen2-7b: 28H) force
    GSPMD into per-tile score all-reduces under head sharding — the
    chunk-parallel layout keeps every score tile device-local
    (EXPERIMENTS.md §Perf/qwen2_7b-prefill).
    """
    from repro.sharding.partition import get_mesh
    mesh = get_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return None
    msize = mesh.shape["model"]
    if H % msize == 0:
        return (None, "batch", None, "model", None)
    if nq % msize == 0:
        return ("model", "batch", None, None, None)
    return (None, "batch", None, None, None)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, q_chunk: int = 512,
                    kv_chunk: int = 1024,
                    q_offset: int = 0) -> jax.Array:
    """Memory-bounded attention (flash-style online softmax).

    q: (B, Sq, H, D); k/v: (B, Sk, Hkv, D) with H % Hkv == 0 (GQA).
    Online-softmax over kv chunks (sequential scan), VMAPPED over q
    chunks — q chunks are independent, so the chunk axis is shardable
    (sequence parallelism) and XLA may batch it.  Peak score buffer is
    (chunks_local, B, H, q_chunk, kv_chunk).

    The whole body runs under ``jax.named_scope('fused_attention')``: on
    TPU this region maps to the Pallas kernel
    ``kernels/flash_attention.py`` (same tiling, VMEM-resident score
    tiles); the roofline accounting uses the scope to model the fused
    kernel's HBM traffic (launch.hlo_cost skip_byte_scopes).

    ``q_offset``: absolute position of q[0] (prefill continuation).
    """
    from repro.sharding.partition import constrain
    B, Sq, H, D = q.shape
    _, Sk, Hkv, _ = k.shape
    rep = H // Hkv
    scale = 1.0 / jnp.sqrt(jnp.float32(D))
    qc = min(q_chunk, Sq)
    kc = min(kv_chunk, Sk)
    # pad to chunk multiples
    pq = (-Sq) % qc
    pk = (-Sk) % kc
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    nq, nk = qp.shape[1] // qc, kp.shape[1] // kc
    qb = qp.reshape(B, nq, qc, H, D).transpose(1, 0, 2, 3, 4)
    kb = kp.reshape(B, nk, kc, Hkv, D).transpose(1, 0, 2, 3, 4)
    vb = vp.reshape(B, nk, kc, Hkv, D).transpose(1, 0, 2, 3, 4)
    spec = _attn_chunk_spec(nq, B, H)
    if spec is not None:
        qb = constrain(qb, *spec)

    with jax.named_scope("fused_attention"):
        def q_step(qi, blk):                               # (), (B,qc,H,D)
            qpos = q_offset + qi * qc + jnp.arange(qc)

            def kv_step(carry, kj_blks):
                m, l, acc = carry
                kj, kblk, vblk = kj_blks
                kk = jnp.repeat(kblk, rep, axis=2)         # (B,kc,H,D)
                vv = jnp.repeat(vblk, rep, axis=2)
                s = jnp.einsum("bqhd,bkhd->bhqk", blk, kk,
                               preferred_element_type=jnp.float32) * scale
                kp_abs = kj * kc + jnp.arange(kc)
                mask = kp_abs < Sk
                if causal:
                    mask = mask[None, :] & \
                        (kp_abs[None, :] <= qpos[:, None])
                else:
                    mask = jnp.broadcast_to(mask[None, :], (qc, kc))
                s = jnp.where(mask[None, None], s, -jnp.inf)
                m2 = jnp.maximum(m, s.max(axis=-1))
                # guard rows with no valid keys yet
                m2s = jnp.where(jnp.isinf(m2), 0.0, m2)
                p = jnp.exp(s - m2s[..., None])
                p = jnp.where(mask[None, None], p, 0.0)
                corr = jnp.where(jnp.isinf(m), 0.0, jnp.exp(m - m2s))
                l2 = l * corr + p.sum(axis=-1)
                acc2 = acc * corr[..., None] + jnp.einsum(
                    "bhqk,bkhd->bhqd", p, vv,
                    preferred_element_type=jnp.float32)
                return (m2, l2, acc2), None

            m0 = jnp.full((B, H, qc), -jnp.inf, jnp.float32)
            l0 = jnp.zeros((B, H, qc), jnp.float32)
            a0 = jnp.zeros((B, H, qc, D), jnp.float32)
            (m, l, acc), _ = jax.lax.scan(
                kv_step, (m0, l0, a0), (jnp.arange(nk), kb, vb))
            out = acc / jnp.maximum(l, 1e-20)[..., None]
            return out.transpose(0, 2, 1, 3).astype(q.dtype)

        outs = jax.vmap(q_step)(jnp.arange(nq), qb)        # (nq,B,qc,H,D)

    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, nq * qc, H, D)
    return out[:, :Sq]


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cache_len: jax.Array) -> jax.Array:
    """Single-token attention against a (B, S, Hkv, D) cache.

    q: (B, 1, H, D); cache_len: () or (B,) number of valid cache slots.
    GQA via grouped einsum — NOT jnp.repeat, which would materialize the
    KV cache rep x (H/Hkv-fold HBM read amplification at decode).

    MHA (rep == 1) pads the replica axis to two rows (one zero row,
    discarded after): XLA lowers a 1-row contraction through a
    matrix-vector emitter whose f32 association differs from the >= 2
    row gemm, and the block-sparse decode kernel — which reduces per
    (slot, kv-head) tile and is bit-exact against this function — can
    only reproduce the gemm form.  Padding keeps BOTH paths on one
    canonical association for every head layout; rep >= 2 bits are
    untouched (tests/test_paged_attention.py).
    """
    B, S, Hkv, D = k_cache.shape
    H = q.shape[2]
    rep = H // Hkv
    # serve-TP: q arrives head-sharded (columns of wq).  rep is a FREE
    # dim of the grouped dot below, so a shard would shrink the local
    # row count and flip XLA between the gemm and matrix-vector
    # emitters — the exact association split this function's rep==1
    # padding exists to prevent.  All-gather q (pure data movement);
    # the kv-head axis g is a BATCH dim of the dot, so a kv-head-
    # sharded cache keeps the per-row reduction shape and stays exact.
    q = gather_rep(q)
    qg = q.reshape(B, 1, Hkv, rep, D)
    if rep == 1:
        qg = jnp.concatenate([qg, jnp.zeros_like(qg)], axis=3)
    s = jnp.einsum("bqgrd,bkgd->bgrqk", qg, k_cache,
                   preferred_element_type=jnp.float32) / jnp.sqrt(
                       jnp.float32(D))
    pos = jnp.arange(S)
    mask = pos[None, :] < jnp.reshape(cache_len, (-1, 1))
    s = jnp.where(mask[:, None, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bgrqk,bkgd->bqgrd", p, v_cache,
                     preferred_element_type=jnp.float32)
    if rep == 1:
        out = out[:, :, :, :1]
    return out.reshape(B, 1, H, D).astype(q.dtype)


# --------------------------------------------------------------------------
# paged KV cache: (slot, logical_pos) -> (block, offset) indirection
# --------------------------------------------------------------------------

def paged_table_width(max_len: int, kv_block: int) -> int:
    """Block-table width MB: blocks needed to span max_len tokens."""
    return -(-max_len // kv_block)


def init_block_table(batch: int, max_len: int, kv_block: int) -> jax.Array:
    """Fresh all-unmapped (-1) per-slot block table."""
    return jnp.full((batch, paged_table_width(max_len, kv_block)), -1,
                    jnp.int32)


def paged_scatter(pool: jax.Array, block_table: jax.Array,
                  lens: jax.Array, new: jax.Array) -> jax.Array:
    """Scatter per-slot KV entries into a global block pool.

    pool: (NB, BS, ...) physical blocks of BS tokens each;
    block_table: (B, MB) int32, logical block j of slot b lives in
    physical block ``block_table[b, j]`` (-1 = unmapped);
    lens: (B,) current logical depth per slot; new: (B, S, ...) entries
    for logical positions ``lens[b] + [0, S)``.

    Writes to unmapped (-1) or out-of-table logical positions are
    DROPPED — the paged analog of the dense layout's out-of-bounds
    scatter drop, and what makes post-eviction junk steps harmless (an
    evicted slot's table row is all -1).
    """
    BS = pool.shape[1]
    MB = block_table.shape[1]
    B, S = new.shape[:2]
    idx = lens[:, None] + jnp.arange(S)[None, :]            # (B, S) logical
    tbl = idx // BS
    rows = jnp.arange(B)[:, None]
    phys = jnp.where(tbl < MB,
                     block_table[rows, jnp.minimum(tbl, MB - 1)], -1)
    # sentinel must be OOB-positive: jnp wraps negative indices
    # numpy-style BEFORE the mode="drop" check, so -1 would silently hit
    # the last physical block instead of dropping
    phys = jnp.where(phys < 0, pool.shape[0], phys)
    return pool.at[phys, idx % BS].set(new, mode="drop")


def copy_block(pool: jax.Array, src: jax.Array, dst: jax.Array) -> jax.Array:
    """Copy-on-write: duplicate physical block ``src`` into ``dst``.

    pool: (NB, BS, ...).  The serving engine calls this (vmapped over
    the layer axis of every paged KV leaf) when a slot is about to
    scatter into a block it shares with the prefix cache: the slot's
    table entry is swapped to ``dst`` host-side and the divergent write
    lands in the copy, leaving the cached original untouched.
    """
    return pool.at[dst].set(pool[src])


def paged_gather(pool: jax.Array, block_table: jax.Array) -> jax.Array:
    """Gather each slot's logical KV strip from the block pool.

    pool: (NB, BS, ...); block_table: (B, MB).  Returns (B, MB*BS, ...)
    — the dense logical view attention reads.  The gather is
    block-granular (one index per block, not per token: logical position
    j lives at (table[j // BS], j % BS), so whole blocks move
    contiguously).  Unmapped entries gather block 0 — which may be a
    prefix-cache-OWNED block holding another request's tokens — so
    callers must mask by ``mapped_span``, not raw ``cache_len``: a
    slot whose depth outruns its mapped prefix (an evicted slot's junk
    steps) would otherwise feed cached bytes into its softmax
    (tests/test_paged_attention.py::TestUnmappedMasking).
    """
    g = pool[jnp.maximum(block_table, 0)]          # (B, MB, BS, ...)
    return g.reshape(g.shape[0], -1, *pool.shape[2:])


def mapped_span(block_table: jax.Array, block_size: int,
                cache_len: jax.Array) -> jax.Array:
    """Readable depth per slot: ``cache_len`` clamped to the tokens the
    table's leading mapped blocks actually span.

    block_table: (B, MB); cache_len: () or (B,).  Mapped entries always
    form a PREFIX of a row (admission and grants fill left to right,
    CoW swaps in place, eviction wipes the whole row), so the clamp
    ``min(cache_len, leading_mapped * block_size)`` masks exactly the
    positions whose logical block is unmapped.  For live slots the
    grant covers the depth and this is the identity; it only bites on
    junk slots (all ``-1`` after eviction, depth still advancing) whose
    ``paged_gather`` fallback would otherwise read physical block 0 —
    potentially prefix-cache-owned bytes — below ``cache_len``.
    """
    mapped = (block_table >= 0).astype(jnp.int32)
    leading = jnp.cumprod(mapped, axis=1).sum(axis=1)
    return jnp.minimum(jnp.broadcast_to(jnp.reshape(cache_len, (-1,)),
                                        (block_table.shape[0],)),
                       leading * block_size)


# --------------------------------------------------------------------------
# attention block (GQA, optional QKV bias, RoPE)
# --------------------------------------------------------------------------

def init_attention(key, cfg: ArchConfig, d_model: Optional[int] = None):
    d = d_model or cfg.d_model
    hd, H, Hkv = cfg.head_dim, cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 4)
    dt = dtype_of(cfg)
    p = {
        "wq": he_init(ks[0], (d, H * hd), d, dt),
        "wk": he_init(ks[1], (d, Hkv * hd), d, dt),
        "wv": he_init(ks[2], (d, Hkv * hd), d, dt),
        "wo": he_init(ks[3], (H * hd, d), H * hd, dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dt)
        p["bk"] = jnp.zeros((Hkv * hd,), dt)
        p["bv"] = jnp.zeros((Hkv * hd,), dt)
    return p


def apply_attention(p, cfg: ArchConfig, x: jax.Array, *,
                    positions: jax.Array, causal: bool = True,
                    kv_cache: Optional[tuple] = None,
                    cache_len: Optional[jax.Array] = None,
                    block_table: Optional[jax.Array] = None,
                    cross_kv: Optional[tuple] = None):
    """Returns (out, new_kv) where new_kv is the updated (k, v) cache slot
    content for decode, or the computed (k, v) for prefill, or None.

    ``block_table`` selects the paged-KV layout: ``kv_cache`` is then a
    pair of global block POOLS (NB, BS, Hkv, D) instead of per-slot
    strips (B, S, Hkv, D), and reads/writes go through the
    (slot, logical_pos) -> (block, offset) indirection of
    ``paged_scatter`` / ``paged_gather``.  Bit-exact against the dense
    layout when the logical span MB*BS equals the dense max_len: masked
    positions differ only in garbage that ``decode_attention`` replaces
    with -inf before the softmax either way (positions past the mapped
    prefix included — ``mapped_span`` clamps the readable depth).

    ``cfg.decode_attn`` picks the paged decode read path:
    ``'gather'`` (the bit-exact reference) materializes the full
    logical strip; ``'kernel'`` runs the block-sparse Pallas kernel
    (``kernels/paged_attention.py``) that reads only mapped, in-depth
    blocks straight from the pool — same bits, HBM reads scaling with
    ``cache_len`` (tests/test_paged_attention.py)."""
    B, S, d = x.shape
    H, Hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = _mm(x, p["wq"])
    if "bq" in p:
        q = q + p["bq"]
    q = q.reshape(B, S, H, hd)

    if cross_kv is not None:
        k, v = cross_kv
        out = flash_attention(q, k, v, causal=False,
                              q_chunk=cfg.attn_q_chunk,
                              kv_chunk=cfg.attn_kv_chunk)
        new_kv = None
    else:
        k = _mm(x, p["wk"])
        v = _mm(x, p["wv"])
        if "bk" in p:
            k, v = k + p["bk"], v + p["bv"]
        # serve-TP: wk/wv column shards land on hd after the reshape —
        # the CONTRACTED dim of the score dot — and a q-side all-gather
        # leaves that shard as the dot's only sharding, so GSPMD would
        # partial-sum over D shards and all-reduce (a re-associated
        # float reduction).  Gather adjacent to the projection instead:
        # pure data movement, and every attention operand downstream is
        # replicated (or kv-head/batch-sharded via the cache, which
        # never re-associates a contraction).
        k = gather_rep(k).reshape(B, S, Hkv, hd)
        v = gather_rep(v).reshape(B, S, Hkv, hd)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        if kv_cache is not None:
            kc, vc = kv_cache
            # cache_len: () -> every row appends at the same depth;
            # (B,) -> slot-indexed cache, each row writes its own offset
            # (continuous batching: slots admitted at different times sit
            # at different depths).  The scatter handles any S (chunked
            # appends included); rows already at capacity land out of
            # bounds and are dropped.
            lens = jnp.broadcast_to(jnp.reshape(cache_len, (-1,)), (B,))
            if block_table is not None:
                kc = paged_scatter(kc, block_table, lens, k)
                vc = paged_scatter(vc, block_table, lens, v)
                if cfg.decode_attn == "kernel" and S == 1:
                    # block-sparse Pallas kernel: reads only mapped,
                    # in-depth blocks from the pool — HBM traffic
                    # scales with cache_len, not the MB*BS span; the
                    # gather path below stays the bit-exact reference
                    from repro.kernels.ops import paged_decode_attention
                    out = paged_decode_attention(q, kc, vc, block_table,
                                                 lens + S)
                else:
                    # readable depth clamped to the mapped prefix so an
                    # unmapped entry's block-0 gather fallback never
                    # reaches the softmax (block 0 may be owned by the
                    # prefix cache)
                    eff = mapped_span(block_table, kc.shape[1], lens + S)
                    out = decode_attention(q,
                                           paged_gather(kc, block_table),
                                           paged_gather(vc, block_table),
                                           eff)
            else:
                rows = jnp.arange(B)[:, None]
                idx = lens[:, None] + jnp.arange(S)[None, :]
                kc = kc.at[rows, idx].set(k, mode="drop")
                vc = vc.at[rows, idx].set(v, mode="drop")
                out = decode_attention(q, kc, vc, lens + S)
            new_kv = (kc, vc)
        else:
            out = flash_attention(q, k, v, causal=causal,
                                  q_chunk=cfg.attn_q_chunk,
                                  kv_chunk=cfg.attn_kv_chunk,
                                  q_offset=0)
            new_kv = (k, v)
    out = out.reshape(B, S, H * hd)
    return _mm(gather_rep(out), p["wo"]), new_kv


def apply_attention_suffix(p, cfg: ArchConfig, x: jax.Array, *,
                           prefix_kv: tuple, prefix_len: int,
                           positions: jax.Array):
    """Prefill continuation: attention for the UNCACHED suffix of a
    prompt whose first ``prefix_len`` positions already live in the KV
    cache (prefix-cache hit).

    x: (B, S, d) suffix hidden states for absolute positions
    ``prefix_len + [0, S)``; ``prefix_kv``: (k, v) logical strips
    (B, prefix_len, Hkv, D) — exactly the cached span, sliced by the
    caller; ``positions``: (B or 1, S) absolute RoPE positions
    (``prefix_len + arange(S)``).  ``prefix_len`` must be a STATIC
    Python int (one compile per hit length), not a traced value.

    Returns (out, (k_suffix, v_suffix)) — the suffix K/V the caller
    scatters into the pool at logical offset ``prefix_len``.

    BIT-EXACTNESS: this runs the same ``flash_attention`` code path as
    the cold full-prompt prefill, attending over exactly
    ``prefix_len + S`` keys — cached prefix concatenated with the
    suffix K/V, i.e. the identical operand values at the identical
    indices AND the identical reduction extent as the cold path's
    suffix rows.  Equal reduction lengths matter: XLA's lane/remainder
    handling associates a k-axis sum differently for different key
    counts, so attending over a longer padded-and-masked strip would
    drift in the last ulp even though masked positions contribute
    exact zeros.  Queries are row-independent, so the q-chunk geometry
    differing from the cold path is irrelevant.  Tested bitwise in
    tests/test_prefix_cache.py.
    """
    B, S, d = x.shape
    H, Hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = _mm(x, p["wq"])
    if "bq" in p:
        q = q + p["bq"]
    q = q.reshape(B, S, H, hd)
    k = _mm(x, p["wk"])
    v = _mm(x, p["wv"])
    if "bk" in p:
        k, v = k + p["bk"], v + p["bv"]
    # serve-TP: gather next to the projection (see apply_attention)
    k = gather_rep(k).reshape(B, S, Hkv, hd)
    v = gather_rep(v).reshape(B, S, Hkv, hd)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    kc, vc = prefix_kv
    ks = jnp.concatenate([kc.astype(k.dtype), k], axis=1)
    vs = jnp.concatenate([vc.astype(v.dtype), v], axis=1)
    out = flash_attention(q, ks, vs, causal=True,
                          q_chunk=cfg.attn_q_chunk,
                          kv_chunk=cfg.attn_kv_chunk,
                          q_offset=prefix_len)
    out = out.reshape(B, S, H * hd)
    return _mm(gather_rep(out), p["wo"]), (k, v)


def apply_attention_chunk(p, cfg: ArchConfig, x: jax.Array, *,
                          kv_pools: tuple, block_row: jax.Array,
                          offset: jax.Array, span: int):
    """Chunked-prefill attention for ONE slot against its paged KV pool.

    x: (1, S, d) hidden states of a prompt chunk occupying absolute
    positions ``offset + [0, S)``; ``kv_pools``: (k, v) block pools
    (NB, BS, Hkv, D); ``block_row``: (1, MB) the slot's table row;
    ``offset``: TRACED int32 scalar (chunk progress is data, not shape);
    ``span``: STATIC token extent of the whole prompt's attention
    reduction — the bucketed width W for padding-safe families, the
    exact prompt length for exact-extent ones.

    The chunk's K/V are scattered into the pool FIRST, then the strip is
    read back over ``span`` tokens and attended with the same
    ``flash_attention`` (or the multi-query block-sparse kernel when
    ``cfg.decode_attn == 'kernel'``) the batch prefill uses.

    BIT-EXACTNESS vs batch prefill: every chunk reduces over the SAME
    static extent ``span`` that the batch path uses for the whole
    prompt, with not-yet-written positions causally masked — masked
    positions contribute exact zeros regardless of the junk they hold,
    and equal reduction extents keep XLA's k-axis sum association
    identical (see ``apply_attention_suffix``).  Q rows are independent,
    so splitting them across chunks is free.  Tested bitwise in
    tests/test_chunked_prefill.py.
    """
    B, S, d = x.shape
    H, Hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = _mm(x, p["wq"])
    if "bq" in p:
        q = q + p["bq"]
    q = q.reshape(B, S, H, hd)
    k = _mm(x, p["wk"])
    v = _mm(x, p["wv"])
    if "bk" in p:
        k, v = k + p["bk"], v + p["bv"]
    # serve-TP: gather next to the projection (see apply_attention)
    k = gather_rep(k).reshape(B, S, Hkv, hd)
    v = gather_rep(v).reshape(B, S, Hkv, hd)
    positions = offset + jnp.arange(S)[None, :]
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    kc, vc = kv_pools
    lens = jnp.broadcast_to(jnp.reshape(offset, (-1,)), (B,))
    kc = paged_scatter(kc, block_row, lens, k)
    vc = paged_scatter(vc, block_row, lens, v)
    BS = kc.shape[1]
    nb = -(-span // BS)
    if cfg.decode_attn == "kernel":
        from repro.kernels.ops import paged_prefill_attention
        out = paged_prefill_attention(q, kc, vc, block_row[:, :nb],
                                      offset, span=span,
                                      kv_chunk=cfg.attn_kv_chunk)
    else:
        ks = paged_gather(kc, block_row[:, :nb])[:, :span]
        vs = paged_gather(vc, block_row[:, :nb])[:, :span]
        out = flash_attention(q, ks, vs, causal=True,
                              q_chunk=cfg.attn_q_chunk,
                              kv_chunk=cfg.attn_kv_chunk,
                              q_offset=offset)
    out = out.reshape(B, S, H * hd)
    return _mm(gather_rep(out), p["wo"]), (kc, vc)


def make_cross_kv(p, cfg: ArchConfig, enc_out: jax.Array):
    """Precompute cross-attention K/V from encoder output (no RoPE)."""
    B, S, _ = enc_out.shape
    Hkv, hd = cfg.num_kv_heads, cfg.head_dim
    # serve-TP: gather next to the projection (see apply_attention)
    k = gather_rep(_mm(enc_out, p["wk"])).reshape(B, S, Hkv, hd)
    v = gather_rep(_mm(enc_out, p["wv"])).reshape(B, S, Hkv, hd)
    return k, v


# --------------------------------------------------------------------------
# MLP (gated silu/gelu or nemotron squared-ReLU)
# --------------------------------------------------------------------------

def init_mlp(key, cfg: ArchConfig, d_model: Optional[int] = None,
             d_ff: Optional[int] = None):
    d = d_model or cfg.d_model
    ff = d_ff or cfg.d_ff
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 3)
    if cfg.mlp_activation == "relu2":
        return {"w1": he_init(ks[0], (d, ff), d, dt),
                "w2": he_init(ks[1], (ff, d), ff, dt)}
    return {"w1": he_init(ks[0], (d, ff), d, dt),       # gate
            "w3": he_init(ks[1], (d, ff), d, dt),       # up
            "w2": he_init(ks[2], (ff, d), ff, dt)}      # down


def apply_mlp(p, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    # serve-TP: gather DIRECTLY on each column-sharded matmul output,
    # before the activation.  A gather placed later — on act(g)*u just
    # ahead of the down-projection — leaves dot(all-gather(h), w2) in
    # the module, which XLA rewrites into per-shard partial dots plus
    # an all-reduce over the ff contraction: a re-associated float sum
    # that breaks bitwise parity with the unsharded engine.  With the
    # gather adjacent to the producer the down-projection sees a plain
    # replicated operand and stays a single local gemm.
    if cfg.mlp_activation == "relu2":
        h = gather_rep(_mm(x, p["w1"]))
        h = jnp.square(jax.nn.relu(h))
        return _mm(h, p["w2"])
    g = gather_rep(_mm(x, p["w1"]))
    u = gather_rep(_mm(x, p["w3"]))
    act = jax.nn.silu if cfg.mlp_activation == "silu" else jax.nn.gelu
    return _mm(act(g) * u, p["w2"])


# --------------------------------------------------------------------------
# embeddings + (Bayesian) output head
# --------------------------------------------------------------------------

def init_embed(key, cfg: ArchConfig):
    dt = dtype_of(cfg)
    return {"table": he_init(key, (cfg.vocab_size, cfg.d_model),
                             cfg.d_model, dt)}


def apply_embed(p, tokens: jax.Array) -> jax.Array:
    from repro.sharding.partition import constrain
    x = jnp.take(p["table"], tokens, axis=0)
    return constrain(x, "batch", None, None)


def init_head(key, cfg: ArchConfig):
    """Deterministic or Gaussian-variational output projection."""
    if cfg.bayesian_head:
        return {"q": GaussianVariational.init(
            key, (cfg.d_model, cfg.vocab_size), fan_in=cfg.d_model,
            init_sigma=cfg.head_init_sigma, dtype=jnp.float32)}
    return {"w": he_init(key, (cfg.d_model, cfg.vocab_size), cfg.d_model,
                         dtype_of(cfg))}


def head_logits_mean(p, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    """Mean logits (training fwd uses MC draws via head_logits_sampled)."""
    w = p["q"].mu if "q" in p else p["w"]
    # vocab columns are exact per-shard; gather DIRECTLY on the dot
    # output.  A gather placed after the softcap would let GSPMD sink
    # the elementwise ops across the all-gather, parking the gather
    # next to the softmax/entropy V-reductions in
    # uncertainty_from_logits — which XLA then splits into per-shard
    # partial sums, a re-associated reduction that drifts the
    # uncertainty floats off the unsharded reference.
    logits = gather_rep(jnp.dot(x, w.astype(x.dtype),
                                preferred_element_type=jnp.float32))
    if cfg.logits_softcap:
        c = cfg.logits_softcap
        logits = c * jnp.tanh(logits / c)
    return logits


def decode_head_noise(key: jax.Array, cache_len: jax.Array,
                      num_samples: int, vocab: int) -> jax.Array:
    """Per-(slot, depth) operand noise for the Bayesian decode head.

    Returns an (S, B, V) f32 xi tensor where column b is drawn from
    ``fold_in(fold_in(key, b), cache_len[b])`` — slot index and the
    slot's own token depth, NOT the engine's global step.  A slot's
    noise stream is therefore a function of its position alone: two
    schedules that reach the same (slot, depth) through different
    global interleavings (batch vs chunked prefill, a slot paused on a
    block-grant shortfall, different ``--chunk`` sizes) draw identical
    variates, which is what keeps the engine's decode streams bit-exact
    across scheduling policies (tests/test_serve.py,
    tests/test_chunked_prefill.py).
    """
    depths = jnp.broadcast_to(jnp.reshape(cache_len, (-1,)).astype(
        jnp.int32), (cache_len.shape[0] if cache_len.ndim else 1,))
    slots = jnp.arange(depths.shape[0], dtype=jnp.int32)

    def one(slot, depth):
        kb = jax.random.fold_in(jax.random.fold_in(key, slot), depth)
        return jax.random.normal(kb, (num_samples, vocab), jnp.float32)

    return jax.vmap(one, in_axes=(0, 0), out_axes=1)(slots, depths)


def head_logits_sampled(p, x: jax.Array, cfg: ArchConfig,
                        xi: jax.Array) -> jax.Array:
    """One LRT draw of the Bayesian head: x (..., d), xi (..., V).

    This is the jnp form of kernels/lrt_matmul (kernel used on TPU).
    """
    if "q" not in p:
        return head_logits_mean(p, x, cfg)
    q = p["q"]
    x32 = x.astype(jnp.float32)
    # serve-TP: gather each vocab-sharded dot output before the LRT
    # combine (see head_logits_mean for why the gather must sit on the
    # producer, not after the elementwise tail)
    mean = gather_rep(x32 @ q.mu)
    var = gather_rep((x32 * x32) @ (q.sigma ** 2))
    logits = mean + jnp.sqrt(jnp.maximum(var, 0.0)) * xi
    if cfg.logits_softcap:
        c = cfg.logits_softcap
        logits = c * jnp.tanh(logits / c)
    return logits
