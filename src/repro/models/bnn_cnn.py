"""The paper's hand-crafted hybrid BNN (Fig. 3).

DenseNet-style concat skip connections + MobileNetV1 depthwise-separable
(DWS) convolutions, six conv layers + a final linear head.  Exactly ONE
probabilistic block (partial stochasticity, ref. 15): the depthwise 3x3
conv of the marked DWS block — the natural photonic mapping, since each
depthwise channel kernel has 9 weights == the machine's 9 spectral
channels, and full grouping minimizes unique weights ('favoring highly
grouped convolutions', paper §BNN).

Three forward modes:
  * 'surrogate' — training: Gaussian draw + STE quantization + sigma
    clamped to the machine-realizable band (core.surrogate).
  * 'machine'   — prediction on the digital twin: Gamma(M) ASE statistics
    + DAC/ADC quantization, mirroring the paper swapping its surrogate
    for the photonic hardware. On TPU this block routes through
    kernels/bayes_matmul (im2col fusion).
  * 'mean'      — deterministic baseline (MAP network) for ablations.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import entropy as E
from repro.core.bayesian import GaussianVariational
from repro.core.photonic import quantize_ste
from repro.core.surrogate import SurrogateSpec


@dataclasses.dataclass(frozen=True)
class BNNConfig:
    num_classes: int = 7
    in_channels: int = 3
    width: int = 16                 # base channel count
    image_size: int = 28
    mc_samples: int = 10            # paper: N=10
    prob_block: int = 3             # which block carries the variational dw
    init_sigma: float = 0.08


def _conv(key, cin, cout, kh=3, kw=3, groups=1):
    fan = cin // groups * kh * kw
    return (jax.random.normal(key, (cout, cin // groups, kh, kw))
            / jnp.sqrt(float(fan)))


def conv2d(x, w, groups=1, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME", feature_group_count=groups,
        dimension_numbers=("NCHW", "OIHW", "NCHW"))


def init_params(key, cfg: BNNConfig):
    """Six conv layers in four blocks: A(std conv), DWS, DWS(prob), DWS."""
    ks = jax.random.split(key, 12)
    w = cfg.width
    c0 = cfg.in_channels
    p = {}
    # block 0: standard 3x3 conv (1 conv layer)
    p["b0"] = {"w": _conv(ks[0], c0, w)}
    c = w + c0                                     # concat skip
    # blocks 1..3: DWS (2 conv layers each... depthwise + pointwise)
    chans = [w * 2, w * 3, w * 4]
    for i, co in enumerate(chans, start=1):
        kd, kp_ = jax.random.split(ks[i + 1])
        dw = _conv(kd, c, c, groups=c)             # (C, 1, 3, 3)
        if i == cfg.prob_block:
            p[f"b{i}"] = {
                "dw": GaussianVariational(
                    mu=dw, rho=jnp.full(dw.shape,
                                        float(jnp.log(jnp.expm1(
                                            jnp.array(cfg.init_sigma)))))),
                "pw": _conv(kp_, c, co, 1, 1),
            }
        else:
            p[f"b{i}"] = {"dw": dw, "pw": _conv(kp_, c, co, 1, 1)}
        c = co + c                                 # concat skip
    p["head"] = {"w": (jax.random.normal(ks[8], (c, cfg.num_classes))
                       / jnp.sqrt(float(c))),
                 "b": jnp.zeros((cfg.num_classes,))}
    return p


def _dw_weights(q: GaussianVariational, key, mode: str,
                spec: SurrogateSpec):
    """Sample the probabilistic depthwise weights according to mode."""
    if mode == "mean":
        return q.mu
    if mode == "surrogate":
        eps = jax.random.normal(key, q.mu.shape)
        return spec.apply_weight(q, eps)
    if mode == "machine":
        # ASE Gamma(M) statistics at the programmed bandwidth + DAC grid
        sigma = spec.realizable_sigma(q.mu, q.sigma)
        rel = sigma / jnp.maximum(jnp.abs(q.mu), 1e-6)
        m = E.modes_from_bandwidth(E.bandwidth_for_relstd(rel))
        gam = jax.random.gamma(key, m) / m
        eps = (gam - 1.0) * jnp.sqrt(m)
        w = q.mu + sigma * eps
        return quantize_ste(w, spec.machine.dac_bits,
                            spec.machine.weight_range)
    raise ValueError(mode)


def apply(params, cfg: BNNConfig, x: jax.Array, key: jax.Array,
          mode: str = "surrogate",
          spec: SurrogateSpec = SurrogateSpec()) -> jax.Array:
    """x: (B, C, H, W) in [0, 1] -> logits (B, num_classes)."""
    act = jax.nn.gelu
    h = act(conv2d(x, params["b0"]["w"]))
    h = jnp.concatenate([h, x], axis=1)
    h = jax.lax.reduce_window(                    # 2x2 avg pool
        h, 0.0, jax.lax.add, (1, 1, 2, 2), (1, 1, 2, 2), "VALID") / 4.0
    for i in (1, 2, 3):
        bp = params[f"b{i}"]
        cin = h.shape[1]
        if isinstance(bp["dw"], GaussianVariational):
            kd = jax.random.fold_in(key, i)
            dw = _dw_weights(bp["dw"], kd, mode, spec)
            hin = spec.apply_input(jnp.clip(h, -1.0, 1.0)) \
                if mode != "mean" else h
            hd = conv2d(hin, dw, groups=cin)
            if mode != "mean":
                hd = spec.apply_output(hd)        # ADC on the way back
        else:
            hd = conv2d(h, bp["dw"], groups=cin)
        hp = act(conv2d(hd, bp["pw"], 1))         # pointwise 1x1
        h = jnp.concatenate([hp, h], axis=1)
        if i < 3:
            h = jax.lax.reduce_window(
                h, 0.0, jax.lax.add, (1, 1, 2, 2), (1, 1, 2, 2),
                "VALID") / 4.0
    h = h.mean(axis=(2, 3))                        # global average pool
    return h @ params["head"]["w"] + params["head"]["b"]


def mc_predict(params, cfg: BNNConfig, x: jax.Array, key: jax.Array,
               mode: str = "machine",
               spec: SurrogateSpec = SurrogateSpec(),
               entropy: Optional[E.KernelEntropy] = None) -> jax.Array:
    """N stochastic forward passes -> probs (N, B, classes) (paper N=10).

    ``entropy`` selects the seed-driven fast path: the per-sample streams
    derive from ``entropy.seed`` instead of the ambient ``key``, making
    the prediction a pure function of (params, x, seed) — the contract
    the in-kernel TPU entropy path (kernels/bayes_matmul) serves, and
    what lets serving replicas agree without shipping PRNG state.
    """
    if entropy is not None:
        keys = jax.random.split(entropy.key(), cfg.mc_samples)
    else:
        keys = jax.random.split(key, cfg.mc_samples)
    logits = jax.vmap(
        lambda k: apply(params, cfg, x, k, mode=mode, spec=spec))(keys)
    return jax.nn.softmax(logits, axis=-1)


def nll_fn(cfg: BNNConfig, spec: SurrogateSpec = SurrogateSpec()):
    """ELBO-compatible NLL closure for core.svi.elbo_loss."""

    def nll(params, batch, key):
        logits = apply(params, cfg, batch["images"], key,
                       mode="surrogate", spec=spec)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(
            logp, batch["labels"][:, None], axis=-1).mean()
        acc = (logits.argmax(-1) == batch["labels"]).mean()
        return nll, {"accuracy": acc}

    return nll
