"""Continuous-batching uncertainty serving engine.

The deployment analog of the paper's high-throughput trustworthy
inference: a queue of requests is served through a fixed set of decode
slots over one slot-indexed KV cache.  A host-side ``SlotScheduler``
admits queued requests into free slots (batch-1 jitted prefill written
into the slot at its own offset), the inner decode loop is a
``jax.lax.scan`` that generates ``--chunk`` tokens per device call --
carrying the (H, SE, MI) uncertainty triplet and the epistemic/aleatoric
gating flags in the scan carry, one host sync per chunk instead of one
per token -- and slots are evicted on EOS / max-new-tokens and refilled
from the queue.

Each decode step draws ``cfg.mc_samples`` (paper: N=10) samples of the
Bayesian output head -- fused in the uncertainty-head kernel on TPU,
jnp-LRT elsewhere.  Tokens whose MI exceeds ``--mi-threshold`` are
flagged epistemic (the LM analog of the paper's OOD rejection);
high-SE/low-MI tokens are flagged aleatoric (ambiguous continuation).

The pre-engine per-token loop survives as ``decode_loop_reference`` --
the parity oracle (scan decode replays its token stream exactly in
operand-entropy mode for requests admitted at engine start; requests
admitted later draw from the engine's global step stream, so replaying
them needs the same step offset) and the benchmark baseline that
``benchmarks/bench_serve.py`` measures the engine against.

Container-scale: reduced config, debug mesh.  Full-size serving shapes
(prefill_32k / decode_32k / long_500k) are compile-proven by launch.dryrun.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2_1_5b \
      --slots 4 --num-requests 8 --prompt-len 32 --gen-len 16 --chunk 8
"""

from __future__ import annotations

import argparse
import collections
import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config, reduced
from repro.core.entropy import KernelEntropy
from repro.data.synthetic import TokenStreamState, token_batch
from repro.launch import steps as S
from repro.models import registry as M


# ---------------------------------------------------------------------------
# requests + host-side slot scheduler
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Request:
    """One serving request plus its accumulated results."""

    rid: int
    prompt: np.ndarray                    # (S,) int32
    max_new_tokens: int
    t_submit: float = 0.0
    t_finish: float = 0.0
    finish_reason: str = ""
    tokens: list = dataclasses.field(default_factory=list)
    H: list = dataclasses.field(default_factory=list)
    SE: list = dataclasses.field(default_factory=list)
    MI: list = dataclasses.field(default_factory=list)
    p_max: list = dataclasses.field(default_factory=list)
    epistemic_flags: int = 0
    aleatoric_flags: int = 0

    @property
    def latency_s(self) -> float:
        return self.t_finish - self.t_submit


class SlotScheduler:
    """FIFO admission of queued requests into fixed decode slots.

    Pure host-side bookkeeping (no jax): ``admit`` fills free slots in
    slot order from the queue front, ``evict`` frees a slot for reuse.
    """

    def __init__(self, num_slots: int):
        self.slots: list[Optional[Request]] = [None] * num_slots
        self.queue: collections.deque[Request] = collections.deque()

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def admit(self) -> list[tuple[int, Request]]:
        placed = []
        for i, occupant in enumerate(self.slots):
            if occupant is None and self.queue:
                req = self.queue.popleft()
                self.slots[i] = req
                placed.append((i, req))
        return placed

    def evict(self, slot: int) -> Request:
        req = self.slots[slot]
        if req is None:
            raise ValueError(f"evict of empty slot {slot}")
        self.slots[slot] = None
        return req

    def active(self) -> list[tuple[int, Request]]:
        return [(i, r) for i, r in enumerate(self.slots) if r is not None]

    def has_work(self) -> bool:
        return bool(self.queue) or any(r is not None for r in self.slots)


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------

class ServeEngine:
    """Continuous-batching scan-decoded uncertainty engine.

    ``num_slots`` concurrent decode slots over one slot-indexed KV cache
    of depth ``max_len``; ``chunk`` tokens decoded per device call.
    ``entropy`` (KernelEntropy) selects the seeded head-draw stream
    (in-kernel on TPU); None keeps the legacy operand stream.
    """

    def __init__(self, params, cfg, *, num_slots: int, max_len: int,
                 chunk: int = 8, entropy: Optional[KernelEntropy] = None,
                 mi_threshold: float = 0.05, se_threshold: float = 1.0,
                 eos_id: Optional[int] = None):
        self.params = params
        self.cfg = cfg
        self.num_slots = num_slots
        self.max_len = max_len
        self.chunk = chunk
        self.eos_id = eos_id
        self._prefill = jax.jit(
            lambda p, t, m: M.prefill(p, cfg, t, max_len, m))
        self._write = jax.jit(
            lambda c, slot, sub: M.write_slot(cfg, c, slot, sub),
            donate_argnums=(0,))
        self._scan = jax.jit(
            S.build_scan_decode(cfg, entropy=entropy, chunk=chunk,
                                mi_threshold=mi_threshold,
                                se_threshold=se_threshold),
            donate_argnums=(2,))

    def _modality(self, batch: int):
        cfg = self.cfg
        if cfg.family == "encdec":
            from repro.models.encdec import ENC_LEN
            return jnp.zeros((batch, ENC_LEN, cfg.d_model), jnp.float32)
        if cfg.family == "vlm":
            return jnp.zeros((batch, cfg.num_prefix_embeds, cfg.d_model),
                             jnp.float32)
        return None

    def run(self, requests: list[Request]) -> dict:
        """Serve ``requests`` to completion; returns engine metrics.

        One host sync per admission (prefill) and one per decoded chunk
        (the stacked (chunk, B) outputs) -- never per token.
        """
        for r in requests:
            if len(r.prompt) == 0:
                raise ValueError(f"request {r.rid}: empty prompt")
            if r.max_new_tokens < 1:
                raise ValueError(
                    f"request {r.rid}: max_new_tokens must be >= 1")
            if len(r.prompt) + r.max_new_tokens > self.max_len:
                raise ValueError(
                    f"request {r.rid}: prompt {len(r.prompt)} + "
                    f"max_new_tokens {r.max_new_tokens} exceeds the "
                    f"slot capacity max_len={self.max_len}; cache writes "
                    f"past capacity would be dropped silently")
        sched = SlotScheduler(self.num_slots)
        t_start = time.perf_counter()
        for r in requests:
            r.t_submit = time.perf_counter()
            sched.submit(r)

        tok = jnp.zeros((self.num_slots,), jnp.int32)
        cache = M.make_cache(self.cfg, self.num_slots, self.max_len)
        active = jnp.zeros((self.num_slots,), bool)
        flags = {"epistemic": jnp.zeros((self.num_slots,), jnp.int32),
                 "aleatoric": jnp.zeros((self.num_slots,), jnp.int32)}
        step0 = 0
        decode_s = 0.0
        # the jitted prefill compiles once per distinct prompt length;
        # classify each admission's time accordingly so mixed-length
        # traffic doesn't launder recompiles into the steady-state stat
        compile_times: list[float] = []
        steady_times: list[float] = []
        seen_prompt_lens: set[int] = set()
        modality1 = self._modality(1)

        while sched.has_work():
            for slot, req in sched.admit():
                t0 = time.perf_counter()
                _, sub = self._prefill(
                    self.params, jnp.asarray(req.prompt)[None], modality1)
                cache = self._write(cache, jnp.asarray(slot, jnp.int32),
                                    sub)
                tok = tok.at[slot].set(int(req.prompt[-1]))
                active = active.at[slot].set(True)
                flags = {k: v.at[slot].set(0) for k, v in flags.items()}
                jax.block_until_ready(cache)
                dt = time.perf_counter() - t0
                if len(req.prompt) in seen_prompt_lens:
                    steady_times.append(dt)
                else:
                    seen_prompt_lens.add(len(req.prompt))
                    compile_times.append(dt)

            t0 = time.perf_counter()
            tok, cache, flags, ys = self._scan(
                self.params, tok, cache, jnp.asarray(step0, jnp.int32),
                active, flags)
            ys = jax.device_get(ys)            # the chunk's single sync
            decode_s += time.perf_counter() - t0
            step0 += self.chunk

            for slot, req in sched.active():
                for t in range(self.chunk):
                    tk = int(ys["token"][t, slot])
                    req.tokens.append(tk)
                    for name in ("H", "SE", "MI", "p_max"):
                        getattr(req, name).append(float(ys[name][t, slot]))
                    req.epistemic_flags += int(ys["epistemic"][t, slot])
                    req.aleatoric_flags += int(ys["aleatoric"][t, slot])
                    done_eos = self.eos_id is not None and tk == self.eos_id
                    if done_eos or len(req.tokens) >= req.max_new_tokens:
                        req.t_finish = time.perf_counter()
                        req.finish_reason = "eos" if done_eos else "length"
                        sched.evict(slot)
                        active = active.at[slot].set(False)
                        break

        total_s = time.perf_counter() - t_start
        gen_tokens = sum(len(r.tokens) for r in requests)
        lat = np.array([r.latency_s for r in requests]) if requests \
            else np.zeros((1,))
        epi = sum(r.epistemic_flags for r in requests)
        alea = sum(r.aleatoric_flags for r in requests)
        return {
            "requests": requests,
            "num_requests": len(requests),
            "gen_tokens": gen_tokens,
            "total_s": total_s,
            "decode_s": decode_s,
            # first prefill per prompt length includes compilation; the
            # rest are steady-state dispatch
            "prefill_compile_s": float(np.sum(compile_times)),
            "prefill_steady_s": float(np.mean(steady_times))
            if steady_times else 0.0,
            "decode_tok_per_s": gen_tokens / max(decode_s, 1e-9),
            "e2e_tok_per_s": gen_tokens / max(total_s, 1e-9),
            "latency_p50_s": float(np.percentile(lat, 50)),
            "latency_p99_s": float(np.percentile(lat, 99)),
            "epistemic_flags": int(epi),
            "aleatoric_flags": int(alea),
            "flags_per_1k_tokens": {
                "epistemic": 1000.0 * epi / max(gen_tokens, 1),
                "aleatoric": 1000.0 * alea / max(gen_tokens, 1),
            },
            # device-side telemetry from the scan carry: per-slot totals a
            # pure-device driver could read without syncing ys.  Upper-
            # bounds the exact host accounting above (a request finishing
            # mid-chunk keeps counting until its chunk boundary).
            "device_flag_counters": {
                k: np.asarray(v).tolist() for k, v in flags.items()
            },
        }


# ---------------------------------------------------------------------------
# per-token reference loop (parity oracle + benchmark baseline)
# ---------------------------------------------------------------------------

def decode_loop_reference(params, cfg, tokens, gen_len: int, *,
                          entropy: Optional[KernelEntropy] = None,
                          max_len: Optional[int] = None,
                          modality=None, decode_fn=None) -> dict:
    """The pre-engine decode driver: one jitted step + one host sync per
    token over a statically batched prompt matrix.  Scan decode must
    reproduce this loop's token stream exactly in operand-entropy mode
    (same fold_in(base, global_step) noise; tested in test_serve.py).

    ``decode_fn`` lets benchmarks pass a pre-compiled step so the timed
    loop measures steady-state dispatch, not compilation.
    """
    tokens = jnp.asarray(tokens)
    B, P = tokens.shape
    max_len = max_len or P + gen_len
    _, cache = M.prefill(params, cfg, tokens, max_len, modality)
    decode = decode_fn or jax.jit(S.build_decode_step(cfg, entropy=entropy),
                                  donate_argnums=(2,))
    tok = tokens[:, -1]
    rows = {"token": [], "H": [], "SE": [], "MI": [], "p_max": []}
    t0 = time.perf_counter()
    for i in range(gen_len):
        out, cache = decode(params, tok, cache, jnp.asarray(i, jnp.int32))
        tok = out["next_token"]
        rows["token"].append(np.asarray(tok))        # per-token sync
        for k in ("H", "SE", "MI", "p_max"):
            rows[k].append(np.asarray(out[k]))
    decode_s = time.perf_counter() - t0
    return {name: np.stack(vals) for name, vals in rows.items()} | {
        "decode_s": decode_s,
        "decode_tok_per_s": gen_len * B / max(decode_s, 1e-9),
    }


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def make_requests(args, cfg) -> list[Request]:
    stream = TokenStreamState(seed=args.seed, host=0, num_hosts=1)
    toks, _ = token_batch(stream, args.num_requests, args.prompt_len,
                          cfg.vocab_size)
    return [Request(rid=i, prompt=np.asarray(toks[i], np.int32),
                    max_new_tokens=args.gen_len)
            for i in range(args.num_requests)]


def serve(args) -> dict:
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    cfg = dataclasses.replace(cfg, head_entropy=args.entropy)
    params = M.init_params(jax.random.key(args.seed), cfg)

    entropy = KernelEntropy(seed=args.seed) \
        if args.entropy == "kernel" else None
    engine = ServeEngine(
        params, cfg, num_slots=args.slots,
        max_len=args.prompt_len + args.gen_len + args.chunk,
        chunk=args.chunk, entropy=entropy,
        mi_threshold=args.mi_threshold, se_threshold=args.se_threshold,
        eos_id=args.eos_id)
    result = engine.run(make_requests(args, cfg))

    # entropy HBM traffic of the head's MC draws per decoded token: the
    # xi operand is (S, B, V) f32 per decode step and a step emits B
    # tokens, so the per-token share is S*V*4; 0 on the in-kernel path
    # (TPU only — off-TPU the kernel-mode falls back to the seeded host
    # oracle, which still materializes the variates).
    in_kernel = args.entropy == "kernel" and jax.default_backend() == "tpu"
    result["entropy_mode"] = args.entropy
    result["entropy_hbm_bytes_per_token"] = 0 if in_kernel else \
        cfg.mc_samples * cfg.vocab_size * 4
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_1_5b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--slots", type=int, default=4,
                    help="concurrent decode slots (the decode batch)")
    ap.add_argument("--num-requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16,
                    help="max new tokens per request")
    ap.add_argument("--chunk", type=int, default=8,
                    help="decode steps per device call (scan length)")
    ap.add_argument("--eos-id", type=int, default=None)
    ap.add_argument("--mi-threshold", type=float, default=0.05)
    ap.add_argument("--se-threshold", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--entropy", choices=("operand", "kernel"),
                    default="kernel",
                    help="'kernel': seed-driven head draws, generated "
                         "in-kernel on TPU (0 HBM entropy bytes); "
                         "'operand': legacy key-threaded xi tensor")
    args = ap.parse_args()
    r = serve(args)
    print(f"served {r['num_requests']} requests / {r['gen_tokens']} tokens "
          f"in {r['total_s']:.2f}s")
    print(f"prefill compile {r['prefill_compile_s']:.2f}s  "
          f"steady {r['prefill_steady_s'] * 1e3:.1f}ms")
    print(f"decode {r['decode_tok_per_s']:.1f} tok/s "
          f"(e2e {r['e2e_tok_per_s']:.1f})  "
          f"latency p50 {r['latency_p50_s']:.2f}s "
          f"p99 {r['latency_p99_s']:.2f}s")
    print(f"epistemic flags {r['epistemic_flags']}  "
          f"aleatoric flags {r['aleatoric_flags']}  "
          f"(per 1k tokens: {r['flags_per_1k_tokens']['epistemic']:.1f} / "
          f"{r['flags_per_1k_tokens']['aleatoric']:.1f})")
    print(f"entropy: {r['entropy_mode']} path, "
          f"{r['entropy_hbm_bytes_per_token'] / 1e6:.2f} MB/token "
          f"of randomness over HBM")
    print("MI per request:")
    for r_ in r["requests"]:
        print(f"  #{r_.rid} ({r_.finish_reason}): "
              + np.array2string(np.asarray(r_.MI), precision=4))


if __name__ == "__main__":
    main()
