"""Continuous-batching uncertainty serving engine.

The deployment analog of the paper's high-throughput trustworthy
inference: a queue of requests is served through a fixed set of decode
slots over one slot-indexed KV cache.  A host-side ``SlotScheduler``
admits queued requests into free slots (batch-1 jitted prefill written
into the slot at its own offset), the inner decode loop is a
``jax.lax.scan`` that generates ``--chunk`` tokens per device call --
carrying the (H, SE, MI) uncertainty triplet and the epistemic/aleatoric
gating flags in the scan carry, one host sync per chunk instead of one
per token -- and slots are evicted on EOS / max-new-tokens and refilled
from the queue.

Each decode step draws ``cfg.mc_samples`` (paper: N=10) samples of the
Bayesian output head -- fused in the uncertainty-head kernel on TPU,
jnp-LRT elsewhere.  Tokens whose MI exceeds ``--mi-threshold`` are
flagged epistemic (the LM analog of the paper's OOD rejection);
high-SE/low-MI tokens are flagged aleatoric (ambiguous continuation).

The pre-engine per-token loop survives as ``decode_loop_reference`` --
the parity oracle (scan decode replays its token stream exactly in
operand-entropy mode for requests admitted at engine start; requests
admitted later draw from the engine's global step stream, so replaying
them needs the same step offset) and the benchmark baseline that
``benchmarks/bench_serve.py`` measures the engine against.

KV layout: ``--kv-layout dense`` (the reference) gives each slot one
contiguous ``max_len`` strip; ``--kv-layout paged`` backs the
self-attention KV with a global pool of ``--kv-block``-token blocks
managed by the host-side ``BlockAllocator`` (free list, per-slot block
tables, whole-request budget reserved at admission, blocks granted
chunk by chunk, full release on eviction).  Admission then asks "are
enough blocks free" instead of "is a slot free", so mixed prompt/gen
lengths stop paying ``num_slots * max_len`` padding waste; pool
exhaustion defers the queue head instead of crashing.  The paged path
is bit-exact against dense in operand-entropy mode (tested in
tests/test_paged_kv.py).

Container-scale: reduced config, debug mesh.  Full-size serving shapes
(prefill_32k / decode_32k / long_500k) are compile-proven by launch.dryrun.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2_1_5b \
      --slots 4 --num-requests 8 --prompt-len 32 --gen-len 16 --chunk 8
"""

from __future__ import annotations

import argparse
import collections
import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config, reduced
from repro.core.entropy import KernelEntropy
from repro.data.synthetic import TokenStreamState, token_batch
from repro.launch import steps as S
from repro.models import registry as M


# ---------------------------------------------------------------------------
# requests + host-side slot scheduler
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Request:
    """One serving request plus its accumulated results."""

    rid: int
    prompt: np.ndarray                    # (S,) int32
    max_new_tokens: int
    t_submit: float = 0.0
    t_finish: float = 0.0
    finish_reason: str = ""
    tokens: list = dataclasses.field(default_factory=list)
    H: list = dataclasses.field(default_factory=list)
    SE: list = dataclasses.field(default_factory=list)
    MI: list = dataclasses.field(default_factory=list)
    p_max: list = dataclasses.field(default_factory=list)
    epistemic_flags: int = 0
    aleatoric_flags: int = 0

    @property
    def latency_s(self) -> float:
        return self.t_finish - self.t_submit


class BlockAllocator:
    """Free-list allocator over a global pool of fixed-size KV blocks.

    Pure host-side (no jax).  A request's whole-lifetime block budget is
    RESERVED at admission (so a running request can never starve
    mid-decode and need preemption) but blocks are only ALLOCATED —
    pulled off the free list and mapped into the slot's block table — as
    the sequence actually grows: prompt blocks at admission, decode
    blocks granted chunk by chunk by the scheduler.  ``available()`` is
    what admission checks: free minus outstanding reservations.
    """

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 1 or block_size < 1:
            raise ValueError("need at least one block of at least one "
                             "token")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._free: list[int] = list(range(num_blocks - 1, -1, -1))
        self._reserved = 0
        self.peak_in_use = 0

    def blocks_for(self, tokens: int) -> int:
        """Blocks needed to hold ``tokens`` KV entries (ceil)."""
        return -(-tokens // self.block_size)

    @property
    def in_use(self) -> int:
        return self.num_blocks - len(self._free)

    def available(self) -> int:
        return len(self._free) - self._reserved

    def reserve(self, n: int) -> bool:
        """Set aside ``n`` blocks for later alloc; False if they aren't
        there (the caller defers admission instead of crashing)."""
        if self.available() < n:
            return False
        self._reserved += n
        return True

    def unreserve(self, n: int) -> None:
        if n > self._reserved:
            raise ValueError(f"unreserve({n}) exceeds {self._reserved} "
                             "outstanding reservations")
        self._reserved -= n

    def alloc(self, n: int) -> list[int]:
        """Draw ``n`` physical blocks down from an existing reservation."""
        if n > self._reserved:
            raise ValueError(f"alloc({n}) without reservation "
                             f"({self._reserved} reserved)")
        self._reserved -= n
        ids = [self._free.pop() for _ in range(n)]
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        return ids

    def free(self, ids: list[int]) -> None:
        dupes = sorted(set(ids) & set(self._free)) + sorted(
            i for i in set(ids) if ids.count(i) > 1)
        if dupes:
            raise ValueError(f"double free of blocks {dupes}")
        self._free.extend(ids)


class SlotScheduler:
    """FIFO admission of queued requests into fixed decode slots.

    Pure host-side bookkeeping (no jax): ``admit`` fills free slots in
    slot order from the queue front, ``evict`` frees a slot for reuse.

    With a ``BlockAllocator`` the scheduler also owns the paged-KV block
    tables: admission switches from "is a slot free" to "are enough
    blocks free" (whole-request budget reserved up front; the queue head
    defers — FIFO, no skip-ahead — when the pool can't cover it), prompt
    blocks are allocated at admission, ``grant`` maps further blocks
    incrementally as decode deepens, and ``evict`` returns every block.
    """

    def __init__(self, num_slots: int,
                 allocator: Optional[BlockAllocator] = None,
                 table_width: int = 0):
        self.slots: list[Optional[Request]] = [None] * num_slots
        self.queue: collections.deque[Request] = collections.deque()
        self.allocator = allocator
        if allocator is not None:
            if table_width < 1:
                raise ValueError("paged scheduling needs table_width "
                                 "(max blocks per slot)")
            self.block_tables = np.full((num_slots, table_width), -1,
                                        np.int32)
            self._slot_blocks: list[list[int]] = \
                [[] for _ in range(num_slots)]
            self._slot_reserved = [0] * num_slots
            # bumped on every table mutation (admit/grant/evict) so the
            # engine only re-uploads the device table when it changed
            self.table_version = 0

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit_paged(self, slot: int) -> Optional[Request]:
        alloc = self.allocator
        req = self.queue[0]
        need = alloc.blocks_for(len(req.prompt) + req.max_new_tokens)
        if not alloc.reserve(need):
            return None                  # pool exhausted: defer, FIFO
        self.queue.popleft()
        prompt_blocks = alloc.blocks_for(len(req.prompt))
        ids = alloc.alloc(prompt_blocks)
        self._slot_blocks[slot] = ids
        self._slot_reserved[slot] = need - prompt_blocks
        self.block_tables[slot, :] = -1
        self.block_tables[slot, :prompt_blocks] = ids
        self.table_version += 1
        return req

    def admit(self) -> list[tuple[int, Request]]:
        placed = []
        for i, occupant in enumerate(self.slots):
            if occupant is None and self.queue:
                if self.allocator is not None:
                    req = self._admit_paged(i)
                    if req is None:
                        break
                else:
                    req = self.queue.popleft()
                self.slots[i] = req
                placed.append((i, req))
        return placed

    def grant(self, slot: int, target_len: int) -> list[int]:
        """Map blocks so slot ``slot`` can hold ``target_len`` tokens.

        Draws from the request's admission-time reservation, so it
        cannot fail; the grant is capped at that budget (junk steps a
        finished request runs until its chunk boundary drop against the
        unmapped tail instead of consuming pool)."""
        have = len(self._slot_blocks[slot])
        want = min(self.allocator.blocks_for(target_len),
                   have + self._slot_reserved[slot])
        if want <= have:
            return []
        ids = self.allocator.alloc(want - have)
        self._slot_reserved[slot] -= len(ids)
        self.block_tables[slot, have:want] = ids
        self._slot_blocks[slot].extend(ids)
        self.table_version += 1
        return ids

    def evict(self, slot: int) -> Request:
        req = self.slots[slot]
        if req is None:
            raise ValueError(f"evict of empty slot {slot}")
        self.slots[slot] = None
        if self.allocator is not None:
            self.allocator.free(self._slot_blocks[slot])
            self._slot_blocks[slot] = []
            self.allocator.unreserve(self._slot_reserved[slot])
            self._slot_reserved[slot] = 0
            self.block_tables[slot, :] = -1
            self.table_version += 1
        return req

    def active(self) -> list[tuple[int, Request]]:
        return [(i, r) for i, r in enumerate(self.slots) if r is not None]

    def has_work(self) -> bool:
        return bool(self.queue) or any(r is not None for r in self.slots)


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------

class ServeEngine:
    """Continuous-batching scan-decoded uncertainty engine.

    ``num_slots`` concurrent decode slots over one slot-indexed KV cache
    of depth ``max_len``; ``chunk`` tokens decoded per device call.
    ``entropy`` (KernelEntropy) selects the seeded head-draw stream
    (in-kernel on TPU); None keeps the legacy operand stream.

    ``kv_layout`` picks the cache layout.  Both layouts bound a request
    to ``prompt + gen <= max_len`` (block tables span ``max_len``
    logical tokens).  ``'dense'`` — the bit-exact reference — gives
    every slot one contiguous ``max_len`` KV strip, so mixed-length
    traffic pays full padding waste.  ``'paged'`` backs the self-attention KV
    with a global pool of ``kv_blocks`` blocks of ``kv_block`` tokens:
    admission reserves a request's whole-lifetime block budget ("are
    enough blocks free", deferring instead of crashing when the pool is
    exhausted), decode blocks are granted chunk by chunk, and eviction
    returns everything — KV bytes in use track the tokens actually
    resident instead of ``num_slots * max_len``.  Paged decode is
    bit-exact against dense when ``max_len`` is a ``kv_block`` multiple
    (equal logical spans; tested in tests/test_paged_kv.py).  Families
    without KV strips (ssm) fall back to dense.
    """

    def __init__(self, params, cfg, *, num_slots: int, max_len: int,
                 chunk: int = 8, entropy: Optional[KernelEntropy] = None,
                 mi_threshold: float = 0.05, se_threshold: float = 1.0,
                 eos_id: Optional[int] = None, kv_layout: str = "dense",
                 kv_block: int = 16, kv_blocks: Optional[int] = None):
        if kv_layout not in ("dense", "paged"):
            raise ValueError(f"unknown kv_layout {kv_layout!r}")
        if kv_block < 1:
            raise ValueError(f"kv_block must be >= 1, got {kv_block}")
        self.params = params
        self.cfg = cfg
        self.num_slots = num_slots
        self.max_len = max_len
        self.chunk = chunk
        self.eos_id = eos_id
        self.kv_layout = kv_layout if M.supports_paged(cfg) else "dense"
        self.kv_block = kv_block
        self.table_width = M.paged_table_width(max_len, kv_block)
        # default pool = full dense capacity: no admission change, the
        # savings then show up as peak blocks in use < blocks allocated
        self.kv_blocks = (kv_blocks if kv_blocks is not None
                          else num_slots * self.table_width)
        if self.kv_blocks < 1:
            raise ValueError(f"kv_blocks must be >= 1, got {kv_blocks}")
        paged = self.kv_layout == "paged"
        if paged:
            # paged prefill builds a minimal prompt-length strip (the
            # scatter pages it out token by token); dense keeps the
            # engine-wide max_len strip its slot write needs
            self._prefill = jax.jit(
                lambda p, t, m: M.prefill(p, cfg, t, t.shape[1], m))
            self._write = jax.jit(
                lambda c, slot, sub, row: M.write_slot(cfg, c, slot, sub,
                                                       row),
                donate_argnums=(0,))
        else:
            self._prefill = jax.jit(
                lambda p, t, m: M.prefill(p, cfg, t, max_len, m))
            self._write = jax.jit(
                lambda c, slot, sub: M.write_slot(cfg, c, slot, sub),
                donate_argnums=(0,))
        self._scan = jax.jit(
            S.build_scan_decode(cfg, entropy=entropy, chunk=chunk,
                                mi_threshold=mi_threshold,
                                se_threshold=se_threshold),
            donate_argnums=(2,))

    def _modality(self, batch: int):
        cfg = self.cfg
        if cfg.family == "encdec":
            from repro.models.encdec import ENC_LEN
            return jnp.zeros((batch, ENC_LEN, cfg.d_model), jnp.float32)
        if cfg.family == "vlm":
            return jnp.zeros((batch, cfg.num_prefix_embeds, cfg.d_model),
                             jnp.float32)
        return None

    def run(self, requests: list[Request]) -> dict:
        """Serve ``requests`` to completion; returns engine metrics.

        One host sync per admission (prefill) and one per decoded chunk
        (the stacked (chunk, B) outputs) -- never per token.
        """
        for r in requests:
            if len(r.prompt) == 0:
                raise ValueError(f"request {r.rid}: empty prompt")
            if r.max_new_tokens < 1:
                raise ValueError(
                    f"request {r.rid}: max_new_tokens must be >= 1")
            if len(r.prompt) + r.max_new_tokens > self.max_len:
                raise ValueError(
                    f"request {r.rid}: prompt {len(r.prompt)} + "
                    f"max_new_tokens {r.max_new_tokens} exceeds the "
                    f"slot capacity max_len={self.max_len}; cache writes "
                    f"past capacity would be dropped silently")
        paged = self.kv_layout == "paged"
        alloc = None
        if paged:
            alloc = BlockAllocator(self.kv_blocks, self.kv_block)
            for r in requests:
                need = alloc.blocks_for(len(r.prompt) + r.max_new_tokens)
                if need > self.kv_blocks:
                    raise ValueError(
                        f"request {r.rid}: needs {need} KV blocks but the "
                        f"pool only has {self.kv_blocks}; it could never "
                        f"be admitted")
        sched = SlotScheduler(self.num_slots, allocator=alloc,
                              table_width=self.table_width)
        t_start = time.perf_counter()
        for r in requests:
            r.t_submit = time.perf_counter()
            sched.submit(r)

        tok = jnp.zeros((self.num_slots,), jnp.int32)
        cache = M.make_cache(self.cfg, self.num_slots, self.max_len,
                             layout=self.kv_layout,
                             kv_block=self.kv_block,
                             num_blocks=self.kv_blocks)
        active = jnp.zeros((self.num_slots,), bool)
        flags = {"epistemic": jnp.zeros((self.num_slots,), jnp.int32),
                 "aleatoric": jnp.zeros((self.num_slots,), jnp.int32)}
        step0 = 0
        table_synced = -1            # device block-table version synced
        decode_s = 0.0
        # the jitted prefill compiles once per distinct prompt length;
        # classify each admission's time accordingly so mixed-length
        # traffic doesn't launder recompiles into the steady-state stat
        compile_times: list[float] = []
        steady_times: list[float] = []
        seen_prompt_lens: set[int] = set()
        modality1 = self._modality(1)

        while sched.has_work():
            for slot, req in sched.admit():
                t0 = time.perf_counter()
                _, sub = self._prefill(
                    self.params, jnp.asarray(req.prompt)[None], modality1)
                if paged:
                    cache = self._write(
                        cache, jnp.asarray(slot, jnp.int32), sub,
                        jnp.asarray(sched.block_tables[slot]))
                else:
                    cache = self._write(cache,
                                        jnp.asarray(slot, jnp.int32), sub)
                tok = tok.at[slot].set(int(req.prompt[-1]))
                active = active.at[slot].set(True)
                flags = {k: v.at[slot].set(0) for k, v in flags.items()}
                jax.block_until_ready(cache)
                dt = time.perf_counter() - t0
                if len(req.prompt) in seen_prompt_lens:
                    steady_times.append(dt)
                else:
                    seen_prompt_lens.add(len(req.prompt))
                    compile_times.append(dt)

            if paged:
                # incremental grant: map the blocks the coming chunk can
                # write (capped at each request's admission-time budget);
                # re-upload the device table (tiny: slots x MB) only when
                # something actually changed since the last chunk
                for slot, req in sched.active():
                    sched.grant(slot, len(req.prompt)
                                + min(len(req.tokens) + self.chunk,
                                      req.max_new_tokens))
                if sched.table_version != table_synced:
                    cache = dict(cache, block_table=jnp.asarray(
                        sched.block_tables))
                    table_synced = sched.table_version

            t0 = time.perf_counter()
            tok, cache, flags, ys = self._scan(
                self.params, tok, cache, jnp.asarray(step0, jnp.int32),
                active, flags)
            ys = jax.device_get(ys)            # the chunk's single sync
            decode_s += time.perf_counter() - t0
            step0 += self.chunk

            for slot, req in sched.active():
                for t in range(self.chunk):
                    tk = int(ys["token"][t, slot])
                    req.tokens.append(tk)
                    for name in ("H", "SE", "MI", "p_max"):
                        getattr(req, name).append(float(ys[name][t, slot]))
                    req.epistemic_flags += int(ys["epistemic"][t, slot])
                    req.aleatoric_flags += int(ys["aleatoric"][t, slot])
                    done_eos = self.eos_id is not None and tk == self.eos_id
                    if done_eos or len(req.tokens) >= req.max_new_tokens:
                        req.t_finish = time.perf_counter()
                        req.finish_reason = "eos" if done_eos else "length"
                        sched.evict(slot)
                        active = active.at[slot].set(False)
                        break

        total_s = time.perf_counter() - t_start
        gen_tokens = sum(len(r.tokens) for r in requests)
        # KV residency accounting: dense permanently owns num_slots
        # strips of max_len; paged owns only the blocks actually mapped
        # (peak over the run), which is what mixed-length traffic saves
        kv_alloc_bytes = M.kv_bytes(cache)
        if paged:
            token_bytes = kv_alloc_bytes / (self.kv_blocks * self.kv_block)
            block_bytes = kv_alloc_bytes // self.kv_blocks
            kv_stats = {
                "layout": "paged",
                "block_tokens": self.kv_block,
                "blocks_total": self.kv_blocks,
                "blocks_peak": alloc.peak_in_use,
                "bytes_in_use_peak": alloc.peak_in_use * block_bytes,
                "bytes_dense_equiv": int(token_bytes * self.num_slots
                                         * self.max_len),
            }
        else:
            kv_stats = {
                "layout": "dense",
                "bytes_in_use_peak": kv_alloc_bytes,
                "bytes_dense_equiv": kv_alloc_bytes,
            }
        lat = np.array([r.latency_s for r in requests]) if requests \
            else np.zeros((1,))
        epi = sum(r.epistemic_flags for r in requests)
        alea = sum(r.aleatoric_flags for r in requests)
        return {
            "requests": requests,
            "num_requests": len(requests),
            "gen_tokens": gen_tokens,
            "total_s": total_s,
            "decode_s": decode_s,
            # first prefill per prompt length includes compilation; the
            # rest are steady-state dispatch
            "prefill_compile_s": float(np.sum(compile_times)),
            "prefill_steady_s": float(np.mean(steady_times))
            if steady_times else 0.0,
            "decode_tok_per_s": gen_tokens / max(decode_s, 1e-9),
            "e2e_tok_per_s": gen_tokens / max(total_s, 1e-9),
            "latency_p50_s": float(np.percentile(lat, 50)),
            "latency_p99_s": float(np.percentile(lat, 99)),
            "kv": kv_stats,
            "epistemic_flags": int(epi),
            "aleatoric_flags": int(alea),
            "flags_per_1k_tokens": {
                "epistemic": 1000.0 * epi / max(gen_tokens, 1),
                "aleatoric": 1000.0 * alea / max(gen_tokens, 1),
            },
            # device-side telemetry from the scan carry: per-slot totals a
            # pure-device driver could read without syncing ys.  Upper-
            # bounds the exact host accounting above (a request finishing
            # mid-chunk keeps counting until its chunk boundary).
            "device_flag_counters": {
                k: np.asarray(v).tolist() for k, v in flags.items()
            },
        }


# ---------------------------------------------------------------------------
# per-token reference loop (parity oracle + benchmark baseline)
# ---------------------------------------------------------------------------

def decode_loop_reference(params, cfg, tokens, gen_len: int, *,
                          entropy: Optional[KernelEntropy] = None,
                          max_len: Optional[int] = None,
                          modality=None, decode_fn=None) -> dict:
    """The pre-engine decode driver: one jitted step + one host sync per
    token over a statically batched prompt matrix.  Scan decode must
    reproduce this loop's token stream exactly in operand-entropy mode
    (same fold_in(base, global_step) noise; tested in test_serve.py).

    ``decode_fn`` lets benchmarks pass a pre-compiled step so the timed
    loop measures steady-state dispatch, not compilation.
    """
    tokens = jnp.asarray(tokens)
    B, P = tokens.shape
    max_len = max_len or P + gen_len
    _, cache = M.prefill(params, cfg, tokens, max_len, modality)
    decode = decode_fn or jax.jit(S.build_decode_step(cfg, entropy=entropy),
                                  donate_argnums=(2,))
    tok = tokens[:, -1]
    rows = {"token": [], "H": [], "SE": [], "MI": [], "p_max": []}
    t0 = time.perf_counter()
    for i in range(gen_len):
        out, cache = decode(params, tok, cache, jnp.asarray(i, jnp.int32))
        tok = out["next_token"]
        rows["token"].append(np.asarray(tok))        # per-token sync
        for k in ("H", "SE", "MI", "p_max"):
            rows[k].append(np.asarray(out[k]))
    decode_s = time.perf_counter() - t0
    return {name: np.stack(vals) for name, vals in rows.items()} | {
        "decode_s": decode_s,
        "decode_tok_per_s": gen_len * B / max(decode_s, 1e-9),
    }


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def make_requests(args, cfg) -> list[Request]:
    stream = TokenStreamState(seed=args.seed, host=0, num_hosts=1)
    toks, _ = token_batch(stream, args.num_requests, args.prompt_len,
                          cfg.vocab_size)
    return [Request(rid=i, prompt=np.asarray(toks[i], np.int32),
                    max_new_tokens=args.gen_len)
            for i in range(args.num_requests)]


def serve(args) -> dict:
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    cfg = dataclasses.replace(cfg, head_entropy=args.entropy)
    params = M.init_params(jax.random.key(args.seed), cfg)

    entropy = KernelEntropy(seed=args.seed) \
        if args.entropy == "kernel" else None
    engine = ServeEngine(
        params, cfg, num_slots=args.slots,
        max_len=args.prompt_len + args.gen_len + args.chunk,
        chunk=args.chunk, entropy=entropy,
        mi_threshold=args.mi_threshold, se_threshold=args.se_threshold,
        eos_id=args.eos_id, kv_layout=args.kv_layout,
        kv_block=args.kv_block, kv_blocks=args.kv_blocks)
    result = engine.run(make_requests(args, cfg))

    # entropy HBM traffic of the head's MC draws per decoded token: the
    # xi operand is (S, B, V) f32 per decode step and a step emits B
    # tokens, so the per-token share is S*V*4; 0 on the in-kernel path
    # (TPU only — off-TPU the kernel-mode falls back to the seeded host
    # oracle, which still materializes the variates).
    in_kernel = args.entropy == "kernel" and jax.default_backend() == "tpu"
    result["entropy_mode"] = args.entropy
    result["entropy_hbm_bytes_per_token"] = 0 if in_kernel else \
        cfg.mc_samples * cfg.vocab_size * 4
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_1_5b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--slots", type=int, default=4,
                    help="concurrent decode slots (the decode batch)")
    ap.add_argument("--num-requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16,
                    help="max new tokens per request")
    ap.add_argument("--chunk", type=int, default=8,
                    help="decode steps per device call (scan length)")
    ap.add_argument("--eos-id", type=int, default=None)
    ap.add_argument("--mi-threshold", type=float, default=0.05)
    ap.add_argument("--se-threshold", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--entropy", choices=("operand", "kernel"),
                    default="kernel",
                    help="'kernel': seed-driven head draws, generated "
                         "in-kernel on TPU (0 HBM entropy bytes); "
                         "'operand': legacy key-threaded xi tensor")
    ap.add_argument("--kv-layout", choices=("dense", "paged"),
                    default="dense",
                    help="'paged': self-attention KV in a global pool of "
                         "--kv-block-token blocks behind per-slot block "
                         "tables (admission = enough blocks free); "
                         "'dense': one max_len strip per slot, the "
                         "bit-exact reference layout")
    ap.add_argument("--kv-block", type=int, default=16,
                    help="tokens per KV block (paged layout)")
    ap.add_argument("--kv-blocks", type=int, default=None,
                    help="pool size in blocks (default: full dense "
                         "capacity, slots * ceil(max_len / kv_block))")
    args = ap.parse_args()
    r = serve(args)
    print(f"served {r['num_requests']} requests / {r['gen_tokens']} tokens "
          f"in {r['total_s']:.2f}s")
    print(f"prefill compile {r['prefill_compile_s']:.2f}s  "
          f"steady {r['prefill_steady_s'] * 1e3:.1f}ms")
    print(f"decode {r['decode_tok_per_s']:.1f} tok/s "
          f"(e2e {r['e2e_tok_per_s']:.1f})  "
          f"latency p50 {r['latency_p50_s']:.2f}s "
          f"p99 {r['latency_p99_s']:.2f}s")
    print(f"epistemic flags {r['epistemic_flags']}  "
          f"aleatoric flags {r['aleatoric_flags']}  "
          f"(per 1k tokens: {r['flags_per_1k_tokens']['epistemic']:.1f} / "
          f"{r['flags_per_1k_tokens']['aleatoric']:.1f})")
    print(f"entropy: {r['entropy_mode']} path, "
          f"{r['entropy_hbm_bytes_per_token'] / 1e6:.2f} MB/token "
          f"of randomness over HBM")
    kv = r["kv"]
    if kv["layout"] == "paged":
        print(f"kv: paged, {kv['blocks_peak']}/{kv['blocks_total']} blocks "
              f"peak ({kv['block_tokens']} tokens each) — "
              f"{kv['bytes_in_use_peak'] / 1e6:.2f} MB in use vs "
              f"{kv['bytes_dense_equiv'] / 1e6:.2f} MB dense strips")
    else:
        print(f"kv: dense strips, {kv['bytes_in_use_peak'] / 1e6:.2f} MB "
              f"resident for the whole run")
    print("MI per request:")
    for r_ in r["requests"]:
        print(f"  #{r_.rid} ({r_.finish_reason}): "
              + np.array2string(np.asarray(r_.MI), precision=4))


if __name__ == "__main__":
    main()
