"""Continuous-batching uncertainty serving engine.

The deployment analog of the paper's high-throughput trustworthy
inference: a queue of requests is served through a fixed set of decode
slots over one slot-indexed KV cache.  A host-side ``SlotScheduler``
admits queued requests into free slots (batch-1 jitted prefill written
into the slot at its own offset), the inner decode loop is a
``jax.lax.scan`` that generates ``--chunk`` tokens per device call --
carrying the (H, SE, MI) uncertainty triplet and the epistemic/aleatoric
gating flags in the scan carry, one host sync per chunk instead of one
per token -- and slots are evicted on EOS / max-new-tokens and refilled
from the queue.

Each decode step draws ``cfg.mc_samples`` (paper: N=10) samples of the
Bayesian output head -- fused in the uncertainty-head kernel on TPU,
jnp-LRT elsewhere.  Tokens whose MI exceeds ``--mi-threshold`` are
flagged epistemic (the LM analog of the paper's OOD rejection);
high-SE/low-MI tokens are flagged aleatoric (ambiguous continuation).

The pre-engine per-token loop survives as ``decode_loop_reference`` --
the parity oracle (scan decode replays its token stream exactly in
operand-entropy mode for requests admitted at engine start; requests
admitted later draw from the engine's global step stream, so replaying
them needs the same step offset) and the benchmark baseline that
``benchmarks/bench_serve.py`` measures the engine against.

KV layout: ``--kv-layout dense`` (the reference) gives each slot one
contiguous ``max_len`` strip; ``--kv-layout paged`` backs the
self-attention KV with a global pool of ``--kv-block``-token blocks
managed by the host-side ``BlockAllocator`` (free list, per-slot block
tables, whole-request budget reserved at admission, blocks granted
chunk by chunk, full release on eviction).  Admission then asks "are
enough blocks free" instead of "is a slot free", so mixed prompt/gen
lengths stop paying ``num_slots * max_len`` padding waste; pool
exhaustion defers the queue head instead of crashing.  The paged path
is bit-exact against dense in operand-entropy mode (tested in
tests/test_paged_kv.py).

``--prefix-cache on`` (paged only) adds the copy-on-write radix prefix
cache (``launch.prefix_cache``): admission walks a host-side radix tree
of cached token prefixes, maps the hit's refcounted blocks into the
slot's table read-only, prefills only the uncached suffix (zero prefill
compute on a full-prompt hit), and copies a shared block device-side
when a slot would scatter into it (CoW at the divergence point).
Prefix-hit decode is bit-exact vs the cold path in operand mode
(tests/test_prefix_cache.py).

``--decode-attn kernel`` (paged only) swaps the decode-attention read
path from gather-the-whole-logical-span to the block-sparse Pallas
kernel (``kernels/paged_attention.py``), which reads K/V straight from
the block pool through the per-slot table — per-step HBM reads scale
with the tokens actually cached instead of ``MB*BS``.  Gather stays the
bit-exact reference (tests/test_paged_attention.py), mirroring how
dense anchors paged and ``decode_loop_reference`` anchors scan decode.

``--prefill chunked`` (paged only) merges prefill into the decode loop
(Sarathi/vLLM-style): each engine iteration runs at most ONE prompt
chunk of ``--prefill-chunk`` tokens from the head admitting request
(``models.*.prefill_chunk`` scatters it straight into the slot's pool
blocks) plus the usual decode scan for already-active slots — a long
prompt no longer stalls every in-flight decode stream for its whole
prefill, which is what ``decode_interarrival_p99_s`` measures.  The
batch path survives as the bit-exactness reference: every chunk reduces
over the same static span the batch prefill uses, so the decoded
streams are identical token-for-token in operand-entropy mode
(tests/test_chunked_prefill.py, including prefix-cache hits chunking
only the post-CoW suffix).

Block tables are GROWABLE: admission maps only the prompt's blocks
(plus a watermark of free headroom for running decoders), decode blocks
are granted on demand, and when a grant outruns the table width the
host table widens (device side re-uploads and the scan retraces once
per growth) — so ``prompt + gen`` may exceed the admission-time span,
and ``max_len`` no longer bounds paged requests.  A grant the pool
cannot cover first LRU-evicts cached-but-unreferenced prefix blocks,
then PREEMPTS the slot (tokens cleared, requeued at the queue front —
depth-keyed decode noise makes the replay bit-identical).

Container-scale: reduced config, debug mesh.  Full-size serving shapes
(prefill_32k / decode_32k / long_500k) are compile-proven by launch.dryrun.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2_1_5b \
      --slots 4 --num-requests 8 --prompt-len 32 --gen-len 16 --chunk 8
"""

from __future__ import annotations

import argparse
import collections
import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config, reduced
from repro.core.entropy import KernelEntropy
from repro.data.synthetic import TokenStreamState, token_batch
from repro.kernels.paged_attention import kv_blocks_read
from repro.launch import steps as S
from repro.models import registry as M


# ---------------------------------------------------------------------------
# requests + host-side slot scheduler
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Request:
    """One serving request plus its accumulated results."""

    rid: int
    prompt: np.ndarray                    # (S,) int32
    max_new_tokens: int
    t_submit: float = 0.0
    t_finish: float = 0.0
    finish_reason: str = ""
    tokens: list = dataclasses.field(default_factory=list)
    H: list = dataclasses.field(default_factory=list)
    SE: list = dataclasses.field(default_factory=list)
    MI: list = dataclasses.field(default_factory=list)
    p_max: list = dataclasses.field(default_factory=list)
    epistemic_flags: int = 0
    aleatoric_flags: int = 0

    @property
    def latency_s(self) -> float:
        return self.t_finish - self.t_submit


class BlockAllocator:
    """Refcounted free-list allocator over a global pool of KV blocks.

    Pure host-side (no jax).  Reservations are TRANSIENT: the scheduler
    reserves exactly the blocks an admission or grant is about to
    ``alloc`` (the reserve/alloc pair keeps the accounting honest), not
    a request's whole-lifetime budget — decode blocks are granted on
    demand as the sequence grows, and a grant the pool can't cover is
    the scheduler's problem (LRU-evict cached blocks, else preempt the
    slot), not an up-front admission tax.  ``available()`` is free minus
    outstanding reservations.

    Blocks carry per-block REFCOUNTS so the prefix cache can share them:
    ``alloc`` hands a block out at refcount 1, ``incref`` adds a holder
    (the radix tree adopting a block, a slot mapping a cached prefix),
    and ``free`` is a decref — the block returns to the free list only
    when the last holder lets go.  Freeing a block whose refcount is
    already 0 is the double-free error it always was.
    """

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 1 or block_size < 1:
            raise ValueError("need at least one block of at least one "
                             "token")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._free: list[int] = list(range(num_blocks - 1, -1, -1))
        self._ref = [0] * num_blocks
        self._reserved = 0
        self.peak_in_use = 0

    def blocks_for(self, tokens: int) -> int:
        """Blocks needed to hold ``tokens`` KV entries (ceil)."""
        return -(-tokens // self.block_size)

    @property
    def in_use(self) -> int:
        return self.num_blocks - len(self._free)

    def available(self) -> int:
        return len(self._free) - self._reserved

    def reserve(self, n: int) -> bool:
        """Set aside ``n`` blocks for later alloc; False if they aren't
        there (the caller defers admission instead of crashing)."""
        if self.available() < n:
            return False
        self._reserved += n
        return True

    def unreserve(self, n: int) -> None:
        if n > self._reserved:
            raise ValueError(f"unreserve({n}) exceeds {self._reserved} "
                             "outstanding reservations")
        self._reserved -= n

    def alloc(self, n: int) -> list[int]:
        """Draw ``n`` physical blocks down from an existing reservation."""
        if n > self._reserved:
            raise ValueError(f"alloc({n}) without reservation "
                             f"({self._reserved} reserved)")
        self._reserved -= n
        ids = [self._free.pop() for _ in range(n)]
        for i in ids:
            self._ref[i] = 1
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        return ids

    def refcount(self, block: int) -> int:
        return self._ref[block]

    def incref(self, ids: list[int]) -> None:
        """Add a holder to live blocks (prefix-cache adoption/sharing)."""
        for i in ids:
            if self._ref[i] < 1:
                raise ValueError(f"incref of free block {i}")
            self._ref[i] += 1

    def free(self, ids: list[int]) -> None:
        """Decref; a block rejoins the free list when its last holder
        (slot or prefix-cache node) releases it.  No single holder ever
        releases one block twice in a call, so same-call duplicates are
        a caller bug caught here rather than a silent refcount steal."""
        if len(set(ids)) != len(ids):
            dupes = sorted({i for i in ids if ids.count(i) > 1})
            raise ValueError(f"double free of blocks {dupes}")
        for i in ids:
            if self._ref[i] < 1:
                raise ValueError(f"double free of blocks [{i}]")
            self._ref[i] -= 1
            if self._ref[i] == 0:
                self._free.append(i)


@dataclasses.dataclass
class PrefixAdmit:
    """Per-slot prefix-cache admission record the engine acts on.

    ``tokens`` of the prompt are already resident in shared blocks
    mapped read-only into the slot's table; prefill runs only on the
    suffix.  ``cow`` is a pending ``(src, dst)`` device-side block copy:
    the partially-matched tail block ``src`` stays referenced until the
    engine copies it into ``dst`` (already swapped into the table) and
    calls ``finish_cow``.
    """

    tokens: int
    cow: Optional[tuple] = None


class SlotScheduler:
    """FIFO admission of queued requests into fixed decode slots.

    Pure host-side bookkeeping (no jax): ``admit`` fills free slots in
    slot order from the queue front, ``evict`` frees a slot for reuse.

    With a ``BlockAllocator`` the scheduler also owns the paged-KV block
    tables: admission switches from "is a slot free" to "are enough
    blocks free" — the PROMPT's blocks plus a WATERMARK of free headroom
    (``num_slots`` blocks by default, waived when no slot is running) so
    in-flight decoders keep growing while the queue head defers (FIFO,
    no skip-ahead).  ``grant`` maps decode blocks on demand as slots
    deepen, capped at each request's ``prompt + max_new_tokens`` budget,
    WIDENING the block tables when a grant outruns them (the table
    width is a floor, not a ceiling); a grant the pool cannot cover
    even after LRU-evicting unreferenced cached blocks returns None and
    the engine preempts the slot (``preempt``: blocks released, request
    requeued at the queue front).  ``evict`` returns every block.

    With a ``prefix_cache`` (``launch.prefix_cache.RadixPrefixCache``)
    admission first walks the radix tree: the matched prefix's blocks
    are mapped into the slot's table shared (incref, read-only), only
    the uncached span reserves fresh blocks, a token-granular partial
    match allocates one extra block for the copy-on-write of the shared
    tail, and eviction INSERTS the request's prompt blocks into the tree
    (ownership transfers to the cache) before the slot's decref.  Under
    pool pressure admission asks the cache to LRU-evict unreferenced
    blocks before deferring.
    """

    def __init__(self, num_slots: int,
                 allocator: Optional[BlockAllocator] = None,
                 table_width: int = 0, prefix_cache=None,
                 watermark: Optional[int] = None):
        self.slots: list[Optional[Request]] = [None] * num_slots
        self.queue: collections.deque[Request] = collections.deque()
        self.allocator = allocator
        self.prefix_cache = prefix_cache
        # free-block headroom admission must leave for running decoders'
        # on-demand grants (now that their budgets are no longer
        # reserved up front); waived when nothing is running, so an
        # empty engine admits exactly what fits
        self.watermark = num_slots if watermark is None else watermark
        self.table_growths = 0
        if prefix_cache is not None and allocator is None:
            raise ValueError("prefix cache requires a BlockAllocator")
        if allocator is not None:
            if table_width < 1:
                raise ValueError("paged scheduling needs table_width "
                                 "(initial blocks per slot)")
            self.block_tables = np.full((num_slots, table_width), -1,
                                        np.int32)
            self._slot_blocks: list[list[int]] = \
                [[] for _ in range(num_slots)]
            # decode blocks still grantable per slot (budget, NOT an
            # allocator reservation): blocks_for(prompt + max_new) minus
            # what the slot already holds
            self._slot_budget = [0] * num_slots
            self._slot_prefix: list[Optional[PrefixAdmit]] = \
                [None] * num_slots
            self._slot_cow_src: list[Optional[int]] = [None] * num_slots
            # bumped on every table mutation (admit/grant/evict) so the
            # engine only re-uploads the device table when it changed
            self.table_version = 0
            self.table_growths = 0

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _ensure_width(self, want: int) -> None:
        """Widen the host block tables to hold ``want`` blocks per slot
        (doubling, -1-padded).  The engine notices via table_version:
        the device table re-uploads at the new shape and the decode
        scan retraces once per growth."""
        w = self.block_tables.shape[1]
        if want <= w:
            return
        grown = np.full((len(self.slots), max(want, 2 * w)), -1, np.int32)
        grown[:, :w] = self.block_tables
        self.block_tables = grown
        self.table_growths += 1
        self.table_version += 1

    def _try_reserve(self, need: int, protect: frozenset) -> bool:
        """Reserve ``need`` blocks for an admission, LRU-evicting
        cached-but-unreferenced blocks first when the pool is short
        (``protect`` pins the hit being admitted).  On top of ``need``
        the pool must keep ``watermark`` blocks free for running slots'
        decode grants — waived when no slot is running (nothing to
        starve, and the head request could otherwise never admit)."""
        alloc = self.allocator
        wm = self.watermark if any(r is not None for r in self.slots) \
            else 0
        short = need + wm - alloc.available()
        if short > 0 and self.prefix_cache is not None:
            self.prefix_cache.evict_lru(short, protect=protect)
        if alloc.available() < need + wm:
            return False
        return alloc.reserve(need)

    def _admit_paged(self, slot: int) -> Optional[Request]:
        alloc = self.allocator
        req = self.queue[0]
        P = len(req.prompt)
        nprompt = alloc.blocks_for(P)
        # grant cap, NOT a reservation: decode blocks are drawn from the
        # pool on demand, so admission only needs the prompt's blocks
        total = alloc.blocks_for(P + req.max_new_tokens)
        hit = self.prefix_cache.match(req.prompt) \
            if self.prefix_cache is not None else None
        if hit is not None and hit.tokens:
            # uncached span + one extra block when the shared tail needs
            # a copy-on-write duplicate before this slot writes into it
            need = nprompt - len(hit.blocks) + (1 if hit.partial else 0)
            if not self._try_reserve(need, frozenset(hit.blocks)):
                # liveness: when no live slot will ever free a block
                # (everything left is cache-held, pinned by this very
                # hit), fall back to a cold admission rather than
                # deadlocking on the hit's own protection
                if alloc.in_use > self.prefix_cache.cached_blocks():
                    return None           # a running slot will free some
                hit = None
        if hit is None or not hit.tokens:
            if not self._try_reserve(nprompt, frozenset()):
                return None               # pool exhausted: defer, FIFO
            self.queue.popleft()
            ids = alloc.alloc(nprompt)
            if self.prefix_cache is not None:
                self._slot_prefix[slot] = PrefixAdmit(tokens=0)
        else:
            self.queue.popleft()
            self.prefix_cache.lock(hit)   # slot refs on shared blocks
            ids = list(hit.blocks)
            cow = None
            if hit.partial:
                [dst] = alloc.alloc(1)
                cow = (ids[-1], dst)      # src stays ref'd: finish_cow
                self._slot_cow_src[slot] = ids[-1]
                ids[-1] = dst
            ids += alloc.alloc(nprompt - len(hit.blocks))
            self._slot_prefix[slot] = PrefixAdmit(tokens=hit.tokens,
                                                  cow=cow)
        self._slot_budget[slot] = total - nprompt
        self._slot_blocks[slot] = ids
        self._ensure_width(len(ids))
        self.block_tables[slot, :] = -1
        self.block_tables[slot, :len(ids)] = ids
        self.table_version += 1
        return req

    def prefix_admit(self, slot: int) -> Optional[PrefixAdmit]:
        """The slot's prefix-cache admission record (None when the cache
        is off)."""
        return self._slot_prefix[slot] if self.prefix_cache is not None \
            else None

    def finish_cow(self, slot: int) -> None:
        """The engine copied the shared tail block device-side; release
        this slot's reference on the source (the tree keeps its own)."""
        src = self._slot_cow_src[slot]
        if src is None:
            raise ValueError(f"no pending CoW on slot {slot}")
        self._slot_cow_src[slot] = None
        self.allocator.free([src])

    def admit(self) -> list[tuple[int, Request]]:
        placed = []
        for i, occupant in enumerate(self.slots):
            if occupant is None and self.queue:
                if self.allocator is not None:
                    req = self._admit_paged(i)
                    if req is None:
                        break
                else:
                    req = self.queue.popleft()
                self.slots[i] = req
                placed.append((i, req))
        return placed

    def grant(self, slot: int, target_len: int) -> Optional[list[int]]:
        """Map blocks so slot ``slot`` can hold ``target_len`` tokens.

        Draws from the pool on demand, capped at the request's
        ``prompt + max_new_tokens`` budget (junk steps a finished
        request runs until its chunk boundary drop against the unmapped
        tail instead of consuming pool) and widening the block tables
        when the target outruns them.  Returns the granted ids ([] when
        nothing is needed) or None when the pool cannot cover the
        shortfall even after LRU-evicting cached-but-unreferenced
        prefix blocks — the engine preempts the slot."""
        alloc = self.allocator
        have = len(self._slot_blocks[slot])
        want = min(alloc.blocks_for(target_len),
                   have + self._slot_budget[slot])
        if want <= have:
            return []
        n = want - have
        if alloc.available() < n and self.prefix_cache is not None:
            # a cached-but-unreferenced prefix must never starve a
            # running decoder (or livelock a deferred admission behind
            # it): reclaim before giving up
            self.prefix_cache.evict_lru(n - alloc.available(),
                                        protect=frozenset())
        if not alloc.reserve(n):
            return None
        ids = alloc.alloc(n)
        self._slot_budget[slot] -= n
        self._ensure_width(want)
        self.block_tables[slot, have:want] = ids
        self._slot_blocks[slot].extend(ids)
        self.table_version += 1
        return ids

    def preempt(self, slot: int) -> Request:
        """Evict a slot whose growth grant failed and requeue its
        request at the queue FRONT (FIFO order preserved).  The caller
        clears the request's accumulated output first — on readmission
        it restarts from its prompt (depth-keyed decode noise replays
        the aborted stream bit-exactly when it lands in the same
        slot)."""
        req = self.evict(slot)
        self.queue.appendleft(req)
        return req

    def evict(self, slot: int) -> Request:
        req = self.slots[slot]
        if req is None:
            raise ValueError(f"evict of empty slot {slot}")
        self.slots[slot] = None
        if self.allocator is not None:
            if self.prefix_cache is not None:
                # adopt the prompt's blocks into the radix tree BEFORE
                # the slot lets go: chunks already cached share the
                # existing nodes, fresh ones transfer to the cache
                nprompt = self.allocator.blocks_for(len(req.prompt))
                self.prefix_cache.insert(req.prompt,
                                         self._slot_blocks[slot][:nprompt])
                if self._slot_cow_src[slot] is not None:
                    self.allocator.free([self._slot_cow_src[slot]])
                    self._slot_cow_src[slot] = None
                self._slot_prefix[slot] = None
            self.allocator.free(self._slot_blocks[slot])
            self._slot_blocks[slot] = []
            self._slot_budget[slot] = 0
            self.block_tables[slot, :] = -1
            self.table_version += 1
        return req

    def pool_stats(self) -> dict:
        """Queue depth + block-pool occupancy snapshot (free / reserved
        / cached / in-use counts), so allocator behavior is observable
        per chunk without a debugger."""
        out = {"queue_depth": len(self.queue),
               "active_slots": sum(r is not None for r in self.slots)}
        if self.allocator is not None:
            a = self.allocator
            out.update(
                blocks_free=len(a._free), blocks_reserved=a._reserved,
                blocks_in_use=a.in_use,
                blocks_cached=(self.prefix_cache.cached_blocks()
                               if self.prefix_cache is not None else 0))
        return out

    def mapped_blocks(self, slot: int) -> int:
        """Physical blocks currently mapped into the slot's table (what
        the block-sparse decode kernel can actually read)."""
        return len(self._slot_blocks[slot])

    def active(self) -> list[tuple[int, Request]]:
        return [(i, r) for i, r in enumerate(self.slots) if r is not None]

    def has_work(self) -> bool:
        return bool(self.queue) or any(r is not None for r in self.slots)


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------

class ServeEngine:
    """Continuous-batching scan-decoded uncertainty engine.

    ``num_slots`` concurrent decode slots over one slot-indexed KV cache
    of depth ``max_len``; ``chunk`` tokens decoded per device call.
    ``entropy`` (KernelEntropy) selects the seeded head-draw stream
    (in-kernel on TPU); None keeps the legacy operand stream.

    ``kv_layout`` picks the cache layout.  Both layouts bound a request
    to ``prompt + gen <= max_len`` (block tables span ``max_len``
    logical tokens).  ``'dense'`` — the bit-exact reference — gives
    every slot one contiguous ``max_len`` KV strip, so mixed-length
    traffic pays full padding waste.  ``'paged'`` backs the self-attention KV
    with a global pool of ``kv_blocks`` blocks of ``kv_block`` tokens:
    admission reserves a request's whole-lifetime block budget ("are
    enough blocks free", deferring instead of crashing when the pool is
    exhausted), decode blocks are granted chunk by chunk, and eviction
    returns everything — KV bytes in use track the tokens actually
    resident instead of ``num_slots * max_len``.  Paged decode is
    bit-exact against dense when ``max_len`` is a ``kv_block`` multiple
    (equal logical spans; tested in tests/test_paged_kv.py).  Families
    without KV strips (ssm) fall back to dense.

    ``prefix_cache=True`` (paged only) puts a host-side radix tree
    (``launch.prefix_cache.RadixPrefixCache``) over the block pool:
    admission walks the tree, maps the longest cached token prefix's
    blocks into the slot's table read-only (refcounted sharing), and
    prefill runs only on the uncached suffix — a full-prompt hit costs
    zero prefill compute.  A token-granular partial match into a shared
    block triggers copy-on-write (device-side block duplicate + table
    swap) before the slot writes at the divergence point.  Evicted
    requests donate their prompt blocks to the tree; cached-but-
    unreferenced blocks are LRU-evicted under pool pressure.  Restricted
    to families whose prompt KV is a pure function of token IDs
    (``registry.supports_prefix_cache``); hit decode is bit-exact vs the
    cold path under the same admission schedule (tested in
    tests/test_prefix_cache.py).

    ``decode_attn`` (paged only) selects the decode-attention read path:
    ``'gather'`` — the bit-exact reference — materializes each slot's
    full ``MB*BS`` logical strip per layer per step, so decode HBM
    traffic is identical to dense strips; ``'kernel'`` runs the
    block-sparse Pallas kernel (``kernels/paged_attention.py``) that
    reads only mapped blocks under each slot's depth straight from the
    pool, bit-exact vs gather in operand/interpret mode (tested in
    tests/test_paged_attention.py).  ``trace_every`` downsamples the
    per-chunk scheduler/pool snapshot (1 = every chunk) so long runs
    don't grow host memory linearly in chunks decoded.
    """

    def __init__(self, params, cfg, *, num_slots: int, max_len: int,
                 chunk: int = 8, entropy: Optional[KernelEntropy] = None,
                 mi_threshold: float = 0.05, se_threshold: float = 1.0,
                 eos_id: Optional[int] = None, kv_layout: str = "dense",
                 kv_block: int = 16, kv_blocks: Optional[int] = None,
                 prefix_cache: bool = False, decode_attn: str = "gather",
                 prefill_mode: str = "batch", prefill_chunk: int = 32,
                 trace_every: int = 1):
        if kv_layout not in ("dense", "paged"):
            raise ValueError(f"unknown kv_layout {kv_layout!r}")
        if kv_block < 1:
            raise ValueError(f"kv_block must be >= 1, got {kv_block}")
        if prefix_cache and kv_layout != "paged":
            raise ValueError("prefix cache shares blocks of the paged "
                             "pool; run with kv_layout='paged'")
        if decode_attn not in ("gather", "kernel"):
            raise ValueError(f"unknown decode_attn {decode_attn!r}")
        if decode_attn == "kernel" and kv_layout != "paged":
            raise ValueError("the block-sparse decode kernel reads "
                             "through the paged block table; run with "
                             "kv_layout='paged'")
        if prefill_mode not in ("batch", "chunked"):
            raise ValueError(f"unknown prefill_mode {prefill_mode!r}")
        if prefill_mode == "chunked" and kv_layout != "paged":
            raise ValueError("chunked prefill scatters prompt chunks "
                             "into pool blocks; run with "
                             "kv_layout='paged'")
        if prefill_chunk < 1:
            raise ValueError(f"prefill_chunk must be >= 1, got "
                             f"{prefill_chunk}")
        if trace_every < 1:
            raise ValueError(f"trace_every must be >= 1, got {trace_every}")
        self.params = params
        self.num_slots = num_slots
        self.max_len = max_len
        self.chunk = chunk
        self.eos_id = eos_id
        self.trace_every = trace_every
        self.kv_layout = kv_layout if M.supports_paged(cfg) else "dense"
        # the block-sparse decode kernel reads through the block table,
        # so it only exists on the paged layout; families that fell back
        # to dense silently keep the gather/dense read path, mirroring
        # the ssm dense fallback below
        self.decode_attn = decode_attn if self.kv_layout == "paged" \
            else "gather"
        # decode_attn rides ArchConfig (like head_entropy) so every
        # family's decode threads it to layers.apply_attention without
        # signature churn; params are structure-independent of it
        self.cfg = cfg = dataclasses.replace(cfg,
                                             decode_attn=self.decode_attn)
        # prefix reuse additionally needs prompt KV that is a pure
        # function of the token IDs (see registry.supports_prefix_cache);
        # unsupported families silently serve cold, like the ssm
        # dense fallback above
        self.prefix_cache = (prefix_cache and self.kv_layout == "paged"
                             and M.supports_prefix_cache(cfg))
        self.kv_block = kv_block
        self.table_width = M.paged_table_width(max_len, kv_block)
        # default pool = full dense capacity: no admission change, the
        # savings then show up as peak blocks in use < blocks allocated
        self.kv_blocks = (kv_blocks if kv_blocks is not None
                          else num_slots * self.table_width)
        if self.kv_blocks < 1:
            raise ValueError(f"kv_blocks must be >= 1, got {kv_blocks}")
        paged = self.kv_layout == "paged"
        # prompt-length bucketing: padding-safe families right-pad cold
        # prompts to the next kv_block multiple, so the jitted batch
        # prefill compiles once per BUCKET instead of once per distinct
        # prompt length (prefill_compiles in the run stats); recurrent
        # families keep exact lengths
        self.pad_prompts = M.supports_prompt_padding(cfg)
        # chunked prefill needs the per-family prefill_chunk walker and
        # the paged layout; others fall back to batch silently, like the
        # ssm dense fallback above
        self.prefill_mode = prefill_mode if paged \
            and M.supports_chunked_prefill(cfg) else "batch"
        self.prefill_chunk = prefill_chunk
        if self.prefill_mode == "chunked" and cfg.family == "hybrid":
            # hybrid chunks walk the SSM in ssm_chunk segments; round
            # the knob up so every full chunk is a clean multiple
            sc = cfg.ssm_chunk
            self.prefill_chunk = -(-prefill_chunk // sc) * sc
        if paged:
            # paged prefill builds a minimal prompt-length strip (the
            # scatter pages it out token by token); dense keeps the
            # engine-wide max_len strip its slot write needs
            self._prefill = jax.jit(
                lambda p, t, m: M.prefill(p, cfg, t, t.shape[1], m))
            self._write = jax.jit(
                lambda c, slot, sub, row: M.write_slot(cfg, c, slot, sub,
                                                       row),
                donate_argnums=(0,))
        if self.prefill_mode == "chunked":
            # one jitted walker per family kwarg shape; span (the whole
            # prompt's static attention-reduction extent) is static, so
            # compiles scale with distinct (chunk, span) pairs — bucketed
            # prompts collapse most of those (see prefill_compiles)
            if cfg.family == "moe":
                self._chunk_fn = jax.jit(
                    lambda p, t, c, s, o, n, off, span: M.prefill_chunk(
                        p, cfg, t, c, s, o, n, span, expert_offsets=off),
                    static_argnums=(7,), donate_argnums=(2,))
            elif cfg.family == "hybrid":
                self._chunk_fn = jax.jit(
                    lambda p, t, c, s, o, n, st, span, fin:
                    M.prefill_chunk(p, cfg, t, c, s, o, n, span,
                                    state=st, finalize=fin),
                    static_argnums=(7, 8), donate_argnums=(2,))
            elif cfg.family == "encdec":
                self._chunk_first = jax.jit(
                    lambda p, t, c, s, o, n, fr, span: M.prefill_chunk(
                        p, cfg, t, c, s, o, n, span, frames=fr),
                    static_argnums=(7,), donate_argnums=(2,))
                self._chunk_fn = jax.jit(
                    lambda p, t, c, s, o, n, span: M.prefill_chunk(
                        p, cfg, t, c, s, o, n, span),
                    static_argnums=(6,), donate_argnums=(2,))
            else:
                self._chunk_fn = jax.jit(
                    lambda p, t, c, s, o, n, span: M.prefill_chunk(
                        p, cfg, t, c, s, o, n, span),
                    static_argnums=(6,), donate_argnums=(2,))
        if self.prefix_cache:
            # prefix-hit fast paths.  _suffix gathers the slot's cached
            # prefix strips from the pool, prefills ONLY the uncached
            # suffix against them (bit-exact vs the cold flash-attention
            # path; see layers.apply_attention_suffix) and scatters the
            # suffix KV at its logical offset.  _copy is the device-side
            # CoW block duplicate.
            def suffix_fn(p, c, slot, row, toks, plen):
                # gather only the blocks the hit spans (plen is static),
                # not the full table-width logical strip
                nb = -(-plen // kv_block)
                strips = {
                    n: jax.vmap(lambda pool: M.paged_gather(
                        pool, row[None, :nb]))(c[n])
                    for n in M.PAGED_KV_LEAVES if n in c}
                _, sub = M.prefill_suffix(p, cfg, toks, strips, plen)
                return M.write_slot(cfg, c, slot, sub, row, offset=plen)

            # plen is STATIC: bit-exactness vs the cold path needs the
            # suffix attention to reduce over exactly prefix + suffix
            # keys, so each (hit, suffix) length pair compiles once
            self._suffix = jax.jit(suffix_fn, static_argnums=(5,),
                                   donate_argnums=(1,))
            self._copy = jax.jit(
                lambda c, src, dst: M.copy_block(cfg, c, src, dst),
                donate_argnums=(0,))
        if not paged:
            self._prefill = jax.jit(
                lambda p, t, m: M.prefill(p, cfg, t, max_len, m))
            self._write = jax.jit(
                lambda c, slot, sub: M.write_slot(cfg, c, slot, sub),
                donate_argnums=(0,))
        # depth pinning: bucketed/suffix/chunked prefill all write
        # strips wider than the true prompt, then fix the slot's len to
        # the real token count (full-prompt prefix hits need nothing
        # else at all)
        self._set_len = jax.jit(
            lambda c, slot, n: dict(c, len=c["len"].at[slot].set(n)),
            donate_argnums=(0,))
        self._scan = jax.jit(
            S.build_scan_decode(cfg, entropy=entropy, chunk=chunk,
                                mi_threshold=mi_threshold,
                                se_threshold=se_threshold),
            donate_argnums=(2,))

    def _bucket(self, n: int) -> int:
        """Prompt-length bucket: next kv_block multiple (dense strips
        additionally clamp to max_len).  The static attention span every
        prefill path of a bucketed prompt reduces over."""
        if not self.pad_prompts:
            return n
        w = -(-n // self.kv_block) * self.kv_block
        return min(w, self.max_len) if self.kv_layout == "dense" else w

    def _start_job(self, req: Request, hit_len: int, span: int,
                   cache) -> dict:
        """Open a chunked-prefill walk over ``req``'s prompt.

        The job carries the walk offset plus whatever state the family's
        ``prefill_chunk`` threads between chunks: running expert load for
        MoE capacity splits, SSM/conv recurrent state for hybrid, and the
        encoder-frames-pending flag for encdec.
        """
        job = {"req": req, "P": len(req.prompt), "span": span,
               "off": hit_len, "first": True}
        cfg = self.cfg
        if cfg.family == "moe":
            job["ex_off"] = jnp.zeros((cfg.num_layers, cfg.num_experts),
                                      jnp.float32)
        elif cfg.family == "hybrid":
            from repro.models.ssm import dims
            d_in, H, Pd, N = dims(cfg)
            job["state"] = {
                "ssm": jnp.zeros((cfg.num_layers, 1, H, Pd, N),
                                 jnp.float32),
                "conv": jnp.zeros((cfg.num_layers, 1,
                                   cfg.ssm_conv_width - 1, d_in + 2 * N),
                                  cache["conv"].dtype)}
        return job

    def _run_chunk(self, cache, slot: int, job: dict):
        """Advance ``job`` by one prompt chunk; returns
        ``(cache, done, shape_key)``.

        Padding-safe families pad every chunk to exactly prefill_chunk
        tokens (one compile per (chunk, span) pair; trailing junk either
        scatters into the in-bucket pad region the batch path also
        writes, or drops at unmapped blocks).  Hybrid walks exact
        ssm_chunk-multiple segments instead — its recurrence is not
        padding-safe.
        """
        off, P, W = job["off"], job["P"], job["span"]
        pc = self.prefill_chunk
        real = min(pc, P - off)
        S_len = pc if self.pad_prompts else real
        toks = np.zeros((S_len,), np.int32)
        toks[:real] = job["req"].prompt[off:off + real]
        new_len = off + real
        done = new_len >= P
        args = (self.params, jnp.asarray(toks)[None], cache,
                jnp.asarray(slot, jnp.int32), jnp.asarray(off, jnp.int32),
                jnp.asarray(new_len, jnp.int32))
        fam = self.cfg.family
        variant = ""
        if fam == "moe":
            cache, job["ex_off"] = self._chunk_fn(*args, job["ex_off"], W)
        elif fam == "hybrid":
            cache, job["state"] = self._chunk_fn(*args, job["state"], W,
                                                 done)
            variant = "final" if done else ""
        elif fam == "encdec" and job["first"]:
            cache = self._chunk_first(*args, self._modality(1), W)
            variant = "first"
        else:
            cache = self._chunk_fn(*args, W)
        job["first"] = False
        job["off"] = new_len
        return cache, done, ("chunk", S_len, W, variant)

    def _modality(self, batch: int):
        cfg = self.cfg
        if cfg.family == "encdec":
            from repro.models.encdec import ENC_LEN
            return jnp.zeros((batch, ENC_LEN, cfg.d_model), jnp.float32)
        if cfg.family == "vlm":
            return jnp.zeros((batch, cfg.num_prefix_embeds, cfg.d_model),
                             jnp.float32)
        return None

    def run(self, requests: list[Request]) -> dict:
        """Serve ``requests`` to completion; returns engine metrics.

        One host sync per admission (prefill) and one per decoded chunk
        (the stacked (chunk, B) outputs) -- never per token.
        """
        paged = self.kv_layout == "paged"
        for r in requests:
            if len(r.prompt) == 0:
                raise ValueError(f"request {r.rid}: empty prompt")
            if r.max_new_tokens < 1:
                raise ValueError(
                    f"request {r.rid}: max_new_tokens must be >= 1")
            # paged tables GROW on demand (grant widens them past the
            # admission-time span), so only dense strips — whose depth
            # is baked into the cache shape — bound prompt + gen
            if not paged and len(r.prompt) + r.max_new_tokens \
                    > self.max_len:
                raise ValueError(
                    f"request {r.rid}: prompt {len(r.prompt)} + "
                    f"max_new_tokens {r.max_new_tokens} exceeds the "
                    f"slot capacity max_len={self.max_len}; cache writes "
                    f"past capacity would be dropped silently")
        alloc = None
        pcache = None
        if paged:
            alloc = BlockAllocator(self.kv_blocks, self.kv_block)
            for r in requests:
                need = alloc.blocks_for(len(r.prompt) + r.max_new_tokens)
                if need > self.kv_blocks:
                    raise ValueError(
                        f"request {r.rid}: needs {need} KV blocks but the "
                        f"pool only has {self.kv_blocks}; it could never "
                        f"be admitted")
            if self.prefix_cache:
                from repro.launch.prefix_cache import RadixPrefixCache
                pcache = RadixPrefixCache(alloc, self.kv_block)
        sched = SlotScheduler(self.num_slots, allocator=alloc,
                              table_width=self.table_width,
                              prefix_cache=pcache)
        # observable post-mortem (tests assert the pool balances even
        # when run() raises mid-decode)
        self._last_alloc, self._last_pcache = alloc, pcache
        t_start = time.perf_counter()
        for r in requests:
            r.t_submit = time.perf_counter()
            sched.submit(r)

        tok = jnp.zeros((self.num_slots,), jnp.int32)
        cache = M.make_cache(self.cfg, self.num_slots, self.max_len,
                             layout=self.kv_layout,
                             kv_block=self.kv_block,
                             num_blocks=self.kv_blocks)
        active = jnp.zeros((self.num_slots,), bool)
        flags = {"epistemic": jnp.zeros((self.num_slots,), jnp.int32),
                 "aleatoric": jnp.zeros((self.num_slots,), jnp.int32)}
        step0 = 0
        table_synced = -1            # device block-table version synced
        decode_s = 0.0
        # the jitted prefill compiles once per distinct prompt length
        # (suffix prefill: per distinct suffix length); classify each
        # admission's time accordingly so mixed-length traffic doesn't
        # launder recompiles into the steady-state stat
        compile_times: list[float] = []
        steady_times: list[float] = []
        seen_prefill_shapes: set[tuple] = set()
        modality1 = self._modality(1)
        # prefix-cache counters + per-chunk scheduler/pool trace
        pc_hits = pc_misses = pc_cow = 0
        pc_tokens = pc_saved = 0
        sched_trace: list[dict] = []
        chunks_run = 0
        # decode-attention HBM accounting (paged): physical KV blocks the
        # selected read path touches per decode step vs the full logical
        # span the gather path materializes (kernel skip rule in host
        # arithmetic, kernels.paged_attention.kv_blocks_read)
        attn_blocks_read = 0
        attn_blocks_span = 0
        # chunked-prefill bookkeeping: slot -> in-flight prompt walk
        # (offset + family carry), FIFO order of pending walks, and the
        # slots currently DECODING (mid-prefill slots sit in the scan
        # batch inactive; their junk steps are overwritten by the next
        # chunk's scatter, see models.layers.apply_attention_chunk)
        prefilling: dict[int, dict] = {}
        jobs: collections.deque[int] = collections.deque()
        decoding: set[int] = set()
        prefill_chunks = 0
        preemptions = 0
        # decode-token inter-arrival: one timestamp per scan that served
        # at least one decoding slot — the stall a long batch prefill
        # injects between consecutive chunks is exactly what chunked
        # prefill bounds (decode_interarrival_p99_s)
        arrivals: list[float] = []

        def activate(slot, req):
            nonlocal tok, active, flags
            tok = tok.at[slot].set(int(req.prompt[-1]))
            active = active.at[slot].set(True)
            flags = {k: v.at[slot].set(0) for k, v in flags.items()}
            decoding.add(slot)

        def classify(shape_key, dt):
            if shape_key in seen_prefill_shapes:
                steady_times.append(dt)
            else:
                seen_prefill_shapes.add(shape_key)
                compile_times.append(dt)

        def sync_table():
            # re-upload the device block table (tiny: slots x MB) only
            # when the host copy changed; a width change alters the
            # cache shape, so downstream jits retrace once per growth
            nonlocal cache, table_synced
            if sched.table_version != table_synced:
                cache = dict(cache, block_table=jnp.asarray(
                    sched.block_tables))
                table_synced = sched.table_version

        try:
            while sched.has_work():
                admitted = sched.admit()
                if paged:
                    # admissions mutate the host tables (and may WIDEN
                    # them); the device copy must match before any
                    # prefill write installs a row at the new width
                    sync_table()
                for slot, req in admitted:
                    t0 = time.perf_counter()
                    info = sched.prefix_admit(slot) if paged else None
                    hit_len = info.tokens if info is not None else 0
                    P = len(req.prompt)
                    W = self._bucket(P)
                    if info is not None and info.cow is not None:
                        # the shared tail block is about to be written at the
                        # divergence point: duplicate it device-side and let
                        # the scheduler drop this slot's ref on the original
                        src, dst = info.cow
                        cache = self._copy(cache, jnp.asarray(src, jnp.int32),
                                           jnp.asarray(dst, jnp.int32))
                        sched.finish_cow(slot)
                        pc_cow += 1
                    slot_ = jnp.asarray(slot, jnp.int32)
                    shape_key: Optional[tuple] = None
                    if hit_len == P:
                        # whole prompt resident: zero prefill compute — the
                        # decode loop only needs the slot's depth
                        cache = self._set_len(cache, slot_,
                                              jnp.asarray(P, jnp.int32))
                        shape_key = ("hit",)
                        activate(slot, req)
                    elif self.prefill_mode == "chunked":
                        # enqueue an incremental prompt walk (suffix-only
                        # on a partial prefix hit — CoW already settled
                        # above) and pin the slot's depth to the resident
                        # span NOW: interleaved scans write junk at
                        # [len, len+chunk) for every slot, and a stale
                        # len would point into shared prefix blocks
                        cache = self._set_len(
                            cache, slot_, jnp.asarray(hit_len, jnp.int32))
                        prefilling[slot] = self._start_job(req, hit_len, W,
                                                           cache)
                        jobs.append(slot)
                    elif hit_len > 0:
                        # suffix padded to the same bucketed span the
                        # cold path reduces over (W - hit junk tokens):
                        # equal extents keep hit and cold bit-identical
                        stoks = np.zeros((W - hit_len,), np.int32)
                        stoks[:P - hit_len] = req.prompt[hit_len:]
                        cache = self._suffix(
                            self.params, cache, slot_,
                            jnp.asarray(sched.block_tables[slot]),
                            jnp.asarray(stoks)[None], hit_len)
                        if W > P:
                            cache = self._set_len(
                                cache, slot_, jnp.asarray(P, jnp.int32))
                        shape_key = ("suffix", hit_len, W - hit_len)
                        activate(slot, req)
                    else:
                        toks = np.zeros((W,), np.int32)
                        toks[:P] = req.prompt
                        _, sub = self._prefill(
                            self.params, jnp.asarray(toks)[None],
                            modality1)
                        if paged:
                            cache = self._write(
                                cache, slot_, sub,
                                jnp.asarray(sched.block_tables[slot]))
                        else:
                            cache = self._write(cache, slot_, sub)
                        if W > P:
                            # junk pad KV stays masked above the true len
                            cache = self._set_len(
                                cache, slot_, jnp.asarray(P, jnp.int32))
                        shape_key = ("cold", W)
                        activate(slot, req)
                    if info is not None:
                        pc_hits += bool(hit_len)
                        pc_misses += not hit_len
                        pc_tokens += P
                        pc_saved += hit_len
                    if shape_key is not None:
                        jax.block_until_ready(cache)
                        classify(shape_key, time.perf_counter() - t0)

                if jobs:
                    # at most ONE prompt chunk per engine iteration
                    # (Sarathi-style): the head walk advances by
                    # prefill_chunk tokens, then the decode scan below
                    # still runs for every active slot
                    slot = jobs[0]
                    job = prefilling[slot]
                    req = job["req"]
                    t0 = time.perf_counter()
                    cache, done, shape_key = self._run_chunk(cache, slot,
                                                             job)
                    prefill_chunks += 1
                    jax.block_until_ready(cache)
                    classify(shape_key, time.perf_counter() - t0)
                    if done:
                        jobs.popleft()
                        del prefilling[slot]
                        # activate BEFORE this iteration's scan: the
                        # slot's first real decode tokens come from it
                        # (no junk window between prefill and decode)
                        activate(slot, req)

                if paged:
                    # incremental grant: map the blocks the coming chunk
                    # can write, on demand from the pool (capped at each
                    # request's prompt+max_new budget); re-upload the
                    # device table (tiny: slots x MB) only when
                    # something actually changed since the last chunk
                    for slot, req in sched.active():
                        if slot in prefilling:
                            continue     # prompt blocks mapped at admission
                        ids = sched.grant(slot, len(req.prompt)
                                          + min(len(req.tokens) + self.chunk,
                                                req.max_new_tokens))
                        if ids is None:
                            # the pool cannot grow this slot even after
                            # LRU-evicting cached blocks: preempt — blocks
                            # release, output clears, the request restarts
                            # from the queue FRONT
                            sched.preempt(slot)
                            req.tokens.clear()
                            for name in ("H", "SE", "MI", "p_max"):
                                getattr(req, name).clear()
                            req.epistemic_flags = 0
                            req.aleatoric_flags = 0
                            decoding.discard(slot)
                            active = active.at[slot].set(False)
                            preemptions += 1
                    sync_table()

                if chunks_run % self.trace_every == 0:
                    # downsampled pool/queue snapshot: a long run would
                    # otherwise grow host memory (and the results
                    # payload) by one dict per chunk, unbounded
                    sched_trace.append(sched.pool_stats())
                if not decoding:
                    if not jobs and not admitted:
                        raise RuntimeError(
                            "scheduler stalled: queued requests, no "
                            "admission, nothing prefilling or decoding")
                    continue             # prefill-only iteration: no scan
                if paged:
                    MB = sched.block_tables.shape[1]
                    # the gather path materializes every slot's full
                    # logical span each step, occupied or not
                    attn_blocks_span += self.num_slots * MB * self.chunk
                    if self.decode_attn == "kernel":
                        # the kernel reads only mapped blocks under
                        # each occupied slot's depth
                        for slot, occupant in sched.active():
                            if slot in prefilling:
                                continue
                            len0 = len(occupant.prompt) \
                                + len(occupant.tokens)
                            mapped = sched.mapped_blocks(slot)
                            attn_blocks_read += sum(
                                kv_blocks_read(len0 + t + 1, mapped,
                                               self.kv_block, MB)
                                for t in range(self.chunk))
                chunks_run += 1
                t0 = time.perf_counter()
                tok, cache, flags, ys = self._scan(
                    self.params, tok, cache, jnp.asarray(step0, jnp.int32),
                    active, flags)
                ys = jax.device_get(ys)            # the chunk's single sync
                arrivals.append(time.perf_counter())
                decode_s += time.perf_counter() - t0
                step0 += self.chunk

                for slot, req in sched.active():
                    if slot in prefilling:
                        continue         # mid-prefill: junk steps, no harvest
                    for t in range(self.chunk):
                        tk = int(ys["token"][t, slot])
                        req.tokens.append(tk)
                        for name in ("H", "SE", "MI", "p_max"):
                            getattr(req, name).append(float(ys[name][t, slot]))
                        req.epistemic_flags += int(ys["epistemic"][t, slot])
                        req.aleatoric_flags += int(ys["aleatoric"][t, slot])
                        done_eos = self.eos_id is not None and tk == self.eos_id
                        if done_eos or len(req.tokens) >= req.max_new_tokens:
                            req.t_finish = time.perf_counter()
                            req.finish_reason = "eos" if done_eos else "length"
                            sched.evict(slot)
                            decoding.discard(slot)
                            active = active.at[slot].set(False)
                            break

        except BaseException:
            # eviction / exception / early-exit path: slots mid-decode
            # still hold blocks — release them so the pool balances even
            # when the run dies (evict also settles any pending CoW ref
            # and donates prompt blocks to the prefix tree, exactly like
            # a clean eviction would have)
            for slot, _ in list(sched.active()):
                sched.evict(slot)
            raise
        finally:
            # leak check on EVERY exit path, clean drain or not: each
            # block is either free or held by the prefix cache (cached
            # refcounts included) and no reservation is outstanding
            # (tests/test_paged_attention.py::TestEngineRobustness::
            # test_mid_run_exception_releases_blocks)
            if alloc is not None:
                cached_end = pcache.cached_blocks() if pcache else 0
                if alloc._reserved or alloc.in_use != cached_end:
                    raise RuntimeError(
                        f"block leak after drain: {alloc.in_use} in use "
                        f"vs {cached_end} cached, {alloc._reserved} "
                        "reserved")

        total_s = time.perf_counter() - t_start
        gen_tokens = sum(len(r.tokens) for r in requests)
        # KV residency accounting: dense permanently owns num_slots
        # strips of max_len; paged owns only the blocks actually mapped
        # (peak over the run), which is what mixed-length traffic saves
        kv_alloc_bytes = M.kv_bytes(cache)
        if paged:
            token_bytes = kv_alloc_bytes / (self.kv_blocks * self.kv_block)
            block_bytes = kv_alloc_bytes // self.kv_blocks
            kv_stats = {
                "layout": "paged",
                "block_tokens": self.kv_block,
                "blocks_total": self.kv_blocks,
                "blocks_peak": alloc.peak_in_use,
                "bytes_in_use_peak": alloc.peak_in_use * block_bytes,
                "bytes_dense_equiv": int(token_bytes * self.num_slots
                                         * self.max_len),
            }
        else:
            kv_stats = {
                "layout": "dense",
                "bytes_in_use_peak": kv_alloc_bytes,
                "bytes_dense_equiv": kv_alloc_bytes,
            }
        # block-sparse decode attention accounting: KV bytes the selected
        # read path pulls from HBM per decode step vs the full logical
        # span (what gather materializes regardless of residency)
        steps_run = chunks_run * self.chunk
        if paged:
            read_blocks = attn_blocks_read if self.decode_attn == "kernel" \
                else attn_blocks_span
            decode_attn_stats = {
                "mode": self.decode_attn,
                "kv_bytes_read_per_step": read_blocks * block_bytes
                / max(steps_run, 1),
                "kv_bytes_span_per_step": attn_blocks_span * block_bytes
                / max(steps_run, 1),
                "kv_blocks_read": read_blocks,
                "kv_blocks_span": attn_blocks_span,
            }
        else:
            decode_attn_stats = {"mode": "gather"}
        lat = np.array([r.latency_s for r in requests]) if requests \
            else np.zeros((1,))
        epi = sum(r.epistemic_flags for r in requests)
        alea = sum(r.aleatoric_flags for r in requests)
        return {
            "requests": requests,
            "num_requests": len(requests),
            "gen_tokens": gen_tokens,
            "total_s": total_s,
            "decode_s": decode_s,
            # first prefill per prompt length includes compilation; the
            # rest are steady-state dispatch
            "prefill_compile_s": float(np.sum(compile_times)),
            "prefill_steady_s": float(np.mean(steady_times))
            if steady_times else 0.0,
            "decode_tok_per_s": gen_tokens / max(decode_s, 1e-9),
            "e2e_tok_per_s": gen_tokens / max(total_s, 1e-9),
            "latency_p50_s": float(np.percentile(lat, 50)),
            # nearest-rank (no interpolation): at small N a linear-
            # interpolated p99 fabricates a tail latency no request
            # experienced; "higher" reports a latency that actually
            # happened (= max below 100 requests)
            "latency_p99_s": float(np.percentile(lat, 99,
                                                 method="higher")),
            "latency_max_s": float(lat.max()),
            "kv": kv_stats,
            # block-sparse decode kernel vs gather HBM traffic
            "decode_attn": decode_attn_stats,
            # radix prefix cache over the paged pool: zero-compute hit
            # spans, CoW divergence copies, LRU pressure evictions
            "prefix_cache": {
                "enabled": self.prefix_cache,
                "hits": pc_hits,
                "misses": pc_misses,
                "hit_rate": pc_hits / max(pc_hits + pc_misses, 1),
                "prompt_tokens": pc_tokens,
                "prompt_tokens_saved": pc_saved,
                "saved_frac": pc_saved / max(pc_tokens, 1),
                "cow_copies": pc_cow,
                "cache_evictions": pcache.evictions if pcache else 0,
                "blocks_cached_end": (pcache.cached_blocks()
                                      if pcache else 0),
            },
            # scheduler snapshot (queue depth + pool occupancy) every
            # trace_every chunks — downsampled so long runs don't grow
            # host memory linearly in chunks decoded
            "sched_trace": sched_trace,
            "sched_trace_every": self.trace_every,
            "chunks_run": chunks_run,
            # chunked-prefill / growable-table telemetry
            "prefill_mode": self.prefill_mode,
            "prefill_chunk": self.prefill_chunk,
            "prefill_chunks": prefill_chunks,
            # distinct prefill/chunk shapes traced (bucketing collapses
            # per-prompt-length recompiles to one per kv_block bucket)
            "prefill_compiles": len(seen_prefill_shapes),
            "table_growths": sched.table_growths,
            "preemptions": preemptions,
            # worst gap between consecutive decode-serving scans: the
            # stall a monolithic batch prefill injects mid-stream, which
            # interleaved chunked prefill bounds at ~one chunk's compute
            "decode_interarrival_p99_s": float(np.percentile(
                np.diff(arrivals), 99, method="higher"))
            if len(arrivals) >= 2 else 0.0,
            "epistemic_flags": int(epi),
            "aleatoric_flags": int(alea),
            "flags_per_1k_tokens": {
                "epistemic": 1000.0 * epi / max(gen_tokens, 1),
                "aleatoric": 1000.0 * alea / max(gen_tokens, 1),
            },
            # device-side telemetry from the scan carry: per-slot totals a
            # pure-device driver could read without syncing ys.  Upper-
            # bounds the exact host accounting above (a request finishing
            # mid-chunk keeps counting until its chunk boundary).
            "device_flag_counters": {
                k: np.asarray(v).tolist() for k, v in flags.items()
            },
        }


# ---------------------------------------------------------------------------
# per-token reference loop (parity oracle + benchmark baseline)
# ---------------------------------------------------------------------------

def decode_loop_reference(params, cfg, tokens, gen_len: int, *,
                          entropy: Optional[KernelEntropy] = None,
                          max_len: Optional[int] = None,
                          modality=None, decode_fn=None) -> dict:
    """The pre-engine decode driver: one jitted step + one host sync per
    token over a statically batched prompt matrix.  Scan decode must
    reproduce this loop's token stream exactly in operand-entropy mode
    (same fold_in(base, global_step) noise; tested in test_serve.py).

    ``decode_fn`` lets benchmarks pass a pre-compiled step so the timed
    loop measures steady-state dispatch, not compilation.
    """
    tokens = jnp.asarray(tokens)
    B, P = tokens.shape
    max_len = max_len or P + gen_len
    _, cache = M.prefill(params, cfg, tokens, max_len, modality)
    decode = decode_fn or jax.jit(S.build_decode_step(cfg, entropy=entropy),
                                  donate_argnums=(2,))
    tok = tokens[:, -1]
    rows = {"token": [], "H": [], "SE": [], "MI": [], "p_max": []}
    t0 = time.perf_counter()
    for i in range(gen_len):
        out, cache = decode(params, tok, cache, jnp.asarray(i, jnp.int32))
        tok = out["next_token"]
        rows["token"].append(np.asarray(tok))        # per-token sync
        for k in ("H", "SE", "MI", "p_max"):
            rows[k].append(np.asarray(out[k]))
    decode_s = time.perf_counter() - t0
    return {name: np.stack(vals) for name, vals in rows.items()} | {
        "decode_s": decode_s,
        "decode_tok_per_s": gen_len * B / max(decode_s, 1e-9),
    }


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def make_requests(args, cfg) -> list[Request]:
    stream = TokenStreamState(seed=args.seed, host=0, num_hosts=1)
    toks, _ = token_batch(stream, args.num_requests, args.prompt_len,
                          cfg.vocab_size)
    toks = np.asarray(toks, np.int32).copy()
    if args.shared_prefix:
        # shared-system-prompt traffic: every request opens with the
        # same template tokens (what the prefix cache amortizes)
        n = min(args.shared_prefix, args.prompt_len)
        toks[:, :n] = toks[0, :n]
    reqs = [Request(rid=i, prompt=toks[i], max_new_tokens=args.gen_len)
            for i in range(args.num_requests)]
    if getattr(args, "long_prompt", 0):
        # one outlier request whose prompt (and so prompt + gen) can
        # exceed the admission-time table span: exercises on-demand
        # block-table growth and, in batch-prefill mode, the decode
        # stall a monolithic long prefill injects
        long_toks, _ = token_batch(TokenStreamState(seed=args.seed + 1,
                                                    host=0, num_hosts=1),
                                   1, args.long_prompt, cfg.vocab_size)
        reqs[0] = Request(rid=0,
                          prompt=np.asarray(long_toks, np.int32)[0],
                          max_new_tokens=args.gen_len)
    return reqs


def serve(args) -> dict:
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    cfg = dataclasses.replace(cfg, head_entropy=args.entropy)
    params = M.init_params(jax.random.key(args.seed), cfg)

    entropy = KernelEntropy(seed=args.seed) \
        if args.entropy == "kernel" else None
    max_len = args.prompt_len + args.gen_len + args.chunk
    kv_blocks = args.kv_blocks
    long_prompt = getattr(args, "long_prompt", 0)
    if long_prompt and kv_blocks is None and args.kv_layout == "paged":
        # the admission-time table span stays sized for the SHORT
        # prompts (that is what the long request outgrows); the pool
        # just needs enough blocks for the outlier to finish
        bf = -(-(long_prompt + args.gen_len + args.chunk) // args.kv_block)
        kv_blocks = args.slots * -(-max_len // args.kv_block) + bf
    engine = ServeEngine(
        params, cfg, num_slots=args.slots, max_len=max_len,
        chunk=args.chunk, entropy=entropy,
        mi_threshold=args.mi_threshold, se_threshold=args.se_threshold,
        eos_id=args.eos_id, kv_layout=args.kv_layout,
        kv_block=args.kv_block, kv_blocks=kv_blocks,
        prefix_cache=args.prefix_cache == "on",
        decode_attn=args.decode_attn,
        prefill_mode=args.prefill, prefill_chunk=args.prefill_chunk,
        trace_every=args.trace_every)
    result = engine.run(make_requests(args, cfg))

    # entropy HBM traffic of the head's MC draws per decoded token: the
    # xi operand is (S, B, V) f32 per decode step and a step emits B
    # tokens, so the per-token share is S*V*4; 0 on the in-kernel path
    # (TPU only — off-TPU the kernel-mode falls back to the seeded host
    # oracle, which still materializes the variates).
    in_kernel = args.entropy == "kernel" and jax.default_backend() == "tpu"
    result["entropy_mode"] = args.entropy
    result["entropy_hbm_bytes_per_token"] = 0 if in_kernel else \
        cfg.mc_samples * cfg.vocab_size * 4
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_1_5b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--slots", type=int, default=4,
                    help="concurrent decode slots (the decode batch)")
    ap.add_argument("--num-requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16,
                    help="max new tokens per request")
    ap.add_argument("--chunk", type=int, default=8,
                    help="decode steps per device call (scan length)")
    ap.add_argument("--eos-id", type=int, default=None)
    ap.add_argument("--mi-threshold", type=float, default=0.05)
    ap.add_argument("--se-threshold", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--entropy", choices=("operand", "kernel"),
                    default="kernel",
                    help="'kernel': seed-driven head draws, generated "
                         "in-kernel on TPU (0 HBM entropy bytes); "
                         "'operand': legacy key-threaded xi tensor")
    ap.add_argument("--kv-layout", choices=("dense", "paged"),
                    default="dense",
                    help="'paged': self-attention KV in a global pool of "
                         "--kv-block-token blocks behind per-slot block "
                         "tables (admission = enough blocks free); "
                         "'dense': one max_len strip per slot, the "
                         "bit-exact reference layout")
    ap.add_argument("--kv-block", type=int, default=16,
                    help="tokens per KV block (paged layout)")
    ap.add_argument("--kv-blocks", type=int, default=None,
                    help="pool size in blocks (default: full dense "
                         "capacity, slots * ceil(max_len / kv_block))")
    ap.add_argument("--decode-attn", choices=("kernel", "gather"),
                    default="gather",
                    help="paged decode attention read path: 'kernel' "
                         "runs the block-sparse Pallas kernel straight "
                         "over the block pool (HBM reads scale with "
                         "tokens cached); 'gather' materializes the full "
                         "logical span, the bit-exact reference")
    ap.add_argument("--prefill", choices=("batch", "chunked"),
                    default="batch",
                    help="'chunked': interleave up to --prefill-chunk "
                         "prompt tokens of ONE admitting request with "
                         "every decode chunk (Sarathi-style) so running "
                         "streams never stall behind a long prefill; "
                         "'batch': whole-prompt prefill at admission, "
                         "the bit-exact reference (needs --kv-layout "
                         "paged for 'chunked')")
    ap.add_argument("--prefill-chunk", type=int, default=32,
                    help="prompt tokens per interleaved prefill chunk "
                         "(rounded up to ssm_chunk on hybrid)")
    ap.add_argument("--long-prompt", type=int, default=0,
                    help="give request 0 a prompt of N tokens (its "
                         "prompt + gen may exceed the admission-time "
                         "table span — block tables grow on demand)")
    ap.add_argument("--trace-every", type=int, default=1,
                    help="record the scheduler/pool snapshot every N "
                         "chunks (1 = every chunk, the CI default; "
                         "raise it on long runs to bound host memory)")
    ap.add_argument("--prefix-cache", choices=("on", "off"),
                    default="off",
                    help="'on': radix prefix cache over the paged pool — "
                         "prompts sharing a cached prefix map its blocks "
                         "read-only (zero prefill compute for the hit "
                         "span, copy-on-write at divergence); needs "
                         "--kv-layout paged")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="make the first N prompt tokens identical "
                         "across requests (shared-system-prompt traffic "
                         "for the prefix cache)")
    args = ap.parse_args()
    r = serve(args)
    print(f"served {r['num_requests']} requests / {r['gen_tokens']} tokens "
          f"in {r['total_s']:.2f}s")
    print(f"prefill compile {r['prefill_compile_s']:.2f}s  "
          f"steady {r['prefill_steady_s'] * 1e3:.1f}ms  "
          f"({r['prefill_compiles']} shapes)")
    print(f"prefill: {r['prefill_mode']} mode"
          + (f", {r['prefill_chunks']} chunks of {r['prefill_chunk']}"
             if r['prefill_mode'] == "chunked" else "")
          + f"  decode inter-arrival p99 "
            f"{r['decode_interarrival_p99_s'] * 1e3:.1f}ms")
    if r["kv"]["layout"] == "paged":
        print(f"tables: {r['table_growths']} growths, "
              f"{r['preemptions']} preemptions")
    print(f"decode {r['decode_tok_per_s']:.1f} tok/s "
          f"(e2e {r['e2e_tok_per_s']:.1f})  "
          f"latency p50 {r['latency_p50_s']:.2f}s "
          f"p99 {r['latency_p99_s']:.2f}s "
          f"max {r['latency_max_s']:.2f}s")
    print(f"epistemic flags {r['epistemic_flags']}  "
          f"aleatoric flags {r['aleatoric_flags']}  "
          f"(per 1k tokens: {r['flags_per_1k_tokens']['epistemic']:.1f} / "
          f"{r['flags_per_1k_tokens']['aleatoric']:.1f})")
    print(f"entropy: {r['entropy_mode']} path, "
          f"{r['entropy_hbm_bytes_per_token'] / 1e6:.2f} MB/token "
          f"of randomness over HBM")
    kv = r["kv"]
    if kv["layout"] == "paged":
        print(f"kv: paged, {kv['blocks_peak']}/{kv['blocks_total']} blocks "
              f"peak ({kv['block_tokens']} tokens each) — "
              f"{kv['bytes_in_use_peak'] / 1e6:.2f} MB in use vs "
              f"{kv['bytes_dense_equiv'] / 1e6:.2f} MB dense strips")
        da = r["decode_attn"]
        print(f"decode attn: {da['mode']} — "
              f"{da['kv_bytes_read_per_step'] / 1e3:.1f} KB KV read/step "
              f"vs {da['kv_bytes_span_per_step'] / 1e3:.1f} KB full "
              f"logical span")
    else:
        print(f"kv: dense strips, {kv['bytes_in_use_peak'] / 1e6:.2f} MB "
              f"resident for the whole run")
    pc = r["prefix_cache"]
    if pc["enabled"]:
        print(f"prefix cache: {pc['hits']}/{pc['hits'] + pc['misses']} "
              f"admissions hit ({pc['hit_rate']:.0%}), "
              f"{pc['prompt_tokens_saved']}/{pc['prompt_tokens']} prefill "
              f"tokens saved ({pc['saved_frac']:.0%}), "
              f"{pc['cow_copies']} CoW copies, "
              f"{pc['cache_evictions']} LRU evictions, "
              f"{pc['blocks_cached_end']} blocks cached at exit")
    print("MI per request:")
    for r_ in r["requests"]:
        print(f"  #{r_.rid} ({r_.finish_reason}): "
              + np.array2string(np.asarray(r_.MI), precision=4))


if __name__ == "__main__":
    main()
