"""Continuous-batching uncertainty serving engine — CLI + import surface.

The deployment analog of the paper's high-throughput trustworthy
inference: a queue of requests is served through a fixed set of decode
slots over one slot-indexed KV cache, decoding ``--chunk`` tokens per
device call with the (H, SE, MI) uncertainty triplet and the
epistemic/aleatoric gating flags in the scan carry — one host sync per
chunk instead of one per token.

The engine itself lives in the layered ``launch.engine`` package (one
module per concern; see its __init__ docstring and
docs/architecture.md):

  engine.ServeEngine        policy + the per-chunk serve loop
  engine.SlotScheduler      admission / grants / preemption (host numpy)
  engine.BlockAllocator     refcounted paged-KV block pool accounting
  engine.ModelRunner        compiled callables + ALL device placement
  engine.ServeStats         run counters + the metrics payload

This module keeps the historical import surface (``from
repro.launch.serve import ServeEngine, SlotScheduler, BlockAllocator,
Request, decode_loop_reference`` all still work) and the CLI.

Serving features (each with its bit-exact reference; see docs/serving.md):
``--kv-layout paged`` blocks the self-attention KV behind per-slot
block tables (dense is the reference); ``--prefix-cache on`` adds the
copy-on-write radix prefix cache over the pool; ``--decode-attn
kernel`` swaps the decode read path to the block-sparse Pallas kernel
(gather is the reference); ``--prefill chunked`` interleaves
Sarathi-style prompt chunks with running decode (batch is the
reference); block tables GROW on demand and exhausted grants preempt;
``--spec-decode on`` runs uncertainty-gated speculative rounds (k-step
shared-body draft + one batched full-sample verify, MI-gated per slot)
whose accepted stream is bitwise identical to spec-decode off in
operand-entropy mode (tests/test_spec_decode.py).

``--mesh DxM`` (e.g. ``--mesh 1x4``) serves decode tensor-parallel
over the ``model`` axis of a debug mesh: parameters shard by the
serve-TP rules (attention/ff/vocab columns), the paged KV pool shards
on its kv-head axis, host scheduler state stays in numpy, and decode
is BIT-EXACT vs the unsharded engine in operand-entropy mode
(tests/test_mesh_runner.py; ``launch.engine.mesh_check`` is the
standalone checker).  On CPU, force devices with
``XLA_FLAGS=--xla_force_host_platform_device_count=4``.

Container-scale: reduced config, debug mesh.  Full-size serving shapes
(prefill_32k / decode_32k / long_500k) are compile-proven by launch.dryrun.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2_1_5b \
      --slots 4 --num-requests 8 --prompt-len 32 --gen-len 16 --chunk 8
"""

from __future__ import annotations

import argparse
import dataclasses
import json

import jax
import numpy as np

from repro.configs.registry import get_config, reduced
from repro.core.entropy import KernelEntropy
from repro.data.synthetic import TokenStreamState, token_batch
from repro.launch.engine import (BlockAllocator, EscalationLane, FifoPolicy,
                                 ModelRunner, PrefixAdmit, PriorityPolicy,
                                 Request, SchedPolicy, ServeEngine,
                                 ServeStats, SlotScheduler,
                                 decode_loop_reference, get_policy,
                                 resolve_mesh)
from repro.models import registry as M

__all__ = [
    "BlockAllocator", "EscalationLane", "FifoPolicy", "ModelRunner",
    "PrefixAdmit", "PriorityPolicy", "Request", "SchedPolicy",
    "ServeEngine", "ServeStats", "SlotScheduler",
    "decode_loop_reference", "get_policy", "resolve_mesh",
    "make_requests", "serve", "main",
]


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def make_requests(args, cfg) -> list[Request]:
    stream = TokenStreamState(seed=args.seed, host=0, num_hosts=1)
    toks, _ = token_batch(stream, args.num_requests, args.prompt_len,
                          cfg.vocab_size)
    toks = np.asarray(toks, np.int32).copy()
    if args.shared_prefix:
        # shared-system-prompt traffic: every request opens with the
        # same template tokens (what the prefix cache amortizes)
        n = min(args.shared_prefix, args.prompt_len)
        toks[:, :n] = toks[0, :n]
    # mixed-priority / SLO / bursty-arrival traffic: each comma list is
    # cycled across the request indices, so "--priorities 0,2,2,2" makes
    # every fourth request high-priority (class 0) without a trace file
    prios = [int(x) for x in args.priorities.split(",")] \
        if getattr(args, "priorities", "") else [0]
    slos = [float(x) / 1e3 if float(x) > 0 else None
            for x in args.slo_ms.split(",")] \
        if getattr(args, "slo_ms", "") else [None]
    arrivals = [int(x) for x in args.arrivals.split(",")] \
        if getattr(args, "arrivals", "") else [0]
    reqs = [Request(rid=i, prompt=toks[i], max_new_tokens=args.gen_len,
                    priority=prios[i % len(prios)],
                    slo_s=slos[i % len(slos)],
                    arrival_step=arrivals[i % len(arrivals)])
            for i in range(args.num_requests)]
    if getattr(args, "long_prompt", 0):
        # one outlier request whose prompt (and so prompt + gen) can
        # exceed the admission-time table span: exercises on-demand
        # block-table growth and, in batch-prefill mode, the decode
        # stall a monolithic long prefill injects
        long_toks, _ = token_batch(TokenStreamState(seed=args.seed + 1,
                                                    host=0, num_hosts=1),
                                   1, args.long_prompt, cfg.vocab_size)
        reqs[0] = Request(rid=0,
                          prompt=np.asarray(long_toks, np.int32)[0],
                          max_new_tokens=args.gen_len,
                          priority=reqs[0].priority, slo_s=reqs[0].slo_s,
                          arrival_step=reqs[0].arrival_step)
    return reqs


def serve(args) -> dict:
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    cfg = dataclasses.replace(cfg, head_entropy=args.entropy)
    params = M.init_params(jax.random.key(args.seed), cfg)

    entropy = KernelEntropy(seed=args.seed) \
        if args.entropy == "kernel" else None
    max_len = args.prompt_len + args.gen_len + args.chunk
    kv_blocks = args.kv_blocks
    long_prompt = getattr(args, "long_prompt", 0)
    if long_prompt and kv_blocks is None and args.kv_layout == "paged":
        # the admission-time table span stays sized for the SHORT
        # prompts (that is what the long request outgrows); the pool
        # just needs enough blocks for the outlier to finish
        bf = -(-(long_prompt + args.gen_len + args.chunk) // args.kv_block)
        kv_blocks = args.slots * -(-max_len // args.kv_block) + bf
    engine = ServeEngine(
        params, cfg, num_slots=args.slots, max_len=max_len,
        chunk=args.chunk, entropy=entropy,
        mi_threshold=args.mi_threshold, se_threshold=args.se_threshold,
        eos_id=args.eos_id, kv_layout=args.kv_layout,
        kv_block=args.kv_block, kv_blocks=kv_blocks,
        prefix_cache=args.prefix_cache == "on",
        decode_attn=args.decode_attn,
        prefill_mode=args.prefill, prefill_chunk=args.prefill_chunk,
        trace_every=args.trace_every,
        mesh=resolve_mesh(getattr(args, "mesh", None)),
        spec_decode=args.spec_decode == "on", spec_k=args.spec_k,
        spec_mi_threshold=args.spec_mi_threshold,
        spec_draft_s=args.spec_draft_s,
        spec_k_min=getattr(args, "spec_k_min", None),
        spec_k_max=getattr(args, "spec_k_max", None),
        policy=getattr(args, "policy", "fifo"),
        escalate_mi=getattr(args, "escalate_mi", None),
        escalate_s=getattr(args, "escalate_s", None))
    result = engine.run(make_requests(args, cfg))

    # entropy HBM traffic of the head's MC draws per decoded token: the
    # xi operand is (S, B, V) f32 per decode step and a step emits B
    # tokens, so the per-token share is S*V*4; 0 on the in-kernel path
    # (TPU only — off-TPU the kernel-mode falls back to the seeded host
    # oracle, which still materializes the variates).
    in_kernel = args.entropy == "kernel" and jax.default_backend() == "tpu"
    result["entropy_mode"] = args.entropy
    result["entropy_hbm_bytes_per_token"] = 0 if in_kernel else \
        cfg.mc_samples * cfg.vocab_size * 4
    result["mesh"] = (f"{engine.mesh.devices.size} devices "
                      f"{dict(engine.mesh.shape)}"
                      if engine.mesh is not None else "none")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_1_5b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--slots", type=int, default=4,
                    help="concurrent decode slots (the decode batch)")
    ap.add_argument("--num-requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16,
                    help="max new tokens per request")
    ap.add_argument("--chunk", type=int, default=8,
                    help="decode steps per device call (scan length)")
    ap.add_argument("--eos-id", type=int, default=None)
    ap.add_argument("--mi-threshold", type=float, default=0.05)
    ap.add_argument("--se-threshold", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--entropy", choices=("operand", "kernel"),
                    default="kernel",
                    help="'kernel': seed-driven head draws, generated "
                         "in-kernel on TPU (0 HBM entropy bytes); "
                         "'operand': legacy key-threaded xi tensor")
    ap.add_argument("--kv-layout", choices=("dense", "paged"),
                    default="dense",
                    help="'paged': self-attention KV in a global pool of "
                         "--kv-block-token blocks behind per-slot block "
                         "tables (admission = enough blocks free); "
                         "'dense': one max_len strip per slot, the "
                         "bit-exact reference layout")
    ap.add_argument("--kv-block", type=int, default=16,
                    help="tokens per KV block (paged layout)")
    ap.add_argument("--kv-blocks", type=int, default=None,
                    help="pool size in blocks (default: full dense "
                         "capacity, slots * ceil(max_len / kv_block))")
    ap.add_argument("--decode-attn", choices=("kernel", "gather"),
                    default="gather",
                    help="paged decode attention read path: 'kernel' "
                         "runs the block-sparse Pallas kernel straight "
                         "over the block pool (HBM reads scale with "
                         "tokens cached); 'gather' materializes the full "
                         "logical span, the bit-exact reference")
    ap.add_argument("--prefill", choices=("batch", "chunked"),
                    default="batch",
                    help="'chunked': interleave up to --prefill-chunk "
                         "prompt tokens of ONE admitting request with "
                         "every decode chunk (Sarathi-style) so running "
                         "streams never stall behind a long prefill; "
                         "'batch': whole-prompt prefill at admission, "
                         "the bit-exact reference (needs --kv-layout "
                         "paged for 'chunked')")
    ap.add_argument("--prefill-chunk", type=int, default=32,
                    help="prompt tokens per interleaved prefill chunk "
                         "(rounded up to ssm_chunk on hybrid)")
    ap.add_argument("--long-prompt", type=int, default=0,
                    help="give request 0 a prompt of N tokens (its "
                         "prompt + gen may exceed the admission-time "
                         "table span — block tables grow on demand)")
    ap.add_argument("--trace-every", type=int, default=1,
                    help="record the scheduler/pool snapshot every N "
                         "chunks (1 = every chunk, the CI default; "
                         "raise it on long runs to bound host memory)")
    ap.add_argument("--prefix-cache", choices=("on", "off"),
                    default="off",
                    help="'on': radix prefix cache over the paged pool — "
                         "prompts sharing a cached prefix map its blocks "
                         "read-only (zero prefill compute for the hit "
                         "span, copy-on-write at divergence); needs "
                         "--kv-layout paged")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="make the first N prompt tokens identical "
                         "across requests (shared-system-prompt traffic "
                         "for the prefix cache)")
    ap.add_argument("--spec-decode", choices=("on", "off"), default="off",
                    help="'on': uncertainty-gated speculative decoding — "
                         "a k-step shared-body draft proposes tokens with "
                         "a cheap head, ONE batched full-sample verify "
                         "re-draws the uncertain head at the same (slot, "
                         "depth) noise sites, and only slots whose "
                         "carried MI sits below --spec-mi-threshold "
                         "draft; the accepted stream is bitwise identical "
                         "to spec-decode off (needs --entropy operand)")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="draft positions per speculative round")
    ap.add_argument("--spec-mi-threshold", type=float, default=None,
                    help="MI gate for drafting (default: --mi-threshold); "
                         "0 never speculates")
    ap.add_argument("--spec-draft-s", type=int, default=1,
                    help="head samples for draft proposals (0 = "
                         "deterministic mean head)")
    ap.add_argument("--spec-k-min", type=int, default=None,
                    help="adaptive draft-depth floor: per-slot "
                         "acceptance EMA shrinks/grows k between "
                         "--spec-k-min and --spec-k-max (default: pin "
                         "both to --spec-k, disabling adaptation)")
    ap.add_argument("--spec-k-max", type=int, default=None,
                    help="adaptive draft-depth ceiling (see "
                         "--spec-k-min)")
    ap.add_argument("--policy", choices=("fifo", "priority"),
                    default="fifo",
                    help="scheduling policy: 'fifo' admits strictly in "
                         "submission order (the bit-exact reference); "
                         "'priority' ranks by (--priorities class, SLO "
                         "deadline, order) and preempts strictly "
                         "lower-priority decoding slots under pressure")
    ap.add_argument("--priorities", default="",
                    help="comma list of priority classes cycled across "
                         "requests (lower = better; e.g. 0,2,2,2); "
                         "empty = all class 0")
    ap.add_argument("--slo-ms", default="",
                    help="comma list of SLO deadlines in ms cycled "
                         "across requests (0 = none); the priority "
                         "policy serves earliest-deadline-first inside "
                         "a class")
    ap.add_argument("--arrivals", default="",
                    help="comma list of arrival steps cycled across "
                         "requests: each request joins the queue once "
                         "the engine has decoded that many steps "
                         "(bursty open-loop traces; empty = all at 0)")
    ap.add_argument("--escalate-mi", type=float, default=None,
                    help="hand a decoding request to the high-S "
                         "escalation lane when its carried MI reaches "
                         "this threshold (cf. the OOD band in "
                         "examples/blood_cell_ood.py); default: off")
    ap.add_argument("--escalate-s", type=int, default=None,
                    help="MC head samples for the escalation lane's "
                         "verify config (default: 4x the serving S); "
                         "each distinct S compiles its own sidecar "
                         "runner once")
    ap.add_argument("--mesh", default=None,
                    help="serve tensor-parallel on a DxM debug mesh "
                         "(e.g. 1x4): params + paged KV pool shard over "
                         "the model axis, bit-exact vs unsharded in "
                         "operand mode; on CPU force devices with "
                         "XLA_FLAGS="
                         "--xla_force_host_platform_device_count=4")
    ap.add_argument("--stats-json", default=None, metavar="PATH",
                    help="also dump the run's stats dict (counters only, "
                         "no per-request streams) as JSON — what the CI "
                         "smoke legs assert against")
    args = ap.parse_args()
    r = serve(args)
    print(f"served {r['num_requests']} requests / {r['gen_tokens']} tokens "
          f"in {r['total_s']:.2f}s")
    if r["mesh"] != "none":
        print(f"mesh: {r['mesh']}")
    print(f"prefill compile {r['prefill_compile_s']:.2f}s  "
          f"steady {r['prefill_steady_s'] * 1e3:.1f}ms  "
          f"({r['prefill_compiles']} shapes)")
    print(f"prefill: {r['prefill_mode']} mode"
          + (f", {r['prefill_chunks']} chunks of {r['prefill_chunk']}"
             if r['prefill_mode'] == "chunked" else "")
          + f"  decode inter-arrival p99 "
            f"{r['decode_interarrival_p99_s'] * 1e3:.1f}ms")
    if r["kv"]["layout"] == "paged":
        print(f"tables: {r['table_growths']} growths")
    print(f"policy: {r['policy']}  preemptions {r['preemptions']}")
    print(f"decode {r['decode_tok_per_s']:.1f} tok/s "
          f"(e2e {r['e2e_tok_per_s']:.1f})  "
          f"latency p50 {r['latency_p50_s']:.2f}s "
          f"p99 {r['latency_p99_s']:.2f}s "
          f"max {r['latency_max_s']:.2f}s")
    print(f"latency split: queue p99 {r['queue_time_p99_s']:.2f}s  "
          f"service p99 {r['service_time_p99_s']:.2f}s")
    if len(r["per_class"]) > 1:
        for cls, c in sorted(r["per_class"].items()):
            print(f"  class {cls}: {c['num_requests']} reqs  "
                  f"latency p50 {c['latency_p50_s']:.2f}s "
                  f"p99 {c['latency_p99_s']:.2f}s  "
                  f"queue p99 {c['queue_p99_s']:.2f}s  "
                  f"{c['escalations']} escalations  "
                  f"{c['preemptions']} preemptions")
    esc = r["escalation"]
    if esc["enabled"]:
        print(f"escalation: {esc['escalations']} requests at MI >= "
              f"{esc['mi_threshold']} finished at S={esc['verify_samples']} "
              f"({esc['tokens']} tokens, {esc['skipped_too_long']} "
              f"skipped too-long)")
    print(f"epistemic flags {r['epistemic_flags']}  "
          f"aleatoric flags {r['aleatoric_flags']}  "
          f"(per 1k tokens: {r['flags_per_1k_tokens']['epistemic']:.1f} / "
          f"{r['flags_per_1k_tokens']['aleatoric']:.1f})")
    print(f"entropy: {r['entropy_mode']} path, "
          f"{r['entropy_hbm_bytes_per_token'] / 1e6:.2f} MB/token "
          f"of randomness over HBM")
    kv = r["kv"]
    if kv["layout"] == "paged":
        print(f"kv: paged, {kv['blocks_peak']}/{kv['blocks_total']} blocks "
              f"peak ({kv['block_tokens']} tokens each) — "
              f"{kv['bytes_in_use_peak'] / 1e6:.2f} MB in use vs "
              f"{kv['bytes_dense_equiv'] / 1e6:.2f} MB dense strips")
        da = r["decode_attn"]
        print(f"decode attn: {da['mode']} — "
              f"{da['kv_bytes_read_per_step'] / 1e3:.1f} KB KV read/step "
              f"vs {da['kv_bytes_span_per_step'] / 1e3:.1f} KB full "
              f"logical span")
    else:
        print(f"kv: dense strips, {kv['bytes_in_use_peak'] / 1e6:.2f} MB "
              f"resident for the whole run")
    sd = r["spec_decode"]
    if sd["enabled"]:
        print(f"spec decode: k={sd['k']}, {sd['rounds']} rounds, "
              f"{sd['accepted']}/{sd['drafted']} proposals accepted "
              f"({sd['acceptance_rate']:.0%}), "
              f"{sd['tokens_per_round']:.2f} tokens/round, "
              f"{sd['rollbacks']} rollbacks, "
              f"{sd['gated_slot_rounds']} MI-gated slot-rounds, "
              f"{sd['full_model_calls']} full-model calls for "
              f"{r['gen_tokens']} tokens")
        if sd["k_min"] != sd["k_max"]:
            print(f"  adaptive k in [{sd['k_min']}, {sd['k_max']}]: "
                  f"round depths {sd['round_k_min']}-{sd['round_k_max']}, "
                  f"{sd['k_up']} grows / {sd['k_down']} shrinks")
    pc = r["prefix_cache"]
    if pc["enabled"]:
        print(f"prefix cache: {pc['hits']}/{pc['hits'] + pc['misses']} "
              f"admissions hit ({pc['hit_rate']:.0%}), "
              f"{pc['prompt_tokens_saved']}/{pc['prompt_tokens']} prefill "
              f"tokens saved ({pc['saved_frac']:.0%}), "
              f"{pc['cow_copies']} CoW copies, "
              f"{pc['cache_evictions']} LRU evictions, "
              f"{pc['blocks_cached_end']} blocks cached at exit")
    print("MI per request:")
    for r_ in r["requests"]:
        print(f"  #{r_.rid} ({r_.finish_reason}): "
              + np.array2string(np.asarray(r_.MI), precision=4))
    if args.stats_json:
        payload = {k: v for k, v in r.items() if k != "requests"}
        with open(args.stats_json, "w") as f:
            json.dump(payload, f, indent=2, default=float)
        print(f"stats written to {args.stats_json}")


if __name__ == "__main__":
    main()
