"""Batched uncertainty-aware serving driver.

The inference analog of the paper's deployment: a batch of requests is
prefLLed once, then decoded token by token; each decode step draws
``cfg.mc_samples`` (paper: N=10) samples of the Bayesian output head --
fused in the uncertainty-head kernel on TPU, jnp-LRT elsewhere -- and
emits the (H, SE, MI) uncertainty triplet per token alongside the greedy
token.  Tokens whose MI exceeds ``--mi-threshold`` are flagged epistemic
(the LM analog of the paper's OOD rejection); high-SE/low-MI tokens are
flagged aleatoric (ambiguous continuation).

Container-scale: reduced config, debug mesh.  Full-size serving shapes
(prefill_32k / decode_32k / long_500k) are compile-proven by launch.dryrun.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2_1_5b \
      --batch 4 --prompt-len 32 --gen-len 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config, reduced
from repro.core.entropy import KernelEntropy
from repro.data.synthetic import TokenStreamState, token_batch
from repro.launch import steps as S
from repro.models import registry as M


def serve(args) -> dict:
    import dataclasses
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    cfg = dataclasses.replace(cfg, head_entropy=args.entropy)
    key = jax.random.key(args.seed)
    params = M.init_params(key, cfg)

    stream = TokenStreamState(seed=args.seed, host=0, num_hosts=1)
    toks, _ = token_batch(stream, args.batch, args.prompt_len,
                          cfg.vocab_size)
    tokens = jnp.asarray(toks)
    max_len = args.prompt_len + args.gen_len

    modality = None
    if cfg.family == "encdec":
        from repro.models.encdec import ENC_LEN
        modality = jnp.zeros((args.batch, ENC_LEN, cfg.d_model),
                             jnp.float32)
    if cfg.family == "vlm":
        modality = jnp.zeros((args.batch, cfg.num_prefix_embeds,
                              cfg.d_model), jnp.float32)

    entropy = KernelEntropy(seed=args.seed) \
        if args.entropy == "kernel" else None
    prefill = jax.jit(lambda p, t, m: M.prefill(p, cfg, t, max_len, m),
                      static_argnames=())
    decode = jax.jit(S.build_decode_step(cfg, entropy=entropy),
                     donate_argnums=(2,))

    t0 = time.time()
    hidden, cache = M.prefill(params, cfg, tokens, max_len, modality)
    prefill_s = time.time() - t0

    tok = tokens[:, -1]
    rows = {"token": [], "H": [], "SE": [], "MI": [], "p_max": []}
    t0 = time.time()
    for i in range(args.gen_len):
        out, cache = decode(params, tok, cache, jnp.asarray(i, jnp.int32))
        tok = out["next_token"]
        for k in ("H", "SE", "MI", "p_max"):
            rows[k].append(np.asarray(out[k]))
        rows["token"].append(np.asarray(tok))
    decode_s = time.time() - t0

    mi = np.stack(rows["MI"])           # (T, B)
    se = np.stack(rows["SE"])
    flags_epi = mi > args.mi_threshold
    flags_alea = (se > args.se_threshold) & ~flags_epi
    # entropy HBM traffic of the head's MC draws per decoded token: the
    # xi operand is (S, B, V) f32 per decode step and a step emits B
    # tokens, so the per-token share is S*V*4; 0 on the in-kernel path
    # (TPU only — off-TPU the kernel-mode falls back to the seeded host
    # oracle, which still materializes the variates).
    in_kernel = args.entropy == "kernel" and jax.default_backend() == "tpu"
    entropy_bytes = 0 if in_kernel else \
        cfg.mc_samples * cfg.vocab_size * 4
    result = {
        "tokens": np.stack(rows["token"]),
        "MI": mi, "SE": se, "H": np.stack(rows["H"]),
        "p_max": np.stack(rows["p_max"]),
        "epistemic_flags": int(flags_epi.sum()),
        "aleatoric_flags": int(flags_alea.sum()),
        "prefill_s": prefill_s,
        "decode_tok_per_s": args.gen_len * args.batch / max(decode_s, 1e-9),
        "entropy_mode": args.entropy,
        "entropy_hbm_bytes_per_token": entropy_bytes,
    }
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_1_5b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--mi-threshold", type=float, default=0.05)
    ap.add_argument("--se-threshold", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--entropy", choices=("operand", "kernel"),
                    default="kernel",
                    help="'kernel': seed-driven head draws, generated "
                         "in-kernel on TPU (0 HBM entropy bytes); "
                         "'operand': legacy key-threaded xi tensor")
    args = ap.parse_args()
    r = serve(args)
    print(f"prefill {r['prefill_s']:.2f}s  "
          f"decode {r['decode_tok_per_s']:.1f} tok/s  "
          f"epistemic flags {r['epistemic_flags']}  "
          f"aleatoric flags {r['aleatoric_flags']}")
    print(f"entropy: {r['entropy_mode']} path, "
          f"{r['entropy_hbm_bytes_per_token'] / 1e6:.2f} MB/token "
          f"of randomness over HBM")
    print("MI (T,B):\n", np.array2string(r["MI"], precision=4))


if __name__ == "__main__":
    main()
