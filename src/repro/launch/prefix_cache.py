"""Copy-on-write radix prefix cache over the paged KV pool.

The paper's economics say Monte-Carlo sampling is nearly free once the
entropy comes from the photonic substrate — so the digital side must not
re-pay prefill for every stochastic sample of the same prompt, or for
every user hitting the same system-prompt template.  This module is the
host-side half of that amortization: a radix tree over token-ID prefixes
whose nodes hold *refcounted* KV blocks from the serving engine's
``BlockAllocator`` pool.

Structure: tree edges are BLOCK-granular — each node owns exactly one
physical block and is keyed by the ``block_size`` token IDs written into
it (a leaf may hold a partial block, ``ntok < block_size``).  Matching
is TOKEN-granular: the walk descends whole-block exact matches and may
finish with a partial match *into* the last block (the longest common
prefix against any child's key).  That last partially-matched block is
what makes copy-on-write real: it is mapped into the new slot's table
read-only, and the first write at the divergence point triggers a
device-side block copy (``models.layers.copy_block``) plus a table swap.

Block lifecycle (who holds references):

  * ``BlockAllocator.alloc`` hands out a block at refcount 1 (the slot).
  * ``insert`` (called at request eviction) adopts the blocks covering
    the request's prompt into the tree: +1 ref per newly created node.
  * ``lock`` (called when admission commits to a hit) takes +1 per
    matched block for the admitted slot; slot eviction decrefs.
  * ``BlockAllocator.free`` is a decref — a block returns to the free
    list only when the last holder (slot or tree) lets go.
  * Under pool pressure the scheduler calls ``evict_lru``: leaf nodes
    whose block has no slot reference left (refcount == 1, the tree's
    own) are freed oldest-first until enough blocks come back.

The cache never touches jax: it deals purely in token IDs and block
IDs.  The engine performs the device-side CoW copy and the suffix
prefill; see ``launch.serve`` and ``docs/serving.md``.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Optional


@dataclasses.dataclass
class PrefixHit:
    """Result of a radix walk: ``tokens`` matched, covered by ``blocks``.

    ``tokens`` may end mid-block (``partial`` True): the final block is
    then only valid up to the divergence point and must be copied before
    the admitted slot writes into it (copy-on-write).
    """

    tokens: int = 0
    blocks: list = dataclasses.field(default_factory=list)
    partial: bool = False


class _Node:
    __slots__ = ("key", "ntok", "block", "children", "parent", "last_use")

    def __init__(self, key: tuple, ntok: int, block: int,
                 parent: "_Node", last_use: int):
        self.key = key                # the block's token IDs (len == ntok)
        self.ntok = ntok              # valid tokens in this block
        self.block = block            # physical block id in the pool
        self.children: dict = {}      # child.key -> child
        self.parent = parent
        self.last_use = last_use


def _common_prefix(a, b) -> int:
    n = min(len(a), len(b))
    for i in range(n):
        if a[i] != b[i]:
            return i
    return n


class RadixPrefixCache:
    """Host-side radix tree of cached prompt prefixes over the block pool.

    ``allocator`` is the engine's ``launch.serve.BlockAllocator`` (the
    refcount authority); ``block_size`` its tokens-per-block.
    """

    def __init__(self, allocator, block_size: int):
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        self.allocator = allocator
        self.block_size = block_size
        self._root = _Node(key=(), ntok=0, block=-1, parent=None,
                           last_use=0)
        self._clock = 0
        self.evictions = 0            # blocks LRU-evicted over lifetime

    # -- introspection ----------------------------------------------------

    def _nodes(self) -> Iterable[_Node]:
        stack = [self._root]
        while stack:
            n = stack.pop()
            if n is not self._root:
                yield n
            stack.extend(n.children.values())

    def cached_blocks(self) -> int:
        """Blocks currently held by the tree (each node owns one)."""
        return sum(1 for _ in self._nodes())

    # -- the radix walk ---------------------------------------------------

    def match(self, tokens) -> PrefixHit:
        """Longest cached prefix of ``tokens``: whole-block exact
        descents, then at most one token-granular partial match into a
        child's block.  Read-only apart from LRU stamps — the caller
        decides whether to commit (``lock``) after its block budget
        clears."""
        self._clock += 1
        bs = self.block_size
        toks = [int(t) for t in tokens]
        node, depth, blocks = self._root, 0, []
        while depth < len(toks):
            rest = toks[depth:]
            if len(rest) >= bs:
                child = node.children.get(tuple(rest[:bs]))
                if child is not None and child.ntok == bs:
                    child.last_use = self._clock
                    blocks.append(child.block)
                    depth += bs
                    node = child
                    continue
            best, blen = None, 0
            for child in node.children.values():
                n = _common_prefix(rest, child.key[:child.ntok])
                if n > blen:
                    best, blen = child, n
            if best is not None and blen > 0:
                best.last_use = self._clock
                blocks.append(best.block)
                depth += blen
            break
        return PrefixHit(tokens=depth, blocks=blocks,
                         partial=bool(depth % bs))

    def lock(self, hit: PrefixHit) -> None:
        """Commit a hit: the admitted slot takes a reference on every
        matched block (released by the slot's eviction decref)."""
        self.allocator.incref(hit.blocks)

    # -- insertion (at request eviction) ----------------------------------

    def insert(self, tokens, blocks: list) -> int:
        """Adopt the prompt ``tokens`` (covered, in logical order, by
        ``blocks`` — ``ceil(len(tokens) / block_size)`` of them) into the
        tree.  Blocks backing chunks already cached are NOT adopted (the
        existing node keeps serving them); newly adopted blocks get a
        tree reference (incref).  Returns the number adopted."""
        bs = self.block_size
        toks = [int(t) for t in tokens]
        need = -(-len(toks) // bs) if toks else 0
        if len(blocks) < need:
            raise ValueError(f"insert of {len(toks)} tokens needs {need} "
                             f"blocks, got {len(blocks)}")
        self._clock += 1
        node, depth, bi, adopted = self._root, 0, 0, 0
        while depth < len(toks):
            n = min(bs, len(toks) - depth)
            chunk = tuple(toks[depth:depth + n])
            if n == bs:
                child = node.children.get(chunk)
                if child is not None:
                    child.last_use = self._clock
                    node = child
                    depth += bs
                    bi += 1
                    continue
                child = _Node(chunk, bs, int(blocks[bi]), node,
                              self._clock)
                self.allocator.incref([child.block])
                node.children[chunk] = child
                node = child
                adopted += 1
            else:
                # partial tail: only adopt if no existing child already
                # covers this chunk (a longer or equal cached prefix)
                covered = any(
                    _common_prefix(chunk, c.key[:c.ntok]) >= n
                    for c in node.children.values())
                if not covered and chunk not in node.children:
                    child = _Node(chunk, n, int(blocks[bi]), node,
                                  self._clock)
                    self.allocator.incref([child.block])
                    node.children[chunk] = child
                    adopted += 1
            depth += n
            bi += 1
        return adopted

    # -- eviction ----------------------------------------------------------

    def _evictable(self, protect: frozenset) -> list:
        """Leaf nodes whose block only the tree still references."""
        return [n for n in self._nodes()
                if not n.children and n.block not in protect
                and self.allocator.refcount(n.block) == 1]

    def _drop(self, node: _Node) -> None:
        del node.parent.children[node.key]
        self.allocator.free([node.block])      # decref -> free list
        self.evictions += 1

    def evict_lru(self, want: int, protect: frozenset = frozenset()) -> int:
        """Free up to ``want`` cached-but-unreferenced blocks, oldest
        access first.  Interior nodes become evictable as their leaves
        go.  ``protect`` pins blocks (e.g. the hit being admitted right
        now).  Returns how many blocks were freed."""
        freed = 0
        while freed < want:
            cands = self._evictable(protect)
            if not cands:
                break
            self._drop(min(cands, key=lambda n: n.last_use))
            freed += 1
        return freed

    def clear(self) -> int:
        """Release every cached block (tree decref).  Blocks still
        referenced by live slots survive until those slots evict."""
        dropped = 0
        for node in list(self._nodes()):
            self.allocator.free([node.block])
            dropped += 1
        self._root.children.clear()
        return dropped
