"""Refcounted KV block pool accounting (the serving engine's memory layer).

Pure host-side (no jax): the physical pool tensors live in the model
cache (``models.*.make_cache(layout='paged')``); this module owns WHICH
block belongs to WHOM.  ``launch.prefix_cache.RadixPrefixCache`` builds
its copy-on-write sharing on exactly this interface: ``alloc`` hands a
block out at refcount 1, ``incref`` is the tree (or a slot mapping a
cached prefix) adopting it, and ``free`` is a decref that only returns
the block to the free list when the last holder lets go.
"""

from __future__ import annotations


class BlockAllocator:
    """Refcounted free-list allocator over a global pool of KV blocks.

    Pure host-side (no jax).  Reservations are TRANSIENT: the scheduler
    reserves exactly the blocks an admission or grant is about to
    ``alloc`` (the reserve/alloc pair keeps the accounting honest), not
    a request's whole-lifetime budget — decode blocks are granted on
    demand as the sequence grows, and a grant the pool can't cover is
    the scheduler's problem (LRU-evict cached blocks, else preempt the
    slot), not an up-front admission tax.  ``available()`` is free minus
    outstanding reservations.

    Blocks carry per-block REFCOUNTS so the prefix cache can share them:
    ``alloc`` hands a block out at refcount 1, ``incref`` adds a holder
    (the radix tree adopting a block, a slot mapping a cached prefix),
    and ``free`` is a decref — the block returns to the free list only
    when the last holder lets go.  Freeing a block whose refcount is
    already 0 is the double-free error it always was.
    """

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 1 or block_size < 1:
            raise ValueError("need at least one block of at least one "
                             "token")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._free: list[int] = list(range(num_blocks - 1, -1, -1))
        self._ref = [0] * num_blocks
        self._reserved = 0
        self.peak_in_use = 0

    def blocks_for(self, tokens: int) -> int:
        """Blocks needed to hold ``tokens`` KV entries (ceil)."""
        return -(-tokens // self.block_size)

    @property
    def in_use(self) -> int:
        return self.num_blocks - len(self._free)

    def available(self) -> int:
        return len(self._free) - self._reserved

    def utilization(self) -> float:
        """Fraction of the pool held or reserved — the pool-pressure
        signal risk-aware scheduling keys on (1.0 means the next
        admission/grant must evict, preempt or defer).  Traced per
        chunk in ``SlotScheduler.pool_stats``."""
        return (self.in_use + self._reserved) / self.num_blocks

    def reserve(self, n: int) -> bool:
        """Set aside ``n`` blocks for later alloc; False if they aren't
        there (the caller defers admission instead of crashing)."""
        if self.available() < n:
            return False
        self._reserved += n
        return True

    def unreserve(self, n: int) -> None:
        if n > self._reserved:
            raise ValueError(f"unreserve({n}) exceeds {self._reserved} "
                             "outstanding reservations")
        self._reserved -= n

    def alloc(self, n: int) -> list[int]:
        """Draw ``n`` physical blocks down from an existing reservation."""
        if n > self._reserved:
            raise ValueError(f"alloc({n}) without reservation "
                             f"({self._reserved} reserved)")
        self._reserved -= n
        ids = [self._free.pop() for _ in range(n)]
        for i in ids:
            self._ref[i] = 1
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        return ids

    def refcount(self, block: int) -> int:
        return self._ref[block]

    def incref(self, ids: list[int]) -> None:
        """Add a holder to live blocks (prefix-cache adoption/sharing)."""
        for i in ids:
            if self._ref[i] < 1:
                raise ValueError(f"incref of free block {i}")
            self._ref[i] += 1

    def free(self, ids: list[int]) -> None:
        """Decref; a block rejoins the free list when its last holder
        (slot or prefix-cache node) releases it.  No single holder ever
        releases one block twice in a call, so same-call duplicates are
        a caller bug caught here rather than a silent refcount steal."""
        if len(set(ids)) != len(ids):
            dupes = sorted({i for i in ids if ids.count(i) > 1})
            raise ValueError(f"double free of blocks {dupes}")
        for i in ids:
            if self._ref[i] < 1:
                raise ValueError(f"double free of blocks [{i}]")
            self._ref[i] -= 1
            if self._ref[i] == 0:
                self._free.append(i)
