"""High-S escalation lane (the engine's OOD verification sidecar).

When a decoding slot's carried MI crosses ``--escalate-mi`` the engine
hands the request to an ``EscalationLane``: a single-slot dense sidecar
driven by a second ``ModelRunner`` whose config re-draws the uncertain
head with ``--escalate-s`` MC samples instead of the serving S
(``ServeEngine.escalation_runner`` keys one runner — one jit cache —
per distinct S).  More samples shrink the MC error of the MI estimate
(see docs/uncertainty.md), so the tokens that actually ship for a
flagged-OOD request carry the better uncertainty read — the serving
analogue of routing flagged blood-cell images to a bigger verify pass
in ``examples/blood_cell_ood.py``.

The lane is deliberately primitive mechanism: one request at a time,
re-prefill of ``prompt + tokens-so-far`` into its own dense cache
(S only changes head draws, never the KV, so the replayed cache is
exactly what the main engine held), then plain scan chunks to the
request's finish.  It does ONE unit of work per engine iteration — an
admission or a decode chunk — so escalations never stall the main
pool's decode cadence.  Requests whose ``prompt + max_new_tokens``
exceed the lane's dense ``max_len`` don't fit (``fits``) and keep
decoding in the main engine, counted once in ``esc_skipped``.

Tested in tests/test_policy.py::TestEscalation.
"""

from __future__ import annotations

import collections
import time

import jax
import jax.numpy as jnp
import numpy as np


class EscalationLane:
    """One-slot high-S finish lane over a dedicated ``ModelRunner``.

    Host-side driver state only: the cache/carry live on device via the
    runner's callables, and the lane's global step counter is its own
    (operand-mode head noise is depth-keyed, so the escalated stream is
    reproducible regardless of when the engine escalated).
    """

    def __init__(self, runner, *, chunk: int, eos_id=None, pad_to=None,
                 modality=None):
        self.runner = runner
        self.chunk = chunk
        self.eos_id = eos_id
        self.pad_to = pad_to          # prompt bucket (None: exact lengths)
        self.modality = modality
        self.max_len = runner.max_len
        self.queue: collections.deque = collections.deque()
        self.current = None
        self._cache = None            # built lazily on first admission
        self._tok = None
        self._active = None
        self._flags = None
        self._step0 = 0

    def fits(self, req) -> bool:
        """Whole-lifetime bound: the dense sidecar strip must hold the
        full prompt + generation budget."""
        return len(req.prompt) + req.max_new_tokens <= self.max_len

    def has_work(self) -> bool:
        return self.current is not None or bool(self.queue)

    def step(self, stats) -> bool:
        """One unit of lane work per engine iteration: admit the next
        escalated request, or decode one chunk of the current one.
        Returns whether anything ran (the engine's stall guard)."""
        if self.current is None:
            if not self.queue:
                return False
            self._admit(self.queue.popleft())
            return True
        self._decode_chunk(stats)
        return True

    def submit(self, req) -> None:
        self.queue.append(req)

    def _admit(self, req) -> None:
        """Re-prefill ``prompt + tokens-so-far`` into the sidecar cache.

        S affects only the head's MC draws, never the body or its KV
        writes, so this replay reconstructs bit-for-bit the KV state
        the request left behind in the main engine; decode then simply
        continues from the last emitted token at the higher S.
        """
        r = self.runner
        if self._cache is None:
            self._cache = r.make_cache(1)
            self._tok = jnp.zeros((1,), jnp.int32)
            self._active = jnp.zeros((1,), bool)
            self._flags = {"epistemic": jnp.zeros((1,), jnp.int32),
                           "aleatoric": jnp.zeros((1,), jnp.int32)}
        seq = list(req.prompt) + list(req.tokens)
        n = len(seq)
        W = n
        if self.pad_to:
            W = min(-(-n // self.pad_to) * self.pad_to, self.max_len)
        toks = np.zeros((W,), np.int32)
        toks[:n] = seq
        slot0 = jnp.asarray(0, jnp.int32)
        _, sub = r._prefill(r.params, jnp.asarray(toks)[None],
                            self.modality)
        cache = r._write(self._cache, slot0, sub)
        if W > n:
            cache = r._set_len(cache, slot0, jnp.asarray(n, jnp.int32))
        self._cache = cache
        self._tok = self._tok.at[0].set(int(seq[-1]))
        self._active = self._active.at[0].set(True)
        self._flags = {k: v.at[0].set(0) for k, v in self._flags.items()}
        self.current = req

    def _decode_chunk(self, stats) -> None:
        """One scan chunk at the verify S, harvested into the request."""
        r = self.runner
        req = self.current
        t0 = time.perf_counter()
        self._tok, self._cache, self._flags, ys = r._scan(
            r.params, self._tok, self._cache,
            jnp.asarray(self._step0, jnp.int32), self._active, self._flags)
        ys = jax.device_get(ys)
        dt = time.perf_counter() - t0
        stats.esc_decode_s += dt
        stats.decode_s += dt
        stats.esc_steps += self.chunk
        self._step0 += self.chunk
        for t in range(self.chunk):
            tk = int(ys["token"][t, 0])
            req.tokens.append(tk)
            for name in ("H", "SE", "MI", "p_max"):
                getattr(req, name).append(float(ys[name][t, 0]))
            req.epistemic_flags += int(ys["epistemic"][t, 0])
            req.aleatoric_flags += int(ys["aleatoric"][t, 0])
            req.last_mi = float(ys["MI"][t, 0])
            stats.esc_tokens += 1
            done_eos = self.eos_id is not None and tk == self.eos_id
            if done_eos or len(req.tokens) >= req.max_new_tokens:
                req.transition("finished",
                               reason="eos" if done_eos else "length")
                self._active = self._active.at[0].set(False)
                self.current = None
                break
