"""Scheduling policies (the admission/eviction decision layer).

``SchedPolicy`` is the interface ``scheduler.SlotScheduler`` consults
at every admission: WHICH queued request to try next (``select``) and,
when no slot or not enough pool is available for it, WHICH running
slot to preempt on its behalf (``victim``).  The scheduler keeps all
the mechanism — block reservations, table rewrites, requeueing — so a
policy is a pure ranking function over host-side request state and
never touches the allocator.

``FifoPolicy`` is the bit-exact reference: always the queue head, no
skip-ahead, never a preemption at admission time.  A refactored engine
running fifo must replay the pre-policy engine's streams bit for bit
(tests/test_policy.py::TestFifoReference).

``PriorityPolicy`` ranks by (priority class, SLO deadline, submission
order) — lower ``Request.priority`` wins, an SLO'd request's deadline
is ``t_submit + slo_s`` (EDF within its class) — and under pressure
preempts the lowest-ranked *decoding* slot whose class is strictly
worse than the candidate's.  Only decoding slots are preemptible:
their output replays bit-exactly from the prompt when the request
lands back in the same slot (depth-keyed operand noise), whereas
aborting a mid-prefill walk would waste the chunks already paid.
"""

from __future__ import annotations

from typing import Optional


class SchedPolicy:
    """Admission-ranking interface the scheduler consults.

    ``select`` returns the queue INDEX of the request to try admitting
    next (None defers admission entirely); ``victim`` returns the slot
    to preempt so ``candidate`` can admit (None defers the candidate
    instead).  ``running`` only ever contains decoding slots — the
    scheduler filters states so no policy can preempt a prefill walk.
    """

    name = "base"

    def select(self, queue) -> Optional[int]:
        raise NotImplementedError

    def victim(self, candidate, running) -> Optional[int]:
        raise NotImplementedError


class FifoPolicy(SchedPolicy):
    """The reference policy: queue head only, defer on failure, never
    preempt for an admission.  Byte-for-byte the pre-policy scheduler
    (grant-failure preemption still exists — that is the engine's
    last-resort mechanism, not an admission decision)."""

    name = "fifo"

    def select(self, queue) -> Optional[int]:
        return 0 if queue else None

    def victim(self, candidate, running) -> Optional[int]:
        return None


class PriorityPolicy(SchedPolicy):
    """Priority classes + SLO deadlines + preempt-under-pressure.

    Rank key: ``(priority, deadline, seq)`` — lower priority value is
    the better class, ``deadline = t_submit + slo_s`` (inf without an
    SLO) gives earliest-deadline-first inside a class, and the
    submission sequence breaks remaining ties so equal-priority
    traffic degrades exactly to FIFO order.

    ``victim`` picks the decoding slot with the numerically LARGEST
    priority — strictly worse than the candidate's class, never a
    peer — preferring the slot with the fewest emitted tokens (the
    cheapest replay) and the youngest submission among those.
    """

    name = "priority"

    @staticmethod
    def _deadline(req) -> float:
        return req.t_submit + req.slo_s if req.slo_s is not None \
            else float("inf")

    def select(self, queue) -> Optional[int]:
        if not queue:
            return None
        best, best_key = None, None
        for i, r in enumerate(queue):
            key = (r.priority, self._deadline(r), r.seq)
            if best_key is None or key < best_key:
                best, best_key = i, key
        return best

    def victim(self, candidate, running) -> Optional[int]:
        worst = [(slot, r) for slot, r in running
                 if r.priority > candidate.priority]
        if not worst:
            return None
        slot, _ = max(worst, key=lambda sr: (sr[1].priority,
                                             -len(sr[1].tokens),
                                             sr[1].seq))
        return slot


_POLICIES = {"fifo": FifoPolicy, "priority": PriorityPolicy}


def get_policy(name: str) -> SchedPolicy:
    """Resolve a ``--policy`` name to a fresh policy instance."""
    if name not in _POLICIES:
        raise ValueError(f"unknown scheduling policy {name!r}; "
                         f"choose from {sorted(_POLICIES)}")
    return _POLICIES[name]()
