"""The continuous-batching serving engine (policy + chunk loop).

``ServeEngine`` is the top of the layered engine package: it resolves
the serving POLICY (layout/kernel/prefill-mode fallbacks per family
support), then drives the per-chunk loop — admit via
``scheduler.SlotScheduler``, prefill through ``runner.ModelRunner``'s
compiled callables, grant/preempt against ``block_pool``, harvest the
scan outputs, account everything into ``stats.ServeStats``.  Nothing
here touches device placement (runner) or block accounting (scheduler/
pool) directly; the split is the module map in docs/architecture.md.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.entropy import KernelEntropy
from repro.kernels.paged_attention import kv_blocks_read
from repro.launch.engine.block_pool import BlockAllocator
from repro.launch.engine.escalate import EscalationLane
from repro.launch.engine.policy import SchedPolicy, get_policy
from repro.launch.engine.runner import ModelRunner
from repro.launch.engine.scheduler import Request, SlotScheduler
from repro.launch.engine.stats import ServeStats
from repro.models import registry as M


class ServeEngine:
    """Continuous-batching scan-decoded uncertainty engine.

    ``num_slots`` concurrent decode slots over one slot-indexed KV cache
    of depth ``max_len``; ``chunk`` tokens decoded per device call.
    ``entropy`` (KernelEntropy) selects the seeded head-draw stream
    (in-kernel on TPU); None keeps the legacy operand stream.

    ``kv_layout`` picks the cache layout.  Both layouts bound a request
    to ``prompt + gen <= max_len`` (block tables span ``max_len``
    logical tokens).  ``'dense'`` — the bit-exact reference — gives
    every slot one contiguous ``max_len`` KV strip, so mixed-length
    traffic pays full padding waste.  ``'paged'`` backs the self-attention KV
    with a global pool of ``kv_blocks`` blocks of ``kv_block`` tokens:
    admission reserves a request's whole-lifetime block budget ("are
    enough blocks free", deferring instead of crashing when the pool is
    exhausted), decode blocks are granted chunk by chunk, and eviction
    returns everything — KV bytes in use track the tokens actually
    resident instead of ``num_slots * max_len``.  Paged decode is
    bit-exact against dense when ``max_len`` is a ``kv_block`` multiple
    (equal logical spans; tested in tests/test_paged_kv.py).  Families
    without KV strips (ssm) fall back to dense.

    ``prefix_cache=True`` (paged only) puts a host-side radix tree
    (``launch.prefix_cache.RadixPrefixCache``) over the block pool:
    admission walks the tree, maps the longest cached token prefix's
    blocks into the slot's table read-only (refcounted sharing), and
    prefill runs only on the uncached suffix — a full-prompt hit costs
    zero prefill compute.  A token-granular partial match into a shared
    block triggers copy-on-write (device-side block duplicate + table
    swap) before the slot writes at the divergence point.  Evicted
    requests donate their prompt blocks to the tree; cached-but-
    unreferenced blocks are LRU-evicted under pool pressure.  Restricted
    to families whose prompt KV is a pure function of token IDs
    (``registry.supports_prefix_cache``); hit decode is bit-exact vs the
    cold path under the same admission schedule (tested in
    tests/test_prefix_cache.py).

    ``decode_attn`` (paged only) selects the decode-attention read path:
    ``'gather'`` — the bit-exact reference — materializes each slot's
    full ``MB*BS`` logical strip per layer per step, so decode HBM
    traffic is identical to dense strips; ``'kernel'`` runs the
    block-sparse Pallas kernel (``kernels/paged_attention.py``) that
    reads only mapped blocks under each slot's depth straight from the
    pool, bit-exact vs gather in operand/interpret mode (tested in
    tests/test_paged_attention.py).  ``trace_every`` downsamples the
    per-chunk scheduler/pool snapshot (1 = every chunk) so long runs
    don't grow host memory linearly in chunks decoded.

    ``mesh`` (a ``Mesh`` from ``runner.resolve_mesh``) serves decode
    tensor-parallel over the mesh's ``model`` axis — the runner shards
    parameters and the paged KV pool, scheduler state stays host-side,
    and decode is bit-exact vs the unsharded engine in operand-entropy
    mode (tests/test_mesh_runner.py).  The block-sparse decode kernel
    does not partition under GSPMD, so a multi-device mesh silently
    keeps the gather read path, like the family fallbacks above.
    """

    def __init__(self, params, cfg, *, num_slots: int, max_len: int,
                 chunk: int = 8, entropy: Optional[KernelEntropy] = None,
                 mi_threshold: float = 0.05, se_threshold: float = 1.0,
                 eos_id: Optional[int] = None, kv_layout: str = "dense",
                 kv_block: int = 16, kv_blocks: Optional[int] = None,
                 prefix_cache: bool = False, decode_attn: str = "gather",
                 prefill_mode: str = "batch", prefill_chunk: int = 32,
                 trace_every: int = 1, mesh=None,
                 spec_decode: bool = False, spec_k: int = 4,
                 spec_mi_threshold: Optional[float] = None,
                 spec_draft_s: int = 1,
                 spec_k_min: Optional[int] = None,
                 spec_k_max: Optional[int] = None,
                 policy="fifo",
                 escalate_mi: Optional[float] = None,
                 escalate_s: Optional[int] = None):
        if kv_layout not in ("dense", "paged"):
            raise ValueError(f"unknown kv_layout {kv_layout!r}")
        if kv_block < 1:
            raise ValueError(f"kv_block must be >= 1, got {kv_block}")
        if prefix_cache and kv_layout != "paged":
            raise ValueError("prefix cache shares blocks of the paged "
                             "pool; run with kv_layout='paged'")
        if decode_attn not in ("gather", "kernel"):
            raise ValueError(f"unknown decode_attn {decode_attn!r}")
        if decode_attn == "kernel" and kv_layout != "paged":
            raise ValueError("the block-sparse decode kernel reads "
                             "through the paged block table; run with "
                             "kv_layout='paged'")
        if prefill_mode not in ("batch", "chunked"):
            raise ValueError(f"unknown prefill_mode {prefill_mode!r}")
        if prefill_mode == "chunked" and kv_layout != "paged":
            raise ValueError("chunked prefill scatters prompt chunks "
                             "into pool blocks; run with "
                             "kv_layout='paged'")
        if prefill_chunk < 1:
            raise ValueError(f"prefill_chunk must be >= 1, got "
                             f"{prefill_chunk}")
        if trace_every < 1:
            raise ValueError(f"trace_every must be >= 1, got {trace_every}")
        if spec_decode:
            if spec_k < 1:
                raise ValueError(f"spec_k must be >= 1, got {spec_k}")
            if spec_draft_s < 0:
                raise ValueError(
                    f"spec_draft_s must be >= 0, got {spec_draft_s}")
            # losslessness hinges on the head noise being a pure function
            # of (slot, depth): the seeded/kernel streams fold the GLOBAL
            # step into the key, so a verify replayed at the same depth
            # but a different step could not reproduce plain decode's draw
            if entropy is not None or cfg.head_entropy == "kernel":
                raise ValueError(
                    "speculative decoding requires the operand entropy "
                    "mode (depth-keyed head noise); the seeded/kernel "
                    "streams fold the global step and cannot replay "
                    "plain decode's draws at draft positions")
            if not M.supports_spec_decode(cfg):
                raise ValueError(
                    f"family {cfg.family!r} does not support speculative "
                    "decoding")
        self.spec_decode = spec_decode
        self.spec_k = spec_k
        self.spec_mi_threshold = mi_threshold if spec_mi_threshold is None \
            else spec_mi_threshold
        self.spec_draft_s = spec_draft_s
        # adaptive draft depth: per-slot acceptance EMA walks each
        # slot's k inside [k_min, k_max] (defaults pin both to spec_k,
        # which disables adaptation and keeps the fixed-k stream
        # bitwise); a round drafts at the drafting slots' minimum
        self.spec_k_min = spec_k if spec_k_min is None else spec_k_min
        self.spec_k_max = spec_k if spec_k_max is None else spec_k_max
        if spec_decode and not (1 <= self.spec_k_min <= spec_k
                                <= self.spec_k_max):
            raise ValueError(
                f"adaptive spec-k bounds must satisfy 1 <= k_min <= k "
                f"<= k_max, got k_min={self.spec_k_min} k={spec_k} "
                f"k_max={self.spec_k_max}")
        # admission/eviction decision layer (policy.SchedPolicy): a name
        # from --policy or a ready instance; fifo is the bit-exact
        # reference the priority policy is anchored against
        self.policy = policy if isinstance(policy, SchedPolicy) \
            else get_policy(policy)
        # MI-triggered OOD escalation: a slot whose carried MI reaches
        # escalate_mi finishes on a high-S sidecar runner (escalate_s
        # MC samples; default 4x the serving S)
        if escalate_mi is not None and escalate_mi < 0:
            raise ValueError(
                f"escalate_mi must be >= 0, got {escalate_mi}")
        self.escalate_mi = escalate_mi
        self.escalate_s = escalate_s if escalate_s is not None \
            else 4 * cfg.mc_samples
        if self.escalate_s < 1:
            raise ValueError(
                f"escalate_s must be >= 1, got {self.escalate_s}")
        self.num_slots = num_slots
        self.max_len = max_len
        self.chunk = chunk
        self.eos_id = eos_id
        self.trace_every = trace_every
        self.mesh = mesh
        self.kv_layout = kv_layout if M.supports_paged(cfg) else "dense"
        # the block-sparse decode kernel reads through the block table,
        # so it only exists on the paged layout; families that fell back
        # to dense silently keep the gather/dense read path, mirroring
        # the ssm dense fallback below
        self.decode_attn = decode_attn if self.kv_layout == "paged" \
            else "gather"
        if mesh is not None and mesh.devices.size > 1 \
                and self.decode_attn == "kernel":
            # the Pallas kernel body can't be partitioned by GSPMD over
            # the pool's head shards; a real multi-device mesh keeps the
            # (shardable) gather read path, silently like the fallbacks
            # around it.  A 1-device fallback mesh shards nothing, so
            # the kernel stays available there.
            self.decode_attn = "gather"
        # decode_attn rides ArchConfig (like head_entropy) so every
        # family's decode threads it to layers.apply_attention without
        # signature churn; params are structure-independent of it
        self.cfg = cfg = dataclasses.replace(cfg,
                                             decode_attn=self.decode_attn)
        # prefix reuse additionally needs prompt KV that is a pure
        # function of the token IDs (see registry.supports_prefix_cache);
        # unsupported families silently serve cold, like the ssm
        # dense fallback above
        self.prefix_cache = (prefix_cache and self.kv_layout == "paged"
                             and M.supports_prefix_cache(cfg))
        self.kv_block = kv_block
        self.table_width = M.paged_table_width(max_len, kv_block)
        # default pool = full dense capacity: no admission change, the
        # savings then show up as peak blocks in use < blocks allocated
        self.kv_blocks = (kv_blocks if kv_blocks is not None
                          else num_slots * self.table_width)
        if self.kv_blocks < 1:
            raise ValueError(f"kv_blocks must be >= 1, got {kv_blocks}")
        paged = self.kv_layout == "paged"
        # prompt-length bucketing: padding-safe families right-pad cold
        # prompts to the next kv_block multiple, so the jitted batch
        # prefill compiles once per BUCKET instead of once per distinct
        # prompt length (prefill_compiles in the run stats); recurrent
        # families keep exact lengths
        self.pad_prompts = M.supports_prompt_padding(cfg)
        # chunked prefill needs the per-family prefill_chunk walker and
        # the paged layout; others fall back to batch silently, like the
        # ssm dense fallback above
        self.prefill_mode = prefill_mode if paged \
            and M.supports_chunked_prefill(cfg) else "batch"
        self.prefill_chunk = prefill_chunk
        if self.prefill_mode == "chunked" and cfg.family == "hybrid":
            # hybrid chunks walk the SSM in ssm_chunk segments; round
            # the knob up so every full chunk is a clean multiple
            sc = cfg.ssm_chunk
            self.prefill_chunk = -(-prefill_chunk // sc) * sc
        self.runner = ModelRunner(
            params, cfg, max_len=max_len, chunk=chunk, entropy=entropy,
            mi_threshold=mi_threshold, se_threshold=se_threshold,
            kv_layout=self.kv_layout, kv_block=kv_block,
            kv_blocks=self.kv_blocks, prefix_cache=self.prefix_cache,
            prefill_mode=self.prefill_mode, mesh=mesh,
            spec_decode=spec_decode, spec_k=spec_k,
            spec_draft_s=spec_draft_s)
        # mesh mode re-places params by the serve-TP rules; the engine
        # always dispatches the runner's copy
        self.params = self.runner.params
        # escalation sidecars: unplaced params + head-draw knobs so
        # escalation_runner can build a second ModelRunner (its own jit
        # cache) per distinct verify S, on demand
        self._base_params = params
        self._entropy = entropy
        self._mi_threshold = mi_threshold
        self._se_threshold = se_threshold
        self._esc_runners: dict[int, ModelRunner] = {}
        # compiled-callable aliases: run() dispatches through self so
        # tests can interpose on a single engine attribute (e.g. the
        # mid-run fault injection in tests/test_paged_attention.py)
        self._prefill = self.runner._prefill
        self._write = self.runner._write
        self._chunk_fn = self.runner._chunk_fn
        self._chunk_first = self.runner._chunk_first
        self._suffix = self.runner._suffix
        self._copy = self.runner._copy
        self._set_len = self.runner._set_len
        self._scan = self.runner._scan
        self._draft = self.runner._draft
        self._verify = self.runner._verify
        self._spec_commit = self.runner._spec_commit

    def escalation_runner(self, s: int) -> ModelRunner:
        """The high-S verify runner for ``s`` MC head samples — a
        second ModelRunner jit cache KEYED BY S (each distinct verify S
        compiles its own prefill/scan once, then every escalated
        request at that S reuses them).  Single-slot dense sidecar: S
        only changes head draws, so the cheap layout is fine, and the
        gather read path is the dense reference
        (tests/test_policy.py::TestEscalation)."""
        if s not in self._esc_runners:
            cfg = dataclasses.replace(self.cfg, mc_samples=s,
                                      decode_attn="gather")
            self._esc_runners[s] = ModelRunner(
                self._base_params, cfg, max_len=self.max_len,
                chunk=self.chunk, entropy=self._entropy,
                mi_threshold=self._mi_threshold,
                se_threshold=self._se_threshold, kv_layout="dense",
                kv_block=self.kv_block, kv_blocks=self.table_width,
                prefix_cache=False, prefill_mode="batch")
        return self._esc_runners[s]

    def _bucket(self, n: int) -> int:
        """Prompt-length bucket: next kv_block multiple (dense strips
        additionally clamp to max_len).  The static attention span every
        prefill path of a bucketed prompt reduces over."""
        if not self.pad_prompts:
            return n
        w = -(-n // self.kv_block) * self.kv_block
        return min(w, self.max_len) if self.kv_layout == "dense" else w

    def _start_job(self, req: Request, hit_len: int, span: int,
                   cache) -> dict:
        """Open a chunked-prefill walk over ``req``'s prompt.

        The job carries the walk offset plus whatever state the family's
        ``prefill_chunk`` threads between chunks: running expert load for
        MoE capacity splits, SSM/conv recurrent state for hybrid, and the
        encoder-frames-pending flag for encdec.
        """
        job = {"req": req, "P": len(req.prompt), "span": span,
               "off": hit_len, "first": True}
        cfg = self.cfg
        if cfg.family == "moe":
            job["ex_off"] = jnp.zeros((cfg.num_layers, cfg.num_experts),
                                      jnp.float32)
        elif cfg.family == "hybrid":
            from repro.models.ssm import dims
            d_in, H, Pd, N = dims(cfg)
            job["state"] = {
                "ssm": jnp.zeros((cfg.num_layers, 1, H, Pd, N),
                                 jnp.float32),
                "conv": jnp.zeros((cfg.num_layers, 1,
                                   cfg.ssm_conv_width - 1, d_in + 2 * N),
                                  cache["conv"].dtype)}
        return job

    def _run_chunk(self, cache, slot: int, job: dict):
        """Advance ``job`` by one prompt chunk; returns
        ``(cache, done, shape_key)``.

        Padding-safe families pad every chunk to exactly prefill_chunk
        tokens (one compile per (chunk, span) pair; trailing junk either
        scatters into the in-bucket pad region the batch path also
        writes, or drops at unmapped blocks).  Hybrid walks exact
        ssm_chunk-multiple segments instead — its recurrence is not
        padding-safe.
        """
        off, P, W = job["off"], job["P"], job["span"]
        pc = self.prefill_chunk
        real = min(pc, P - off)
        S_len = pc if self.pad_prompts else real
        toks = np.zeros((S_len,), np.int32)
        toks[:real] = job["req"].prompt[off:off + real]
        new_len = off + real
        done = new_len >= P
        args = (self.params, jnp.asarray(toks)[None], cache,
                jnp.asarray(slot, jnp.int32), jnp.asarray(off, jnp.int32),
                jnp.asarray(new_len, jnp.int32))
        fam = self.cfg.family
        variant = ""
        if fam == "moe":
            cache, job["ex_off"] = self._chunk_fn(*args, job["ex_off"], W)
        elif fam == "hybrid":
            cache, job["state"] = self._chunk_fn(*args, job["state"], W,
                                                 done)
            variant = "final" if done else ""
        elif fam == "encdec" and job["first"]:
            cache = self._chunk_first(*args, self._modality(1), W)
            variant = "first"
        else:
            cache = self._chunk_fn(*args, W)
        job["first"] = False
        job["off"] = new_len
        return cache, done, ("chunk", S_len, W, variant)

    def _modality(self, batch: int):
        cfg = self.cfg
        if cfg.family == "encdec":
            from repro.models.encdec import ENC_LEN
            return jnp.zeros((batch, ENC_LEN, cfg.d_model), jnp.float32)
        if cfg.family == "vlm":
            return jnp.zeros((batch, cfg.num_prefix_embeds, cfg.d_model),
                             jnp.float32)
        return None

    def _spec_round(self, sched, stats, decoding, tok, cache, active,
                    flags, *, k=None, escalate=None):
        """One uncertainty-gated speculative round (replaces a scan
        chunk): a k-step shared-body draft proposes cheap-head tokens
        for every slot, ONE batched full-S-sample verify re-draws the
        uncertain head over the draft's stacked hiddens at the same
        (slot, depth) noise sites, and the host keeps each slot's
        longest agreeing prefix plus the first verified correction.

        Because the draft runs the SAME body (same params, same cache)
        as plain decode, every accepted position's KV/state writes are
        bitwise what plain decode would have written, and the verify
        head at depth-keyed operand noise reproduces plain decode's
        emissions exactly — so the accepted stream is bitwise identical
        to spec-decode off (tests/test_spec_decode.py).  Slots whose
        carried MI sits at/above the gate ride the round as plain
        decode: position 1's verified token only.  Rejected suffixes
        roll back host-side (``scheduler.rollback`` frees the decode
        blocks past the kept depth) and device-side (``spec_commit``
        pins tok/len and rewinds recurrent ssm/conv state); junk KV
        above the kept depth stays masked until overwritten.
        """
        runner = self.runner
        k = self.spec_k if k is None else k
        # the engine-attribute aliases stay the dispatch point at the
        # default depth (tests interpose on engine._draft); other
        # adaptive depths resolve through the runner's per-k jit cache
        draft_fn, verify_fn = (self._draft, self._verify) \
            if k == self.spec_k else runner.spec_fns(k)
        stats.record_round_k(k)
        parts = [(slot, req) for slot, req in sched.active()
                 if slot in decoding]
        lens0 = np.zeros((self.num_slots,), np.int32)
        for slot, req in parts:
            lens0[slot] = len(req.prompt) + len(req.tokens)
        t0 = time.perf_counter()
        tok, cache, dys = draft_fn(self.params, tok, cache)
        vys = verify_fn(self.params, dys["hidden"],
                        runner.put_replicated(jnp.asarray(lens0)))
        host = jax.device_get({"draft": dys["token"], **vys})
        stats.arrivals.append(time.perf_counter())
        stats.decode_s += time.perf_counter() - t0
        stats.spec_rounds += 1
        stats.full_model_calls += 1          # ONE batched verify
        stats.steps_run += k
        commit_mask = np.zeros((self.num_slots,), bool)
        commit_tok = np.zeros((self.num_slots,), np.int32)
        commit_len = np.zeros((self.num_slots,), np.int32)
        commit_idx = np.zeros((self.num_slots,), np.int32)
        epi_add = np.zeros((self.num_slots,), np.int32)
        alea_add = np.zeros((self.num_slots,), np.int32)
        for slot, req in parts:
            if req.last_mi < self.spec_mi_threshold:
                a = 0
                while a < k and host["draft"][a, slot] \
                        == host["next_token"][a, slot]:
                    a += 1
                stats.spec_drafted += k
                stats.spec_accepted += a
                # adaptive depth: acceptance EMA per slot walks its k
                # inside [k_min, k_max]; at pinned bounds neither
                # branch can fire and the fixed-k stream is untouched
                rate = a / k
                req.spec_ema = rate if req.spec_ema is None \
                    else 0.5 * req.spec_ema + 0.5 * rate
                cur = req.spec_k_cur or self.spec_k
                if req.spec_ema >= 0.8 and cur < self.spec_k_max:
                    req.spec_k_cur = cur + 1
                    stats.spec_k_up += 1
                elif req.spec_ema <= 0.4 and cur > self.spec_k_min:
                    req.spec_k_cur = cur - 1
                    stats.spec_k_down += 1
                else:
                    req.spec_k_cur = cur
            else:
                # carried MI at/above the gate: no drafting credit —
                # the slot emits position 1's verified token only,
                # exactly one plain decode step's worth
                a = 0
                stats.spec_gated += 1
            m = min(a + 1, k)
            emitted = 0
            finished = False
            for j in range(m):
                tk = int(host["next_token"][j, slot])
                req.tokens.append(tk)
                for name in ("H", "SE", "MI", "p_max"):
                    getattr(req, name).append(float(host[name][j, slot]))
                req.epistemic_flags += int(host["epistemic"][j, slot])
                req.aleatoric_flags += int(host["aleatoric"][j, slot])
                epi_add[slot] += int(host["epistemic"][j, slot])
                alea_add[slot] += int(host["aleatoric"][j, slot])
                req.last_mi = float(host["MI"][j, slot])
                emitted = j + 1
                done_eos = self.eos_id is not None and tk == self.eos_id
                if done_eos or len(req.tokens) >= req.max_new_tokens:
                    req.transition("finished",
                                   reason="eos" if done_eos else "length")
                    sched.evict(slot)
                    decoding.discard(slot)
                    active = active.at[slot].set(False)
                    finished = True
                    break
            stats.spec_emitted += emitted
            if finished:
                continue
            if escalate is not None and escalate(slot, req):
                # handed to the high-S lane: the eviction already freed
                # every block (draft tail included), the slot goes
                # inactive, and no commit pin is needed
                active = active.at[slot].set(False)
                continue
            # keep depth lens0+emitted: free the decode blocks the
            # rejected draft tail grew into (host) and pin the slot's
            # carry token / device len / recurrent state (device).
            # emitted == k still commits — the carry token must be the
            # VERIFIED v_k, not the draft's final proposal.
            if emitted < k:
                stats.spec_rollbacks += 1
                sched.rollback(slot, int(lens0[slot]) + emitted)
            commit_mask[slot] = True
            commit_tok[slot] = host["next_token"][emitted - 1, slot]
            commit_len[slot] = lens0[slot] + emitted
            commit_idx[slot] = emitted - 1
        states = {leaf: dys[leaf] for leaf in M.RECURRENT_LEAVES
                  if leaf in dys}
        tok, cache = self._spec_commit(
            cache, tok,
            runner.put_replicated(jnp.asarray(commit_mask)),
            runner.put_replicated(jnp.asarray(commit_tok)),
            runner.put_replicated(jnp.asarray(commit_len)),
            states,
            runner.put_replicated(jnp.asarray(commit_idx)))
        # device flag telemetry: exactly the emitted positions' flags
        # (the scan carry instead counts junk steps to the chunk edge)
        flags = {"epistemic": flags["epistemic"]
                 + runner.put_replicated(jnp.asarray(epi_add)),
                 "aleatoric": flags["aleatoric"]
                 + runner.put_replicated(jnp.asarray(alea_add))}
        return tok, cache, active, flags

    def run(self, requests: list[Request]) -> dict:
        """Serve ``requests`` to completion; returns engine metrics.

        One host sync per admission (prefill) and one per decoded chunk
        (the stacked (chunk, B) outputs) -- never per token.
        """
        paged = self.kv_layout == "paged"
        for r in requests:
            if len(r.prompt) == 0:
                raise ValueError(f"request {r.rid}: empty prompt")
            if r.max_new_tokens < 1:
                raise ValueError(
                    f"request {r.rid}: max_new_tokens must be >= 1")
            # paged tables GROW on demand (grant widens them past the
            # admission-time span), so only dense strips — whose depth
            # is baked into the cache shape — bound prompt + gen
            if not paged and len(r.prompt) + r.max_new_tokens \
                    > self.max_len:
                raise ValueError(
                    f"request {r.rid}: prompt {len(r.prompt)} + "
                    f"max_new_tokens {r.max_new_tokens} exceeds the "
                    f"slot capacity max_len={self.max_len}; cache writes "
                    f"past capacity would be dropped silently")
        alloc = None
        pcache = None
        if paged:
            alloc = BlockAllocator(self.kv_blocks, self.kv_block)
            for r in requests:
                need = alloc.blocks_for(len(r.prompt) + r.max_new_tokens)
                if need > self.kv_blocks:
                    raise ValueError(
                        f"request {r.rid}: needs {need} KV blocks but the "
                        f"pool only has {self.kv_blocks}; it could never "
                        f"be admitted")
            if self.prefix_cache:
                from repro.launch.prefix_cache import RadixPrefixCache
                pcache = RadixPrefixCache(alloc, self.kv_block)
        sched = SlotScheduler(self.num_slots, allocator=alloc,
                              table_width=self.table_width,
                              prefix_cache=pcache, policy=self.policy)
        # observable post-mortem (tests assert the pool balances even
        # when run() raises mid-decode)
        self._last_alloc, self._last_pcache = alloc, pcache
        stats = ServeStats(trace_every=self.trace_every)
        # open-loop arrivals: requests with arrival_step > 0 join the
        # queue only once the engine has decoded that many steps (the
        # bursty traces bench_serve drives); step-0 requests submit now
        pending = collections.deque(
            sorted((r for r in requests if r.arrival_step > 0),
                   key=lambda r: r.arrival_step))
        for r in requests:
            if r.arrival_step <= 0:
                sched.submit(r)
        # MI-triggered escalation lane: a one-slot high-S sidecar the
        # harvest paths hand flagged requests to (None keeps every
        # escalation branch dead and the loop byte-for-byte)
        lane = None
        if self.escalate_mi is not None:
            lane = EscalationLane(
                self.escalation_runner(self.escalate_s),
                chunk=self.chunk, eos_id=self.eos_id,
                pad_to=self.kv_block if self.pad_prompts else None,
                modality=self._modality(1))
        esc_skipped_rids: set = set()

        def maybe_escalate(slot, req):
            """Hand a flagged slot to the lane; the CALLER clears the
            slot's active lane in the device mask (this closure cannot
            rebind the loop's `active` from inside _spec_round)."""
            if lane is None or req.last_mi < self.escalate_mi:
                return False
            if not lane.fits(req):
                # dense sidecar can't hold prompt + budget: keep
                # decoding in the main engine, counted once
                if req.rid not in esc_skipped_rids:
                    esc_skipped_rids.add(req.rid)
                    stats.esc_skipped += 1
                return False
            req.transition("escalated")
            sched.evict(slot)
            decoding.discard(slot)
            lane.submit(req)
            stats.escalations += 1
            stats.esc_by_class[req.priority] += 1
            return True

        runner = self.runner
        tok = runner.put_replicated(jnp.zeros((self.num_slots,), jnp.int32))
        cache = runner.make_cache(self.num_slots)
        active = runner.put_replicated(jnp.zeros((self.num_slots,), bool))
        flags = {
            "epistemic": runner.put_replicated(
                jnp.zeros((self.num_slots,), jnp.int32)),
            "aleatoric": runner.put_replicated(
                jnp.zeros((self.num_slots,), jnp.int32))}
        step0 = 0
        table_synced = -1            # device block-table version synced
        modality1 = self._modality(1)
        # chunked-prefill bookkeeping: slot -> in-flight prompt walk
        # (offset + family carry), FIFO order of pending walks, and the
        # slots currently DECODING (mid-prefill slots sit in the scan
        # batch inactive; their junk steps are overwritten by the next
        # chunk's scatter, see models.layers.apply_attention_chunk)
        prefilling: dict[int, dict] = {}
        jobs: collections.deque[int] = collections.deque()
        decoding: set[int] = set()

        def activate(slot, req):
            nonlocal tok, active, flags
            req.transition("decoding")
            req.spec_k_cur = self.spec_k
            tok = tok.at[slot].set(int(req.prompt[-1]))
            active = active.at[slot].set(True)
            flags = {k: v.at[slot].set(0) for k, v in flags.items()}
            decoding.add(slot)

        def sync_table():
            # re-upload the device block table (tiny: slots x MB) only
            # when the host copy changed; a width change alters the
            # cache shape, so downstream jits retrace once per growth
            nonlocal cache, table_synced
            if sched.table_version != table_synced:
                cache = dict(cache, block_table=runner.place_table(
                    sched.block_tables))
                table_synced = sched.table_version

        try:
            while sched.has_work() or pending \
                    or (lane is not None and lane.has_work()):
                # fire every arrival whose step has come; when the
                # engine is otherwise idle, fast-forward to the next
                # arrival group instead of spinning on empty iterations
                fired = 0
                while pending \
                        and pending[0].arrival_step <= stats.steps_run:
                    sched.submit(pending.popleft())
                    fired += 1
                if not fired and pending and not sched.has_work() \
                        and not (lane is not None and lane.has_work()):
                    nxt = pending[0].arrival_step
                    while pending and pending[0].arrival_step == nxt:
                        sched.submit(pending.popleft())
                        fired += 1
                admitted = sched.admit()
                # admission-pressure preemptions (priority policy):
                # the victims' requests are already requeued; drop the
                # slots from the engine's decode set before the new
                # admissions (possibly into those slots) re-arm them
                for slot, _req in sched.take_preempted():
                    decoding.discard(slot)
                    active = active.at[slot].set(False)
                if paged:
                    # admissions mutate the host tables (and may WIDEN
                    # them); the device copy must match before any
                    # prefill write installs a row at the new width
                    sync_table()
                for slot, req in admitted:
                    t0 = time.perf_counter()
                    info = sched.prefix_admit(slot) if paged else None
                    hit_len = info.tokens if info is not None else 0
                    P = len(req.prompt)
                    W = self._bucket(P)
                    if info is not None and info.cow is not None:
                        # the shared tail block is about to be written at the
                        # divergence point: duplicate it device-side and let
                        # the scheduler drop this slot's ref on the original
                        src, dst = info.cow
                        cache = self._copy(cache, jnp.asarray(src, jnp.int32),
                                           jnp.asarray(dst, jnp.int32))
                        sched.finish_cow(slot)
                        stats.pc_cow += 1
                    slot_ = jnp.asarray(slot, jnp.int32)
                    shape_key: Optional[tuple] = None
                    if hit_len == P:
                        # whole prompt resident: zero prefill compute — the
                        # decode loop only needs the slot's depth
                        cache = self._set_len(cache, slot_,
                                              jnp.asarray(P, jnp.int32))
                        shape_key = ("hit",)
                        activate(slot, req)
                    elif self.prefill_mode == "chunked":
                        # enqueue an incremental prompt walk (suffix-only
                        # on a partial prefix hit — CoW already settled
                        # above) and pin the slot's depth to the resident
                        # span NOW: interleaved scans write junk at
                        # [len, len+chunk) for every slot, and a stale
                        # len would point into shared prefix blocks
                        cache = self._set_len(
                            cache, slot_, jnp.asarray(hit_len, jnp.int32))
                        prefilling[slot] = self._start_job(req, hit_len, W,
                                                           cache)
                        jobs.append(slot)
                    elif hit_len > 0:
                        # suffix padded to the same bucketed span the
                        # cold path reduces over (W - hit junk tokens):
                        # equal extents keep hit and cold bit-identical
                        stoks = np.zeros((W - hit_len,), np.int32)
                        stoks[:P - hit_len] = req.prompt[hit_len:]
                        cache = self._suffix(
                            self.params, cache, slot_,
                            runner.place_table(sched.block_tables[slot]),
                            jnp.asarray(stoks)[None], hit_len)
                        if W > P:
                            cache = self._set_len(
                                cache, slot_, jnp.asarray(P, jnp.int32))
                        shape_key = ("suffix", hit_len, W - hit_len)
                        activate(slot, req)
                    else:
                        toks = np.zeros((W,), np.int32)
                        toks[:P] = req.prompt
                        _, sub = self._prefill(
                            self.params, jnp.asarray(toks)[None],
                            modality1)
                        if paged:
                            cache = self._write(
                                cache, slot_, sub,
                                runner.place_table(sched.block_tables[slot]))
                        else:
                            cache = self._write(cache, slot_, sub)
                        if W > P:
                            # junk pad KV stays masked above the true len
                            cache = self._set_len(
                                cache, slot_, jnp.asarray(P, jnp.int32))
                        shape_key = ("cold", W)
                        activate(slot, req)
                    if info is not None:
                        stats.record_admission(P, hit_len)
                    if shape_key is not None:
                        jax.block_until_ready(cache)
                        stats.classify(shape_key, time.perf_counter() - t0)

                if jobs:
                    # at most ONE prompt chunk per engine iteration
                    # (Sarathi-style): the head walk advances by
                    # prefill_chunk tokens, then the decode scan below
                    # still runs for every active slot
                    slot = jobs[0]
                    job = prefilling[slot]
                    req = job["req"]
                    t0 = time.perf_counter()
                    cache, done, shape_key = self._run_chunk(cache, slot,
                                                             job)
                    stats.prefill_chunks += 1
                    jax.block_until_ready(cache)
                    stats.classify(shape_key, time.perf_counter() - t0)
                    if done:
                        jobs.popleft()
                        del prefilling[slot]
                        # activate BEFORE this iteration's scan: the
                        # slot's first real decode tokens come from it
                        # (no junk window between prefill and decode)
                        activate(slot, req)

                # a speculative round replaces this iteration's scan
                # chunk when ANY decoding slot's carried MI sits strictly
                # below the gate (threshold 0 therefore never drafts and
                # the loop is byte-for-byte the plain scan path); decided
                # before grants so the lookahead matches what the round
                # will write (k draft positions instead of a chunk).
                # Adaptive depth: the round drafts at the drafting
                # slots' MINIMUM current k, so no slot overdrafts past
                # its own EMA-chosen depth.
                drafting = [req for slot, req in sched.active()
                            if slot in decoding
                            and req.last_mi < self.spec_mi_threshold]
                run_spec = self.spec_decode and bool(drafting)
                k_round = min(req.spec_k_cur or self.spec_k
                              for req in drafting) if run_spec \
                    else self.spec_k
                ahead = k_round if run_spec else self.chunk
                if paged:
                    # incremental grant: map the blocks the coming chunk
                    # can write, on demand from the pool (capped at each
                    # request's prompt+max_new budget); re-upload the
                    # device table (tiny: slots x MB) only when
                    # something actually changed since the last chunk
                    for slot, req in sched.active():
                        if slot in prefilling:
                            continue     # prompt blocks mapped at admission
                        ids = sched.grant(slot, len(req.prompt)
                                          + min(len(req.tokens) + ahead,
                                                req.max_new_tokens))
                        if ids is None:
                            # the pool cannot grow this slot even after
                            # LRU-evicting cached blocks: preempt — blocks
                            # release, the lifecycle transition clears the
                            # output, the request restarts from the queue
                            # FRONT
                            sched.preempt(slot)
                            decoding.discard(slot)
                            active = active.at[slot].set(False)
                    sync_table()

                stats.trace(sched)
                # ONE unit of lane work per iteration (admission or a
                # chunk at the verify S): escalations drain alongside
                # the main pool without stalling its decode cadence
                lane_ran = lane.step(stats) if lane is not None else False
                if not decoding:
                    if not jobs and not admitted and not lane_ran \
                            and not fired:
                        raise RuntimeError(
                            "scheduler stalled: queued requests, no "
                            "admission, nothing prefilling or decoding")
                    continue             # prefill-only iteration: no scan
                if paged:
                    MB = sched.block_tables.shape[1]
                    # the gather path materializes every slot's full
                    # logical span each step, occupied or not (a spec
                    # round's draft reads decode attention for its k
                    # steps exactly like k scan steps; the verify is
                    # head-only and touches no KV)
                    stats.attn_blocks_span += self.num_slots * MB * ahead
                    if self.decode_attn == "kernel":
                        # the kernel reads only mapped blocks under
                        # each occupied slot's depth
                        for slot, occupant in sched.active():
                            if slot in prefilling:
                                continue
                            len0 = len(occupant.prompt) \
                                + len(occupant.tokens)
                            mapped = sched.mapped_blocks(slot)
                            stats.attn_blocks_read += sum(
                                kv_blocks_read(len0 + t + 1, mapped,
                                               self.kv_block, MB)
                                for t in range(ahead))

                if run_spec:
                    tok, cache, active, flags = self._spec_round(
                        sched, stats, decoding, tok, cache, active, flags,
                        k=k_round, escalate=maybe_escalate)
                    continue

                stats.chunks_run += 1
                stats.full_model_calls += self.chunk
                stats.steps_run += self.chunk
                t0 = time.perf_counter()
                tok, cache, flags, ys = self._scan(
                    self.params, tok, cache, jnp.asarray(step0, jnp.int32),
                    active, flags)
                ys = jax.device_get(ys)            # the chunk's single sync
                stats.arrivals.append(time.perf_counter())
                stats.decode_s += time.perf_counter() - t0
                step0 += self.chunk

                for slot, req in sched.active():
                    if slot in prefilling:
                        continue         # mid-prefill: junk steps, no harvest
                    finished = False
                    for t in range(self.chunk):
                        tk = int(ys["token"][t, slot])
                        req.tokens.append(tk)
                        for name in ("H", "SE", "MI", "p_max"):
                            getattr(req, name).append(float(ys[name][t, slot]))
                        req.epistemic_flags += int(ys["epistemic"][t, slot])
                        req.aleatoric_flags += int(ys["aleatoric"][t, slot])
                        req.last_mi = float(ys["MI"][t, slot])
                        done_eos = self.eos_id is not None and tk == self.eos_id
                        if done_eos or len(req.tokens) >= req.max_new_tokens:
                            req.transition(
                                "finished",
                                reason="eos" if done_eos else "length")
                            sched.evict(slot)
                            decoding.discard(slot)
                            active = active.at[slot].set(False)
                            finished = True
                            break
                    # escalation check on the slot's CARRIED (chunk-end)
                    # MI: unfinished flagged slots finish on the lane
                    if not finished and maybe_escalate(slot, req):
                        active = active.at[slot].set(False)

        except BaseException:
            # eviction / exception / early-exit path: slots mid-decode
            # still hold blocks — release them so the pool balances even
            # when the run dies (evict also settles any pending CoW ref
            # and donates prompt blocks to the prefix tree, exactly like
            # a clean eviction would have)
            for slot, _ in list(sched.active()):
                sched.evict(slot)
            raise
        finally:
            # leak check on EVERY exit path, clean drain or not: each
            # block is either free or held by the prefix cache (cached
            # refcounts included) and no reservation is outstanding
            # (tests/test_paged_attention.py::TestEngineRobustness::
            # test_mid_run_exception_releases_blocks)
            if alloc is not None:
                cached_end = pcache.cached_blocks() if pcache else 0
                if alloc._reserved or alloc.in_use != cached_end:
                    raise RuntimeError(
                        f"block leak after drain: {alloc.in_use} in use "
                        f"vs {cached_end} cached, {alloc._reserved} "
                        "reserved")

        return stats.results(self, requests, sched=sched, alloc=alloc,
                             pcache=pcache, cache=cache, flags=flags)
