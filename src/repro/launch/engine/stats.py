"""Per-run serving telemetry (the engine's observability layer).

``ServeStats`` owns every counter the engine run accumulates — prefill
compile-vs-steady classification, prefix-cache hit accounting, the
decode-attention HBM block tally, the downsampled scheduler trace,
decode-chunk arrival timestamps — and builds the results dict
``ServeEngine.run`` returns.  Pure host-side: nothing here touches jax
beyond ``device_flag_counters`` reading back the scan carry the engine
hands it.
"""

from __future__ import annotations

import time
from collections import Counter

import numpy as np

from repro.models import registry as M


def _pcts(values) -> tuple[float, float]:
    """(p50, p99) with nearest-rank p99 — at small N an interpolated
    p99 fabricates a latency no request experienced."""
    arr = np.array(values) if len(values) else np.zeros((1,))
    return (float(np.percentile(arr, 50)),
            float(np.percentile(arr, 99, method="higher")))


class ServeStats:
    """Counters for one ``ServeEngine.run`` + the results-dict builder.

    The engine mutates the counter attributes directly inside its chunk
    loop (they are the same names the monolithic loop used as locals);
    ``classify`` splits each prefill dispatch into compile-vs-steady by
    whether its shape key was seen before, ``trace`` appends the
    scheduler/pool snapshot every ``trace_every``-th chunk, and
    ``results`` assembles the full metrics payload.
    """

    def __init__(self, *, trace_every: int):
        self.trace_every = trace_every
        self.t_start = time.perf_counter()
        self.decode_s = 0.0
        # the jitted prefill compiles once per distinct prompt length
        # (suffix prefill: per distinct suffix length); classify each
        # admission's time accordingly so mixed-length traffic doesn't
        # launder recompiles into the steady-state stat
        self.compile_times: list[float] = []
        self.steady_times: list[float] = []
        self.seen_prefill_shapes: set[tuple] = set()
        # prefix-cache counters + per-chunk scheduler/pool trace
        self.pc_hits = self.pc_misses = self.pc_cow = 0
        self.pc_tokens = self.pc_saved = 0
        self.sched_trace: list[dict] = []
        self.chunks_run = 0
        # decode-attention HBM accounting (paged): physical KV blocks the
        # selected read path touches per decode step vs the full logical
        # span the gather path materializes (kernel skip rule in host
        # arithmetic, kernels.paged_attention.kv_blocks_read)
        self.attn_blocks_read = 0
        self.attn_blocks_span = 0
        self.prefill_chunks = 0
        # OOD escalation: requests handed to the high-S verify lane when
        # their carried MI crossed --escalate-mi, the tokens that lane
        # finished for them, and the requests it could NOT take (prompt
        # + budget exceeding the lane's max_len)
        self.escalations = 0
        self.esc_by_class: Counter = Counter()
        self.esc_tokens = 0
        self.esc_skipped = 0
        self.esc_decode_s = 0.0
        self.esc_steps = 0
        # adaptive spec-decode depth: per-slot EMA grow/shrink events
        # and the range of round depths actually drafted
        self.spec_k_up = 0
        self.spec_k_down = 0
        self.spec_round_k_min = None
        self.spec_round_k_max = None
        # speculative decoding: rounds run, proposals drafted/accepted/
        # emitted, partial-round rollbacks, and MI-gated (non-drafting)
        # slot-rounds.  full_model_calls counts full-S-sample dispatches
        # (chunk per scan, ONE per batched verify) — the quantity spec
        # decode exists to reduce; steps_run the real KV-advancing steps
        # either path executed (replaces the chunks_run*chunk estimate)
        self.spec_rounds = 0
        self.spec_drafted = 0
        self.spec_accepted = 0
        self.spec_emitted = 0
        self.spec_rollbacks = 0
        self.spec_gated = 0
        self.full_model_calls = 0
        self.steps_run = 0
        # decode-token inter-arrival: one timestamp per scan that served
        # at least one decoding slot — the stall a long batch prefill
        # injects between consecutive chunks is exactly what chunked
        # prefill bounds (decode_interarrival_p99_s)
        self.arrivals: list[float] = []

    def classify(self, shape_key: tuple, dt: float) -> None:
        if shape_key in self.seen_prefill_shapes:
            self.steady_times.append(dt)
        else:
            self.seen_prefill_shapes.add(shape_key)
            self.compile_times.append(dt)

    def record_round_k(self, k: int) -> None:
        """Track the range of draft depths adaptive-k rounds used."""
        self.spec_round_k_min = k if self.spec_round_k_min is None \
            else min(self.spec_round_k_min, k)
        self.spec_round_k_max = k if self.spec_round_k_max is None \
            else max(self.spec_round_k_max, k)

    def record_admission(self, prompt_len: int, hit_len: int) -> None:
        """Prefix-cache hit accounting for one paged admission."""
        self.pc_hits += bool(hit_len)
        self.pc_misses += not hit_len
        self.pc_tokens += prompt_len
        self.pc_saved += hit_len

    def trace(self, sched) -> None:
        """Downsampled pool/queue snapshot: a long run would otherwise
        grow host memory (and the results payload) by one dict per
        chunk, unbounded."""
        if self.chunks_run % self.trace_every == 0:
            self.sched_trace.append(sched.pool_stats())

    def results(self, engine, requests, *, sched, alloc, pcache, cache,
                flags) -> dict:
        """Assemble the engine metrics payload from the run's counters
        plus the terminal scheduler / allocator / cache state."""
        paged = engine.kv_layout == "paged"
        total_s = time.perf_counter() - self.t_start
        gen_tokens = sum(len(r.tokens) for r in requests)
        # KV residency accounting: dense permanently owns num_slots
        # strips of max_len; paged owns only the blocks actually mapped
        # (peak over the run), which is what mixed-length traffic saves
        kv_alloc_bytes = M.kv_bytes(cache)
        if paged:
            token_bytes = kv_alloc_bytes / (engine.kv_blocks
                                            * engine.kv_block)
            block_bytes = kv_alloc_bytes // engine.kv_blocks
            kv_stats = {
                "layout": "paged",
                "block_tokens": engine.kv_block,
                "blocks_total": engine.kv_blocks,
                "blocks_peak": alloc.peak_in_use,
                "bytes_in_use_peak": alloc.peak_in_use * block_bytes,
                "bytes_dense_equiv": int(token_bytes * engine.num_slots
                                         * engine.max_len),
            }
        else:
            kv_stats = {
                "layout": "dense",
                "bytes_in_use_peak": kv_alloc_bytes,
                "bytes_dense_equiv": kv_alloc_bytes,
            }
        # block-sparse decode attention accounting: KV bytes the selected
        # read path pulls from HBM per decode step vs the full logical
        # span (what gather materializes regardless of residency)
        steps_run = self.steps_run
        if paged:
            read_blocks = self.attn_blocks_read \
                if engine.decode_attn == "kernel" else self.attn_blocks_span
            decode_attn_stats = {
                "mode": engine.decode_attn,
                "kv_bytes_read_per_step": read_blocks * block_bytes
                / max(steps_run, 1),
                "kv_bytes_span_per_step": self.attn_blocks_span
                * block_bytes / max(steps_run, 1),
                "kv_blocks_read": read_blocks,
                "kv_blocks_span": self.attn_blocks_span,
            }
        else:
            decode_attn_stats = {"mode": "gather"}
        lat = np.array([r.latency_s for r in requests]) if requests \
            else np.zeros((1,))
        queue_p50, queue_p99 = _pcts([r.queue_time_s for r in requests])
        svc_p50, svc_p99 = _pcts([r.service_time_s for r in requests])
        # per-priority-class breakdown: under a priority policy the
        # aggregate p99 hides exactly the split the policy exists to
        # create, so report latency AND its queue/service decomposition
        # per class alongside that class's escalation/preemption counts
        per_class = {}
        for cls in sorted({r.priority for r in requests}):
            group = [r for r in requests if r.priority == cls]
            c_lat = _pcts([r.latency_s for r in group])
            c_queue = _pcts([r.queue_time_s for r in group])
            c_svc = _pcts([r.service_time_s for r in group])
            per_class[cls] = {
                "num_requests": len(group),
                "latency_p50_s": c_lat[0], "latency_p99_s": c_lat[1],
                "queue_p50_s": c_queue[0], "queue_p99_s": c_queue[1],
                "service_p50_s": c_svc[0], "service_p99_s": c_svc[1],
                "escalations": sum(r.was_escalated for r in group),
                "preemptions": sum(r.preempt_count for r in group),
            }
        epi = sum(r.epistemic_flags for r in requests)
        alea = sum(r.aleatoric_flags for r in requests)
        return {
            "requests": requests,
            "num_requests": len(requests),
            "gen_tokens": gen_tokens,
            "total_s": total_s,
            "decode_s": self.decode_s,
            # first prefill per prompt length includes compilation; the
            # rest are steady-state dispatch
            "prefill_compile_s": float(np.sum(self.compile_times)),
            "prefill_steady_s": float(np.mean(self.steady_times))
            if self.steady_times else 0.0,
            "decode_tok_per_s": gen_tokens / max(self.decode_s, 1e-9),
            "e2e_tok_per_s": gen_tokens / max(total_s, 1e-9),
            "latency_p50_s": float(np.percentile(lat, 50)),
            # nearest-rank (no interpolation): at small N a linear-
            # interpolated p99 fabricates a tail latency no request
            # experienced; "higher" reports a latency that actually
            # happened (= max below 100 requests)
            "latency_p99_s": float(np.percentile(lat, 99,
                                                 method="higher")),
            "latency_max_s": float(lat.max()),
            # latency decomposition: time queued (admission pressure,
            # what a priority policy trades between classes) vs time in
            # a slot (prefill + decode + any escalation tail)
            "queue_time_p50_s": queue_p50,
            "queue_time_p99_s": queue_p99,
            "service_time_p50_s": svc_p50,
            "service_time_p99_s": svc_p99,
            "policy": sched.policy.name,
            "per_class": per_class,
            "kv": kv_stats,
            # block-sparse decode kernel vs gather HBM traffic
            "decode_attn": decode_attn_stats,
            # radix prefix cache over the paged pool: zero-compute hit
            # spans, CoW divergence copies, LRU pressure evictions
            "prefix_cache": {
                "enabled": engine.prefix_cache,
                "hits": self.pc_hits,
                "misses": self.pc_misses,
                "hit_rate": self.pc_hits / max(self.pc_hits
                                               + self.pc_misses, 1),
                "prompt_tokens": self.pc_tokens,
                "prompt_tokens_saved": self.pc_saved,
                "saved_frac": self.pc_saved / max(self.pc_tokens, 1),
                "cow_copies": self.pc_cow,
                "cache_evictions": pcache.evictions if pcache else 0,
                "blocks_cached_end": (pcache.cached_blocks()
                                      if pcache else 0),
            },
            # scheduler snapshot (queue depth + pool occupancy) every
            # trace_every chunks — downsampled so long runs don't grow
            # host memory linearly in chunks decoded
            "sched_trace": self.sched_trace,
            "sched_trace_every": self.trace_every,
            "chunks_run": self.chunks_run,
            # chunked-prefill / growable-table telemetry
            "prefill_mode": engine.prefill_mode,
            "prefill_chunk": engine.prefill_chunk,
            "prefill_chunks": self.prefill_chunks,
            # distinct prefill/chunk shapes traced (bucketing collapses
            # per-prompt-length recompiles to one per kv_block bucket)
            "prefill_compiles": len(self.seen_prefill_shapes),
            "table_growths": sched.table_growths,
            # single source of truth is the scheduler: every preemption
            # (admission-pressure victim or grant-failure last resort)
            # goes through SlotScheduler.preempt
            "preemptions": sched.preemptions,
            # MI-triggered OOD escalation: requests finished on the
            # high-S sidecar runner after their carried MI crossed the
            # --escalate-mi threshold (cf. examples/blood_cell_ood.py)
            "escalation": {
                "enabled": engine.escalate_mi is not None,
                "mi_threshold": engine.escalate_mi,
                "verify_samples": engine.escalate_s,
                "escalations": self.escalations,
                "by_class": dict(self.esc_by_class),
                "tokens": self.esc_tokens,
                "skipped_too_long": self.esc_skipped,
                "decode_s": self.esc_decode_s,
                "steps": self.esc_steps,
            },
            # uncertainty-gated speculative decoding: acceptance per
            # drafted proposal, emitted tokens per round, and the
            # full-S-sample dispatch count the rounds amortize (a scan
            # chunk costs ``chunk`` full-model calls, a verify ONE)
            "spec_decode": {
                "enabled": engine.spec_decode,
                "k": engine.spec_k,
                "mi_threshold": engine.spec_mi_threshold,
                "draft_samples": engine.spec_draft_s,
                "rounds": self.spec_rounds,
                "drafted": self.spec_drafted,
                "accepted": self.spec_accepted,
                "acceptance_rate": self.spec_accepted
                / max(self.spec_drafted, 1),
                "emitted": self.spec_emitted,
                "tokens_per_round": self.spec_emitted
                / max(self.spec_rounds, 1),
                "rollbacks": self.spec_rollbacks,
                "gated_slot_rounds": self.spec_gated,
                "full_model_calls": self.full_model_calls,
                # adaptive draft depth: per-slot acceptance EMA walks k
                # inside [k_min, k_max]; with k_min == k == k_max the
                # depth is pinned and the engine is bitwise-identical
                # to the fixed-k build
                "k_min": engine.spec_k_min,
                "k_max": engine.spec_k_max,
                "k_up": self.spec_k_up,
                "k_down": self.spec_k_down,
                "round_k_min": self.spec_round_k_min,
                "round_k_max": self.spec_round_k_max,
            },
            # worst gap between consecutive decode-serving scans: the
            # stall a monolithic batch prefill injects mid-stream, which
            # interleaved chunked prefill bounds at ~one chunk's compute
            "decode_interarrival_p99_s": float(np.percentile(
                np.diff(self.arrivals), 99, method="higher"))
            if len(self.arrivals) >= 2 else 0.0,
            "epistemic_flags": int(epi),
            "aleatoric_flags": int(alea),
            "flags_per_1k_tokens": {
                "epistemic": 1000.0 * epi / max(gen_tokens, 1),
                "aleatoric": 1000.0 * alea / max(gen_tokens, 1),
            },
            # device-side telemetry from the scan carry: per-slot totals a
            # pure-device driver could read without syncing ys.  Upper-
            # bounds the exact host accounting above (a request finishing
            # mid-chunk keeps counting until its chunk boundary).
            "device_flag_counters": {
                k: np.asarray(v).tolist() for k, v in flags.items()
            },
        }
