"""Layered serving-engine package.

One layer per module, host-side policy strictly above device dispatch:

  engine.py     -- ServeEngine: serving policy + the per-chunk loop
  scheduler.py  -- Request lifecycle state machine / PrefixAdmit /
                   SlotScheduler (admission, grants, preemption, block
                   tables; numpy only)
  policy.py     -- SchedPolicy: the admission/eviction DECISION layer
                   (fifo reference, priority classes + SLO deadlines)
  escalate.py   -- EscalationLane: high-S OOD verification sidecar
  block_pool.py -- BlockAllocator: refcounted KV block accounting
  runner.py     -- ModelRunner: compiled callables + ALL device
                   placement, incl. the --mesh tensor-parallel mode;
                   decode_loop_reference (parity oracle / baseline)
  stats.py      -- ServeStats: run counters + the results payload
  mesh_check.py -- sharded-vs-unsharded parity + scaling CLI

``launch.serve`` remains the CLI and the back-compat import surface;
it re-exports everything below.
"""

from repro.launch.engine.block_pool import BlockAllocator
from repro.launch.engine.engine import ServeEngine
from repro.launch.engine.escalate import EscalationLane
from repro.launch.engine.policy import (FifoPolicy, PriorityPolicy,
                                        SchedPolicy, get_policy)
from repro.launch.engine.runner import (ModelRunner, decode_loop_reference,
                                        resolve_mesh)
from repro.launch.engine.scheduler import (LIFECYCLE, PrefixAdmit, Request,
                                           SlotScheduler)
from repro.launch.engine.stats import ServeStats

__all__ = [
    "BlockAllocator", "EscalationLane", "FifoPolicy", "LIFECYCLE",
    "ModelRunner", "PrefixAdmit", "PriorityPolicy", "Request",
    "SchedPolicy", "ServeEngine", "ServeStats", "SlotScheduler",
    "decode_loop_reference", "get_policy", "resolve_mesh",
]
