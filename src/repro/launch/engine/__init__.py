"""Layered serving-engine package.

One layer per module, host-side policy strictly above device dispatch:

  engine.py     -- ServeEngine: serving policy + the per-chunk loop
  scheduler.py  -- Request / PrefixAdmit / SlotScheduler (admission,
                   grants, preemption, block tables; numpy only)
  block_pool.py -- BlockAllocator: refcounted KV block accounting
  runner.py     -- ModelRunner: compiled callables + ALL device
                   placement, incl. the --mesh tensor-parallel mode;
                   decode_loop_reference (parity oracle / baseline)
  stats.py      -- ServeStats: run counters + the results payload
  mesh_check.py -- sharded-vs-unsharded parity + scaling CLI

``launch.serve`` remains the CLI and the back-compat import surface;
it re-exports everything below.
"""

from repro.launch.engine.block_pool import BlockAllocator
from repro.launch.engine.engine import ServeEngine
from repro.launch.engine.runner import (ModelRunner, decode_loop_reference,
                                        resolve_mesh)
from repro.launch.engine.scheduler import (PrefixAdmit, Request,
                                           SlotScheduler)
from repro.launch.engine.stats import ServeStats

__all__ = [
    "BlockAllocator", "ModelRunner", "PrefixAdmit", "Request",
    "ServeEngine", "ServeStats", "SlotScheduler",
    "decode_loop_reference", "resolve_mesh",
]
