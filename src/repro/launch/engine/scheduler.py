"""Host-side request scheduling (the serving engine's admission layer).

``Request`` is the unit of work — an explicit lifecycle state machine
(``new → queued → prefilling → decoding → finished``, with
``preempted`` re-entering at ``queued`` and ``escalated`` finishing on
the high-sample lane) whose every edge funnels through ONE audited
``transition`` method, so scheduler, engine, spec-decode rollback and
stats never mutate lifecycle fields ad hoc.  ``SlotScheduler`` maps
queued requests onto fixed decode slots through a pluggable
``policy.SchedPolicy`` (fifo = the bit-exact reference; priority adds
classes + SLO deadlines + admission-time preemption) and — on the
paged KV layout — owns the per-slot block tables over a
``block_pool.BlockAllocator``: admission, on-demand decode grants
(tables WIDEN when a grant outruns them), LRU pressure eviction
through the prefix cache, and preemption as the last resort.
Everything here is plain Python + numpy; device work (prefill, CoW
copies, table uploads) is the engine's job, driven by the records this
layer produces.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Optional

import numpy as np

from repro.launch.engine.block_pool import BlockAllocator
from repro.launch.engine.policy import FifoPolicy, SchedPolicy

# the request lifecycle: every legal edge of the state machine.  One
# transition method audits against this map, so an illegal move (e.g.
# harvesting into a preempted request, finishing twice) raises instead
# of silently corrupting per-request accounting.
LIFECYCLE = {
    "new": ("queued",),
    "queued": ("prefilling",),
    "prefilling": ("decoding", "preempted"),
    "decoding": ("finished", "preempted", "escalated"),
    "preempted": ("queued",),
    "escalated": ("finished",),
    "finished": (),
}


@dataclasses.dataclass
class Request:
    """One serving request plus its accumulated results."""

    rid: int
    prompt: np.ndarray                    # (S,) int32
    max_new_tokens: int
    # priority CLASS (lower value = better class; 0 is the best) and
    # optional SLO deadline offset — only the priority policy reads
    # them, fifo traffic leaves the defaults
    priority: int = 0
    slo_s: Optional[float] = None
    # engine step count (stats.steps_run) at which this request joins
    # the queue; 0 = submitted up front.  Bursty arrival traces for the
    # priority benchmarks are built from this
    arrival_step: int = 0
    t_submit: float = 0.0
    t_finish: float = 0.0
    finish_reason: str = ""
    tokens: list = dataclasses.field(default_factory=list)
    H: list = dataclasses.field(default_factory=list)
    SE: list = dataclasses.field(default_factory=list)
    MI: list = dataclasses.field(default_factory=list)
    p_max: list = dataclasses.field(default_factory=list)
    epistemic_flags: int = 0
    aleatoric_flags: int = 0
    # MI of the most recently harvested token; the engine's speculative
    # rounds gate on it (only slots with last_mi strictly below the
    # spec threshold draft).  +inf until the first token lands — a fresh
    # or just-preempted slot never speculates before the model has shown
    # it is confident there.
    last_mi: float = float("inf")
    # the slot this request was (last) admitted into.  Telemetry, but
    # load-bearing for parity tests: operand-mode decode noise folds the
    # slot index, so two runs only produce bitwise-equal streams for
    # requests that landed in the same slot.
    slot: Optional[int] = None
    # lifecycle state + audited history of (state, timestamp) edges
    state: str = "new"
    history: list = dataclasses.field(default_factory=list)
    # wall time spent waiting in the queue (accumulates across preempt
    # re-entries); latency_s - queue_time_s is the service time
    queue_time_s: float = 0.0
    preempt_count: int = 0
    # submission order (assigned once by the scheduler); the priority
    # policy's final tie-break, so equal-priority traffic stays FIFO
    seq: int = -1
    # adaptive speculative draft depth: the slot's current k and the
    # acceptance-rate EMA driving it (engine-owned, reset on preempt)
    spec_k_cur: int = 0
    spec_ema: Optional[float] = None
    _t_queued: float = dataclasses.field(default=0.0, repr=False)

    def transition(self, to: str, *, reason: str = "") -> None:
        """THE audited lifecycle edge — every state change funnels
        through here.  Raises on an illegal move; applies the edge's
        side effects exactly once: ``queued`` stamps t_submit (first
        entry) and opens the queue-wait clock, ``prefilling`` closes
        it into queue_time_s, ``preempted`` clears the accumulated
        output (re-admission replays from the prompt) and resets the
        spec-decode EMA, ``finished`` stamps t_finish/finish_reason."""
        if to not in LIFECYCLE[self.state]:
            raise ValueError(
                f"request {self.rid}: illegal lifecycle transition "
                f"{self.state!r} -> {to!r} (legal: "
                f"{LIFECYCLE[self.state]})")
        now = time.perf_counter()
        if to == "queued":
            if self.state == "new":
                self.t_submit = now
            self._t_queued = now
        elif to == "prefilling":
            self.queue_time_s += now - self._t_queued
        elif to == "preempted":
            self.preempt_count += 1
            self.tokens.clear()
            for name in ("H", "SE", "MI", "p_max"):
                getattr(self, name).clear()
            self.epistemic_flags = 0
            self.aleatoric_flags = 0
            self.last_mi = float("inf")
            self.spec_k_cur = 0
            self.spec_ema = None
        elif to == "finished":
            self.t_finish = now
            self.finish_reason = reason
        self.state = to
        self.history.append((to, now))

    @property
    def latency_s(self) -> float:
        return self.t_finish - self.t_submit

    @property
    def service_time_s(self) -> float:
        """Latency net of queue wait: admission + prefill + decode
        (+ replayed work after a preemption — the preempted tokens are
        re-decoded, which is service, not queueing)."""
        return self.latency_s - self.queue_time_s

    @property
    def was_escalated(self) -> bool:
        return any(s == "escalated" for s, _ in self.history)


@dataclasses.dataclass
class PrefixAdmit:
    """Per-slot prefix-cache admission record the engine acts on.

    ``tokens`` of the prompt are already resident in shared blocks
    mapped read-only into the slot's table; prefill runs only on the
    suffix.  ``cow`` is a pending ``(src, dst)`` device-side block copy:
    the partially-matched tail block ``src`` stays referenced until the
    engine copies it into ``dst`` (already swapped into the table) and
    calls ``finish_cow``.
    """

    tokens: int
    cow: Optional[tuple] = None


class SlotScheduler:
    """Policy-driven admission of queued requests into fixed decode
    slots.

    Pure host-side bookkeeping (no jax): ``admit`` fills free slots in
    slot order with whatever request the ``policy`` selects (fifo — the
    default and the bit-exact reference — always picks the queue
    front), ``evict`` frees a slot for reuse.  When admission fails for
    the selected request (no free slot, or not enough pool), the policy
    may name a strictly-lower-priority DECODING slot to preempt on its
    behalf; preemptions performed inside ``admit`` are surfaced through
    ``take_preempted`` so the engine can deactivate those slots before
    acting on the new placements.

    With a ``BlockAllocator`` the scheduler also owns the paged-KV block
    tables: admission switches from "is a slot free" to "are enough
    blocks free" — the PROMPT's blocks plus a WATERMARK of free headroom
    (``num_slots`` blocks by default, waived when no slot is running) so
    in-flight decoders keep growing while the queue head defers (FIFO,
    no skip-ahead).  ``grant`` maps decode blocks on demand as slots
    deepen, capped at each request's ``prompt + max_new_tokens`` budget,
    WIDENING the block tables when a grant outruns them (the table
    width is a floor, not a ceiling); a grant the pool cannot cover
    even after LRU-evicting unreferenced cached blocks returns None and
    the engine preempts the slot (``preempt``: blocks released, request
    requeued at the queue front).  ``evict`` returns every block.

    With a ``prefix_cache`` (``launch.prefix_cache.RadixPrefixCache``)
    admission first walks the radix tree: the matched prefix's blocks
    are mapped into the slot's table shared (incref, read-only), only
    the uncached span reserves fresh blocks, a token-granular partial
    match allocates one extra block for the copy-on-write of the shared
    tail, and eviction INSERTS the request's prompt blocks into the tree
    (ownership transfers to the cache) before the slot's decref.  Under
    pool pressure admission asks the cache to LRU-evict unreferenced
    blocks before deferring.
    """

    def __init__(self, num_slots: int,
                 allocator: Optional[BlockAllocator] = None,
                 table_width: int = 0, prefix_cache=None,
                 watermark: Optional[int] = None,
                 policy: Optional[SchedPolicy] = None):
        self.slots: list[Optional[Request]] = [None] * num_slots
        self.queue: collections.deque[Request] = collections.deque()
        self.allocator = allocator
        self.prefix_cache = prefix_cache
        self.policy = policy if policy is not None else FifoPolicy()
        self.preemptions = 0
        self._seq = 0
        self._admit_preempted: list[tuple[int, Request]] = []
        # free-block headroom admission must leave for running decoders'
        # on-demand grants (now that their budgets are no longer
        # reserved up front); waived when nothing is running, so an
        # empty engine admits exactly what fits
        self.watermark = num_slots if watermark is None else watermark
        self.table_growths = 0
        if prefix_cache is not None and allocator is None:
            raise ValueError("prefix cache requires a BlockAllocator")
        if allocator is not None:
            if table_width < 1:
                raise ValueError("paged scheduling needs table_width "
                                 "(initial blocks per slot)")
            self.block_tables = np.full((num_slots, table_width), -1,
                                        np.int32)
            self._slot_blocks: list[list[int]] = \
                [[] for _ in range(num_slots)]
            # decode blocks still grantable per slot (budget, NOT an
            # allocator reservation): blocks_for(prompt + max_new) minus
            # what the slot already holds
            self._slot_budget = [0] * num_slots
            self._slot_prefix: list[Optional[PrefixAdmit]] = \
                [None] * num_slots
            self._slot_cow_src: list[Optional[int]] = [None] * num_slots
            # bumped on every table mutation (admit/grant/evict) so the
            # engine only re-uploads the device table when it changed
            self.table_version = 0
            self.table_growths = 0

    def submit(self, req: Request) -> None:
        if req.seq < 0:
            req.seq = self._seq
            self._seq += 1
        req.transition("queued")
        self.queue.append(req)

    def _pop(self, qi: int) -> Request:
        if qi == 0:
            return self.queue.popleft()
        req = self.queue[qi]
        del self.queue[qi]
        return req

    def _ensure_width(self, want: int) -> None:
        """Widen the host block tables to hold ``want`` blocks per slot
        (doubling, -1-padded).  The engine notices via table_version:
        the device table re-uploads at the new shape and the decode
        scan retraces once per growth."""
        w = self.block_tables.shape[1]
        if want <= w:
            return
        grown = np.full((len(self.slots), max(want, 2 * w)), -1, np.int32)
        grown[:, :w] = self.block_tables
        self.block_tables = grown
        self.table_growths += 1
        self.table_version += 1

    def _try_reserve(self, need: int, protect: frozenset) -> bool:
        """Reserve ``need`` blocks for an admission, LRU-evicting
        cached-but-unreferenced blocks first when the pool is short
        (``protect`` pins the hit being admitted).  On top of ``need``
        the pool must keep ``watermark`` blocks free for running slots'
        decode grants — waived when no slot is running (nothing to
        starve, and the head request could otherwise never admit)."""
        alloc = self.allocator
        wm = self.watermark if any(r is not None for r in self.slots) \
            else 0
        short = need + wm - alloc.available()
        if short > 0 and self.prefix_cache is not None:
            self.prefix_cache.evict_lru(short, protect=protect)
        if alloc.available() < need + wm:
            return False
        return alloc.reserve(need)

    def _admit_paged(self, slot: int, qi: int = 0) -> Optional[Request]:
        alloc = self.allocator
        req = self.queue[qi]
        P = len(req.prompt)
        nprompt = alloc.blocks_for(P)
        # grant cap, NOT a reservation: decode blocks are drawn from the
        # pool on demand, so admission only needs the prompt's blocks
        total = alloc.blocks_for(P + req.max_new_tokens)
        hit = self.prefix_cache.match(req.prompt) \
            if self.prefix_cache is not None else None
        if hit is not None and hit.tokens:
            # uncached span + one extra block when the shared tail needs
            # a copy-on-write duplicate before this slot writes into it
            need = nprompt - len(hit.blocks) + (1 if hit.partial else 0)
            if not self._try_reserve(need, frozenset(hit.blocks)):
                # liveness: when no live slot will ever free a block
                # (everything left is cache-held, pinned by this very
                # hit), fall back to a cold admission rather than
                # deadlocking on the hit's own protection
                if alloc.in_use > self.prefix_cache.cached_blocks():
                    return None           # a running slot will free some
                hit = None
        if hit is None or not hit.tokens:
            if not self._try_reserve(nprompt, frozenset()):
                return None               # pool exhausted: defer
            self._pop(qi)
            ids = alloc.alloc(nprompt)
            if self.prefix_cache is not None:
                self._slot_prefix[slot] = PrefixAdmit(tokens=0)
        else:
            self._pop(qi)
            self.prefix_cache.lock(hit)   # slot refs on shared blocks
            ids = list(hit.blocks)
            cow = None
            if hit.partial:
                [dst] = alloc.alloc(1)
                cow = (ids[-1], dst)      # src stays ref'd: finish_cow
                self._slot_cow_src[slot] = ids[-1]
                ids[-1] = dst
            ids += alloc.alloc(nprompt - len(hit.blocks))
            self._slot_prefix[slot] = PrefixAdmit(tokens=hit.tokens,
                                                  cow=cow)
        self._slot_budget[slot] = total - nprompt
        self._slot_blocks[slot] = ids
        self._ensure_width(len(ids))
        self.block_tables[slot, :] = -1
        self.block_tables[slot, :len(ids)] = ids
        self.table_version += 1
        return req

    def prefix_admit(self, slot: int) -> Optional[PrefixAdmit]:
        """The slot's prefix-cache admission record (None when the cache
        is off)."""
        return self._slot_prefix[slot] if self.prefix_cache is not None \
            else None

    def finish_cow(self, slot: int) -> None:
        """The engine copied the shared tail block device-side; release
        this slot's reference on the source (the tree keeps its own)."""
        src = self._slot_cow_src[slot]
        if src is None:
            raise ValueError(f"no pending CoW on slot {slot}")
        self._slot_cow_src[slot] = None
        self.allocator.free([src])

    def _preempt_for(self, candidate: Request) -> bool:
        """Ask the policy for a decoding slot to preempt so
        ``candidate`` can admit; False defers the candidate instead.
        Only DECODING occupants are offered (a preempted decode replays
        bit-exactly from its prompt; aborting a mid-prefill walk would
        throw away chunks already paid for), and every preemption
        strictly shrinks that set, so the admit loop terminates."""
        running = [(i, r) for i, r in enumerate(self.slots)
                   if r is not None and r.state == "decoding"]
        victim = self.policy.victim(candidate, running)
        if victim is None:
            return False
        self._admit_preempted.append((victim, self.preempt(victim)))
        return True

    def take_preempted(self) -> list[tuple[int, Request]]:
        """(slot, request) pairs the policy preempted inside the last
        ``admit`` call; the engine must deactivate those slots before
        acting on the new placements."""
        out = self._admit_preempted
        self._admit_preempted = []
        return out

    def admit(self) -> list[tuple[int, Request]]:
        placed = []
        self._admit_preempted = []
        while self.queue:
            qi = self.policy.select(self.queue)
            if qi is None:
                break
            candidate = self.queue[qi]
            slot = next((i for i, r in enumerate(self.slots)
                         if r is None), None)
            if slot is None:
                # every slot busy: the policy may preempt a strictly
                # lower-priority decoding slot for the candidate (fifo
                # never does — all slots busy simply ends admission)
                if not self._preempt_for(candidate):
                    break
                continue
            if self.allocator is not None:
                req = self._admit_paged(slot, qi)
                if req is None:
                    # pool short for the selected request: preempt for
                    # it (freed blocks retry the admission) or defer
                    if not self._preempt_for(candidate):
                        break
                    continue
            else:
                req = self._pop(qi)
            req.slot = slot
            req.transition("prefilling")
            self.slots[slot] = req
            placed.append((slot, req))
        return placed

    def grant(self, slot: int, target_len: int) -> Optional[list[int]]:
        """Map blocks so slot ``slot`` can hold ``target_len`` tokens.

        Draws from the pool on demand, capped at the request's
        ``prompt + max_new_tokens`` budget (junk steps a finished
        request runs until its chunk boundary drop against the unmapped
        tail instead of consuming pool) and widening the block tables
        when the target outruns them.  Returns the granted ids ([] when
        nothing is needed) or None when the pool cannot cover the
        shortfall even after LRU-evicting cached-but-unreferenced
        prefix blocks — the engine preempts the slot."""
        alloc = self.allocator
        have = len(self._slot_blocks[slot])
        want = min(alloc.blocks_for(target_len),
                   have + self._slot_budget[slot])
        if want <= have:
            return []
        n = want - have
        if alloc.available() < n and self.prefix_cache is not None:
            # a cached-but-unreferenced prefix must never starve a
            # running decoder (or livelock a deferred admission behind
            # it): reclaim before giving up
            self.prefix_cache.evict_lru(n - alloc.available(),
                                        protect=frozenset())
        if not alloc.reserve(n):
            return None
        ids = alloc.alloc(n)
        self._slot_budget[slot] -= n
        self._ensure_width(want)
        self.block_tables[slot, have:want] = ids
        self._slot_blocks[slot].extend(ids)
        self.table_version += 1
        return ids

    def rollback(self, slot: int, target_len: int) -> int:
        """Shrink a slot back to ``target_len`` tokens after a partially
        rejected speculative round: decode-granted blocks beyond
        ``blocks_for(target_len)`` return to the pool and re-credit the
        slot's grant budget.  Only ever drops blocks this slot drew via
        ``grant`` AFTER its prompt landed (target_len >= prompt length
        + 1 by construction), so every freed block is exclusively owned
        (refcount 1, never a shared prefix-cache block).  Junk KV the
        draft wrote into the kept tail block is masked by decode
        attention (positions >= len) and overwritten by later steps.
        Returns the number of blocks released."""
        alloc = self.allocator
        if alloc is None:
            return 0
        keep = alloc.blocks_for(target_len)
        blocks = self._slot_blocks[slot]
        if keep >= len(blocks):
            return 0
        drop = blocks[keep:]
        del blocks[keep:]
        alloc.free(drop)
        self._slot_budget[slot] += len(drop)
        self.block_tables[slot, keep:] = -1
        self.table_version += 1
        return len(drop)

    def preempt(self, slot: int) -> Request:
        """Evict a slot (growth grant failed, or the policy claimed it
        for a better candidate) and requeue its request at the queue
        FRONT (FIFO order preserved; the priority policy re-ranks
        anyway).  The audited ``preempted`` transition clears the
        request's accumulated output — on readmission it restarts from
        its prompt (depth-keyed decode noise replays the aborted
        stream bit-exactly when it lands in the same slot)."""
        req = self.evict(slot)
        req.transition("preempted")
        req.transition("queued")
        self.queue.appendleft(req)
        self.preemptions += 1
        return req

    def evict(self, slot: int) -> Request:
        req = self.slots[slot]
        if req is None:
            raise ValueError(f"evict of empty slot {slot}")
        self.slots[slot] = None
        if self.allocator is not None:
            if self.prefix_cache is not None:
                # adopt the prompt's blocks into the radix tree BEFORE
                # the slot lets go: chunks already cached share the
                # existing nodes, fresh ones transfer to the cache
                nprompt = self.allocator.blocks_for(len(req.prompt))
                self.prefix_cache.insert(req.prompt,
                                         self._slot_blocks[slot][:nprompt])
                if self._slot_cow_src[slot] is not None:
                    self.allocator.free([self._slot_cow_src[slot]])
                    self._slot_cow_src[slot] = None
                self._slot_prefix[slot] = None
            self.allocator.free(self._slot_blocks[slot])
            self._slot_blocks[slot] = []
            self._slot_budget[slot] = 0
            self.block_tables[slot, :] = -1
            self.table_version += 1
        return req

    def pool_stats(self) -> dict:
        """Queue depth + block-pool occupancy snapshot (free / reserved
        / cached / in-use counts), so allocator behavior is observable
        per chunk without a debugger."""
        out = {"queue_depth": len(self.queue),
               "active_slots": sum(r is not None for r in self.slots)}
        if self.allocator is not None:
            a = self.allocator
            out.update(
                blocks_free=len(a._free), blocks_reserved=a._reserved,
                blocks_in_use=a.in_use,
                blocks_utilization=a.utilization(),
                blocks_cached=(self.prefix_cache.cached_blocks()
                               if self.prefix_cache is not None else 0))
        return out

    def mapped_blocks(self, slot: int) -> int:
        """Physical blocks currently mapped into the slot's table (what
        the block-sparse decode kernel can actually read)."""
        return len(self._slot_blocks[slot])

    def active(self) -> list[tuple[int, Request]]:
        return [(i, r) for i, r in enumerate(self.slots) if r is not None]

    def has_work(self) -> bool:
        return bool(self.queue) or any(r is not None for r in self.slots)
