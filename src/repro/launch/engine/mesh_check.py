"""Sharded-vs-unsharded serving parity checker + mesh scaling bench.

Runs the SAME staggered mixed-length traffic through an unsharded
``ServeEngine`` and a ``--mesh``-sharded one and asserts the decoded
streams are BIT-IDENTICAL — token ids exactly, the (H, SE, MI, p_max)
uncertainty floats bitwise — in operand-entropy mode, per attention
family.  This is the executable form of the serve-TP exactness
argument (sharding/partition.py): only column-parallel shards exist
and each is all-gathered before any consumer contracts over it, so no
floating-point reduction is ever re-ordered.

CPU needs forced devices (set BEFORE jax imports — hence a fresh
process):

  XLA_FLAGS=--xla_force_host_platform_device_count=4 PYTHONPATH=src \
      python -m repro.launch.engine.mesh_check --families dense,moe

``--bench`` additionally measures decode tok/s at 1 device vs the
mesh (the ``mesh_scaling`` row of BENCH_serve.json); ``--json`` prints
a machine-readable result.  Exit code is non-zero on any mismatch.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import sys

import numpy as np

from repro.configs.registry import get_config, reduced
from repro.data.synthetic import TokenStreamState, token_batch

# one representative arch per attention family (all four serve paths:
# dense GQA, MoE with capacity routing, hybrid ssm+attention, encdec
# cross-attention); dense additionally runs with the prefix cache on
FAMILIES = {
    "dense": "qwen2_1_5b",
    "moe": "deepseek_moe_16b",
    "hybrid": "zamba2_7b",
    "encdec": "seamless_m4t_medium",
}

# staggered mixed-length traffic: admissions, evictions, grants and
# (on dense) prefix hits all land at different chunks, so the sharded
# engine must reproduce the reference under a non-trivial schedule
PROMPTS = (9, 17, 5, 24, 12)
GENS = (6, 9, 5, 8, 7)
SHARED = 8          # dense: requests 1 and 3 reuse request 0's opening
                    # block (one kv_block) to exercise cached-hit decode


def make_traffic(cfg, family: str):
    from repro.launch.engine import Request
    reqs = []
    base = None
    for i, (p, g) in enumerate(zip(PROMPTS, GENS)):
        toks, _ = token_batch(
            TokenStreamState(seed=100 + i, host=0, num_hosts=1),
            1, p, cfg.vocab_size)
        prompt = np.asarray(toks, np.int32)[0].copy()
        if i == 0:
            base = prompt
        elif family == "dense" and i in (1, 3):
            prompt[:SHARED] = base[:SHARED]
        reqs.append(Request(rid=i, prompt=prompt, max_new_tokens=g))
    return reqs


def run_engine(params, cfg, family: str, mesh):
    from repro.launch.engine import ServeEngine
    eng = ServeEngine(
        params, cfg, num_slots=2, max_len=32, chunk=4,
        kv_layout="paged", kv_block=8, kv_blocks=12,
        prefill_mode="chunked", prefill_chunk=8,
        prefix_cache=family == "dense", trace_every=4, mesh=mesh)
    return eng, eng.run(make_traffic(cfg, family))


def compare(ref: dict, got: dict) -> list[str]:
    """Field-by-field bitwise diff of two runs' request streams."""
    errs = []
    for a, b in zip(ref["requests"], got["requests"]):
        if a.tokens != b.tokens:
            errs.append(f"request {a.rid}: tokens diverge "
                        f"({a.tokens} vs {b.tokens})")
        for name in ("H", "SE", "MI", "p_max"):
            va, vb = getattr(a, name), getattr(b, name)
            if not (len(va) == len(vb)
                    and all(x == y for x, y in zip(va, vb))):
                errs.append(f"request {a.rid}: {name} not bitwise equal")
        if (a.epistemic_flags, a.aleatoric_flags) \
                != (b.epistemic_flags, b.aleatoric_flags):
            errs.append(f"request {a.rid}: flag counts diverge")
    return errs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--families", default="dense,moe,hybrid,encdec",
                    help="comma list of " + ",".join(FAMILIES))
    ap.add_argument("--mesh", default="1x4",
                    help="DxM debug-mesh shape for the sharded run")
    ap.add_argument("--bench", action="store_true",
                    help="also measure decode tok/s unsharded vs mesh "
                         "(the mesh_scaling bench row)")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="print a machine-readable result")
    args = ap.parse_args(argv)

    import jax
    from repro.launch.engine import resolve_mesh
    from repro.models import registry as M

    mesh = resolve_mesh(args.mesh)
    out = {"bench": "mesh_scaling", "mesh": args.mesh,
           "devices": jax.device_count(),
           "mesh_devices": int(mesh.devices.size), "families": {}}
    failed = False
    for family in args.families.split(","):
        cfg = reduced(get_config(FAMILIES[family]))
        # operand entropy: the seeded per-(slot, depth) noise stream the
        # bit-exactness contract is defined over
        cfg = dataclasses.replace(cfg, head_entropy="operand")
        params = M.init_params(jax.random.key(0), cfg)
        ref_eng, ref = run_engine(params, cfg, family, mesh=None)
        eng, got = run_engine(params, cfg, family, mesh=mesh)
        errs = compare(ref, got)
        failed |= bool(errs)
        out["families"][family] = {
            "arch": FAMILIES[family],
            "bitwise_equal": not errs,
            "errors": errs,
            "gen_tokens": ref["gen_tokens"],
            "prefill_mode": ref["prefill_mode"],
            "prefix_cache_hits": ref["prefix_cache"]["hits"],
        }
        if args.bench and family == "dense":
            # steady-state decode rate, compile excluded: re-run the
            # same traffic on the already-compiled engines
            ref2 = ref_eng.run(make_traffic(cfg, family))
            got2 = eng.run(make_traffic(cfg, family))
            out["tok_per_s_1dev"] = ref2["decode_tok_per_s"]
            out["tok_per_s_mesh"] = got2["decode_tok_per_s"]
            out["mesh_speedup"] = (got2["decode_tok_per_s"]
                                   / max(ref2["decode_tok_per_s"], 1e-9))
        if not args.as_json:
            status = "BITWISE OK" if not errs else "MISMATCH"
            print(f"{family:8s} ({FAMILIES[family]}): {status}  "
                  f"[{ref['gen_tokens']} tokens, "
                  f"prefill={ref['prefill_mode']}]")
            for e in errs:
                print(f"  {e}")
    out["ok"] = not failed
    if args.as_json:
        print(json.dumps(out))
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
