"""Device-facing model runner (the serving engine's execution layer).

``ModelRunner`` owns everything that touches jax for one serving run:
the (possibly mesh-sharded) parameters, every compiled callable the
engine dispatches — batch/suffix/chunked prefill, slot writes, CoW
block copies, the scan decode — and the placement of the KV cache and
block tables.  The engine above it is pure host-side policy; this is
the ONLY module where device placement decisions live.

Mesh mode (``mesh=resolve_mesh("1x4")``) shards decode tensor-parallel
over the mesh's ``model`` axis: parameters by the serve-TP rules
(``sharding/partition.serve_shardings_for`` — attention/ff/vocab
COLUMNS sharded, every contraction-feeding weight replicated), the
paged KV pool on its kv-head axis when divisible, and nothing else —
host-side scheduler state never leaves numpy.  Every callable is
dispatched under the serve-mesh context so the forced all-gathers in
``models.layers`` (``partition.gather_rep``) bake into the traced
computation, which is what keeps sharded decode BIT-EXACT against the
unsharded runner in operand-entropy mode: only column-parallel shards
exist, and each is all-gathered (pure data movement, no re-reduction)
before any consumer contracts over it.  Validated on a forced-host
4-device CPU mesh by ``launch.engine.mesh_check`` /
tests/test_mesh_runner.py.
"""

from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.entropy import KernelEntropy
from repro.launch import mesh as meshlib
from repro.launch import steps as S
from repro.models import registry as M
from repro.sharding.partition import serve_shardings_for, set_serve_mesh

# cache leaves carrying a per-head KV axis at -2 (self-attention pool
# or strips, hybrid attention pool, encdec self + cross strips); all
# other leaves (lens, tables, ssm/conv state) stay replicated
_KV_HEAD_LEAVES = ("k", "v", "attn_k", "attn_v", "ck", "cv")


def resolve_mesh(spec: Optional[str]) -> Optional[Mesh]:
    """Parse a ``--mesh DxM`` flag ("1x4" → a (data=1, model=4) mesh).

    None/""/"none" mean single-device serving (no mesh).  The shape
    must tile the process's device count; when it doesn't,
    ``make_debug_mesh`` falls back to a 1D ``("model",)`` mesh over
    every available device — on one device every serve-TP spec then
    degrades to replication and sharded serving is a no-op, which is
    what lets the same flag work under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` and on a
    bare CPU test process alike.
    """
    if not spec or spec == "none":
        return None
    parts = spec.lower().split("x")
    if len(parts) != 2 or not all(p.isdigit() for p in parts):
        raise ValueError(f"--mesh wants DxM (e.g. 1x4), got {spec!r}")
    return meshlib.make_debug_mesh((int(parts[0]), int(parts[1])),
                                   ("data", "model"))


class ModelRunner:
    """Compiled callables + device placement for one engine config.

    Receives POLICY-RESOLVED knobs from ``ServeEngine`` (kv_layout
    after the family fallback, cfg with ``decode_attn`` already
    substituted, prefill_mode after the support gate) and builds the
    jitted callables the engine's chunk loop dispatches.  With a
    ``mesh``, parameters are placed by the serve-TP rules, the cache's
    KV leaves are sharded on their head axis (replicating when the
    head count doesn't divide the model axis), and every callable runs
    under the serve-mesh context so the layer-level all-gather
    constraints bake in at trace time.
    """

    def __init__(self, params, cfg, *, max_len: int, chunk: int,
                 entropy: Optional[KernelEntropy],
                 mi_threshold: float, se_threshold: float,
                 kv_layout: str, kv_block: int, kv_blocks: int,
                 prefix_cache: bool, prefill_mode: str,
                 mesh: Optional[Mesh] = None,
                 spec_decode: bool = False, spec_k: int = 4,
                 spec_draft_s: int = 1):
        self.cfg = cfg
        self.max_len = max_len
        self.kv_layout = kv_layout
        self.kv_block = kv_block
        self.kv_blocks = kv_blocks
        self.mesh = mesh
        self.params = params if mesh is None else jax.device_put(
            params, serve_shardings_for(params, mesh))
        paged = kv_layout == "paged"
        if paged:
            # paged prefill builds a minimal prompt-length strip (the
            # scatter pages it out token by token); dense keeps the
            # engine-wide max_len strip its slot write needs
            self._prefill = self._jit(
                lambda p, t, m: M.prefill(p, cfg, t, t.shape[1], m))
            self._write = self._jit(
                lambda c, slot, sub, row: M.write_slot(cfg, c, slot, sub,
                                                       row),
                donate_argnums=(0,))
        else:
            self._prefill = self._jit(
                lambda p, t, m: M.prefill(p, cfg, t, max_len, m))
            self._write = self._jit(
                lambda c, slot, sub: M.write_slot(cfg, c, slot, sub),
                donate_argnums=(0,))
        self._chunk_fn = self._chunk_first = None
        if prefill_mode == "chunked":
            # one jitted walker per family kwarg shape; span (the whole
            # prompt's static attention-reduction extent) is static, so
            # compiles scale with distinct (chunk, span) pairs — bucketed
            # prompts collapse most of those (see prefill_compiles)
            if cfg.family == "moe":
                self._chunk_fn = self._jit(
                    lambda p, t, c, s, o, n, off, span: M.prefill_chunk(
                        p, cfg, t, c, s, o, n, span, expert_offsets=off),
                    static_argnums=(7,), donate_argnums=(2,))
            elif cfg.family == "hybrid":
                self._chunk_fn = self._jit(
                    lambda p, t, c, s, o, n, st, span, fin:
                    M.prefill_chunk(p, cfg, t, c, s, o, n, span,
                                    state=st, finalize=fin),
                    static_argnums=(7, 8), donate_argnums=(2,))
            elif cfg.family == "encdec":
                self._chunk_first = self._jit(
                    lambda p, t, c, s, o, n, fr, span: M.prefill_chunk(
                        p, cfg, t, c, s, o, n, span, frames=fr),
                    static_argnums=(7,), donate_argnums=(2,))
                self._chunk_fn = self._jit(
                    lambda p, t, c, s, o, n, span: M.prefill_chunk(
                        p, cfg, t, c, s, o, n, span),
                    static_argnums=(6,), donate_argnums=(2,))
            else:
                self._chunk_fn = self._jit(
                    lambda p, t, c, s, o, n, span: M.prefill_chunk(
                        p, cfg, t, c, s, o, n, span),
                    static_argnums=(6,), donate_argnums=(2,))
        self._suffix = self._copy = None
        if prefix_cache:
            # prefix-hit fast paths.  _suffix gathers the slot's cached
            # prefix strips from the pool, prefills ONLY the uncached
            # suffix against them (bit-exact vs the cold flash-attention
            # path; see layers.apply_attention_suffix) and scatters the
            # suffix KV at its logical offset.  _copy is the device-side
            # CoW block duplicate.
            def suffix_fn(p, c, slot, row, toks, plen):
                # gather only the blocks the hit spans (plen is static),
                # not the full table-width logical strip
                nb = -(-plen // kv_block)
                strips = {
                    n: jax.vmap(lambda pool: M.paged_gather(
                        pool, row[None, :nb]))(c[n])
                    for n in M.PAGED_KV_LEAVES if n in c}
                _, sub = M.prefill_suffix(p, cfg, toks, strips, plen)
                return M.write_slot(cfg, c, slot, sub, row, offset=plen)

            # plen is STATIC: bit-exactness vs the cold path needs the
            # suffix attention to reduce over exactly prefix + suffix
            # keys, so each (hit, suffix) length pair compiles once
            self._suffix = self._jit(suffix_fn, static_argnums=(5,),
                                     donate_argnums=(1,))
            self._copy = self._jit(
                lambda c, src, dst: M.copy_block(cfg, c, src, dst),
                donate_argnums=(0,))
        # depth pinning: bucketed/suffix/chunked prefill all write
        # strips wider than the true prompt, then fix the slot's len to
        # the real token count (full-prompt prefix hits need nothing
        # else at all)
        self._set_len = self._jit(
            lambda c, slot, n: dict(c, len=c["len"].at[slot].set(n)),
            donate_argnums=(0,))
        self._scan = self._jit(
            S.build_scan_decode(cfg, entropy=entropy, chunk=chunk,
                                mi_threshold=mi_threshold,
                                se_threshold=se_threshold),
            donate_argnums=(2,))
        self._draft = self._verify = self._spec_commit = None
        # per-k jit cache for speculative draft/verify: k is STATIC in
        # both builders, so the adaptive-k engine asks ``spec_fns(k)``
        # for each depth it visits and pays one trace per distinct k
        # (the commit is k-independent — it retraces per stacked shape)
        self._entropy = entropy
        self._mi_threshold = mi_threshold
        self._se_threshold = se_threshold
        self._spec_draft_s = spec_draft_s
        self._spec_k_fns: dict[int, tuple] = {}
        if spec_decode:
            # speculative round: k-step shared-body draft (cache donated
            # forward like the scan's), ONE vmapped full-S verify over
            # the stacked hiddens, then the masked rollback/commit
            self._draft, self._verify = self.spec_fns(spec_k)
            self._spec_commit = self._jit(S.build_spec_commit(cfg),
                                          donate_argnums=(0,))

    def spec_fns(self, k: int):
        """(draft, verify) compiled callables for draft depth ``k``,
        built lazily and cached per k — the adaptive-k rounds walk
        depths between ``--spec-k-min`` and ``--spec-k-max`` and reuse
        each depth's jits after its first visit."""
        if k not in self._spec_k_fns:
            draft = self._jit(
                S.build_spec_draft(self.cfg, entropy=self._entropy, k=k,
                                   draft_samples=self._spec_draft_s),
                donate_argnums=(2,))
            verify = self._jit(
                S.build_spec_verify(self.cfg, entropy=self._entropy, k=k,
                                    mi_threshold=self._mi_threshold,
                                    se_threshold=self._se_threshold))
            self._spec_k_fns[k] = (draft, verify)
        return self._spec_k_fns[k]

    def _jit(self, fn, **kw):
        """jit + serve-mesh context around every dispatch: tracing
        happens inside the wrapped call, so the ``gather_rep`` seams in
        models.layers see the mesh and bake their all-gather
        constraints into the compiled computation.  The context is
        cleared on exit so co-resident training code (whose sharding
        uses the separate train-mesh context) is never affected."""
        jitted = jax.jit(fn, **kw)
        if self.mesh is None:
            return jitted
        mesh = self.mesh

        def dispatch(*args):
            set_serve_mesh(mesh)
            try:
                return jitted(*args)
            finally:
                set_serve_mesh(None)
        return dispatch

    def make_cache(self, num_slots: int):
        """Build (and in mesh mode, place) the engine's KV cache: only
        the per-head KV leaves shard (heads axis over ``model``, with
        the usual divisibility fallback to replication); slot lens,
        block tables and recurrent ssm/conv state replicate — the host
        scheduler keeps mutating its numpy copies obliviously."""
        cache = M.make_cache(self.cfg, num_slots, self.max_len,
                             layout=self.kv_layout,
                             kv_block=self.kv_block,
                             num_blocks=self.kv_blocks)
        if self.mesh is None:
            return cache
        shardings = {}
        for name, leaf in cache.items():
            if name in _KV_HEAD_LEAVES:
                dims = [None] * leaf.ndim
                dims[-2] = "model"
                spec = meshlib.spec_if(self.mesh, leaf.shape, *dims)
            else:
                spec = P()
            shardings[name] = NamedSharding(self.mesh, spec)
        return jax.device_put(cache, shardings)

    def place_table(self, table: np.ndarray) -> jax.Array:
        """Upload a host block table; replicated across the mesh so
        every shard of the pool gathers through identical indices."""
        if self.mesh is None:
            return jnp.asarray(table)
        return jax.device_put(jnp.asarray(table),
                              NamedSharding(self.mesh, P()))

    def put_replicated(self, x) -> jax.Array:
        """Replicate a small carry array (tokens / active mask / flag
        counters) across the mesh; identity off-mesh."""
        if self.mesh is None:
            return x
        return jax.device_put(x, NamedSharding(self.mesh, P()))


# ---------------------------------------------------------------------------
# per-token reference loop (parity oracle + benchmark baseline)
# ---------------------------------------------------------------------------

def decode_loop_reference(params, cfg, tokens, gen_len: int, *,
                          entropy: Optional[KernelEntropy] = None,
                          max_len: Optional[int] = None,
                          modality=None, decode_fn=None) -> dict:
    """The pre-engine decode driver: one jitted step + one host sync per
    token over a statically batched prompt matrix.  Scan decode must
    reproduce this loop's token stream exactly in operand-entropy mode
    (same fold_in(base, global_step) noise; tested in test_serve.py).

    ``decode_fn`` lets benchmarks pass a pre-compiled step so the timed
    loop measures steady-state dispatch, not compilation.
    """
    tokens = jnp.asarray(tokens)
    B, P_ = tokens.shape
    max_len = max_len or P_ + gen_len
    _, cache = M.prefill(params, cfg, tokens, max_len, modality)
    decode = decode_fn or jax.jit(S.build_decode_step(cfg, entropy=entropy),
                                  donate_argnums=(2,))
    tok = tokens[:, -1]
    rows = {"token": [], "H": [], "SE": [], "MI": [], "p_max": []}
    t0 = time.perf_counter()
    for i in range(gen_len):
        out, cache = decode(params, tok, cache, jnp.asarray(i, jnp.int32))
        tok = out["next_token"]
        rows["token"].append(np.asarray(tok))        # per-token sync
        for k in ("H", "SE", "MI", "p_max"):
            rows[k].append(np.asarray(out[k]))
    decode_s = time.perf_counter() - t0
    return {name: np.stack(vals) for name, vals in rows.items()} | {
        "decode_s": decode_s,
        "decode_tok_per_s": gen_len * B / max(decode_s, 1e-9),
    }
