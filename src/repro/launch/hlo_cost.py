"""Trip-count-aware cost accounting over post-SPMD optimized HLO.

XLA's ``compiled.cost_analysis()`` counts the body of a ``while`` loop
ONCE, so any scanned program (scan-over-layers, flash-attention chunk
scans, SSD chunk scans, microbatch accumulation) under-reports FLOPs,
bytes and collective traffic by the trip count.  The optimized HLO,
however, annotates every counted loop with
``backend_config={"known_trip_count":{"n":"64"}}``.

This module re-derives the three roofline inputs by walking the HLO call
graph with multipliers:

  * FLOPs       -- ``dot`` ops: 2 * prod(result) * prod(contracted dims)
                   (+ convolution approx); dots inside fusions are
                   counted too (output fusions can wrap dots).
  * HBM bytes   -- per *materialization point*: operand + result bytes of
                   fusions and of non-fusable data-movement ops (dot,
                   copy, gather, dynamic-slice, ...).  Fusion-internal
                   traffic is excluded -- a fusion is one kernel pass,
                   which is exactly the roofline notion of HBM traffic.
  * collectives -- link-byte model per op kind (ring algorithms):
                   all-reduce 2x, all-gather/reduce-scatter the
                   shard-delta, all-to-all / permute 1x.

The HLO here is the per-device program (post-SPMD partitioning), so all
numbers are per-chip.  Validated against XLA cost_analysis on unrolled
(trip-count-free) configs in tests/test_hlo_cost.py.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8,
                "f64": 8, "s16": 2, "u16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter",
                "all-to-all", "collective-permute")

# ops that materialize operands/results through HBM even when not fused
_MOVER_OPS = {
    "dot", "convolution", "copy", "gather", "scatter", "dynamic-slice",
    "dynamic-update-slice", "slice", "concatenate", "pad", "reduce",
    "reduce-window", "sort", "transpose", "convert", "select-and-scatter",
    "rng", "rng-bit-generator", "cholesky", "triangular-solve",
} | set(_COLLECTIVES)


def _dims(dim_str: str) -> list[int]:
    return [int(d) for d in dim_str.split(",") if d]


def _type_info(type_str: str) -> tuple[int, list[list[int]]]:
    """(total bytes, list of array shapes) of an HLO type string."""
    total = 0
    shapes = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        ds = _dims(dims)
        n = 1
        for d in ds:
            n *= d
        total += n * _DTYPE_BYTES[dt]
        shapes.append(ds)
    return total, shapes


@dataclasses.dataclass
class Instr:
    name: str
    result_type: str
    op: str
    operands: list[str]
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list[Instr]


_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\(.*\)\s*->\s*.*\{")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
    r"((?:\([^)]*\)|[\w\[\],]+(?:\{[\d,]*\})?))\s+"
    r"([\w\-]+)\((.*?)\)(.*)$")
_OPERAND = re.compile(r"%([\w.\-]+)")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS = re.compile(r"calls=%([\w.\-]+)")
_BODY = re.compile(r"body=%([\w.\-]+)")
_COND = re.compile(r"condition=%([\w.\-]+)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_GROUPS = re.compile(r"feature_group_count=(\d+)")


def parse_module(hlo_text: str) -> tuple[dict[str, Computation], str,
                                         dict[str, str]]:
    """-> (computations by name, entry name, instr name -> result type)."""
    comps: dict[str, Computation] = {}
    types: dict[str, str] = {}
    entry = None
    cur: Optional[Computation] = None
    for line in hlo_text.splitlines():
        h = _COMP_HDR.match(line)
        if h:
            cur = Computation(h.group(1), [])
            comps[cur.name] = cur
            if line.startswith("ENTRY"):
                entry = cur.name
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, rtype, op, args, rest = m.groups()
        operands = _OPERAND.findall(args)
        ins = Instr(name, rtype, op, operands, line)
        cur.instrs.append(ins)
        types[name] = rtype
    if entry is None:
        # fall back: the last computation is usually the entry
        entry = list(comps)[-1] if comps else ""
    return comps, entry, types


def _dot_flops(ins: Instr, types: dict[str, str]) -> float:
    _, rshapes = _type_info(ins.result_type)
    rsize = 1
    for d in (rshapes[0] if rshapes else []):
        rsize *= d
    cm = _CONTRACT.search(ins.line)
    contract = 1
    if cm and ins.operands:
        lhs_type = types.get(ins.operands[0], "")
        _, lshapes = _type_info(lhs_type)
        if lshapes:
            lshape = lshapes[0]
            for ci in _dims(cm.group(1)):
                if ci < len(lshape):
                    contract *= lshape[ci]
    return 2.0 * rsize * contract


def _conv_flops(ins: Instr, types: dict[str, str]) -> float:
    _, rshapes = _type_info(ins.result_type)
    rsize = 1
    for d in (rshapes[0] if rshapes else []):
        rsize *= d
    if len(ins.operands) < 2:
        return 0.0
    _, kshapes = _type_info(types.get(ins.operands[1], ""))
    ksize = 1
    for d in (kshapes[0] if kshapes else []):
        ksize *= d
    g = _GROUPS.search(ins.line)
    groups = int(g.group(1)) if g else 1
    # kernel total / output-features ~ per-output MACs (grouped aware)
    _, rsh = _type_info(ins.result_type)
    out_feat = rsh[0][-1] if rsh and rsh[0] else 1
    per_out = ksize / max(out_feat, 1)
    return 2.0 * rsize * per_out * (1.0 / max(groups, 1)) * groups


_META_NAME = re.compile(r'op_name="([^"]*)"')


class HloCost:
    """detail=True records per-instruction contributions for profiling
    (the §Perf loop's 'profile': top collectives / byte movers with their
    jaxpr op_name provenance).  skip_byte_scopes: op_name substrings whose
    instructions contribute NO HBM bytes — used to model Pallas-fused
    regions (e.g. 'fused_attention': the flash kernel keeps score tiles
    in VMEM; kernels/flash_attention.py is the backing implementation)."""

    def __init__(self, hlo_text: str, detail: bool = False,
                 skip_byte_scopes: tuple[str, ...] = ()):
        self.comps, self.entry, self.types = parse_module(hlo_text)
        self.flops = 0.0
        self.bytes = 0.0
        self.coll = {c: {"count": 0.0, "bytes": 0.0}
                     for c in _COLLECTIVES}
        self.detail = detail
        self.skip_byte_scopes = skip_byte_scopes
        self.records: list[tuple[float, str, str, str]] = []
        self._walk(self.entry, 1.0, count_bytes=True)

    def _scoped_out(self, ins: Instr) -> bool:
        if not self.skip_byte_scopes:
            return False
        m = _META_NAME.search(ins.line)
        name = m.group(1) if m else ""
        return any(s in name for s in self.skip_byte_scopes)

    def _record(self, kind: str, amount: float, ins: Instr):
        if self.detail and amount > 0:
            m = _META_NAME.search(ins.line)
            name = (m.group(1) if m else ins.name)
            self.records.append(
                (amount, kind, ins.op,
                 f"{ins.result_type.split('{')[0]} {name}"))

    def top(self, kind: str, n: int = 15) -> list[tuple[float, str, str]]:
        import collections
        agg: dict = collections.Counter()
        for amount, k, op, name in self.records:
            if k == kind:
                agg[(op, name)] += amount
        return [(v, op, name)
                for (op, name), v in agg.most_common(n)]

    # -- traversal ----------------------------------------------------------

    def _operand_bytes(self, ins: Instr) -> float:
        total = 0.0
        for o in ins.operands:
            t = self.types.get(o)
            if t:
                total += _type_info(t)[0]
        return total

    _PARAM_IDX = re.compile(r"parameter\((\d+)\)")

    def _fusion_operand_bytes(self, ins: Instr) -> float:
        """Operand bytes of a fusion, slice-aware.

        A scan body's fusions take the whole stacked (L, ...) carry as an
        operand but only dynamic-slice one layer's slab out of it; HBM
        traffic is the slice, not the stack.  For each fusion parameter
        consumed ONLY by dynamic-slice ops inside the fused computation,
        count the slice results instead of the full operand.
        """
        cm = _CALLS.search(ins.line)
        comp = self.comps.get(cm.group(1)) if cm else None
        if comp is None:
            return self._operand_bytes(ins)
        params: dict[int, str] = {}
        for i2 in comp.instrs:
            if i2.op == "parameter":
                m = self._PARAM_IDX.search(i2.line)
                if m:
                    params[int(m.group(1))] = i2.name
        total = 0.0
        for idx, o in enumerate(ins.operands):
            ob = _type_info(self.types.get(o, ""))[0]
            pname = params.get(idx)
            if pname is not None and ob > 0:
                consumers = [i2 for i2 in comp.instrs
                             if pname in i2.operands]
                if consumers and all(c.op == "dynamic-slice"
                                     for c in consumers):
                    ob = sum(_type_info(c.result_type)[0]
                             for c in consumers)
            total += ob
        return total

    def _walk(self, comp_name: str, mult: float, count_bytes: bool):
        comp = self.comps.get(comp_name)
        if comp is None:
            return
        for ins in comp.instrs:
            op = ins.op
            if op == "while":
                t = _TRIP.search(ins.line)
                trips = float(t.group(1)) if t else 1.0
                b = _BODY.search(ins.line)
                c = _COND.search(ins.line)
                if b:
                    self._walk(b.group(1), mult * trips, count_bytes)
                if c:
                    self._walk(c.group(1), mult * trips, count_bytes)
                continue
            if op == "fusion":
                if count_bytes and not self._scoped_out(ins):
                    rb = _type_info(ins.result_type)[0]
                    ob = self._fusion_operand_bytes(ins)
                    # in-place update fusions (scan writing one layer slice
                    # into the stacked (L, ...) carry) alias an operand with
                    # the result buffer: traffic is the update region, not
                    # the whole carry.  Detect via a same-typed operand.
                    aliased = 0.0
                    for o in ins.operands:
                        t = self.types.get(o, "")
                        if t and t.split("{")[0] == \
                                ins.result_type.split("{")[0]:
                            aliased = _type_info(t)[0]
                            break
                    if aliased and "dynamic-update-slice" in ins.name:
                        self.bytes += mult * 2.0 * (ob - aliased)
                        self._record("bytes", mult * 2.0 * (ob - aliased),
                                     ins)
                    else:
                        self.bytes += mult * (ob + rb)
                        self._record("bytes", mult * (ob + rb), ins)
                cm = _CALLS.search(ins.line)
                if cm:
                    # count dots inside the fusion; bytes stay at the call
                    self._walk(cm.group(1), mult, count_bytes=False)
                continue
            if op in ("call", "conditional", "async-start"):
                for sub in re.findall(
                        r"(?:to_apply|calls|branch_computations=\{)"
                        r"=?%?([\w.\-]+)", ins.line):
                    self._walk(sub, mult, count_bytes)
                continue
            if op == "dot":
                self.flops += mult * _dot_flops(ins, self.types)
            elif op == "convolution":
                self.flops += mult * _conv_flops(ins, self.types)
            if op in _COLLECTIVES or (op.endswith("-start")
                                      and op[:-6] in _COLLECTIVES):
                kind = op[:-6] if op.endswith("-start") else op
                ob = self._operand_bytes(ins)
                rb = _type_info(ins.result_type)[0]
                if kind == "all-reduce":
                    link = 2.0 * ob
                elif kind == "all-gather":
                    link = max(rb - ob, 0.0)
                elif kind == "reduce-scatter":
                    link = max(ob - rb, 0.0)
                else:
                    link = ob
                self.coll[kind]["count"] += mult
                self.coll[kind]["bytes"] += mult * link
                self._record(kind, mult * link, ins)
                if count_bytes:
                    self.bytes += mult * (ob + rb)
                continue
            if count_bytes and op in _MOVER_OPS \
                    and not self._scoped_out(ins):
                rb = _type_info(ins.result_type)[0]
                if op in ("slice", "dynamic-slice", "gather"):
                    # reads only the sliced region, not the full operand
                    b = mult * 2.0 * rb
                elif op == "dynamic-update-slice":
                    # in-place: read + write of the update region only
                    ub = (_type_info(self.types.get(ins.operands[1], ""))[0]
                          if len(ins.operands) > 1 else rb)
                    b = mult * 2.0 * ub
                elif op == "scatter":
                    ub = (_type_info(self.types.get(ins.operands[2], ""))[0]
                          if len(ins.operands) > 2 else rb)
                    b = mult * 3.0 * ub
                else:
                    b = mult * (self._operand_bytes(ins) + rb)
                self.bytes += b
                self._record("bytes", b, ins)

    # -- results ------------------------------------------------------------

    def summary(self) -> dict:
        total_link = sum(v["bytes"] for v in self.coll.values())
        return {
            "flops": self.flops,
            "bytes": self.bytes,
            "collectives": {**{k: dict(v) for k, v in self.coll.items()},
                            "total_link_bytes": total_link},
        }


def analyze(hlo_text: str,
            skip_byte_scopes: tuple[str, ...] = ()) -> dict:
    return HloCost(hlo_text, skip_byte_scopes=skip_byte_scopes).summary()
