"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be imported/run before any other jax usage: the first two lines pin
512 placeholder host devices so ``jax.make_mesh`` can build the production
meshes (single pod 16x16 = 256 chips, multi-pod 2x16x16 = 512).

For every runnable cell this script:
  1. builds ShapeDtypeStruct inputs (``steps.input_specs``) -- no allocation,
  2. jits the train/prefill/decode step with the arch's in/out shardings,
  3. ``.lower().compile()`` -- any sharding mismatch / unsupported
     collective / compile-OOM is a hard failure,
  4. records memory_analysis + cost_analysis + the collective schedule
     parsed from the post-SPMD HLO into ``artifacts/dryrun/<cell>.json``
     (the roofline analysis in benchmarks/roofline.py reads these).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2_1_5b \
      --shape train_4k --mesh both
  PYTHONPATH=src python -m repro.launch.dryrun --all
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse     # noqa: E402
import json         # noqa: E402
import re           # noqa: E402
import time         # noqa: E402
import traceback    # noqa: E402

import jax          # noqa: E402
import jax.numpy as jnp                    # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs.base import SHAPE_CELLS, cell_applicable   # noqa: E402
from repro.launch import hlo_cost                              # noqa: E402
from repro.configs.registry import ARCH_IDS, get_config       # noqa: E402
from repro.core.svi import SVIConfig       # noqa: E402
from repro.launch import mesh as meshlib   # noqa: E402
from repro.launch import steps as S        # noqa: E402
from repro.optim import adamw              # noqa: E402
from repro.sharding.partition import set_mesh_context  # noqa: E402

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__),
                            "..", "..", "..", "artifacts", "dryrun")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "s64": 8, "u64": 8, "f64": 8,
                "s16": 2, "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8}


def _type_bytes(type_str: str) -> int:
    """bytes of an HLO result type like 'bf16[8,128,6144]' (tuples summed)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Per-op collective operand bytes from post-SPMD (per-device) HLO.

    Link-traffic model per chip (ring algorithms, (n-1)/n ~= 1):
      all-reduce       2 x operand     (reduce-scatter + all-gather phases)
      all-gather       result - operand  (received shards)
      reduce-scatter   operand - result  (sent shards)
      all-to-all       operand
      collective-permute operand
    """
    # name -> result bytes for operand lookup
    sizes: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = re.match(r"\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|\S+)\s",
                     line)
        if m:
            sizes[m.group(1)] = _type_bytes(m.group(2))

    out = {c: {"count": 0, "bytes": 0} for c in _COLLECTIVES}
    ops = []
    for line in hlo_text.splitlines():
        m = re.match(
            r"\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+"
            r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
            r"collective-permute)(?:-start)?\(([^)]*)\)", line)
        if not m:
            continue
        name, rtype, kind, args = m.groups()
        rbytes = _type_bytes(rtype)
        obytes = 0
        for a in args.split(","):
            a = a.strip().lstrip("%")
            obytes += sizes.get(a, 0)
        if kind == "all-reduce":
            link = 2 * obytes
        elif kind == "all-gather":
            link = max(rbytes - obytes, 0)
        elif kind == "reduce-scatter":
            link = max(obytes - rbytes, 0)
        else:
            link = obytes
        out[kind]["count"] += 1
        out[kind]["bytes"] += link
        ops.append({"kind": kind, "operand_bytes": obytes,
                    "result_bytes": rbytes, "link_bytes": link})
    out["total_link_bytes"] = sum(v["bytes"] for k, v in out.items()
                                  if isinstance(v, dict))
    out["ops"] = ops[:200]
    return out


def _mem_analysis(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
        return {
            "argument_size_in_bytes": ma.argument_size_in_bytes,
            "output_size_in_bytes": ma.output_size_in_bytes,
            "temp_size_in_bytes": ma.temp_size_in_bytes,
            "generated_code_size_in_bytes": ma.generated_code_size_in_bytes,
            "peak_bytes": (ma.argument_size_in_bytes
                           + ma.temp_size_in_bytes),
        }
    except Exception as e:  # CPU backend may not implement it
        return {"error": str(e)[:200]}


def _cost_analysis(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        return {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float))}
    except Exception as e:
        return {"error": str(e)[:200]}


def pick_micro_batches(cfg, cell, dp: int) -> int:
    """Bound the per-replica microbatch to ~4 sequences (activation +
    MoE dispatch buffer control; DESIGN.md §5)."""
    per_replica = max(cell.global_batch // dp, 1)
    micro = max(per_replica // 4, 1)
    while cell.global_batch % (micro * dp) and micro > 1:
        micro -= 1
    return micro


def lower_cell(arch: str, shape: str, multi_pod: bool,
               micro_batches: int | None = None,
               extra_tags: dict | None = None):
    """Lower+compile one cell; returns (record, lowered, compiled)."""
    cfg = get_config(arch)
    cell = SHAPE_CELLS[shape]
    ok, why = cell_applicable(cfg, cell)
    if not ok:
        return {"arch": arch, "shape": shape, "skipped": why}, None, None

    mesh = meshlib.make_production_mesh(multi_pod=multi_pod)
    set_mesh_context(mesh)
    dp = meshlib.dp_size(mesh)
    specs = S.input_specs(cfg, cell)

    t0 = time.time()
    try:
        with mesh:
            if cell.kind == "train":
                opt_cfg = adamw.AdamWConfig(moment_dtype=cfg.moment_dtype)
                micro = micro_batches or pick_micro_batches(cfg, cell, dp)
                svi = SVIConfig(num_train_examples=cell.global_batch * 1000)
                step_fn = S.build_train_step(cfg, opt_cfg, svi,
                                             micro_batches=micro)
                state_specs = S.train_state_specs(cfg, opt_cfg)
                st_pspec = S.state_pspecs(cfg, mesh, state_specs)
                b_pspec = S.batch_pspecs(mesh, specs["batch"])
                in_sh = (jax.tree.map(lambda s: NamedSharding(mesh, s),
                                      st_pspec,
                                      is_leaf=lambda x: isinstance(x, P)),
                         jax.tree.map(lambda s: NamedSharding(mesh, s),
                                      b_pspec,
                                      is_leaf=lambda x: isinstance(x, P)))
                lowered = jax.jit(step_fn, in_shardings=in_sh).lower(
                    state_specs, specs["batch"])
                meta = {"kind": "train", "micro_batches": micro}
            elif cell.kind == "prefill":
                step_fn = S.build_prefill_step(cfg, cell.seq_len)
                params_specs = S.train_state_specs(
                    cfg, adamw.AdamWConfig())["params"]
                p_pspec = S.state_pspecs(
                    cfg, mesh, {"params": params_specs,
                                "opt": {}})["params"]
                b_pspec = S.batch_pspecs(mesh, specs["batch"])
                in_sh = (jax.tree.map(lambda s: NamedSharding(mesh, s),
                                      p_pspec,
                                      is_leaf=lambda x: isinstance(x, P)),
                         jax.tree.map(lambda s: NamedSharding(mesh, s),
                                      b_pspec,
                                      is_leaf=lambda x: isinstance(x, P)))
                lowered = jax.jit(step_fn, in_shardings=in_sh).lower(
                    params_specs, specs["batch"])
                meta = {"kind": "prefill"}
            else:  # decode
                step_fn = S.build_decode_step(cfg)
                params_specs = S.train_state_specs(
                    cfg, adamw.AdamWConfig())["params"]
                p_pspec = S.state_pspecs(
                    cfg, mesh, {"params": params_specs,
                                "opt": {}})["params"]
                c_pspec = S.cache_pspecs(mesh, specs["cache"])
                tok_sh = NamedSharding(mesh, meshlib.spec_if(
                    mesh, specs["token"].shape, "batch"))
                in_sh = (jax.tree.map(lambda s: NamedSharding(mesh, s),
                                      p_pspec,
                                      is_leaf=lambda x: isinstance(x, P)),
                         tok_sh,
                         jax.tree.map(lambda s: NamedSharding(mesh, s),
                                      c_pspec,
                                      is_leaf=lambda x: isinstance(x, P)),
                         NamedSharding(mesh, P()))
                lowered = jax.jit(step_fn, in_shardings=in_sh).lower(
                    params_specs, specs["token"], specs["cache"],
                    specs["step"])
                meta = {"kind": "decode"}

            compiled = lowered.compile()
    finally:
        set_mesh_context(None)

    hlo = compiled.as_text()
    rec = {
        "arch": arch, "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "num_devices": mesh.devices.size,
        "compile_s": round(time.time() - t0, 1),
        "memory_analysis": _mem_analysis(compiled),
        "cost_analysis": _cost_analysis(compiled),
        # trip-count-aware accounting (launch.hlo_cost): XLA cost_analysis
        # counts while bodies once, so scanned programs under-report.
        "hlo_cost": hlo_cost.analyze(hlo),
        # same HLO with the 'fused_attention' scope's HBM bytes excluded:
        # models the Pallas kernel (kernels/flash_attention.py) keeping
        # score tiles in VMEM -- the TPU production path.
        "hlo_cost_fused_attn": hlo_cost.analyze(
            hlo, skip_byte_scopes=("fused_attention",)),
        "collectives": parse_collectives(hlo),
        "param_count": cfg.param_count,
        "active_param_count": cfg.active_param_count,
        "tokens": cell.global_batch * (cell.seq_len
                                       if cell.kind != "decode" else 1),
        **meta,
    }
    if extra_tags:
        rec.update(extra_tags)
    return rec, lowered, compiled


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: str,
             tag: str = "") -> dict:
    rec, _, compiled = lower_cell(arch, shape, multi_pod)
    mesh_tag = "multi" if multi_pod else "single"
    name = f"{arch}__{shape}__{mesh_tag}{('__' + tag) if tag else ''}.json"
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(rec, f, indent=1)
    if "skipped" in rec:
        print(f"SKIP  {arch:22s} {shape:12s} {mesh_tag:6s} {rec['skipped']}")
    else:
        ca = rec["cost_analysis"]
        print(f"OK    {arch:22s} {shape:12s} {mesh_tag:6s} "
              f"compile {rec['compile_s']:6.1f}s  "
              f"flops/dev {ca.get('flops', 0):.3e}  "
              f"coll {rec['collectives']['total_link_bytes']:.3e}B")
    del compiled
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=os.path.abspath(ARTIFACT_DIR))
    args = ap.parse_args()

    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPE_CELLS) if (args.all or not args.shape) \
        else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    run_cell(arch, shape, mp, args.out)
                except Exception as e:
                    traceback.print_exc()
                    failures.append((arch, shape, mp, str(e)[:200]))
                    print(f"FAIL  {arch:22s} {shape:12s} "
                          f"{'multi' if mp else 'single'}")
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print("\nall dry-run cells green")


if __name__ == "__main__":
    main()
