"""Fault-tolerant training driver.

Production posture (DESIGN.md §5) at container scale:

  * SVI ELBO train step (Bayesian head KL + NLL) built by launch.steps,
    jit-ted with the arch's partition specs over an explicit device mesh,
  * atomic async checkpoints every ``--ckpt-every`` steps including the
    optimizer state AND the data-iterator cursor; ``--resume`` discovers
    the latest valid step and continues bit-exactly,
  * elastic restart: the checkpoint stores full (gathered) arrays, so a
    restart may use a different mesh shape (degraded pod) -- restore
    re-places under the new sharding,
  * straggler/hang mitigation: a step-deadline monitor flags steps whose
    wall time exceeds ``deadline_factor`` x the trailing median (on real
    multi-host deployments this triggers requeue of the slow host; here
    it logs and counts, and the test suite asserts the detector fires),
  * simulated failure injection (``--fail-at-step``) used by tests to
    prove a mid-run crash resumes losslessly.

Container-scale by default: a reduced config on a (1,1) or (2,2) debug
mesh.  The full-size path is exercised by launch.dryrun (compile-only).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen2_1_5b \
      --steps 50 --batch 8 --seq 64 --reduced --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import os
import statistics
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpoint import CheckpointManager
from repro.configs.registry import get_config, reduced
from repro.core.svi import SVIConfig
from repro.data.synthetic import TokenStreamState, token_batch
from repro.launch import mesh as meshlib
from repro.launch import steps as S
from repro.models import registry as M
from repro.optim import adamw
from repro.sharding.partition import (set_mesh_context, shardings_for,
                                      sanitize_pspecs, param_pspecs)
from jax.sharding import NamedSharding, PartitionSpec as P


class StragglerMonitor:
    """Flags steps slower than ``factor`` x trailing-median step time."""

    def __init__(self, factor: float = 3.0, window: int = 16):
        self.factor = factor
        self.window = window
        self.times: list[float] = []
        self.flagged = 0

    def observe(self, dt: float) -> bool:
        slow = False
        if len(self.times) >= 4:
            med = statistics.median(self.times[-self.window:])
            slow = dt > self.factor * med
        self.times.append(dt)
        if slow:
            self.flagged += 1
        return slow


def make_mesh_for_args(args):
    n = jax.device_count()
    if n >= 4:
        return meshlib.make_debug_mesh((2, 2), ("data", "model"))
    return meshlib.make_debug_mesh((1, 1), ("data", "model"))


def train(args) -> dict:
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    mesh = make_mesh_for_args(args)
    set_mesh_context(mesh)

    opt_cfg = adamw.AdamWConfig(
        lr=args.lr, total_steps=args.steps, warmup_steps=args.steps // 10,
        moment_dtype=cfg.moment_dtype, compress_topk=args.compress_topk)
    svi = SVIConfig(num_train_examples=max(60_000,
                                           args.batch * args.steps),
                    kl_warmup_steps=max(args.steps // 4, 1))
    step_fn = S.build_train_step(cfg, opt_cfg, svi,
                                 micro_batches=args.micro_batches,
                                 seed=args.seed)

    mgr = CheckpointManager(args.ckpt_dir, keep=3) if args.ckpt_dir else None
    stream = TokenStreamState(seed=args.seed, host=jax.process_index(),
                              num_hosts=jax.process_count())

    start_step = 0
    key = jax.random.key(args.seed)
    params = M.init_params(key, cfg)
    state = {"params": params,
             "opt": adamw.init_state(params, opt_cfg)}

    if mgr is not None and args.resume:
        step, tree, extra = mgr.restore_latest(state)
        if step is not None:
            state = tree
            start_step = int(extra["step"])
            stream = TokenStreamState(**extra["stream"])
            print(f"resumed from step {start_step}")

    with mesh:
        # place the state under its target shardings
        sh = jax.tree.map(
            lambda s: NamedSharding(mesh, s),
            S.state_pspecs(cfg, mesh, jax.eval_shape(lambda: state)),
            is_leaf=lambda x: isinstance(x, P))
        state = jax.tree.map(jax.device_put, state, sh)
        jstep = jax.jit(step_fn, donate_argnums=(0,))

        monitor = StragglerMonitor()
        history = []
        # join any in-flight async save on EVERY exit from the step loop
        # (including exceptions): a completed-in-memory snapshot must
        # reach its atomic rename before the process can act on the
        # failure, or an immediate in-process resume races the writer
        # thread and silently restarts from an older (or no) step.
        try:
            for i in range(start_step, args.steps):
                toks, stream = token_batch(stream, args.batch, args.seq + 1,
                                           cfg.vocab_size)
                batch = {"tokens": jnp.asarray(toks[:, :-1]),
                         "labels": jnp.asarray(toks[:, 1:])}
                if cfg.family == "encdec":
                    from repro.models.encdec import ENC_LEN
                    batch["frames"] = jnp.zeros(
                        (args.batch, ENC_LEN, cfg.d_model), jnp.float32)
                if cfg.family == "vlm":
                    batch["prefix_embeds"] = jnp.zeros(
                        (args.batch, cfg.num_prefix_embeds, cfg.d_model),
                        jnp.float32)

                t0 = time.time()
                state, metrics = jstep(state, batch)
                loss = float(metrics["loss"])
                dt = time.time() - t0
                slow = monitor.observe(dt)
                history.append(loss)
                if args.fail_at_step is not None and i == args.fail_at_step:
                    raise RuntimeError(f"injected failure at step {i}")
                if mgr is not None and (i + 1) % args.ckpt_every == 0:
                    mgr.save_async(i + 1, state,
                                   extra={"step": i + 1,
                                          "stream": vars(stream)})
                if i % max(args.steps // 10, 1) == 0 or i == args.steps - 1:
                    print(f"step {i:5d} loss {loss:8.4f} "
                          f"nll {float(metrics['nll']):8.4f} "
                          f"kl {float(metrics['kl']):10.1f} "
                          f"gnorm {float(metrics['grad_norm']):7.3f} "
                          f"{'STRAGGLER' if slow else ''}")
        finally:
            if mgr is not None:
                mgr.wait()
        if mgr is not None:
            mgr.save_async(args.steps, state,
                           extra={"step": args.steps,
                                  "stream": vars(stream)})
            mgr.wait()
    set_mesh_context(None)
    return {"final_loss": history[-1] if history else float("nan"),
            "history": history, "straggler_flags": monitor.flagged,
            "state": state}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_1_5b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--micro-batches", type=int, default=1)
    ap.add_argument("--compress-topk", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--fail-at-step", type=int, default=None)
    args = ap.parse_args()
    out = train(args)
    print(f"final loss {out['final_loss']:.4f} "
          f"(stragglers flagged: {out['straggler_flags']})")


if __name__ == "__main__":
    main()
