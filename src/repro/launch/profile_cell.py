"""Profile one dry-run cell: top collective / HBM-byte contributors.

The §Perf loop's 'profiler': recompiles a cell and attributes the
trip-count-aware cost to jaxpr op_names, so a hypothesis like 'the head
FSDP contraction ARs the logits' is checkable directly.

  PYTHONPATH=src python -m repro.launch.profile_cell \
      --arch grok_1_314b --shape train_4k [--multi-pod] [--fused-attn]
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse  # noqa: E402

from repro.launch import dryrun  # noqa: E402
from repro.launch.hlo_cost import HloCost  # noqa: E402


def profile(arch: str, shape: str, multi_pod: bool = False,
            skip_byte_scopes: tuple[str, ...] = (), top: int = 14) -> dict:
    rec, lowered, compiled = dryrun.lower_cell(arch, shape, multi_pod)
    if compiled is None:
        print("cell skipped:", rec.get("skipped"))
        return rec
    cost = HloCost(compiled.as_text(), detail=True,
                   skip_byte_scopes=skip_byte_scopes)
    s = cost.summary()
    print(f"\n{arch} x {shape} x "
          f"{'2x16x16' if multi_pod else '16x16'}   "
          f"flops/dev {s['flops']:.3e}  bytes/dev {s['bytes']:.3e}  "
          f"coll/dev {s['collectives']['total_link_bytes']:.3e}")
    for kind in ("all-reduce", "all-gather", "reduce-scatter",
                 "all-to-all", "bytes"):
        rows = cost.top(kind, top)
        if not rows:
            continue
        print(f"\n top {kind}:")
        for amount, op, name in rows:
            print(f"  {amount:11.3e}  {op:10s} {name[:110]}")
    return {"record": rec, "summary": s}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--fused-attn", action="store_true",
                    help="model Pallas-fused attention (skip its bytes)")
    ap.add_argument("--top", type=int, default=14)
    args = ap.parse_args()
    scopes = ("fused_attention",) if args.fused_attn else ()
    profile(args.arch, args.shape, args.multi_pod, scopes, args.top)


if __name__ == "__main__":
    main()
