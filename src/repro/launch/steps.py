"""Arch-agnostic step builders shared by dryrun / train / serve.

``build_train_step`` returns a pure ``(state, batch) -> (state, metrics)``
function implementing the paper's SVI objective (ELBO = NLL + beta*KL of
the Bayesian head) with gradient accumulation, global-norm clipping and
AdamW.  ``build_prefill_step`` / ``build_decode_step`` wrap the model
zoo's serving API; the decode step emits the paper's uncertainty triplet
(H, SE, MI) per generated token from ``cfg.mc_samples`` MC head draws.

``input_specs`` produces ShapeDtypeStruct stand-ins for every input of a
given (arch x shape-cell), and ``*_pspecs`` the matching PartitionSpecs --
this is everything the multi-pod dry-run lowers against (no allocation).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeCell
from repro.core.svi import SVIConfig, elbo_loss
from repro.models import registry as M
from repro.optim import adamw
from repro.launch import mesh as meshlib
from repro.sharding.partition import param_pspecs, sanitize_pspecs


# ---------------------------------------------------------------------------
# train step
# ---------------------------------------------------------------------------

def build_train_step(cfg: ArchConfig, opt_cfg: adamw.AdamWConfig,
                     svi_cfg: Optional[SVIConfig] = None,
                     micro_batches: int = 1, seed: int = 0):
    """(state, batch) -> (state, metrics); state = {params, opt}.

    micro_batches > 1 scans over leading-dim splits of the batch,
    accumulating grads in f32 (bounds activation memory; the MoE dispatch
    buffer scales with the microbatch, DESIGN.md §5).

    ``seed`` roots the SVI noise stream: the per-step key is
    fold_in(PRNGKey(seed), step), so two runs with different seeds draw
    different head samples (and two runs with the same seed replay the
    same stream -- crash/resume stays bit-exact).
    """
    svi = svi_cfg or SVIConfig()

    def loss_fn(params, batch, key, step):
        return elbo_loss(lambda p, b, k: M.nll_loss(p, cfg, b, k),
                         params, batch, key, step, svi)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state, batch):
        params, opt = state["params"], state["opt"]
        step = opt["step"]
        key = jax.random.fold_in(jax.random.PRNGKey(seed), step)

        if micro_batches == 1:
            (loss, aux), grads = grad_fn(params, batch, key, step)
        else:
            def split(x):
                b = x.shape[0]
                return x.reshape(micro_batches, b // micro_batches,
                                 *x.shape[1:])

            mbatches = jax.tree.map(split, batch)
            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def mb_step(carry, mb):
                g_acc, l_acc, i = carry
                (l, aux), g = grad_fn(params, mb,
                                      jax.random.fold_in(key, i), step)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + l, i + 1), aux

            (grads, loss, _), auxs = jax.lax.scan(
                mb_step, (g0, jnp.zeros(()), jnp.zeros((), jnp.int32)),
                mbatches)
            inv = 1.0 / micro_batches
            grads = jax.tree.map(lambda g: g * inv, grads)
            loss = loss * inv
            aux = jax.tree.map(lambda a: a.mean(0), auxs)

        new_params, new_opt, om = adamw.apply_updates(
            params, grads, opt, opt_cfg)
        metrics = {"loss": loss, **aux, **om}
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


# ---------------------------------------------------------------------------
# serving steps
# ---------------------------------------------------------------------------

def build_prefill_step(cfg: ArchConfig, max_len: int):
    def prefill_step(params, batch):
        modality = batch.get("frames", batch.get("prefix_embeds"))
        hidden, cache = M.prefill(params, cfg, batch["tokens"], max_len,
                                  modality)
        return hidden, cache

    return prefill_step


def _decode_base_key(entropy):
    """Base PRNG key of the decode noise stream.

    ``entropy`` (a ``core.entropy.KernelEntropy``) selects the seed-driven
    path: the Bayesian head's MC draws are generated in-kernel on TPU
    (zero HBM entropy operand).  ``None`` keeps the legacy fixed-key
    stream.
    """
    return entropy.key() if entropy is not None else jax.random.PRNGKey(17)


def _folds_step_key(cfg: ArchConfig, entropy) -> bool:
    """Whether the per-step key folds in the global step index.

    The seeded kernel path derives its in-kernel stream from the folded
    key, so it keeps the global-step convention.  Operand mode instead
    passes the UNFOLDED base key down to the models, whose
    ``layers.decode_head_noise`` folds (slot, depth) — making each
    slot's noise a function of its own token position, independent of
    the engine's scheduling (chunked prefill interleavings, pauses,
    chunk sizes).
    """
    return entropy is not None or cfg.head_entropy == "kernel"


def build_decode_step(cfg: ArchConfig, entropy=None):
    """Single uncertain decode step: (params, token, cache, step) ->
    (outputs, cache).  Keys follow the same convention
    ``build_scan_decode`` uses — fold_in(base, step) on the seeded
    kernel path, the raw base key in operand mode (the models fold
    (slot, depth) themselves; see ``_folds_step_key``) — so the two
    paths draw identical noise at identical (slot, depth) sites."""
    base = _decode_base_key(entropy)
    fold = _folds_step_key(cfg, entropy)

    def decode_step(params, token, cache, step):
        key = jax.random.fold_in(base, step) if fold else base
        return M.decode_step(params, cfg, token, cache, key)

    return decode_step


def build_scan_decode(cfg: ArchConfig, entropy=None, chunk: int = 8,
                      mi_threshold: float = 0.05,
                      se_threshold: float = 1.0):
    """Chunked on-device decode: ``chunk`` tokens per host round-trip.

    Returns ``scan_decode(params, token, cache, step0, active, flags) ->
    (token, cache, flags, ys)`` where the inner ``jax.lax.scan`` carries
    (token, slot-indexed cache, cumulative per-slot epistemic/aleatoric
    flag counters) and stacks per-step outputs ``ys`` = {token, H, SE,
    MI, p_max, epistemic, aleatoric}, each (chunk, B).  No per-token
    host sync: the caller transfers ``ys`` once per chunk.

    ``active`` (B,) bool gates the carried counters: only occupied slots
    accumulate, so a pure-device driver can read per-slot flag totals
    without ever syncing ``ys``.  The counters are device telemetry: a
    request finishing mid-chunk keeps counting until the chunk boundary
    (the host can't evict inside the scan), so they upper-bound the
    exact per-request host accounting done from ``ys``.

    Noise stream under scan: in operand mode the UNFOLDED base key is
    passed down every step and the models fold (slot, depth) into it
    (``layers.decode_head_noise``), so a slot's draws depend only on
    its own token position — scan decode replays the per-step loop's
    stream bit-for-bit at equal (slot, depth) sites regardless of how
    the engine interleaves admissions, chunked prefill, or pauses
    around it.  On the seeded kernel path step t of the chunk uses key
    fold_in(base, step0 + t) -- the global-step convention of
    ``build_decode_step`` -- and the folded key reaches the
    uncertainty-head kernel as an int32 seed whose in-kernel PRNG
    re-mixes it with the grid coordinates, so every (slot, step) site
    owns a distinct replayable stream with zero HBM entropy traffic.

    Per-slot cache depths (``cache['len']``) give per-slot RoPE
    positions, so slots admitted mid-stream decode correctly alongside
    older slots.  A slot's capacity is enforced by the engine at
    admission (prompt + max-new-tokens must fit ``max_len``); writes of
    an over-deep slot would be dropped by the scatter.

    Paged KV: when the cache carries a ``block_table`` (the engine's
    ``--kv-layout paged``), the (slot, logical_pos) -> (block, offset)
    indirection rides through the scan unchanged in the carry — every
    decode step inside the chunk reads/writes the block pool through the
    same table, and the host refreshes the table between chunks as the
    scheduler grants blocks.  The scan itself is layout-agnostic, and
    that includes the read path ``cfg.decode_attn`` selects: the
    block-sparse decode kernel (``--decode-attn kernel``) consumes the
    same carried table and pool leaves per step, so it needs no carry
    change — only the per-step HBM traffic differs (mapped blocks vs
    the full logical span; see kernels/paged_attention.py).
    """
    base = _decode_base_key(entropy)
    fold = _folds_step_key(cfg, entropy)

    def scan_decode(params, token, cache, step0, active, flags):
        def body(carry, t):
            tok, cache, epi, alea = carry
            key = jax.random.fold_in(base, step0 + t) if fold else base
            out, cache = M.decode_step(params, cfg, tok, cache, key)
            is_epi = out["MI"] > mi_threshold
            is_alea = (out["SE"] > se_threshold) & ~is_epi
            ys = {"token": out["next_token"], "H": out["H"],
                  "SE": out["SE"], "MI": out["MI"], "p_max": out["p_max"],
                  "epistemic": is_epi, "aleatoric": is_alea}
            carry = (out["next_token"], cache,
                     epi + (is_epi & active).astype(jnp.int32),
                     alea + (is_alea & active).astype(jnp.int32))
            return carry, ys

        (token, cache, epi, alea), ys = jax.lax.scan(
            body, (token, cache, flags["epistemic"], flags["aleatoric"]),
            jnp.arange(chunk, dtype=jnp.int32))
        return token, cache, {"epistemic": epi, "aleatoric": alea}, ys

    return scan_decode


# ---------------------------------------------------------------------------
# speculative decoding (draft / verify / commit)
# ---------------------------------------------------------------------------

def build_spec_draft(cfg: ArchConfig, entropy=None, k: int = 4,
                     draft_samples: int = 1):
    """``k``-step draft pass for uncertainty-gated speculative decoding.

    Operand-entropy mode ONLY (the engine validates): the head noise is
    then a pure function of (slot, depth), never of the global step, so
    draft and verify can replay plain decode's stream at equal sites.

    The draft SHARES the full model body: each step runs
    ``M.decode_hidden`` — whose KV/state writes at the slot's pre-step
    depth are bitwise the writes plain decode would do for the same fed
    token — and proposes with a cheap ``draft_samples``-draw head
    (0 = the deterministic mean head).  No separate draft cache exists;
    a rejected suffix leaves junk KV above the rolled-back ``len``,
    which every decode read masks and later writes overwrite.

    Returns ``spec_draft(params, token, cache) -> (token, cache, ys)``
    with ``ys = {token (k, B) proposals, hidden (k, B, d) pre-head
    hiddens}`` plus the post-step recurrent leaves (``ssm``/``conv``)
    stacked for rollback (``build_spec_commit``).
    """
    base = _decode_base_key(entropy)

    def spec_draft(params, token, cache):
        def body(carry, _):
            tok, cache = carry
            depth = cache["len"]
            hidden, cache = M.decode_hidden(params, cfg, tok, cache)
            out = M.head_outputs(params, cfg, hidden, depth, base,
                                 num_samples=draft_samples)
            ys = {"token": out["next_token"], "hidden": hidden}
            for leaf in M.RECURRENT_LEAVES:
                if leaf in cache:
                    ys[leaf] = cache[leaf]
            return (out["next_token"], cache), ys

        (token, cache), ys = jax.lax.scan(body, (token, cache), None,
                                          length=k)
        return token, cache, ys

    return spec_draft


def build_spec_verify(cfg: ArchConfig, entropy=None, k: int = 4,
                      mi_threshold: float = 0.05,
                      se_threshold: float = 1.0):
    """ONE batched full-S-sample verify over the k draft positions.

    ``spec_verify(params, hiddens, lens0)``: ``hiddens`` are the draft
    pass's stacked (k, B, d) pre-head hiddens, ``lens0`` the (B,)
    pre-round depths.  Runs the family's exact uncertain head
    (``M.head_outputs``) vmapped over positions, at depth ``lens0 + j``
    for position j — in operand mode the depth-keyed noise
    (``layers.decode_head_noise`` folds (slot, depth), never the step)
    makes the vmapped head BITWISE identical to k sequential per-step
    heads, so verify output j IS what plain decode would have emitted
    there (tests/test_spec_decode.py).  Also emits the engine's
    epistemic/aleatoric gating flags per position.
    """
    base = _decode_base_key(entropy)

    def spec_verify(params, hiddens, lens0):
        def one(j, h):
            out = M.head_outputs(params, cfg, h, lens0 + j, base)
            is_epi = out["MI"] > mi_threshold
            is_alea = (out["SE"] > se_threshold) & ~is_epi
            return dict(out, epistemic=is_epi, aleatoric=is_alea)

        return jax.vmap(one)(jnp.arange(k, dtype=jnp.int32), hiddens)

    return spec_verify


def build_spec_commit(cfg: ArchConfig):
    """Device-side rollback/commit after a speculative round.

    ``spec_commit(cache, token, mask, new_tok, new_len, states, idx)``:
    ``mask`` (B,) selects the slots keeping spec-round results (active
    participants that did not finish); their carry token and depth are
    pinned to ``new_tok``/``new_len`` (= pre-round len + emitted).  KV
    written above the rolled-back ``len`` needs no cleanup — decode
    attention masks positions >= len and later steps overwrite — but
    the hybrid/ssm RECURRENT state must rewind: ``states`` holds the
    draft scan's stacked (k, L, B, ...) post-step leaves and ``idx``
    (B,) picks index ``emitted - 1`` (the state after the last kept
    step) per slot.  Unmasked slots keep their (junk-advanced) state,
    exactly like inactive slots under a plain scan chunk.
    """
    def spec_commit(cache, token, mask, new_tok, new_len, states, idx):
        token = jnp.where(mask, new_tok, token)
        cache = dict(cache, len=jnp.where(mask, new_len, cache["len"]))
        for leaf in M.RECURRENT_LEAVES:
            if leaf not in cache:
                continue
            st = jnp.moveaxis(states[leaf], 2, 0)          # (B, k, L, ...)
            picked = jax.vmap(lambda s, i: s[i])(st, idx)  # (B, L, ...)
            picked = jnp.moveaxis(picked, 0, 1)            # (L, B, ...)
            keep = mask.reshape((1, -1) + (1,) * (picked.ndim - 2))
            cache[leaf] = jnp.where(
                keep, picked.astype(cache[leaf].dtype), cache[leaf])
        return token, cache

    return spec_commit


# ---------------------------------------------------------------------------
# dry-run input specs + shardings
# ---------------------------------------------------------------------------

def train_state_specs(cfg: ArchConfig, opt_cfg: adamw.AdamWConfig):
    params = M.init_params_shape(cfg)
    opt = jax.eval_shape(lambda p: adamw.init_state(p, opt_cfg), params)
    return {"params": params, "opt": opt}


def input_specs(cfg: ArchConfig, cell: ShapeCell) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    if cell.kind == "train":
        return {"batch": M.make_batch_specs(cfg, cell.global_batch,
                                            cell.seq_len)}
    if cell.kind == "prefill":
        return {"batch": M.make_batch_specs(cfg, cell.global_batch,
                                            cell.seq_len)}
    # decode: one new token against a seq_len-deep cache
    cache = jax.eval_shape(
        lambda: M.make_cache(cfg, cell.global_batch, cell.seq_len))
    return {
        "token": jax.ShapeDtypeStruct((cell.global_batch,), jnp.int32),
        "cache": cache,
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def batch_pspecs(mesh: Mesh, specs: dict) -> dict:
    """Data batches shard their leading (global batch) dim over DP axes."""
    out = {}
    for name, s in specs.items():
        dims = ["batch"] + [None] * (len(s.shape) - 1)
        out[name] = meshlib.spec_if(mesh, s.shape, *dims)
    return out


_CACHE_AXES = {
    # leaf-name -> axis roles per trailing dims (L, B, S, H, D) etc.
    "k": ("layer", "batch", "seq", "heads", None),
    "v": ("layer", "batch", "seq", "heads", None),
    "attn_k": ("layer", "batch", "seq", "heads", None),
    "attn_v": ("layer", "batch", "seq", "heads", None),
    "ck": ("layer", "batch", "seq", "heads", None),
    "cv": ("layer", "batch", "seq", "heads", None),
    "conv": ("layer", "batch", None, "model_dim"),
    "ssm": ("layer", "batch", "heads", None, None),
    "len": ("batch",),
}


def cache_pspecs(mesh: Mesh, cache_specs: Any) -> Any:
    """KV/SSM cache shardings with divisibility-aware fallbacks.

    Preference order per leaf: batch over DP axes; heads/model_dim over
    the model axis.  If the batch dim does not divide (long_500k, B=1),
    the sequence dim takes the DP axes instead (sequence parallelism for
    the long-context KV cache).
    """

    def leaf_spec(path, s):
        name = path[-1] if path else ""
        roles = _CACHE_AXES.get(name, (None,) * len(s.shape))
        dims: list = []
        batch_taken = False
        for size, role in zip(s.shape, roles):
            if role == "batch" and meshlib.shardable(
                    size, mesh, meshlib.batch_axes(mesh)):
                dims.append("batch")
                batch_taken = True
            elif role == "seq" and not batch_taken and meshlib.shardable(
                    size, mesh, meshlib.batch_axes(mesh)):
                dims.append("batch")
                batch_taken = True
            elif role in ("heads", "model_dim") and meshlib.shardable(
                    size, mesh, "model"):
                dims.append("model")
            else:
                dims.append(None)
        return meshlib.spec_if(mesh, s.shape, *dims)

    flat = jax.tree_util.tree_flatten_with_path(cache_specs)[0]
    specs = {}
    for kp, leaf in flat:
        path = tuple(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in kp)
        specs[path] = leaf_spec(path, leaf)

    def walk(path, node):
        if isinstance(node, dict):
            return {k: walk(path + (k,), v) for k, v in node.items()}
        return specs[path]

    return walk((), cache_specs)


def state_pspecs(cfg: ArchConfig, mesh: Mesh, state_specs: dict) -> dict:
    """Partition specs for {params, opt}: params by the name rules, opt
    moments like their params (ZeRO: FSDP axis shards moments too)."""
    fsdp = cfg.fsdp_params and "data" in mesh.axis_names
    pod_fsdp = fsdp and "pod" in mesh.axis_names
    pspec = param_pspecs(state_specs["params"], fsdp=fsdp,
                         pod_fsdp=pod_fsdp)
    pspec = sanitize_pspecs(pspec, state_specs["params"], mesh)
    opt = state_specs["opt"]
    out_opt: dict = {"mu": pspec, "nu": pspec, "step": P()}
    if "error" in opt:
        out_opt["error"] = pspec
    return {"params": pspec, "opt": out_opt}
