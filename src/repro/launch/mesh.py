"""Production mesh construction (single-pod and multi-pod).

The target machine is a TPU v5e pod of 16 x 16 = 256 chips; the multi-pod
dry-run stacks two pods on a leading ``pod`` axis (DCN data parallelism;
ICI inside a pod).  Everything is a FUNCTION -- importing this module never
touches jax device state, so smoke tests keep seeing 1 CPU device.

Axis semantics (see sharding/partition.py):
  pod   -- inter-pod data parallelism (gradient all-reduce over DCN)
  data  -- intra-pod data parallelism + FSDP/ZeRO shard axis
  model -- tensor parallelism (heads / ff / vocab / experts)
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# TPU v5e hardware constants used by the roofline analysis (per chip).
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW = 50e9                   # bytes/s per link (~per-chip usable)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(shape=(2, 2), axes=("data", "model")) -> Mesh:
    """Small mesh over whatever devices exist (tests / examples).

    ``jax.make_mesh`` requires the shape to tile the device count
    exactly and raises from deep inside device assignment otherwise
    (e.g. the default (2, 2) on a 1-CPU test process).  When it
    doesn't, fall back to a 1D ``("model",)`` mesh over every
    available device — callers get a working mesh whose axis names
    the sharding rules still understand, and divisibility-aware specs
    (``spec_if`` / ``sanitize_pspecs``) degrade to replication
    exactly as they would on the requested shape.
    """
    n = len(jax.devices())
    want = 1
    for s in shape:
        want *= s
    if want != n:
        return jax.make_mesh((n,), ("model",))
    return jax.make_mesh(shape, axes)


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def dp_size(mesh: Mesh) -> int:
    n = 1
    for a in batch_axes(mesh):
        n *= mesh.shape[a]
    return n


def shardable(dim: int, mesh: Mesh, axes) -> bool:
    """True if ``dim`` divides evenly over the product of mesh ``axes``."""
    if axes is None:
        return True
    axes = (axes,) if isinstance(axes, str) else tuple(axes)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return dim % n == 0 and dim >= n


def spec_if(mesh: Mesh, shape: tuple[int, ...], *dims) -> P:
    """PartitionSpec with per-dim divisibility fallback to replication.

    dims entries: None | axis-name | tuple of axis names | "batch"
    ("batch" expands to the mesh's DP axes).
    """
    out = []
    for size, d in zip(shape, dims):
        if d == "batch":
            d = batch_axes(mesh)
            if len(d) == 1:
                d = d[0]
        if d is None or not shardable(size, mesh, d):
            out.append(None)
        else:
            out.append(d)
    return P(*out)


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)
