"""Procedural synthetic datasets (offline stand-ins, see DESIGN.md §6).

Images (28x28, for the paper's experiments):
  * ``blood_cells``   -- 7 ID classes of textured-ellipse 'cells' with
    class-dependent radius / eccentricity / granularity / intensity,
    mimicking the BloodMNIST morphology axes, plus an 8th generator
    ('erythroblast') drawn from a held-out morphology for the OOD split.
  * ``glyphs``        -- 10 stroke-rendered digit-like classes (MNIST
    stand-in).
  * ``ambiguous``     -- convex pixel blends of two glyph classes; this is
    literally how Ambiguous-MNIST is constructed, so the aleatoric
    semantics carry over.
  * ``fashion_ood``   -- striped/checkered garment-like silhouettes,
    structurally unlike glyphs (epistemic OOD).

Tokens (for the LM architectures): a Zipf-weighted order-2 Markov chain
over the arch's vocabulary — deterministic given (seed, host, step), so
the stream is shardable across hosts and exactly resumable from a
checkpointed cursor.
"""

from __future__ import annotations

import dataclasses

import numpy as np

IMG = 28


# ---------------------------------------------------------------------------
# image primitives
# ---------------------------------------------------------------------------

def _grid():
    y, x = np.mgrid[0:IMG, 0:IMG].astype(np.float32)
    return (x - IMG / 2) / (IMG / 2), (y - IMG / 2) / (IMG / 2)


_X, _Y = _grid()

# per-class morphology: (radius, eccentricity, granularity, nucleus, hue)
_BLOOD_CLASSES = {
    0: (0.55, 1.00, 0.9, 0.35, 0.9),   # basophil: dark granular
    1: (0.60, 1.05, 0.7, 0.45, 0.7),   # eosinophil: bilobed
    2: (0.70, 0.95, 0.5, 0.60, 0.6),   # immature granulocyte: large
    3: (0.45, 1.00, 0.1, 0.80, 0.5),   # lymphocyte: big round nucleus
    4: (0.75, 0.90, 0.2, 0.50, 0.55),  # monocyte: kidney nucleus
    5: (0.60, 1.10, 0.6, 0.30, 0.65),  # neutrophil: multilobed
    6: (0.25, 1.00, 0.3, 0.00, 0.8),   # platelet: tiny fragment
    # held-out morphology -> epistemic OOD at prediction time
    7: (0.50, 1.30, 0.15, 0.95, 0.3),  # erythroblast: dense round nucleus,
                                        # strongly eccentric halo
}


def blood_cells(rng: np.random.Generator, n: int, classes=range(7)):
    """-> images (n, 3, 28, 28) float32 in [0,1], labels (n,)."""
    classes = list(classes)
    labels = rng.integers(0, len(classes), n)
    imgs = np.zeros((n, 3, IMG, IMG), np.float32)
    for i in range(n):
        c = classes[labels[i]]
        rad, ecc, gran, nuc, hue = _BLOOD_CLASSES[c]
        cx, cy = rng.uniform(-0.15, 0.15, 2)
        th = rng.uniform(0, np.pi)
        xr = (_X - cx) * np.cos(th) + (_Y - cy) * np.sin(th)
        yr = -(_X - cx) * np.sin(th) + (_Y - cy) * np.cos(th)
        r2 = (xr / (rad * ecc)) ** 2 + (yr / rad) ** 2
        body = np.clip(1.2 - r2, 0, 1)
        tex = gran * rng.standard_normal((IMG, IMG)).astype(np.float32)
        tex = np.clip(tex, -1, 1) * (body > 0)
        nucleus = nuc * np.clip(1.0 - r2 / (0.35 + 0.1 * nuc), 0, 1)
        base = 0.25 + 0.5 * body + 0.25 * tex
        img = np.stack([
            base * (1.0 - 0.5 * hue) + nucleus * 0.6,
            base * 0.8 + nucleus * 0.2,
            base * hue + nucleus * 0.7,
        ])
        img += 0.03 * rng.standard_normal(img.shape).astype(np.float32)
        imgs[i] = np.clip(img, 0, 1)
    return imgs, labels.astype(np.int32)


def blood_cells_ood(rng, n):
    imgs, _ = blood_cells(rng, n, classes=[7])
    return imgs, np.full((n,), -1, np.int32)


# digit-like strokes: each class = set of line segments in unit coords
_GLYPH_STROKES = {
    0: [(.3, .2, .7, .2), (.7, .2, .7, .8), (.7, .8, .3, .8), (.3, .8, .3, .2)],
    1: [(.5, .2, .5, .8), (.4, .3, .5, .2)],
    2: [(.3, .25, .7, .25), (.7, .25, .7, .5), (.7, .5, .3, .8), (.3, .8, .7, .8)],
    3: [(.3, .2, .7, .3), (.7, .3, .4, .5), (.4, .5, .7, .7), (.7, .7, .3, .8)],
    4: [(.6, .2, .3, .6), (.3, .6, .75, .6), (.6, .2, .6, .85)],
    5: [(.7, .2, .3, .2), (.3, .2, .3, .5), (.3, .5, .7, .6), (.7, .6, .3, .8)],
    6: [(.6, .2, .35, .5), (.35, .5, .35, .75), (.35, .75, .65, .75),
        (.65, .75, .65, .55), (.65, .55, .35, .55)],
    7: [(.3, .2, .7, .2), (.7, .2, .45, .8)],
    8: [(.5, .2, .7, .35), (.7, .35, .3, .6), (.3, .6, .5, .8),
        (.5, .8, .7, .6), (.7, .6, .3, .35), (.3, .35, .5, .2)],
    9: [(.65, .45, .35, .45), (.35, .45, .35, .25), (.35, .25, .65, .25),
        (.65, .25, .65, .8)],
}


def _render_strokes(strokes, rng, thick=0.08):
    img = np.zeros((IMG, IMG), np.float32)
    jit = rng.uniform(-0.05, 0.05, 4 * len(strokes))
    for si, (x0, y0, x1, y1) in enumerate(strokes):
        j = jit[4 * si:4 * si + 4]
        x0, y0, x1, y1 = x0 + j[0], y0 + j[1], x1 + j[2], y1 + j[3]
        ts = np.linspace(0, 1, 40)[:, None]
        pts = np.stack([x0 + (x1 - x0) * ts[:, 0],
                        y0 + (y1 - y0) * ts[:, 0]], 1) * IMG
        d2 = (np.arange(IMG)[None, :, None] - pts[:, 0]) ** 2 + \
             (np.arange(IMG)[:, None, None] - pts[:, 1]) ** 2
        img = np.maximum(img, np.exp(-d2.min(-1) /
                                     (2 * (thick * IMG) ** 2)))
    return img


def glyphs(rng: np.random.Generator, n: int):
    """MNIST stand-in: (n, 1, 28, 28) in [0,1], labels (n,)."""
    labels = rng.integers(0, 10, n)
    imgs = np.zeros((n, 1, IMG, IMG), np.float32)
    for i in range(n):
        img = _render_strokes(_GLYPH_STROKES[int(labels[i])], rng,
                              thick=rng.uniform(0.06, 0.1))
        img += 0.05 * rng.standard_normal((IMG, IMG)).astype(np.float32)
        imgs[i, 0] = np.clip(img, 0, 1)
    return imgs, labels.astype(np.int32)


def ambiguous_glyphs(rng: np.random.Generator, n: int):
    """Convex blends of two classes (the Ambiguous-MNIST construction).

    labels: the pair (a, b) packed as a*10+b — evaluation treats either
    constituent as 'correct' and expects HIGH SE, LOW MI.
    """
    a = rng.integers(0, 10, n)
    b = (a + rng.integers(1, 10, n)) % 10
    w = rng.uniform(0.35, 0.65, n).astype(np.float32)
    imgs = np.zeros((n, 1, IMG, IMG), np.float32)
    for i in range(n):
        ia = _render_strokes(_GLYPH_STROKES[int(a[i])], rng)
        ib = _render_strokes(_GLYPH_STROKES[int(b[i])], rng)
        img = w[i] * ia + (1 - w[i]) * ib
        img += 0.05 * rng.standard_normal((IMG, IMG)).astype(np.float32)
        imgs[i, 0] = np.clip(img, 0, 1)
    return imgs, (a * 10 + b).astype(np.int32)


def fashion_ood(rng: np.random.Generator, n: int):
    """Garment-like silhouettes (Fashion-MNIST stand-in): epistemic OOD."""
    imgs = np.zeros((n, 1, IMG, IMG), np.float32)
    for i in range(n):
        kind = rng.integers(0, 3)
        w, h = rng.uniform(0.4, 0.8, 2)
        mask = (np.abs(_X) < w / 1.4) & (np.abs(_Y) < h / 1.4)
        if kind == 0:      # striped shirt
            tex = 0.5 + 0.5 * np.sin(_Y * rng.uniform(8, 20))
        elif kind == 1:    # checkered bag
            tex = ((np.floor(_X * 6) + np.floor(_Y * 6)) % 2)
        else:              # trouser split
            mask &= np.abs(_X) > 0.12
            tex = np.full_like(_X, 0.8)
        img = mask * tex * rng.uniform(0.6, 1.0)
        img += 0.05 * rng.standard_normal((IMG, IMG)).astype(np.float32)
        imgs[i, 0] = np.clip(img, 0, 1)
    return imgs, np.full((n,), -1, np.int32)


# ---------------------------------------------------------------------------
# token streams
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TokenStreamState:
    """Exactly-resumable cursor for the synthetic LM stream."""
    seed: int
    host: int
    num_hosts: int
    step: int = 0


def token_batch(state: TokenStreamState, batch: int, seq: int,
                vocab: int) -> tuple[np.ndarray, TokenStreamState]:
    """Zipf-weighted order-2 Markov token stream, sharded per host.

    Deterministic in (seed, host, step) -- restarting from a checkpointed
    ``state`` regenerates the identical remaining stream (fault tolerance
    without storing data offsets).
    """
    rng = np.random.default_rng(
        (state.seed * 1_000_003 + state.host) * 1_000_003 + state.step)
    # stationary Zipf over a hashed permutation of the vocab
    ranks = 1.0 / np.arange(1, min(vocab, 4096) + 1) ** 1.1
    probs = ranks / ranks.sum()
    base = rng.choice(len(probs), size=(batch, seq), p=probs)
    # order-2 structure: every 3rd token is a deterministic mix of the
    # previous two (gives the model something learnable)
    toks = base.astype(np.int64)
    toks[:, 2::3] = (toks[:, 1::3][:, :toks[:, 2::3].shape[1]] * 31 +
                     toks[:, 0::3][:, :toks[:, 2::3].shape[1]] * 17) % \
        max(vocab // 7, 11)
    toks = toks % vocab
    new_state = dataclasses.replace(state, step=state.step + 1)
    return toks.astype(np.int32), new_state
