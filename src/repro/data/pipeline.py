"""Sharded host data pipeline with prefetch + checkpointable state.

A production multi-pod run has one loader per host feeding its addressable
shard of the global batch.  Here:

  * ``ShardedLoader`` wraps the synthetic generators, carves the global
    batch into per-host shards, prefetches on a background thread, and
    exposes ``state_dict()/load_state_dict()`` so the cursor rides along
    with checkpoints (exact resume, no data replay or skip).
  * ``device_put_sharded_batch`` lays a host batch onto the mesh according
    to the batch PartitionSpec (DP axes), forming global arrays.
"""

from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.data import synthetic as syn


class ShardedLoader:
    """Prefetching, host-sharded, exactly-resumable loader."""

    def __init__(self, cfg: ArchConfig, global_batch: int, seq: int,
                 seed: int = 0, host: Optional[int] = None,
                 num_hosts: Optional[int] = None, prefetch: int = 2):
        self.cfg = cfg
        self.host = jax.process_index() if host is None else host
        self.num_hosts = jax.process_count() if num_hosts is None else \
            num_hosts
        assert global_batch % self.num_hosts == 0
        self.local_batch = global_batch // self.num_hosts
        self.seq = seq
        self.state = syn.TokenStreamState(seed=seed, host=self.host,
                                          num_hosts=self.num_hosts)
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    # -- background producer ------------------------------------------------
    def _make(self, state):
        toks, new_state = syn.token_batch(
            state, self.local_batch, self.seq + 1, self.cfg.vocab_size)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if self.cfg.family == "encdec":
            from repro.models.encdec import ENC_LEN
            rng = np.random.default_rng(state.step)
            batch["frames"] = rng.standard_normal(
                (self.local_batch, ENC_LEN, self.cfg.d_model)).astype(
                    np.float32) * 0.02
        if self.cfg.family == "vlm":
            rng = np.random.default_rng(state.step)
            batch["prefix_embeds"] = rng.standard_normal(
                (self.local_batch, self.cfg.num_prefix_embeds,
                 self.cfg.d_model)).astype(np.float32) * 0.02
        return batch, new_state

    def _worker(self):
        state = self.state
        while not self._stop.is_set():
            batch, state = self._make(state)
            while not self._stop.is_set():
                try:
                    self._q.put((batch, state), timeout=0.2)
                    break
                except queue.Full:
                    continue

    def __next__(self):
        batch, self.state = self._q.get()
        return batch

    def __iter__(self) -> Iterator[dict]:
        return self

    def close(self):
        self._stop.set()

    # -- checkpointable cursor ----------------------------------------------
    def state_dict(self) -> dict:
        return {"seed": self.state.seed, "host": self.state.host,
                "num_hosts": self.state.num_hosts, "step": self.state.step}

    def load_state_dict(self, d: dict):
        # drain prefetched batches built from the stale cursor
        self.close()
        self._thread.join(timeout=2.0)
        while not self._q.empty():
            self._q.get_nowait()
        self.state = syn.TokenStreamState(**d)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()


def batch_pspec(mesh: Mesh) -> P:
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return P(axes)


def device_put_sharded_batch(batch: dict, mesh: Mesh) -> dict:
    """Host numpy batch -> global arrays sharded over the DP axes."""
    spec = batch_pspec(mesh)

    def put(x):
        ndim = np.ndim(x)
        s = NamedSharding(mesh, P(*(spec + (None,) * (ndim - 1))))
        return jax.device_put(x, s)

    return {k: put(v) for k, v in batch.items()}
