"""Uncertainty metrics and decision rules (paper Eq. 1, Eq. 2, Figs. 4-5).

Given N Monte-Carlo predictive distributions p_n(c) (softmax outputs of N
sampled forward passes):

  total      H  = entropy( mean_n p_n )                      (Eq. 1)
  aleatoric  SE = mean_n entropy( p_n )                      (Eq. 2)
  epistemic  MI = H - SE                                     (mutual info)

Decision rules:
  * OOD rejection: reject if MI > threshold  (epistemic flag, Fig. 4c/d)
  * ambiguity flag: SE high, MI low          (aleatoric, Fig. 5e)

Also: threshold-sweep ROC / AUROC and rejection-accuracy curves used for
the paper's headline numbers, implemented in pure numpy-compatible jnp so
benchmarks can jit them.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


_EPSLOG = 1e-12


def _entropy(p: jax.Array, axis: int = -1) -> jax.Array:
    return -jnp.sum(p * jnp.log(p + _EPSLOG), axis=axis)


def predictive_moments(probs: jax.Array) -> dict[str, jax.Array]:
    """probs: (N, ..., C) MC samples of class probabilities.

    Returns dict of (...,)-shaped H, SE, MI and (..., C) mean predictive.
    """
    p_mean = probs.mean(axis=0)
    h = _entropy(p_mean)
    se = _entropy(probs).mean(axis=0)
    mi = jnp.maximum(h - se, 0.0)
    return {"p_mean": p_mean, "H": h, "SE": se, "MI": mi}


def uncertainty_from_logits(logits: jax.Array) -> dict[str, jax.Array]:
    """logits: (N, ..., C) MC samples -> same dict as predictive_moments.

    Numerically stable path used by the fused uncertainty-head kernel's
    reference: softmax in float32 with logsumexp normalization.
    """
    logits = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    probs = jnp.exp(logp)
    p_mean = probs.mean(axis=0)
    h = _entropy(p_mean)
    se = (-jnp.sum(probs * logp, axis=-1)).mean(axis=0)
    mi = jnp.maximum(h - se, 0.0)
    return {"p_mean": p_mean, "H": h, "SE": se, "MI": mi}


# --------------------------------------------------------------------------
# decision rules + evaluation curves
# --------------------------------------------------------------------------

def roc_curve(scores_pos: jax.Array, scores_neg: jax.Array,
              num_thresholds: int = 512) -> dict[str, jax.Array]:
    """ROC of 'score > t => positive' over a threshold sweep.

    scores_pos: scores of true positives (e.g. MI of OOD images),
    scores_neg: scores of true negatives (MI of ID images).
    """
    lo = jnp.minimum(scores_pos.min(), scores_neg.min())
    hi = jnp.maximum(scores_pos.max(), scores_neg.max())
    ts = jnp.linspace(hi, lo, num_thresholds)
    tpr = (scores_pos[None, :] > ts[:, None]).mean(axis=1)
    fpr = (scores_neg[None, :] > ts[:, None]).mean(axis=1)
    return {"thresholds": ts, "tpr": tpr, "fpr": fpr}


def auroc(scores_pos: jax.Array, scores_neg: jax.Array) -> jax.Array:
    """Exact AUROC via the Mann-Whitney U statistic (ties count 1/2)."""
    pos = scores_pos[:, None]
    neg = scores_neg[None, :]
    wins = (pos > neg).mean() + 0.5 * (pos == neg).mean()
    return wins


def rejection_accuracy(p_mean: jax.Array, mi: jax.Array, labels: jax.Array,
                       threshold: float) -> dict[str, jax.Array]:
    """Accuracy on accepted (MI <= threshold) samples + rejection rate.

    Reproduces Fig. 4d / Fig. 5f: rejecting uncertain cases raises ID
    accuracy (paper: 90.26% -> 94.62% blood cells, 96.01% -> 99.7% MNIST).
    """
    pred = p_mean.argmax(axis=-1)
    accept = mi <= threshold
    correct = (pred == labels) & accept
    acc_all = (pred == labels).mean()
    n_acc = jnp.maximum(accept.sum(), 1)
    return {"accuracy_all": acc_all,
            "accuracy_accepted": correct.sum() / n_acc,
            "rejection_rate": 1.0 - accept.mean()}


def best_rejection_threshold(mi_id: jax.Array, p_mean_id: jax.Array,
                             labels_id: jax.Array,
                             num_thresholds: int = 256) -> tuple[float, float]:
    """Sweep MI thresholds, return (best_threshold, best_accepted_accuracy)."""
    ts = jnp.linspace(float(mi_id.min()), float(mi_id.max()), num_thresholds)

    def acc_at(t):
        r = rejection_accuracy(p_mean_id, mi_id, labels_id, t)
        # mild pressure against rejecting everything
        return r["accuracy_accepted"] - 0.01 * r["rejection_rate"]

    accs = jax.vmap(acc_at)(ts)
    i = int(jnp.argmax(accs))
    r = rejection_accuracy(p_mean_id, mi_id, labels_id, ts[i])
    return float(ts[i]), float(r["accuracy_accepted"])


def disentangle_clusters(mi: jax.Array, se: jax.Array,
                         dataset_id: jax.Array) -> dict[str, jax.Array]:
    """Per-dataset (ID=0, ambiguous=1, OOD=2) centroids in (SE, MI) space.

    The paper's Fig. 5e shows three clusters; we report centroids and the
    silhouette-style separation used by tests to assert the clusters exist.
    """
    cents = []
    for d in range(3):
        m = dataset_id == d
        w = m / jnp.maximum(m.sum(), 1)
        cents.append(jnp.stack([jnp.sum(se * w), jnp.sum(mi * w)]))
    c = jnp.stack(cents)  # (3, 2)
    d01 = jnp.linalg.norm(c[0] - c[1])
    d02 = jnp.linalg.norm(c[0] - c[2])
    d12 = jnp.linalg.norm(c[1] - c[2])
    return {"centroids": c, "min_pairwise": jnp.minimum(d01, jnp.minimum(d02, d12))}
