"""Entropy sources for the photonic Bayesian machine.

The paper's physical entropy source is amplified spontaneous emission (ASE)
from an erbium-doped fiber: broadband chaotic light whose spectrum is sliced
into frequency channels. The detected power of one channel of optical
bandwidth ``B_opt`` measured with electrical bandwidth ``B_elec`` follows a
Gamma distribution with

    M = B_opt / B_elec          (degrees of freedom / "modes")
    mean  = P                    (set by the channel's optical power)
    std   = P / sqrt(M)          (set by the channel's *bandwidth*)

which is exactly the paper's programming rule: optical power -> weight mean,
channel bandwidth -> weight standard deviation (Fig. 1c, Fig. S2). For
M >~ 10 the Gamma converges to a Gaussian, which is why the paper can model
the physical weights with Gaussian variational posteriors (SVI).

Negative weights cannot be carried by optical power directly; the machine
realizes them differentially (balanced detection of a signal and a reference
arm). We model that as an affine map ``w = g * (I - I_ref)`` applied to the
non-negative photocurrent ``I``.

This module gives four interchangeable sources behind one API:

  * ``PRNGEntropy``      -- counter-based Gaussian, the digital baseline the
                            paper says is the bottleneck (and our oracle).
  * ``ASEEntropy``       -- Gamma(M) photocurrent statistics, the physical
                            digital twin.  Per-channel M is derived from the
                            programmed bandwidth, clipped to the hardware's
                            25-150 GHz range.
  * ``EntropyStream``    -- a pre-drawn host buffer replayed into kernels,
                            mirroring how the physical machine's randomness
                            is *external* to the digital datapath.  Pallas
                            kernels take this as a plain input tensor.
  * ``KernelEntropy``    -- the in-kernel TPU PRNG: randomness is generated
                            *at the MAC* (pltpu.prng_random_bits +
                            Box-Muller inside the Pallas kernels, seeded
                            from this source's base seed + grid coords), so
                            zero entropy bytes cross HBM — the TPU twin of
                            the machine's architectural rule.  Off-TPU,
                            ``sample`` emulates the stream host-side from
                            the same seed (moment-, not bit-, equivalent).

All sampling is shaped (num_samples, *weight_shape) and returns *standard*
variates (zero mean, unit std) so that layers can apply the reparameterized
``w = mu + sigma * eps`` regardless of the source.  For ``ASEEntropy`` the
standardized Gamma keeps its skewness ``2/sqrt(M)`` -- tests assert both the
standardization and the residual skew so the physics is not silently lost;
``KernelEntropy`` is contractually Gaussian (skew 0) and seed-deterministic.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

# --- hardware constants from the paper --------------------------------------
CENTER_FREQ_THZ = 194.0          # channel grid center
CHANNEL_SPACING_GHZ = 403.0      # spacing between the 9 channels
NUM_CHANNELS = 9                 # one probabilistic weight per channel
BW_MIN_GHZ = 25.0                # minimum programmable channel bandwidth
BW_MAX_GHZ = 150.0               # maximum programmable channel bandwidth
ELEC_BW_GHZ = 40.0               # detection bandwidth (80 GSPS Nyquist)
GROUP_DELAY_PS_PER_THZ = -93.1   # chirped-grating dispersion
DAC_BITS = 8
ADC_BITS = 8
SAMPLES_PER_SYMBOL = 3           # 80 GSPS DAC, 3 samples per vector entry
CONV_LATENCY_PS = 37.5           # one 9-tap probabilistic convolution


# Detection integrates SAMPLES_PER_SYMBOL ADC samples per symbol plus the
# analog front-end's time-bandwidth product; both multiply the effective
# Gamma mode count M (variance averaging).  No polarization-diversity 2x:
# the balanced (differential) receiver that carries the weight sign
# detects a single polarization per arm, so the mode count stays at the
# temporal integration alone.  The resulting sigma floor 1/sqrt(M_max)
# sits above part of the programmable target range -- the bandwidth axis
# is the machine's less accurate one, which is why the paper's std error
# (0.266) exceeds its mean error (0.158, Fig. 2c/d).
INTEGRATION_FACTOR = 2.0 * SAMPLES_PER_SYMBOL


def modes_from_bandwidth(bw_ghz: jax.Array) -> jax.Array:
    """Gamma degrees of freedom M for a channel of optical bandwidth bw."""
    bw = jnp.clip(bw_ghz, BW_MIN_GHZ, BW_MAX_GHZ)
    return bw / ELEC_BW_GHZ * INTEGRATION_FACTOR


def relstd_range() -> tuple[float, float]:
    """Realizable sigma/|mu| band of one channel: [1/sqrt(M_max), 1/sqrt(M_min)].

    The 25-150 GHz programmable bandwidth spans a sqrt(6) ~ 2.45x ratio in
    std -- the paper's 'change in standard deviation by about 68 percent'
    around the band center.
    """
    m_lo = BW_MIN_GHZ / ELEC_BW_GHZ * INTEGRATION_FACTOR
    m_hi = BW_MAX_GHZ / ELEC_BW_GHZ * INTEGRATION_FACTOR
    return 1.0 / m_hi ** 0.5, 1.0 / m_lo ** 0.5


def bandwidth_for_relstd(rel_std: jax.Array) -> jax.Array:
    """Invert std/mean = 1/sqrt(M): which bandwidth realizes a relative std.

    Used by the calibration loop; the requested rel_std is clipped to the
    hardware band (see ``relstd_range``).
    """
    m = 1.0 / jnp.maximum(rel_std, 1e-6) ** 2
    bw = m * ELEC_BW_GHZ / INTEGRATION_FACTOR
    return jnp.clip(bw, BW_MIN_GHZ, BW_MAX_GHZ)


class EntropySource:
    """Standard-variate sampler interface: eps has mean 0, std 1."""

    def sample(self, key: jax.Array, shape: tuple[int, ...],
               dtype=jnp.float32) -> jax.Array:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class PRNGEntropy(EntropySource):
    """Digital counter-based Gaussian baseline (threefry)."""

    def sample(self, key, shape, dtype=jnp.float32):
        return jax.random.normal(key, shape, dtype)


@dataclasses.dataclass(frozen=True)
class ASEEntropy(EntropySource):
    """Gamma(M) photocurrent statistics of a spectrally sliced ASE source.

    ``modes`` is the per-draw M; a scalar applies one bandwidth to every
    weight, an array broadcastable to ``shape`` programs per-channel
    bandwidths.  The returned variate is standardized:
        eps = (I/P - 1) * sqrt(M),  I ~ Gamma(k=M, theta=P/M)
    so mean(eps)=0, std(eps)=1, skew(eps)=2/sqrt(M) > 0 (chaotic light is
    super-Poissonian; the Gaussian SVI surrogate is exact only as M -> inf).
    """

    modes: float = 2.0 * 100.0 / ELEC_BW_GHZ  # default: 100 GHz channel

    def sample(self, key, shape, dtype=jnp.float32):
        m = jnp.asarray(self.modes, jnp.float32)
        gam = jax.random.gamma(key, jnp.broadcast_to(m, shape)) / m
        return ((gam - 1.0) * jnp.sqrt(m)).astype(dtype)


@dataclasses.dataclass(frozen=True)
class KernelEntropy(EntropySource):
    """In-kernel TPU PRNG source: entropy born and consumed in registers.

    Carries the base seed that the Pallas kernels mix with their grid
    coordinates (``pltpu.prng_seed(seed, i, j, ...)``), so every tile owns
    a distinct, replayable stream and no entropy tensor ever exists in
    HBM.  The ``*_sampled`` wrappers in ``kernels.ops`` consume
    ``self.seed`` directly; ``sample``/``key`` provide the host-side
    emulation used off-TPU and by layers that need materialized variates.

    Contract (tested): standard normal — mean 0, std 1, skew 0 (unlike
    ``ASEEntropy``'s 2/sqrt(M)) — and same seed -> same stream.
    """

    seed: int = 0

    def fold(self, *ids: int) -> jax.Array:
        """Derive a per-site int32 seed from the base seed (same mixing
        on host and device: successive fold-ins of the call-site ids)."""
        s = jnp.asarray(self.seed, jnp.uint32)
        for i in ids:
            s = s * jnp.uint32(0x9E3779B9) + jnp.asarray(i, jnp.uint32) \
                + jnp.uint32(1)
        return s.astype(jnp.int32)

    def key(self, *ids: int) -> jax.Array:
        """Host-side PRNG key for the (optionally folded) stream."""
        return jax.random.key(
            jnp.asarray(self.fold(*ids), jnp.uint32))

    def sample(self, key, shape, dtype=jnp.float32):
        """EntropySource interface: key=None draws the seed's own stream."""
        k = self.key() if key is None else key
        return jax.random.normal(k, shape, dtype)


@dataclasses.dataclass(frozen=True)
class EntropyStream:
    """Pre-drawn entropy replayed into compute kernels.

    The physical machine's randomness arrives on the optical carrier --
    the digital side never generates it.  We mirror that: a host-side ring
    buffer of standard variates is sliced per step and fed to the Pallas
    kernels as a tensor operand.  ``cursor`` advances functionally so the
    stream state can live in the train-step carry (and in checkpoints).
    """

    buffer: jax.Array          # (capacity,) standard variates
    cursor: jax.Array          # () int32

    @staticmethod
    def create(key: jax.Array, capacity: int,
               source: Optional[EntropySource] = None) -> "EntropyStream":
        src = source or ASEEntropy()
        buf = src.sample(key, (capacity,))
        return EntropyStream(buffer=buf, cursor=jnp.zeros((), jnp.int32))

    def draw(self, shape: tuple[int, ...]) -> tuple[jax.Array, "EntropyStream"]:
        n = int(np.prod(shape))
        cap = self.buffer.shape[0]
        if n > cap:
            raise ValueError(f"draw of {n} exceeds stream capacity {cap}")
        # wrap-around ring read (gather keeps it jit-safe for traced cursor)
        idx = (self.cursor + jnp.arange(n, dtype=jnp.int32)) % cap
        flat = self.buffer[idx]
        nxt = EntropyStream(self.buffer, (self.cursor + n) % cap)
        return flat.reshape(shape), nxt


def tree_flatten_stream(s: EntropyStream):
    return (s.buffer, s.cursor), None


def tree_unflatten_stream(_, children):
    return EntropyStream(*children)


jax.tree_util.register_pytree_node(
    EntropyStream, tree_flatten_stream, tree_unflatten_stream)


# -- NIST-style sanity statistics (paper cites SP 800-22 for the source) -----

def entropy_health(bits: np.ndarray) -> dict[str, float]:
    """Light-weight health tests on a bitstream (monobit, runs, chi2 bytes).

    Not the full SP 800-22 battery -- the subset that catches a dead or
    correlated source, which is what a production machine monitors online.
    """
    bits = np.asarray(bits).astype(np.uint8) & 1
    n = bits.size
    ones = float(bits.sum())
    monobit_z = abs(ones - n / 2) / np.sqrt(n / 4)
    # runs test
    pi = ones / n
    runs = 1 + int(np.sum(bits[1:] != bits[:-1]))
    runs_expected = 2 * n * pi * (1 - pi) + 1
    runs_var = 2 * n * pi * (1 - pi) * (2 * pi * (1 - pi)) if n else 1.0
    runs_z = abs(runs - runs_expected) / max(np.sqrt(max(runs_var, 1e-12)), 1e-12)
    # byte chi^2
    nbytes = n // 8
    byts = np.packbits(bits[: nbytes * 8])
    hist = np.bincount(byts, minlength=256)
    expected = nbytes / 256.0
    chi2 = float(np.sum((hist - expected) ** 2 / max(expected, 1e-12)))
    return {"monobit_z": float(monobit_z), "runs_z": float(runs_z),
            "byte_chi2": chi2, "n_bits": float(n)}


def gaussian_to_bits(eps: np.ndarray) -> np.ndarray:
    """Median-threshold bit extraction used for the health tests."""
    med = np.median(eps)
    return (np.asarray(eps) > med).astype(np.uint8)
