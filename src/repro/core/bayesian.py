"""Variational (Bayesian) parameters and layers.

The paper's BNN keeps a *single* probabilistic layer (partial stochasticity,
ref. 15) whose weights carry Gaussian variational posteriors
``q(w) = N(mu, sigma^2)`` trained with SVI against a Gaussian prior.
``sigma`` is parameterized through softplus(rho) for unconstrained
optimization (Blundell et al. 2015).

The sampled forward pass is reparameterized:  w = mu + sigma * eps, with eps
from an ``EntropySource`` -- the digital PRNG baseline, the ASE digital
twin, an explicit entropy-stream operand, or (the fast path) the in-kernel
TPU PRNG: ``KernelEntropy`` carries a base seed and the Pallas ``*_sampled``
kernels draw the variates in-register, so eps never exists in HBM.  The
same code path therefore runs the surrogate (training) and the machine
(prediction) exactly like the paper swaps its surrogate for the photonic
hardware; ``bayes_dense_sampled`` / ``mc_forward_seeded`` are the
seed-driven twins of ``bayes_dense`` / ``mc_forward``.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.entropy import EntropySource, KernelEntropy, PRNGEntropy
from repro.core.photonic import quantize_ste


def softplus(x):
    return jnp.logaddexp(x, 0.0)


def inv_softplus(y):
    return jnp.log(jnp.expm1(jnp.maximum(y, 1e-8)))


@dataclasses.dataclass(frozen=True)
class GaussianVariational:
    """q(w) = N(mu, softplus(rho)^2) over a weight tensor."""
    mu: jax.Array
    rho: jax.Array

    @property
    def sigma(self) -> jax.Array:
        return softplus(self.rho)

    @staticmethod
    def init(key: jax.Array, shape: tuple[int, ...], fan_in: int,
             init_sigma: float = 0.05, dtype=jnp.float32) -> "GaussianVariational":
        mu = jax.random.normal(key, shape, dtype) / jnp.sqrt(float(fan_in))
        rho = jnp.full(shape, inv_softplus(init_sigma), dtype)
        return GaussianVariational(mu=mu, rho=rho)

    def sample(self, key: jax.Array, source: Optional[EntropySource] = None,
               num: Optional[int] = None) -> jax.Array:
        src = source or PRNGEntropy()
        shape = self.mu.shape if num is None else (num, *self.mu.shape)
        eps = src.sample(key, shape, self.mu.dtype)
        return self.mu + self.sigma * eps

    def sample_with_eps(self, eps: jax.Array) -> jax.Array:
        """Reparameterized sample from an externally supplied entropy tensor
        (the kernel path: entropy is an operand, not generated inline)."""
        return self.mu + self.sigma * eps

    def kl_to_prior(self, prior_sigma: float = 1.0) -> jax.Array:
        """KL( N(mu, sigma) || N(0, prior_sigma) ), summed over weights."""
        s2 = self.sigma ** 2
        p2 = prior_sigma ** 2
        kl = 0.5 * (s2 / p2 + self.mu ** 2 / p2 - 1.0 - jnp.log(s2 / p2))
        return kl.sum()


jax.tree_util.register_pytree_node(
    GaussianVariational,
    lambda g: ((g.mu, g.rho), None),
    lambda _, c: GaussianVariational(*c),
)


# --------------------------------------------------------------------------
# layer applications (pure functions over a GaussianVariational + inputs)
# --------------------------------------------------------------------------

def bayes_dense(x: jax.Array, q: GaussianVariational, key: jax.Array,
                source: Optional[EntropySource] = None,
                hardware_bits: Optional[int] = None,
                w_range: float = 1.0) -> jax.Array:
    """y = x @ w, w ~ q. One weight draw per call (per MC sample).

    hardware_bits: if set, pass the sampled weights through the machine's
    STE quantizer -- the surrogate's limited-accuracy forward (paper §BNN).
    """
    w = q.sample(key, source)
    if hardware_bits is not None:
        w = quantize_ste(w, hardware_bits, w_range)
    return x @ w


def bayes_conv2d(x: jax.Array, q: GaussianVariational, key: jax.Array,
                 source: Optional[EntropySource] = None,
                 stride: int = 1, groups: int = 1,
                 hardware_bits: Optional[int] = None,
                 w_range: float = 1.0) -> jax.Array:
    """NCHW conv with sampled weights q.mu/q.sigma of shape (O, I/g, kh, kw).

    This is the layer the photonic machine executes: a 3x3 kernel has 9
    weights == the machine's 9 spectral channels; grouped convs minimize
    unique weights (paper: 'favoring highly grouped convolutions').
    """
    w = q.sample(key, source)
    if hardware_bits is not None:
        w = quantize_ste(w, hardware_bits, w_range)
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding="SAME",
        feature_group_count=groups,
        dimension_numbers=("NCHW", "OIHW", "NCHW"))


def mc_forward(apply_fn: Callable[[jax.Array], jax.Array], key: jax.Array,
               num_samples: int) -> jax.Array:
    """Run ``apply_fn(key_i)`` for N MC samples; stack on axis 0.

    apply_fn must consume a PRNG key and return class probabilities/logits.
    The paper uses N=10 samples per prediction.
    """
    keys = jax.random.split(key, num_samples)
    return jax.vmap(apply_fn)(keys)


# --------------------------------------------------------------------------
# seed-driven fast path (in-kernel entropy on TPU)
# --------------------------------------------------------------------------

def bayes_dense_sampled(x: jax.Array, q: GaussianVariational,
                        entropy: KernelEntropy, num_samples: int,
                        impl: str = "auto") -> jax.Array:
    """All S MC samples of y = x @ w, w ~ q, in one fused call: (S, M, N).

    On TPU the weight noise is generated inside the kernel from
    ``entropy.seed`` (mu/sigma tiles read once for all S samples — the
    37.5 ps/conv amortization); elsewhere the seeded oracle runs.  The
    per-sample twin is ``bayes_dense`` (one key, one draw).
    """
    from repro.kernels import ops
    return ops.bayes_matmul_sampled(x, q.mu, q.sigma, entropy.fold(),
                                    num_samples=num_samples, impl=impl)


def mc_forward_seeded(apply_fn: Callable[[jax.Array], jax.Array],
                      entropy: KernelEntropy,
                      num_samples: int) -> jax.Array:
    """Seed-driven ``mc_forward``: sample s runs on ``entropy.key(s)``.

    Deterministic per base seed (same KernelEntropy -> same prediction),
    so serving replicas with the same seed agree bit-for-bit off-TPU and
    distributionally on-TPU.
    """
    keys = jax.random.split(entropy.key(), num_samples)
    return jax.vmap(apply_fn)(keys)
