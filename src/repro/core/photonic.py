"""Digital twin of the photonic Bayesian machine (Fig. 2).

Signal chain, end to end, matching the paper's system architecture:

  1. 8-bit DAC (80 GSPS, 3 samples/symbol) encodes the input vector on a
     broadband EOM -> every frequency channel carries the same temporal
     input waveform.
  2. The ASE spectrum is shaped into NUM_CHANNELS=9 channels; channel ``k``
     carries the k-th probabilistic weight: mean from optical power,
     std from bandwidth (Gamma(M) statistics, see ``core.entropy``).
  3. The chirped grating applies a frequency-dependent group delay of
     -93.1 ps/THz == exactly one symbol (3 samples @ 80 GSPS) between
     adjacent channels (403 GHz spacing): channel k sees x[t-k].
  4. The photodetector sums all channels:  y[t] = sum_k w_k(t) * x[t-k]
     -- a 9-tap convolution whose taps are *fresh random draws per output
     sample* (the chaotic carrier decorrelates between symbols).
  5. 8-bit ADC digitizes y.

The machine is programmed per channel with (power, bandwidth); the
calibration loop (`calibrate`) reproduces the paper's iterative
feedback-based update rule: run test convolutions, compare measured output
moments with targets, correct the per-channel settings.

Everything is functional JAX so the twin can sit inside jit-ted eval loops;
the analog imperfections (quantization, detector noise, finite calibration)
reproduce the paper's measured computation errors (~0.158 on the output
mean, ~0.266 on the output std, Fig. 2c/d).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import entropy as E


# --------------------------------------------------------------------------
# quantization (8-bit DAC / ADC) with straight-through estimators
# --------------------------------------------------------------------------

def quantize_ste(x: jax.Array, bits: int, x_max: float) -> jax.Array:
    """Uniform symmetric quantizer with a straight-through gradient.

    The paper trains the surrogate with STEs so the forward pass sees the
    8-bit DAC/ADC grid while gradients flow as identity.
    """
    levels = 2 ** (bits - 1) - 1
    scale = x_max / levels
    xq = jnp.clip(jnp.round(x / scale), -levels, levels) * scale
    return x + jax.lax.stop_gradient(xq - x)


# --------------------------------------------------------------------------
# machine state
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MachineConfig:
    num_channels: int = E.NUM_CHANNELS
    dac_bits: int = E.DAC_BITS
    adc_bits: int = E.ADC_BITS
    input_range: float = 1.0          # EOM drive normalized to [-1, 1]
    output_range: float = 4.0         # photodetector + TIA full scale
    weight_range: float = 1.0         # |w| realizable per channel
    detector_noise: float = 5e-3      # thermal+shot noise floor (rel. FS)
    programming_gain: float = 0.6     # feedback step size of calibration
    gaussian_surrogate: bool = False  # True -> Gaussian eps (surrogate mode)
    # analog impairments (Fig. 2c/d error budget)
    crosstalk: float = 0.04           # adjacent-channel leakage (grating sidelobes)
    eom_mod_depth: float = 0.75       # residual sin() nonlinearity after linearization
    drift_std: float = 0.03           # slow power drift between calibration and use
    # bandwidth-axis impairments: the waveshaper programs channel bandwidth
    # on a discrete setpoint grid and its filter edges wander shot to shot;
    # both hit sigma (prop. 1/sqrt(BW)) while leaving the power (mean) axis
    # untouched -- the asymmetry behind the paper's std error (0.266)
    # exceeding its mean error (0.158).
    bw_quant_ghz: float = 12.5        # waveshaper setpoint granularity
    bw_jitter_std: float = 0.05       # fractional filter-edge jitter per shot


@dataclasses.dataclass(frozen=True)
class ChannelProgram:
    """Per-channel analog settings, the machine's 'weights register'."""
    power: jax.Array      # (C,)  differential optical power -> weight mean
    bandwidth: jax.Array  # (C,)  GHz -> weight std via Gamma modes

    def moments(self, bandwidth: Optional[jax.Array] = None
                ) -> tuple[jax.Array, jax.Array]:
        """Weight moments for a bandwidth (default: the programmed
        setpoint -- the controller's ideal model; the plant passes the
        *effective* bandwidth it realizes, see ``effective_bandwidth``)."""
        m = E.modes_from_bandwidth(self.bandwidth if bandwidth is None
                                   else bandwidth)
        mu = self.power
        # std of the detected weight: |power|/sqrt(M); the differential
        # reference arm carries the sign but both arms fluctuate.
        sigma = jnp.abs(self.power) / jnp.sqrt(m)
        return mu, sigma


jax.tree_util.register_pytree_node(
    ChannelProgram,
    lambda p: ((p.power, p.bandwidth), None),
    lambda _, c: ChannelProgram(*c),
)


def program_for_target(mu: jax.Array, sigma: jax.Array,
                       cfg: MachineConfig = MachineConfig()) -> ChannelProgram:
    """Open-loop programming: invert the moment maps (no feedback yet)."""
    mu = jnp.clip(mu, -cfg.weight_range, cfg.weight_range)
    rel = sigma / jnp.maximum(jnp.abs(mu), 1e-3)
    bw = E.bandwidth_for_relstd(rel)
    return ChannelProgram(power=mu, bandwidth=bw)


# --------------------------------------------------------------------------
# the analog forward pass
# --------------------------------------------------------------------------

def effective_bandwidth(key: jax.Array, bw_ghz: jax.Array,
                        cfg: MachineConfig = MachineConfig()) -> jax.Array:
    """Bandwidth the filter actually realizes for a programmed setpoint.

    The waveshaper snaps the request to its setpoint grid (``bw_quant_ghz``)
    and its filter edges wander between shots (``bw_jitter_std``, fractional).
    The controller's moment model (``ChannelProgram.moments``) stays ideal:
    feedback calibration sees these imperfections only through measured
    output moments, which is why they survive as residual sigma error.
    """
    bw = bw_ghz
    if cfg.bw_quant_ghz > 0:
        bw = jnp.round(bw / cfg.bw_quant_ghz) * cfg.bw_quant_ghz
    if cfg.bw_jitter_std > 0:
        jit = 1.0 + cfg.bw_jitter_std * jax.random.normal(
            key, jnp.shape(bw))
        bw = bw * jnp.maximum(jit, 0.1)
    return jnp.clip(bw, E.BW_MIN_GHZ, E.BW_MAX_GHZ)


def sample_weights(key: jax.Array, prog: ChannelProgram, shape: tuple[int, ...],
                   cfg: MachineConfig = MachineConfig()) -> jax.Array:
    """Draw physical weights w ~ machine(prog), fresh per output symbol.

    shape is appended in front of the channel axis:  (*shape, C).
    """
    bw = effective_bandwidth(jax.random.fold_in(key, 0xB4D), prog.bandwidth,
                             cfg)
    mu, sigma = prog.moments(bandwidth=bw)
    if cfg.gaussian_surrogate:
        eps = jax.random.normal(key, (*shape, mu.shape[-1]))
    else:
        m = jnp.broadcast_to(E.modes_from_bandwidth(bw),
                             (*shape, mu.shape[-1]))
        gam = jax.random.gamma(key, m) / m
        eps = (gam - 1.0) * jnp.sqrt(m)
    return mu + sigma * eps


def convolve(key: jax.Array, x: jax.Array, prog: ChannelProgram,
             cfg: MachineConfig = MachineConfig()) -> jax.Array:
    """One analog pass: y[t] = sum_k w_k[t] * x[t - k]  (valid region).

    x: (..., T) input waveform in [-input_range, input_range].
    returns (..., T - C + 1) probabilistic convolution outputs, each output
    sample computed with an independent draw of the 9 weights (the chaotic
    carrier decorrelates between symbols; paper Fig. 1c).
    """
    C = cfg.num_channels
    xq = quantize_ste(x, cfg.dac_bits, cfg.input_range)  # DAC
    if cfg.eom_mod_depth > 0:
        # EOM sin() transfer, digitally linearized up to residual curvature
        a = cfg.eom_mod_depth * jnp.pi / 2
        xq = jnp.sin(a * xq) / jnp.sin(a)
    T = x.shape[-1]
    To = T - C + 1
    # frequency-dependent group delay == stack of shifted copies (im2col)
    idx = jnp.arange(To)[:, None] + jnp.arange(C)[None, :]  # (To, C)
    taps = xq[..., idx]                                     # (..., To, C)
    if cfg.crosstalk > 0:
        # grating sidelobes leak a tap onto its neighbours' delays
        c = cfg.crosstalk
        left = jnp.roll(taps, 1, axis=-1).at[..., 0].set(0.0)
        right = jnp.roll(taps, -1, axis=-1).at[..., -1].set(0.0)
        taps = (1 - c) * taps + 0.5 * c * (left + right)
    if cfg.drift_std > 0:
        dkey = jax.random.fold_in(key, 0xD41F7)
        drift = 1.0 + cfg.drift_std * jax.random.normal(
            dkey, (cfg.num_channels,))
        prog = ChannelProgram(power=prog.power * drift,
                              bandwidth=prog.bandwidth)
    w = sample_weights(key, prog, (*x.shape[:-1], To), cfg) # (..., To, C)
    y = jnp.sum(taps * w[..., ::-1], axis=-1)               # photodetector
    if cfg.detector_noise > 0:
        nkey = jax.random.fold_in(key, 0x5EED)
        y = y + cfg.detector_noise * cfg.output_range * \
            jax.random.normal(nkey, y.shape)
    return quantize_ste(y, cfg.adc_bits, cfg.output_range)  # ADC


def conv_throughput_estimate(cfg: MachineConfig = MachineConfig()) -> dict:
    """Paper: 80 GSPS / 3 samples-per-symbol ~ 26.7e9 prob-conv/s; 37.5 ps."""
    sps = 80e9 / E.SAMPLES_PER_SYMBOL
    return {"conv_per_s": sps, "latency_ps": E.CONV_LATENCY_PS,
            "interface_tbit_s": 2 * 80e9 * 8 / 1e12}


# --------------------------------------------------------------------------
# feedback-based calibration (paper: iterative programming, Supp. S8)
# --------------------------------------------------------------------------

def measure_moments(key: jax.Array, prog: ChannelProgram, n_shots: int,
                    cfg: MachineConfig = MachineConfig()) -> tuple[jax.Array, jax.Array]:
    """Estimate per-channel weight moments from test convolutions.

    Probe with unit impulses on each tap position (the machine measures the
    output distribution of known test inputs, not the weights directly).
    """
    C = cfg.num_channels
    # impulse probe per channel: x_k = e_k  ->  y = w_k
    eye = jnp.eye(C)
    probes = jnp.pad(eye, ((0, 0), (C - 1, C - 1)))  # (C, T)
    keys = jax.random.split(key, n_shots)

    def shot(k):
        return convolve(k, probes, prog, cfg)  # (C, To)

    ys = jax.vmap(shot)(keys)                   # (S, C, To)
    # probe row k has its impulse at padded position C-1+k, so output column
    # C-1 of that row reads tap w_{C-1-k}; flip to recover channel order.
    vals = ys[..., C - 1][:, ::-1]               # (S, C) in channel order
    return vals.mean(0), vals.std(0)


def calibrate(key: jax.Array, target_mu: jax.Array, target_sigma: jax.Array,
              iters: int = 12, n_shots: int = 256,
              cfg: MachineConfig = MachineConfig()) -> tuple[ChannelProgram, dict]:
    """Iterative feedback programming against target (mu, sigma).

    update rule (paper, Supplementary):
        power     <- power     - g * (mu_meas    - mu_target)
        bandwidth <- bandwidth * (sigma_meas / sigma_target)^(2g)
    (bandwidth acts on sigma as 1/sqrt(BW): halving sigma needs 4x BW).
    """
    prog = program_for_target(target_mu, target_sigma, cfg)
    g = cfg.programming_gain
    history = {"mu_err": [], "sigma_err": []}

    for i in range(iters):
        key, mk = jax.random.split(key)
        mu_m, sg_m = measure_moments(mk, prog, n_shots, cfg)
        mu_err = mu_m - target_mu
        ratio = jnp.clip(sg_m / jnp.maximum(target_sigma, 1e-4), 0.25, 4.0)
        prog = ChannelProgram(
            power=jnp.clip(prog.power - g * mu_err,
                           -cfg.weight_range, cfg.weight_range),
            bandwidth=jnp.clip(prog.bandwidth * ratio ** (2 * g),
                               E.BW_MIN_GHZ, E.BW_MAX_GHZ),
        )
        history["mu_err"].append(float(jnp.abs(mu_err).mean()))
        history["sigma_err"].append(
            float(jnp.abs(sg_m - target_sigma).mean()))
    return prog, history


def computation_error(key: jax.Array, n_kernels: int = 25, n_shots: int = 512,
                      seq_len: int = 64,
                      cfg: MachineConfig = MachineConfig()) -> dict:
    """Reproduce Fig. 2(c,d): normalized error of output mean and std.

    For ``n_kernels`` random probabilistic kernels, compare the measured
    output distribution of random input waveforms against the analytic
    target and report RMS errors normalized by the target output std range
    (the paper's Eq. S8 convention).
    """
    errs_mu, errs_sg = [], []
    for i in range(n_kernels):
        key, k1, k2, k3, k4 = jax.random.split(key, 5)
        mu_t = jax.random.uniform(k1, (cfg.num_channels,), minval=-0.8,
                                  maxval=0.8)
        sg_t = jnp.abs(mu_t) * jax.random.uniform(
            k2, (cfg.num_channels,), minval=0.12, maxval=0.28)
        prog, _ = calibrate(k3, mu_t, sg_t, iters=8, n_shots=128, cfg=cfg)
        x = jax.random.uniform(k4, (seq_len,), minval=-1.0, maxval=1.0)
        keys = jax.random.split(jax.random.fold_in(key, i), n_shots)
        ys = jax.vmap(lambda k: convolve(k, x, prog, cfg))(keys)  # (S, To)
        C = cfg.num_channels
        idx = jnp.arange(x.shape[-1] - C + 1)[:, None] + jnp.arange(C)
        taps = x[idx]
        y_mu_t = taps @ mu_t[::-1]
        y_sg_t = jnp.sqrt(taps ** 2 @ (sg_t[::-1] ** 2))
        scale = jnp.maximum(y_sg_t.mean(), 1e-6)
        errs_mu.append(float(jnp.sqrt(jnp.mean(
            (ys.mean(0) - y_mu_t) ** 2)) / (4 * scale)))
        errs_sg.append(float(jnp.sqrt(jnp.mean(
            (ys.std(0) - y_sg_t) ** 2)) / scale))
    return {"mean_error": float(jnp.mean(jnp.array(errs_mu))),
            "std_error": float(jnp.mean(jnp.array(errs_sg))),
            "paper_mean_error": 0.158, "paper_std_error": 0.266}
