"""Core paper contribution: photonic Bayesian machine + SVI + uncertainty."""

from repro.core import bayesian, entropy, photonic, svi, uncertainty  # noqa: F401
from repro.core.bayesian import GaussianVariational, mc_forward  # noqa: F401
from repro.core.entropy import (  # noqa: F401
    ASEEntropy, EntropySource, EntropyStream, PRNGEntropy)
from repro.core.photonic import (  # noqa: F401
    ChannelProgram, MachineConfig, calibrate, computation_error, convolve,
    program_for_target, quantize_ste)
from repro.core.svi import SVIConfig, elbo_loss, kl_divergence  # noqa: F401
from repro.core.uncertainty import (  # noqa: F401
    auroc, predictive_moments, rejection_accuracy, roc_curve,
    uncertainty_from_logits)
