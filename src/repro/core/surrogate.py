"""Differentiable surrogate of the photonic Bayesian machine (paper §BNN).

Training never touches the analog hardware: the paper trains against a
Gaussian surrogate whose forward pass mimics the machine's limited accuracy
via straight-through estimators, then swaps the surrogate for the machine
at prediction time.  This module is that surrogate, plus the hardware-
realizability constraints the machine imposes on the variational family:

  * sigma is representable only inside the relative-std band set by the
    25-150 GHz programmable channel bandwidth (``entropy.relstd_range``);
    the surrogate clamps sigma into the realizable band *with an STE* so
    SVI gradients keep shaping rho while the forward pass is honest.
  * weights pass the 8-bit DAC grid (STE quantization);
  * activations pass the 8-bit DAC (inputs) and ADC (outputs) grids.

``SurrogateSpec.apply_weight`` is used by the Bayesian layers during
training; at prediction `models.bnn_cnn` routes the probabilistic block
through ``core.photonic.convolve`` (the digital twin) or the fused Pallas
kernel instead.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import entropy as E
from repro.core.bayesian import GaussianVariational
from repro.core.photonic import MachineConfig, quantize_ste


def ste_clip(x: jax.Array, lo: jax.Array, hi: jax.Array) -> jax.Array:
    """clip with identity gradient (keeps SVI gradients alive at the rails)."""
    return x + jax.lax.stop_gradient(jnp.clip(x, lo, hi) - x)


@dataclasses.dataclass(frozen=True)
class SurrogateSpec:
    machine: MachineConfig = MachineConfig()
    quantize_weights: bool = True
    clamp_sigma: bool = True
    quantize_activations: bool = True

    def realizable_sigma(self, mu: jax.Array, sigma: jax.Array) -> jax.Array:
        """Project sigma into the machine's per-channel band.

        sigma in [r_lo * |mu|, r_hi * |mu|] with (r_lo, r_hi) from the
        bandwidth range; |mu| floor keeps near-zero weights programmable.
        """
        r_lo, r_hi = E.relstd_range()
        a = jnp.maximum(jnp.abs(mu), 2.0 / (2 ** self.machine.dac_bits))
        return ste_clip(sigma, r_lo * a, r_hi * a)

    def apply_weight(self, q: GaussianVariational, eps: jax.Array) -> jax.Array:
        """Surrogate forward draw: reparam + hardware constraints w/ STE."""
        sigma = q.sigma
        if self.clamp_sigma:
            sigma = self.realizable_sigma(q.mu, sigma)
        w = q.mu + sigma * eps
        if self.quantize_weights:
            w = quantize_ste(w, self.machine.dac_bits,
                             self.machine.weight_range)
        return w

    def apply_input(self, x: jax.Array) -> jax.Array:
        if not self.quantize_activations:
            return x
        return quantize_ste(x, self.machine.dac_bits,
                            self.machine.input_range)

    def apply_output(self, y: jax.Array) -> jax.Array:
        if not self.quantize_activations:
            return y
        return quantize_ste(y, self.machine.adc_bits,
                            self.machine.output_range)
