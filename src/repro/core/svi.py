"""Stochastic Variational Inference training (Hoffman et al. 2013; paper §BNN).

ELBO for a partially-stochastic network with variational block q(theta_s)
and deterministic weights theta_d:

    L = E_q[ log p(y | x, theta_s, theta_d) ] - beta * KL( q || p )

with the KL computed in closed form for Gaussian q against a Gaussian
prior, the expectation estimated with ``train_mc_samples`` reparameterized
draws, and ``beta`` annealed (KL warm-up) and scaled 1/num_train_examples
(per-example ELBO, the standard Pyro convention the paper uses).

The module is model-agnostic: models expose
    loss_fn(params, batch, key) -> (nll, aux)
and declare their variational leaves via ``is_variational`` (any
GaussianVariational in the params pytree).  ``elbo_loss`` adds the KL of
every variational leaf.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.bayesian import GaussianVariational


@dataclasses.dataclass(frozen=True)
class SVIConfig:
    prior_sigma: float = 1.0
    kl_warmup_steps: int = 500        # beta: 0 -> 1 linearly
    num_train_examples: int = 60_000  # ELBO 1/N scaling
    train_mc_samples: int = 1         # MC draws per training step


def kl_divergence(params: Any, prior_sigma: float = 1.0) -> jax.Array:
    """Sum KL(q||p) over every GaussianVariational leaf in the pytree."""
    total = jnp.zeros(())
    for leaf in jax.tree_util.tree_leaves(
            params, is_leaf=lambda x: isinstance(x, GaussianVariational)):
        if isinstance(leaf, GaussianVariational):
            total = total + leaf.kl_to_prior(prior_sigma)
    return total


def kl_beta(step: jax.Array, cfg: SVIConfig) -> jax.Array:
    """Linear KL warm-up; beta in [0, 1]."""
    return jnp.clip(step / jnp.maximum(cfg.kl_warmup_steps, 1), 0.0, 1.0)


def elbo_loss(nll_fn: Callable[[Any, Any, jax.Array], tuple[jax.Array, dict]],
              params: Any, batch: Any, key: jax.Array, step: jax.Array,
              cfg: SVIConfig) -> tuple[jax.Array, dict]:
    """Negative per-example ELBO = NLL + beta * KL / N_train.

    nll_fn returns the *mean per-example* negative log likelihood; MC
    averaging over ``train_mc_samples`` reparameterized draws.
    """
    keys = jax.random.split(key, cfg.train_mc_samples)
    nlls, aux = jax.vmap(lambda k: nll_fn(params, batch, k))(keys)
    nll = nlls.mean()
    kl = kl_divergence(params, cfg.prior_sigma)
    beta = kl_beta(step, cfg)
    loss = nll + beta * kl / cfg.num_train_examples
    aux = jax.tree.map(lambda a: a.mean(0), aux)
    aux.update({"nll": nll, "kl": kl, "beta": beta})
    return loss, aux
