"""Sharding rules: param-path -> PartitionSpec, activation constraints.

Logical mesh axes:
  * ``pod``   -- inter-pod data parallelism (multi-pod mesh only)
  * ``data``  -- intra-pod data parallelism; also the FSDP shard axis for
                 large-arch weights (ZeRO-3 style via GSPMD)
  * ``model`` -- tensor parallelism (heads / ff / vocab / experts)

Rules are name-based over the flattened param path, so every architecture
in the zoo gets coherent sharding without per-model boilerplate.  Stacked
scan layers contribute a leading ``L`` axis which is never sharded.
"""

from __future__ import annotations

import re
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.bayesian import GaussianVariational

# ---------------------------------------------------------------------------
# activation-constraint context (set by launch scripts, no-op otherwise)
# ---------------------------------------------------------------------------

_ctx = threading.local()


def set_mesh_context(mesh: Optional[Mesh]) -> None:
    _ctx.mesh = mesh
    _ctx.batch_axes = None
    if mesh is not None:
        axes = mesh.axis_names
        _ctx.batch_axes = tuple(a for a in ("pod", "data") if a in axes)


def get_mesh() -> Optional[Mesh]:
    return getattr(_ctx, "mesh", None)


def constrain_seq(x: jax.Array, enabled: bool = True) -> jax.Array:
    """Sequence-parallel residual stream: shard (B, S, d) activations'
    S over 'model' (Korthikanti et al.): the attention/MLP row-parallel
    all-reduce becomes reduce-scatter + all-gather (same link bytes) and
    every saved-for-backward residual shrinks by the TP width — the
    capacity fix that keeps 64-layer remat stacks inside HBM
    (EXPERIMENTS.md §Perf/grok iteration 6).

    No-op when S doesn't divide the model axis (decode steps, tests) or
    when the arch opts out (``ArchConfig.seq_parallel``).
    """
    mesh = get_mesh()
    if not enabled:
        return x
    if mesh is None or "model" not in mesh.axis_names or x.ndim != 3:
        return x
    if x.shape[1] % mesh.shape["model"] != 0:
        return x
    return constrain(x, "batch", "model", None)


def constrain(x: jax.Array, *spec) -> jax.Array:
    """with_sharding_constraint if a mesh context is active, else no-op.

    spec entries: "batch" expands to the active DP axes tuple, "model"
    passes through, None is unsharded.
    """
    mesh = get_mesh()
    if mesh is None:
        return x
    resolved = tuple(
        (_ctx.batch_axes if s == "batch" else s) for s in spec)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*resolved)))


# ---------------------------------------------------------------------------
# serving tensor-parallel context (set by launch.engine.runner.ModelRunner)
# ---------------------------------------------------------------------------
#
# Serving TP is ALL-GATHER-ONLY: every weight whose contraction dim the
# train rules shard (wo, w2, out_proj, embed-on-vocab, experts) stays
# REPLICATED at serve time, and the few activations that feed a
# contraction over a sharded dim are force-replicated (``gather_rep``)
# first.  The reason is bitwise: a row-parallel matmul ends in an
# all-reduce of per-shard PARTIAL SUMS, and float addition is not
# associative — the sharded engine would drift from the unsharded
# reference in the last ulp.  A forced all-gather is pure data movement
# (no cross-shard reduction ever happens), so every f32 sum runs at full
# extent on every device and the sharded runner replays the unsharded
# token/uncertainty stream bit-for-bit in operand-entropy mode
# (tests/test_mesh_runner.py).  The context is separate from the train
# mesh context above so training sharding is unaffected.

_serve_ctx = threading.local()


def set_serve_mesh(mesh: Optional[Mesh]) -> None:
    """Activate (or clear) the serving-TP mesh for the calling thread.

    ``launch.engine.runner.ModelRunner`` sets this around every jitted
    dispatch so the constraints below bake into the traced program; the
    model code itself never knows whether it is running sharded.

    CAVEAT: the mesh is hidden state that jax's trace cache cannot see.
    Tracing the SAME function object with the same avals first without
    and then with a mesh reuses the meshless jaxpr — every
    ``gather_rep`` silently a no-op in the "sharded" run.  Jit a fresh
    function object (closure/lambda) per mesh context, as
    ``ModelRunner._jit`` does with its per-instance lambdas.
    """
    _serve_ctx.mesh = mesh


def get_serve_mesh() -> Optional[Mesh]:
    return getattr(_serve_ctx, "mesh", None)


def gather_rep(x: jax.Array) -> jax.Array:
    """Force ``x`` to replicated under the serve mesh (no-op otherwise).

    Placed DIRECTLY on the output of each column-sharded matmul (q/k/v
    projections, MLP w1/w3, head mu/rho dots).  Without the constraint
    GSPMD is free to keep the operand sharded into the downstream
    contraction and emit a partial-sum all-reduce, which is not bitwise
    stable; with it the all-gather moves bytes but never reassociates a
    float sum.  Placement matters: a gather deferred past the
    elementwise tail (activation, bias, softcap) gets the elementwise
    ops sunk across it by the partitioner, parking the all-gather
    adjacent to the next dot/reduction — which XLA then still splits
    into per-shard partial sums.  Adjacent to the producer, every
    consumer sees a plain replicated operand and compiles to the same
    single-device reduction as the unsharded module.

    Two sharded shapes never need a gather at all: a BATCH dim of a dot
    (the kv-head axis of the paged pool in ``decode_attention``) keeps
    each per-row reduction at full extent, and elementwise ops on
    identically-sharded operands (bias adds) are exact per shard.
    """
    mesh = get_serve_mesh()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P()))


# Serving param rules: column-parallel shards only.  Axes listed here are
# all OUTPUT (free) dims of their matmuls — attention/ff/vocab columns —
# so each device computes exact full-precision columns and no collective
# ever reduces.  Everything else (wo, w2, embed, experts, router, ssm
# mixers, norms) replicates; ``serve_pspecs`` falls back to replication
# per-dim when a shape doesn't divide the mesh (``sanitize_pspecs``).
_SERVE_RULES: list[tuple[str, dict[int, tuple]]] = [
    # MoE experts / router / shared-expert stacks ("shared/w1", not the
    # hybrid "shared/attn" block) and every ssm mixer stay replicated:
    # their contractions (expert-combine sum over E, ssm recurrence)
    # would cross shards.  Matched FIRST so the w1/w3/head column rules
    # below cannot reach into these subtrees.
    (r"(experts_|router|shared/w|in_proj|out_proj|conv_|A_log|D$|dt_)",
     {}),
    (r"head.*(mu|rho|w)$", {2: (None, "model")}),     # vocab columns
    (r"(wq|wk|wv)$", {2: (None, "model")}),           # head columns
    (r"(bq|bk|bv)$", {1: ("model",)}),
    (r"(w1|w3)$", {2: (None, "model")}),              # ff columns
    (r".*", {}),
]


def _serve_spec_for(path: str, ndim: int) -> P:
    for pat, table in _SERVE_RULES:
        if re.search(pat, path):
            dims = table.get(ndim)
            if dims is None:
                for nd, d in table.items():
                    if nd < ndim:
                        dims = (None,) * (ndim - nd) + d
                        break
            return P(*dims) if dims is not None else P()
    return P()


def serve_pspecs(params: Any) -> Any:
    """All-gather-only serving-TP PartitionSpec tree for ``params``.

    Same name-based machinery as ``param_pspecs`` but over
    ``_SERVE_RULES``: only column-parallel dims shard, so the sharded
    decode stays bitwise equal to the unsharded reference (see the
    module comment above).  Callers sanitize against the actual mesh
    (``sanitize_pspecs``) before building shardings.
    """

    def spec_leaf(path, leaf):
        if isinstance(leaf, GaussianVariational):
            s = _serve_spec_for(path + "/mu", leaf.mu.ndim)
            return GaussianVariational(mu=s, rho=s)  # type: ignore
        return _serve_spec_for(path, getattr(leaf, "ndim", 0))

    def walk(path, node):
        if isinstance(node, GaussianVariational):
            return spec_leaf(path, node)
        if isinstance(node, dict):
            return {k: walk(f"{path}/{k}", v) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            t = [walk(f"{path}/{i}", v) for i, v in enumerate(node)]
            return type(node)(t)
        return spec_leaf(path, node)

    return walk("", params)


def serve_shardings_for(params: Any, mesh: Mesh) -> Any:
    """NamedSharding tree placing ``params`` for the serving runner."""
    specs = sanitize_pspecs(serve_pspecs(params), params, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# parameter partition rules
# ---------------------------------------------------------------------------

# (path regex, ndim -> PartitionSpec dims for the trailing ndim axes).
# FSDP ('data') is applied on the non-'model' big axis when fsdp=True.
_RULES: list[tuple[str, dict[int, tuple]]] = [
    # embeddings: vocab on model, d_model FSDP.  (A d_model-on-model
    # layout would keep the token gather local, but XLA's gather
    # partitioner emits an invalid dynamic-slice for it (verifier
    # failure, see EXPERIMENTS.md §Perf/grok iteration 3 — refuted);
    # the vocab-sharded gather costs one table AG per microbatch.)
    (r"embed.*table$", {2: ("model", "data")}),
    # bayesian / plain head: d_model REPLICATED (contraction dim), vocab
    # sharded over both axes.  FSDP on the contraction dim turned the
    # head matmul into partial sums + an all-reduce of the full (B, S,
    # vocab) logits (17 GB/microbatch for grok) — §Perf/grok iteration 2.
    (r"head.*(mu|rho|w)$", {2: (None, ("data", "model"))}),
    # attention projections
    (r"(wq|wk|wv)$", {2: ("data", "model")}),
    (r"wo$", {2: ("model", "data")}),
    (r"(bq|bk|bv)$", {1: ("model",)}),
    # dense mlp
    (r"(w1|w3)$", {2: ("data", "model")}),
    (r"w2$", {2: ("model", "data")}),
    # MoE experts, EP layout: experts on model axis, ff FSDP
    (r"experts_ep.*(w1|w3)$", {3: ("model", None, "data")}),
    (r"experts_ep.*w2$", {3: ("model", "data", None)}),
    # MoE experts, TP layout (num_experts < model axis): column-parallel
    # w1/w3 and row-parallel w2 over ff (Megatron), FSDP share on ff.
    # FSDP on the d_model contraction dim forced an all-reduce of the
    # full (E, C, ff) activations per layer — §Perf/grok iteration 1.
    (r"experts_tp.*(w1|w3)$", {3: (None, None, ("data", "model"))}),
    (r"experts_tp.*w2$", {3: (None, ("data", "model"), None)}),
    (r"router.*w$", {2: (None, None)}),
    # mamba2
    (r"in_proj$", {2: ("data", "model")}),
    (r"out_proj$", {2: ("model", "data")}),
    (r"(conv_w|conv_b|A_log|D|dt_bias)$", {1: ("model",), 2: (None, "model")}),
    # norms / scalars: replicated
    (r".*", {}),
]


def _spec_for(path: str, ndim: int, fsdp: bool,
              pod_fsdp: bool = False) -> P:
    def expand(d):
        """'data' -> ('pod','data') when ZeRO spans the pod (DCN) axis."""
        if not pod_fsdp:
            return d
        if d == "data":
            return ("pod", "data")
        if isinstance(d, tuple):
            return tuple(x for e in d for x in
                         (("pod", "data") if e == "data" else (e,)))
        return d

    for pat, table in _RULES:
        if re.search(pat, path):
            dims = table.get(ndim)
            if dims is None:
                # stacked-layer leading axes: match on trailing dims
                for nd, d in table.items():
                    if nd < ndim:
                        dims = (None,) * (ndim - nd) + d
                        break
            if dims is None:
                return P()
            if not fsdp:
                dims = tuple(None if d == "data" else d for d in dims)
            dims = tuple(expand(d) for d in dims)
            return P(*dims)
    return P()


def _flatten_with_paths(tree: Any):
    flat = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: isinstance(x, GaussianVariational))[0]
    out = []
    for kp, leaf in flat:
        path = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        out.append((path, leaf))
    return out


def param_pspecs(params: Any, fsdp: bool = True,
                 pod_fsdp: bool = False) -> Any:
    """PartitionSpec pytree matching ``params`` (GaussianVariational leaves
    get identical specs for mu and rho)."""

    def spec_leaf(path, leaf):
        if isinstance(leaf, GaussianVariational):
            s = _spec_for(path + "/mu", leaf.mu.ndim, fsdp, pod_fsdp)
            return GaussianVariational(mu=s, rho=s)  # type: ignore
        return _spec_for(path, getattr(leaf, "ndim", 0), fsdp, pod_fsdp)

    paths = {id(leaf): p for p, leaf in _flatten_with_paths(params)}

    def walk(path, node):
        if isinstance(node, GaussianVariational):
            return spec_leaf(path, node)
        if isinstance(node, dict):
            return {k: walk(f"{path}/{k}", v) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            t = [walk(f"{path}/{i}", v) for i, v in enumerate(node)]
            return type(node)(t)
        return spec_leaf(path, node)

    return walk("", params)


def sanitize_pspecs(specs: Any, shapes: Any, mesh: Mesh) -> Any:
    """Drop mesh axes from any spec dim that does not divide the shape.

    Published vocab sizes are not always mesh-divisible (mamba2 50280,
    seamless 256206); GSPMD handles uneven sharding for constraints but
    ``jit(in_shardings=...)`` requires exact divisibility, so those dims
    fall back to replication.  This keeps the name-rules table clean and
    the fallback decision local to the actual (shape, mesh) pair.
    """

    def fix(spec, shaped):
        if not isinstance(spec, P):
            return spec
        shape = getattr(shaped, "shape", None)
        if shape is None:
            return spec
        dims = list(spec) + [None] * (len(shape) - len(spec))
        out = []
        for size, d in zip(shape, dims):
            if d is None:
                out.append(None)
                continue
            axes = (d,) if isinstance(d, str) else tuple(d)
            n = 1
            for a in axes:
                n *= mesh.shape[a]
            out.append(d if (n and size % n == 0) else None)
        return P(*out)

    return jax.tree.map(
        fix, specs, shapes,
        is_leaf=lambda x: isinstance(x, P))


def shardings_for(params: Any, mesh: Mesh, fsdp: bool = True) -> Any:
    specs = sanitize_pspecs(param_pspecs(params, fsdp), params, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
