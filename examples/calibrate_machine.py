"""Calibrate the photonic Bayesian machine (paper Supp. S8).

Shows the iterative feedback programming loop: target weight
distributions (mu_k, sigma_k) per spectral channel -> measure output
moments with test convolutions -> correct per-channel power & bandwidth.

  PYTHONPATH=src python examples/calibrate_machine.py
"""

import jax
import jax.numpy as jnp

from repro.core import entropy as E
from repro.core import photonic as PH


def main():
    key = jax.random.key(0)
    # a realistic 9-tap probabilistic kernel
    target_mu = jnp.array([0.62, -0.35, 0.18, 0.77, -0.52,
                           0.41, -0.11, 0.29, -0.66])
    target_sigma = jnp.abs(target_mu) * jnp.array(
        [0.15, 0.22, 0.30, 0.12, 0.18, 0.25, 0.35, 0.20, 0.14])

    lo, hi = E.relstd_range()
    print("photonic Bayesian machine calibration (paper Supp. S8)")
    print(f"  programmable sigma/|mu| band: [{lo:.3f}, {hi:.3f}]  "
          f"(25-150 GHz channel bandwidth)")
    print(f"  9 channels @ 403 GHz spacing around 194 THz\n")

    prog, hist = PH.calibrate(key, target_mu, target_sigma,
                              iters=12, n_shots=512)
    print("  iter   |mu error|   |sigma error|")
    for i, (em, es) in enumerate(zip(hist["mu_err"], hist["sigma_err"])):
        print(f"  {i:4d}   {em:9.5f}    {es:9.5f}")

    mu_m, sg_m = PH.measure_moments(jax.random.key(1), prog, 2048)
    print("\n  channel   target mu  measured   target sg  measured   bw GHz")
    for k in range(9):
        print(f"  {k:5d}     {float(target_mu[k]):+8.3f}  "
              f"{float(mu_m[k]):+8.3f}   {float(target_sigma[k]):8.3f}  "
              f"{float(sg_m[k]):8.3f}   {float(prog.bandwidth[k]):6.1f}")

    t = PH.conv_throughput_estimate()
    print(f"\n  rated: {t['conv_per_s'] / 1e9:.1f}G prob-conv/s, "
          f"{t['latency_ps']} ps latency, "
          f"{t['interface_tbit_s']:.2f} Tbit/s digital interface")


if __name__ == "__main__":
    main()
