"""Blood-cell classification with OOD rejection (paper Fig. 4).

Full experiment: train on 7 ID cell classes, deploy with erythroblast
(held-out cell type) mixed in, use Mutual Information to reject unknown
cells and report the ROC/AUROC + confusion behaviour.

  PYTHONPATH=src python examples/blood_cell_ood.py
"""

import sys
import os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.bench_bloodcell import train_bnn
from repro.core.uncertainty import (auroc, predictive_moments, roc_curve,
                                    rejection_accuracy)
from repro.data import synthetic as D
from repro.models import bnn_cnn as B

CLASS_NAMES = ["basophil", "eosinophil", "imm.granulocyte", "lymphocyte",
               "monocyte", "neutrophil", "platelet"]


def main():
    rng = np.random.default_rng(0)
    cfg = B.BNNConfig(num_classes=7, in_channels=3, width=16)
    print("training the hybrid BNN (SVI, surrogate mode)...")
    xtr, ytr = D.blood_cells(rng, 3000)
    params = train_bnn(cfg, xtr, ytr, steps=300)

    xte, yte = D.blood_cells(rng, 600)
    xood, _ = D.blood_cells_ood(rng, 400)
    key = jax.random.key(11)
    print("predicting on the photonic machine twin (N=10 samples)...")
    m_id = predictive_moments(
        B.mc_predict(params, cfg, jnp.asarray(xte), key, "machine"))
    m_ood = predictive_moments(
        B.mc_predict(params, cfg, jnp.asarray(xood), key, "machine"))

    print("\nper-class ID accuracy:")
    pred = np.asarray(m_id["p_mean"].argmax(-1))
    for c, name in enumerate(CLASS_NAMES):
        mask = yte == c
        if mask.sum():
            print(f"  {name:16s} {float((pred[mask] == c).mean()):.3f}"
                  f"  (n={int(mask.sum())})")

    roc = roc_curve(m_ood["MI"], m_id["MI"], 32)
    a = float(auroc(m_ood["MI"], m_id["MI"]))
    print(f"\nOOD (erythroblast) detection: AUROC {a:.4f} "
          f"(paper: 0.9116)")
    print("  MI-threshold ROC (fpr -> tpr):")
    for i in range(0, 32, 6):
        print(f"    t={float(roc['thresholds'][i]):.4f}  "
              f"fpr {float(roc['fpr'][i]):.3f}  "
              f"tpr {float(roc['tpr'][i]):.3f}")

    for t in (0.01, 0.02, 0.05):
        r = rejection_accuracy(m_id["p_mean"], m_id["MI"],
                               jnp.asarray(yte), t)
        ood_rej = float((m_ood["MI"] > t).mean())
        print(f"  threshold {t:.3f}: ID acc "
              f"{float(r['accuracy_accepted']):.4f} "
              f"(rejects {float(r['rejection_rate']):.1%} ID, "
              f"{ood_rej:.1%} OOD)")


if __name__ == "__main__":
    main()
