"""Uncertainty-aware LM decoding (beyond-paper: the LM analog of Fig. 4).

Applies the paper's technique — a single Bayesian (variational) layer +
N=10 MC samples + H/SE/MI readout — to an assigned LM architecture's
output head.  Every generated token carries an epistemic flag (high MI:
the model's weights disagree -> knowledge gap) or aleatoric flag (high
SE, low MI: genuinely ambiguous continuation).

  PYTHONPATH=src python examples/lm_uncertain_decode.py --arch qwen2_1_5b
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCH_IDS, get_config, reduced
from repro.data.synthetic import TokenStreamState, token_batch
from repro.launch import steps as S
from repro.models import registry as M


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_1_5b", choices=ARCH_IDS)
    ap.add_argument("--gen-len", type=int, default=24)
    ap.add_argument("--batch", type=int, default=2)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    print(f"arch {args.arch} (reduced: {cfg.num_layers}L "
          f"d={cfg.d_model}), Bayesian head: {cfg.bayesian_head}, "
          f"N={cfg.mc_samples} MC samples/token")

    key = jax.random.key(0)
    params = M.init_params(key, cfg)
    stream = TokenStreamState(seed=3, host=0, num_hosts=1)
    toks, _ = token_batch(stream, args.batch, 16, cfg.vocab_size)
    tokens = jnp.asarray(toks)
    max_len = 16 + args.gen_len

    modality = None
    if cfg.family == "encdec":
        from repro.models.encdec import ENC_LEN
        modality = jnp.zeros((args.batch, ENC_LEN, cfg.d_model))
    if cfg.family == "vlm":
        modality = jnp.zeros((args.batch, cfg.num_prefix_embeds,
                              cfg.d_model))

    _, cache = M.prefill(params, cfg, tokens, max_len, modality)
    decode = jax.jit(S.build_decode_step(cfg), donate_argnums=(2,))

    print(f"\n tok | token id |    H    |   SE    |   MI    | flag")
    print("-" * 58)
    tok = tokens[:, -1]
    mis = []
    for i in range(args.gen_len):
        out, cache = decode(params, tok, cache, jnp.asarray(i, jnp.int32))
        tok = out["next_token"]
        mi = float(out["MI"][0])
        se = float(out["SE"][0])
        h = float(out["H"][0])
        mis.append(mi)
        flag = ""
        if mi > 0.02:
            flag = "EPISTEMIC (knowledge gap)"
        elif se > 2.0:
            flag = "aleatoric (ambiguous)"
        print(f" {i:3d} | {int(tok[0]):8d} | {h:7.4f} | {se:7.4f} | "
              f"{mi:7.4f} | {flag}")

    print(f"\nmean MI over generation: {np.mean(mis):.4f} "
          f"(untrained model -> expect wide uncertainty; after SVI "
          f"training MI concentrates on genuinely novel contexts)")


if __name__ == "__main__":
    main()
