"""Uncertainty disentanglement (paper Fig. 5, the DDU benchmark).

Train on clean glyphs ONLY (the paper's strict protocol: no uncertainty
samples in training), then show the three (SE, MI) clusters: ID /
ambiguous (aleatoric) / fashion-OOD (epistemic), with an ASCII scatter.

  PYTHONPATH=src python examples/uncertainty_disentanglement.py
"""

import sys
import os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.bench_bloodcell import train_bnn
from repro.core.uncertainty import auroc, predictive_moments
from repro.data import synthetic as D
from repro.models import bnn_cnn as B


def ascii_scatter(points, width=64, height=20):
    """points: list of (se, mi, char)."""
    ses = np.array([p[0] for p in points])
    mis = np.array([p[1] for p in points])
    se_max = max(ses.max(), 1e-6)
    mi_max = max(mis.max(), 1e-6)
    grid = [[" "] * width for _ in range(height)]
    for se, mi, ch in points:
        x = min(int(se / se_max * (width - 1)), width - 1)
        y = min(int(mi / mi_max * (height - 1)), height - 1)
        grid[height - 1 - y][x] = ch
    print(f"  MI ^ (max {mi_max:.3f})")
    for row in grid:
        print("     |" + "".join(row))
    print("     +" + "-" * width + f"> SE (max {se_max:.3f})")
    print("     i=ID  a=ambiguous(aleatoric)  o=fashion-OOD(epistemic)")


def main():
    rng = np.random.default_rng(1)
    cfg = B.BNNConfig(num_classes=10, in_channels=1, width=16)
    print("training on clean glyphs only (paper protocol)...")
    xtr, ytr = D.glyphs(rng, 3000)
    params = train_bnn(cfg, xtr, ytr, steps=300, seed=1)

    key = jax.random.key(7)
    n = 300

    def predict(x):
        return predictive_moments(
            B.mc_predict(params, cfg, jnp.asarray(x), key, "machine"))

    m_id = predict(D.glyphs(rng, n)[0])
    m_amb = predict(D.ambiguous_glyphs(rng, n)[0])
    m_ood = predict(D.fashion_ood(rng, n)[0])

    print("\nmean (SE, MI) per regime:")
    for name, m in (("ID", m_id), ("ambiguous", m_amb),
                    ("fashion OOD", m_ood)):
        print(f"  {name:12s} SE {float(m['SE'].mean()):.4f}  "
              f"MI {float(m['MI'].mean()):.4f}")

    a_alea = float(auroc(m_amb["SE"], m_id["SE"]))
    a_epi = float(auroc(m_ood["MI"], m_id["MI"]))
    print(f"\naleatoric detector AUROC (SE, ambiguous vs ID): "
          f"{a_alea:.4f}  (paper 0.8803)")
    print(f"epistemic detector AUROC (MI, OOD vs ID):       "
          f"{a_epi:.4f}  (paper 0.8442)\n")

    pts = []
    sub = slice(0, 80)
    for ch, m in (("i", m_id), ("a", m_amb), ("o", m_ood)):
        for se, mi in zip(np.asarray(m["SE"])[sub],
                          np.asarray(m["MI"])[sub]):
            pts.append((float(se), float(mi), ch))
    ascii_scatter(pts)


if __name__ == "__main__":
    main()
