"""Quickstart: train the paper's Bayesian CNN end-to-end and use it.

This is the end-to-end driver deliverable: ~300 SVI steps of the paper's
hybrid BNN (DenseNet skips + MobileNet DWS convs, ONE probabilistic
block) on synthetic blood-cell images, then uncertainty-aware prediction
on the photonic-machine digital twin with OOD rejection.

  PYTHONPATH=src python examples/quickstart.py [--steps 300]
"""

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "benchmarks") if "benchmarks" not in sys.path else None

from repro.core import svi
from repro.core.uncertainty import (auroc, best_rejection_threshold,
                                    predictive_moments, rejection_accuracy)
from repro.data import synthetic as D
from repro.models import bnn_cnn as B
from repro.optim import adamw


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--width", type=int, default=16)
    ap.add_argument("--batch", type=int, default=64)
    args = ap.parse_args()

    print("=== 1. data: synthetic blood-cell microscope images")
    rng = np.random.default_rng(0)
    xtr, ytr = D.blood_cells(rng, 3000)
    print(f"    train: {xtr.shape}, 7 classes (erythroblast held OUT)")

    print(f"=== 2. SVI training ({args.steps} steps, surrogate mode)")
    cfg = B.BNNConfig(num_classes=7, in_channels=3, width=args.width)
    key = jax.random.key(0)
    params = B.init_params(key, cfg)
    opt_cfg = adamw.AdamWConfig(lr=3e-3, warmup_steps=20,
                                total_steps=args.steps, weight_decay=1e-4)
    state = adamw.init_state(params, opt_cfg)
    svi_cfg = svi.SVIConfig(num_train_examples=xtr.shape[0],
                            kl_warmup_steps=args.steps // 3)
    nll = B.nll_fn(cfg)

    @jax.jit
    def step(params, state, batch, key, i):
        (loss, aux), g = jax.value_and_grad(
            lambda p: svi.elbo_loss(nll, p, batch, key, i, svi_cfg),
            has_aux=True)(params)
        params, state, _ = adamw.apply_updates(params, g, state, opt_cfg)
        return params, state, loss, aux

    t0 = time.time()
    for i in range(args.steps):
        key, k1, k2 = jax.random.split(key, 3)
        idx = jax.random.randint(k1, (args.batch,), 0, xtr.shape[0])
        b = {"images": jnp.asarray(xtr[idx]),
             "labels": jnp.asarray(ytr[idx])}
        params, state, loss, aux = step(params, state, b, k2,
                                        jnp.asarray(i))
        if i % max(args.steps // 6, 1) == 0:
            print(f"    step {i:4d}  elbo-loss {float(loss):7.4f}  "
                  f"acc {float(aux['accuracy']):.3f}")
    print(f"    trained in {time.time() - t0:.1f}s")

    print("=== 3. predict on the photonic machine twin (N=10 MC samples)")
    xte, yte = D.blood_cells(rng, 600)
    xood, _ = D.blood_cells_ood(rng, 300)
    p_id = B.mc_predict(params, cfg, jnp.asarray(xte),
                        jax.random.key(1), "machine")
    p_ood = B.mc_predict(params, cfg, jnp.asarray(xood),
                         jax.random.key(2), "machine")
    m_id = predictive_moments(p_id)
    m_ood = predictive_moments(p_ood)

    print("=== 4. uncertainty reasoning")
    t, _ = best_rejection_threshold(m_id["MI"], m_id["p_mean"],
                                    jnp.asarray(yte))
    r = rejection_accuracy(m_id["p_mean"], m_id["MI"], jnp.asarray(yte), t)
    a = float(auroc(m_ood["MI"], m_id["MI"]))
    print(f"    ID accuracy:           {float(r['accuracy_all']):.4f}")
    print(f"    ID acc w/ rejection:   {float(r['accuracy_accepted']):.4f}"
          f"  (MI threshold {t:.4f}, "
          f"rejects {float(r['rejection_rate']):.1%})")
    print(f"    erythroblast OOD AUROC: {a:.4f}")
    print("    (paper: 90.26% -> 94.62%, AUROC 91.16% on real BloodMNIST)")


if __name__ == "__main__":
    main()
