"""Copy-on-write radix prefix cache over the paged KV pool (ISSUE 4).

The acceptance contract:

  * prefix-hit decode is BIT-EXACT vs ``prefix_cache=False`` in
    operand-entropy mode on staggered shared-prefix traffic, including
    post-divergence copy-on-write;
  * the suffix prefill reproduces the cold flash-attention prefill's
    suffix KV bit for bit (equal reduction extents);
  * refcount churn leaks nothing — the randomized admit/evict/CoW leak
    fuzz lives in test_block_fuzz.py;
  * hit/miss/saved-token accounting is exact;
  * LRU eviction only touches cached-but-unreferenced blocks.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config, reduced
from repro.launch.prefix_cache import RadixPrefixCache
from repro.launch.serve import (BlockAllocator, Request, ServeEngine,
                                SlotScheduler)
from repro.models import registry as M

from conftest import family_setup
from conftest import make_request as _req


@pytest.fixture(scope="module")
def setup():
    # dense cfg/params shared with the other engine modules; this module
    # additionally needs shared-prefix prompt material, so it overrides
    # the conftest fixture with a wider tuple
    cfg, params, _ = family_setup("dense")
    key = jax.random.key(0)
    shared = np.asarray(
        jax.random.randint(key, (20,), 0, cfg.vocab_size), np.int32)
    tails = np.asarray(
        jax.random.randint(jax.random.key(1), (8, 8), 0, cfg.vocab_size),
        np.int32)
    return cfg, params, shared, tails


# ---------------------------------------------------------------------------
# allocator refcounts
# ---------------------------------------------------------------------------

class TestAllocatorRefcounts:
    def test_incref_keeps_block_alive_through_one_free(self):
        a = BlockAllocator(4, block_size=2)
        a.reserve(2)
        ids = a.alloc(2)
        assert all(a.refcount(i) == 1 for i in ids)
        a.incref(ids)                       # a second holder
        a.free(ids)                         # first holder lets go
        assert a.in_use == 2                # still alive
        a.free(ids)                         # last holder
        assert a.in_use == 0
        assert a.available() == 4

    def test_incref_of_free_block_raises(self):
        a = BlockAllocator(4, block_size=2)
        with pytest.raises(ValueError, match="incref of free"):
            a.incref([0])

    def test_free_below_zero_is_double_free(self):
        a = BlockAllocator(4, block_size=2)
        a.reserve(1)
        ids = a.alloc(1)
        a.free(ids)
        with pytest.raises(ValueError, match="double free"):
            a.free(ids)


# ---------------------------------------------------------------------------
# the radix tree (host-only, no engine)
# ---------------------------------------------------------------------------

def _tree(num_blocks=16, bs=4):
    a = BlockAllocator(num_blocks, bs)
    return a, RadixPrefixCache(a, bs)


def _take(alloc, n):
    alloc.reserve(n)
    return alloc.alloc(n)


class TestRadixTree:
    def test_match_full_blocks_then_partial_tail(self):
        a, c = _tree()
        seq = list(range(10))               # 2 full blocks + 2-token tail
        blocks = _take(a, 3)
        assert c.insert(seq, blocks) == 3
        a.free(blocks)                      # tree holds them now
        assert a.in_use == 3 == c.cached_blocks()

        hit = c.match(seq)                  # identical prompt: full hit
        assert hit.tokens == 10 and hit.blocks == blocks and hit.partial

        hit = c.match(seq[:8])              # block-aligned prefix
        assert hit.tokens == 8 and not hit.partial
        assert hit.blocks == blocks[:2]

        hit = c.match(seq[:6] + [99, 99])   # diverges mid-block 2
        assert hit.tokens == 6 and hit.partial
        assert hit.blocks == blocks[:2]

        assert c.match([77, 78]).tokens == 0

    def test_insert_shares_existing_nodes(self):
        a, c = _tree()
        common = list(range(8))
        b1 = _take(a, 3)
        c.insert(common + [50, 51], b1)
        a.free(b1)
        # same common prefix, different tail: only the tail is adopted
        b2 = _take(a, 3)
        adopted = c.insert(common + [60, 61], b2)
        assert adopted == 1
        a.free(b2)                          # unadopted copies die here
        assert a.in_use == 4 == c.cached_blocks()
        # both tails reachable by a token-granular walk
        assert c.match(common + [60, 61]).tokens == 10
        assert c.match(common + [50, 51]).tokens == 10

    def test_lru_eviction_respects_refcounts_and_protection(self):
        a, c = _tree(num_blocks=8)
        b1 = _take(a, 2)
        c.insert(list(range(8)), b1)        # older
        b2 = _take(a, 2)
        c.insert(list(range(100, 108)), b2)  # newer
        a.free(b1)
        a.free(b2)
        a.incref([b2[1]])                   # a slot still maps this one
        # oldest unreferenced leaf goes first: b1's tail
        assert c.evict_lru(1) == 1
        assert a.refcount(b1[1]) == 0
        # b2's tail is slot-referenced -> only interior-turned-leaf
        # b1[0] is evictable; protection can pin it too
        assert c.evict_lru(5, protect=frozenset([b1[0]])) == 0
        assert c.evict_lru(5) == 1          # b1[0] once unprotected
        assert c.cached_blocks() == 2       # b2 survives (tail ref'd)

    def test_clear_releases_every_tree_reference(self):
        a, c = _tree()
        blocks = _take(a, 4)
        c.insert(list(range(16)), blocks)
        a.free(blocks)
        assert a.in_use == 4
        assert c.clear() == 4
        assert a.in_use == 0 and c.cached_blocks() == 0


# ---------------------------------------------------------------------------
# scheduler integration + refcount churn fuzz
# ---------------------------------------------------------------------------

def _prefix_sched(num_slots=2, num_blocks=16, bs=4, width=6):
    a = BlockAllocator(num_blocks, bs)
    cache = RadixPrefixCache(a, bs)
    return SlotScheduler(num_slots, allocator=a, table_width=width,
                         prefix_cache=cache), cache


class TestPrefixScheduler:
    def test_hit_maps_shared_blocks_and_cow_swaps_the_tail(self):
        s, cache = _prefix_sched()
        s.submit(_req(0, list(range(10)), 4))
        [(slot, _)] = s.admit()
        assert s.prefix_admit(slot).tokens == 0     # cold miss
        s.evict(slot)                               # donates 3 blocks
        assert cache.cached_blocks() == 3
        cached_row = list(s.block_tables[slot])     # snapshot: all -1
        assert all(b == -1 for b in cached_row)

        # 9 shared tokens then divergence mid-tail-block: full-block hit
        # of 8 plus a token-granular partial match of 1 into the tail
        s.submit(_req(1, list(range(9)) + [70, 71], 4))
        [(slot, _)] = s.admit()
        info = s.prefix_admit(slot)
        assert info.tokens == 9 and info.cow is not None
        src, dst = info.cow
        assert s.allocator.refcount(src) == 2       # tree + this slot
        assert s.block_tables[slot][2] == dst       # table swapped
        s.finish_cow(slot)
        assert s.allocator.refcount(src) == 1       # tree only
        s.evict(slot)
        assert s.allocator.in_use == cache.cached_blocks()

    # randomized CoW/refcount churn lives in test_block_fuzz.py now: the
    # property-based interpreter there checks the exact refcount identity
    # (slots + tree + pending CoW sources) after every op


# ---------------------------------------------------------------------------
# suffix prefill numerics
# ---------------------------------------------------------------------------

class TestSuffixPrefill:
    def test_suffix_kv_bit_exact_vs_cold_prefill(self, setup):
        """prefill_suffix over a cached prefix must reproduce the cold
        full-prompt prefill's suffix KV bit for bit (the equal-
        reduction-extent argument in layers.apply_attention_suffix)."""
        cfg, params, shared, tails = setup
        prompt = np.concatenate([shared, tails[0][:6]])
        P0, P = 20, len(prompt)
        _, cold = M.prefill(params, cfg, jnp.asarray(prompt)[None], P)
        _, pre = M.prefill(params, cfg, jnp.asarray(prompt[:P0])[None],
                           P0)
        pad = [(0, 0), (0, 0), (0, 32 - P0), (0, 0), (0, 0)]
        strips = {n: jnp.pad(pre[n], pad) for n in ("k", "v")}
        _, sub = M.prefill_suffix(params, cfg,
                                  jnp.asarray(prompt[P0:])[None],
                                  strips, P0)
        assert int(sub["len"][0]) == P
        for name in ("k", "v"):
            np.testing.assert_array_equal(
                np.asarray(cold[name][:, :, P0:P]),
                np.asarray(sub[name]))

    def test_unsupported_family_raises(self):
        cfg = reduced(get_config("deepseek_moe_16b"))
        with pytest.raises(ValueError, match="cannot prefix-share"):
            M.prefill_suffix(None, cfg, None, None, 0)


# ---------------------------------------------------------------------------
# the engine: bit-exactness, CoW divergence, accounting
# ---------------------------------------------------------------------------

def _streams_equal(a, b):
    return (a.tokens == b.tokens
            and np.array_equal(np.asarray(a.H, np.float32),
                               np.asarray(b.H, np.float32))
            and np.array_equal(np.asarray(a.SE, np.float32),
                               np.asarray(b.SE, np.float32))
            and np.array_equal(np.asarray(a.MI, np.float32),
                               np.asarray(b.MI, np.float32)))


class TestPrefixEngine:
    def _shared_requests(self, shared, tails, n=6):
        # 20 shared tokens then a unique tail: with kv_block=8 the
        # divergence lands mid-block -> every hit takes the CoW path
        return [_req(i, np.concatenate([shared, tails[i][:6]]), 6)
                for i in range(n)]

    def test_cow_divergence_parity_bit_exact_vs_cold(self, setup):
        """Staggered shared-prefix traffic through prefix_cache on/off:
        identical token AND uncertainty streams, with real hits and real
        copy-on-write divergences on the cached path."""
        cfg, params, shared, tails = setup
        kw = dict(num_slots=2, max_len=32, chunk=4, kv_layout="paged",
                  kv_block=8)
        cold = ServeEngine(params, cfg, **kw)
        rc = cold.run(self._shared_requests(shared, tails))
        warm = ServeEngine(params, cfg, **kw, prefix_cache=True)
        rw = warm.run(self._shared_requests(shared, tails))
        for a, b in zip(rc["requests"], rw["requests"]):
            assert _streams_equal(a, b), f"request {a.rid} diverged"
        pc = rw["prefix_cache"]
        assert pc["hits"] > 0 and pc["cow_copies"] > 0
        assert pc["prompt_tokens_saved"] > 0
        assert rc["prefix_cache"]["enabled"] is False

    def test_full_prompt_hit_accounting_is_exact(self, setup):
        """S-sample fanout (identical prompts): the first num_slots
        admissions miss (cache fills at eviction), every later one is a
        full-prompt hit that skips prefill entirely."""
        cfg, params, shared, tails = setup
        prompt = np.concatenate([shared, tails[0][:6]])   # 26 tokens
        n, slots = 8, 2
        engine = ServeEngine(params, cfg, num_slots=slots, max_len=40,
                             chunk=4, kv_layout="paged", kv_block=8,
                             kv_blocks=20, prefix_cache=True)
        res = engine.run([_req(i, prompt.copy(), 6) for i in range(n)])
        pc = res["prefix_cache"]
        assert pc["misses"] == slots
        assert pc["hits"] == n - slots
        assert pc["hit_rate"] == (n - slots) / n
        assert pc["prompt_tokens"] == n * len(prompt)
        assert pc["prompt_tokens_saved"] == (n - slots) * len(prompt)
        assert pc["saved_frac"] == pytest.approx((n - slots) / n)
        # 26 % 8 != 0: every full hit CoWs the partial tail block
        assert pc["cow_copies"] == n - slots
        assert all(len(r.tokens) == 6 for r in res["requests"])

    def test_sched_trace_exposes_pool_occupancy_per_chunk(self, setup):
        cfg, params, shared, tails = setup
        engine = ServeEngine(params, cfg, num_slots=2, max_len=32,
                             chunk=4, kv_layout="paged", kv_block=8,
                             prefix_cache=True)
        res = engine.run(self._shared_requests(shared, tails, n=4))
        trace = res["sched_trace"]
        assert len(trace) > 0
        for snap in trace:
            for key in ("queue_depth", "active_slots", "blocks_free",
                        "blocks_reserved", "blocks_cached",
                        "blocks_in_use"):
                assert key in snap
            assert snap["blocks_in_use"] + snap["blocks_free"] == \
                engine.kv_blocks
        # leak-check invariant incl. cached refcounts held at drain
        # (ServeEngine.run raises otherwise); what remains is cache-owned
        assert res["prefix_cache"]["blocks_cached_end"] > 0

    def test_dense_layout_rejects_prefix_cache(self, setup):
        cfg, params, _, _ = setup
        with pytest.raises(ValueError, match="paged"):
            ServeEngine(params, cfg, num_slots=2, max_len=32,
                        kv_layout="dense", prefix_cache=True)

    def test_unsupported_family_serves_cold(self):
        """moe prompt KV is not a pure function of the token prefix
        (capacity cumsum couples tokens), so the engine silently serves
        it cold — same fallback convention as ssm's dense layout."""
        cfg = dataclasses.replace(reduced(get_config("deepseek_moe_16b")),
                                  head_entropy="operand")
        params = M.init_params(jax.random.key(1), cfg)
        engine = ServeEngine(params, cfg, num_slots=2, max_len=24,
                             chunk=4, kv_layout="paged", kv_block=8,
                             prefix_cache=True)
        assert engine.prefix_cache is False
        toks = np.asarray(jax.random.randint(jax.random.key(2), (2, 8),
                                             0, cfg.vocab_size), np.int32)
        res = engine.run([_req(i, toks[i], 4) for i in range(2)])
        assert res["prefix_cache"]["enabled"] is False
        assert all(len(r.tokens) == 4 for r in res["requests"])
