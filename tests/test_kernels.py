"""Per-kernel validation: Pallas (interpret mode) vs the pure-jnp oracle.

Shape/dtype sweeps + hypothesis property tests per the deliverables: every
kernel is checked against ref.py over a grid of problem sizes including
non-tile-multiple shapes (the ops.py wrappers pad/strip).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref

jax.config.update("jax_enable_x64", False)


def _rand(key, *shape, scale=1.0):
    return scale * jax.random.normal(key, shape, jnp.float32)


# ---------------------------------------------------------------------------
# bayes_matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [
    (8, 16, 8), (128, 128, 128), (64, 96, 80), (33, 70, 17),
    (256, 512, 128), (1, 9, 7),
])
def test_bayes_matmul_matches_ref(m, k, n):
    ks = jax.random.split(jax.random.key(0), 4)
    x = _rand(ks[0], m, k)
    mu = _rand(ks[1], k, n, scale=0.3)
    sg = jnp.abs(_rand(ks[2], k, n, scale=0.1))
    eps = _rand(ks[3], k, n)
    got = ops.bayes_matmul(x, mu, sg, eps, impl="pallas")
    want = ref.bayes_matmul(x, mu, sg, eps)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bayes_matmul_dtypes(dtype):
    ks = jax.random.split(jax.random.key(1), 4)
    x = _rand(ks[0], 32, 64).astype(dtype)
    mu = _rand(ks[1], 64, 48, scale=0.3).astype(dtype)
    sg = jnp.abs(_rand(ks[2], 64, 48, scale=0.1)).astype(dtype)
    eps = _rand(ks[3], 64, 48).astype(dtype)
    got = ops.bayes_matmul(x, mu, sg, eps, impl="pallas")
    want = ref.bayes_matmul(x, mu, sg, eps)
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


def test_bayes_matmul_zero_sigma_is_deterministic():
    """sigma=0 -> exactly the mean GEMM regardless of entropy."""
    ks = jax.random.split(jax.random.key(2), 3)
    x = _rand(ks[0], 16, 32)
    mu = _rand(ks[1], 32, 24)
    z = jnp.zeros((32, 24))
    for eps_scale in (0.0, 1.0, 100.0):
        eps = _rand(ks[2], 32, 24, scale=eps_scale)
        got = ops.bayes_matmul(x, mu, z, eps, impl="pallas")
        np.testing.assert_allclose(got, x @ mu, rtol=1e-5, atol=1e-4)


# ---------------------------------------------------------------------------
# lrt_matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [
    (8, 16, 8), (128, 256, 128), (40, 50, 60), (1, 128, 11),
])
def test_lrt_matmul_matches_ref(m, k, n):
    ks = jax.random.split(jax.random.key(3), 4)
    x = _rand(ks[0], m, k)
    mu = _rand(ks[1], k, n, scale=0.3)
    sg = jnp.abs(_rand(ks[2], k, n, scale=0.1))
    xi = _rand(ks[3], m, n)
    got = ops.lrt_matmul(x, mu, sg, xi, impl="pallas")
    want = ref.lrt_matmul(x, mu, sg, xi)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_lrt_moments_match_weight_space_sampling():
    """LRT and weight-space sampling share mean and variance (the local
    reparameterization theorem) — the statistical contract that lets the
    LM head replace per-sample weight draws with output-space noise."""
    key = jax.random.key(4)
    ks = jax.random.split(key, 3)
    m, k, n, S = 4, 32, 8, 4000
    x = _rand(ks[0], m, k)
    mu = _rand(ks[1], k, n, scale=0.3)
    sg = jnp.abs(_rand(ks[2], k, n, scale=0.2))

    eps = jax.random.normal(jax.random.key(5), (S, k, n))
    y_ws = jax.vmap(lambda e: ref.bayes_matmul(x, mu, sg, e))(eps)
    xi = jax.random.normal(jax.random.key(6), (S, m, n))
    y_lrt = jax.vmap(lambda z: ref.lrt_matmul(x, mu, sg, z))(xi)

    np.testing.assert_allclose(y_ws.mean(0), y_lrt.mean(0),
                               rtol=0.1, atol=0.15)
    np.testing.assert_allclose(y_ws.std(0), y_lrt.std(0),
                               rtol=0.15, atol=0.05)


# ---------------------------------------------------------------------------
# photonic_conv
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,t", [(1, 16), (8, 64), (5, 40), (16, 256)])
def test_photonic_conv_matches_ref(b, t):
    ks = jax.random.split(jax.random.key(7), 3)
    x = jax.random.uniform(ks[0], (b, t), minval=-1, maxval=1)
    mu = jax.random.uniform(ks[1], (9,), minval=-0.8, maxval=0.8)
    sg = jnp.abs(mu) * 0.2
    eps = jax.random.normal(ks[2], (b, t - 8, 9))
    got = ops.photonic_conv(x, mu, sg, eps, impl="pallas")
    want = ref.photonic_conv(x, mu, sg, eps)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_photonic_conv_matches_machine_twin():
    """Kernel == core.photonic.convolve with impairments disabled."""
    from repro.core.photonic import MachineConfig, convolve, ChannelProgram
    from repro.core import entropy as E
    cfg = MachineConfig(detector_noise=0.0, crosstalk=0.0, drift_std=0.0,
                        eom_mod_depth=0.0, gaussian_surrogate=True)
    key = jax.random.key(8)
    x = jax.random.uniform(key, (24,), minval=-1, maxval=1)
    mu = jnp.linspace(-0.5, 0.5, 9)
    bw = jnp.full((9,), 100.0)
    prog = ChannelProgram(power=mu, bandwidth=bw)
    y_machine = convolve(key, x, prog, cfg)
    # reproduce the machine's eps draw through the kernel interface
    m = E.modes_from_bandwidth(bw)
    sigma = jnp.abs(mu) / jnp.sqrt(m)
    eps = jax.random.normal(key, (1, 16, 9))
    y_kernel = ops.photonic_conv(x[None], mu, sigma,
                                 eps, impl="ref")
    assert y_machine.shape == (16,)
    assert y_kernel.shape == (1, 16)


# ---------------------------------------------------------------------------
# uncertainty_head
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,v,s", [
    (8, 16, 12, 4), (32, 64, 48, 10), (7, 33, 21, 3), (128, 128, 256, 10),
])
def test_uncertainty_head_matches_ref(m, k, v, s):
    ks = jax.random.split(jax.random.key(9), 4)
    x = _rand(ks[0], m, k)
    mu = _rand(ks[1], k, v, scale=0.2)
    sg = jnp.abs(_rand(ks[2], k, v, scale=0.05))
    xi = _rand(ks[3], s, m, v)
    got = ops.uncertainty_head(x, mu, sg, xi, impl="pallas")
    want = ref.uncertainty_head(x, mu, sg, xi)
    for name in ("H", "SE", "MI", "p_max"):
        np.testing.assert_allclose(got[name], want[name], rtol=1e-4,
                                   atol=1e-5, err_msg=name)
    np.testing.assert_array_equal(got["pred"], want["pred"])


def test_uncertainty_head_identities():
    """0 <= MI <= H <= log(V); SE = H - MI."""
    ks = jax.random.split(jax.random.key(10), 4)
    m, k, v, s = 64, 32, 10, 10
    out = ref.uncertainty_head(
        _rand(ks[0], m, k), _rand(ks[1], k, v, scale=0.5),
        jnp.abs(_rand(ks[2], k, v, scale=0.3)), _rand(ks[3], s, m, v))
    h, se, mi = out["H"], out["SE"], out["MI"]
    assert (mi >= -1e-6).all()
    assert (h <= np.log(v) + 1e-5).all()
    assert (mi <= h + 1e-6).all()
    np.testing.assert_allclose(se, h - mi, atol=1e-5)


# ---------------------------------------------------------------------------
# in-kernel entropy path: seeded parity (moments) + determinism
# ---------------------------------------------------------------------------
# The in-kernel PRNG only lowers on real TPUs; in interpret mode the
# *_sampled wrappers run the same fused kernels with an explicit operand
# derived host-side from the same seed (the validation path).  The oracle
# and the kernel draw different bit streams, so parity is statistical —
# mean/std over S samples — exactly the contract the TPU path satisfies.

def _sampled_setup(key, m, k, n):
    ks = jax.random.split(key, 3)
    x = _rand(ks[0], m, k)
    mu = _rand(ks[1], k, n, scale=0.3)
    sg = jnp.abs(_rand(ks[2], k, n, scale=0.1))
    return x, mu, sg


def _assert_sample_moments(got, x, mu, sg, lrt=False):
    """Moments of S MC samples vs the analytic LRT mean/std."""
    x32 = x.astype(jnp.float32)
    mean = x32 @ mu
    std = jnp.sqrt(jnp.maximum((x32 * x32) @ (sg ** 2), 0.0))
    s = got.shape[0]
    # standardized residual of the sample mean is ~N(0,1) per element:
    # its mean |.| is ~0.8 for an unbiased stream; a mean/std bug in the
    # generated variates shifts it by O(sqrt(S)).
    resid = (np.asarray(got.mean(0)) - np.asarray(mean)) \
        / np.maximum(np.asarray(std) / np.sqrt(s), 1e-6)
    assert np.abs(resid).mean() < 1.5, np.abs(resid).mean()
    ratio = np.asarray(got.std(0)) / np.maximum(np.asarray(std), 1e-6)
    assert abs(ratio.mean() - 1.0) < 0.2, ratio.mean()


@pytest.mark.parametrize("fn,oracle", [
    (ops.bayes_matmul_sampled, ref.bayes_matmul_sampled),
    (ops.lrt_matmul_sampled, ref.lrt_matmul_sampled),
])
def test_sampled_matmul_moments_match_oracle(fn, oracle):
    m, k, n, s = 16, 64, 24, 64
    x, mu, sg = _sampled_setup(jax.random.key(30), m, k, n)
    got = fn(x, mu, sg, 123, num_samples=s, impl="pallas")
    want = oracle(x, mu, sg, 123, s)
    assert got.shape == want.shape == (s, m, n)
    _assert_sample_moments(got, x, mu, sg)
    _assert_sample_moments(want, x, mu, sg)
    # the two paths agree on the analytic mean within MC error of each
    np.testing.assert_allclose(got.mean(0), want.mean(0), atol=0.5)


@pytest.mark.parametrize("fn", [ops.bayes_matmul_sampled,
                                ops.lrt_matmul_sampled])
@pytest.mark.parametrize("impl", ["pallas", "ref"])
def test_sampled_matmul_determinism(fn, impl):
    x, mu, sg = _sampled_setup(jax.random.key(31), 8, 32, 16)
    a = fn(x, mu, sg, 7, num_samples=4, impl=impl)
    b = fn(x, mu, sg, 7, num_samples=4, impl=impl)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    c = fn(x, mu, sg, 8, num_samples=4, impl=impl)
    assert not np.allclose(a, c)


def test_fused_kernels_match_ref_with_explicit_entropy():
    """Bit-exact parity of the fused S-sample kernel *structure* against
    the oracle when both consume the same explicit variates (the
    validation path — isolates the fusion from the RNG)."""
    from repro.kernels.bayes_matmul import (bayes_matmul_fused_kernel,
                                            lrt_matmul_fused_kernel)
    m, k, n, s = 16, 32, 24, 5
    x, mu, sg = _sampled_setup(jax.random.key(32), m, k, n)
    eps = jax.random.normal(jax.random.key(33), (s, k, n))
    got = bayes_matmul_fused_kernel(x, mu, sg, 0, num_samples=s, eps=eps,
                                    interpret=True)
    want = jax.vmap(lambda e: ref.bayes_matmul(x, mu, sg, e))(eps)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)

    xi = jax.random.normal(jax.random.key(34), (s, m, n))
    got = lrt_matmul_fused_kernel(x, mu, sg, 0, num_samples=s, xi=xi,
                                  interpret=True)
    want = jax.vmap(lambda z: ref.lrt_matmul(x, mu, sg, z))(xi)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_fused_head_matches_ref_with_explicit_entropy():
    """The scratch-free two-pass head (pass 2 regenerates logits instead
    of re-reading the (S, M, V) buffer) is exact vs the oracle when both
    consume the same xi."""
    from repro.kernels.uncertainty_head import uncertainty_head_fused_kernel
    m, k, v, s = 8, 16, 21, 6
    ks = jax.random.split(jax.random.key(35), 4)
    x = _rand(ks[0], m, k)
    mu = _rand(ks[1], k, v, scale=0.2)
    sg = jnp.abs(_rand(ks[2], k, v, scale=0.05))
    xi = _rand(ks[3], s, m, v)
    got = uncertainty_head_fused_kernel(x, mu, sg, 0, num_samples=s, xi=xi,
                                        bm=8, bv=16, interpret=True)
    want = ref.uncertainty_head(x, mu, sg, xi)
    for name in ("H", "SE", "MI", "p_max"):
        np.testing.assert_allclose(got[name], want[name], rtol=1e-4,
                                   atol=1e-5, err_msg=name)
    np.testing.assert_array_equal(got["pred"], want["pred"])


def test_sampled_head_moments_and_determinism():
    m, k, v, s = 8, 16, 12, 10
    ks = jax.random.split(jax.random.key(36), 3)
    x = _rand(ks[0], m, k)
    mu = _rand(ks[1], k, v, scale=0.2)
    sg = jnp.abs(_rand(ks[2], k, v, scale=0.05))
    a = ops.uncertainty_head_sampled(x, mu, sg, 5, num_samples=s,
                                     impl="pallas")
    b = ops.uncertainty_head_sampled(x, mu, sg, 5, num_samples=s,
                                     impl="pallas")
    for name in a:
        np.testing.assert_array_equal(np.asarray(a[name]),
                                      np.asarray(b[name]))
    want = ref.uncertainty_head_sampled(x, mu, sg, 5, s)
    # H of the mean predictive is dominated by the mean logits -> the two
    # seed streams must land in the same entropy regime
    np.testing.assert_allclose(a["H"], want["H"], atol=0.35)
    assert (np.asarray(a["MI"]) >= -1e-6).all()
    np.testing.assert_allclose(np.asarray(a["SE"]),
                               np.asarray(a["H"]) - np.asarray(a["MI"]),
                               atol=1e-5)


def test_sampled_conv_moments_and_determinism():
    b, t, c = 8, 64, 9
    ks = jax.random.split(jax.random.key(37), 2)
    x = jax.random.uniform(ks[0], (b, t), minval=-1, maxval=1)
    mu = jax.random.uniform(ks[1], (c,), minval=-0.6, maxval=0.6)
    sg = jnp.abs(mu) * 0.2
    a = ops.photonic_conv_sampled(x, mu, sg, 9, impl="pallas")
    a2 = ops.photonic_conv_sampled(x, mu, sg, 9, impl="pallas")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(a2))
    # different seeds -> different shot noise, same mean conv
    ys = np.stack([np.asarray(
        ops.photonic_conv_sampled(x, mu, sg, s, impl="ref"))
        for s in range(40)])
    want = ref.photonic_conv(x, mu, sg, jnp.zeros((b, t - c + 1, c)))
    np.testing.assert_allclose(ys.mean(0), np.asarray(want), atol=0.15)


def test_im2col_sampled_shape_determinism_and_mean():
    """The seeded 3x3-conv GEMM: (S, B, C_out, H, W) layout is right
    (sample mean converges to the mean-weight conv), and the stream is a
    pure function of the seed."""
    ks = jax.random.split(jax.random.key(40), 3)
    b, cin, cout, h, w, s = 2, 3, 4, 6, 6, 64
    x = _rand(ks[0], b, cin, h, w)
    mu = _rand(ks[1], cout, cin, 3, 3, scale=0.2)
    sg = jnp.abs(_rand(ks[2], cout, cin, 3, 3, scale=0.05))
    y = ops.bayes_conv2d_im2col_sampled(x, mu, sg, 3, num_samples=s,
                                        impl="ref")
    assert y.shape == (s, b, cout, h, w)
    y2 = ops.bayes_conv2d_im2col_sampled(x, mu, sg, 3, num_samples=s,
                                         impl="ref")
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y2))
    mean_conv = ops.bayes_conv2d_im2col(x, mu, sg, jnp.zeros_like(mu),
                                        impl="ref")
    np.testing.assert_allclose(y.mean(0), mean_conv, atol=0.35)


def test_entropy_bytes_accounting():
    """The benchmark's traffic columns: operand path counts the exact
    operand bytes, in-kernel path is 0 by construction."""
    s, m, k, v = 10, 128, 1024, 4096
    assert ops.entropy_bytes("weight_space", num_samples=s, k=k, n=v) \
        == s * k * v * 4
    assert ops.entropy_bytes("head", num_samples=s, m=m, n=v) \
        == s * m * v * 4
    assert ops.entropy_bytes("conv", num_samples=1, b=8, t_out=248) \
        == 8 * 248 * 9 * 4
    for kind in ("weight_space", "lrt", "head", "conv"):
        assert ops.entropy_bytes(kind, num_samples=s, m=m, k=k, n=v, b=8,
                                 t_out=248, in_kernel=True) == 0


# ---------------------------------------------------------------------------
# hypothesis property sweeps
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(m=st.integers(1, 40), k=st.integers(1, 64), n=st.integers(1, 40),
       seed=st.integers(0, 2**31 - 1))
def test_prop_bayes_matmul_any_shape(m, k, n, seed):
    ks = jax.random.split(jax.random.key(seed), 4)
    x = _rand(ks[0], m, k)
    mu = _rand(ks[1], k, n, scale=0.3)
    sg = jnp.abs(_rand(ks[2], k, n, scale=0.1))
    eps = _rand(ks[3], k, n)
    got = ops.bayes_matmul(x, mu, sg, eps, impl="pallas")
    want = ref.bayes_matmul(x, mu, sg, eps)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


@settings(max_examples=20, deadline=None)
@given(m=st.integers(1, 24), v=st.integers(2, 24), s=st.integers(1, 12),
       seed=st.integers(0, 2**31 - 1))
def test_prop_uncertainty_head_invariants(m, v, s, seed):
    ks = jax.random.split(jax.random.key(seed), 4)
    k = 16
    out = ops.uncertainty_head(
        _rand(ks[0], m, k), _rand(ks[1], k, v, scale=0.4),
        jnp.abs(_rand(ks[2], k, v, scale=0.2)), _rand(ks[3], s, m, v),
        impl="pallas")
    assert (out["MI"] >= -1e-6).all()
    assert (out["H"] >= out["MI"] - 1e-5).all()
    assert (out["H"] <= np.log(v) + 1e-4).all()
    assert ((out["pred"] >= 0) & (out["pred"] < v)).all()
    assert (out["p_max"] >= 1.0 / v - 1e-6).all()


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

def _naive_attention(q, k, v, causal=True):
    B, S, H, D = q.shape
    Hkv = k.shape[2]
    kk = jnp.repeat(k, H // Hkv, axis=2)
    vv = jnp.repeat(v, H // Hkv, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kk) / jnp.sqrt(jnp.float32(D))
    if causal:
        m = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(m[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vv)


@pytest.mark.parametrize("b,s,h,hkv,d,causal", [
    (1, 32, 4, 4, 16, True),
    (2, 70, 6, 2, 16, True),      # GQA, non-multiple seq
    (2, 64, 8, 1, 32, False),     # MQA, non-causal
    (1, 128, 2, 2, 64, True),
])
def test_flash_attention_kernel_matches_naive(b, s, h, hkv, d, causal):
    ks = jax.random.split(jax.random.key(20), 3)
    q = _rand(ks[0], b, s, h, d)
    k = _rand(ks[1], b, s, hkv, d)
    v = _rand(ks[2], b, s, hkv, d)
    got = ops.flash_attention(q, k, v, impl="pallas", causal=causal,
                              bq=16, bk=32)
    want = _naive_attention(q, k, v, causal)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_flash_attention_jnp_scope_matches_kernel():
    """The models' jnp flash path (named_scope 'fused_attention') and the
    Pallas kernel agree — the roofline's scope-skip accounting is backed
    by a real kernel with identical semantics."""
    from repro.models.layers import flash_attention as jnp_flash
    ks = jax.random.split(jax.random.key(21), 3)
    b, s, h, hkv, d = 2, 48, 4, 2, 16
    q = _rand(ks[0], b, s, h, d)
    k = _rand(ks[1], b, s, hkv, d)
    v = _rand(ks[2], b, s, hkv, d)
    a = jnp_flash(q, k, v, causal=True, q_chunk=16, kv_chunk=16)
    bb = ops.flash_attention(q, k, v, impl="pallas", causal=True,
                             bq=16, bk=16)
    np.testing.assert_allclose(a, bb, rtol=1e-4, atol=1e-5)


def test_flash_attention_q_offset_decode_window():
    """Continuation: last token of a prefix equals full-seq attention."""
    ks = jax.random.split(jax.random.key(22), 3)
    b, s, h, d = 1, 40, 2, 16
    q = _rand(ks[0], b, s, h, d)
    k = _rand(ks[1], b, s, h, d)
    v = _rand(ks[2], b, s, h, d)
    full = _naive_attention(q, k, v, causal=True)
    last = ops.flash_attention(q[:, -1:], k, v, impl="pallas",
                               causal=True, q_offset=s - 1, bq=8, bk=16)
    np.testing.assert_allclose(last[:, 0], full[:, -1], rtol=1e-4,
                               atol=1e-5)
