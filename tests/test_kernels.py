"""Per-kernel validation: Pallas (interpret mode) vs the pure-jnp oracle.

Shape/dtype sweeps + hypothesis property tests per the deliverables: every
kernel is checked against ref.py over a grid of problem sizes including
non-tile-multiple shapes (the ops.py wrappers pad/strip).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

jax.config.update("jax_enable_x64", False)


def _rand(key, *shape, scale=1.0):
    return scale * jax.random.normal(key, shape, jnp.float32)


# ---------------------------------------------------------------------------
# bayes_matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [
    (8, 16, 8), (128, 128, 128), (64, 96, 80), (33, 70, 17),
    (256, 512, 128), (1, 9, 7),
])
def test_bayes_matmul_matches_ref(m, k, n):
    ks = jax.random.split(jax.random.key(0), 4)
    x = _rand(ks[0], m, k)
    mu = _rand(ks[1], k, n, scale=0.3)
    sg = jnp.abs(_rand(ks[2], k, n, scale=0.1))
    eps = _rand(ks[3], k, n)
    got = ops.bayes_matmul(x, mu, sg, eps, impl="pallas")
    want = ref.bayes_matmul(x, mu, sg, eps)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bayes_matmul_dtypes(dtype):
    ks = jax.random.split(jax.random.key(1), 4)
    x = _rand(ks[0], 32, 64).astype(dtype)
    mu = _rand(ks[1], 64, 48, scale=0.3).astype(dtype)
    sg = jnp.abs(_rand(ks[2], 64, 48, scale=0.1)).astype(dtype)
    eps = _rand(ks[3], 64, 48).astype(dtype)
    got = ops.bayes_matmul(x, mu, sg, eps, impl="pallas")
    want = ref.bayes_matmul(x, mu, sg, eps)
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


def test_bayes_matmul_zero_sigma_is_deterministic():
    """sigma=0 -> exactly the mean GEMM regardless of entropy."""
    ks = jax.random.split(jax.random.key(2), 3)
    x = _rand(ks[0], 16, 32)
    mu = _rand(ks[1], 32, 24)
    z = jnp.zeros((32, 24))
    for eps_scale in (0.0, 1.0, 100.0):
        eps = _rand(ks[2], 32, 24, scale=eps_scale)
        got = ops.bayes_matmul(x, mu, z, eps, impl="pallas")
        np.testing.assert_allclose(got, x @ mu, rtol=1e-5, atol=1e-4)


# ---------------------------------------------------------------------------
# lrt_matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [
    (8, 16, 8), (128, 256, 128), (40, 50, 60), (1, 128, 11),
])
def test_lrt_matmul_matches_ref(m, k, n):
    ks = jax.random.split(jax.random.key(3), 4)
    x = _rand(ks[0], m, k)
    mu = _rand(ks[1], k, n, scale=0.3)
    sg = jnp.abs(_rand(ks[2], k, n, scale=0.1))
    xi = _rand(ks[3], m, n)
    got = ops.lrt_matmul(x, mu, sg, xi, impl="pallas")
    want = ref.lrt_matmul(x, mu, sg, xi)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_lrt_moments_match_weight_space_sampling():
    """LRT and weight-space sampling share mean and variance (the local
    reparameterization theorem) — the statistical contract that lets the
    LM head replace per-sample weight draws with output-space noise."""
    key = jax.random.key(4)
    ks = jax.random.split(key, 3)
    m, k, n, S = 4, 32, 8, 4000
    x = _rand(ks[0], m, k)
    mu = _rand(ks[1], k, n, scale=0.3)
    sg = jnp.abs(_rand(ks[2], k, n, scale=0.2))

    eps = jax.random.normal(jax.random.key(5), (S, k, n))
    y_ws = jax.vmap(lambda e: ref.bayes_matmul(x, mu, sg, e))(eps)
    xi = jax.random.normal(jax.random.key(6), (S, m, n))
    y_lrt = jax.vmap(lambda z: ref.lrt_matmul(x, mu, sg, z))(xi)

    np.testing.assert_allclose(y_ws.mean(0), y_lrt.mean(0),
                               rtol=0.1, atol=0.15)
    np.testing.assert_allclose(y_ws.std(0), y_lrt.std(0),
                               rtol=0.15, atol=0.05)


# ---------------------------------------------------------------------------
# photonic_conv
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,t", [(1, 16), (8, 64), (5, 40), (16, 256)])
def test_photonic_conv_matches_ref(b, t):
    ks = jax.random.split(jax.random.key(7), 3)
    x = jax.random.uniform(ks[0], (b, t), minval=-1, maxval=1)
    mu = jax.random.uniform(ks[1], (9,), minval=-0.8, maxval=0.8)
    sg = jnp.abs(mu) * 0.2
    eps = jax.random.normal(ks[2], (b, t - 8, 9))
    got = ops.photonic_conv(x, mu, sg, eps, impl="pallas")
    want = ref.photonic_conv(x, mu, sg, eps)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_photonic_conv_matches_machine_twin():
    """Kernel == core.photonic.convolve with impairments disabled."""
    from repro.core.photonic import MachineConfig, convolve, ChannelProgram
    from repro.core import entropy as E
    cfg = MachineConfig(detector_noise=0.0, crosstalk=0.0, drift_std=0.0,
                        eom_mod_depth=0.0, gaussian_surrogate=True)
    key = jax.random.key(8)
    x = jax.random.uniform(key, (24,), minval=-1, maxval=1)
    mu = jnp.linspace(-0.5, 0.5, 9)
    bw = jnp.full((9,), 100.0)
    prog = ChannelProgram(power=mu, bandwidth=bw)
    y_machine = convolve(key, x, prog, cfg)
    # reproduce the machine's eps draw through the kernel interface
    m = E.modes_from_bandwidth(bw)
    sigma = jnp.abs(mu) / jnp.sqrt(m)
    eps = jax.random.normal(key, (1, 16, 9))
    y_kernel = ops.photonic_conv(x[None], mu, sigma,
                                 eps, impl="ref")
    assert y_machine.shape == (16,)
    assert y_kernel.shape == (1, 16)


# ---------------------------------------------------------------------------
# uncertainty_head
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,v,s", [
    (8, 16, 12, 4), (32, 64, 48, 10), (7, 33, 21, 3), (128, 128, 256, 10),
])
def test_uncertainty_head_matches_ref(m, k, v, s):
    ks = jax.random.split(jax.random.key(9), 4)
    x = _rand(ks[0], m, k)
    mu = _rand(ks[1], k, v, scale=0.2)
    sg = jnp.abs(_rand(ks[2], k, v, scale=0.05))
    xi = _rand(ks[3], s, m, v)
    got = ops.uncertainty_head(x, mu, sg, xi, impl="pallas")
    want = ref.uncertainty_head(x, mu, sg, xi)
    for name in ("H", "SE", "MI", "p_max"):
        np.testing.assert_allclose(got[name], want[name], rtol=1e-4,
                                   atol=1e-5, err_msg=name)
    np.testing.assert_array_equal(got["pred"], want["pred"])


def test_uncertainty_head_identities():
    """0 <= MI <= H <= log(V); SE = H - MI."""
    ks = jax.random.split(jax.random.key(10), 4)
    m, k, v, s = 64, 32, 10, 10
    out = ref.uncertainty_head(
        _rand(ks[0], m, k), _rand(ks[1], k, v, scale=0.5),
        jnp.abs(_rand(ks[2], k, v, scale=0.3)), _rand(ks[3], s, m, v))
    h, se, mi = out["H"], out["SE"], out["MI"]
    assert (mi >= -1e-6).all()
    assert (h <= np.log(v) + 1e-5).all()
    assert (mi <= h + 1e-6).all()
    np.testing.assert_allclose(se, h - mi, atol=1e-5)


# ---------------------------------------------------------------------------
# hypothesis property sweeps
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(m=st.integers(1, 40), k=st.integers(1, 64), n=st.integers(1, 40),
       seed=st.integers(0, 2**31 - 1))
def test_prop_bayes_matmul_any_shape(m, k, n, seed):
    ks = jax.random.split(jax.random.key(seed), 4)
    x = _rand(ks[0], m, k)
    mu = _rand(ks[1], k, n, scale=0.3)
    sg = jnp.abs(_rand(ks[2], k, n, scale=0.1))
    eps = _rand(ks[3], k, n)
    got = ops.bayes_matmul(x, mu, sg, eps, impl="pallas")
    want = ref.bayes_matmul(x, mu, sg, eps)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


@settings(max_examples=20, deadline=None)
@given(m=st.integers(1, 24), v=st.integers(2, 24), s=st.integers(1, 12),
       seed=st.integers(0, 2**31 - 1))
def test_prop_uncertainty_head_invariants(m, v, s, seed):
    ks = jax.random.split(jax.random.key(seed), 4)
    k = 16
    out = ops.uncertainty_head(
        _rand(ks[0], m, k), _rand(ks[1], k, v, scale=0.4),
        jnp.abs(_rand(ks[2], k, v, scale=0.2)), _rand(ks[3], s, m, v),
        impl="pallas")
    assert (out["MI"] >= -1e-6).all()
    assert (out["H"] >= out["MI"] - 1e-5).all()
    assert (out["H"] <= np.log(v) + 1e-4).all()
    assert ((out["pred"] >= 0) & (out["pred"] < v)).all()
    assert (out["p_max"] >= 1.0 / v - 1e-6).all()


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

def _naive_attention(q, k, v, causal=True):
    B, S, H, D = q.shape
    Hkv = k.shape[2]
    kk = jnp.repeat(k, H // Hkv, axis=2)
    vv = jnp.repeat(v, H // Hkv, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kk) / jnp.sqrt(jnp.float32(D))
    if causal:
        m = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(m[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vv)


@pytest.mark.parametrize("b,s,h,hkv,d,causal", [
    (1, 32, 4, 4, 16, True),
    (2, 70, 6, 2, 16, True),      # GQA, non-multiple seq
    (2, 64, 8, 1, 32, False),     # MQA, non-causal
    (1, 128, 2, 2, 64, True),
])
def test_flash_attention_kernel_matches_naive(b, s, h, hkv, d, causal):
    ks = jax.random.split(jax.random.key(20), 3)
    q = _rand(ks[0], b, s, h, d)
    k = _rand(ks[1], b, s, hkv, d)
    v = _rand(ks[2], b, s, hkv, d)
    got = ops.flash_attention(q, k, v, impl="pallas", causal=causal,
                              bq=16, bk=32)
    want = _naive_attention(q, k, v, causal)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_flash_attention_jnp_scope_matches_kernel():
    """The models' jnp flash path (named_scope 'fused_attention') and the
    Pallas kernel agree — the roofline's scope-skip accounting is backed
    by a real kernel with identical semantics."""
    from repro.models.layers import flash_attention as jnp_flash
    ks = jax.random.split(jax.random.key(21), 3)
    b, s, h, hkv, d = 2, 48, 4, 2, 16
    q = _rand(ks[0], b, s, h, d)
    k = _rand(ks[1], b, s, hkv, d)
    v = _rand(ks[2], b, s, hkv, d)
    a = jnp_flash(q, k, v, causal=True, q_chunk=16, kv_chunk=16)
    bb = ops.flash_attention(q, k, v, impl="pallas", causal=True,
                             bq=16, bk=16)
    np.testing.assert_allclose(a, bb, rtol=1e-4, atol=1e-5)


def test_flash_attention_q_offset_decode_window():
    """Continuation: last token of a prefix equals full-seq attention."""
    ks = jax.random.split(jax.random.key(22), 3)
    b, s, h, d = 1, 40, 2, 16
    q = _rand(ks[0], b, s, h, d)
    k = _rand(ks[1], b, s, h, d)
    v = _rand(ks[2], b, s, h, d)
    full = _naive_attention(q, k, v, causal=True)
    last = ops.flash_attention(q[:, -1:], k, v, impl="pallas",
                               causal=True, q_offset=s - 1, bq=8, bk=16)
    np.testing.assert_allclose(last[:, 0], full[:, -1], rtol=1e-4,
                               atol=1e-5)
