"""Paged KV cache: allocator, scheduler integration, dense parity.

The acceptance contract of the paged-KV rebuild (ISSUE 3):

  * the paged decode path is BIT-EXACT against ``--kv-layout dense`` in
    operand-entropy mode, including staggered mixed-length slots;
  * pool exhaustion defers admission (FIFO) instead of crashing;
  * eviction returns every block — the randomized admit/evict leak
    fuzz lives in test_block_fuzz.py;
  * the block-table gather reconstructs exactly the dense per-slot KV
    strip.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config, reduced
from repro.launch.serve import (BlockAllocator, Request, ServeEngine,
                                SlotScheduler)
from repro.models import layers as L
from repro.models import registry as M

from conftest import make_request as _req

# the shared (cfg, params, prompts) `setup` fixture lives in conftest.py


# ---------------------------------------------------------------------------
# BlockAllocator
# ---------------------------------------------------------------------------

class TestBlockAllocator:
    def test_reserve_alloc_free_roundtrip(self):
        a = BlockAllocator(8, block_size=4)
        assert a.blocks_for(1) == 1 and a.blocks_for(4) == 1
        assert a.blocks_for(5) == 2
        assert a.reserve(5)
        assert a.available() == 3
        ids = a.alloc(3)
        assert len(ids) == 3 and a.in_use == 3
        assert a.available() == 3           # 2 still reserved
        more = a.alloc(2)
        a.free(ids + more)
        a.unreserve(0)
        assert a.in_use == 0 and a.available() == 8

    def test_exhaustion_reports_unavailable_not_crash(self):
        a = BlockAllocator(4, block_size=2)
        assert a.reserve(3)
        assert not a.reserve(2)             # only 1 left: defer
        assert a.reserve(1)
        assert not a.reserve(1)

    def test_alloc_without_reservation_raises(self):
        a = BlockAllocator(4, block_size=2)
        with pytest.raises(ValueError, match="without reservation"):
            a.alloc(1)

    def test_double_free_raises(self):
        a = BlockAllocator(4, block_size=2)
        a.reserve(2)
        ids = a.alloc(2)
        a.free(ids)
        with pytest.raises(ValueError, match="double free"):
            a.free(ids)

    def test_peak_tracks_high_water_mark(self):
        a = BlockAllocator(8, block_size=2)
        a.reserve(6)
        ids = a.alloc(6)
        a.free(ids[3:])
        assert a.in_use == 3
        assert a.peak_in_use == 6


# ---------------------------------------------------------------------------
# SlotScheduler + allocator
# ---------------------------------------------------------------------------

def _paged_sched(num_slots=2, num_blocks=8, block=4, width=4):
    return SlotScheduler(num_slots,
                         allocator=BlockAllocator(num_blocks, block),
                         table_width=width)


class TestPagedScheduler:
    def test_admission_maps_prompt_blocks_only(self):
        s = _paged_sched()
        s.submit(_req(0, [1] * 6, 8))        # 2 prompt blocks, budget 4
        [(slot, req)] = s.admit()
        assert slot == 0
        row = s.block_tables[0]
        assert (row >= 0).sum() == 2         # ceil(6/4) mapped
        assert s.allocator.in_use == 2
        # decode blocks are NOT reserved up front: only the prompt's two
        # blocks leave the pool (the rest is a grant-time budget)
        assert s.allocator.available() == 8 - 2

    def test_grant_is_incremental_and_budget_capped(self):
        s = _paged_sched()
        s.submit(_req(0, [1] * 6, 8))
        s.admit()
        s.grant(0, 6 + 4)                    # one chunk deeper
        assert (s.block_tables[0] >= 0).sum() == 3
        s.grant(0, 10_000)                   # capped at the budget
        assert (s.block_tables[0] >= 0).sum() == 4
        assert s.allocator.in_use == 4

    def test_pool_exhaustion_defers_admission_fifo(self):
        s = _paged_sched(num_slots=2, num_blocks=4)
        s.submit(_req(0, [1] * 8, 4))        # budget 3 blocks
        s.submit(_req(1, [1] * 8, 4))        # budget 3 blocks: must wait
        placed = s.admit()
        assert [r.rid for _, r in placed] == [0]
        assert s.admit() == []               # deferred, queue intact
        assert s.queue[0].rid == 1
        s.evict(0)
        placed = s.admit()                   # blocks back -> head admits
        assert [r.rid for _, r in placed] == [1]

    # randomized admit/grant/evict churn lives in test_block_fuzz.py now:
    # the property-based interpreter there checks the exact refcount
    # identity after every op instead of only at drain time


# ---------------------------------------------------------------------------
# block-table gather vs dense strips
# ---------------------------------------------------------------------------

class TestPagedGather:
    def test_gather_reconstructs_dense_strip_for_staggered_slots(self,
                                                                 setup):
        """write_slot through the (block, offset) indirection followed by
        paged_gather must reproduce the dense per-slot KV strips exactly,
        with slots mapped to disjoint out-of-order physical blocks."""
        cfg, params, prompts = setup
        bs, max_len = 8, 24
        mb = max_len // bs
        dense = M.make_cache(cfg, 2, max_len)
        paged = M.make_cache(cfg, 2, max_len, layout="paged", kv_block=bs,
                             num_blocks=2 * mb)
        rows = {0: [5, 1, 3], 1: [0, 4, 2]}  # deliberately shuffled
        lens = [12, 8]                       # staggered depths
        for slot, plen in enumerate(lens):
            _, sub_d = M.prefill(params, cfg,
                                 jnp.asarray(prompts[slot:slot + 1, :plen]),
                                 max_len)
            dense = M.write_slot(cfg, dense, jnp.asarray(slot, jnp.int32),
                                 sub_d)
            _, sub_p = M.prefill(params, cfg,
                                 jnp.asarray(prompts[slot:slot + 1, :plen]),
                                 plen)
            paged = M.write_slot(cfg, paged, jnp.asarray(slot, jnp.int32),
                                 sub_p, jnp.asarray(rows[slot], jnp.int32))
        np.testing.assert_array_equal(np.asarray(paged["len"]),
                                      np.asarray(dense["len"]))
        for name in ("k", "v"):
            for layer in range(cfg.num_layers):
                got = np.asarray(L.paged_gather(paged[name][layer],
                                                paged["block_table"]))
                want = np.asarray(dense[name][layer])
                for slot, plen in enumerate(lens):
                    np.testing.assert_array_equal(got[slot, :plen],
                                                  want[slot, :plen])

    def test_scatter_drops_out_of_table_writes(self):
        pool = jnp.zeros((2, 4, 3))          # 2 blocks of 4 tokens
        table = jnp.asarray([[1, -1]])       # slot 0: one mapped block
        new = jnp.ones((1, 2, 3))
        # append at depth 3: token 0 -> (block 1, off 3), token 1 ->
        # logical block 1 which is unmapped -> dropped
        out = L.paged_scatter(pool, table, jnp.asarray([3]), new)
        assert float(out[1, 3].sum()) == 3.0
        assert float(out.sum()) == 3.0
        # append past the table entirely -> everything drops
        out = L.paged_scatter(pool, table, jnp.asarray([8]), new)
        assert float(out.sum()) == 0.0


# ---------------------------------------------------------------------------
# engine: paged vs dense bit-exactness + deferral under a small pool
# ---------------------------------------------------------------------------

class TestPagedEngine:
    def _mixed_requests(self, prompts):
        gens = (8, 4, 8, 6, 8, 5)
        return [_req(i, prompts[i][:(12 if i % 2 == 0 else 8)], gens[i])
                for i in range(6)]

    def test_paged_matches_dense_staggered(self, setup):
        """Same mixed-length queue through both layouts (max_len a block
        multiple => equal logical spans): every request's token and MI
        streams must match bit for bit, and the paged peak residency
        must undercut the dense strips."""
        cfg, params, prompts = setup
        max_len = 32                          # multiple of kv_block=8
        dense = ServeEngine(params, cfg, num_slots=2, max_len=max_len,
                            chunk=4)
        rd = dense.run(self._mixed_requests(prompts))
        paged = ServeEngine(params, cfg, num_slots=2, max_len=max_len,
                            chunk=4, kv_layout="paged", kv_block=8)
        rp = paged.run(self._mixed_requests(prompts))
        for a, b in zip(rd["requests"], rp["requests"]):
            np.testing.assert_array_equal(a.tokens, b.tokens)
            np.testing.assert_array_equal(np.asarray(a.MI, np.float32),
                                          np.asarray(b.MI, np.float32))
            np.testing.assert_array_equal(np.asarray(a.H, np.float32),
                                          np.asarray(b.H, np.float32))
        assert rp["kv"]["bytes_in_use_peak"] < rd["kv"]["bytes_in_use_peak"]
        assert rp["kv"]["bytes_dense_equiv"] == \
            rd["kv"]["bytes_in_use_peak"]

    def test_pool_exhaustion_defers_and_still_drains(self, setup):
        """A pool that fits one request at a time serializes admissions
        but every request still completes, within the pool bound."""
        cfg, params, prompts = setup
        engine = ServeEngine(params, cfg, num_slots=2, max_len=32,
                             chunk=4, kv_layout="paged", kv_block=8,
                             kv_blocks=3)
        res = engine.run(self._mixed_requests(prompts))
        assert all(r.finish_reason == "length" for r in res["requests"])
        assert res["kv"]["blocks_peak"] <= 3

    def test_impossible_request_rejected_upfront(self, setup):
        cfg, params, prompts = setup
        engine = ServeEngine(params, cfg, num_slots=2, max_len=32,
                             chunk=4, kv_layout="paged", kv_block=8,
                             kv_blocks=2)
        with pytest.raises(ValueError, match="never be admitted"):
            engine.run([_req(0, prompts[0], 8)])   # needs 3 > 2 blocks

    def test_ssm_family_falls_back_to_dense(self):
        cfg = reduced(get_config("mamba2_370m"))
        params = M.init_params(jax.random.key(1), cfg)
        engine = ServeEngine(params, cfg, num_slots=2, max_len=16,
                             chunk=4, kv_layout="paged", kv_block=8)
        assert engine.kv_layout == "dense"
        toks = np.asarray(jax.random.randint(jax.random.key(2), (2, 6),
                                             0, cfg.vocab_size), np.int32)
        res = engine.run([_req(i, toks[i], 4) for i in range(2)])
        assert res["kv"]["layout"] == "dense"
        assert all(len(r.tokens) == 4 for r in res["requests"])
