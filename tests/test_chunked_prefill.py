"""Chunked prefill + growable block tables (ISSUE 6).

The acceptance contract:

  * ``--prefill chunked`` decode streams are BIT-EXACT against the
    ``--prefill batch`` reference in operand-entropy mode on staggered
    mixed-length traffic, across every chunk-capable family — including
    ``--prefix-cache on`` after a copy-on-write divergence;
  * chunk sizes are invariant: any ``--prefill-chunk`` (and any decode
    ``--chunk``) produces the same streams;
  * block tables GROW on demand — a request whose prompt + gen exceeds
    the admission-time table span still completes, bit-exact vs batch;
  * a growth grant the pool cannot cover LRU-evicts cached-but-
    unreferenced prefix blocks before preempting (no livelock);
  * allocator/scheduler churn through the growth path leaks nothing —
    the randomized leak fuzz lives in test_block_fuzz.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import family_setup
from repro.kernels import ops
from repro.launch.serve import (BlockAllocator, Request, ServeEngine,
                                SlotScheduler)
from repro.models import registry as M

# the chunk-capable subset of conftest.FAMILY_ARCHS
CHUNK_FAMILIES = ("dense", "encdec", "hybrid", "moe")


def _reqs(cfg, lens, gen=8, seed=7):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab_size - 1,
                                        size=n).astype(np.int32),
                    max_new_tokens=gen)
            for i, n in enumerate(lens)]


def _run(params, cfg, lens, mode, *, pc=8, chunk=4, gen=8, max_len=None,
         kv_blocks=None, prefix=False, slots=2, seed=7):
    eng = ServeEngine(params, cfg, num_slots=slots,
                      max_len=max_len or max(lens) + gen + chunk,
                      chunk=chunk, kv_layout="paged", kv_block=4,
                      kv_blocks=kv_blocks, prefix_cache=prefix,
                      prefill_mode=mode, prefill_chunk=pc)
    return eng.run(_reqs(cfg, lens, gen=gen, seed=seed))


def _assert_same_streams(ra, rb):
    for a, b in zip(ra["requests"], rb["requests"]):
        np.testing.assert_array_equal(a.tokens, b.tokens)
        for name in ("H", "SE", "MI", "p_max"):
            np.testing.assert_array_equal(
                np.asarray(getattr(a, name), np.float32),
                np.asarray(getattr(b, name), np.float32))
        assert a.finish_reason == b.finish_reason


# ---------------------------------------------------------------------------
# chunked == batch, every chunk-capable family
# ---------------------------------------------------------------------------

class TestChunkedMatchesBatch:
    @pytest.mark.parametrize("family", sorted(CHUNK_FAMILIES))
    def test_staggered_mixed_lengths(self, family):
        """Uneven prompts forcing partial chunks, bucket pads, and
        mid-stream admissions: streams must match batch bit for bit."""
        cfg, params, _ = family_setup(family)
        lens = [13, 27, 5, 18]
        ra = _run(params, cfg, lens, "batch")
        rb = _run(params, cfg, lens, "chunked")
        assert rb["prefill_mode"] == "chunked"
        assert rb["prefill_chunks"] > 0
        _assert_same_streams(ra, rb)

    def test_prefix_cache_cow_traffic(self):
        """Shared prefixes admitted through the radix cache: chunked
        prefill walks only the uncached suffix, after the admission-time
        CoW — still bit-exact vs batch."""
        cfg, params, _ = family_setup("dense")
        rng = np.random.default_rng(11)
        shared = rng.integers(1, cfg.vocab_size - 1, size=16)
        reqs_spec = []                       # prefix reuse + divergence
        for i, (cut, extra) in enumerate([(16, 5), (16, 5), (10, 9),
                                          (16, 2)]):
            p = np.concatenate([shared[:cut],
                                rng.integers(1, cfg.vocab_size - 1,
                                             size=extra)])
            reqs_spec.append(p.astype(np.int32))

        def run(mode):
            eng = ServeEngine(params, cfg, num_slots=2, max_len=36,
                              chunk=4, kv_layout="paged", kv_block=4,
                              prefix_cache=True, prefill_mode=mode,
                              prefill_chunk=8)
            return eng.run([Request(rid=i, prompt=p, max_new_tokens=6)
                            for i, p in enumerate(reqs_spec)])

        ra, rb = run("batch"), run("chunked")
        assert rb["prefix_cache"]["hits"] > 0
        assert rb["prefix_cache"]["cow_copies"] > 0
        _assert_same_streams(ra, rb)

    def test_prefill_chunk_size_invariance(self):
        cfg, params, _ = family_setup("dense")
        lens = [13, 27, 5]
        r8 = _run(params, cfg, lens, "chunked", pc=8)
        r32 = _run(params, cfg, lens, "chunked", pc=32)
        assert r8["prefill_chunks"] > r32["prefill_chunks"]
        _assert_same_streams(r8, r32)

    def test_decode_chunk_size_invariance(self):
        cfg, params, _ = family_setup("dense")
        lens = [13, 18]
        r4 = _run(params, cfg, lens, "chunked", chunk=4, max_len=36)
        r16 = _run(params, cfg, lens, "chunked", chunk=16, max_len=36)
        _assert_same_streams(r4, r16)

    def test_chunked_requires_paged(self):
        cfg, params, _ = family_setup("dense")
        with pytest.raises(ValueError, match="paged"):
            ServeEngine(params, cfg, num_slots=1, max_len=16,
                        prefill_mode="chunked")


# ---------------------------------------------------------------------------
# growable block tables
# ---------------------------------------------------------------------------

class TestTableGrowth:
    def test_request_outgrows_admission_span(self):
        """prompt + gen far beyond the admission-time table width: the
        table widens on demand and the stream still matches batch."""
        cfg, params, _ = family_setup("dense")
        kw = dict(gen=12, max_len=16, kv_blocks=40)  # width 4 blocks
        ra = _run(params, cfg, [40, 6], "batch", **kw)
        rb = _run(params, cfg, [40, 6], "chunked", **kw)
        assert ra["table_growths"] > 0 and rb["table_growths"] > 0
        assert all(len(r.tokens) == 12 for r in rb["requests"])
        _assert_same_streams(ra, rb)

    def test_scheduler_widens_tables_on_grant(self):
        s = SlotScheduler(2, allocator=BlockAllocator(16, 4),
                          table_width=2, watermark=0)
        s.submit(Request(rid=0, prompt=np.ones(6, np.int32),
                         max_new_tokens=40))
        [(slot, req)] = s.admit()
        assert s.block_tables.shape[1] == 2
        ids = s.grant(slot, 30)              # 8 blocks > width 2
        assert ids and s.block_tables.shape[1] >= 8
        assert s.table_growths >= 1
        assert (s.block_tables[slot] >= 0).sum() == 8

    def test_growth_grant_evicts_cached_blocks_before_preempt(self):
        """Livelock regression: every free block is held by cached-but-
        unreferenced prefixes; a decoder's growth grant must reclaim
        them via LRU eviction, not fail into preemption forever."""
        from repro.launch.prefix_cache import RadixPrefixCache
        alloc = BlockAllocator(4, 4)
        pcache = RadixPrefixCache(alloc, 4)
        s = SlotScheduler(1, allocator=alloc, table_width=4,
                          prefix_cache=pcache, watermark=0)
        # request A runs, evicts: its 2 prompt blocks go to the tree
        s.submit(Request(rid=0, prompt=np.ones(8, np.int32),
                         max_new_tokens=4))
        [(slot, _)] = s.admit()
        s.evict(slot)
        assert pcache.cached_blocks() == 2
        # request B (different prompt) admits cold into the remaining
        # pool, then needs growth the cached blocks are sitting on
        s.submit(Request(rid=1, prompt=np.full(8, 2, np.int32),
                         max_new_tokens=16))
        [(slot, req)] = s.admit()
        assert alloc.available() == 0        # 2 held + 2 cached... all gone
        ids = s.grant(slot, 8 + 8)           # needs 2 more blocks
        assert ids is not None and len(ids) == 2
        assert pcache.cached_blocks() == 0   # LRU-reclaimed, not deadlocked
        assert pcache.evictions >= 1

    def test_preemption_requeues_and_completes(self):
        """A pool too small for two full streams preempts, requeues at
        the FIFO front, and still finishes every request."""
        cfg, params, _ = family_setup("dense")
        r = _run(params, cfg, [8, 8, 8], "chunked", gen=16, max_len=32,
                 kv_blocks=8)
        assert r["preemptions"] > 0
        assert all(x.finish_reason == "length" for x in r["requests"])
        assert all(len(x.tokens) == 16 for x in r["requests"])

    # randomized growth/preempt churn lives in test_block_fuzz.py now:
    # the property-based interpreter there drives the same grant-outruns-
    # width path with per-op refcount and table-mirror invariants

    def test_watermark_defers_admission_but_not_first(self):
        """Admission keeps `watermark` free blocks for running slots'
        grants — waived when nothing runs so the head always starts."""
        s = SlotScheduler(2, allocator=BlockAllocator(4, 4),
                          table_width=4, watermark=2)
        s.submit(Request(rid=0, prompt=np.ones(8, np.int32),
                         max_new_tokens=4))
        s.submit(Request(rid=1, prompt=np.ones(4, np.int32),
                         max_new_tokens=4))
        placed = s.admit()
        # slot 0 admits (waived watermark); rid 1 would leave only 1
        # free < watermark 2 -> deferred even though its block exists
        assert [r.rid for _, r in placed] == [0]
        assert s.queue[0].rid == 1
        s.evict(0)
        assert [r.rid for _, r in s.admit()] == [1]


# ---------------------------------------------------------------------------
# multi-query paged prefill kernel vs gather+flash reference
# ---------------------------------------------------------------------------

class TestPagedPrefillKernel:
    def test_kernel_matches_reference_jitted(self):
        """The query-span-tiled Pallas prefill kernel (interpret mode off
        TPU) against the gather+flash reference, both jitted, over
        partial blocks and a GQA head layout."""
        H, Hkv, D, BS = 4, 2, 8, 4
        key = jax.random.PRNGKey(0)
        for S, span, nblk in [(5, 17, 5), (1, 9, 3), (8, 8, 2)]:
            ks = jax.random.split(jax.random.fold_in(key, span), 3)
            q = jax.random.normal(ks[0], (1, S, H, D), jnp.float32)
            pool_k = jax.random.normal(ks[1], (8, BS, Hkv, D), jnp.float32)
            pool_v = jax.random.normal(ks[2], (8, BS, Hkv, D), jnp.float32)
            row = jnp.full((1, 8), -1, jnp.int32)
            row = row.at[0, :nblk].set(jnp.arange(nblk)[::-1])
            off = jnp.asarray(span - S, jnp.int32)
            ref = ops.paged_prefill_attention(q, pool_k, pool_v, row, off,
                                              span=span, impl="ref")
            got = ops.paged_prefill_attention(q, pool_k, pool_v, row, off,
                                              span=span, impl="kernel")
            # separately-jitted programs may differ in the last ulp on
            # CPU (XLA fuses each jaxpr independently); the bitwise
            # serving guarantee lives on the gather path, asserted
            # stream-level above
            np.testing.assert_allclose(np.asarray(ref), np.asarray(got),
                                       rtol=3e-7, atol=3e-7)
