"""Mesh-sharded serving runner: bit-exactness, placement, fallbacks.

The acceptance contract of serve tensor parallelism: a ``--mesh``
runner on a forced-host multi-device CPU mesh replays the unsharded
engine's token AND uncertainty streams bit-for-bit (operand-entropy
mode) under staggered continuous-batching traffic, for every attention
family.  The parity drive runs ``launch.engine.mesh_check`` in a
SUBPROCESS because ``XLA_FLAGS=--xla_force_host_platform_device_count``
must be pinned before jax initializes, and this test process already
holds a 1-device jax.
"""

import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from conftest import family_setup
from repro.launch.engine import ServeEngine, Request, resolve_mesh
from repro.launch.mesh import make_debug_mesh
from repro.sharding.partition import serve_pspecs

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_mesh_check(families: str) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.engine.mesh_check",
         "--families", families, "--json"],
        capture_output=True, text=True, env=env, timeout=540, cwd=REPO)
    assert out.returncode == 0, out.stdout + out.stderr
    return json.loads(out.stdout.strip().splitlines()[-1])


# ---------------------------------------------------------------------------
# sharded-vs-unsharded parity on a real 4-device mesh (subprocess)
# ---------------------------------------------------------------------------

class TestShardedParity:
    def test_dense_and_moe_bitwise(self):
        # dense: prefix cache + CoW + chunked prefill on the sharded
        # runner; moe: Hkv=4 divides the model axis, so the paged KV
        # pool really shards (the batch-dim exactness case)
        rec = _run_mesh_check("dense,moe")
        assert rec["ok"]
        assert rec["mesh_devices"] == 4
        for fam, r in rec["families"].items():
            assert r["bitwise_equal"], (fam, r["errors"])
        assert rec["families"]["dense"]["prefix_cache_hits"] > 0

    def test_hybrid_and_encdec_bitwise(self):
        # hybrid: replicated ssm state interleaved with sharded
        # attention; encdec: cross-attention K/V through make_cross_kv
        rec = _run_mesh_check("hybrid,encdec")
        assert rec["ok"]
        for fam, r in rec["families"].items():
            assert r["bitwise_equal"], (fam, r["errors"])


# ---------------------------------------------------------------------------
# serve-TP partition rules (no mesh needed)
# ---------------------------------------------------------------------------

class TestServeRules:
    def test_column_parallel_only(self):
        _, params, _ = family_setup("dense")
        specs = serve_pspecs(params)
        blocks = specs["blocks"]["attn"]
        # column (output) dims shard...
        for name in ("wq", "wk", "wv"):
            assert blocks[name] == P(None, None, "model")
        assert blocks["bq"] == P(None, "model")
        assert specs["blocks"]["mlp"]["w1"] == P(None, None, "model")
        assert specs["head"]["q"].mu == P(None, "model")
        # ...every contraction-feeding weight replicates (a row-parallel
        # shard would end in a partial-sum all-reduce: not bitwise)
        assert blocks["wo"] == P()
        assert specs["blocks"]["mlp"]["w2"] == P()
        assert specs["embed"]["table"] == P()

    def test_moe_and_ssm_subtrees_replicate(self):
        for family in ("moe", "hybrid"):
            _, params, _ = family_setup(family)
            flat = jax.tree_util.tree_flatten_with_path(
                serve_pspecs(params),
                is_leaf=lambda x: isinstance(x, P))[0]
            for kp, spec in flat:
                path = "/".join(str(getattr(k, "key", k)) for k in kp)
                if any(t in path for t in ("experts", "router", "in_proj",
                                           "out_proj", "conv_", "A_log",
                                           "dt_")):
                    assert spec == P(), (path, spec)


# ---------------------------------------------------------------------------
# mesh construction + single-device degradation (in-process)
# ---------------------------------------------------------------------------

class TestMeshFallback:
    def test_debug_mesh_falls_back_to_1d(self):
        # (1, 4) cannot tile this 1-CPU process: 1D ("model",) fallback
        mesh = make_debug_mesh((1, 4), ("data", "model"))
        assert mesh.axis_names == ("model",)
        assert mesh.devices.size == len(jax.devices())

    def test_resolve_mesh_flag_forms(self):
        assert resolve_mesh(None) is None
        assert resolve_mesh("") is None
        assert resolve_mesh("none") is None
        with pytest.raises(ValueError):
            resolve_mesh("4")

    def test_one_device_mesh_engine_matches_meshless(self):
        # on one device every serve spec degrades to replication, so
        # --mesh must be a bitwise no-op (this is what lets the CI
        # serve-smoke matrix pass the flag unconditionally)
        cfg, params, _ = family_setup("dense")

        def reqs():
            prompts = np.asarray(jax.random.randint(
                jax.random.key(1), (3, 10), 0, cfg.vocab_size), np.int32)
            return [Request(rid=i, prompt=prompts[i], max_new_tokens=5)
                    for i in range(3)]

        kw = dict(num_slots=2, max_len=24, chunk=4, kv_layout="paged",
                  kv_block=8, kv_blocks=10)
        ref = ServeEngine(params, cfg, **kw).run(reqs())
        got = ServeEngine(params, cfg, mesh=resolve_mesh("1x4"),
                          **kw).run(reqs())
        for a, b in zip(ref["requests"], got["requests"]):
            assert a.tokens == b.tokens
            for f in ("H", "SE", "MI", "p_max"):
                assert getattr(a, f) == getattr(b, f)
