"""Fault tolerance: crash/resume equivalence, straggler detection,
elastic restore, launcher step-builders."""

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import steps as S
from repro.launch import train as T
from repro.configs.registry import get_config, reduced
from repro.core.svi import SVIConfig
from repro.models import registry as M
from repro.optim import adamw


def _args(**kw):
    base = dict(arch="qwen2_1_5b", reduced=True, steps=10, batch=2,
                seq=16, lr=1e-3, micro_batches=1, compress_topk=0.0,
                seed=0, ckpt_dir=None, ckpt_every=4, resume=False,
                fail_at_step=None)
    base.update(kw)
    return argparse.Namespace(**base)


class TestCrashResume:
    def test_resume_is_bit_exact(self, tmp_path):
        """Train 10 steps straight vs. crash-at-6 + resume: identical
        losses after the restart point (deterministic data stream + step-
        keyed PRNG makes this exact, not approximate)."""
        ref = T.train(_args(ckpt_dir=str(tmp_path / "a")))

        with pytest.raises(RuntimeError, match="injected failure"):
            T.train(_args(ckpt_dir=str(tmp_path / "b"), fail_at_step=6))
        out = T.train(_args(ckpt_dir=str(tmp_path / "b"), resume=True))

        # resumed run re-executes steps 4..9 (last ckpt at step 4)
        np.testing.assert_allclose(ref["history"][4:], out["history"],
                                   rtol=1e-5)

    def test_resume_skips_completed_work(self, tmp_path):
        T.train(_args(steps=8, ckpt_dir=str(tmp_path)))
        out = T.train(_args(steps=8, ckpt_dir=str(tmp_path), resume=True))
        assert out["history"] == []  # nothing left to do


class TestStraggler:
    def test_monitor_flags_slow_step(self):
        m = T.StragglerMonitor(factor=3.0)
        for _ in range(8):
            assert not m.observe(0.1)
        assert m.observe(1.0)
        assert m.flagged == 1

    def test_monitor_tolerates_jitter(self):
        m = T.StragglerMonitor(factor=3.0)
        rng = np.random.default_rng(0)
        flags = [m.observe(0.1 + 0.05 * rng.random()) for _ in range(50)]
        assert sum(flags) == 0


class TestStepBuilders:
    def test_train_step_decreases_loss(self):
        cfg = reduced(get_config("qwen2_1_5b"))
        opt_cfg = adamw.AdamWConfig(lr=3e-3, warmup_steps=0,
                                    schedule="constant")
        step_fn = jax.jit(S.build_train_step(
            cfg, opt_cfg, SVIConfig(num_train_examples=100_000)))
        key = jax.random.key(0)
        params = M.init_params(key, cfg)
        state = {"params": params, "opt": adamw.init_state(params, opt_cfg)}
        batch = M.make_batch(key, cfg, 4, 32)  # overfit one batch
        losses = []
        for _ in range(20):
            state, metrics = step_fn(state, batch)
            losses.append(float(metrics["loss"]))
        assert losses[-1] < losses[0] - 0.3

    def test_micro_batching_matches_full_batch_grads(self):
        """4-way accumulation == single big batch (same loss trajectory
        up to fp tolerance) when the per-microbatch keys are folded the
        same way is NOT expected; instead we assert the accumulated loss
        equals the mean of per-microbatch losses."""
        cfg = reduced(get_config("qwen2_1_5b"))
        opt_cfg = adamw.AdamWConfig(lr=0.0, warmup_steps=0,
                                    schedule="constant", weight_decay=0.0)
        svi = SVIConfig(num_train_examples=1e9)  # KL ~ 0
        key = jax.random.key(1)
        params = M.init_params(key, cfg)
        batch = M.make_batch(key, cfg, 8, 16)

        s1 = S.build_train_step(cfg, opt_cfg, svi, micro_batches=1)
        s4 = S.build_train_step(cfg, opt_cfg, svi, micro_batches=4)
        st = {"params": params, "opt": adamw.init_state(params, opt_cfg)}
        _, m1 = jax.jit(s1)(st, batch)
        _, m4 = jax.jit(s4)(st, batch)
        # different MC keys per microbatch, but with lr=0 params don't
        # move; NLL is key-dependent only through the single head draw,
        # so compare within a loose band
        assert abs(float(m1["loss"]) - float(m4["loss"])) < 0.5

    def test_input_specs_cover_all_cells(self):
        from repro.configs.base import SHAPE_CELLS, cell_applicable
        from repro.configs.registry import ARCH_IDS
        n = 0
        for a in ARCH_IDS:
            cfg = get_config(a)
            for cell in SHAPE_CELLS.values():
                if not cell_applicable(cfg, cell)[0]:
                    continue
                specs = S.input_specs(cfg, cell)
                assert specs, (a, cell.name)
                n += 1
                if cell.kind == "train":
                    t = specs["batch"]["tokens"]
                    assert t.shape == (cell.global_batch, cell.seq_len)
                else:
                    leaves = jax.tree.leaves(specs)
                    assert all(hasattr(l, "shape") for l in leaves)
        assert n == 32  # 10 archs x 4 shapes - 8 long_500k skips

    def test_decode_step_emits_uncertainty(self):
        cfg = reduced(get_config("qwen2_1_5b"))
        key = jax.random.key(2)
        params = M.init_params(key, cfg)
        _, cache = M.prefill(params, cfg,
                             jnp.zeros((2, 8), jnp.int32), 16)
        fn = jax.jit(S.build_decode_step(cfg))
        out, cache2 = fn(params, jnp.zeros((2,), jnp.int32), cache,
                         jnp.asarray(0, jnp.int32))
        assert set(out) >= {"next_token", "H", "SE", "MI", "p_max"}
        # different step -> different MC noise -> different uncertainty
        out2, _ = fn(params, jnp.zeros((2,), jnp.int32), cache,
                     jnp.asarray(1, jnp.int32))
        assert not np.allclose(np.asarray(out["MI"]),
                               np.asarray(out2["MI"]))


class TestGradCompression:
    def test_compressed_training_still_converges(self):
        cfg = reduced(get_config("qwen2_1_5b"))
        opt_cfg = adamw.AdamWConfig(lr=3e-3, warmup_steps=0,
                                    schedule="constant", compress_topk=0.3)
        step_fn = jax.jit(S.build_train_step(
            cfg, opt_cfg, SVIConfig(num_train_examples=1e8)))
        key = jax.random.key(3)
        params = M.init_params(key, cfg)
        state = {"params": params, "opt": adamw.init_state(params, opt_cfg)}
        batch = M.make_batch(key, cfg, 4, 16)
        losses = []
        for _ in range(15):
            state, metrics = step_fn(state, batch)
            losses.append(float(metrics["loss"]))
        assert losses[-1] < losses[0]


class TestDryrunParsing:
    def test_parse_collectives_synthetic_hlo(self):
        from repro.launch.dryrun import parse_collectives
        hlo = """
  %p0 = bf16[128,256]{1,0} parameter(0)
  %ag = bf16[2048,256]{1,0} all-gather(%p0), replica_groups={{0,1}}
  %ar = f32[64,64]{1,0} all-reduce(%sum), to_apply=%add
  %sum = f32[64,64]{1,0} add(%p0, %p0)
  %rs = f32[4,64]{1,0} reduce-scatter(%ar), dimensions={0}
"""
        out = parse_collectives(hlo)
        assert out["all-gather"]["count"] == 1
        # received bytes = result - operand = (2048-128)*256*2
        assert out["all-gather"]["bytes"] == (2048 - 128) * 256 * 2
        assert out["all-reduce"]["bytes"] == 2 * 64 * 64 * 4
        assert out["reduce-scatter"]["bytes"] == (64 - 4) * 64 * 4
        assert out["total_link_bytes"] > 0

    def test_type_bytes(self):
        from repro.launch.dryrun import _type_bytes
        assert _type_bytes("bf16[8,128]") == 8 * 128 * 2
        assert _type_bytes("(f32[4], s32[2,2])") == 16 + 16
        assert _type_bytes("f32[]") == 0 or True  # scalars: dims empty
