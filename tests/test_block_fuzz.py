"""Property-based fuzz of the paged-KV block bookkeeping stack.

A random op interpreter drives ``SlotScheduler`` + ``BlockAllocator``
(+ optionally ``RadixPrefixCache``) through admit / grant / rollback /
CoW / preempt / evict / LRU-evict sequences and asserts the EXACT
refcount identity after every single op:

    refcount(b) == (#slot tables mapping b)
                 + (1 if the radix tree holds b)
                 + (#slots holding b as a pending CoW source)

plus free-list integrity (duplicate-free, disjoint from every held
block, partitions the pool), zero leftover reservations between ops,
and host block tables mirroring ``_slot_blocks`` row for row.  After
the op sequence the machine drains and the whole pool must be back on
the free list.

This is the main leak defense for the allocator stack — the fixed-seed
200-cycle churn loops it replaces only ever sampled one trajectory
each.  ``test_churn_smoke`` replays the same interpreter from a fixed
seed so bare environments without hypothesis still execute it; the
``@given`` property test explores adversarial orderings (and shrinks
failures) wherever hypothesis is installed.
"""

import collections
import random

import numpy as np

from _hypothesis_compat import given, settings, st
from repro.launch.engine.block_pool import BlockAllocator
from repro.launch.engine.policy import get_policy
from repro.launch.engine.scheduler import Request, SlotScheduler
from repro.launch.prefix_cache import RadixPrefixCache

NUM_SLOTS = 3
NUM_BLOCKS = 16
BLOCK = 4

# small token universe with shared stems so random prompts naturally
# produce full-block hits, token-granular partials (-> CoW), and cold
# misses against the radix tree
_TEMPLATES = ([1] * 12, [1] * 4 + [2] * 8, [3] * 12)


class _Machine:
    """Interprets (op, a, b) triples against one scheduler stack.

    ``a``/``b`` are free integers the ops fold into choices (which
    slot, what target length, finish the CoW now or later) so a flat
    list of triples reaches every interesting interleaving.
    """

    def __init__(self, use_cache: bool, use_priority: bool = False):
        self.alloc = BlockAllocator(NUM_BLOCKS, BLOCK)
        self.cache = RadixPrefixCache(self.alloc, BLOCK) if use_cache \
            else None
        self.sched = SlotScheduler(NUM_SLOTS, allocator=self.alloc,
                                   table_width=2,
                                   prefix_cache=self.cache,
                                   policy=get_policy(
                                       "priority" if use_priority
                                       else "fifo"))
        self.rid = 0
        self.prefix_hits = 0
        self.pending_cow: set[int] = set()

    # -- ops --------------------------------------------------------------

    def _submit(self, a, b, priority=2):
        t = _TEMPLATES[a % len(_TEMPLATES)]
        plen = 1 + b % len(t)
        self.sched.submit(Request(rid=self.rid,
                                  prompt=np.asarray(t[:plen], np.int32),
                                  max_new_tokens=1 + a % 8,
                                  priority=priority))
        self.rid += 1

    def _submit_hi(self, a, b):
        # a class-0 candidate: under the priority policy its admission
        # may preempt a strictly-worse DECODING slot (see _activate)
        self._submit(a, b, priority=0)

    def _admit(self, a, b):
        placed = self.sched.admit()
        # the policy may have preempted decoding slots to place better
        # candidates — their pending CoW sources died with the evict
        # (a re-placed slot can re-enter pending_cow just below)
        for slot, _ in self.sched.take_preempted():
            self.pending_cow.discard(slot)
        for slot, _ in placed:
            info = self.sched.prefix_admit(slot)
            if info is None:
                continue
            self.prefix_hits += info.tokens > 0
            if info.cow is not None:
                if b % 2:                    # engine copies immediately...
                    self.sched.finish_cow(slot)
                else:                        # ...or the copy is in flight
                    self.pending_cow.add(slot)

    def _finish_cow(self, a, b):
        if self.pending_cow:
            slot = sorted(self.pending_cow)[a % len(self.pending_cow)]
            self.pending_cow.discard(slot)
            self.sched.finish_cow(slot)

    def _grant(self, a, b):
        active = self.sched.active()
        if not active:
            return
        slot, req = active[a % len(active)]
        # overshoot past the budget on purpose: grant must cap, not leak
        target = len(req.prompt) + b % (req.max_new_tokens + 9)
        if self.sched.grant(slot, target) is None:
            self.pending_cow.discard(slot)   # preempt frees the CoW src
            self.sched.preempt(slot)

    def _rollback(self, a, b):
        active = self.sched.active()
        if not active:
            return
        slot, req = active[a % len(active)]
        # >= prompt + 1 by the engine's construction: only ever drops
        # decode-granted (exclusively owned) blocks, never shared ones
        target = len(req.prompt) + 1 + b % req.max_new_tokens
        self.sched.rollback(slot, target)

    def _evict(self, a, b):
        active = self.sched.active()
        if not active:
            return
        slot, _ = active[a % len(active)]
        self.pending_cow.discard(slot)
        self.sched.evict(slot)

    def _preempt(self, a, b):
        active = self.sched.active()
        if not active:
            return
        slot, _ = active[a % len(active)]
        self.pending_cow.discard(slot)
        self.sched.preempt(slot)

    def _evict_lru(self, a, b):
        if self.cache is not None:
            self.cache.evict_lru(1 + a % 4, protect=frozenset())

    def _activate(self, a, b):
        # engine's activate(): a prefilled slot starts decoding — the
        # ONLY state the priority policy may claim as a victim
        prefilling = [(s, r) for s, r in self.sched.active()
                      if r.state == "prefilling"]
        if prefilling:
            _, req = prefilling[a % len(prefilling)]
            req.transition("decoding")

    # codes 0-7 keep their pre-priority meaning so the fixed-seed smoke
    # trajectories below replay unchanged; 8-9 are the lifecycle ops
    _OPS = (_submit, _admit, _grant, _rollback, _evict, _preempt,
            _finish_cow, _evict_lru, _submit_hi, _activate)

    def step(self, op):
        code, a, b = op
        self._OPS[code % len(self._OPS)](self, a, b)
        self.check()

    # -- the invariants ---------------------------------------------------

    def check(self):
        alloc, sched = self.alloc, self.sched
        tree = {n.block for n in self.cache._nodes()} \
            if self.cache is not None else set()
        expected = collections.Counter()
        for blocks in sched._slot_blocks:
            expected.update(blocks)
        for blk in tree:
            expected[blk] += 1
        for src in sched._slot_cow_src:
            if src is not None:
                expected[src] += 1
        for blk in range(alloc.num_blocks):
            assert alloc.refcount(blk) == expected[blk], (
                f"block {blk}: refcount {alloc.refcount(blk)} != "
                f"{expected[blk]} (slots + tree + pending CoW)")
        held = {blk for blk, c in expected.items() if c}
        free = alloc._free
        assert len(free) == len(set(free)), "duplicate on the free list"
        assert not set(free) & held, "held block on the free list"
        assert set(free) | held == set(range(alloc.num_blocks))
        assert alloc.in_use == len(held)
        assert alloc._reserved == 0, "reservation leaked across an op"
        for slot, blocks in enumerate(sched._slot_blocks):
            row = sched.block_tables[slot]
            assert list(row[:len(blocks)]) == blocks
            assert (row[len(blocks):] == -1).all()
        if self.cache is not None:
            assert self.cache.cached_blocks() == len(tree) <= alloc.in_use

    # -- end state --------------------------------------------------------

    def drain(self):
        for _ in range(200):
            if not self.sched.has_work():
                break
            for slot, _ in self.sched.admit():
                info = self.sched.prefix_admit(slot)
                if info is not None and info.cow is not None:
                    self.sched.finish_cow(slot)
            for slot, _ in list(self.sched.active()):
                self.pending_cow.discard(slot)
                self.sched.evict(slot)
            self.check()
        else:
            raise AssertionError("drain did not converge")
        assert self.alloc._reserved == 0
        cached = self.cache.cached_blocks() if self.cache is not None \
            else 0
        assert self.alloc.in_use == cached
        if self.cache is not None:
            self.cache.clear()
        assert self.alloc.in_use == 0
        assert self.alloc.available() == self.alloc.num_blocks
        assert sorted(self.alloc._free) == list(range(self.alloc.num_blocks))
        assert (self.sched.block_tables == -1).all()


@settings(max_examples=60, deadline=None)
@given(ops=st.lists(st.tuples(st.integers(0, 9), st.integers(0, 15),
                              st.integers(0, 15)),
                    min_size=1, max_size=150),
       use_cache=st.booleans(), use_priority=st.booleans())
def test_fuzz_refcount_invariants_hold_at_every_step(ops, use_cache,
                                                     use_priority):
    m = _Machine(use_cache, use_priority)
    for op in ops:
        m.step(op)
    m.drain()


def test_churn_smoke():
    """Fixed-seed trajectory through the same interpreter so the leak
    defense still runs (tier-1) where hypothesis is not installed."""
    for seed, use_cache in ((0, False), (1, True)):
        rng = random.Random(seed)
        m = _Machine(use_cache)
        for _ in range(300):
            m.step((rng.randint(0, 7), rng.randint(0, 15),
                    rng.randint(0, 15)))
        m.drain()
        assert m.rid > 20                    # the trajectory did real work
        assert m.sched.table_growths > 0     # ...through the growth path
        if use_cache:
            assert m.prefix_hits > 0         # ...including prefix sharing


def test_priority_churn_smoke():
    """Same interpreter, priority policy, the full op set (class-0
    submissions + explicit decode activation): the trajectory must
    actually exercise admission-time preemption and hold the exact
    refcount identity through it."""
    rng = random.Random(2)
    m = _Machine(use_cache=True, use_priority=True)
    for _ in range(400):
        m.step((rng.randint(0, 9), rng.randint(0, 15),
                rng.randint(0, 15)))
    m.drain()
    assert m.rid > 20
    assert m.sched.preemptions > 0           # policy preempted a victim
