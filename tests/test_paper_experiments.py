"""End-to-end paper experiments at CPU scale (qualitative agreement).

These train the paper's BNN on synthetic stand-ins and assert the
*mechanisms* behind the headline numbers: ID accuracy above chance, OOD
MI > ID MI, rejection improves accuracy, three-cluster disentanglement.
Exact figures are dataset-bound (DESIGN.md §6); the benchmarks print the
quantitative comparison table.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import svi
from repro.core.bayesian import GaussianVariational
from repro.core.surrogate import SurrogateSpec
from repro.core.uncertainty import (auroc, best_rejection_threshold,
                                    disentangle_clusters,
                                    predictive_moments,
                                    rejection_accuracy)
from repro.data import synthetic as D
from repro.models import bnn_cnn as B
from repro.optim import adamw


def _train_bnn(cfg, images, labels, steps=120, lr=3e-3, batch=64, seed=0):
    key = jax.random.key(seed)
    params = B.init_params(key, cfg)
    opt_cfg = adamw.AdamWConfig(lr=lr, warmup_steps=10, total_steps=steps,
                                weight_decay=1e-4)
    state = adamw.init_state(params, opt_cfg)
    svi_cfg = svi.SVIConfig(num_train_examples=images.shape[0],
                            kl_warmup_steps=steps // 3)
    nll = B.nll_fn(cfg)

    @jax.jit
    def step(params, state, batch, key, i):
        (loss, aux), g = jax.value_and_grad(
            lambda p: svi.elbo_loss(nll, p, batch, key, i, svi_cfg),
            has_aux=True)(params)
        params, state, _ = adamw.apply_updates(params, g, state, opt_cfg)
        return params, state, loss, aux

    n = images.shape[0]
    for i in range(steps):
        key, k1, k2 = jax.random.split(key, 3)
        idx = jax.random.randint(k1, (batch,), 0, n)
        b = {"images": jnp.asarray(images[idx]),
             "labels": jnp.asarray(labels[idx])}
        params, state, loss, aux = step(params, state, b, k2,
                                        jnp.asarray(i))
    return params


@pytest.fixture(scope="module")
def bloodcell_bnn():
    # quickstart scale: the epistemic signal needs enough SVI steps for
    # sigma to concentrate where data constrains it (under-trained BNNs
    # can invert the OOD-MI ordering; see EXPERIMENTS.md)
    rng = np.random.default_rng(0)
    cfg = B.BNNConfig(num_classes=7, in_channels=3, width=16,
                      mc_samples=10)
    xtr, ytr = D.blood_cells(rng, 3000)
    params = _train_bnn(cfg, xtr, ytr, steps=300)
    return cfg, params


class TestBloodCell:
    def test_mc_predict_seed_driven_entropy_is_deterministic(
            self, bloodcell_bnn):
        """The KernelEntropy path: the prediction is a pure function of
        (params, x, seed) — no ambient key — the contract the in-kernel
        TPU entropy path serves."""
        from repro.core.entropy import KernelEntropy
        cfg, params = bloodcell_bnn
        rng = np.random.default_rng(7)
        xte, _ = D.blood_cells(rng, 16)
        x = jnp.asarray(xte)
        dead_key = jax.random.key(123)    # must be ignored when entropy set
        a = B.mc_predict(params, cfg, x, dead_key, mode="machine",
                         entropy=KernelEntropy(seed=4))
        b = B.mc_predict(params, cfg, x, jax.random.key(999),
                         mode="machine", entropy=KernelEntropy(seed=4))
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        c = B.mc_predict(params, cfg, x, dead_key, mode="machine",
                         entropy=KernelEntropy(seed=5))
        assert not np.allclose(a, c)

    def test_id_accuracy_above_chance(self, bloodcell_bnn):
        cfg, params = bloodcell_bnn
        rng = np.random.default_rng(1)
        xte, yte = D.blood_cells(rng, 300)
        probs = B.mc_predict(params, cfg, jnp.asarray(xte),
                             jax.random.key(5), mode="machine")
        m = predictive_moments(probs)
        acc = float((m["p_mean"].argmax(-1) == jnp.asarray(yte)).mean())
        assert acc > 0.5, f"ID accuracy {acc} barely above chance (1/7)"

    def test_ood_has_higher_mi_and_auroc(self, bloodcell_bnn):
        """Erythroblast (held-out morphology) MI must separate from ID MI
        (paper: AUROC 91.16%; we assert >> 0.5)."""
        cfg, params = bloodcell_bnn
        rng = np.random.default_rng(2)
        xid, yid = D.blood_cells(rng, 250)
        xood, _ = D.blood_cells_ood(rng, 250)
        key = jax.random.key(6)
        p_id = B.mc_predict(params, cfg, jnp.asarray(xid), key, "machine")
        p_ood = B.mc_predict(params, cfg, jnp.asarray(xood), key, "machine")
        mi_id = predictive_moments(p_id)["MI"]
        mi_ood = predictive_moments(p_ood)["MI"]
        a = float(auroc(mi_ood, mi_id))
        assert a > 0.7, f"OOD AUROC {a}"

    def test_rejection_improves_id_accuracy(self, bloodcell_bnn):
        """Fig. 4d mechanism: rejecting high-MI samples raises accuracy."""
        cfg, params = bloodcell_bnn
        rng = np.random.default_rng(3)
        xte, yte = D.blood_cells(rng, 400)
        probs = B.mc_predict(params, cfg, jnp.asarray(xte),
                             jax.random.key(7), "machine")
        m = predictive_moments(probs)
        t, acc_rej = best_rejection_threshold(m["MI"], m["p_mean"],
                                              jnp.asarray(yte))
        r = rejection_accuracy(m["p_mean"], m["MI"], jnp.asarray(yte), t)
        assert float(r["accuracy_accepted"]) >= float(r["accuracy_all"])


@pytest.fixture(scope="module")
def glyph_bnn():
    rng = np.random.default_rng(10)
    cfg = B.BNNConfig(num_classes=10, in_channels=1, width=16,
                      mc_samples=10)
    xtr, ytr = D.glyphs(rng, 3000)
    params = _train_bnn(cfg, xtr, ytr, steps=300, seed=1)
    return cfg, params


class TestDisentanglement:
    def _moments(self, params, cfg, x, key):
        probs = B.mc_predict(params, cfg, jnp.asarray(x), key, "machine")
        return predictive_moments(probs)

    def test_three_regimes(self, glyph_bnn):
        """ID low-everything; ambiguous high SE; fashion-OOD higher MI
        than ID (paper Fig. 5e)."""
        cfg, params = glyph_bnn
        rng = np.random.default_rng(11)
        key = jax.random.key(8)
        m_id = self._moments(params, cfg, D.glyphs(rng, 200)[0], key)
        m_amb = self._moments(params, cfg,
                              D.ambiguous_glyphs(rng, 200)[0], key)
        m_ood = self._moments(params, cfg, D.fashion_ood(rng, 200)[0], key)

        # aleatoric: ambiguous SE above ID SE
        assert float(m_amb["SE"].mean()) > float(m_id["SE"].mean())
        # epistemic: OOD MI above ID MI
        assert float(m_ood["MI"].mean()) > float(m_id["MI"].mean())
        # disentanglement: SE-detector and MI-detector both informative
        a_alea = float(auroc(m_amb["SE"], m_id["SE"]))
        a_epi = float(auroc(m_ood["MI"], m_id["MI"]))
        assert a_alea > 0.6, f"aleatoric AUROC {a_alea}"
        assert a_epi > 0.6, f"epistemic AUROC {a_epi}"

    def test_cluster_separation(self, glyph_bnn):
        cfg, params = glyph_bnn
        rng = np.random.default_rng(12)
        key = jax.random.key(9)
        mis, ses, ids = [], [], []
        for d, gen in enumerate((D.glyphs, D.ambiguous_glyphs,
                                 D.fashion_ood)):
            m = self._moments(params, cfg, gen(rng, 150)[0], key)
            mis.append(m["MI"])
            ses.append(m["SE"])
            ids.append(jnp.full((150,), d))
        r = disentangle_clusters(jnp.concatenate(mis),
                                 jnp.concatenate(ses),
                                 jnp.concatenate(ids))
        assert float(r["min_pairwise"]) > 0.01


class TestSurrogateMachineAgreement:
    def test_surrogate_and_machine_agree_on_mean(self, glyph_bnn):
        """The paper trains on the surrogate and predicts on the machine:
        both paths must yield consistent mean predictions."""
        cfg, params = glyph_bnn
        rng = np.random.default_rng(13)
        x = jnp.asarray(D.glyphs(rng, 100)[0])
        key = jax.random.key(10)
        p_sur = B.mc_predict(params, cfg, x, key, "surrogate").mean(0)
        p_mac = B.mc_predict(params, cfg, x, key, "machine").mean(0)
        agree = float((p_sur.argmax(-1) == p_mac.argmax(-1)).mean())
        assert agree > 0.85, f"surrogate/machine agreement {agree}"
