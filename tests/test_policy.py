"""Risk-aware request lifecycle: policy layer, preemption, escalation.

The acceptance contract of the policy-layered scheduler refactor
(ISSUE 10): every lifecycle edge funnels through the audited
``Request.transition`` (illegal moves raise), ``--policy fifo`` with
escalation off replays the pre-refactor engine's streams bit for bit
(anchored on ``decode_loop_reference``, the pre-engine oracle, across
all four KV-carrying attention families — the prefix-cache CoW and
chunked-prefill bitwise contracts are carried by their own unchanged
suites), the priority policy preempts strictly-lower-priority decoding
slots and the preempted request's replayed stream is bitwise identical
to never-preempted (exact-refcount pool identity included), and
MI-triggered escalation finishes flagged requests on a high-S sidecar
runner cached per S.

Operand-mode decode noise folds the SLOT index, so every bitwise
comparison here pins the admission schedule by construction and
asserts the slot breadcrumbs matched (same discipline as
tests/test_spec_decode.py).
"""

import numpy as np
import pytest

from conftest import family_setup as _family
from conftest import make_request as _req
from repro.launch.engine.scheduler import LIFECYCLE
from repro.launch.serve import (FifoPolicy, PriorityPolicy, Request,
                                ServeEngine, SlotScheduler,
                                decode_loop_reference, get_policy)

# one family per KV-carrying attention variant (same set the spec-decode
# parity sweep anchors); ssm has no KV strips and serves dense
POLICY_FAMILIES = ("dense", "encdec", "hybrid", "moe")


def _preq(rid, prompt, n, priority=0, slo=None, arrival=0):
    return Request(rid=rid, prompt=np.asarray(prompt, np.int32),
                   max_new_tokens=n, priority=priority, slo_s=slo,
                   arrival_step=arrival)


def _assert_streams_equal(ra, rb):
    assert len(ra["requests"]) == len(rb["requests"])
    for a, b in zip(ra["requests"], rb["requests"]):
        assert a.slot == b.slot, \
            f"request {a.rid} reshuffled to a different slot " \
            f"({a.slot} vs {b.slot}): parity undefined, fix the workload"
        assert a.finish_reason == b.finish_reason
        np.testing.assert_array_equal(a.tokens, b.tokens)
        for name in ("H", "SE", "MI", "p_max"):
            np.testing.assert_array_equal(
                np.asarray(getattr(a, name), np.float32),
                np.asarray(getattr(b, name), np.float32))


# ---------------------------------------------------------------------------
# lifecycle state machine
# ---------------------------------------------------------------------------

class TestLifecycle:
    def test_legal_walk_records_history_and_times(self):
        r = _req(0, [1, 2, 3], 4)
        assert r.state == "new"
        r.transition("queued")
        assert r.t_submit > 0
        r.transition("prefilling")
        r.transition("decoding")
        r.transition("finished", reason="length")
        assert r.t_finish >= r.t_submit
        assert r.finish_reason == "length"
        assert [s for s, _ in r.history] == \
            ["queued", "prefilling", "decoding", "finished"]
        assert r.queue_time_s >= 0.0
        assert abs(r.service_time_s - (r.latency_s - r.queue_time_s)) \
            < 1e-12

    @pytest.mark.parametrize("path", [
        ("decoding",),                       # new can only go queued
        ("queued", "finished"),              # no queue-jump to finished
        ("queued", "prefilling", "queued"),  # no un-admission
        ("queued", "prefilling", "decoding", "finished", "queued"),
    ])
    def test_illegal_transitions_raise(self, path):
        r = _req(0, [1], 2)
        with pytest.raises(ValueError, match="illegal lifecycle"):
            for to in path:
                r.transition(to)

    def test_lifecycle_map_is_closed(self):
        """Every named successor state exists as a key — no edge can
        reach a state the machine doesn't define."""
        for state, succ in LIFECYCLE.items():
            for s in succ:
                assert s in LIFECYCLE, (state, s)

    def test_preempted_clears_output_and_reenters(self):
        r = _req(0, [1, 2], 8)
        for to in ("queued", "prefilling", "decoding"):
            r.transition(to)
        r.tokens += [5, 6]
        r.H += [0.1, 0.2]
        r.SE += [0.1, 0.2]
        r.MI += [0.1, 0.2]
        r.p_max += [0.9, 0.9]
        r.epistemic_flags = 1
        r.last_mi = 0.2
        r.spec_ema = 0.5
        t0 = r.t_submit
        r.transition("preempted")
        r.transition("queued")
        assert r.state == "queued"
        assert r.preempt_count == 1
        assert r.tokens == [] and r.H == [] and r.MI == []
        assert r.epistemic_flags == 0
        assert r.last_mi == float("inf")
        assert r.spec_ema is None
        # t_submit stamps once (first queued entry), never on re-entry
        assert r.t_submit == t0

    def test_escalated_edge_and_was_escalated(self):
        r = _req(0, [1], 2)
        for to in ("queued", "prefilling", "decoding", "escalated",
                   "finished"):
            r.transition(to)
        assert r.was_escalated
        assert not _req(1, [1], 2).was_escalated


# ---------------------------------------------------------------------------
# policy ranking (pure host-side units)
# ---------------------------------------------------------------------------

class TestPolicyRanking:
    def test_get_policy_resolves_and_rejects(self):
        assert isinstance(get_policy("fifo"), FifoPolicy)
        assert isinstance(get_policy("priority"), PriorityPolicy)
        with pytest.raises(ValueError, match="unknown scheduling policy"):
            get_policy("round_robin")

    def test_fifo_is_head_only_and_never_preempts(self):
        p = FifoPolicy()
        q = [_preq(i, [1], 2, priority=9 - i) for i in range(3)]
        assert p.select(q) == 0
        assert p.select([]) is None
        assert p.victim(q[0], [(0, q[1])]) is None

    def test_priority_select_class_then_deadline_then_seq(self):
        p = PriorityPolicy()
        a = _preq(0, [1], 2, priority=2)
        b = _preq(1, [1], 2, priority=0, slo=10.0)
        c = _preq(2, [1], 2, priority=0, slo=1.0)
        d = _preq(3, [1], 2, priority=0)       # no SLO: deadline inf
        for seq, r in enumerate((a, b, c, d)):
            r.seq = seq
            r.t_submit = 100.0
        assert p.select([a, b, c, d]) == 2     # best class, earliest ddl
        assert p.select([a, b, d]) == 1        # finite ddl beats none
        assert p.select([a, d]) == 1           # class beats order
        e = _preq(4, [1], 2, priority=0)
        e.seq, e.t_submit = 9, 100.0
        assert p.select([d, e]) == 0           # equal key tail: FIFO seq

    def test_priority_victim_strictly_worse_class_only(self):
        p = PriorityPolicy()
        cand = _preq(0, [1], 2, priority=1)
        peer = _preq(1, [1], 2, priority=1)
        worse = _preq(2, [1], 2, priority=3)
        worst = _preq(3, [1], 2, priority=3)
        worse.tokens, worst.tokens = [1, 2, 3], [1]   # worst: cheapest replay
        worse.seq, worst.seq = 0, 1
        assert p.victim(cand, [(0, peer)]) is None    # never a peer
        assert p.victim(cand, [(0, peer), (1, worse), (2, worst)]) == 2
        best = _preq(4, [1], 2, priority=0)
        assert p.victim(best, [(0, cand)]) == 0       # 1 > 0: preemptible


# ---------------------------------------------------------------------------
# fifo: the bit-exact reference policy
# ---------------------------------------------------------------------------

class TestFifoReference:
    def test_fifo_replays_per_token_loop(self):
        """--policy fifo, escalation off, one static wave: the
        refactored engine must still replay the pre-engine per-token
        oracle bit for bit (dense family — the only family whose scan
        compiles to the oracle's exact float schedule; cross-family
        coverage is engine-vs-engine below, and paged-vs-dense parity
        has its own suite in tests/test_paged_kv.py)."""
        cfg, params, prompts = _family("dense")
        gen = 6
        max_len = prompts.shape[1] + gen
        eng = ServeEngine(params, cfg, num_slots=3, max_len=max_len,
                          chunk=4, policy="fifo")
        res = eng.run([_req(i, prompts[i], gen) for i in range(3)])
        ref = decode_loop_reference(params, cfg, prompts[:3], gen,
                                    max_len=max_len,
                                    modality=eng._modality(3))
        for j, req in enumerate(res["requests"]):
            assert req.slot == j
            np.testing.assert_array_equal(req.tokens, ref["token"][:, j])
            for name in ("H", "SE", "MI", "p_max"):
                np.testing.assert_array_equal(
                    np.asarray(getattr(req, name), np.float32),
                    ref[name][:, j])
        assert res["policy"] == "fifo" and res["preemptions"] == 0

    @pytest.mark.parametrize("family", sorted(POLICY_FAMILIES))
    def test_policy_layer_inert_is_bitwise_across_families(self, family):
        """The whole new layer ARMED but never triggering — priority
        policy on uniform-class traffic, escalation at an unreachable
        threshold — must be byte-for-byte the plain fifo engine on
        every KV-carrying attention family (paged layout, staggered
        queue churn)."""
        cfg, params, prompts = _family(family)
        kw = dict(num_slots=2, max_len=24, chunk=4, kv_layout="paged",
                  kv_block=8)
        mk = lambda: [_req(i, prompts[i][:(12 if i % 2 == 0 else 8)], 6)
                      for i in range(4)]
        r_fifo = ServeEngine(params, cfg, **kw, policy="fifo").run(mk())
        armed = ServeEngine(params, cfg, **kw, policy="priority",
                            escalate_mi=float("inf"))
        r_armed = armed.run(mk())
        _assert_streams_equal(r_fifo, r_armed)
        assert r_armed["policy"] == "priority"
        assert r_armed["preemptions"] == 0
        assert r_armed["escalation"]["escalations"] == 0

    def test_priority_on_uniform_class_degrades_to_fifo(self):
        """All-default-priority traffic under the priority policy ranks
        by submission seq alone — admissions, slots and streams must be
        byte-for-byte the fifo run's, through queue churn, prefix-cache
        CoW hits and chunked prefill."""
        cfg, params, _ = _family("dense")
        import jax
        shared = np.asarray(jax.random.randint(jax.random.key(3), (20,),
                                               0, cfg.vocab_size), np.int32)
        tails = np.asarray(jax.random.randint(jax.random.key(4), (5, 8),
                                              0, cfg.vocab_size), np.int32)
        mk = lambda: [_req(i, np.concatenate([shared, tails[i]]), 6)
                      for i in range(5)]
        kw = dict(num_slots=2, max_len=48, chunk=4, kv_layout="paged",
                  kv_block=8, prefix_cache=True, prefill_mode="chunked",
                  prefill_chunk=16)
        r_fifo = ServeEngine(params, cfg, **kw, policy="fifo").run(mk())
        r_prio = ServeEngine(params, cfg, **kw, policy="priority").run(mk())
        _assert_streams_equal(r_fifo, r_prio)
        assert r_fifo["prefix_cache"]["cow_copies"] > 0
        assert r_fifo["prefill_chunks"] > 0
        assert r_prio["preemptions"] == 0

    def test_queue_and_service_time_split(self):
        """queue_time + service_time = latency per request, and queued
        requests accrue strictly more queue wait than the first wave."""
        cfg, params, prompts = _family("dense")
        eng = ServeEngine(params, cfg, num_slots=2, max_len=32, chunk=4)
        reqs = [_req(i, prompts[i], 6) for i in range(6)]
        res = eng.run(reqs)
        for r in reqs:
            assert r.queue_time_s >= 0.0
            assert abs(r.queue_time_s + r.service_time_s - r.latency_s) \
                < 1e-9
        assert reqs[-1].queue_time_s > reqs[0].queue_time_s
        assert res["queue_time_p99_s"] >= res["queue_time_p50_s"] >= 0.0
        assert res["service_time_p99_s"] > 0.0


# ---------------------------------------------------------------------------
# priority preemption
# ---------------------------------------------------------------------------

class TestPriorityPreemption:
    def test_high_priority_skips_the_queue(self):
        """A class-0 request submitted LAST admits in the first wave
        under the priority policy and finishes before the queued
        class-2 traffic."""
        cfg, params, prompts = _family("dense")
        eng = ServeEngine(params, cfg, num_slots=2, max_len=32, chunk=4,
                          policy="priority")
        reqs = [_preq(i, prompts[i], 6, priority=2) for i in range(4)]
        hi = _preq(4, prompts[4], 6, priority=0)
        res = eng.run(reqs + [hi])
        assert hi.slot == 0                   # first placement of wave 1
        assert hi.t_finish < max(r.t_finish for r in reqs)
        assert res["per_class"][0]["num_requests"] == 1

    def test_preempt_and_restore_bitwise_with_refcount_identity(self):
        """The tentpole's preempt-and-restore contract: a class-0
        arrival preempts the only (class-2, decoding) slot; the victim
        replays from its prompt into the SAME slot and its final stream
        is bitwise identical to a never-preempted run, the high-priority
        stream matches ITS solo run, and the pool ends at exact-refcount
        identity."""
        cfg, params, prompts = _family("dense")
        kw = dict(num_slots=1, max_len=32, chunk=4, kv_layout="paged",
                  kv_block=8)
        lo_solo = _req(0, prompts[0], 8)
        r_lo = ServeEngine(params, cfg, **kw).run([lo_solo])
        hi_solo = _req(1, prompts[1][:8], 4)
        r_hi = ServeEngine(params, cfg, **kw).run([hi_solo])

        lo = _preq(0, prompts[0], 8, priority=2)
        hi = _preq(1, prompts[1][:8], 4, priority=0, arrival=4)
        eng = ServeEngine(params, cfg, **kw, policy="priority")
        res = eng.run([lo, hi])

        assert res["preemptions"] == 1
        assert lo.preempt_count == 1
        assert lo.slot == 0 and hi.slot == 0
        np.testing.assert_array_equal(lo.tokens, lo_solo.tokens)
        np.testing.assert_array_equal(hi.tokens, hi_solo.tokens)
        for name in ("H", "SE", "MI", "p_max"):
            np.testing.assert_array_equal(
                np.asarray(getattr(lo, name), np.float32),
                np.asarray(getattr(lo_solo, name), np.float32))
        assert [s for s, _ in lo.history].count("preempted") == 1
        # exact-refcount identity after the drain: every block free,
        # nothing reserved (the engine's leak guard saw the same)
        alloc = eng._last_alloc
        assert alloc.in_use == 0 and alloc._reserved == 0
        assert sorted(alloc._free) == list(range(alloc.num_blocks))
        assert res["per_class"][2]["preemptions"] == 1

    def test_admission_preemption_surfaces_via_take_preempted(self):
        """Scheduler-level: admit() under the priority policy preempts
        the worst decoding slot for a better candidate and surfaces the
        (slot, request) pair through take_preempted."""
        s = SlotScheduler(1, policy=get_policy("priority"))
        lo = _preq(0, [1, 2], 4, priority=2)
        s.submit(lo)
        [(slot, req)] = s.admit()
        assert (slot, req.rid) == (0, 0)
        req.transition("decoding")
        hi = _preq(1, [1], 4, priority=0)
        s.submit(hi)
        placed = s.admit()
        assert [(sl, r.rid) for sl, r in placed] == [(0, 1)]
        assert [(sl, r.rid) for sl, r in s.take_preempted()] == [(0, 0)]
        assert s.take_preempted() == []       # drained
        assert s.preemptions == 1
        assert lo.state == "queued" and lo.preempt_count == 1

    def test_fifo_never_preempts_on_admission(self):
        s = SlotScheduler(1)
        s.submit(_preq(0, [1, 2], 4, priority=9))
        [(slot, req)] = s.admit()
        req.transition("decoding")
        s.submit(_preq(1, [1], 4, priority=0))
        assert s.admit() == []
        assert s.take_preempted() == [] and s.preemptions == 0


# ---------------------------------------------------------------------------
# MI-triggered escalation
# ---------------------------------------------------------------------------

class TestEscalation:
    def test_escalation_runner_cache_keyed_by_s(self):
        cfg, params, _ = _family("dense")
        eng = ServeEngine(params, cfg, num_slots=2, max_len=32, chunk=4)
        r8 = eng.escalation_runner(8)
        assert eng.escalation_runner(8) is r8         # cached per S
        r16 = eng.escalation_runner(16)
        assert r16 is not r8
        assert r8.cfg.mc_samples == 8 and r16.cfg.mc_samples == 16
        assert r8.kv_layout == "dense"
        assert set(eng._esc_runners) == {8, 16}

    def test_escalation_finishes_flagged_requests_on_high_s_lane(self):
        """Threshold set AT a value the baseline's first-chunk carried
        MI reaches: the flagged request leaves the main pool mid-decode
        and the lane finishes its full budget at the verify S, counted
        per class."""
        cfg, params, prompts = _family("dense")
        kw = dict(num_slots=3, max_len=32, chunk=4, kv_layout="paged",
                  kv_block=8)
        base_reqs = [_req(i, prompts[i], 8) for i in range(3)]
        ServeEngine(params, cfg, **kw).run(base_reqs)
        # the escalated run replays the SAME pre-escalation stream, so
        # request 0's chunk-end carried MI equals this bit for bit and
        # the >= trigger fires deterministically
        thr = float(base_reqs[0].MI[3])
        reqs = [_preq(i, prompts[i], 8, priority=i % 2) for i in range(3)]
        eng = ServeEngine(params, cfg, **kw, escalate_mi=thr,
                          escalate_s=4 * cfg.mc_samples)
        res = eng.run(reqs)
        esc = res["escalation"]
        assert esc["enabled"] and esc["escalations"] >= 1
        assert esc["verify_samples"] == 4 * cfg.mc_samples
        assert esc["tokens"] > 0 and esc["steps"] > 0
        assert reqs[0].was_escalated
        assert sum(esc["by_class"].values()) == esc["escalations"]
        for r in reqs:
            assert r.state == "finished"
            assert len(r.tokens) == 8 and r.finish_reason == "length"
        assert sum(r.was_escalated for r in reqs) == esc["escalations"]
        # the lane's runner compiled once, keyed by the verify S
        assert set(eng._esc_runners) == {4 * cfg.mc_samples}
        alloc = eng._last_alloc
        assert alloc.in_use == 0 and alloc._reserved == 0

    def test_inf_threshold_is_bitwise_no_op(self):
        """Escalation ARMED but with an unreachable threshold: the lane
        never fires and the streams are byte-for-byte the plain fifo
        engine's."""
        cfg, params, prompts = _family("dense")
        kw = dict(num_slots=2, max_len=32, chunk=4, kv_layout="paged",
                  kv_block=8)
        mk = lambda: [_req(i, prompts[i], 6) for i in range(5)]
        r_plain = ServeEngine(params, cfg, **kw).run(mk())
        eng = ServeEngine(params, cfg, **kw,
                          escalate_mi=float("inf")).run(mk())
        _assert_streams_equal(r_plain, eng)
        assert eng["escalation"]["escalations"] == 0
        assert eng["escalation"]["tokens"] == 0

    def test_too_long_requests_skip_the_lane_once(self):
        """A request whose prompt + budget exceeds the dense sidecar's
        max_len cannot escalate: it keeps decoding in the main (paged,
        growable) engine and is counted once in skipped_too_long."""
        cfg, params, prompts = _family("dense")
        eng = ServeEngine(params, cfg, num_slots=1, max_len=16, chunk=4,
                          kv_layout="paged", kv_block=8, kv_blocks=4,
                          escalate_mi=0.0)    # every carried MI triggers
        req = _req(0, prompts[0], 8)          # 12 + 8 > max_len 16
        res = eng.run([req])
        esc = res["escalation"]
        assert esc["escalations"] == 0
        assert esc["skipped_too_long"] == 1
        assert not req.was_escalated
        assert len(req.tokens) == 8 and req.state == "finished"


# ---------------------------------------------------------------------------
# open-loop arrivals + validation
# ---------------------------------------------------------------------------

class TestArrivalsAndValidation:
    def test_arrival_steps_delay_submission(self):
        """arrival_step > 0 requests join the queue only once the engine
        has decoded that many steps; an idle engine fast-forwards to the
        next arrival instead of stalling."""
        cfg, params, prompts = _family("dense")
        eng = ServeEngine(params, cfg, num_slots=1, max_len=32, chunk=4)
        a = _preq(0, prompts[0], 4, arrival=0)
        b = _preq(1, prompts[1], 4, arrival=100)   # after a finished
        res = eng.run([a, b])
        assert a.state == "finished" and b.state == "finished"
        assert b.t_submit > a.t_submit
        assert res["gen_tokens"] == 8

    def test_engine_rejects_unknown_policy(self):
        cfg, params, _ = _family("dense")
        with pytest.raises(ValueError, match="unknown scheduling policy"):
            ServeEngine(params, cfg, num_slots=1, max_len=32, chunk=4,
                        policy="lifo")

    def test_engine_validates_escalation_knobs(self):
        cfg, params, _ = _family("dense")
        with pytest.raises(ValueError, match="escalate_mi"):
            ServeEngine(params, cfg, num_slots=1, max_len=32, chunk=4,
                        escalate_mi=-0.1)
        with pytest.raises(ValueError, match="escalate_s"):
            ServeEngine(params, cfg, num_slots=1, max_len=32, chunk=4,
                        escalate_s=0)

    def test_engine_validates_adaptive_k_bounds(self):
        cfg, params, _ = _family("dense")
        with pytest.raises(ValueError, match="k_min"):
            ServeEngine(params, cfg, num_slots=1, max_len=32, chunk=4,
                        spec_decode=True, spec_k=3, spec_k_min=4)
        with pytest.raises(ValueError, match="k_max"):
            ServeEngine(params, cfg, num_slots=1, max_len=32, chunk=4,
                        spec_decode=True, spec_k=3, spec_k_max=2)
