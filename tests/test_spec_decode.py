"""Uncertainty-gated speculative decoding: the lossless parity contract.

The acceptance contract of ``--spec-decode`` (ISSUE 8): in
operand-entropy mode the engine's accepted stream — tokens AND the full
uncertainty triplet — is BITWISE identical to the same queue served
with speculation off, across every attention family, staggered
mixed-length slots, and the prefix cache (including post-CoW hits);
``--spec-mi-threshold 0`` never drafts and degenerates to the plain
scan path; a draft that proposes garbage still yields the exact stream
(one verified token per round); and partially rejected rounds roll
their decode-granted blocks back without leaking.

Operand-mode decode noise folds the SLOT index, so bitwise parity is
only defined for requests that land in the same slot in both runs —
and speculation changes finish timing, which can reshuffle queued
admissions across slots.  The workloads here therefore pin the
admission schedule by construction (first-wave-only for the multi-slot
sweeps, a single slot for queue churn) and every comparison asserts
the slot breadcrumbs actually matched, so a reshuffle fails loudly
instead of silently comparing different noise streams.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import family_setup as _family
from conftest import make_request as _req
from repro.launch.serve import ServeEngine

# one family per KV-carrying attention variant; hybrid additionally
# exercises the recurrent ssm/conv state rewind on rollback (ssm-only
# has no KV and serves dense, covered by the same rewind path)
SPEC_FAMILIES = ("dense", "encdec", "hybrid", "moe")


def _first_wave(prompts):
    # 3 slots, 3 requests: staggered prompt lengths AND finish times
    # without queue refill, so admission is FIFO-into-slot-order in
    # both runs regardless of how speculation shifts finish timing
    lens, gens = (12, 8, 10), (8, 4, 6)
    return [_req(i, prompts[i][:lens[i]], gens[i]) for i in range(3)]


def _churn_queue(prompts):
    # single slot + a deep queue: real admission churn (evict, readmit,
    # prefix-tree inserts) with a trivially identical schedule
    gens = (8, 4, 8, 6, 5)
    return [_req(i, prompts[i][:(12 if i % 2 == 0 else 8)], gens[i])
            for i in range(5)]


def _assert_streams_equal(ra, rb):
    assert len(ra["requests"]) == len(rb["requests"])
    for a, b in zip(ra["requests"], rb["requests"]):
        assert a.slot == b.slot, \
            f"request {a.rid} reshuffled to a different slot " \
            f"({a.slot} vs {b.slot}): parity undefined, fix the workload"
        assert a.finish_reason == b.finish_reason
        np.testing.assert_array_equal(a.tokens, b.tokens)
        for name in ("H", "SE", "MI", "p_max"):
            np.testing.assert_array_equal(
                np.asarray(getattr(a, name), np.float32),
                np.asarray(getattr(b, name), np.float32))
        assert a.epistemic_flags == b.epistemic_flags
        assert a.aleatoric_flags == b.aleatoric_flags


_ENGINE_KW = dict(num_slots=3, max_len=32, chunk=4, kv_layout="paged",
                  kv_block=8)
# gate wide open: every slot drafts as soon as it has carried one MI
_SPEC_KW = dict(spec_decode=True, spec_k=3, spec_mi_threshold=float("inf"))


def _garbage_draft(engine):
    """Wrap the engine's draft so every proposal is an impossible token:
    verification rejects everything, every round emits exactly its one
    verified correction."""
    orig = engine._draft

    def bad(params, tok, cache):
        tok, cache, dys = orig(params, tok, cache)
        return tok, cache, dict(dys, token=jnp.full_like(dys["token"], -1))

    engine._draft = bad


def _garbage_all_k(engine):
    """Garbage drafts at EVERY depth: wraps both the default-k alias
    (``engine._draft``) and the per-k jit cache (``runner.spec_fns``),
    so adaptive-k rounds reject everything no matter which k the
    controller picked."""
    _garbage_draft(engine)
    orig = engine.runner.spec_fns

    def fns(k):
        draft, verify = orig(k)

        def bad(params, tok, cache):
            tok, cache, dys = draft(params, tok, cache)
            return tok, cache, \
                dict(dys, token=jnp.full_like(dys["token"], -1))

        return bad, verify

    engine.runner.spec_fns = fns


class TestSpecParity:
    @pytest.mark.parametrize("family", sorted(SPEC_FAMILIES))
    def test_bitwise_stream_parity_across_families(self, family):
        """Staggered first wave, spec on vs off: every request's token +
        (H, SE, MI, p_max) streams must match bit for bit, speculation
        must actually run, and the expensive full-sample head must
        dispatch no more often than the chunk-per-scan baseline."""
        cfg, params, prompts = _family(family)
        off = ServeEngine(params, cfg, **_ENGINE_KW)
        r_off = off.run(_first_wave(prompts))
        on = ServeEngine(params, cfg, **_ENGINE_KW, **_SPEC_KW)
        r_on = on.run(_first_wave(prompts))
        _assert_streams_equal(r_off, r_on)
        sd = r_on["spec_decode"]
        assert sd["enabled"] and sd["rounds"] > 0
        assert sd["emitted"] > 0
        assert sd["full_model_calls"] <= \
            r_off["spec_decode"]["full_model_calls"]

    def test_queue_churn_parity_single_slot(self):
        """Admission churn (evict, readmit into the same slot) under
        speculation: the whole drained queue replays the off-mode run
        bitwise, and acceptance actually saves full-model calls."""
        cfg, params, prompts = _family("dense")
        kw = dict(num_slots=1, max_len=32, chunk=4, kv_layout="paged",
                  kv_block=8)
        off = ServeEngine(params, cfg, **kw)
        r_off = off.run(_churn_queue(prompts))
        on = ServeEngine(params, cfg, **kw, **_SPEC_KW)
        r_on = on.run(_churn_queue(prompts))
        _assert_streams_equal(r_off, r_on)
        sd = r_on["spec_decode"]
        assert sd["rounds"] > 0 and sd["accepted"] > 0
        assert sd["full_model_calls"] < \
            r_off["spec_decode"]["full_model_calls"]

    def test_threshold_zero_never_speculates(self):
        """MI gating is STRICT (<): threshold 0 admits no slot, so the
        engine never leaves the plain scan path and the run is
        indistinguishable from spec-decode off."""
        cfg, params, prompts = _family("dense")
        off = ServeEngine(params, cfg, **_ENGINE_KW)
        r_off = off.run(_first_wave(prompts))
        on = ServeEngine(params, cfg, **_ENGINE_KW, spec_decode=True,
                         spec_k=3, spec_mi_threshold=0.0)
        r_on = on.run(_first_wave(prompts))
        _assert_streams_equal(r_off, r_on)
        sd = r_on["spec_decode"]
        assert sd["rounds"] == 0 and sd["drafted"] == 0
        assert sd["full_model_calls"] == \
            r_off["spec_decode"]["full_model_calls"]
        assert r_on["chunks_run"] == r_off["chunks_run"]

    def test_reject_all_draft_stream_still_exact(self):
        """A draft proposing garbage must cost throughput, never
        correctness: every round accepts nothing, emits exactly the one
        verified token per slot, and the stream stays bitwise
        identical."""
        cfg, params, prompts = _family("dense")
        off = ServeEngine(params, cfg, **_ENGINE_KW)
        r_off = off.run(_first_wave(prompts))
        on = ServeEngine(params, cfg, **_ENGINE_KW, **_SPEC_KW)
        _garbage_draft(on)
        r_on = on.run(_first_wave(prompts))
        _assert_streams_equal(r_off, r_on)
        sd = r_on["spec_decode"]
        assert sd["rounds"] > 0
        assert sd["accepted"] == 0 and sd["acceptance_rate"] == 0.0
        assert sd["rollbacks"] > 0
        assert sd["tokens_per_round"] <= on.num_slots

    def test_rollback_releases_blocks(self):
        """Every speculative rejection rewinds the slot's decode-granted
        blocks: after a drain with forced 100% rejection (maximum
        rollback traffic) the pool must balance exactly — nothing in
        use, nothing reserved, every block back on the free list."""
        cfg, params, prompts = _family("dense")
        on = ServeEngine(params, cfg, **_ENGINE_KW, **_SPEC_KW)
        _garbage_draft(on)
        res = on.run(_first_wave(prompts))
        assert res["spec_decode"]["rollbacks"] > 0
        alloc = on._last_alloc
        assert alloc.in_use == 0
        assert alloc._reserved == 0
        assert sorted(alloc._free) == list(range(alloc.num_blocks))

    def test_parity_with_prefix_cache_and_cow(self):
        """Spec rounds over prefix-cache hits, including post-CoW slots
        (20 shared tokens over 8-token blocks => a partial tail match
        every admission after the first): the hit + CoW + speculate
        pipeline must still replay the spec-off stream exactly, and the
        pool must end balanced against the cache's refcounts."""
        cfg, params, _ = _family("dense")
        shared = np.asarray(jax.random.randint(jax.random.key(3), (20,),
                                               0, cfg.vocab_size), np.int32)
        tails = np.asarray(jax.random.randint(jax.random.key(4), (5, 8),
                                              0, cfg.vocab_size), np.int32)
        mk = lambda: [_req(i, np.concatenate([shared, tails[i]]), 6)
                      for i in range(5)]
        kw = dict(num_slots=1, max_len=48, chunk=4, kv_layout="paged",
                  kv_block=8, prefix_cache=True)
        off = ServeEngine(params, cfg, **kw)
        r_off = off.run(mk())
        on = ServeEngine(params, cfg, **kw, **_SPEC_KW)
        r_on = on.run(mk())
        _assert_streams_equal(r_off, r_on)
        assert r_on["prefix_cache"]["hits"] > 0
        assert r_on["prefix_cache"]["cow_copies"] > 0
        assert r_on["spec_decode"]["rounds"] > 0
        alloc, pcache = on._last_alloc, on._last_pcache
        assert alloc.in_use == pcache.cached_blocks()
        assert alloc._reserved == 0

    def test_dense_layout_parity(self):
        """The dense reference layout speculates too (rollback is then
        pure tok/len/state rewind, no block bookkeeping)."""
        cfg, params, prompts = _family("dense")
        off = ServeEngine(params, cfg, num_slots=3, max_len=32, chunk=4)
        r_off = off.run(_first_wave(prompts))
        on = ServeEngine(params, cfg, num_slots=3, max_len=32, chunk=4,
                         **_SPEC_KW)
        r_on = on.run(_first_wave(prompts))
        _assert_streams_equal(r_off, r_on)
        assert r_on["spec_decode"]["rounds"] > 0

    def test_mean_head_draft_is_also_lossless(self):
        """spec_draft_s=0 (deterministic mean-head proposals): a
        different draft distribution changes ONLY acceptance, never the
        emitted stream."""
        cfg, params, prompts = _family("dense")
        off = ServeEngine(params, cfg, **_ENGINE_KW)
        r_off = off.run(_first_wave(prompts))
        on = ServeEngine(params, cfg, **_ENGINE_KW, **_SPEC_KW,
                         spec_draft_s=0)
        r_on = on.run(_first_wave(prompts))
        _assert_streams_equal(r_off, r_on)


class TestAdaptiveK:
    """--spec-k-min/--spec-k-max: the per-slot acceptance-EMA depth
    controller (ISSUE 10 satellite).  Adaptation moves WHERE the
    draft/verify round boundaries fall, never what ships — the lossless
    gate is depth-independent, so every adaptive run below must replay
    the spec-off stream bitwise."""

    _ADAPT_KW = dict(_SPEC_KW, spec_k_min=1, spec_k_max=5)

    def test_adaptive_depth_stream_parity(self):
        """Queue churn with the controller live (k free in [1, 5]): the
        drained streams still match spec-off bit for bit, and the
        per-round depths stay inside the configured bounds."""
        cfg, params, prompts = _family("dense")
        kw = dict(num_slots=1, max_len=32, chunk=4, kv_layout="paged",
                  kv_block=8)
        r_off = ServeEngine(params, cfg, **kw).run(_churn_queue(prompts))
        on = ServeEngine(params, cfg, **kw, **self._ADAPT_KW)
        r_on = on.run(_churn_queue(prompts))
        _assert_streams_equal(r_off, r_on)
        sd = r_on["spec_decode"]
        assert sd["rounds"] > 0
        assert (sd["k_min"], sd["k_max"]) == (1, 5)
        assert 1 <= sd["round_k_min"] <= sd["round_k_max"] <= 5

    def test_default_bounds_pin_depth_fixed(self):
        """No bounds given: k_min = k = k_max, so grow/shrink are
        unreachable and every round runs at exactly spec_k — the
        adaptive machinery is bitwise inert by default."""
        cfg, params, prompts = _family("dense")
        on = ServeEngine(params, cfg, **_ENGINE_KW, **_SPEC_KW)
        r_on = on.run(_first_wave(prompts))
        sd = r_on["spec_decode"]
        assert sd["rounds"] > 0
        assert sd["k_up"] == 0 and sd["k_down"] == 0
        assert sd["round_k_min"] == sd["round_k_max"] == 3

    def test_garbage_drafts_shrink_to_k_min(self):
        """Deterministic shrink: garbage drafts at EVERY depth drive
        acceptance (and so the EMA) to 0, the controller steps each
        slot down to k_min and stays there, and the stream is still
        exactly the spec-off run's."""
        cfg, params, prompts = _family("dense")
        kw = dict(num_slots=1, max_len=32, chunk=4, kv_layout="paged",
                  kv_block=8)
        r_off = ServeEngine(params, cfg, **kw).run(_churn_queue(prompts))
        on = ServeEngine(params, cfg, **kw, **self._ADAPT_KW)
        _garbage_all_k(on)
        r_on = on.run(_churn_queue(prompts))
        _assert_streams_equal(r_off, r_on)
        sd = r_on["spec_decode"]
        assert sd["accepted"] == 0
        assert sd["k_down"] > 0 and sd["k_up"] == 0
        assert sd["round_k_min"] == 1          # bottomed out at k_min
        assert sd["round_k_max"] == 3          # first rounds at spec_k


class TestSpecValidation:
    def test_spec_requires_operand_entropy(self):
        import dataclasses

        from repro.core.entropy import KernelEntropy
        cfg, params, _ = _family("dense")
        with pytest.raises(ValueError, match="operand"):
            ServeEngine(params, cfg, num_slots=2, max_len=32, chunk=4,
                        entropy=KernelEntropy(seed=0), spec_decode=True)
        kcfg = dataclasses.replace(cfg, head_entropy="kernel")
        with pytest.raises(ValueError, match="operand"):
            ServeEngine(params, kcfg, num_slots=2, max_len=32, chunk=4,
                        spec_decode=True)

    def test_spec_knob_validation(self):
        cfg, params, _ = _family("dense")
        with pytest.raises(ValueError, match="spec_k"):
            ServeEngine(params, cfg, num_slots=2, max_len=32, chunk=4,
                        spec_decode=True, spec_k=0)
        with pytest.raises(ValueError, match="spec_draft_s"):
            ServeEngine(params, cfg, num_slots=2, max_len=32, chunk=4,
                        spec_decode=True, spec_draft_s=-1)
