"""Optional-hypothesis shim.

pyproject.toml declares hypothesis as a test dependency, but the tier-1
suite must still *collect and run* on environments without it (e.g. a
container where only the runtime deps are baked in).  Importing from here
instead of hypothesis directly turns the property tests into skips when
hypothesis is absent, instead of failing the whole collection with
ModuleNotFoundError.
"""

from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # pragma: no cover - exercised in bare envs
    import pytest

    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stands in for hypothesis.strategies; returns inert objects."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def settings(*args, **kwargs):
        return lambda f: f

    def given(*args, **kwargs):
        def deco(f):
            @pytest.mark.skip(reason="hypothesis not installed")
            def _skipped():
                pass
            _skipped.__name__ = f.__name__
            _skipped.__doc__ = f.__doc__
            return _skipped
        return deco
