"""Substrate layers: data pipeline, optimizer, checkpoint, sharding rules."""

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.checkpoint import checkpoint as C
from repro.core.bayesian import GaussianVariational
from repro.data import synthetic as D
from repro.optim import adamw
from repro.sharding import partition as SP


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

class TestData:
    def test_token_stream_deterministic_and_resumable(self):
        s0 = D.TokenStreamState(seed=7, host=0, num_hosts=2)
        a1, s1 = D.token_batch(s0, 4, 16, 1000)
        a2, s2 = D.token_batch(s1, 4, 16, 1000)
        # replay from the checkpointed cursor
        b2, _ = D.token_batch(dataclasses.replace(s0, step=s1.step),
                              4, 16, 1000)
        np.testing.assert_array_equal(a2, b2)
        assert not np.array_equal(a1, a2)

    def test_token_stream_host_sharding(self):
        s_h0 = D.TokenStreamState(seed=7, host=0, num_hosts=2)
        s_h1 = D.TokenStreamState(seed=7, host=1, num_hosts=2)
        a, _ = D.token_batch(s_h0, 4, 16, 1000)
        b, _ = D.token_batch(s_h1, 4, 16, 1000)
        assert not np.array_equal(a, b)

    def test_token_range(self):
        s = D.TokenStreamState(seed=1, host=0, num_hosts=1)
        t, _ = D.token_batch(s, 8, 64, 513)
        assert t.min() >= 0 and t.max() < 513

    def test_blood_cells_shapes_and_classes(self):
        rng = np.random.default_rng(0)
        x, y = D.blood_cells(rng, 32)
        assert x.shape == (32, 3, 28, 28)
        assert x.min() >= 0 and x.max() <= 1
        assert set(np.unique(y)) <= set(range(7))
        xo, yo = D.blood_cells_ood(rng, 8)
        assert (yo == -1).all()

    def test_glyph_families(self):
        rng = np.random.default_rng(1)
        g, yg = D.glyphs(rng, 16)
        a, ya = D.ambiguous_glyphs(rng, 16)
        f, yf = D.fashion_ood(rng, 16)
        for x in (g, a, f):
            assert x.shape == (16, 1, 28, 28)
            assert x.min() >= 0 and x.max() <= 1
        assert (yf == -1).all()
        # ambiguous labels pack two distinct classes
        assert ((ya // 10) != (ya % 10)).all()


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

class TestAdamW:
    def test_converges_on_quadratic(self):
        cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                                total_steps=200, schedule="constant")
        params = {"w": jnp.array([5.0, -3.0])}
        state = adamw.init_state(params, cfg)
        target = jnp.array([1.0, 2.0])
        for _ in range(200):
            g = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
            params, state, _ = adamw.apply_updates(params, g, state, cfg)
        np.testing.assert_allclose(params["w"], target, atol=1e-2)

    def test_clip_by_global_norm(self):
        g = {"a": jnp.full((4,), 100.0)}
        clipped, norm = adamw.clip_by_global_norm(g, 1.0)
        assert float(norm) == 200.0
        np.testing.assert_allclose(adamw.global_norm(clipped), 1.0,
                                   rtol=1e-5)

    def test_topk_compression_error_feedback(self):
        """Dropped gradient mass reappears via the error accumulator —
        no information is lost across steps."""
        g = {"w": jnp.array([1.0, 0.1, 0.01, 2.0])}
        e = {"w": jnp.zeros(4)}
        sent, err = adamw.compress_topk(g, e, frac=0.5)
        np.testing.assert_allclose(np.asarray(sent["w"]) +
                                   np.asarray(err["w"]),
                                   np.asarray(g["w"]), atol=1e-6)
        assert (np.asarray(sent["w"]) == 0).sum() >= 1
        # second step: error feedback promotes previously dropped entries
        sent2, err2 = adamw.compress_topk(
            {"w": jnp.zeros(4)}, err, frac=0.5)
        np.testing.assert_allclose(np.asarray(sent2["w"]) +
                                   np.asarray(err2["w"]),
                                   np.asarray(err["w"]), atol=1e-6)

    def test_moment_dtype_policy(self):
        cfg = adamw.AdamWConfig(moment_dtype="bfloat16")
        st = adamw.init_state({"w": jnp.zeros((3,), jnp.float32)}, cfg)
        assert st["mu"]["w"].dtype == jnp.bfloat16

    def test_schedule_shapes(self):
        cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                                schedule="cosine", min_lr_ratio=0.1)
        lrs = [float(adamw.schedule_lr(cfg, jnp.asarray(s)))
               for s in (0, 5, 10, 55, 100)]
        assert lrs[0] == 0.0 and lrs[1] == 0.5
        np.testing.assert_allclose(lrs[2], 1.0)
        assert lrs[2] > lrs[3] > lrs[4]
        np.testing.assert_allclose(lrs[4], 0.1, atol=1e-6)

    def test_variational_leaves_are_updated(self):
        q = GaussianVariational.init(jax.random.key(0), (3, 2), fan_in=3)
        params = {"head": {"q": q}}
        cfg = adamw.AdamWConfig(lr=0.1, warmup_steps=0, schedule="constant")
        state = adamw.init_state(params, cfg)
        g = jax.grad(lambda p: (p["head"]["q"].mu ** 2).sum()
                     + (p["head"]["q"].rho ** 2).sum())(params)
        new, _, _ = adamw.apply_updates(params, g, state, cfg)
        assert not np.allclose(new["head"]["q"].mu, params["head"]["q"].mu)
        assert not np.allclose(new["head"]["q"].rho, params["head"]["q"].rho)


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

class TestCheckpoint:
    def _tree(self, key):
        return {"params": {"w": jax.random.normal(key, (4, 3)),
                           "q": GaussianVariational.init(key, (2, 2), 2)},
                "opt": {"step": jnp.asarray(7, jnp.int32)}}

    def test_roundtrip(self, tmp_path):
        tree = self._tree(jax.random.key(0))
        C.save(str(tmp_path), 7, tree, extra={"stream": {"step": 3}})
        template = jax.tree.map(jnp.zeros_like, tree)
        restored, extra = C.restore(str(tmp_path), 7, template)
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert extra["stream"]["step"] == 3

    def test_atomicity_ignores_tmp(self, tmp_path):
        tree = self._tree(jax.random.key(1))
        C.save(str(tmp_path), 5, tree)
        # a crashed half-write
        os.makedirs(tmp_path / "step_000000009.tmp")
        assert C.latest_step(str(tmp_path)) == 5

    def test_manager_gc_and_latest(self, tmp_path):
        mgr = C.CheckpointManager(str(tmp_path), keep=2)
        tree = self._tree(jax.random.key(2))
        for s in (1, 2, 3, 4):
            mgr.save_async(s, tree)
            mgr.wait()
        assert C.list_steps(str(tmp_path)) == [3, 4]
        step, restored, _ = mgr.restore_latest(
            jax.tree.map(jnp.zeros_like, tree))
        assert step == 4

    def test_shape_mismatch_raises(self, tmp_path):
        tree = {"w": jnp.zeros((3,))}
        C.save(str(tmp_path), 1, tree)
        with pytest.raises(ValueError, match="shape mismatch"):
            C.restore(str(tmp_path), 1, {"w": jnp.zeros((4,))})

    def test_elastic_restore_across_meshes(self, tmp_path):
        """Save unsharded, restore under an explicit (1,1) mesh sharding —
        the container-scale version of pod-shape elasticity."""
        from repro.launch import mesh as meshlib
        tree = {"w": jnp.arange(16.0).reshape(4, 4)}
        C.save(str(tmp_path), 1, tree)
        mesh = meshlib.make_debug_mesh((1, 1), ("data", "model"))
        sh = {"w": meshlib.named(mesh, P("data", "model"))}
        restored, _ = C.restore(str(tmp_path), 1,
                                jax.tree.map(jnp.zeros_like, tree), sh)
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.asarray(tree["w"]))
        assert restored["w"].sharding.spec == P("data", "model")


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

class TestSharding:
    def test_rule_table(self):
        params = {
            "embed": {"table": jnp.zeros((512, 64))},
            "blocks": {"attn": {"wq": jnp.zeros((64, 64)),
                                "wo": jnp.zeros((64, 64))},
                       "mlp": {"w1": jnp.zeros((64, 128)),
                               "w2": jnp.zeros((128, 64))},
                       "ln1": jnp.zeros((64,))},
            "head": {"q": GaussianVariational.init(
                jax.random.key(0), (64, 512), 64)},
        }
        specs = SP.param_pspecs(params, fsdp=True)
        assert specs["embed"]["table"] == P("model", "data")
        assert specs["blocks"]["attn"]["wq"] == P("data", "model")
        assert specs["blocks"]["attn"]["wo"] == P("model", "data")
        assert specs["blocks"]["ln1"] == P()
        # head: vocab sharded over BOTH axes, contraction dim replicated
        # (FSDP on the contraction dim would AR the logits — §Perf)
        assert specs["head"]["q"].mu == P(None, ("data", "model"))
        assert specs["head"]["q"].rho == P(None, ("data", "model"))
        # pod-level ZeRO expands 'data' to ('pod', 'data')
        pod = SP.param_pspecs(params, fsdp=True, pod_fsdp=True)
        assert pod["blocks"]["attn"]["wq"] == P(("pod", "data"), "model")
        assert pod["head"]["q"].mu == P(None, ("data", "model")) or \
            pod["head"]["q"].mu == P(None, ("pod", "data", "model"))

    def test_fsdp_off_drops_data_axis(self):
        params = {"mlp": {"w1": jnp.zeros((8, 16))}}
        specs = SP.param_pspecs(params, fsdp=False)
        assert specs["mlp"]["w1"] == P(None, "model")

    def test_stacked_layer_leading_axis_unsharded(self):
        params = {"blocks": {"attn": {"wq": jnp.zeros((4, 64, 64))}}}
        specs = SP.param_pspecs(params, fsdp=True)
        assert specs["blocks"]["attn"]["wq"] == P(None, "data", "model")

    def test_sanitize_drops_nondivisible(self):
        from repro.launch import mesh as meshlib
        mesh = meshlib.make_debug_mesh((1, 1), ("data", "model"))
        # fake a 16-way model axis via abstract mesh shape: use debug mesh
        # of (1,1): everything divides by 1 so nothing is dropped
        spec = SP.sanitize_pspecs(
            {"w": P("model", None)},
            {"w": jax.ShapeDtypeStruct((7, 3), jnp.float32)}, mesh)
        assert spec["w"] == P("model", None)

    def test_constrain_noop_without_mesh(self):
        SP.set_mesh_context(None)
        x = jnp.zeros((4, 4))
        y = SP.constrain(x, "batch", None)
        assert y is x
