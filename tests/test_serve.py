"""Serving engine: scheduler, slot-indexed cache, scan-decode parity.

The acceptance contract of the continuous-batching rebuild: scan decode
replays the per-token loop bit-exactly in operand-entropy mode, slots
behave like independent sequences at independent depths, and the
host-side scheduler admits/evicts/reuses slots in FIFO order.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_request as _req
from repro.configs.registry import get_config, reduced
from repro.core.entropy import KernelEntropy
from repro.launch import steps as S
from repro.launch.serve import (Request, ServeEngine, SlotScheduler,
                                decode_loop_reference)
from repro.models import registry as M

# the shared (cfg, params, prompts) `setup` fixture lives in conftest.py


# ---------------------------------------------------------------------------
# host-side scheduler
# ---------------------------------------------------------------------------

class TestSlotScheduler:
    def test_fifo_admission_and_slot_order(self):
        s = SlotScheduler(2)
        for i in range(5):
            s.submit(_req(i, [0], 4))
        placed = s.admit()
        assert [(slot, r.rid) for slot, r in placed] == [(0, 0), (1, 1)]
        assert s.admit() == []               # both slots busy
        assert len(s.queue) == 3

    def test_eviction_frees_slot_for_next_in_queue(self):
        s = SlotScheduler(2)
        for i in range(4):
            s.submit(_req(i, [0], 4))
        s.admit()
        evicted = s.evict(1)
        assert evicted.rid == 1
        placed = s.admit()                   # slot 1 reused, FIFO order
        assert [(slot, r.rid) for slot, r in placed] == [(1, 2)]
        s.evict(0)
        with pytest.raises(ValueError):
            s.evict(0)                       # evict of an empty slot

    def test_has_work_lifecycle(self):
        s = SlotScheduler(1)
        assert not s.has_work()
        s.submit(_req(0, [0], 1))
        assert s.has_work()
        s.admit()
        assert s.has_work()                  # active slot counts as work
        s.evict(0)
        assert not s.has_work()


# ---------------------------------------------------------------------------
# slot-indexed cache
# ---------------------------------------------------------------------------

class TestSlotCache:
    def test_write_slot_matches_batched_prefill(self, setup):
        cfg, params, prompts = setup
        max_len = 20
        _, batched = M.prefill(params, cfg, jnp.asarray(prompts[:3]),
                               max_len)
        cache = M.make_cache(cfg, 3, max_len)
        for i in range(3):
            _, sub = M.prefill(params, cfg, jnp.asarray(prompts[i:i + 1]),
                               max_len)
            cache = M.write_slot(cfg, cache, jnp.asarray(i, jnp.int32),
                                 sub)
        for leaf_b, leaf_s in zip(jax.tree.leaves(batched),
                                  jax.tree.leaves(cache)):
            np.testing.assert_allclose(np.asarray(leaf_b),
                                       np.asarray(leaf_s), atol=1e-5)

    @pytest.mark.parametrize("arch", ["qwen2_1_5b", "mamba2_370m",
                                      "zamba2_7b"])
    def test_write_slot_generic_across_families(self, arch):
        cfg = reduced(get_config(arch))
        key = jax.random.key(1)
        params = M.init_params(key, cfg)
        toks = jax.random.randint(key, (1, 8), 0, cfg.vocab_size)
        cache = M.make_cache(cfg, 3, 16)
        _, sub = M.prefill(params, cfg, toks, 16)
        cache = M.write_slot(cfg, cache, jnp.asarray(1, jnp.int32), sub)
        np.testing.assert_array_equal(np.asarray(cache["len"]), [0, 8, 0])
        out, cache2 = M.decode_step(params, cfg,
                                    jnp.zeros((3,), jnp.int32), cache, key)
        assert np.isfinite(np.asarray(out["H"])).all()
        np.testing.assert_array_equal(np.asarray(cache2["len"]), [1, 9, 1])

    def test_staggered_slots_decode_like_isolated_sequences(self, setup):
        """Slots at different depths must behave as independent sequences
        (per-slot RoPE positions + per-slot cache offsets).  Deterministic
        head isolates cache correctness from MC noise."""
        cfg, _, prompts = setup
        cfg = dataclasses.replace(cfg, bayesian_head=False)
        params = M.init_params(jax.random.key(7), cfg)
        max_len = 24
        scan = S.build_scan_decode(cfg, chunk=3)
        flags0 = {"epistemic": jnp.zeros((2,), jnp.int32),
                  "aleatoric": jnp.zeros((2,), jnp.int32)}

        # slot 0: request A; decode 3; then admit B into slot 1; decode 3
        cache = M.make_cache(cfg, 2, max_len)
        _, sub_a = M.prefill(params, cfg, jnp.asarray(prompts[:1]), max_len)
        cache = M.write_slot(cfg, cache, jnp.asarray(0, jnp.int32), sub_a)
        tok = jnp.zeros((2,), jnp.int32).at[0].set(int(prompts[0, -1]))
        tok, cache, _, ys1 = scan(params, tok, cache,
                                  jnp.asarray(0, jnp.int32),
                                  jnp.array([True, False]), flags0)
        _, sub_b = M.prefill(params, cfg, jnp.asarray(prompts[1:2]),
                             max_len)
        cache = M.write_slot(cfg, cache, jnp.asarray(1, jnp.int32), sub_b)
        tok = tok.at[1].set(int(prompts[1, -1]))
        tok, cache, _, ys2 = scan(params, tok, cache,
                                  jnp.asarray(3, jnp.int32),
                                  jnp.array([True, True]), flags0)
        a_tokens = np.concatenate([ys1["token"][:, 0], ys2["token"][:, 0]])
        b_tokens = np.asarray(ys2["token"][:, 1])

        ref_a = decode_loop_reference(params, cfg, prompts[:1], 6,
                                      max_len=max_len)
        ref_b = decode_loop_reference(params, cfg, prompts[1:2], 3,
                                      max_len=max_len)
        np.testing.assert_array_equal(a_tokens, ref_a["token"][:, 0])
        np.testing.assert_array_equal(b_tokens, ref_b["token"][:, 0])


# ---------------------------------------------------------------------------
# scan-decode engine
# ---------------------------------------------------------------------------

class TestEngine:
    def test_scan_decode_parity_with_per_token_loop(self, setup):
        """Operand mode, one static wave: the engine's scan decode must
        replay the per-token loop's stream bit for bit."""
        cfg, params, prompts = setup
        gen = 8
        ref = decode_loop_reference(params, cfg, prompts[:3], gen)
        engine = ServeEngine(params, cfg, num_slots=3,
                             max_len=prompts.shape[1] + gen, chunk=4)
        res = engine.run([_req(i, prompts[i], gen) for i in range(3)])
        for j, req in enumerate(res["requests"]):
            np.testing.assert_array_equal(req.tokens, ref["token"][:, j])
            np.testing.assert_array_equal(
                np.asarray(req.MI, np.float32), ref["MI"][:, j])
            np.testing.assert_array_equal(
                np.asarray(req.H, np.float32), ref["H"][:, j])

    def test_continuous_batching_drains_queue(self, setup):
        cfg, params, prompts = setup
        engine = ServeEngine(params, cfg, num_slots=2, max_len=32, chunk=4)
        reqs = [_req(i, prompts[i], 6) for i in range(6)]
        res = engine.run(reqs)
        assert res["gen_tokens"] == 6 * 6
        for r in reqs:
            assert len(r.tokens) == 6 and r.finish_reason == "length"
            assert r.t_finish >= r.t_submit
        # later arrivals wait for a slot: their latency is strictly larger
        assert reqs[-1].latency_s > reqs[0].latency_s

    def test_eos_evicts_early_and_slot_is_reused(self, setup):
        cfg, params, prompts = setup
        mk = lambda: [_req(i, prompts[i], 8) for i in range(4)]
        engine = ServeEngine(params, cfg, num_slots=2, max_len=32, chunk=4)
        probe = engine.run(mk())
        eos = probe["requests"][0].tokens[2]   # deterministic stream
        engine = ServeEngine(params, cfg, num_slots=2, max_len=32, chunk=4,
                             eos_id=eos)
        res = engine.run(mk())
        req0 = res["requests"][0]
        assert req0.finish_reason == "eos"
        assert len(req0.tokens) <= 3
        assert all(len(r.tokens) > 0 for r in res["requests"])  # reuse

    def test_uncertainty_flags_survive_scan(self, setup):
        """The gating flags computed inside the scan carry must equal a
        host-side recomputation from the emitted (MI, SE) streams."""
        cfg, params, prompts = setup
        mi_thr, se_thr = 0.004, 6.0
        engine = ServeEngine(params, cfg, num_slots=2, max_len=32, chunk=4,
                             mi_threshold=mi_thr, se_threshold=se_thr)
        res = engine.run([_req(i, prompts[i], 8) for i in range(4)])
        total_epi = total_alea = 0
        for r in res["requests"]:
            mi = np.asarray(r.MI)
            se = np.asarray(r.SE)
            epi = mi > mi_thr
            alea = (se > se_thr) & ~epi
            assert r.epistemic_flags == int(epi.sum())
            assert r.aleatoric_flags == int(alea.sum())
            total_epi += int(epi.sum())
            total_alea += int(alea.sum())
        assert res["epistemic_flags"] == total_epi
        assert res["aleatoric_flags"] == total_alea
        # device-side carry counters: requests here finish exactly at
        # chunk boundaries, so each slot's counter equals its last
        # occupant's host-side count (slot i served requests i, i+2)
        reqs = res["requests"]
        for slot in range(2):
            last = reqs[slot + 2]
            dev = res["device_flag_counters"]
            assert dev["epistemic"][slot] == last.epistemic_flags
            assert dev["aleatoric"][slot] == last.aleatoric_flags

    def test_request_over_slot_capacity_is_rejected(self, setup):
        cfg, params, prompts = setup
        engine = ServeEngine(params, cfg, num_slots=2, max_len=16, chunk=4)
        with pytest.raises(ValueError, match="slot capacity"):
            engine.run([_req(0, prompts[0], 8)])   # 12 + 8 > 16
        with pytest.raises(ValueError, match="empty prompt"):
            engine.run([_req(0, [], 2)])
        with pytest.raises(ValueError, match="max_new_tokens"):
            engine.run([_req(0, prompts[0], 0)])

    def test_mixed_prompt_lengths_split_compile_from_steady(self, setup):
        """Each distinct prompt length costs one prefill compile; repeat
        lengths must be classified steady, not averaged as recompiles."""
        cfg, params, prompts = setup
        engine = ServeEngine(params, cfg, num_slots=2, max_len=32, chunk=4)
        reqs = [_req(0, prompts[0], 4), _req(1, prompts[1], 4),
                _req(2, prompts[2][:8], 4), _req(3, prompts[3][:8], 4)]
        res = engine.run(reqs)
        assert all(len(r.tokens) == 4 for r in reqs)
        assert res["prefill_steady_s"] > 0.0
        # two compiles (len 12, len 8) dwarf the steady dispatch mean
        assert res["prefill_compile_s"] > 5 * res["prefill_steady_s"]

    def test_seeded_engine_is_deterministic_per_seed(self, setup):
        cfg, params, prompts = setup
        cfg = dataclasses.replace(cfg, head_entropy="kernel")

        def run(seed):
            engine = ServeEngine(params, cfg, num_slots=2, max_len=32,
                                 chunk=4, entropy=KernelEntropy(seed=seed))
            res = engine.run([_req(i, prompts[i], 6) for i in range(2)])
            return np.asarray([r.MI for r in res["requests"]])

        a, b, c = run(3), run(3), run(4)
        np.testing.assert_array_equal(a, b)
        assert not np.allclose(a, c)


# ---------------------------------------------------------------------------
# train-step seeding (satellite bugfix)
# ---------------------------------------------------------------------------

class TestTrainSeed:
    def test_two_seeds_diverge_same_seed_replays(self):
        from repro.core.svi import SVIConfig
        from repro.optim import adamw
        cfg = reduced(get_config("qwen2_1_5b"))
        opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=0,
                                    schedule="constant")
        svi = SVIConfig(num_train_examples=10_000)
        key = jax.random.key(0)
        params = M.init_params(key, cfg)
        batch = M.make_batch(key, cfg, 2, 16)

        def losses(seed):
            fn = jax.jit(S.build_train_step(cfg, opt_cfg, svi, seed=seed))
            state = {"params": params,
                     "opt": adamw.init_state(params, opt_cfg)}
            out = []
            for _ in range(2):
                state, m = fn(state, batch)
                out.append(float(m["loss"]))
            return out

        a, b, c = losses(0), losses(0), losses(1)
        assert a == b                      # same seed -> same SVI stream
        assert a != c                      # the --seed actually threads
