"""Core paper machinery: entropy sources, photonic twin, SVI, uncertainty."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import entropy as E
from repro.core import photonic as PH
from repro.core import svi
from repro.core import uncertainty as U
from repro.core.bayesian import GaussianVariational, mc_forward
from repro.core.surrogate import SurrogateSpec


# ---------------------------------------------------------------------------
# entropy sources
# ---------------------------------------------------------------------------

class TestEntropy:
    def test_prng_standard_moments(self):
        eps = E.PRNGEntropy().sample(jax.random.key(0), (200_000,))
        assert abs(float(eps.mean())) < 0.01
        assert abs(float(eps.std()) - 1.0) < 0.01

    def test_ase_standard_moments_and_skew(self):
        """Gamma(M) standardization keeps mean 0 / std 1 but the chaotic
        light's positive skew 2/sqrt(M) — the physics the Gaussian
        surrogate approximates away."""
        modes = 30.0
        src = E.ASEEntropy(modes=modes)
        eps = np.asarray(src.sample(jax.random.key(1), (400_000,)))
        assert abs(eps.mean()) < 0.01
        assert abs(eps.std() - 1.0) < 0.01
        skew = ((eps - eps.mean()) ** 3).mean() / eps.std() ** 3
        np.testing.assert_allclose(skew, 2 / np.sqrt(modes), rtol=0.15)

    def test_bandwidth_maps_are_inverse(self):
        bw = jnp.linspace(E.BW_MIN_GHZ, E.BW_MAX_GHZ, 7)
        m = E.modes_from_bandwidth(bw)
        rel = 1.0 / jnp.sqrt(m)
        np.testing.assert_allclose(E.bandwidth_for_relstd(rel), bw,
                                   rtol=1e-5)

    def test_relstd_range_matches_paper_68pct(self):
        """25-150 GHz must span a ~sqrt(6)x (≈68% around center) sigma
        tuning range (paper §System Architecture)."""
        lo, hi = E.relstd_range()
        np.testing.assert_allclose(hi / lo, np.sqrt(6.0), rtol=1e-6)

    def test_entropy_stream_draw_and_wraparound(self):
        s = E.EntropyStream.create(jax.random.key(2), 100)
        a, s2 = s.draw((30,))
        b, s3 = s2.draw((30,))
        assert not np.allclose(a, b)
        assert int(s3.cursor) == 60
        c, s4 = s3.draw((60,))      # wraps
        assert int(s4.cursor) == 20
        np.testing.assert_allclose(c[40:], np.asarray(s.buffer[:20]))

    def test_kernel_entropy_moments_skew_and_determinism(self):
        """Contract of the in-kernel TPU PRNG source: standard normal —
        mean 0, std 1, skew 0 (vs ASE's 2/sqrt(M)) — and the stream is a
        pure function of the base seed (same seed -> same bits; the
        property that lets the uncertainty head regenerate its sample
        logits instead of re-reading them from HBM)."""
        src = E.KernelEntropy(seed=42)
        eps = np.asarray(src.sample(None, (400_000,)))
        assert abs(eps.mean()) < 0.01
        assert abs(eps.std() - 1.0) < 0.01
        skew = ((eps - eps.mean()) ** 3).mean() / eps.std() ** 3
        assert abs(skew) < 0.02              # Gaussian: no residual skew
        eps2 = np.asarray(E.KernelEntropy(seed=42).sample(None, (400_000,)))
        np.testing.assert_array_equal(eps, eps2)
        eps3 = np.asarray(E.KernelEntropy(seed=43).sample(None, (1000,)))
        assert not np.allclose(eps[:1000], eps3)

    def test_kernel_entropy_fold_is_stable_and_distinct(self):
        src = E.KernelEntropy(seed=5)
        assert int(src.fold(1, 2)) == int(E.KernelEntropy(seed=5).fold(1, 2))
        assert int(src.fold(1, 2)) != int(src.fold(2, 1))
        assert int(src.fold()) != int(E.KernelEntropy(seed=6).fold())

    def test_entropy_health_flags_dead_source(self):
        rng = np.random.default_rng(0)
        good = E.entropy_health((rng.random(20_000) > 0.5).astype(np.uint8))
        dead = E.entropy_health(np.ones(20_000, np.uint8))
        assert good["monobit_z"] < 4.0
        assert dead["monobit_z"] > 50.0

    def test_gaussian_bits_pass_health(self):
        eps = np.asarray(E.PRNGEntropy().sample(jax.random.key(3), (40_000,)))
        h = E.entropy_health(E.gaussian_to_bits(eps))
        assert h["monobit_z"] < 4.0 and h["runs_z"] < 4.0
        assert h["byte_chi2"] < 400.0     # 255 dof


# ---------------------------------------------------------------------------
# photonic digital twin
# ---------------------------------------------------------------------------

class TestPhotonicMachine:
    def test_quantize_ste_grid_and_gradient(self):
        x = jnp.linspace(-1, 1, 11)
        q = PH.quantize_ste(x, 8, 1.0)
        assert float(jnp.abs(q - x).max()) <= 1.0 / 127 + 1e-6
        g = jax.grad(lambda v: PH.quantize_ste(v, 8, 1.0).sum())(x)
        np.testing.assert_allclose(g, 1.0)   # straight-through

    def test_convolve_mean_tracks_target(self):
        cfg = PH.MachineConfig(detector_noise=0.0, crosstalk=0.0,
                               drift_std=0.0, eom_mod_depth=0.0)
        mu = jnp.linspace(-0.6, 0.6, 9)
        prog = PH.ChannelProgram(power=mu, bandwidth=jnp.full((9,), 150.0))
        x = jax.random.uniform(jax.random.key(0), (64,), minval=-1, maxval=1)
        keys = jax.random.split(jax.random.key(1), 2000)
        ys = jax.vmap(lambda k: PH.convolve(k, x, prog, cfg))(keys)
        C = 9
        idx = jnp.arange(x.shape[-1] - C + 1)[:, None] + jnp.arange(C)
        target = x[idx] @ mu[::-1]
        np.testing.assert_allclose(ys.mean(0), target, atol=0.05)

    def test_calibration_reduces_error(self):
        key = jax.random.key(4)
        mu_t = jnp.array([0.5, -0.3, 0.7, -0.6, 0.2, 0.4, -0.5, 0.3, -0.2])
        sg_t = jnp.abs(mu_t) * 0.15
        _, hist = PH.calibrate(key, mu_t, sg_t, iters=8, n_shots=256)
        assert hist["mu_err"][-1] < hist["mu_err"][0]
        assert hist["mu_err"][-1] < 0.05

    def test_computation_error_in_paper_band(self):
        """Fig. 2c/d: mean err ~0.158, std err ~0.266.  The twin must land
        in the same regime (we assert generous bands, not exact figures).
        The ordering comes from the bandwidth axis being the machine's
        less accurate one: the balanced receiver's mode count puts the
        realizable sigma floor (1/sqrt(M_max)) inside the target range,
        and waveshaper quantization/jitter sit on top of it -- none of
        which the power (mean) axis sees."""
        r = PH.computation_error(jax.random.key(5), n_kernels=6,
                                 n_shots=256, seq_len=48)
        assert r["mean_error"] < 0.35
        assert r["std_error"] < 0.6
        assert r["mean_error"] < r["std_error"]  # paper's ordering

    def test_effective_bandwidth_quantizes_and_jitters(self):
        cfg = PH.MachineConfig(bw_quant_ghz=12.5, bw_jitter_std=0.0)
        bw = jnp.array([26.0, 99.0, 150.0])
        eff = PH.effective_bandwidth(jax.random.key(0), bw, cfg)
        np.testing.assert_allclose(eff, [25.0, 100.0, 150.0])
        cfg = PH.MachineConfig(bw_quant_ghz=0.0, bw_jitter_std=0.1)
        effs = jax.vmap(lambda k: PH.effective_bandwidth(
            k, jnp.full((64,), 100.0), cfg))(
                jax.random.split(jax.random.key(1), 256))
        rel = np.asarray(effs) / 100.0 - 1.0
        assert abs(rel.std() - 0.1) < 0.02      # per-shot filter jitter
        assert (np.asarray(effs) >= E.BW_MIN_GHZ).all()
        assert (np.asarray(effs) <= E.BW_MAX_GHZ).all()

    def test_throughput_constants(self):
        t = PH.conv_throughput_estimate()
        np.testing.assert_allclose(t["conv_per_s"], 26.7e9, rtol=0.01)
        np.testing.assert_allclose(t["interface_tbit_s"], 1.28, rtol=0.01)
        assert t["latency_ps"] == 37.5


# ---------------------------------------------------------------------------
# variational layers + SVI
# ---------------------------------------------------------------------------

class TestSVI:
    def test_kl_closed_form_vs_monte_carlo(self):
        q = GaussianVariational(mu=jnp.array([0.5, -1.0]),
                                rho=jnp.array([0.0, 0.5]))
        kl = float(q.kl_to_prior(1.0))
        # MC estimate of E_q[log q - log p]
        key = jax.random.key(0)
        w = q.sample(key, num=200_000)
        s = q.sigma
        logq = (-0.5 * ((w - q.mu) / s) ** 2 - jnp.log(s)
                - 0.5 * jnp.log(2 * jnp.pi)).sum(-1)
        logp = (-0.5 * w ** 2 - 0.5 * jnp.log(2 * jnp.pi)).sum(-1)
        np.testing.assert_allclose(kl, float((logq - logp).mean()),
                                   rtol=0.02)

    def test_kl_zero_at_prior(self):
        from repro.core.bayesian import inv_softplus
        q = GaussianVariational(mu=jnp.zeros(5),
                                rho=jnp.full((5,), inv_softplus(1.0)))
        assert abs(float(q.kl_to_prior(1.0))) < 1e-5

    def test_reparam_gradients_flow_to_both_moments(self):
        def loss(q):
            w = q.sample_with_eps(jnp.array([0.7]))
            return (w - 2.0).squeeze() ** 2

        q = GaussianVariational(mu=jnp.array([0.0]), rho=jnp.array([0.0]))
        g = jax.grad(loss)(q)
        assert abs(float(g.mu[0])) > 0 and abs(float(g.rho[0])) > 0

    def test_kl_beta_warmup(self):
        cfg = svi.SVIConfig(kl_warmup_steps=100)
        assert float(svi.kl_beta(jnp.asarray(0), cfg)) == 0.0
        assert float(svi.kl_beta(jnp.asarray(50), cfg)) == 0.5
        assert float(svi.kl_beta(jnp.asarray(500), cfg)) == 1.0

    def test_elbo_loss_aggregates(self):
        q = GaussianVariational.init(jax.random.key(0), (4, 3), fan_in=4)
        params = {"q": q, "w": jnp.ones((3,))}

        def nll_fn(p, batch, key):
            return jnp.square(batch["x"] @ p["q"].mu).mean(), {"m": jnp.ones(())}

        cfg = svi.SVIConfig(kl_warmup_steps=1, num_train_examples=10)
        loss, aux = svi.elbo_loss(
            nll_fn, params, {"x": jnp.ones((2, 4))}, jax.random.key(1),
            jnp.asarray(10), cfg)
        expected = aux["nll"] + aux["kl"] / 10
        np.testing.assert_allclose(float(loss), float(expected), rtol=1e-5)

    def test_surrogate_sigma_clamp_is_ste(self):
        spec = SurrogateSpec()
        q = GaussianVariational(mu=jnp.array([0.5]),
                                rho=jnp.array([5.0]))  # huge sigma

        def f(q):
            return spec.apply_weight(q, jnp.array([1.0])).sum()

        g = jax.grad(f)(q)
        # forward is clamped...
        w = spec.apply_weight(q, jnp.array([1.0]))
        lo, hi = E.relstd_range()
        assert float(w[0]) <= float((q.mu + hi * jnp.abs(q.mu))[0]) + 1e-2
        # ...but the sigma gradient still flows (STE)
        assert abs(float(g.rho[0])) > 0

    def test_mc_forward_shapes(self):
        out = mc_forward(lambda k: jax.random.normal(k, (3,)),
                         jax.random.key(0), 10)
        assert out.shape == (10, 3)
        assert not np.allclose(out[0], out[1])

    def test_mc_forward_seeded_is_seed_deterministic(self):
        from repro.core.bayesian import mc_forward_seeded
        fn = lambda k: jax.random.normal(k, (3,))
        a = mc_forward_seeded(fn, E.KernelEntropy(seed=9), 6)
        b = mc_forward_seeded(fn, E.KernelEntropy(seed=9), 6)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        c = mc_forward_seeded(fn, E.KernelEntropy(seed=10), 6)
        assert a.shape == (6, 3) and not np.allclose(a, c)
        assert not np.allclose(a[0], a[1])     # samples independent

    def test_bayes_dense_sampled_moments_and_determinism(self):
        from repro.core.bayesian import bayes_dense_sampled
        q = GaussianVariational.init(jax.random.key(0), (16, 8), fan_in=16,
                                     init_sigma=0.1)
        x = jax.random.normal(jax.random.key(1), (4, 16))
        src = E.KernelEntropy(seed=21)
        y = bayes_dense_sampled(x, q, src, num_samples=256)
        assert y.shape == (256, 4, 8)
        y2 = bayes_dense_sampled(x, q, src, num_samples=256)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(y2))
        np.testing.assert_allclose(np.asarray(y.mean(0)),
                                   np.asarray(x @ q.mu), atol=0.2)


# ---------------------------------------------------------------------------
# uncertainty metrics
# ---------------------------------------------------------------------------

class TestUncertainty:
    def test_decomposition_identity(self):
        probs = jax.nn.softmax(
            jax.random.normal(jax.random.key(0), (10, 50, 7)), -1)
        m = U.predictive_moments(probs)
        np.testing.assert_allclose(m["H"], m["SE"] + m["MI"], atol=1e-5)

    def test_confident_consistent_has_low_everything(self):
        p = jnp.zeros((10, 1, 5)).at[:, :, 2].set(30.0)
        m = U.uncertainty_from_logits(p)
        assert float(m["H"][0]) < 1e-3 and float(m["MI"][0]) < 1e-3

    def test_disagreement_is_epistemic(self):
        """Each sample confident but in different classes -> high MI,
        low SE (paper Fig. 4f / 5c)."""
        logits = jnp.zeros((5, 1, 5))
        for i in range(5):
            logits = logits.at[i, 0, i].set(30.0)
        m = U.uncertainty_from_logits(logits)
        assert float(m["MI"][0]) > 1.0
        assert float(m["SE"][0]) < 1e-3

    def test_ambiguity_is_aleatoric(self):
        """Every sample 50/50 between two classes -> high SE, zero MI
        (paper Fig. 5d)."""
        logits = jnp.zeros((8, 1, 5))
        logits = logits.at[:, 0, 0].set(10.0).at[:, 0, 1].set(10.0)
        m = U.uncertainty_from_logits(logits)
        assert float(m["SE"][0]) > 0.6
        assert float(m["MI"][0]) < 1e-4

    def test_auroc_perfect_and_chance(self):
        pos = jnp.array([0.9, 0.8, 0.95])
        neg = jnp.array([0.1, 0.2, 0.05])
        assert float(U.auroc(pos, neg)) == 1.0
        assert float(U.auroc(neg, pos)) == 0.0
        same = jnp.array([0.5, 0.5])
        assert float(U.auroc(same, same)) == 0.5

    def test_roc_curve_monotone(self):
        key = jax.random.key(1)
        pos = jax.random.normal(key, (500,)) + 1.0
        neg = jax.random.normal(jax.random.key(2), (500,))
        r = U.roc_curve(pos, neg, 64)
        assert (jnp.diff(r["tpr"]) >= -1e-6).all()
        assert (jnp.diff(r["fpr"]) >= -1e-6).all()

    def test_rejection_improves_accuracy(self):
        """Wrong predictions given higher MI -> rejecting high-MI raises
        accepted accuracy (the paper's Fig. 4d mechanism)."""
        n = 400
        labels = jnp.zeros((n,), jnp.int32)
        p_mean = jnp.zeros((n, 2)).at[: n // 2, 0].set(1.0) \
            .at[n // 2:, 1].set(1.0)   # second half wrong
        mi = jnp.concatenate([jnp.full((n // 2,), 0.01),
                              jnp.full((n // 2,), 0.5)])
        r = U.rejection_accuracy(p_mean, mi, labels, threshold=0.1)
        assert float(r["accuracy_all"]) == 0.5
        assert float(r["accuracy_accepted"]) == 1.0
        np.testing.assert_allclose(float(r["rejection_rate"]), 0.5)


@settings(max_examples=25, deadline=None)
@given(s=st.integers(2, 12), b=st.integers(1, 8), c=st.integers(2, 9),
       seed=st.integers(0, 2**31 - 1))
def test_prop_uncertainty_decomposition(s, b, c, seed):
    """H = SE + MI >= both >= 0, for any MC predictive tensor."""
    logits = 3 * jax.random.normal(jax.random.key(seed), (s, b, c))
    m = U.uncertainty_from_logits(logits)
    assert (m["H"] >= -1e-6).all() and (m["SE"] >= -1e-6).all()
    assert (m["MI"] >= -1e-6).all()
    np.testing.assert_allclose(m["H"], m["SE"] + m["MI"], atol=1e-4)
    assert (m["H"] <= np.log(c) + 1e-4).all()
