"""Partition-rule machinery: divisibility fallbacks, registry sweep,
device-placement round-trip.

``sanitize_pspecs`` / ``spec_if`` are the reason the name-based rule
tables can stay clean while published vocab/head sizes are not always
mesh-divisible: every dim that does not divide its mesh-axis product
must silently fall back to replication, because ``jit(in_shardings=…)``
(unlike a mere constraint) requires exact divisibility.
"""

import json
import os
import subprocess
import sys
import textwrap

import jax
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs.registry import ARCH_IDS, get_config
from repro.launch.mesh import make_debug_mesh, spec_if
from repro.models import registry as M
from repro.sharding.partition import (param_pspecs, sanitize_pspecs,
                                      serve_pspecs)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

MESH_1x4 = AbstractMesh((("data", 1), ("model", 4)))
MESH_2x2 = AbstractMesh((("data", 2), ("model", 2)))


def _axis_product(mesh, d):
    axes = (d,) if isinstance(d, str) else tuple(d)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


# ---------------------------------------------------------------------------
# spec_if: per-dim divisibility fallback
# ---------------------------------------------------------------------------

class TestSpecIf:
    def test_divisible_dims_shard(self):
        assert spec_if(MESH_1x4, (3, 8), None, "model") == P(None, "model")

    def test_indivisible_dim_replicates(self):
        # 6 % 4 != 0: the model axis is dropped, not erred
        assert spec_if(MESH_1x4, (3, 6), None, "model") == P(None, None)

    def test_dim_smaller_than_axis_replicates(self):
        # a 1-head KV pool cannot shard over 4 devices
        assert spec_if(MESH_1x4, (10, 8, 1, 32),
                       None, None, "model", None) \
            == P(None, None, None, None)

    def test_batch_expands_to_dp_axes(self):
        assert spec_if(MESH_2x2, (4, 8), "batch", None) == P("data", None)


# ---------------------------------------------------------------------------
# sanitize_pspecs over every registry config's REAL param shapes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("mesh,rules", [(MESH_1x4, "serve"),
                                        (MESH_2x2, "train")])
def test_sanitized_specs_divide_for(arch, mesh, rules):
    """Every surviving shard axis divides its dim — jit-placeable — at
    the PUBLISHED sizes (eval_shape: no multi-GB allocation)."""
    cfg = get_config(arch)
    shapes = jax.eval_shape(lambda k: M.init_params(k, cfg),
                            jax.random.key(0))
    specs = serve_pspecs(shapes) if rules == "serve" \
        else param_pspecs(shapes)
    clean = sanitize_pspecs(specs, shapes, mesh)
    flat_specs = jax.tree.leaves(clean, is_leaf=lambda x: isinstance(x, P))
    flat_shapes = jax.tree.leaves(shapes)
    assert len(flat_specs) == len(flat_shapes)
    sharded = 0
    for spec, shaped in zip(flat_specs, flat_shapes):
        for size, d in zip(shaped.shape, tuple(spec)):
            if d is not None:
                sharded += 1
                assert size % _axis_product(mesh, d) == 0, \
                    (arch, shaped.shape, spec)
    if rules == "train":
        # the sweep must not sanitize everything away
        assert sharded > 0, arch


# ---------------------------------------------------------------------------
# shardings_for round-trip on a real 1x2 debug mesh (subprocess: the
# forced device count must be pinned before jax initializes)
# ---------------------------------------------------------------------------

_ROUNDTRIP = textwrap.dedent("""
    import json
    import jax, numpy as np
    from repro.configs.registry import get_config, reduced
    from repro.launch.mesh import make_debug_mesh
    from repro.models import registry as M
    from repro.sharding.partition import shardings_for

    cfg = reduced(get_config("qwen2_1_5b"))
    params = M.init_params(jax.random.key(0), cfg)
    mesh = make_debug_mesh((1, 2), ("data", "model"))
    assert mesh.shape == {"data": 1, "model": 2}, mesh
    placed = jax.device_put(params, shardings_for(params, mesh))
    same = jax.tree.all(jax.tree.map(
        lambda a, b: bool(np.array_equal(np.asarray(a), np.asarray(b))),
        params, placed))
    n_sharded = sum(
        1 for leaf in jax.tree.leaves(placed)
        if not leaf.sharding.is_fully_replicated)
    print(json.dumps({"same": bool(same), "n_sharded": n_sharded}))
""")


def test_shardings_for_roundtrip_1x2():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    out = subprocess.run([sys.executable, "-c", _ROUNDTRIP],
                         capture_output=True, text=True, env=env,
                         timeout=300, cwd=REPO)
    assert out.returncode == 0, out.stdout + out.stderr
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["same"], "device_put round-trip changed param bytes"
    assert rec["n_sharded"] > 0, "nothing sharded on a 2-device mesh"


def test_debug_mesh_exact_tile_keeps_shape():
    # this 1-device process CAN tile (1, 1)
    mesh = make_debug_mesh((1, 1), ("data", "model"))
    assert mesh.axis_names == ("data", "model")
