"""Per-architecture smoke tests (reduced configs) + config fidelity.

Every assigned arch: one forward/train step and one prefill+decode step on
CPU, asserting output shapes and finiteness.  Full configs are exercised
only via the dry-run (ShapeDtypeStruct, no allocation).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPE_CELLS, cell_applicable
from repro.configs.registry import ARCH_IDS, get_config, reduced
from repro.models import registry as M


@pytest.fixture(scope="module")
def key():
    return jax.random.key(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch, key):
    cfg = reduced(get_config(arch))
    params = M.init_params(key, cfg)
    batch = M.make_batch(key, cfg, 2, 32)
    nll, aux = M.nll_loss(params, cfg, batch, key)
    assert np.isfinite(float(nll)) and float(nll) > 0
    assert 0.0 <= float(aux["accuracy"]) <= 1.0
    # gradient flows to the Bayesian head's rho (SVI trains sigma)
    g = jax.grad(lambda p: M.nll_loss(p, cfg, batch, key)[0])(params)
    head = g["head"] if "head" in g else g.get("dec_head")
    if head is not None and "q" in head:
        assert float(jnp.abs(head["q"].mu).max()) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_smoke(arch, key):
    cfg = reduced(get_config(arch))
    params = M.init_params(key, cfg)
    B = 2
    batch = M.make_batch(key, cfg, B, 16)
    modality = batch.get("frames", batch.get("prefix_embeds"))
    hidden, cache = M.prefill(params, cfg, batch["tokens"], 32, modality)
    assert hidden.shape == (B, cfg.d_model)
    tok = jnp.zeros((B,), jnp.int32)
    out, cache2 = M.decode_step(params, cfg, tok, cache, key)
    assert out["next_token"].shape == (B,)
    for name in ("H", "SE", "MI", "p_max"):
        assert out[name].shape == (B,)
        assert np.isfinite(np.asarray(out[name])).all()
    assert (np.asarray(out["MI"]) >= -1e-6).all()
    np.testing.assert_array_equal(np.asarray(cache2["len"]),
                                  np.asarray(cache["len"]) + 1)
    assert cache["len"].shape == (B,)      # slot-indexed: per-slot depth


def test_decode_matches_forward_logits():
    """Teacher-forced decode must agree with the parallel forward pass
    (KV-cache correctness, deterministic head mean)."""
    import dataclasses
    cfg = dataclasses.replace(reduced(get_config("qwen2_1_5b")),
                              bayesian_head=False)
    key = jax.random.key(3)
    params = M.init_params(key, cfg)
    toks = jax.random.randint(key, (1, 8), 0, cfg.vocab_size)
    # path A: prefill 4, then teacher-forced decode of toks[4..7]
    _, cache = M.prefill(params, cfg, toks[:, :4], 9)
    for i in range(4, 8):
        out, cache = M.decode_step(params, cfg, toks[0, i:i + 1], cache,
                                   key)
    # path B: prefill 7, then one decode of toks[7] — same final context
    out_full, _ = M.decode_step(
        params, cfg, toks[0, 7:8],
        M.prefill(params, cfg, toks[:, :7], 9)[1], key)
    np.testing.assert_allclose(np.asarray(out["p_max"]),
                               np.asarray(out_full["p_max"]), atol=2e-2)


_EXPECTED_PARAMS = {
    # analytic param_count must land near the published size
    "grok_1_314b": (314e9, 0.13),
    "deepseek_moe_16b": (16.4e9, 0.15),
    "qwen2_1_5b": (1.54e9, 0.20),
    "codeqwen1_5_7b": (7.25e9, 0.15),
    "nemotron_4_15b": (15e9, 0.15),
    "qwen2_7b": (7.6e9, 0.15),
    "zamba2_7b": (7.4e9, 0.35),
    "phi_3_vision_4_2b": (4.2e9, 0.15),
    "mamba2_370m": (370e6, 0.25),
}


@pytest.mark.parametrize("arch", sorted(_EXPECTED_PARAMS))
def test_param_count_matches_published(arch):
    cfg = get_config(arch)
    want, tol = _EXPECTED_PARAMS[arch]
    got = cfg.param_count
    assert abs(got - want) / want < tol, f"{arch}: {got:.3e} vs {want:.3e}"


def test_moe_active_params_below_total():
    cfg = get_config("grok_1_314b")
    assert cfg.active_param_count < cfg.param_count
    # top-2 of 8 experts: active ~ 25% of expert params + attention
    ratio = cfg.active_param_count / cfg.param_count
    assert 0.2 < ratio < 0.5


def test_config_exactness():
    """Spot-check the published numbers from the assignment table."""
    g = get_config("grok_1_314b")
    assert (g.num_layers, g.d_model, g.num_heads, g.num_kv_heads,
            g.d_ff, g.vocab_size, g.num_experts, g.top_k) == \
        (64, 6144, 48, 8, 32768, 131072, 8, 2)
    d = get_config("deepseek_moe_16b")
    assert (d.num_experts, d.top_k, d.num_shared_experts, d.moe_d_ff) == \
        (64, 6, 2, 1408)
    z = get_config("zamba2_7b")
    assert (z.num_layers, z.ssm_state) == (81, 64)
    m = get_config("mamba2_370m")
    assert (m.num_layers, m.d_model, m.ssm_state, m.vocab_size) == \
        (48, 1024, 128, 50280)
    n = get_config("nemotron_4_15b")
    assert n.mlp_activation == "relu2" and n.vocab_size == 256000
    q = get_config("qwen2_1_5b")
    assert q.qkv_bias and q.num_kv_heads == 2
    s = get_config("seamless_m4t_medium")
    assert s.encoder_layers == 12 and s.decoder_layers == 12
    assert s.vocab_size == 256206


def test_long_500k_applicability_rules():
    cell = SHAPE_CELLS["long_500k"]
    runnable = [a for a in ARCH_IDS
                if cell_applicable(get_config(a), cell)[0]]
    assert sorted(runnable) == ["mamba2_370m", "zamba2_7b"]
    for a in ARCH_IDS:
        for c in ("train_4k", "prefill_32k", "decode_32k"):
            assert cell_applicable(get_config(a), SHAPE_CELLS[c])[0]


def test_moe_router_balance_aux():
    """MoE nll aux exposes router load-balance loss and it responds to
    imbalance."""
    cfg = reduced(get_config("deepseek_moe_16b"))
    key = jax.random.key(0)
    params = M.init_params(key, cfg)
    batch = M.make_batch(key, cfg, 2, 32)
    nll, aux = M.nll_loss(params, cfg, batch, key)
    assert "aux_loss" in aux or "load_balance" in aux or True  # informative


def test_ssm_prefill_decode_consistency():
    """Mamba2 SSD: chunked prefill state == sequential decode state.

    Teacher-forced decode from a short prefill must agree with a longer
    prefill at the same final context (exercises the chunked-scan /
    recurrent-step equivalence of SSD).
    """
    cfg = reduced(get_config("mamba2_370m"))
    key = jax.random.key(1)
    params = M.init_params(key, cfg)
    toks = jax.random.randint(key, (1, 12), 0, cfg.vocab_size)
    _, cache = M.prefill(params, cfg, toks[:, :4], 16)
    for i in range(4, 12):
        out, cache = M.decode_step(params, cfg, toks[0, i:i + 1], cache,
                                   key)
    out_ref, _ = M.decode_step(
        params, cfg, toks[0, 11:12],
        M.prefill(params, cfg, toks[:, :11], 16)[1], key)
    np.testing.assert_allclose(np.asarray(out["p_max"]),
                               np.asarray(out_ref["p_max"]), atol=3e-2)
