"""End-to-end dry-run integration: lower+compile on the production mesh.

Runs ``repro.launch.dryrun`` in a SUBPROCESS (the 512 placeholder
devices must be pinned before jax initializes, and this test process
already holds a 1-device jax), for the cheapest cells — proving the
deliverable-(e) path (mesh build, shardings, compile, artifact record)
works from a clean interpreter.
"""

import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_dryrun(tmp_path, arch, shape, mesh):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--mesh", mesh, "--out", str(tmp_path)],
        capture_output=True, text=True, env=env, timeout=540, cwd=REPO)


@pytest.mark.parametrize("mesh", ["single", "multi"])
def test_dryrun_cheapest_cell_compiles(tmp_path, mesh):
    out = _run_dryrun(tmp_path, "mamba2_370m", "long_500k", mesh)
    assert "all dry-run cells green" in out.stdout, out.stdout + out.stderr
    tag = "multi" if mesh == "multi" else "single"
    rec = json.load(open(tmp_path / f"mamba2_370m__long_500k__{tag}.json"))
    assert rec["num_devices"] == (512 if mesh == "multi" else 256)
    assert rec["hlo_cost"]["flops"] > 0
    assert rec["hlo_cost"]["bytes"] > 0
    assert rec["memory_analysis"]["peak_bytes"] > 0
    # decode of an SSM at 500k must NOT scale memory with seq_len
    # (constant-size state): per-device peak well under 1 GB
    assert rec["memory_analysis"]["peak_bytes"] < 1e9


def test_dryrun_skip_rule(tmp_path):
    out = _run_dryrun(tmp_path, "qwen2_7b", "long_500k", "single")
    assert "SKIP" in out.stdout
    rec = json.load(open(tmp_path / "qwen2_7b__long_500k__single.json"))
    assert "skipped" in rec
