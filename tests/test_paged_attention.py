"""Block-sparse paged decode attention kernel + serving-stats fixes.

The acceptance contract of ISSUE 5:

  * the block-sparse kernel (``--decode-attn kernel``) is BIT-EXACT
    against the gather reference (``--decode-attn gather``) in
    operand/interpret mode — at the raw-op level over shuffled
    staggered tables, and through the engine on mixed-length traffic
    with ``--prefix-cache on`` including post-CoW tables;
  * per-step KV reads scale with the tokens actually cached, not the
    ``MB*BS`` logical span;
  * ``paged_gather``'s unmapped-entry fallback (physical block 0 —
    potentially a prefix-cache-OWNED block) never leaks cached bytes
    into a softmax: masked positions are ``-inf`` before the reduction
    on BOTH read paths;
  * seeded decode is chunk-size invariant (``--chunk 4`` == ``16``);
  * a mid-run exception releases every slot's blocks — the leak check
    runs in a ``finally``, not only after a clean drain;
  * ``sched_trace`` is downsampled by ``--trace-every``, and the
    latency tail stats are nearest-rank (a percentile some request
    actually experienced), with ``latency_max_s`` alongside.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config, reduced
from repro.kernels import ops
from repro.launch.serve import Request, ServeEngine
from repro.models import layers as L
from repro.models import registry as M


def _req(rid, prompt, n):
    return Request(rid=rid, prompt=np.asarray(prompt, np.int32),
                   max_new_tokens=n)


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(reduced(get_config("qwen2_1_5b")),
                              head_entropy="operand")
    key = jax.random.key(0)
    params = M.init_params(key, cfg)
    prompts = np.asarray(
        jax.random.randint(key, (8, 12), 0, cfg.vocab_size), np.int32)
    return cfg, params, prompts


def _pools(key, NB=12, BS=8, Hkv=2, D=32, H=4, B=3):
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (B, 1, H, D), jnp.float32)
    kp = jax.random.normal(k2, (NB, BS, Hkv, D), jnp.float32)
    vp = jax.random.normal(k3, (NB, BS, Hkv, D), jnp.float32)
    return q, kp, vp


# ---------------------------------------------------------------------------
# raw-op parity: kernel vs gather over shuffled, staggered tables
# ---------------------------------------------------------------------------

class TestKernelParity:
    def test_kernel_bitwise_vs_gather_shuffled_tables(self):
        """Staggered depths, shuffled physical placement, a granted-
        ahead tail block and a junk (evicted) slot: the kernel's output
        must equal the gather+mask reference bit for bit on every slot
        whose span is readable."""
        q, kp, vp = _pools(jax.random.key(1))
        BS, MB = 8, 5
        bt = np.full((3, MB), -1, np.int32)
        bt[0, :4] = [5, 1, 9, 3]          # 27 tokens over 4 blocks
        bt[1, :3] = [0, 7, 2]             # 18 tokens, granted ahead
        cl = np.array([27, 18, 6], np.int32)
        bt[2, :] = -1                     # evicted slot, depth still > 0
        bt, cl = jnp.asarray(bt), jnp.asarray(cl)
        ref = ops.paged_decode_attention(q, kp, vp, bt, cl, impl="ref")
        got = ops.paged_decode_attention(q, kp, vp, bt, cl)
        np.testing.assert_array_equal(np.asarray(ref)[:2],
                                      np.asarray(got)[:2])
        # the junk slot is fully masked on both paths: NaN, never a
        # finite readout of some other request's block
        assert np.isnan(np.asarray(ref)[2]).all()
        assert np.isnan(np.asarray(got)[2]).all()

    def test_kernel_invariant_to_physical_placement(self):
        """Post-CoW tables differ only in physical ids: relocating a
        block (same logical content) must not change a single bit."""
        q, kp, vp = _pools(jax.random.key(2))
        BS, MB = 8, 5
        bt1 = jnp.asarray([[5, 1, 9, -1, -1]] * 3, jnp.int32)
        # copy block 9 into free block 4 and swap the table entry — the
        # device-side CoW sequence the engine runs at divergence
        kp2 = L.copy_block(kp, 9, 4)
        vp2 = L.copy_block(vp, 9, 4)
        bt2 = jnp.asarray([[5, 1, 4, -1, -1]] * 3, jnp.int32)
        cl = jnp.asarray([21, 17, 24], jnp.int32)
        a = ops.paged_decode_attention(q, kp, vp, bt1, cl)
        b = ops.paged_decode_attention(q, kp2, vp2, bt2, cl)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_engine_kernel_matches_gather_staggered(self, setup):
        """Mixed prompt/gen lengths through 2 slots: every request's
        token and uncertainty streams must match bit for bit between
        the two read paths, and the kernel's accounted reads must
        undercut the logical span."""
        cfg, params, prompts = setup
        gens = (8, 4, 8, 6, 8, 5)

        def reqs():
            return [_req(i, prompts[i][:(12 if i % 2 == 0 else 8)],
                         gens[i]) for i in range(6)]

        res = {}
        for mode in ("gather", "kernel"):
            eng = ServeEngine(params, cfg, num_slots=2, max_len=32,
                              chunk=4, kv_layout="paged", kv_block=8,
                              decode_attn=mode)
            res[mode] = eng.run(reqs())
        for a, b in zip(res["gather"]["requests"],
                        res["kernel"]["requests"]):
            np.testing.assert_array_equal(a.tokens, b.tokens)
            for name in ("MI", "H", "SE"):
                np.testing.assert_array_equal(
                    np.asarray(getattr(a, name), np.float32),
                    np.asarray(getattr(b, name), np.float32))
        da = res["kernel"]["decode_attn"]
        assert da["mode"] == "kernel"
        assert da["kv_blocks_read"] < da["kv_blocks_span"]
        assert da["kv_bytes_read_per_step"] < da["kv_bytes_span_per_step"]
        dg = res["gather"]["decode_attn"]
        assert dg["kv_blocks_read"] == dg["kv_blocks_span"]

    @pytest.mark.parametrize("arch", ["deepseek_moe_16b", "zamba2_7b",
                                      "seamless_m4t_medium"])
    def test_engine_kernel_parity_other_attention_families(self, arch):
        """moe / hybrid / encdec thread cfg.decode_attn through the same
        shared attention — and their reduced configs are MHA (rep 1),
        the head layout whose 1-row contraction XLA lowers through a
        different-association emitter; decode_attention pads the
        replica axis to two rows on both paths so the streams still
        match bit for bit."""
        cfg = dataclasses.replace(reduced(get_config(arch)),
                                  head_entropy="operand")
        assert cfg.num_heads // cfg.num_kv_heads == 1    # MHA regression
        params = M.init_params(jax.random.key(0), cfg)
        prompts = np.asarray(
            jax.random.randint(jax.random.key(1), (4, 10), 0,
                               cfg.vocab_size), np.int32)

        def reqs():
            return [_req(i, prompts[i][:(10 if i % 2 == 0 else 7)],
                         (6, 4)[i % 2]) for i in range(4)]

        res = {}
        for mode in ("gather", "kernel"):
            eng = ServeEngine(params, cfg, num_slots=2, max_len=24,
                              chunk=4, kv_layout="paged", kv_block=8,
                              decode_attn=mode)
            res[mode] = eng.run(reqs())
        for a, b in zip(res["gather"]["requests"],
                        res["kernel"]["requests"]):
            np.testing.assert_array_equal(a.tokens, b.tokens)
            np.testing.assert_array_equal(np.asarray(a.MI, np.float32),
                                          np.asarray(b.MI, np.float32))

    def test_engine_kernel_matches_gather_prefix_cache_cow(self, setup):
        """Shared-system-prompt traffic with --prefix-cache on: hits map
        read-only blocks, partial tails copy-on-write — the kernel must
        reproduce the gather streams bit for bit through all of it."""
        cfg, params, _ = setup
        sysp = np.asarray(jax.random.randint(jax.random.key(2), (20,), 0,
                                             cfg.vocab_size), np.int32)
        uniq = np.asarray(jax.random.randint(jax.random.key(3), (8, 6), 0,
                                             cfg.vocab_size), np.int32)

        def reqs():
            return [_req(i, np.concatenate([sysp, uniq[i]]), 8)
                    for i in range(8)]

        res = {}
        for mode in ("gather", "kernel"):
            eng = ServeEngine(params, cfg, num_slots=2, max_len=40,
                              chunk=4, kv_layout="paged", kv_block=8,
                              kv_blocks=20, prefix_cache=True,
                              decode_attn=mode)
            res[mode] = eng.run(reqs())
        pc = res["kernel"]["prefix_cache"]
        assert pc["hits"] > 0 and pc["cow_copies"] > 0  # CoW exercised
        for a, b in zip(res["gather"]["requests"],
                        res["kernel"]["requests"]):
            np.testing.assert_array_equal(a.tokens, b.tokens)
            np.testing.assert_array_equal(np.asarray(a.MI, np.float32),
                                          np.asarray(b.MI, np.float32))


# ---------------------------------------------------------------------------
# unmapped-entry fallback: masked means masked, on both read paths
# ---------------------------------------------------------------------------

class TestUnmappedMasking:
    def test_mapped_span_clamps_depth_to_leading_mapped_blocks(self):
        bt = jnp.asarray([[2, 5, -1, 7],      # mapped prefix = 2 blocks
                          [-1, -1, -1, -1],   # junk row
                          [1, 3, 6, -1]], jnp.int32)
        eff = L.mapped_span(bt, 4, jnp.asarray([14, 9, 10]))
        np.testing.assert_array_equal(np.asarray(eff), [8, 0, 10])

    def test_unmapped_fallback_never_leaks_block0(self):
        """Physical block 0 may be OWNED by the prefix cache.  A slot
        whose depth outruns its mapped prefix (evicted: all -1) gathers
        block 0 as a fallback — poisoning block 0 must not move a
        single bit of any live slot, and the junk slot must come out
        fully masked (NaN), on BOTH read paths."""
        q, kp, vp = _pools(jax.random.key(3))
        bt = np.full((3, 5), -1, np.int32)
        bt[0, :4] = [5, 1, 9, 3]
        bt[1, :3] = [7, 2, 6]             # no block 0 anywhere mapped
        bt = jnp.asarray(bt)
        cl = jnp.asarray([27, 18, 6], jnp.int32)  # slot 2: junk depth
        kp_bad = kp.at[0].set(1e4)        # "cached bytes" of another user
        vp_bad = vp.at[0].set(-1e4)
        for impl in ("ref", "auto"):
            clean = ops.paged_decode_attention(q, kp, vp, bt, cl,
                                               impl=impl)
            poisoned = ops.paged_decode_attention(q, kp_bad, vp_bad, bt,
                                                  cl, impl=impl)
            np.testing.assert_array_equal(np.asarray(clean)[:2],
                                          np.asarray(poisoned)[:2])
            assert np.isnan(np.asarray(poisoned)[2]).all()


# ---------------------------------------------------------------------------
# chunk-size invariance of seeded decode
# ---------------------------------------------------------------------------

class TestChunkInvariance:
    @pytest.mark.parametrize("mode", ["gather", "kernel"])
    def test_chunk_4_vs_16_same_tokens(self, setup, mode):
        """The per-step key folds the GLOBAL step index, so requests
        admitted together decode the same stream no matter how many
        steps share a device call; junk steps a finished request runs
        to its chunk boundary land past the mapped span and change
        nothing."""
        cfg, params, prompts = setup

        def reqs():
            return [_req(i, prompts[i], 8) for i in range(4)]

        streams = []
        for chunk in (4, 16):
            eng = ServeEngine(params, cfg, num_slots=4, max_len=24,
                              chunk=chunk, kv_layout="paged", kv_block=8,
                              decode_attn=mode)
            streams.append([r.tokens for r in eng.run(reqs())["requests"]])
        assert streams[0] == streams[1]


# ---------------------------------------------------------------------------
# engine robustness + stats honesty
# ---------------------------------------------------------------------------

class TestEngineRobustness:
    def test_kernel_mode_requires_paged_layout(self, setup):
        """An explicit kernel request on the dense layout is a config
        contradiction, not a silent downgrade (the family fallback —
        e.g. ssm — still degrades quietly, like its dense fallback)."""
        cfg, params, _ = setup
        with pytest.raises(ValueError, match="paged"):
            ServeEngine(params, cfg, num_slots=2, max_len=32, chunk=4,
                        kv_layout="dense", decode_attn="kernel")

    def test_mid_run_exception_releases_blocks(self, setup):
        """A crash mid-decode must not strand blocks: the except path
        evicts live slots and the finally leak check still balances —
        in_use equals exactly the prefix cache's refcounted holdings."""
        cfg, params, prompts = setup
        eng = ServeEngine(params, cfg, num_slots=2, max_len=32, chunk=4,
                          kv_layout="paged", kv_block=8,
                          prefix_cache=True, decode_attn="kernel")
        orig, calls = eng._scan, []

        def boom(*args):
            calls.append(1)
            if len(calls) == 2:
                raise RuntimeError("injected failure")
            return orig(*args)

        eng._scan = boom
        with pytest.raises(RuntimeError, match="injected failure"):
            eng.run([_req(i, prompts[i], 8) for i in range(6)])
        alloc, pcache = eng._last_alloc, eng._last_pcache
        assert alloc._reserved == 0
        assert alloc.in_use == pcache.cached_blocks()
        assert alloc.in_use > 0           # evictions donated to the tree

    def test_sched_trace_downsampled_by_trace_every(self, setup):
        cfg, params, prompts = setup

        def run(trace_every):
            eng = ServeEngine(params, cfg, num_slots=2, max_len=32,
                              chunk=4, kv_layout="paged", kv_block=8,
                              trace_every=trace_every)
            return eng.run([_req(i, prompts[i], 8) for i in range(6)])

        full = run(1)
        sparse = run(3)
        assert len(full["sched_trace"]) == full["chunks_run"]
        assert len(sparse["sched_trace"]) == -(-sparse["chunks_run"] // 3)
        assert sparse["sched_trace_every"] == 3

    def test_latency_tail_is_nearest_rank_plus_max(self, setup):
        """At 6 requests a linear-interpolated p99 is a fabricated
        number between the two slowest requests; nearest-rank reports a
        latency someone actually experienced (= the max below 100
        requests), and the max rides along explicitly."""
        cfg, params, prompts = setup
        eng = ServeEngine(params, cfg, num_slots=2, max_len=32, chunk=4)
        res = eng.run([_req(i, prompts[i], 8) for i in range(6)])
        lats = [r.latency_s for r in res["requests"]]
        assert res["latency_max_s"] == max(lats)
        assert res["latency_p99_s"] in lats
        assert res["latency_p99_s"] == max(lats)
