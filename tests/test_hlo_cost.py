"""Trip-count-aware HLO cost model: validation against XLA cost_analysis.

The key property: on an UNROLLED program (no while loops) our accounting
must track XLA's own cost_analysis; on the SCANNED version of the same
model it must still report the unrolled totals (XLA's counts collapse by
the trip count — the bug this module exists to fix).
"""

import dataclasses

import jax
import pytest

from repro.configs.registry import get_config, reduced
from repro.launch.hlo_cost import HloCost, analyze, parse_module
from repro.models import registry as M


@pytest.fixture(scope="module")
def compiled_pair():
    key = jax.random.key(0)
    cfg0 = reduced(get_config("qwen2_1_5b"))

    def compile_for(cfg):
        params = jax.eval_shape(lambda: M.init_params(key, cfg))
        batch = M.make_batch_specs(cfg, 2, 64)
        return jax.jit(jax.grad(
            lambda p, b: M.nll_loss(p, cfg, b, key)[0])).lower(
                params, batch).compile()

    unrolled = compile_for(dataclasses.replace(
        cfg0, scan_layers=False, remat=False, num_layers=4))
    scanned = compile_for(dataclasses.replace(
        cfg0, scan_layers=True, remat=False, num_layers=4))
    return unrolled, scanned


def _xla_cost(compiled):
    ca = compiled.cost_analysis()
    return ca[0] if isinstance(ca, (list, tuple)) else ca


def test_flops_match_xla_on_unrolled(compiled_pair):
    unrolled, _ = compiled_pair
    mine = analyze(unrolled.as_text())
    xla = _xla_cost(unrolled)["flops"]
    assert abs(mine["flops"] - xla) / xla < 0.15


def test_bytes_match_xla_on_unrolled(compiled_pair):
    unrolled, _ = compiled_pair
    mine = analyze(unrolled.as_text())
    xla = _xla_cost(unrolled)["bytes accessed"]
    assert 0.5 < mine["bytes"] / xla < 2.0


def test_scan_recovers_unrolled_flops(compiled_pair):
    """THE fix: scanned program reports the same total flops as unrolled,
    while XLA's own cost_analysis under-reports by ~the trip count."""
    unrolled, scanned = compiled_pair
    mine_u = analyze(unrolled.as_text())["flops"]
    mine_s = analyze(scanned.as_text())["flops"]
    assert abs(mine_s - mine_u) / mine_u < 0.05
    xla_s = _xla_cost(scanned)["flops"]
    assert xla_s < 0.6 * mine_s  # demonstrates XLA's undercount


def test_scan_bytes_within_band(compiled_pair):
    unrolled, scanned = compiled_pair
    mine_u = analyze(unrolled.as_text())["bytes"]
    mine_s = analyze(scanned.as_text())["bytes"]
    assert 0.8 < mine_s / mine_u < 2.5


def test_flops_scale_linearly_in_depth():
    key = jax.random.key(1)
    cfg0 = reduced(get_config("qwen2_1_5b"))

    def flops_at(L):
        cfg = dataclasses.replace(cfg0, scan_layers=True, remat=False,
                                  num_layers=L)
        params = jax.eval_shape(lambda: M.init_params(key, cfg))
        batch = M.make_batch_specs(cfg, 2, 64)
        c = jax.jit(jax.grad(
            lambda p, b: M.nll_loss(p, cfg, b, key)[0])).lower(
                params, batch).compile()
        return analyze(c.as_text())["flops"]

    f4, f8 = flops_at(4), flops_at(8)
    per_layer = (f8 - f4) / 4
    base = f4 - 4 * per_layer
    assert per_layer > 0 and base >= 0
    assert 1.7 < f8 / f4 < 2.0   # near-linear with a base offset


def test_parse_module_structure():
    hlo = """
%fused_add (p0: f32[4], p1: f32[4]) -> f32[4] {
  %p0 = f32[4]{0} parameter(0)
  %p1 = f32[4]{0} parameter(1)
  ROOT %a = f32[4]{0} add(%p0, %p1)
}

ENTRY %main (x: f32[4]) -> f32[4] {
  %x = f32[4]{0} parameter(0)
  ROOT %f = f32[4]{0} fusion(%x, %x), kind=kLoop, calls=%fused_add
}
"""
    comps, entry, types = parse_module(hlo)
    assert entry == "main"
    assert "fused_add" in comps
    assert types["f"] == "f32[4]{0}"


def test_while_multiplier_synthetic():
    hlo = """
%body (t: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %t = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%t), index=0
  %w = f32[8,8]{1,0} get-tuple-element(%t), index=1
  %d = f32[8,8]{1,0} dot(%w, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %r = (s32[], f32[8,8]) tuple(%i, %d)
}

%cond (t: (s32[], f32[8,8])) -> pred[] {
  %t = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%t), index=0
  ROOT %c = pred[] compare(%i, %i), direction=LT
}

ENTRY %main (x: f32[8,8]) -> f32[8,8] {
  %x = f32[8,8]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %t0 = (s32[], f32[8,8]) tuple(%zero, %x)
  %wh = (s32[], f32[8,8]) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
  ROOT %out = f32[8,8]{1,0} get-tuple-element(%wh), index=1
}
"""
    c = HloCost(hlo)
    # one 8x8x8 dot per trip, 10 trips
    assert c.flops == 10 * 2 * 8 * 8 * 8


def test_collective_accounting_synthetic():
    hlo = """
ENTRY %main (x: bf16[128,256]) -> bf16[2048,256] {
  %x = bf16[128,256]{1,0} parameter(0)
  ROOT %ag = bf16[2048,256]{1,0} all-gather(%x), replica_groups={}
}
"""
    c = HloCost(hlo)
    assert c.coll["all-gather"]["bytes"] == (2048 - 128) * 256 * 2
    assert c.coll["all-gather"]["count"] == 1
