"""Docs stay true or the build breaks.

Three classes of grep-able anchors in ``README.md`` and ``docs/*.md``:

  * relative markdown links must resolve on disk;
  * backticked file paths (``src/repro/...py`` etc.) must exist;
  * backticked test anchors (``tests/test_x.py::TestC::test_f``) must
    name a real file and real ``class``/``def`` symbols in it;
  * backticked CLI flags (``--kv-layout``) must be defined somewhere in
    the code — an argparse add_argument literal, or a ``--flag=value``
    spelling for env-var style flags (``XLA_FLAGS=--xla_force_...``)
    that are never quoted bare.

This is the CI docs job (see .github/workflows/ci.yml) and part of
tier-1, so renaming a flag, moving a module, or deleting a test that a
doc cites fails immediately instead of rotting silently.
"""

import re
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent
DOC_FILES = [ROOT / "README.md"] + sorted((ROOT / "docs").glob("*.md"))

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)]+)\)")
CODE_SPAN_RE = re.compile(r"`([^`\n]+)`")
PATH_RE = re.compile(r"^[\w./-]+\.(?:py|md|json|toml|yml|yaml)$")
TEST_ANCHOR_RE = re.compile(r"^([\w./-]+\.py)((?:::[\w\[\]-]+)+)$")
# underscores included so --xla_force_host_platform_device_count parses
# as ONE flag instead of stopping at --xla
FLAG_RE = re.compile(r"--[a-z][a-z0-9_-]*")

# flags argparse provides for free
BUILTIN_FLAGS = {"--help"}


def _docs():
    assert DOC_FILES, "no docs found"
    return [(p, p.read_text()) for p in DOC_FILES]


def _without_fences(text: str) -> str:
    return re.sub(r"```.*?```", "", text, flags=re.S)


@pytest.fixture(scope="module")
def code_text():
    """Concatenated source of every .py in the repo (flag lookup)."""
    chunks = []
    for sub in ("src", "benchmarks", "examples", "tests"):
        for p in sorted((ROOT / sub).rglob("*.py")):
            chunks.append(p.read_text())
    return "\n".join(chunks)


@pytest.mark.parametrize("doc", DOC_FILES, ids=lambda p: p.name)
def test_relative_links_resolve(doc):
    text = _without_fences(doc.read_text())
    for target in LINK_RE.findall(text):
        target = target.split()[0]            # drop '... "title"' forms
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        target = target.split("#")[0]
        if not target:
            continue                          # same-file fragment
        resolved = (doc.parent / target).resolve()
        assert resolved.exists(), \
            f"{doc.name}: dangling link -> {target}"


@pytest.mark.parametrize("doc", DOC_FILES, ids=lambda p: p.name)
def test_backticked_paths_exist(doc):
    text = _without_fences(doc.read_text())
    for span in CODE_SPAN_RE.findall(text):
        token = span.strip().split("::")[0]
        if "/" in token and PATH_RE.match(token):
            assert (ROOT / token).exists(), \
                f"{doc.name}: code path `{token}` does not exist"


@pytest.mark.parametrize("doc", DOC_FILES, ids=lambda p: p.name)
def test_test_anchors_point_at_real_tests(doc):
    text = _without_fences(doc.read_text())
    seen = 0
    for span in CODE_SPAN_RE.findall(text):
        m = TEST_ANCHOR_RE.match(span.strip())
        if not m:
            continue
        seen += 1
        path, parts = m.group(1), m.group(2).strip(":").split("::")
        f = ROOT / path
        assert f.exists(), f"{doc.name}: anchor file {path} missing"
        src = f.read_text()
        for name in parts:
            name = name.split("[")[0]         # strip parametrize ids
            assert re.search(rf"^\s*(?:class|def)\s+{re.escape(name)}\b",
                             src, re.M), \
                f"{doc.name}: `{span}` — no class/def {name} in {path}"
    if doc.parent.name == "docs":
        assert seen > 0, f"{doc.name}: every claim needs a test anchor"


@pytest.mark.parametrize("doc", DOC_FILES, ids=lambda p: p.name)
def test_cli_flags_exist_in_code(doc, code_text):
    for flag in set(FLAG_RE.findall(doc.read_text())):
        if flag in BUILTIN_FLAGS:
            continue
        assert (f'"{flag}"' in code_text or f"'{flag}'" in code_text
                or f"{flag}=" in code_text), \
            f"{doc.name}: flag {flag} not defined anywhere in the code"
