"""Shared engine fixtures for the serving test modules.

test_serve / test_paged_kv / test_prefix_cache / test_chunked_prefill /
test_mesh_runner / test_spec_decode all start from the same
ingredients — a reduced operand-entropy config for one attention
family, seed-0 params, and a fixed prompt pool — and build ServeEngine
instances varying along (family, kv-layout, prefill mode, decode-attn,
mesh).  Those ingredients live here once: ``family_setup`` is lru-cached
so each family's params initialize a single time across the whole run,
and ``engine_kwargs`` is the parametrized factory for the engine's
keyword matrix.  tests/ is the pytest rootdir, so plain helpers are
importable too (``from conftest import family_setup, ...``), same as
``_hypothesis_compat``.
"""

import dataclasses
import functools

import jax
import numpy as np
import pytest

from repro.configs.registry import get_config, reduced
from repro.launch.engine import Request, resolve_mesh
from repro.models import registry as M

# one representative reduced arch per attention family
FAMILY_ARCHS = {
    "dense": "qwen2_1_5b",
    "moe": "deepseek_moe_16b",
    "hybrid": "zamba2_7b",
    "encdec": "seamless_m4t_medium",
    "ssm": "mamba2_370m",
    "vlm": "phi_3_vision_4_2b",
}


def make_request(rid, prompt, n):
    return Request(rid=rid, prompt=np.asarray(prompt, np.int32),
                   max_new_tokens=n)


def operand_cfg(arch):
    """Reduced config pinned to operand entropy — the mode whose decode
    noise is a pure function of (slot, depth), i.e. the mode every
    bitwise engine-equivalence test (and spec decode) runs in."""
    return dataclasses.replace(reduced(get_config(arch)),
                               head_entropy="operand")


@functools.lru_cache(maxsize=None)
def family_setup(family="dense", seed=0, num_prompts=6, prompt_len=12):
    """(cfg, params, prompts) for one attention family, shared across
    every module in the run (init_params dominates setup time)."""
    cfg = operand_cfg(FAMILY_ARCHS[family])
    key = jax.random.key(seed)
    params = M.init_params(key, cfg)
    prompts = np.asarray(
        jax.random.randint(key, (num_prompts, prompt_len), 0,
                           cfg.vocab_size), np.int32)
    return cfg, params, prompts


def engine_kwargs(*, kv_layout="paged", kv_block=8, prefill="batch",
                  decode_attn="gather", mesh=None, num_slots=2,
                  max_len=32, chunk=4, **extra):
    """ServeEngine keyword set along the test matrix's axes.

    ``mesh`` accepts the CLI's string form ("1x4") or an already-built
    mesh; everything else passes straight through, so invalid
    combinations (chunked prefill on dense KV, ...) still hit the
    engine's own validation."""
    kw = dict(num_slots=num_slots, max_len=max_len, chunk=chunk,
              kv_layout=kv_layout, kv_block=kv_block,
              prefill_mode=prefill, decode_attn=decode_attn,
              mesh=resolve_mesh(mesh) if isinstance(mesh, str) else mesh)
    kw.update(extra)
    return kw


@pytest.fixture(scope="session")
def setup():
    """The dense-family (cfg, params, prompts) triple most engine
    modules share (overridden where a module needs different shapes)."""
    return family_setup("dense")
